"""Diff a fresh ``bench_results.json`` against the committed baseline.

    python benchmarks/check_regression.py fresh.json baseline.json \
        [--threshold 0.20] [--timing-threshold 0.50]

Rows are matched on their identity keys (figure + mode/fg/bg/
balance_factor/batch/dataset/variant); metric columns are compared with
a relative tolerance.  Exit 1 on any metric regressing by more than the
tolerance.  Rows present in only one file are reported but do not fail
the check (figures are added over time; the baseline only pins what it
has seen).

Two tolerances, because the two metric families have very different
variance on a single-core CI runner: quality metrics
(recall/final_recall/small_frac) are near-deterministic and get the
tight ``--threshold``; timing metrics (tps/qps) are noisy and get the
loose ``--timing-threshold``.  This is what let CI promote the check to
BLOCKING after two PRs of variance data (see .github/workflows/ci.yml).
"""
from __future__ import annotations

import argparse
import json
import sys

THRESHOLD = 0.20
TIMING_THRESHOLD = 0.50
ID_KEYS = ("figure", "mode", "dataset", "batch", "fg", "bg",
           "balance_factor", "variant", "stream", "rebalance", "shards",
           "workers")
# metric -> direction ("up" = larger is better).  occ_spread is the
# figskew per-shard occupancy ratio max/mean (bounded by the shard
# count, unlike max/min which explodes on an empty shard) — it gets the
# tight quality tolerance: a rebalance regression shows up as the
# zipf/on spread creeping toward the zipf/off ceiling.  The figmem
# device-bytes columns are pinned the same way: a cold-tier regression
# (spilling stops, or the watermark stops holding) reads as the tier-on
# ``vec_device_mb`` / ``device_mb`` rows creeping back toward tier-off.
METRICS = {"tps": "up", "qps": "up", "recall": "up", "final_recall": "up",
           "small_frac": "down", "occ_spread": "down",
           "device_mb": "down", "vec_device_mb": "down",
           "p99_ms": "down", "overhead_pct": "down", "live_recall": "up"}
TIMING_METRICS = {"tps", "qps", "p99_ms", "overhead_pct"}
# below this absolute scale, relative comparison is meaningless noise.
# overhead_pct's floor IS the acceptance bar: the figserve batched-obs
# row pins the QPS cost of the observability plane, and any value <= 5%
# passes outright no matter what the baseline measured.
ABS_FLOOR = {"small_frac": 0.02, "recall": 0.05, "final_recall": 0.05,
             "occ_spread": 0.0, "device_mb": 0.1, "vec_device_mb": 0.02,
             "p99_ms": 0.5, "overhead_pct": 5.0, "live_recall": 0.05}


def row_key(row: dict) -> tuple:
    return tuple((k, row[k]) for k in ID_KEYS if k in row)


def compare(fresh: list, baseline: list, threshold: float = THRESHOLD,
            timing_threshold: float = TIMING_THRESHOLD,
            min_matched: int = 0) -> int:
    """``min_matched`` guards the *baseline coverage itself*: a check
    whose identity keys silently stop matching (e.g. figskew rows keyed
    by shard count when the fake-device flag stops taking effect) would
    otherwise pass vacuously with 0 comparisons."""
    base = {row_key(r): r for r in baseline}
    failures, checked, matched = [], 0, 0
    for row in fresh:
        b = base.get(row_key(row))
        if b is None:
            continue
        matched += 1
        for metric, direction in METRICS.items():
            if metric not in row or metric not in b:
                continue
            new, old = float(row[metric]), float(b[metric])
            if new < 0 or old < 0:  # -1 = not evaluated
                continue
            checked += 1
            tol = (timing_threshold if metric in TIMING_METRICS
                   else threshold)
            floor = ABS_FLOOR.get(metric, 0.0)
            if max(abs(old), abs(new)) <= floor:
                continue
            if direction == "up":
                bad = new < old * (1 - tol)
            else:
                bad = new > old * (1 + tol) + floor
            if bad:
                failures.append(
                    f"  {dict(row_key(row))} {metric}: {old:g} -> {new:g}")
    print(f"regression check: {matched}/{len(fresh)} rows matched baseline, "
          f"{checked} metric comparisons, {len(failures)} regressions "
          f"(threshold {threshold:.0%}, timing {timing_threshold:.0%})")
    if matched < min_matched:
        print(f"VACUOUS: only {matched} rows matched the baseline "
              f"(--min-matched {min_matched}) — identity keys drifted?")
        return 1
    if failures:
        print("REGRESSIONS:")
        print("\n".join(failures))
        return 1
    return 0


def main(argv) -> int:
    ap = argparse.ArgumentParser(
        description="diff fresh benchmark rows against the baseline")
    ap.add_argument("fresh")
    ap.add_argument("baseline")
    ap.add_argument("--threshold", type=float, default=THRESHOLD,
                    help="relative tolerance for quality metrics")
    ap.add_argument("--timing-threshold", type=float,
                    default=TIMING_THRESHOLD,
                    help="relative tolerance for tps/qps (CI noise)")
    ap.add_argument("--min-matched", type=int, default=0,
                    help="fail if fewer fresh rows match the baseline "
                         "(guards against vacuous passes when identity "
                         "keys drift)")
    args = ap.parse_args(argv[1:])
    try:
        with open(args.fresh) as f:
            fresh = json.load(f)
        with open(args.baseline) as f:
            baseline = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_regression: cannot load inputs: {e}")
        return 2
    return compare(fresh, baseline, args.threshold, args.timing_threshold,
                   args.min_matched)


if __name__ == "__main__":
    sys.exit(main(sys.argv))
