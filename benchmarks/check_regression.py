"""Diff a fresh ``bench_results.json`` against the committed baseline.

    python benchmarks/check_regression.py bench_results.json BENCH_baseline.json

Rows are matched on their identity keys (figure + mode/fg/bg/
balance_factor/batch/dataset); metric columns are compared with a
relative tolerance.  Exit 1 on any metric regressing by more than
``THRESHOLD`` (20%).  Rows present in only one file are reported but do
not fail the check (figures are added over time; the baseline only pins
what it has seen).

Wired into CI as a *non-blocking* step for now: single-core CI runners
make TPS noisy, so the signal is advisory until variance is
characterised.  Recall/small_frac are near-deterministic and the ones to
watch.
"""
from __future__ import annotations

import json
import sys

THRESHOLD = 0.20
ID_KEYS = ("figure", "mode", "dataset", "batch", "fg", "bg",
           "balance_factor")
# metric -> direction ("up" = larger is better)
METRICS = {"tps": "up", "qps": "up", "recall": "up", "final_recall": "up",
           "small_frac": "down"}
# below this absolute scale, relative comparison is meaningless noise
ABS_FLOOR = {"small_frac": 0.02, "recall": 0.05, "final_recall": 0.05}


def row_key(row: dict) -> tuple:
    return tuple((k, row[k]) for k in ID_KEYS if k in row)


def compare(fresh: list, baseline: list) -> int:
    base = {row_key(r): r for r in baseline}
    failures, checked, matched = [], 0, 0
    for row in fresh:
        b = base.get(row_key(row))
        if b is None:
            continue
        matched += 1
        for metric, direction in METRICS.items():
            if metric not in row or metric not in b:
                continue
            new, old = float(row[metric]), float(b[metric])
            if new < 0 or old < 0:  # -1 = not evaluated
                continue
            checked += 1
            floor = ABS_FLOOR.get(metric, 0.0)
            if max(abs(old), abs(new)) <= floor:
                continue
            if direction == "up":
                bad = new < old * (1 - THRESHOLD)
            else:
                bad = new > old * (1 + THRESHOLD) + floor
            if bad:
                failures.append(
                    f"  {dict(row_key(row))} {metric}: {old:g} -> {new:g}")
    print(f"regression check: {matched}/{len(fresh)} rows matched baseline, "
          f"{checked} metric comparisons, {len(failures)} regressions "
          f"(threshold {THRESHOLD:.0%})")
    if failures:
        print("REGRESSIONS:")
        print("\n".join(failures))
        return 1
    return 0


def main(argv) -> int:
    if len(argv) != 3:
        print(__doc__)
        return 2
    try:
        with open(argv[1]) as f:
            fresh = json.load(f)
        with open(argv[2]) as f:
            baseline = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_regression: cannot load inputs: {e}")
        return 2
    return compare(fresh, baseline)


if __name__ == "__main__":
    sys.exit(main(sys.argv))
