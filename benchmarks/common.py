"""Shared harness for the paper-figure benchmarks.

Scale honesty (DESIGN.md §8): the paper runs 1M x 128-768d on NVMe with
16 vCPUs; this container is one CPU core, so defaults are 20k x 32d.
Relative claims (UBIS vs SPFresh on recall/TPS, distribution shapes,
parameter trade-offs) are the reproduction target.  ``--full`` scales up.

Every engine is built through ``repro.api.make_index`` and driven
through the ``StreamingIndex`` protocol — the workload loops below
contain ZERO engine-specific branches, which is what makes the
``figengines`` comparison (including ``ubis-sharded``) one loop over
engine names.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import numpy as np

from repro.api import make_index
from repro.core import UBISConfig, metrics
from repro.data import DriftingVectorStream, StaticVectorSet
from repro.obs import Histogram


@dataclasses.dataclass
class BenchScale:
    n: int = 20000
    dim: int = 32
    batches: int = 10
    queries: int = 128
    k: int = 10
    max_postings: int = 2048
    seed: int = 0


QUICK = BenchScale(n=8000, batches=8, queries=96, max_postings=1024)
FULL = BenchScale(n=100000, dim=64, batches=20, queries=256,
                  max_postings=8192)


def make_cfg(scale: BenchScale, mode: str = "ubis",
             balance_factor: float = 0.15, **kw):
    return UBISConfig(dim=scale.dim, max_postings=scale.max_postings,
                      capacity=96, l_min=10, l_max=80,
                      balance_factor=balance_factor,
                      cache_capacity=4096, max_ids=1 << 21,
                      use_pallas="off", mode=mode, **kw)


def make_driver(scale: BenchScale, engine: str, seed_vectors,
                balance_factor: float = 0.15, round_size: int = 512,
                bg_ops: int = 8, fg_threads: int = 1, obs=None):
    """Build any engine behind the one front door.

    fg_threads models the paper's foreground thread count: the
    foreground round budget per tick is fg_threads * round_size.
    Engine-specific construction (mode rewrite, GraphConfig translation,
    seed-corpus ingestion for the build-once engines) lives in the
    registry, not here."""
    cfg = make_cfg(scale, "ubis", balance_factor)
    return make_index(engine, cfg, seed_vectors,
                      seed_ids=np.arange(len(seed_vectors)),
                      seed=scale.seed,
                      round_size=round_size * fg_threads,
                      bg_ops_per_round=bg_ops, obs=obs,
                      max_nodes=max(2 * scale.n, 4096), degree=24, beam=40)


def eval_recall(drv, queries: np.ndarray, k: int,
                stream_vecs=None, stream_ids=None) -> float:
    """Recall vs. ground truth.

    With (stream_vecs, stream_ids): truth = exact k-NN over EVERYTHING
    streamed so far (paper semantics — an index that rejected/blocked
    fresh vectors pays for them in recall).  Otherwise truth = the
    index's own live contents via the engine's ``exact`` oracle."""
    found = drv.search(queries, k).ids
    if stream_vecs is not None:
        d2 = ((queries[:, None, :].astype(np.float32)
               - stream_vecs[None]) ** 2).sum(-1)
        order = np.argsort(d2, axis=1)[:, :k]
        true = np.asarray(stream_ids)[order]
        return metrics.recall_at_k(found, true)
    true = drv.exact(queries, k).ids
    return metrics.recall_at_k(found, np.asarray(true))


def timed_search(drv, queries: np.ndarray, k: int,
                 batch: int = 32) -> Dict:
    """Timed pure-search pass over ``queries`` in device batches.

    Records one *whole-batch* wall-clock span per dispatched batch into
    a log-bucket histogram.  The old loop stored ``span / batch`` (a
    per-query mean) and then took percentiles of those means, which
    collapsed the latency tail — a slow batch averaged down to look like
    32 mildly-slow queries.  Here the tail survives: ``p99_ms`` is the
    99th percentile of *batch* spans, and ``qps`` is total queries over
    total span (identical to the old figure for equal-size batches).
    """
    h = Histogram("search_batch_seconds")
    total = 0
    for off in range(0, len(queries), batch):
        q = queries[off:off + batch]
        t1 = time.perf_counter()
        drv.search(q, k)
        h.record(time.perf_counter() - t1)
        total += len(q)
    s = h.summary()
    return {
        "qps": total / s["sum"] if s["sum"] > 0 else 0.0,
        "p50_ms": s["p50"] * 1e3,
        "p99_ms": s["p99"] * 1e3,
        "mean_batch_ms": s["mean"] * 1e3,
        "search_batch": batch,
    }


def streaming_run(scale: BenchScale, engine: str,
                  dataset: str = "drift",
                  balance_factor: float = 0.15,
                  bg_ops: int = 8,
                  per_batch_eval: bool = True) -> List[Dict]:
    """The paper's *streaming update* workload: feed batches, evaluate
    after each (recall, TPS, QPS, memory, posting CDF stats)."""
    if dataset == "drift":
        stream = DriftingVectorStream(dim=scale.dim, seed=scale.seed)
        batches = [stream.next_batch(scale.n // scale.batches)
                   for _ in range(scale.batches)]
        queries = stream.queries(scale.queries)
    else:
        sset = StaticVectorSet(n=scale.n, dim=scale.dim, seed=scale.seed)
        batches = [v for _, v in sset.batches(scale.batches)]
        queries = sset.queries(scale.queries)

    seed_vecs = batches[0]
    l_min = make_cfg(scale).l_min      # small-posting threshold (fig5)
    drv = make_driver(scale, engine, seed_vecs, balance_factor,
                      bg_ops=bg_ops)
    # warm up compile paths outside timed regions
    drv.search(queries[:8], scale.k)
    records = []
    next_id = 0
    seen_v, seen_i = [], []
    for bi, batch in enumerate(batches):
        ids = np.arange(next_id, next_id + len(batch))
        next_id += len(batch)
        seen_v.append(batch)
        seen_i.append(ids)
        t0 = time.perf_counter()
        r = drv.insert(batch, ids)
        # background phases run continuously in the paper (4 threads);
        # give every engine the same bounded budget per batch
        drv.flush(max_ticks=6)
        t_upd = time.perf_counter() - t0
        rec = {}
        if per_batch_eval:
            t0 = time.perf_counter()
            recall = eval_recall(drv, queries, scale.k,
                                 np.concatenate(seen_v),
                                 np.concatenate(seen_i))
            # timed pure-search pass for QPS / P50 / P99
            ts = timed_search(drv, queries, scale.k)
            rec.update(recall=recall, qps=ts["qps"],
                       p50_ms=ts["p50_ms"], p99_ms=ts["p99_ms"])
        lens = drv.posting_lengths()
        rec.update(
            batch=bi,
            tps=(r.accepted + r.cached) / t_upd,
            accepted=r.accepted, cached=r.cached,
            rejected=r.rejected,
            memory_mb=drv.memory_bytes() / 2 ** 20,
            n_postings=len(lens),
            small_frac=float((lens < l_min).mean()) if len(lens) else 0.0,
            median_len=int(np.median(lens)) if len(lens) else 0,
        )
        records.append(rec)
    drv.flush(max_ticks=40)
    records[-1]["final_recall"] = eval_recall(
        drv, queries, scale.k, np.concatenate(seen_v),
        np.concatenate(seen_i))
    return records


def full_update_run(scale: BenchScale, engine: str,
                    dataset: str = "static") -> Dict:
    """The paper's *full update* workload (Table IV): append everything,
    then measure the final index."""
    sset = StaticVectorSet(n=scale.n, dim=scale.dim, seed=scale.seed)
    queries = sset.queries(scale.queries)
    drv = make_driver(scale, engine, sset.vectors[:2000])
    drv.search(queries[:8], scale.k)  # warm up
    t0 = time.perf_counter()
    r = drv.insert(sset.vectors, np.arange(scale.n))
    drv.flush(max_ticks=100)
    t_upd = time.perf_counter() - t0
    recall = eval_recall(drv, queries, scale.k, sset.vectors,
                         np.arange(scale.n))
    ts = timed_search(drv, queries, scale.k)
    return {
        "mode": engine,
        "recall": recall,
        "tps": (r.accepted + r.cached) / t_upd,
        "rejected": r.rejected,
        "memory_mb": drv.memory_bytes() / 2 ** 20,
        "qps": ts["qps"],
        "p50_ms": ts["p50_ms"],
        "p99_ms": ts["p99_ms"],
    }
