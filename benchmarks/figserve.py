"""figserve: open-loop serving — continuous batching vs the sync loop.

The serving claim of PR 6, measured: a Poisson stream of single-query
search requests (with ingest batches woven in) is served two ways over
the *same* engine build and the *same* seeded arrival trace:

  * ``sync``    — the pre-serving ``RetrievalServer`` shape: every
    query is one blocking one-row ``index.search`` call, every ingest
    batch is insert-then-tick, requests handled FIFO one at a time;
  * ``batched`` — ``repro.serving.ServingEngine``: fill-or-deadline
    batching folds requests into padded device batches, the update lane
    and cadence tick overlap the search dispatch→collect window.

**Virtual-clock accounting.**  Arrivals carry virtual timestamps from
the seeded Poisson process; every index call's compute time is measured
for real (``time.perf_counter``) and *added* to the virtual clock.
Queueing delay then emerges from measured service times — a request
that arrives while the server is busy waits — while the trace itself
replays deterministically (no sleeps, no wall-clock arrival jitter).
Latency for a request is completion minus *arrival* (admission lag
included), so an overloaded server shows its real queue growth.

Reported per mode: achieved ``qps`` (requests / virtual makespan),
``p50_ms`` / ``p99_ms`` arrival-to-completion latency, update ``tps``,
and ``recall`` of the final flushed index against exact k-NN over
everything streamed — the "equal recall" leg of the acceptance claim
(both modes index the identical stream).

A third mode, ``batched-obs``, reruns the batched engine with the
observability plane fully on (structured traces, request-span
histograms, and the sampled live-recall probe at 10% of served
batches) against ``batched`` running with the plane disabled.  Its
``overhead_pct`` column is the QPS cost of observing — the pinned
acceptance bar is <= 5% — and ``live_recall`` is the probe's rolling
gauge, which should agree with the offline ``recall`` column.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.obs import Histogram, Obs
from repro.serving import ServingConfig, ServingEngine

from .common import QUICK, BenchScale, eval_recall, make_driver


class VirtualClock:
    """Injectable clock: jumps to arrival/deadline times, advances by
    measured service seconds."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _make_trace(scale: BenchScale, offered_qps: float, seed: int = 0):
    """Seeded open-loop trace: Poisson search arrivals at
    ``offered_qps`` with ingest batches spread evenly across the span.
    Returns (events, queries, batches) — events are (t, kind, idx)
    sorted by time."""
    rng = np.random.default_rng(seed)
    n_search = scale.queries * 5
    n_stream = scale.n // 4
    n_batches = 8
    dim = scale.dim
    arrivals = np.cumsum(rng.exponential(1.0 / offered_qps, n_search))
    span = float(arrivals[-1])
    centers = rng.normal(size=(12, dim)).astype(np.float32) * 4.0
    assign = rng.integers(0, 12, n_stream + n_search)
    pool = (centers[assign]
            + rng.normal(size=(n_stream + n_search, dim))
            ).astype(np.float32)
    stream, queries = pool[:n_stream], pool[n_stream:]
    per = n_stream // n_batches
    batches = [(stream[i * per:(i + 1) * per],
                np.arange(i * per, (i + 1) * per))
               for i in range(n_batches)]
    ins_times = (np.arange(n_batches) + 0.5) * span / n_batches
    events = sorted(
        [(float(t), "search", i) for i, t in enumerate(arrivals)]
        + [(float(t), "insert", i) for i, t in enumerate(ins_times)])
    return events, queries, batches, stream


def _percentiles(lats: List[float]):
    """p50/p99 (ms) through the shared log-bucket histogram, so the
    figure reports the same quantile estimator the serving engine's
    request-span metrics export."""
    h = Histogram("figserve_latency_seconds")
    for v in lats:
        h.record(v)
    return h.quantile(0.5) * 1e3, h.quantile(0.99) * 1e3


def _run_sync(drv, events, queries, batches, k: int):
    """FIFO one-at-a-time service: start = max(arrival, prev done)."""
    clock = 0.0
    lats = []
    inserted = 0
    for t, kind, i in events:
        start = max(clock, t)
        t0 = time.perf_counter()
        if kind == "search":
            drv.search(queries[i:i + 1], k)
        else:
            vecs, ids = batches[i]
            r = drv.insert(vecs, ids)
            inserted += r.accepted + r.cached
            drv.tick()               # the old tick-per-ingest loop
        dt = time.perf_counter() - t0
        clock = start + dt
        if kind == "search":
            lats.append(clock - t)
    return lats, inserted, clock


def _run_batched(drv, events, queries, batches, k: int,
                 cfg: ServingConfig, obs: Obs = None):
    """Event loop on the virtual clock: admit arrivals, jump to
    ``min(next arrival, engine.next_deadline())``, pump when due —
    every pump's real compute time advances the clock."""
    vc = VirtualClock()
    engine = ServingEngine(drv, cfg, clock=vc, obs=obs)
    done: List[tuple] = []          # (arrival, ticket)
    inserted_box = [0]
    ei = 0
    while ei < len(events) or not engine.idle:
        while ei < len(events) and events[ei][0] <= vc.t:
            t, kind, i = events[ei]
            if kind == "search":
                done.append((t, engine.submit_search(queries[i], k)))
            else:
                vecs, ids = batches[i]
                tk = engine.submit_insert(vecs, ids)
                done.append((t, tk))
            ei += 1
        nd = engine.next_deadline()
        if nd is not None and nd <= vc.t:
            t0 = time.perf_counter()
            engine.pump()
            vc.advance(time.perf_counter() - t0)
            continue
        nxt = [x for x in (nd, events[ei][0] if ei < len(events)
                           else None) if x is not None]
        if not nxt:
            break
        vc.t = max(vc.t, min(nxt))
    lats = []
    for arrival, tk in done:
        # latency from *arrival*: admission lag + queue + service
        lat = tk.latency_s + (tk.t_submit - arrival)
        if tk.kind == "search":
            lats.append(lat)
        else:
            r = tk.result()
            inserted_box[0] += r.accepted + r.cached
    return lats, inserted_box[0], vc.t, engine


def figserve_serving(scale: BenchScale = QUICK,
                     offered_qps: float = 500.0) -> List[Dict]:
    """Paper-style serving figure: sync loop vs batching engine on one
    seeded open-loop trace; the acceptance bars are the batched row
    holding strictly higher achieved QPS at equal final recall, and the
    batched-obs row (full observability plane + live-recall probe) kept
    within 5% of the plane-off batched QPS."""
    events, queries, batches, stream = _make_trace(scale, offered_qps)
    stream_ids = np.arange(len(stream))
    k = scale.k

    def _warm_driver(obs):
        drv = make_driver(scale, "ubis", batches[0][0], obs=obs)
        drv.search(queries[:8], k)   # compile outside the timed region
        drv.search(np.zeros((32, scale.dim), np.float32), k)
        return drv

    def _batched_trials(obs_on: bool, n_trials: int):
        """Replay the trace ``n_trials`` times on fresh drivers and
        return every (qps, lats, inserted, makespan, eng, drv).

        Single-shot QPS on a one-core runner is ±20% noisy — far above
        the <=5% obs-overhead bar — so the batched/batched-obs
        comparison is made on median-of-trials QPS, and the reported
        row is the median trial."""
        out = []
        cfg = ServingConfig(search_batch=32, insert_batch=1024,
                            search_deadline_s=2e-3,
                            insert_deadline_s=10e-3,
                            tick_every=1, default_k=k,
                            recall_probe=0.1 if obs_on else 0.0,
                            recall_probe_rows=8)
        for _ in range(n_trials):
            obs = Obs(enabled=obs_on)
            drv = _warm_driver(obs)
            if obs_on:
                # the probe shadow-executes <=8 rows against exact();
                # warm that compile path too
                drv.exact(queries[:8], k)
            lats, inserted, makespan, eng = _run_batched(
                drv, events, queries, batches, k, cfg, obs=obs)
            out.append((len(lats) / makespan, lats, inserted, makespan,
                        eng, drv))
        return sorted(out, key=lambda t: t[0])

    def _finish_row(mode, lats, inserted, makespan, drv, extra):
        drv.flush(max_ticks=40)
        p50, p99 = _percentiles(lats)
        return {
            "figure": "figserve", "mode": mode,
            "offered_qps": offered_qps,
            "qps": round(len(lats) / makespan, 1),
            "p50_ms": round(p50, 3), "p99_ms": round(p99, 3),
            "tps": round(inserted / makespan, 1),
            "recall": round(eval_recall(drv, queries[:scale.queries], k,
                                        stream, stream_ids), 4),
            "n_search": len(lats),
            **extra,
        }

    rows = []
    # -- sync: the pre-serving blocking loop (plane on by default; its
    #    timed region also absorbs the shared insert/tick compiles) ----
    drv = _warm_driver(None)
    lats, inserted, makespan = _run_sync(drv, events, queries, batches, k)
    rows.append(_finish_row("sync", lats, inserted, makespan, drv, {}))

    # -- batched vs batched-obs: plane off vs full plane + probe, the
    #    obs-overhead comparison on median-of-3 replays ----------------
    trials = {on: _batched_trials(on, 3) for on in (False, True)}
    med_qps = {on: trials[on][len(trials[on]) // 2][0] for on in trials}
    for mode, obs_on in (("batched", False), ("batched-obs", True)):
        qps, lats, inserted, makespan, eng, drv = \
            trials[obs_on][len(trials[obs_on]) // 2]
        c = eng.counters
        extra = {
            "search_batches": c["search_batches"],
            "mean_fill": round(c["search_requests"]
                               / max(c["search_batches"], 1), 1),
            "deadline_fires": c["search_deadline"],
            "fill_fires": c["search_fill"],
        }
        if obs_on:
            snap = eng.obs.snapshot()
            extra.update(
                live_recall=round(float(
                    eng.probe.rolling_recall), 4) if eng.probe else -1,
                probes=int(snap.get("live_recall_probes", 0)),
                trace_events=len(eng.obs.tracer),
                overhead_pct=round(max(
                    0.0, (med_qps[False] - med_qps[True])
                    / max(med_qps[False], 1e-9) * 100), 2),
            )
        rows.append(_finish_row(mode, lats, inserted, makespan, drv,
                                extra))
    return rows
