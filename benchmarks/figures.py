"""One function per paper table/figure (deliverable d).

Each returns a list of CSV-able dict rows and is exposed through
``benchmarks.run``.  Scale flags: quick (default) / full.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from .common import (QUICK, BenchScale, full_update_run, make_cfg,
                     make_driver, streaming_run, timed_search, eval_recall)


def fig5_posting_cdf(scale: BenchScale = QUICK) -> List[Dict]:
    """Paper Fig. 5: posting-length distribution across update batches —
    SPFresh's small-posting accumulation vs UBIS."""
    rows = []
    for mode in ("spfresh", "ubis"):
        recs = streaming_run(scale, mode, dataset="drift",
                             per_batch_eval=False)
        for r in recs:
            rows.append({"figure": "fig5", "mode": mode,
                         "batch": r["batch"],
                         "small_frac": round(r["small_frac"], 4),
                         "median_len": r["median_len"],
                         "n_postings": r["n_postings"]})
    return rows


def fig6_streaming_recall(scale: BenchScale = QUICK) -> List[Dict]:
    """Paper Fig. 6: per-batch search accuracy + memory, streaming."""
    rows = []
    for dataset in ("drift", "static"):
        for mode in ("freshdiskann", "spfresh", "ubis"):
            recs = streaming_run(scale, mode, dataset=dataset)
            for r in recs:
                rows.append({"figure": "fig6", "dataset": dataset,
                             "mode": mode, "batch": r["batch"],
                             "recall": round(r.get("recall", -1), 4),
                             "memory_mb": round(r["memory_mb"], 1)})
    return rows


def fig7_streaming_throughput(scale: BenchScale = QUICK) -> List[Dict]:
    """Paper Fig. 7: per-batch update TPS + search QPS, streaming."""
    rows = []
    for mode in ("freshdiskann", "spfresh", "ubis"):
        recs = streaming_run(scale, mode, dataset="drift")
        for r in recs:
            rows.append({"figure": "fig7", "mode": mode,
                         "batch": r["batch"],
                         "tps": round(r["tps"], 1),
                         "qps": round(r.get("qps", -1), 1),
                         "p99_ms": round(r.get("p99_ms", -1), 2),
                         "rejected": r["rejected"]})
    return rows


def table4_full_update(scale: BenchScale = QUICK) -> List[Dict]:
    """Paper Table IV: full-update workload, final metrics."""
    rows = []
    for mode in ("freshdiskann", "spfresh", "ubis"):
        r = full_update_run(scale, mode)
        r["figure"] = "table4"
        r = {k: (round(v, 3) if isinstance(v, float) else v)
             for k, v in r.items()}
        rows.append(r)
    return rows


def fig8_fg_bg_ratio(scale: BenchScale = QUICK) -> List[Dict]:
    """Paper Fig. 8: foreground/background resource ratio.

    Threads -> phase budgets (DESIGN.md §2): foreground budget is the
    jobs/round; background budget is bg ops/tick.  Sweep the ratio.
    Also reports background-plane cost per structural op — the number the
    batched ``background_round`` is meant to drive down as bg grows (one
    device call per tick regardless of batch size)."""
    import time
    from repro.data import DriftingVectorStream
    rows = []
    for fg, bg in [(1, 1), (1, 2), (1, 4), (1, 8), (2, 8), (4, 8)]:
        stream = DriftingVectorStream(dim=scale.dim, seed=scale.seed)
        batches = [stream.next_batch(scale.n // scale.batches)
                   for _ in range(scale.batches)]
        queries = stream.queries(scale.queries)
        drv = make_driver(scale, "ubis", batches[0],
                          round_size=256 * fg, bg_ops=bg)
        drv.search(queries[:8], scale.k)
        # warm the background_round compile for THIS batch width: a tick
        # on a fresh driver only marks (two-phase), so an all-padding
        # round is the only way to get the compile out of the timed loop
        from repro.core import balance as _balance
        import jax.numpy as _jnp
        B = max(bg, 1)
        _balance.background_round(
            drv.state, drv.cfg, _jnp.zeros(B, _jnp.int32),
            _jnp.full(B, -1, _jnp.int32))
        nid = 0
        t0 = time.perf_counter()
        n_ins = 0
        for b in batches:
            r = drv.insert(b, np.arange(nid, nid + len(b)))
            nid += len(b)
            n_ins += r.accepted + r.cached
            drv.tick()
        tps = n_ins / (time.perf_counter() - t0)
        t0 = time.perf_counter()
        drv.search(queries, scale.k)
        qps = scale.queries / (time.perf_counter() - t0)
        rec = eval_recall(drv, queries, scale.k)
        bg_ops = max(drv.stats["bg_ops"], 1)
        rows.append({"figure": "fig8", "fg": fg, "bg": bg,
                     "tps": round(tps, 1), "qps": round(qps, 1),
                     "recall": round(rec, 4),
                     "bg_ops": int(drv.stats["bg_ops"]),
                     # background_round execution cost only (bg_exec_time
                     # excludes detect/drain/GC scheduler overhead)
                     "bg_ms_per_op": round(
                         drv.stats["bg_exec_time"] * 1e3 / bg_ops, 2)})
    return rows


def figpq_memory_recall(scale: BenchScale = QUICK) -> List[Dict]:
    """New axis beyond the paper: per-vector posting bytes vs recall@10
    vs QPS for the quant plane (use_pq) against the float oracle.

    Sweeps the subspace count m (bytes/vector = m for PQ, 4*dim for
    float).  The workload is the fig5 streaming-drift run; recall is
    measured against exact truth over everything streamed."""
    import dataclasses
    import time
    from repro.core import UBISConfig, UBISDriver, state_memory_bytes
    from repro.data import DriftingVectorStream
    rows = []
    variants = [("float", scale, {})]
    for m in (scale.dim // 8, scale.dim // 4, scale.dim // 2):
        variants.append((f"pq-m{m}", scale, dict(use_pq=True, pq_m=m,
                                                 rerank_k=192)))
    # real-world misaligned dim: d=100 (not a lane multiple) rides the
    # exact same fused scan/rerank path — the kernels are alignment-
    # free — so its quality row is pinned in the baseline alongside the
    # aligned sweeps
    variants.append(("pq-d100-m10", dataclasses.replace(scale, dim=100),
                     dict(use_pq=True, pq_m=10, rerank_k=192)))
    for name, vscale, pq_kw in variants:
        stream = DriftingVectorStream(dim=vscale.dim, seed=vscale.seed)
        batches = [stream.next_batch(vscale.n // vscale.batches)
                   for _ in range(vscale.batches)]
        queries = stream.queries(vscale.queries)
        cfg = make_cfg(vscale, "ubis", **pq_kw)
        drv = UBISDriver(cfg, batches[0], round_size=512, bg_ops_per_round=8,
                         seed=vscale.seed, pq_retrain_every=8)
        # warm the compile at the MEASURED query-batch shape, so the
        # timed loop never pays trace+compile (it differs per variant)
        drv.search(queries[:32], vscale.k)
        nid = 0
        seen_v, seen_i = [], []
        for b in batches:
            ids = np.arange(nid, nid + len(b))
            nid += len(b)
            seen_v.append(b)
            seen_i.append(ids)
            drv.insert(b, ids)
            drv.flush(max_ticks=6)
        drv.flush(max_ticks=40)
        recall = eval_recall(drv, queries, vscale.k,
                             np.concatenate(seen_v), np.concatenate(seen_i))
        ts = timed_search(drv, queries, vscale.k)
        # phase-2 bytes actually scanned per vector: float tiles vs codes
        bpv = cfg.pq_m if cfg.use_pq else cfg.dim * 4
        rows.append({"figure": "figpq", "variant": name,
                     "bytes_per_vector": bpv,
                     "compression_x": round(cfg.dim * 4 / bpv, 1),
                     "recall": round(recall, 4),
                     "qps": round(ts["qps"], 1),
                     "p99_ms": round(ts["p99_ms"], 2),
                     "memory_mb": round(
                         state_memory_bytes(drv.state) / 2 ** 20, 1),
                     "pq_retrains": int(drv.stats["pq_retrains"])})
    return rows


def figengines_comparison(scale: BenchScale = QUICK) -> List[Dict]:
    """Beyond the paper's two-way plots: ALL engines under the identical
    streaming-churn workload, one loop over engine names through
    ``make_index`` — zero engine-specific branches (the point of the
    ``StreamingIndex`` front door).  ``spann`` honestly pays for its
    refused updates in recall; ``ubis-sharded`` runs the distributed
    driver on however many local devices exist (1 in CI)."""
    from repro.api import ENGINES
    rows = []
    for engine in ENGINES:
        recs = streaming_run(scale, engine, dataset="drift")
        last = recs[-1]
        rows.append({
            "figure": "figengines", "mode": engine,
            "final_recall": round(last["final_recall"], 4),
            "mean_tps": round(float(np.mean([r["tps"] for r in recs])), 1),
            "mean_qps": round(float(np.mean(
                [r["qps"] for r in recs if "qps" in r])), 1),
            "rejected": int(sum(r["rejected"] for r in recs)),
            "memory_mb": round(last["memory_mb"], 1),
            "n_postings": last["n_postings"],
        })
    return rows


def figmem_cold_tier(scale: BenchScale = QUICK) -> List[Dict]:
    """Beyond the paper: the cold-tier (host spill) axis — device HBM vs
    recall vs QPS on a cold-heavy stream, tiering off vs on.

    The workload streams a wide cluster mixture but QUERIES only a small
    hot subset, the regime the FreshDiskANN billion-scale tier targets:
    the cold majority decays to heat 0 and the watermark spills their
    float tiles to the pinned host pool (codes stay device-resident);
    the hot working set keeps the bit-identical float path.

    Two device-bytes figures per row, both honest:
      * ``device_mb``   — the full ``memory_tiers()['device']`` split
        (fixed-shape JAX pools included, so it understates the win);
      * ``vec_device_mb`` — float-tile bytes of LIVE postings resident
        on device (hot tiles only), the payload a paging allocator
        holds per tier and the acceptance metric: >= 4x lower with
        tiering on, at recall within 2 points of the all-float run.
    """
    import time

    from repro.api import make_index
    from repro.core import version_manager as vm
    from repro.core.types import tile_bytes

    rng = np.random.default_rng(scale.seed)
    K, K_hot = 48, 4
    cents = (rng.normal(size=(K, scale.dim)) * 6).astype(np.float32)
    a = rng.integers(0, K, scale.n)
    data = (cents[a] + rng.normal(size=(scale.n, scale.dim))
            ).astype(np.float32)
    # the query working set touches only the hot clusters
    qa = rng.integers(0, K_hot, scale.queries)
    queries = (cents[qa] + rng.normal(size=(scale.queries, scale.dim))
               ).astype(np.float32)

    rows = []
    for variant, tier_kw in (("tier-off", {}),
                             ("tier-on", dict(use_tier=True,
                                              tier_hot_max=24))):
        # nprobe stays narrow: the probe set IS the heat signal, so a
        # wide probe would keep cold postings warm and cap the spill
        cfg = make_cfg(scale, "ubis", use_pq=True, pq_m=scale.dim // 4,
                       rerank_k=192, nprobe=8, **tier_kw)
        drv = make_index("ubis", cfg, data[:2000], seed=scale.seed,
                         round_size=512, bg_ops_per_round=8,
                         pq_retrain_every=8)
        drv.search(queries[:32], scale.k)        # warm compile
        per_batch = scale.n // scale.batches
        nid = 0
        t0 = time.perf_counter()
        for bi in range(scale.batches):
            b = data[nid:nid + per_batch]
            drv.insert(b, np.arange(nid, nid + len(b)))
            nid += len(b)
            drv.search(queries, scale.k)         # heat the hot set
            drv.flush(max_ticks=6)
        t_upd = time.perf_counter() - t0
        drv.flush(max_ticks=40)
        recall = eval_recall(drv, queries, scale.k, data[:nid],
                             np.arange(nid))
        ts = timed_search(drv, queries, scale.k)
        mt = drv.memory_tiers()
        status = np.asarray(vm.unpack_status(drv.state.rec_meta))
        alive = np.asarray(drv.state.allocated) & (status != 3)
        spilled = np.asarray(drv.state.tier_spilled)
        tb = tile_bytes(drv.state)
        rows.append({
            "figure": "figmem", "variant": variant,
            "device_mb": round(mt["device"] / 2 ** 20, 2),
            "host_mb": round(mt["host"] / 2 ** 20, 2),
            "vec_device_mb": round(
                int((alive & ~spilled).sum()) * tb / 2 ** 20, 2),
            "live_postings": int(alive.sum()),
            "spilled": int((alive & spilled).sum()),
            "recall": round(recall, 4),
            "qps": round(ts["qps"], 1),
            "p99_ms": round(ts["p99_ms"], 2),
            "tps": round(nid / t_upd, 1),
        })
    return rows


def figskew_skewed_stream(scale: BenchScale = QUICK) -> List[Dict]:
    """Beyond the paper: the *pod-level* imbalanced-distribution axis.

    Replays a hot-shard insert stream (Zipfian cluster popularity) on a
    multi-shard ``ubis-sharded`` mesh and reports recall plus the
    per-shard occupancy spread over time, with the cross-shard rebalance
    stage on and off.  Three variants: ``uniform/on`` (the control),
    ``zipf/on`` (the acceptance run: spread stays bounded, recall within
    points of the control) and ``zipf/off`` (the failure mode the
    rebalance stage closes — with contiguous seeding the whole index
    stays wedged on shard 0).

    Shards = however many local devices exist; rows carry the count so a
    1-device run can never be diffed against a 4-shard baseline (run CI
    with ``XLA_FLAGS=--xla_force_host_platform_device_count=4``).
    """
    import time

    import jax
    from repro.api import make_index
    from repro.core.metrics import occupancy_spread

    n_dev = len(jax.devices())
    if scale.max_postings % n_dev:
        # skip, don't abort: figskew rides in the default figure list
        # and run.py only writes --out after every figure completes
        print(f"figskew: skipped — max_postings={scale.max_postings} "
              f"does not divide the {n_dev}-device model axis")
        return []
    mesh = jax.make_mesh((1, n_dev), ("data", "model"))
    rng = np.random.default_rng(scale.seed)
    K = 16
    cents = (rng.normal(size=(K, scale.dim)) * 5).astype(np.float32)
    queries = (cents[rng.integers(0, K, scale.queries)]
               + rng.normal(size=(scale.queries, scale.dim))
               ).astype(np.float32)

    def draw(kind, n):
        if kind == "uniform":
            a = rng.integers(0, K, n)
        else:
            w = 1.0 / (np.arange(K) + 1) ** 1.5
            a = rng.choice(K, size=n, p=w / w.sum())
        return (cents[a] + rng.normal(size=(n, scale.dim))
                ).astype(np.float32)

    rows = []
    per_batch = scale.n // (2 * scale.batches)
    for stream_kind, rebalance in (("uniform", True), ("zipf", True),
                                   ("zipf", False)):
        batches = [draw(stream_kind, per_batch)
                   for _ in range(scale.batches)]
        # built directly (not via make_driver): the mesh must be the
        # explicit (1, n_dev) one above, or default_mesh silently drops
        # shards on awkward device counts and mislabels every row
        drv = make_index("ubis-sharded", make_cfg(scale, "ubis"),
                         batches[0], seed=scale.seed, mesh=mesh,
                         round_size=512, bg_ops_per_round=8,
                         rebalance=rebalance)
        assert drv.n_shards == n_dev, (drv.n_shards, n_dev)
        drv.search(queries[:8], scale.k)     # warm compile
        nid = 0
        seen_v, seen_i = [], []
        for bi, b in enumerate(batches):
            ids = np.arange(nid, nid + len(b))
            nid += len(b)
            seen_v.append(b)
            seen_i.append(ids)
            t0 = time.perf_counter()
            r = drv.insert(b, ids)
            drv.flush(max_ticks=8)
            t_upd = time.perf_counter() - t0
            recall = eval_recall(drv, queries, scale.k,
                                 np.concatenate(seen_v),
                                 np.concatenate(seen_i))
            spread = occupancy_spread(drv.shard_occupancy())
            rows.append({
                "figure": "figskew", "stream": stream_kind,
                "rebalance": "on" if rebalance else "off",
                "shards": drv.n_shards, "batch": bi,
                "recall": round(recall, 4),
                "tps": round((r.accepted + r.cached) / t_upd, 1),
                "cached": r.cached, "rejected": r.rejected,
                "migrated": int(drv.stats["migrated"]),
                "occ_min": spread["occ_min"],
                "occ_max": spread["occ_max"],
                "occ_ratio": round(spread["occ_ratio"], 3),
                "occ_spread": round(spread["occ_spread"], 3),
            })
        rows[-1]["final_recall"] = rows[-1]["recall"]
    return rows


def figdist_cluster_stream(scale: BenchScale = QUICK) -> List[Dict]:
    """Beyond the paper: the *multi-host* imbalanced-distribution axis.

    Replays a Zipfian-popularity insert stream into a 2-worker
    ``ubis-cluster`` on the **multi-process backend** — the coordinator
    in this process holds every planner, each worker is a separate OS
    process speaking the frame protocol — and reports recall plus the
    cross-worker live-vector occupancy per batch.  The acceptance axis:
    the coordinator's water-filling insert routing plus the
    extract/insert spread-balance stage keep the max/min worker
    occupancy ratio ≤ 1.5 while recall holds the streaming floor.
    """
    import time

    from repro.api import make_index
    from repro.core.metrics import occupancy_spread

    rng = np.random.default_rng(scale.seed)
    K = 16
    cents = (rng.normal(size=(K, scale.dim)) * 5).astype(np.float32)
    queries = (cents[rng.integers(0, K, scale.queries)]
               + rng.normal(size=(scale.queries, scale.dim))
               ).astype(np.float32)
    w = 1.0 / (np.arange(K) + 1) ** 1.5
    p = w / w.sum()

    def draw(n):
        a = rng.choice(K, size=n, p=p)
        return (cents[a] + rng.normal(size=(n, scale.dim))
                ).astype(np.float32)

    per_batch = scale.n // (2 * scale.batches)
    batches = [draw(per_batch) for _ in range(scale.batches)]
    drv = make_index("ubis-cluster", make_cfg(scale, "ubis"),
                     batches[0], seed=scale.seed, workers=2,
                     backend="multiprocess", round_size=512,
                     bg_ops_per_round=8, spread_per_tick=256)
    rows = []
    try:
        drv.search(queries[:8], scale.k)     # warm both workers' compiles
        nid = 0
        seen_v, seen_i = [], []
        for bi, b in enumerate(batches):
            ids = np.arange(nid, nid + len(b))
            nid += len(b)
            seen_v.append(b)
            seen_i.append(ids)
            t0 = time.perf_counter()
            r = drv.insert(b, ids)
            drv.flush(max_ticks=8)
            t_upd = time.perf_counter() - t0
            recall = eval_recall(drv, queries, scale.k,
                                 np.concatenate(seen_v),
                                 np.concatenate(seen_i))
            spread = occupancy_spread(drv.worker_live())
            rows.append({
                "figure": "figdist", "stream": "zipf",
                "rebalance": "on", "workers": drv.n_workers,
                "batch": bi, "recall": round(recall, 4),
                "tps": round((r.accepted + r.cached) / t_upd, 1),
                "cached": r.cached, "rejected": r.rejected,
                "migrated": int(drv.stats["migrated"]),
                "occ_min": spread["occ_min"],
                "occ_max": spread["occ_max"],
                "occ_ratio": round(spread["occ_ratio"], 3),
                "occ_spread": round(spread["occ_spread"], 3),
            })
        rows[-1]["final_recall"] = rows[-1]["recall"]
    finally:
        drv.close()
    return rows


def fig9_balance_factor(scale: BenchScale = QUICK) -> List[Dict]:
    """Paper Fig. 9: balance-factor sweep (recall up, QPS down)."""
    import time
    rows = []
    for f in (0.0, 0.05, 0.1, 0.15, 0.25, 0.4):
        recs = streaming_run(scale, "ubis", dataset="drift",
                             balance_factor=f)
        last = recs[-1]
        rows.append({"figure": "fig9", "balance_factor": f,
                     "recall": round(last.get("recall", -1), 4),
                     "qps": round(last.get("qps", -1), 1),
                     "small_frac": round(last["small_frac"], 4)})
    return rows
