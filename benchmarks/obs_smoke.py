"""CI smoke for the observability plane.

    PYTHONPATH=src python -m benchmarks.obs_smoke [--out metrics_snapshot.json]

Runs a short serving workload (figserve's trace shape, scaled down)
with the full plane on — structured traces, request spans, live-recall
probe — then asserts the plane's external contract:

* the Prometheus text exposition parses (``repro.obs.parse_exposition``);
* every required series is present (driver schema counters, request-span
  histograms, the live-recall gauge);
* planner trace events were actually emitted (tick + background mark);
* the JSON snapshot round-trips through ``json``.

Exit 0 on success; any broken contract raises.  The snapshot is written
for ``benchmarks.report``'s metrics table.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

sys.path.insert(0, ".")
sys.path.insert(0, "src")

REQUIRED_SERIES = (
    # one per driver-schema family the plane promises (full set asserted
    # key-by-key in tests/test_obs.py; this is the serving-path contract)
    "index_inserted", "index_queries", "index_bg_ops",
    "index_search_probed", "index_search_results",
    # request spans
    "serve_queue_wait_seconds", "serve_service_seconds",
    "serve_latency_seconds", "serve_batch_fill",
    # live-recall probe
    "live_recall", "live_recall_probes",
)


def run(out: str = "metrics_snapshot.json") -> dict:
    from repro.api import make_index
    from repro.core.types import UBISConfig
    from repro.obs import parse_exposition, required_series
    from repro.serving import ServingConfig, ServingEngine

    rng = np.random.default_rng(0)
    dim, n = 32, 2048
    cfg = UBISConfig(dim=dim, max_postings=256, capacity=96, l_min=10,
                     l_max=80, cache_capacity=1024, max_ids=1 << 16,
                     use_pallas="off")
    data = rng.normal(size=(n, dim)).astype(np.float32)
    idx = make_index("ubis", cfg, data[:512], seed=0, round_size=256,
                     bg_ops_per_round=8)
    eng = ServingEngine(idx, ServingConfig(
        search_batch=16, search_deadline_s=0.0, insert_deadline_s=0.0,
        tick_every=1, default_k=10, recall_probe=1.0,
        recall_probe_rows=8))

    tickets = []
    for off in range(0, n, 256):
        tickets.append(eng.submit_insert(
            data[off:off + 256], np.arange(off, off + 256)))
        for _ in range(4):
            tickets.append(eng.submit_search(
                data[rng.integers(0, n)][None, :], 10))
        eng.drain()
    assert all(t.done() for t in tickets), "serving tickets left pending"

    # --- the external contract ---------------------------------------
    text = eng.obs.to_prometheus()
    series = parse_exposition(text)           # raises on malformed text
    missing = required_series(series, REQUIRED_SERIES)
    assert not missing, f"exposition is missing series: {missing}"

    kinds = {e["kind"] for e in eng.obs.events()}
    assert "tick" in kinds, f"no tick trace events (saw {sorted(kinds)})"
    assert "insert" in kinds, f"no insert trace events (saw {sorted(kinds)})"

    snap = eng.obs.snapshot()
    js = json.dumps(snap, indent=1, allow_nan=False)
    with open(out, "w") as f:
        f.write(js)

    probes = snap.get("live_recall_probes", 0)
    assert probes > 0, "recall probe never fired at fraction=1.0"
    print(f"obs_smoke: {len(series)} series, {len(list(eng.obs.events()))} "
          f"trace events, {int(probes)} recall probes "
          f"(live_recall={snap['live_recall']:.3f}); wrote {out}")
    return snap


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="metrics_snapshot.json")
    args = ap.parse_args(argv)
    run(args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
