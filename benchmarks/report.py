"""Generate the EXPERIMENTS.md tables from the saved dry-run / roofline
artifacts (dryrun_results.json, roofline_results.json, perf_*.json)."""
from __future__ import annotations

import json
import os
import sys


def _load(path):
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return []


def _fmt(x, nd=2):
    if x is None:
        return "-"
    if isinstance(x, float):
        if x == 0:
            return "0"
        if abs(x) >= 1e5 or abs(x) < 1e-3:
            return f"{x:.2e}"
        return f"{x:.{nd}f}"
    return str(x)


def dryrun_table(recs):
    lines = ["| arch | cell | mesh | params | lower s | compile s | "
             "HLO GFLOP/dev (scan-counted) | status |",
             "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r.get("arch", ""),
                                         r.get("cell", ""),
                                         r.get("mesh", ""))):
        lines.append(
            "| {} | {} | {} | {} | {} | {} | {} | {} |".format(
                r.get("arch"), r.get("cell"), r.get("mesh"),
                _fmt(r.get("n_params", 0) / 1e9, 2) + "B"
                if r.get("n_params") else "-",
                _fmt(r.get("lower_s")), _fmt(r.get("compile_s")),
                _fmt(r.get("hlo_flops", 0) / 1e9, 1),
                r.get("status", "?")))
    return "\n".join(lines)


def roofline_table(recs):
    from benchmarks.roofline import model_flops
    lines = ["| arch | cell | t_compute | t_memory | t_collective | "
             "dominant | MODEL_FLOPS | useful ratio | lever |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("status") != "ok":
            lines.append(f"| {r.get('arch')} | {r.get('cell')} | - | - | "
                         f"- | FAIL | - | - | {r.get('error', '')[:60]} |")
            continue
        try:
            mf = model_flops(r["arch"], r["cell"])
        except Exception:
            mf = r.get("model_flops_global", 0)
        hlo_global = r["hlo_flops"] * r["n_devices"]
        useful = mf / hlo_global if hlo_global else 0
        lines.append(
            "| {} | {} | {} s | {} s | {} s | {} | {} | {} | {} |".format(
                r["arch"], r["cell"],
                _fmt(r["t_compute_s"], 3), _fmt(r["t_memory_s"], 3),
                _fmt(r["t_collective_s"], 3), r["dominant"],
                _fmt(mf), _fmt(useful),
                LEVERS.get((r["arch"], r["cell"]),
                           LEVERS.get(r["dominant"], ""))))
    return "\n".join(lines)


LEVERS = {
    "memory": "fuse attention score chain (Pallas flash path on TPU)",
    "collective": "reshard / reduce-scatter grads; overlap with compute",
    "compute": "already near the MXU roof for this shape",
    ("granite-moe-3b-a800m", "train_4k"):
        "EP needs experts%mesh==0 -> pad experts (see §Perf)",
    ("deepseek-67b", "train_4k"):
        "attention score traffic -> dots remat + flash kernel",
    ("jamba-1.5-large-398b", "train_4k"):
        "mamba scan materialisation -> chunked assoc-scan block sizes",
}


def metrics_table(snap: dict) -> str:
    """Render an observability snapshot (``benchmarks.obs_smoke`` /
    ``Obs.snapshot()``) as one table: scalar series as name/value rows,
    histogram series as count/mean/p50/p99."""
    if not snap:
        return "_no metrics snapshot (run `python -m benchmarks.obs_smoke`)_"
    lines = ["| series | count | value / mean | p50 | p99 |",
             "|---|---|---|---|---|"]
    for name in sorted(snap):
        v = snap[name]
        if isinstance(v, dict):        # histogram summary
            lines.append("| {} | {} | {} | {} | {} |".format(
                name, v.get("count", 0), _fmt(v.get("mean")),
                _fmt(v.get("p50")), _fmt(v.get("p99"))))
        else:
            lines.append(f"| {name} | - | {_fmt(v)} | - | - |")
    return "\n".join(lines)


def main():
    recs_dry = _load("dryrun_results.json")
    recs_roof = _load("roofline_results.json")
    print("## §Dry-run\n")
    print(dryrun_table(recs_dry))
    print("\n## §Roofline\n")
    print(roofline_table(recs_roof))
    snap = _load("metrics_snapshot.json")
    print("\n## §Observability\n")
    print(metrics_table(snap if isinstance(snap, dict) else {}))


if __name__ == "__main__":
    sys.path.insert(0, ".")
    sys.path.insert(0, "src")
    main()
