"""Render saved benchmark artifacts as markdown tables: the per-kernel
roofline report (``roofline_results.json``, written by
``benchmarks.roofline``) and an observability snapshot
(``metrics_snapshot.json``, written by ``benchmarks.obs_smoke``)."""
from __future__ import annotations

import json
import os
import sys


def _load(path, default):
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return default


def _fmt(x, nd=2):
    if x is None:
        return "-"
    if isinstance(x, float):
        if x == 0:
            return "0"
        if abs(x) >= 1e5 or abs(x) < 1e-3:
            return f"{x:.2e}"
        return f"{x:.{nd}f}"
    return str(x)


def roofline_table(rows) -> str:
    """Per-kernel roofline rows (see ``benchmarks.roofline``): executed
    vs useful FLOPs, HBM bytes, arithmetic intensity, and the attainable
    fraction of peak under the memory roof."""
    if not rows:
        return ("_no roofline rows (run `python -m benchmarks.roofline "
                "--out roofline_results.json`)_")
    lines = ["| kernel | backend | shapes | GFLOP | useful GFLOP | MiB | "
             "FLOP/B | bound | roofline frac | measured ms |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: r.get("kernel", "")):
        lines.append(
            "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} |".format(
                r.get("kernel"), r.get("backend", "-"), r.get("shapes"),
                _fmt(r.get("flops", 0) / 1e9, 3),
                _fmt(r.get("useful_flops", 0) / 1e9, 3),
                _fmt(r.get("bytes", 0) / 2 ** 20),
                _fmt(r.get("intensity"), 1), r.get("bound"),
                _fmt(r.get("roofline_frac")), _fmt(r.get("measured_ms"))))
    return "\n".join(lines)


def metrics_table(snap: dict) -> str:
    """Render an observability snapshot (``benchmarks.obs_smoke`` /
    ``Obs.snapshot()``) as one table: scalar series as name/value rows,
    histogram series as count/mean/p50/p99."""
    if not snap:
        return "_no metrics snapshot (run `python -m benchmarks.obs_smoke`)_"
    lines = ["| series | count | value / mean | p50 | p99 |",
             "|---|---|---|---|---|"]
    for name in sorted(snap):
        v = snap[name]
        if isinstance(v, dict):        # histogram summary
            lines.append("| {} | {} | {} | {} | {} |".format(
                name, v.get("count", 0), _fmt(v.get("mean")),
                _fmt(v.get("p50")), _fmt(v.get("p99"))))
        else:
            lines.append(f"| {name} | - | {_fmt(v)} | - | - |")
    return "\n".join(lines)


def main():
    rows = _load("roofline_results.json", [])
    print("## §Roofline\n")
    print(roofline_table(rows if isinstance(rows, list) else []))
    snap = _load("metrics_snapshot.json", {})
    print("\n## §Observability\n")
    print(metrics_table(snap if isinstance(snap, dict) else {}))


if __name__ == "__main__":
    sys.path.insert(0, ".")
    sys.path.insert(0, "src")
    main()
