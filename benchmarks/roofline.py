"""Roofline analysis (deliverable g).

For every (arch x shape) cell on the single-pod production mesh, derive
the three roofline terms from compiled dry-run artifacts:

    compute    = HLO_FLOPs   / (chips * 197e12  bf16 FLOP/s)
    memory     = HLO_bytes   / (chips * 819e9   B/s HBM)
    collective = coll_bytes  / (chips * 50e9    B/s per ICI link)

Method note (EXPERIMENTS.md §Roofline): XLA's cost analysis counts a
``while``-loop (lax.scan) body ONCE, so scan-based full-depth compiles
under-report per-layer work.  We therefore compile two small-depth
variants with the layer scans **unrolled** (exact counts) and linearly
extrapolate to full depth:

    cost(L) = cost(d1) + (cost(d2) - cost(d1)) * (L - d1) / (d2 - d1)

which is exact because every segment's per-layer cost is
depth-independent.  cost_analysis numbers are per-device (the compiled
module is the SPMD per-device program); collective bytes are summed
output sizes of collective ops in the compiled per-device HLO.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 per chip (v5e)
HBM_BW = 819e9           # B/s per chip
LINK_BW = 50e9           # B/s per ICI link (conservative single-link)

# depth variants that preserve segment structure (see docstring)
DEPTH_VARIANTS = {
    "seamless-m4t-medium": (1, 2),   # scales encoder+decoder together
    "tinyllama-1.1b": (1, 2),
    "qwen3-4b": (1, 2),
    "gemma3-4b": (6, 12),            # one/two 5L:1G periods
    "deepseek-67b": (1, 2),
    "rwkv6-3b": (1, 2),
    "granite-moe-3b-a800m": (1, 2),
    "moonshot-v1-16b-a3b": (1, 2),
    "llava-next-34b": (1, 2),
    "jamba-1.5-large-398b": (8, 16),  # one/two hybrid periods
}


def _overrides_for(arch: str, depth: int) -> Dict:
    ov: Dict = {"n_layers": depth}
    if arch == "seamless-m4t-medium":
        ov["encoder_layers"] = depth
    return ov


def _extrapolate(r1: Dict, r2: Dict, d1: int, d2: int, L: int) -> Dict:
    out = {}
    for key in ("hlo_flops", "hlo_bytes"):
        a, b = r1.get(key, 0.0), r2.get(key, 0.0)
        out[key] = a + (b - a) * (L - d1) / (d2 - d1)
    coll = {}
    ops = set(r1.get("collective_bytes", {})) | set(
        r2.get("collective_bytes", {}))
    for op in ops:
        a = r1.get("collective_bytes", {}).get(op, 0)
        b = r2.get("collective_bytes", {}).get(op, 0)
        coll[op] = max(0.0, a + (b - a) * (L - d1) / (d2 - d1))
    out["collective_bytes"] = coll
    return out


def model_flops(arch: str, cell_name: str) -> float:
    """MODEL_FLOPS: the classic useful-work estimate.

    6*N*D (train) / 2*N*D (inference) per token over *active, matmul*
    params — i.e. embedding gathers excluded, MoE experts counted top_k
    of num_experts, the unembedding head charged only for positions that
    actually produce logits (1 per sequence in prefill/decode), and
    encoder params (enc-dec) charged for encoder tokens only."""
    from repro.models import SHAPE_CELLS, get_model
    from repro.models.registry import ENC_SRC_LEN
    import jax
    import jax.tree_util as jtu
    model = get_model(arch)
    cfg = model.cfg
    pv, _ = model.param_shapes(None)
    n_emb = cfg.vocab_padded * cfg.d_model
    n_head = 0 if cfg.tie_embeddings else n_emb
    n_body = n_enc = 0
    for path, leaf in jtu.tree_flatten_with_path(pv)[0]:
        keys = "/".join(str(getattr(p, "key", "")) for p in path)
        if keys in ("emb", "head"):
            continue
        size = int(leaf.size)
        if cfg.moe is not None and "moe" in keys and (
                "w_gate" in keys or "w_up" in keys or "w_down" in keys):
            size = size * cfg.moe.top_k // cfg.moe.num_experts
        if keys.startswith("enc/"):
            n_enc += size
        else:
            n_body += size
    if cfg.tie_embeddings:
        n_head = n_emb  # tied head still does the logits matmul
    cell = SHAPE_CELLS[cell_name]
    B = cell.global_batch
    if cell.kind == "train":
        tok = cell.seq_len * B
        f = 6.0 * n_body * tok + 6.0 * n_head * tok
        f += 6.0 * n_enc * ENC_SRC_LEN * B
        return f
    if cell.kind == "prefill":
        tok = cell.seq_len * B
        f = 2.0 * n_body * tok + 2.0 * n_head * B  # logits: last pos only
        f += 2.0 * n_enc * ENC_SRC_LEN * B
        return f
    # decode: one token per sequence; the cache-attention flops are NOT
    # "model flops" — a low ratio here correctly flags decode as
    # cache-bound, not wasteful.
    return 2.0 * (n_body + n_head) * B


def roofline_terms(rec: Dict, n_devices: int) -> Dict:
    flops = rec.get("hlo_flops", 0.0)
    bytes_ = rec.get("hlo_bytes", 0.0)
    coll = sum(rec.get("collective_bytes", {}).values())
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_ / HBM_BW
    t_coll = coll / LINK_BW
    dom = max((t_compute, "compute"), (t_memory, "memory"),
              (t_coll, "collective"))
    return {
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dom[1],
        "roofline_frac": (max(t_compute, 1e-30)
                          / max(t_compute, t_memory, t_coll, 1e-30)),
    }


def analyze_cell(arch: str, cell: str, mesh, remat: str = "full",
                 rules_override: Optional[dict] = None) -> Dict:
    """Two unrolled small-depth compiles -> extrapolated full-depth
    roofline record (per-device costs)."""
    from repro.launch.dryrun import lower_cell
    from repro.models import get_config
    d1, d2 = DEPTH_VARIANTS[arch]
    r1 = lower_cell(arch, cell, mesh, remat=remat, unroll=True,
                    rules_override=rules_override,
                    **_overrides_for(arch, d1))
    r2 = lower_cell(arch, cell, mesh, remat=remat, unroll=True,
                    rules_override=rules_override,
                    **_overrides_for(arch, d2))
    L = get_config(arch).n_layers
    rec = _extrapolate(r1, r2, d1, d2, L)
    rec.update(arch=arch, cell=cell,
               mesh="x".join(str(s) for s in mesh.devices.shape),
               n_devices=int(mesh.devices.size))
    rec.update(roofline_terms(rec, rec["n_devices"]))
    mf = model_flops(arch, cell)
    rec["model_flops_global"] = mf
    hlo_global = rec["hlo_flops"] * rec["n_devices"]
    rec["useful_ratio"] = mf / hlo_global if hlo_global else 0.0
    return rec
