"""Per-kernel roofline report for the UBIS Pallas kernel suite.

For every kernel in ``src/repro/kernels`` (the search/build hot loop:
centroid scoring, posting scans, fused top-k variants, ADC scans,
k-means assignment, flash attention) this module derives an *analytic*
roofline row — FLOPs, HBM bytes, arithmetic intensity, compute/memory
time at TPU v5e peaks, and the roofline fraction (attainable share of
peak FLOPs given the memory bound) — and measures wall time on the
selected backend for an achieved-vs-predicted column.

Two honesty metrics matter here:

* ``useful_flops`` vs ``flops``: the PQ ADC kernels execute a one-hot
  (C, ksub) @ (ksub, 1) matmul per subspace on the MXU — ``2*C*ksub``
  executed FLOPs for ``C`` useful adds.  The executed count feeds the
  compute-time estimate; the useful count is what recall per second
  actually buys.
* fused vs unfused bytes: the ``*_topk`` kernels write ``2*Q*k`` scalars
  instead of a (Q, M) / (Q, P, C) score tensor; the rows make the HBM
  traffic that fusion removes explicit.

Run:  PYTHONPATH=src:. python -m benchmarks.roofline \
          --backend pallas --preset smoke --check --out roofline.json
``--check`` asserts every kernel module in ``src/repro/kernels``
(excluding ``__init__``/``ops``/``ref``) contributes at least one row —
the CI smoke gate that keeps this report honest as kernels are added.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Callable, Dict, List

PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip (TPU v5e)
HBM_BW = 819e9           # HBM B/s per chip

# shape presets: smoke is small enough for CPU interpret mode in CI;
# full approximates the fig5 serving configuration; misaligned pins the
# alignment-free contract (real-world d=100, odd capacity, ksub=100 —
# the wrappers pad, the fused kernels serve, nothing falls back)
PRESETS = {
    "smoke": dict(Q=8, d=128, M=128, C=128, P=4, k=8,
                  m=2, ksub=128, V=2, N=256, K=128, R=32,
                  B=1, Hq=2, Hkv=1, L=128, D=128),
    "misaligned": dict(Q=8, d=100, M=33, C=100, P=4, k=8,
                       m=4, ksub=100, V=2, N=200, K=100, R=24,
                       B=1, Hq=2, Hkv=1, L=96, D=64),
    "full": dict(Q=128, d=128, M=1024, C=256, P=32, k=64,
                 m=8, ksub=256, V=4, N=4096, K=512, R=256,
                 B=4, Hq=8, Hkv=2, L=512, D=128),
}


def _row(kernel: str, module: str, shapes: str, flops: float,
         useful_flops: float, bytes_: float) -> Dict:
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_ / HBM_BW
    return {
        "kernel": kernel,
        "module": module,
        "shapes": shapes,
        "flops": flops,
        "useful_flops": useful_flops,
        "bytes": bytes_,
        "intensity": flops / bytes_,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "bound": "compute" if t_compute >= t_memory else "memory",
        # attainable fraction of peak FLOPs under the memory roof
        "roofline_frac": t_compute / max(t_compute, t_memory),
    }


def build_cases(p: Dict, backend: str) -> List[Dict]:
    """Construct (row, runner) cases for every kernel entry point.

    Each runner is a no-arg closure calling the ``ops`` wrapper on the
    requested backend; analytic FLOP/byte counts model the kernel's
    streaming behaviour (fused top-k outputs are 2*Q*k scalars, the
    unfused scans write the full score tensor).
    """
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    kq, kc, kv, kp = jax.random.split(jax.random.key(0), 4)
    Q, d, M, C, P, k = p["Q"], p["d"], p["M"], p["C"], p["P"], p["k"]
    m, ksub, V, N, K = p["m"], p["ksub"], p["V"], p["N"], p["K"]
    B, Hq, Hkv, L, D = p["B"], p["Hq"], p["Hkv"], p["L"], p["D"]
    R = p["R"]
    f32 = 4

    q = jax.random.normal(kq, (Q, d), jnp.float32)
    cents = jax.random.normal(kc, (M, d), jnp.float32)
    vis = jnp.ones((M,), bool)
    vecs = jax.random.normal(kv, (M, C, d), jnp.float32)
    slot_valid = jnp.ones((M, C), bool)
    probe = jax.random.randint(kp, (Q, P), 0, M, jnp.int32)
    luts = jax.random.normal(kq, (Q, V, m, ksub), jnp.float32)
    codes = jax.random.randint(kc, (M, m, C), 0, ksub).astype(jnp.uint8)
    pslot = jnp.zeros((M,), jnp.int32)
    pts = jax.random.normal(kv, (N, d), jnp.float32)
    kcents = jax.random.normal(kc, (K, d), jnp.float32)
    qa = jax.random.normal(kq, (B, Hq, L, D), jnp.float32)
    ka = jax.random.normal(kc, (B, Hkv, L, D), jnp.float32)
    va = jax.random.normal(kv, (B, Hkv, L, D), jnp.float32)

    cases: List[Dict] = []

    def add(row: Dict, fn: Callable):
        row["backend"] = backend
        cases.append({"row": row, "fn": fn})

    # --- phase 1: centroid scoring --------------------------------------
    add(_row("centroid_score", "centroid_score", f"Q={Q} M={M} d={d}",
             flops=2.0 * Q * M * d, useful_flops=2.0 * Q * M * d,
             bytes_=f32 * (Q * d + M * d + Q * M)),
        lambda: ops.centroid_score(q, cents, vis, backend=backend))
    add(_row("centroid_topk", "centroid_topk",
             f"Q={Q} M={M} d={d} k={k}",
             flops=2.0 * Q * M * d + 1.0 * Q * k * M,
             useful_flops=2.0 * Q * M * d,
             bytes_=f32 * (Q * d + M * d + 2 * Q * k)),
        lambda: ops.centroid_topk(q, cents, vis, k=k, backend=backend))

    # --- phase 2: float posting scans -----------------------------------
    add(_row("posting_scan", "posting_scan", f"Q={Q} V={M * C} d={d}",
             flops=2.0 * Q * M * C * d, useful_flops=2.0 * Q * M * C * d,
             bytes_=f32 * (Q * d + M * C * d + Q * M * C)),
        lambda: ops.posting_scan(q, vecs, slot_valid, backend=backend))
    add(_row("posting_scan_gather", "posting_scan",
             f"Q={Q} P={P} C={C} d={d}",
             flops=2.0 * Q * P * C * d, useful_flops=2.0 * Q * P * C * d,
             bytes_=f32 * (Q * d + Q * P * C * d + Q * P * C)),
        lambda: ops.posting_scan_gather(q, vecs, slot_valid, vis, probe,
                                        backend=backend))
    add(_row("posting_scan_topk", "posting_scan",
             f"Q={Q} P={P} C={C} d={d} k={k}",
             flops=2.0 * Q * P * C * d + 1.0 * Q * P * k * C,
             useful_flops=2.0 * Q * P * C * d,
             bytes_=f32 * (Q * d + Q * P * C * d + 2 * Q * k)),
        lambda: ops.posting_scan_topk(q, vecs, slot_valid, vis, probe,
                                      k=k, backend=backend))

    # --- quant plane: ADC scans (one-hot MXU trick: 2*C*ksub executed
    # FLOPs per (query, probe, subspace) for C useful adds) --------------
    adc_exec = 2.0 * Q * P * m * C * ksub
    adc_useful = 2.0 * Q * P * m * C
    adc_bytes = Q * P * (m * C + f32 * m * ksub)  # codes u8 + lut tile
    add(_row("pq_scan_gather", "pq_scan",
             f"Q={Q} P={P} C={C} m={m} ksub={ksub}",
             flops=adc_exec, useful_flops=adc_useful,
             bytes_=adc_bytes + f32 * Q * P * C),
        lambda: ops.pq_scan_gather(luts, codes, pslot, slot_valid, vis,
                                   probe, backend=backend))
    add(_row("pq_scan_topk", "pq_scan",
             f"Q={Q} P={P} C={C} m={m} ksub={ksub} k={k}",
             flops=adc_exec + 1.0 * Q * P * k * C,
             useful_flops=adc_useful,
             bytes_=adc_bytes + f32 * 2 * Q * k),
        lambda: ops.pq_scan_topk(luts, codes, pslot, slot_valid, vis,
                                 probe, k=k, backend=backend))

    # --- rerank: fused candidate gather + exact ||v||^2 - 2 q.v + ADC
    # passthrough + top-k (replaces the XLA gather+einsum rerank tail) --
    spilled = jnp.zeros((M,), bool)
    cand = jax.random.randint(kp, (Q, R), 0, M * C, jnp.int32)
    adc = jax.random.normal(kq, (Q, R), jnp.float32)
    add(_row("rerank_topk", "rerank",
             f"Q={Q} R={R} d={d} k={k}",
             flops=4.0 * Q * R * d + 1.0 * Q * R * k,
             useful_flops=4.0 * Q * R * d,
             bytes_=f32 * (Q * d + Q * R * d + 2 * Q * R + 2 * Q * k)),
        lambda: ops.rerank_topk(q, vecs, spilled, cand, adc,
                                k=min(k, R), backend=backend))

    # --- build/maintenance: k-means assignment --------------------------
    add(_row("kmeans_assign", "kmeans_assign", f"N={N} K={K} d={d}",
             flops=2.0 * N * K * d, useful_flops=2.0 * N * K * d,
             bytes_=f32 * (N * d + K * d + 2 * N)),
        lambda: ops.kmeans_assign(pts, kcents, backend=backend))

    # --- serving: attention over the request batch ----------------------
    # causal: half the (L, L) score square does useful work
    add(_row("flash_attention", "flash_attention",
             f"B={B} Hq={Hq} L={L} D={D}",
             flops=4.0 * B * Hq * L * L * D * 0.5,
             useful_flops=4.0 * B * Hq * L * L * D * 0.5,
             bytes_=f32 * (B * (Hq + 2 * Hkv) * L * D + B * Hq * L * D)),
        lambda: ops.flash_attention(qa, ka, va, causal=True,
                                    backend=backend))
    return cases


def measure(fn: Callable, iters: int = 3) -> float:
    """Best-of-N wall seconds, compile excluded (first call warms up)."""
    import jax
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def kernel_modules() -> List[str]:
    """Kernel module names under ``repro.kernels`` that must each have
    at least one roofline row (``ops``/``ref``/``__init__`` excluded)."""
    import pkgutil
    import repro.kernels as pkg
    skip = {"ops", "ref"}
    return sorted(m.name for m in pkgutil.iter_modules(pkg.__path__)
                  if m.name not in skip)


def check_rows(rows: List[Dict]) -> None:
    covered = {r["module"] for r in rows}
    missing = [m for m in kernel_modules() if m not in covered]
    if missing:
        raise SystemExit(
            f"roofline --check: kernel modules without a row: {missing}")


def render(rows: List[Dict]) -> str:
    head = ("| kernel | shapes | GFLOP | useful | MiB | FLOP/B | "
            "bound | roofline | ms |")
    lines = [head, "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            "| {} | {} | {:.3f} | {:.3f} | {:.2f} | {:.1f} | {} | "
            "{:.2f} | {} |".format(
                r["kernel"], r["shapes"], r["flops"] / 1e9,
                r["useful_flops"] / 1e9, r["bytes"] / 2 ** 20,
                r["intensity"], r["bound"], r["roofline_frac"],
                "{:.2f}".format(r["measured_ms"])
                if r.get("measured_ms") is not None else "-"))
    return "\n".join(lines)


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "ref", "pallas"))
    ap.add_argument("--preset", default="smoke", choices=sorted(PRESETS))
    ap.add_argument("--out", default=None, help="write rows as JSON")
    ap.add_argument("--no-measure", action="store_true",
                    help="analytic columns only (skip timing)")
    ap.add_argument("--check", action="store_true",
                    help="fail unless every kernel module has a row")
    args = ap.parse_args(argv)

    cases = build_cases(PRESETS[args.preset], args.backend)
    rows = []
    for c in cases:
        r = c["row"]
        if args.no_measure:
            r["measured_ms"] = None
        else:
            t = measure(c["fn"])
            r["measured_ms"] = t * 1e3
            # predicted-vs-achieved only means something on real TPU;
            # on CPU interpret it is just a magnitude sanity column
            pred = max(r["t_compute_s"], r["t_memory_s"])
            r["achieved_frac"] = pred / t if t > 0 else 0.0
        rows.append(r)

    if args.check:
        check_rows(rows)
    print(render(rows))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {len(rows)} rows -> {args.out}")
    return rows


if __name__ == "__main__":
    import sys
    sys.path.insert(0, ".")
    sys.path.insert(0, "src")
    main()
