"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract, plus
the full per-figure rows.  The per-kernel roofline report is its own
entry point (``python -m benchmarks.roofline``).

    PYTHONPATH=src python -m benchmarks.run [--full] [--figures fig5,...]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

sys.path.insert(0, ".")  # repo root (benchmarks package)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--figures",
                    default="fig5,fig6,fig7,table4,fig8,fig9,figpq,"
                            "figengines,figskew,figmem,figserve")
    ap.add_argument("--out", default="bench_results.json")
    args = ap.parse_args(argv)

    from benchmarks import figures, figserve
    from benchmarks.common import FULL, QUICK
    scale = FULL if args.full else QUICK

    fns = {
        "fig5": figures.fig5_posting_cdf,
        "fig6": figures.fig6_streaming_recall,
        "fig7": figures.fig7_streaming_throughput,
        "table4": figures.table4_full_update,
        "fig8": figures.fig8_fg_bg_ratio,
        "fig9": figures.fig9_balance_factor,
        "figpq": figures.figpq_memory_recall,
        "figengines": figures.figengines_comparison,
        "figskew": figures.figskew_skewed_stream,
        "figdist": figures.figdist_cluster_stream,
        "figmem": figures.figmem_cold_tier,
        "figserve": figserve.figserve_serving,
    }
    wanted = [f.strip() for f in args.figures.split(",") if f.strip()]
    all_rows = []
    print("name,us_per_call,derived")
    for name in wanted:
        t0 = time.perf_counter()
        rows = fns[name](scale)
        dt = time.perf_counter() - t0
        all_rows.extend(rows)
        derived = _headline(name, rows)
        print(f"{name},{dt * 1e6 / max(len(rows), 1):.0f},{derived}",
              flush=True)
    with open(args.out, "w") as f:
        json.dump(all_rows, f, indent=1)
    print(f"# wrote {len(all_rows)} rows to {args.out}")
    # echo rows for the log
    for r in all_rows:
        print("  " + ",".join(f"{k}={v}" for k, v in r.items()))


def _headline(name: str, rows) -> str:
    """One derived number per figure — the paper's comparison axis."""
    if not rows:
        return "skipped"
    by_mode = {}
    for r in rows:
        by_mode.setdefault(r.get("mode", r.get("balance_factor",
                                               r.get("fg"))), []).append(r)
    try:
        if name == "fig5":
            u = [r["small_frac"] for r in by_mode["ubis"]][-1]
            s = [r["small_frac"] for r in by_mode["spfresh"]][-1]
            return f"small_frac ubis={u} spfresh={s}"
        if name == "fig6":
            u = [r["recall"] for r in by_mode["ubis"] if r["recall"] >= 0]
            s = [r["recall"] for r in by_mode["spfresh"]
                 if r["recall"] >= 0]
            return (f"mean_recall ubis={sum(u)/len(u):.3f} "
                    f"spfresh={sum(s)/len(s):.3f}")
        if name == "fig7":
            u = [r["tps"] for r in by_mode["ubis"]]
            s = [r["tps"] for r in by_mode["spfresh"]]
            return (f"mean_tps ubis={sum(u)/len(u):.0f} "
                    f"spfresh={sum(s)/len(s):.0f}")
        if name == "table4":
            u = by_mode["ubis"][0]
            s = by_mode["spfresh"][0]
            return (f"recall {u['recall']:.3f}vs{s['recall']:.3f} "
                    f"tps {u['tps']:.0f}vs{s['tps']:.0f}")
        if name == "fig8":
            best = max(rows, key=lambda r: r["tps"])
            return f"best fg:bg={best['fg']}:{best['bg']}"
        if name == "fig9":
            return "recall rises with f, qps falls (see rows)"
        if name == "figpq":
            fl = next(r for r in rows if r["variant"] == "float")
            best = max((r for r in rows if r["variant"] != "float"),
                       key=lambda r: r["recall"])
            return (f"{best['variant']} {best['compression_x']}x smaller, "
                    f"recall {best['recall']:.3f} vs float "
                    f"{fl['recall']:.3f}")
        if name == "figengines":
            return " ".join(f"{r['mode']}={r['final_recall']:.3f}"
                            for r in rows)
        if name == "figskew":
            last = {(r["stream"], r["rebalance"]): r for r in rows}
            on = last[("zipf", "on")]
            off = last[("zipf", "off")]
            return (f"zipf occ_ratio on={on['occ_ratio']} "
                    f"off={off['occ_ratio']} recall on={on['recall']}")
        if name == "figdist":
            last = rows[-1]
            worst = max(r["occ_ratio"] for r in rows)
            return (f"2-proc zipf occ_ratio last={last['occ_ratio']} "
                    f"worst={worst} recall={last['recall']}")
        if name == "figmem":
            by = {r["variant"]: r for r in rows}
            off_, on_ = by["tier-off"], by["tier-on"]
            ratio = off_["vec_device_mb"] / max(on_["vec_device_mb"],
                                               1e-9)
            return (f"vec_device {off_['vec_device_mb']}->"
                    f"{on_['vec_device_mb']}MB ({ratio:.1f}x) recall "
                    f"{off_['recall']:.3f}->{on_['recall']:.3f}")
        if name == "figserve":
            by = {r["mode"]: r for r in rows}
            s, b = by["sync"], by["batched"]
            return (f"qps sync={s['qps']:.0f} batched={b['qps']:.0f} "
                    f"({b['qps'] / max(s['qps'], 1e-9):.1f}x) p99 "
                    f"{s['p99_ms']:.1f}->{b['p99_ms']:.1f}ms recall "
                    f"{s['recall']:.3f}/{b['recall']:.3f}")
    except Exception as e:  # pragma: no cover
        return f"derived-error:{e}"
    return ""


if __name__ == "__main__":
    main()
