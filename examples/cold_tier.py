"""Cold-tier host spill: serve a mostly-cold index from PQ codes while
the float tiles of the cold majority live in host memory.

    PYTHONPATH=src python examples/cold_tier.py [engine]

``engine`` is "ubis" (default) or "ubis-sharded" — the tier rides the
same ``StreamingIndex`` front door either way.  The stream covers many
clusters but queries hammer a small hot subset: the untouched postings'
heat decays, the device watermark (``tier_hot_max``) spills their float
tiles to the pinned host pool, and search serves them ADC-only with a
host-side exact rerank of the final candidates.
"""
import sys

import numpy as np

from repro.api import make_index
from repro.core import UBISConfig, metrics


def main(engine: str = "ubis"):
    rng = np.random.default_rng(0)
    dim, n, k_hot = 32, 8000, 4
    cents = rng.normal(size=(48, dim)) * 6

    def batch(n, lo=0, hi=48):
        a = rng.integers(lo, hi, n)
        return (cents[a] + rng.normal(size=(n, dim))).astype(np.float32)

    cfg = UBISConfig(dim=dim, max_postings=1024, capacity=96,
                     l_min=10, l_max=80, max_ids=1 << 18, nprobe=8,
                     use_pallas="off",
                     use_pq=True, pq_m=8, rerank_k=192,
                     use_tier=True, tier_hot_max=24)
    data = batch(n)
    index = make_index(engine, cfg, data[:2000])
    queries = batch(96, 0, k_hot)              # the hot working set

    per = n // 8
    for step in range(8):
        index.insert(data[step * per:(step + 1) * per],
                     np.arange(step * per, (step + 1) * per))
        index.search(queries, 10)              # heat the hot clusters
        index.flush(max_ticks=6)
    index.flush(max_ticks=40)

    tiers = index.memory_tiers()
    found = index.search(queries, 10).ids
    true = index.exact(queries, 10).ids
    rec = metrics.recall_at_k(found, np.asarray(true))
    print(f"live vectors: {index.live_count()}")
    print(f"spilled postings: {int(index.stats['tier_resident'])} "
          f"(spills {int(index.stats['tier_spilled'])}, "
          f"promotes {int(index.stats['tier_promoted'])})")
    print(f"memory: device {tiers['device'] / 2**20:.1f} MB, "
          f"host {tiers['host'] / 2**20:.1f} MB "
          f"(sums to {index.memory_bytes() / 2**20:.1f} MB untiered)")
    print(f"recall@10 vs exact (mostly-cold index): {rec:.3f}")
    assert rec >= 0.9, rec


if __name__ == "__main__":
    main(*sys.argv[1:2])
