"""Fault-tolerance demo on the cluster plane: stream into a 2-worker
multi-process cluster, checkpoint, SIGKILL a worker mid-stream, and
watch the coordinator restart it and replay the journal — the live
multiset digest proves nothing was lost or duplicated.  A second,
freshly-built cluster then restores the manifest and serves the same
index.

Workers are real OS processes (``python -m repro.cluster.worker``)
speaking the schema-versioned frame protocol over pipes; the
coordinator here holds every planner and no device state.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import tempfile

import numpy as np


def main():
    from repro.cluster import ClusterCoordinator
    from repro.core.types import UBISConfig
    from repro.obs import Obs

    rng = np.random.default_rng(0)
    cfg = UBISConfig(dim=16, max_postings=64, capacity=96, l_min=10,
                     l_max=80, nprobe=64, max_ids=1 << 13,
                     cache_capacity=2048, use_pallas="off")
    cents = rng.normal(size=(20, 16)) * 5.0
    draw = rng.integers(0, 20, 1100)
    data = (cents[draw] + rng.normal(size=(1100, 16))).astype(np.float32)

    obs = Obs()
    cluster = ClusterCoordinator(cfg, data[:100], workers=2,
                                 backend="multiprocess", round_size=128,
                                 spread_per_tick=64, obs=obs, seed=0)
    ckpt = tempfile.mkdtemp(prefix="cluster_ck_")
    try:
        print("phase 1: stream 400 vectors into 2 worker processes")
        cluster.insert(data[100:500], np.arange(400))
        cluster.flush()
        print(f"  live={cluster.live_count()} "
              f"per-worker={cluster.worker_live().tolist()}")

        manifest = cluster.checkpoint(ckpt)
        print(f"phase 2: checkpoint -> {ckpt} "
              f"(digest {manifest['combined_digest']:#x})")

        print("phase 3: stream 300 more, then SIGKILL worker 0")
        cluster.insert(data[500:800], np.arange(400, 700))
        cluster.tick()
        before = cluster.snapshot().digest
        cluster.backend.kill_worker(0)
        after = cluster.snapshot().digest   # first call trips recovery
        lost = obs.events("worker_lost")[-1]
        rst = obs.events("worker_restarted")[-1]
        print(f"  worker {lost['worker']} lost ({lost['reason']}); "
              f"restarted from checkpoint={rst['from_checkpoint']} "
              f"+ {rst['replayed']} replayed commands")
        assert after == before, "live multiset changed across restart"
        print(f"  multiset digest preserved ({after:#x}), "
              f"live={cluster.live_count()}")

        print("phase 4: fresh cluster restores the manifest")
        cluster2 = ClusterCoordinator(cfg, data[:100], workers=2,
                                      backend="multiprocess",
                                      round_size=128, seed=0)
        try:
            cluster2.restore(ckpt)
            assert (cluster2.snapshot().digest
                    == manifest["combined_digest"])
            r = cluster2.search(data[150:156], 8)
            print(f"  restored live={cluster2.live_count()}, "
                  f"search ok ({int((np.asarray(r.ids) >= 0).sum())} hits)")
        finally:
            cluster2.close()
        print("elastic restart OK")
    finally:
        cluster.close()


if __name__ == "__main__":
    main()
