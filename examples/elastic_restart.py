"""Fault-tolerance demo: train, checkpoint, then restore the SAME
checkpoint onto a *different* mesh (elastic rescale) and keep training.

On real hardware this is the node-failure / cluster-resize path: the
checkpoint stores host-assembled global arrays keyed by tree path, so a
restore may target any device count; shardings are re-derived from the
new mesh and arrays are placed (= resharded) on load.

This demo runs in two subprocesses with different fake device counts
(4 then 8) to prove the reshard-on-restore path end to end.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import os
import subprocess
import sys
import tempfile
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PHASE = """
import os, sys
import jax, jax.numpy as jnp, numpy as np
from repro.models import get_model
from repro.models.layers import values, axes_of, sharding_rules
from repro.distributed.sharding import make_rules, to_named_sharding
from repro.checkpoint import CheckpointManager
from repro.optim import AdamW, AdamWConfig
from repro.data import TokenStream

ckpt_dir, data_ax, model_ax, steps = sys.argv[1:5]
data_ax, model_ax, steps = int(data_ax), int(model_ax), int(steps)
mesh = jax.make_mesh((data_ax, model_ax), ("data", "model"))
rules = make_rules(mesh, "train")
model = get_model("tinyllama-1.1b", reduced=True)
tree = model.init(jax.random.key(0))
pshard = to_named_sharding(mesh, axes_of(tree), rules)
params = jax.device_put(values(tree), pshard)
opt = AdamW(AdamWConfig(), lr=1e-3)
ostate = opt.init(params)
oshard = to_named_sharding(mesh, opt.state_axes(axes_of(tree)), rules)
mgr = CheckpointManager(ckpt_dir, async_save=False)
stream = TokenStream(vocab=model.cfg.vocab, seq_len=32, batch_per_host=4)
start = 0
s0, restored, extra = mgr.restore_latest(
    {"params": params, "opt": ostate},
    shardings={"params": pshard, "opt": oshard})
if s0 is not None:
    params, ostate = restored["params"], restored["opt"]
    stream.load_state_dict(extra["stream"])
    start = s0
    print(f"[mesh {data_ax}x{model_ax}] resumed from step {s0} "
          f"(resharded onto {mesh.devices.size} devices)")
ctx = dict(rules, __mesh__=mesh)
def step_fn(p, o, b):
    with sharding_rules(ctx):
        (l, _), g = jax.value_and_grad(model.train_loss,
                                       has_aux=True)(p, b)
        p, o, _ = opt.apply(p, g, o)
    return p, o, l
step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
for s in range(start, steps):
    b = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
    params, ostate, loss = step_fn(params, ostate, b)
    print(f"[mesh {data_ax}x{model_ax}] step {s} loss {float(loss):.4f}")
mgr.save(steps, {"params": params, "opt": ostate},
         extra={"stream": stream.state_dict()})
mgr.wait()
"""


def run_phase(ckpt, devices, data_ax, model_ax, steps):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(ROOT, "src"),
               TF_CPP_MIN_LOG_LEVEL="2")
    r = subprocess.run(
        [sys.executable, "-c", PHASE, ckpt, str(data_ax), str(model_ax),
         str(steps)], env=env, capture_output=True, text=True)
    print(r.stdout, end="")
    if r.returncode != 0:
        print(r.stderr[-2000:])
        raise SystemExit(1)


def main():
    ckpt = tempfile.mkdtemp(prefix="elastic_")
    print("phase 1: 4 devices (2x2 mesh), steps 0-3")
    run_phase(ckpt, 4, 2, 2, 3)
    print("phase 2: 8 devices (2x4 mesh) — elastic restore + steps 3-6")
    run_phase(ckpt, 8, 2, 4, 6)
    print("elastic restart OK")


if __name__ == "__main__":
    main()
