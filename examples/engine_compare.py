"""Engine comparison through the one front door — the paper's Table-I
story as one loop over engine names.

Every engine (UBIS, SPFresh, the static SPANN snapshot, the
FreshDiskANN graph, and the sharded UBIS driver) is built by
``repro.api.make_index`` and driven through the identical
``StreamingIndex`` calls: no engine-specific branches anywhere in the
workload.  SPANN's refused updates show up honestly as recall decay
against everything streamed.

    PYTHONPATH=src python examples/engine_compare.py
"""
import numpy as np

from repro.api import ENGINES, make_index
from repro.core import UBISConfig, metrics


def main():
    rng = np.random.default_rng(0)
    dim, n_batches, per_batch = 24, 5, 800
    centres = rng.normal(size=(12, dim)) * 6

    def batch(shift):
        a = rng.integers(0, len(centres), per_batch)
        return (centres[a] + shift + rng.normal(
            size=(per_batch, dim))).astype(np.float32)

    batches = [batch(0.4 * s) for s in range(n_batches)]
    queries = np.concatenate([b[:16] for b in batches])
    cfg = UBISConfig(dim=dim, max_postings=512, capacity=96,
                     max_ids=1 << 16, use_pallas="off")

    print(f"{'engine':>14} | recall@10 vs stream | rejected")
    for engine in ENGINES:
        idx = make_index(engine, cfg, batches[0],
                         seed_ids=np.arange(per_batch),
                         round_size=256, bg_ops_per_round=8,
                         max_nodes=8192)
        next_id, rejected = 0, 0
        seen_v, seen_i = [], []
        for b in batches:
            ids = np.arange(next_id, next_id + len(b))
            next_id += len(b)
            seen_v.append(b)
            seen_i.append(ids)
            rejected += idx.insert(b, ids).rejected
            idx.tick()
        idx.flush(max_ticks=20)
        found = idx.search(queries, 10).ids
        sv, si = np.concatenate(seen_v), np.concatenate(seen_i)
        d2 = ((queries[:, None, :] - sv[None]) ** 2).sum(-1)
        true = si[np.argsort(d2, axis=1)[:, :10]]
        rec = metrics.recall_at_k(np.asarray(found), true)
        print(f"{engine:>14} | {rec:19.3f} | {rejected}")


if __name__ == "__main__":
    main()
