"""The observability plane, end to end on a streaming serving workload.

    PYTHONPATH=src python examples/observability.py [--profile-dir DIR]

One ``Obs`` plane is shared by the driver and the serving engine, so a
single exposition covers every layer:

* driver counters under the shared schema (``index_*`` series);
* structured trace events — every background mark/split/merge, tier
  move, and PQ retrain states its reason;
* request spans (queue wait, service, end-to-end latency) from the
  serving engine;
* the sampled live-recall probe, shadow-executing 25% of served query
  batches against ``exact()``.

The script streams ingest + query traffic through a ``ServingEngine``,
then prints the Prometheus exposition, a few trace events, and the
probe's rolling recall.  ``--profile-dir`` additionally captures a
``jax.profiler`` trace of the first working pump (view with
TensorBoard or Perfetto).
"""
import argparse

import numpy as np

from repro.api import make_index
from repro.core import UBISConfig
from repro.obs import parse_exposition
from repro.serving import ServingConfig, ServingEngine


def main(profile_dir=None):
    rng = np.random.default_rng(0)
    dim, n = 32, 6000
    cents = rng.normal(size=(24, dim)) * 5

    def batch(m):
        a = rng.integers(0, 24, m)
        return (cents[a] + rng.normal(size=(m, dim))).astype(np.float32)

    cfg = UBISConfig(dim=dim, max_postings=512, capacity=96, l_min=10,
                     l_max=80, max_ids=1 << 18, nprobe=16,
                     use_pallas="off")
    data = batch(n)
    index = make_index("ubis", cfg, data[:1500], seed=0, round_size=512,
                       bg_ops_per_round=8)
    engine = ServingEngine(index, ServingConfig(
        search_batch=16, search_deadline_s=1e-3, insert_deadline_s=5e-3,
        tick_every=1, default_k=10,
        recall_probe=0.25, recall_probe_rows=8,
        obs_profile_dir=profile_dir))

    per = n // 8
    tickets = []
    for step in range(8):
        lo = step * per
        tickets.append(engine.submit_insert(
            data[lo:lo + per], np.arange(lo, lo + per)))
        for _ in range(6):
            tickets.append(engine.submit_search(batch(1), 10))
        engine.drain()
    assert all(t.done() for t in tickets)

    # ---- one exposition, every layer --------------------------------
    text = engine.obs.to_prometheus()
    series = parse_exposition(text)            # proves it parses
    print(f"== exposition: {len(series)} series ==")
    for name in ("index_inserted", "index_bg_split", "index_bg_merge",
                 "index_search_probed", "serve_latency_seconds_count",
                 "live_recall", "live_recall_probes"):
        print(f"  {name} = {series.get(name)}")

    lat = engine.obs.snapshot()["serve_latency_seconds"]
    print(f"== request spans == n={lat['count']} "
          f"p50={lat['p50']*1e3:.2f}ms p99={lat['p99']*1e3:.2f}ms")

    evs = list(engine.obs.events())
    print(f"== trace ring: {len(evs)} events ==")
    for e in evs[-4:]:
        print("  " + str({k: e[k] for k in list(e)[:6]}))
    marks = engine.obs.events("bg_mark")
    if marks:
        print(f"  bg_mark reasons: "
              f"{sorted({e['reason'] for e in marks})}")

    if engine.probe is not None:
        print(f"== live recall (rolling over "
              f"{int(series['live_recall_probes'])} probes): "
              f"{engine.probe.rolling_recall:.3f} ==")
    if profile_dir:
        print(f"profiler trace written under {profile_dir}")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile-dir", default=None)
    raise SystemExit(main(ap.parse_args().profile_dir))
