"""Quickstart: build a streaming index through the one front door,
stream updates, search.

    PYTHONPATH=src python examples/quickstart.py [engine]

``engine`` is any of repro.api.ENGINES ("ubis" default; try
"ubis-sharded" for the distributed driver — identical API).
"""
import sys

import numpy as np

from repro.api import make_index
from repro.core import UBISConfig, metrics


def main(engine: str = "ubis"):
    rng = np.random.default_rng(0)
    dim = 32
    # a drifting mixture: new clusters appear over time (fresh vectors)
    centres = rng.normal(size=(16, dim)) * 6

    def batch(n, shift):
        c = centres + shift
        a = rng.integers(0, len(c), n)
        return (c[a] + rng.normal(size=(n, dim))).astype(np.float32)

    cfg = UBISConfig(dim=dim, max_postings=1024, capacity=96,
                     l_min=10, l_max=80, balance_factor=0.15,
                     max_ids=1 << 18, use_pallas="off")
    data0 = batch(2000, 0.0)
    index = make_index(engine, cfg, data0)    # k-means-seeded, empty
    index.insert(data0, np.arange(2000))      # initial load

    next_id = 2000
    for step in range(5):                     # streaming updates
        fresh = batch(1000, shift=step * 0.5)
        r = index.insert(fresh, np.arange(next_id, next_id + 1000))
        next_id += 1000
        index.tick()                          # background split/merge/GC
        q = batch(64, shift=step * 0.5)
        found = index.search(q, k=10).ids
        true = index.exact(q, 10).ids
        rec = metrics.recall_at_k(found, np.asarray(true))
        print(f"batch {step}: +{r.accepted + r.cached} vectors, "
              f"recall@10 = {rec:.3f}")

    index.delete(np.arange(0, 1000))          # expire stale vectors
    index.flush()                             # drain background work
    print("live vectors:", index.live_count())
    print("throughput:", {k: round(v, 1)
                          for k, v in index.throughput().items()
                          if k in ("tps", "qps")})


if __name__ == "__main__":
    main(*sys.argv[1:2])
