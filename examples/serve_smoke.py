"""Serving front door, vector-only: every ingest batch and query rides
the ``ServingEngine`` request queue (fill-or-deadline batching, update
lane, cadence ticks) — without building the embedding backbone, which
``RetrievalServer`` now constructs lazily on first token use.

    PYTHONPATH=src python examples/serve_smoke.py
"""
import numpy as np

from repro.core import UBISConfig
from repro.launch.serve import RetrievalServer, ServeConfig
from repro.serving import ServingConfig, ServingEngine


def main():
    dim = 32
    icfg = UBISConfig(dim=dim, max_postings=512, capacity=96,
                      max_ids=1 << 16, use_pallas="off")
    rng = np.random.default_rng(0)
    seeds = rng.normal(size=(256, dim)).astype(np.float32)
    srv = RetrievalServer(ServeConfig(embed_dim=dim, k=5), index_cfg=icfg,
                          seed_vectors=seeds)

    # streaming ingest through the update lane (tick_every=1 cadence)
    all_ids = []
    for _ in range(6):
        vecs = rng.normal(size=(128, dim)).astype(np.float32)
        all_ids.append((srv.ingest_vectors(vecs), vecs))
    srv.index.flush(max_ticks=30)

    # queries through the search lane: self-retrieval on the last batch
    ids, vecs = all_ids[-1]
    res = srv.query_vectors(vecs[:16], k=5)
    hits = sum(int(ids[i]) in set(row.tolist())
               for i, row in enumerate(res.ids))
    print(f"ingested {srv.stats['ingested']} vectors, "
          f"{srv.stats['queries']} queries; "
          f"fresh self-retrieval {hits}/16")
    assert hits >= 14, hits

    # the engine's own per-request surface: tickets resolve on pump,
    # short batches fire on deadline, full ones on fill
    eng = ServingEngine(srv.index, ServingConfig(search_batch=8,
                                                 default_k=5))
    tickets = [eng.submit_search(vecs[i]) for i in range(8)]
    eng.pump()                       # lane full -> fires without force
    assert all(t.done() for t in tickets)
    row = tickets[0].result()
    print(f"ticket 0: top hit {int(row.ids[0, 0])} "
          f"(latency {row.seconds * 1e3:.2f} ms), "
          f"batches={dict(eng.counters)['search_batches']}")
    assert int(row.ids[0, 0]) == int(ids[0])
    print("serve smoke OK")


if __name__ == "__main__":
    main()
