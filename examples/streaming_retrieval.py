"""End-to-end serving driver (the paper-kind scenario): an LM embeds a
stream of fresh documents, UBIS indexes them online, and queries are
answered while updates continue — the Figure-1 workload (vehicles
publishing trajectories while others search).

    PYTHONPATH=src python examples/streaming_retrieval.py \
        [--steps N] [--docs-per-step N] [--engine NAME]

Reduced scale for CI smoke: ``--steps 4 --docs-per-step 48``.
"""
import argparse
import time

import numpy as np

from repro.core import UBISConfig
from repro.launch.serve import RetrievalServer, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--docs-per-step", type=int, default=128)
    ap.add_argument("--seq", type=int, default=24)
    ap.add_argument("--engine", default="ubis",
                    help="any repro.api.ENGINES name")
    args = ap.parse_args(argv)

    cfg = ServeConfig(arch="tinyllama-1.1b", reduced=True, embed_dim=48)
    icfg = UBISConfig(dim=48, max_postings=1024, capacity=96,
                      max_ids=1 << 18, use_pallas="off")
    rng = np.random.default_rng(0)
    seed_vecs = rng.normal(size=(512, 48)).astype(np.float32)
    server = RetrievalServer(cfg, index_cfg=icfg, seed_vectors=seed_vecs,
                             engine=args.engine)
    vocab = server.embedder.model.cfg.vocab

    print(f"streaming {args.steps} batches of fresh docs with "
          f"interleaved queries (engine={args.engine})")
    t0 = time.time()
    for step in range(args.steps):
        docs = rng.integers(0, vocab,
                            (args.docs_per_step, args.seq)).astype(np.int32)
        ids = server.ingest_tokens(docs)
        if step % 3 == 2:
            queries = rng.integers(0, vocab,
                                   (32, args.seq)).astype(np.int32)
            server.query_tokens(queries, k=5)
            qv = server.embedder.embed(queries)
            rec = server.recall_check(qv, k=5)
            print(f"  step {step}: index={server.stats['ingested']} docs, "
                  f"recall@5={rec:.3f}")
    server.index.flush()
    dt = time.time() - t0
    print(f"done: {server.stats['ingested']} docs, "
          f"{server.stats['queries']} queries in {dt:.1f}s")
    # freshness check: the most recent batch must be retrievable
    probe = server.embedder.embed(docs[:8])
    found = server.query_vectors(probe, k=3).ids
    fresh_hits = sum(int(ids[i]) in set(f.tolist())
                     for i, f in enumerate(found[:8]))
    print(f"fresh-batch self-retrieval: {fresh_hits}/8")


if __name__ == "__main__":
    main()
