"""Train a ~100M-param LM for a few hundred steps on the synthetic
stream (deliverable b: end-to-end training driver).

The config is a width/depth-reduced tinyllama (same family) sized to
~100M params.  On this 1-core CPU container a 300-step run takes tens of
minutes; pass --steps 30 for a quick check (loss drops well below the
unigram entropy either way).

    PYTHONPATH=src python examples/train_lm.py --steps 30
"""
import argparse

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    # ~120M params: 12 layers x d_model 768, llama-family
    train_mod.main([
        "--arch", "tinyllama-1.1b",
        "--override", "n_layers=12", "--override", "d_model=768",
        "--override", "n_heads=12", "--override", "n_kv=4",
        "--override", "d_ff=2048", "--override", "head_dim=64",
        "--steps", str(args.steps), "--batch", "8", "--seq", "256",
        "--lr", "1e-3", "--warmup", "20", "--remat", "none",
        "--ckpt", args.ckpt, "--ckpt-every", "100",
        "--log-every", "10",
    ])


if __name__ == "__main__":
    main()
