"""One front door: the engine-agnostic streaming-index API.

    from repro.api import make_index, list_engines

    idx = make_index("ubis", cfg, seed_vectors)      # any engine name
    idx.insert(vecs, ids); idx.tick()
    res = idx.search(queries, k=10)                  # SearchResult

Engines: ``ubis`` | ``spfresh`` | ``spann`` | ``freshdiskann`` |
``ubis-sharded`` — all conform to :class:`StreamingIndex`, so an engine
comparison is one loop over names (see ``benchmarks/figures.py``
``figengines`` and ``examples/engine_compare.py``).  ``list_engines()``
returns each engine's :class:`EngineSpec` with capability flags
(``supports_tier`` / ``supports_pq`` / ``supports_shards``) so callers
never probe engines with try/except.

The registry and the sharded driver import the engine modules, which in
turn import :mod:`repro.api.types` for the result dataclasses — load
them lazily here so ``repro.core`` never re-enters a half-initialised
``repro.api`` package.
"""
from .types import (SearchRequest, SearchResult, StreamingIndex,  # noqa: F401
                    Ticket, TickReport, UpdateResult)

__all__ = ["StreamingIndex", "SearchResult", "UpdateResult", "TickReport",
           "SearchRequest", "Ticket", "make_index", "list_engines",
           "engine_spec", "EngineSpec", "ENGINES", "ShardedUBISDriver",
           "RebalancePlanner"]


def __getattr__(name):
    if name in ("make_index", "ENGINES", "list_engines", "engine_spec",
                "EngineSpec"):
        from . import registry
        return getattr(registry, name)
    if name == "ShardedUBISDriver":
        from .sharded_driver import ShardedUBISDriver
        return ShardedUBISDriver
    if name == "RebalancePlanner":
        from .rebalance import RebalancePlanner
        return RebalancePlanner
    raise AttributeError(f"module 'repro.api' has no attribute {name!r}")
