"""Host-side cross-shard rebalance planning (the control plane).

The sharded background program reports per-shard pressure rows
``(live_postings, free_slots, cache_backlog, live_vectors)`` —
``balance.shard_pressure`` computed inside the tick, zero extra
collectives.  ``RebalancePlanner`` turns those rows plus a host view of
the posting-length table into donor -> receiver posting migrations for
``core.sharded.make_sharded_migrate``.

Two triggers, in priority order:

  * **slot saturation** — a shard whose live sub-pool crosses the
    ``watermark`` fraction is the paper's "imbalanced distribution"
    failure mode lifted to the pod: its splits defer (no local free
    slot until epoch GC) and its inserts park in the host cache while
    cold shards sit on free capacity.  The parked-cache backlog counts
    toward saturation (as ``min_gap``-vector posting equivalents) — a
    shard drowning in parked jobs triggers even below the live-posting
    watermark.  Donors above the watermark shed postings until they
    project below it.
  * **vector imbalance** — even without saturation, a skewed stream
    concentrates live vectors; when the max/min shard occupancy ratio
    exceeds ``ratio_target`` (and the absolute gap is worth at least a
    posting), postings flow from the heaviest to the lightest shard.

The plan is greedy and *simulated-monotone*: every move updates the
planner's local copy of the pressure rows, a vector-mode move must fit
HALF the donor->receiver occupancy gap (a move of mass L closes the gap
by 2L, so the gap strictly shrinks and the pair can never swap roles —
the ping-pong guard), and receivers are only shards with free slots
that stay below the watermark.  The planner is
pure host-side numpy: it owns no device state and is trivially testable.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class RebalancePlanner:
    """Picks donor->receiver posting migrations from pressure stats.

    ``n_shards`` / ``pool_per_shard`` describe the mesh layout
    (``max_postings // n_shards`` local pids per shard, contiguous
    blocks).  ``min_gap`` is the absolute live-vector gap below which
    vector-mode rebalance is not worth a migration (default: one full
    posting, set by the driver to ``cfg.l_max``).
    """

    n_shards: int
    pool_per_shard: int
    watermark: float = 0.85
    ratio_target: float = 1.2
    max_moves: int = 8
    min_gap: int = 80
    #: per-move decision records from the most recent ``plan`` call:
    #: ``{"src", "dst", "donor", "trigger": "watermark" | "spread"}`` —
    #: the obs plane's rebalance trace payload
    last_moves: list = dataclasses.field(default_factory=list)

    def _saturation(self, live, backlog):
        """Slot-saturation fraction per shard.  Parked-cache backlog
        counts as demand the shard has already failed to absorb: it is
        converted to posting-slots-worth at ``min_gap`` (= one full
        posting) vectors each, so a shard drowning in parked jobs
        triggers even while its live-posting count sits below the
        watermark."""
        pending = np.asarray(backlog, float) / max(self.min_gap, 1)
        return (np.asarray(live, float) + pending) / self.pool_per_shard

    def needs(self, pressure: np.ndarray) -> bool:
        """Cheap per-tick gate: does this pressure report justify pulling
        the (M,)-sized host views and running ``plan``?"""
        if self.n_shards < 2:
            return False
        p = np.asarray(pressure)
        if (self._saturation(p[:, 0], p[:, 2]) > self.watermark).any():
            return True
        occ = p[:, 3].astype(float)
        gap = occ.max() - occ.min()
        return bool(gap > self.min_gap
                    and occ.max() > max(occ.min(), 1.0) * self.ratio_target)

    def plan(self, pressure: np.ndarray, lengths: np.ndarray,
             movable: np.ndarray):
        """Returns (src_pids, dst_shards) int32 arrays, at most
        ``max_moves`` long.

        ``lengths`` is the global posting-length table; ``movable``
        marks postings that may migrate (allocated + NORMAL — the
        migrate round re-checks on device, so a stale host view only
        costs a skipped job, never a lost posting).

        Each accepted move is recorded in ``last_moves`` with its
        trigger ("watermark" = slot saturation, "spread" = vector
        imbalance) for the caller's trace events.
        """
        S, pool = self.n_shards, self.pool_per_shard
        p = np.asarray(pressure).astype(float)
        live = p[:, 0].copy()
        free = p[:, 1].copy()
        backlog = p[:, 2].copy()
        occ = p[:, 3].copy()
        lengths = np.asarray(lengths)
        movable = np.asarray(movable)
        # per-shard donor candidates, longest first (a long posting
        # shifts the most vector mass per migration)
        cands = []
        for s in range(S):
            pids = np.flatnonzero(movable[s * pool:(s + 1) * pool]
                                  & (lengths[s * pool:(s + 1) * pool] > 0))
            pids = pids + s * pool
            cands.append(list(pids[np.argsort(-lengths[pids])]))

        src, dst = [], []
        self.last_moves = []
        for _ in range(self.max_moves):
            sat = self._saturation(live, backlog)
            over = np.flatnonzero(sat > self.watermark)
            if len(over):
                d = int(over[np.argmax(sat[over])])
                slot_mode = True                    # slot mode: any length
            else:
                d = int(np.argmax(occ))
                r0 = int(np.argmin(occ))
                gap0 = occ[d] - occ[r0]
                if (gap0 <= self.min_gap
                        or occ[d] <= max(occ[r0], 1.0) * self.ratio_target):
                    break
                slot_mode = False
            # receiver: lightest shard with a free slot, below watermark
            order = np.argsort(occ)
            r = next((int(s) for s in order
                      if s != d and free[s] > 0
                      and (live[s] + 1) / pool <= self.watermark), None)
            if r is None:
                break
            # vector mode: the move must fit HALF the gap to the shard
            # actually receiving (occ[d] -= L, occ[r] += L closes the
            # gap by 2L) — every move strictly shrinks the donor/receiver
            # gap, so the pair can never swap roles and re-migrate the
            # same posting back (the ping-pong guard)
            gap_cap = None if slot_mode else (occ[d] - occ[r]) / 2.0
            if gap_cap is not None and gap_cap <= 0:
                break
            pick = None
            for i, pid in enumerate(cands[d]):
                if gap_cap is None or lengths[pid] <= gap_cap:
                    pick = cands[d].pop(i)
                    break
            if pick is None:
                break
            src.append(pick)
            dst.append(r)
            self.last_moves.append(
                {"src": int(pick), "dst": int(r), "donor": int(d),
                 "trigger": "watermark" if slot_mode else "spread"})
            mass = float(lengths[pick])
            occ[d] -= mass
            occ[r] += mass
            live[d] -= 1                 # donor copy retires immediately
            live[r] += 1
            free[r] -= 1                 # donor slot frees only after GC
        return (np.asarray(src, np.int32), np.asarray(dst, np.int32))
