"""Engine registry: ``make_index(engine, cfg, seed_vectors, **kw)``.

One constructor for every engine in the paper's comparison.  All
engines take the same ``UBISConfig`` (the registry rewrites ``mode``
and, for the graph baseline, translates to a ``GraphConfig``), and
keyword arguments unknown to an engine are silently dropped — which is
what lets one shared kwargs dict drive a whole engine-comparison loop
with zero engine-specific branches at the call site:

    for spec in list_engines():
        idx = make_index(spec.name, cfg, seed, seed_ids=ids0,
                         round_size=512, bg_ops_per_round=8)
        ...same insert/delete/search/tick/flush loop...

Each registry entry is an :class:`EngineSpec` — name, builder, allowed
kwargs, and **capability flags** (``supports_tier`` / ``supports_pq`` /
``supports_shards`` / ``updatable`` + the contract-harness ``audit``
tier), so callers that used to probe engines with try/except or
hard-coded name tuples (figengines, the contract harness, the tiered
property tests) now ask the registry.

``seed_vectors`` semantics follow each engine's construction story:
the cluster engines (ubis/spfresh/ubis-sharded/ubis-cluster) use them
for k-means seeding only (NOT inserted); the build-once engines (spann,
freshdiskann) ingest them under ``seed_ids`` (default ``arange``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import numpy as np

from ..core.types import UBISConfig
from .types import StreamingIndex

_DRIVER_KW = frozenset({
    "seed", "round_size", "bg_ops_per_round", "drain_per_tick",
    "insert_retries", "gc_lag", "reassign_after_split",
    "pq_retrain_every", "tier_moves_per_tick", "tier_rerank_host",
    "tier_async", "obs", "obs_profile_dir"})
_UBIS_KW = _DRIVER_KW | {"fused_tick"}
_SHARDED_KW = _DRIVER_KW | {"mesh", "shard_cache_scan", "rebalance",
                            "rebalance_watermark", "rebalance_ratio",
                            "migrate_per_tick", "route_alpha"}
_CLUSTER_KW = frozenset({
    "seed", "round_size", "bg_ops_per_round", "drain_per_tick",
    "insert_retries", "gc_lag", "reassign_after_split",
    "pq_retrain_every", "tier_moves_per_tick", "tier_rerank_host",
    "obs", "shard_cache_scan", "rebalance", "rebalance_watermark",
    "rebalance_ratio", "migrate_per_tick", "route_alpha", "workers",
    "backend", "worker_devices", "mesh_shape", "spread_ratio",
    "spread_per_tick", "rpc_timeout"})
_SPANN_KW = frozenset({"seed", "round_size", "obs"})
_GRAPH_KW = frozenset({"max_nodes", "degree", "beam", "alpha",
                       "consolidate_every", "obs"})


def _pick(kw: dict, allowed: frozenset) -> dict:
    return {k: v for k, v in kw.items() if k in allowed}


def _with_mode(cfg: UBISConfig, mode: str) -> UBISConfig:
    return cfg if cfg.mode == mode else dataclasses.replace(cfg, mode=mode)


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """One registry entry: how to build an engine + what it supports.

    ``audit`` is the contract-harness audit tier (``state`` = full
    IndexState multiset equality, ``count`` = live-count + no
    resurrection, ``static`` = every update refused); ``build`` is the
    lazily-importing constructor (same signature for every engine).
    """

    name: str
    description: str
    build: Callable[..., StreamingIndex]
    kwargs: frozenset
    supports_tier: bool = False
    supports_pq: bool = False
    supports_shards: bool = False
    updatable: bool = True
    audit: str = "state"

    def make(self, cfg: UBISConfig, seed_vectors, *, seed_ids=None,
             **kw) -> StreamingIndex:
        return self.build(cfg, seed_vectors, seed_ids, _pick(kw, self.kwargs))


def _build_ubis_mode(mode):
    def build(cfg, seed_vectors, seed_ids, kw):
        from ..core.driver import UBISDriver
        return UBISDriver(_with_mode(cfg, mode), seed_vectors, **kw)
    return build


def _build_sharded(cfg, seed_vectors, seed_ids, kw):
    from .sharded_driver import ShardedUBISDriver
    return ShardedUBISDriver(_with_mode(cfg, "ubis"), seed_vectors, **kw)


def _build_cluster(cfg, seed_vectors, seed_ids, kw):
    from ..cluster import ClusterCoordinator
    return ClusterCoordinator(_with_mode(cfg, "ubis"), seed_vectors, **kw)


def _seed_arrays(seed_vectors, seed_ids):
    seeds = np.asarray(seed_vectors, np.float32)
    ids = (np.arange(len(seeds)) if seed_ids is None
           else np.asarray(seed_ids, np.int64))
    return seeds, ids


def _build_spann(cfg, seed_vectors, seed_ids, kw):
    from ..core.spann import SPANNStatic
    seeds, ids = _seed_arrays(seed_vectors, seed_ids)
    return SPANNStatic(_with_mode(cfg, "ubis"), seeds, ids, **kw)


def _build_freshdiskann(cfg, seed_vectors, seed_ids, kw):
    from ..core.freshdiskann import FreshDiskANN, GraphConfig
    seeds, ids = _seed_arrays(seed_vectors, seed_ids)
    kw = dict(kw)
    obs = kw.pop("obs", None)
    kw.setdefault("max_nodes", 1 << 17)
    gcfg = GraphConfig(dim=cfg.dim, **kw)
    return FreshDiskANN(gcfg, seeds, ids, obs=obs)


_REGISTRY: dict[str, EngineSpec] = {spec.name: spec for spec in (
    EngineSpec(
        name="ubis",
        description="the paper's balanced updatable cluster index "
                    "(UBISDriver)",
        build=_build_ubis_mode("ubis"), kwargs=_UBIS_KW,
        supports_tier=True, supports_pq=True, audit="state"),
    EngineSpec(
        name="spfresh",
        description="UBISDriver in the SPFresh lock/strict-trigger mode",
        build=_build_ubis_mode("spfresh"), kwargs=_UBIS_KW,
        supports_tier=True, supports_pq=True, audit="state"),
    EngineSpec(
        name="spann",
        description="build-once SPANN snapshot (updates refused as "
                    "rejected/blocked counts)",
        build=_build_spann, kwargs=_SPANN_KW,
        updatable=False, audit="static"),
    EngineSpec(
        name="freshdiskann",
        description="FreshDiskANN Vamana graph baseline",
        build=_build_freshdiskann, kwargs=_GRAPH_KW, audit="count"),
    EngineSpec(
        name="ubis-sharded",
        description="ShardedUBISDriver: host orchestration over the "
                    "jitted pod-sharded programs",
        build=_build_sharded, kwargs=_SHARDED_KW,
        supports_tier=True, supports_pq=True, supports_shards=True,
        audit="state"),
    EngineSpec(
        name="ubis-cluster",
        description="coordinator/worker cluster plane: all planners on "
                    "the coordinator, ShardedUBISDriver workers behind "
                    "the serializable command protocol",
        build=_build_cluster, kwargs=_CLUSTER_KW,
        supports_tier=True, supports_pq=True, supports_shards=True,
        audit="state"),
)}

ENGINES = tuple(_REGISTRY)


def list_engines() -> Tuple[EngineSpec, ...]:
    """Every registered engine's spec, registration order."""
    return tuple(_REGISTRY.values())


def engine_spec(engine: str) -> EngineSpec:
    """The :class:`EngineSpec` for one engine name."""
    if engine not in _REGISTRY:
        raise ValueError(f"unknown engine {engine!r}; choose from "
                         f"{ENGINES}")
    return _REGISTRY[engine]


def make_index(engine: str, cfg: UBISConfig, seed_vectors, *,
               seed_ids=None, **kw) -> StreamingIndex:
    """Build any engine behind the ``StreamingIndex`` front door."""
    return engine_spec(engine).make(cfg, seed_vectors, seed_ids=seed_ids,
                                    **kw)
