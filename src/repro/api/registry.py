"""Engine registry: ``make_index(engine, cfg, seed_vectors, **kw)``.

One constructor for every engine in the paper's comparison.  All
engines take the same ``UBISConfig`` (the registry rewrites ``mode``
and, for the graph baseline, translates to a ``GraphConfig``), and
keyword arguments unknown to an engine are silently dropped — which is
what lets one shared kwargs dict drive a whole engine-comparison loop
with zero engine-specific branches at the call site:

    for engine in ENGINES:
        idx = make_index(engine, cfg, seed, seed_ids=ids0,
                         round_size=512, bg_ops_per_round=8)
        ...same insert/delete/search/tick/flush loop...

``seed_vectors`` semantics follow each engine's construction story:
the cluster engines (ubis/spfresh/ubis-sharded) use them for k-means
seeding only (NOT inserted); the build-once engines (spann,
freshdiskann) ingest them under ``seed_ids`` (default ``arange``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.types import UBISConfig
from .types import StreamingIndex

ENGINES = ("ubis", "spfresh", "spann", "freshdiskann", "ubis-sharded")

_DRIVER_KW = {"seed", "round_size", "bg_ops_per_round", "drain_per_tick",
              "insert_retries", "gc_lag", "reassign_after_split",
              "pq_retrain_every", "tier_moves_per_tick",
              "tier_rerank_host"}
_UBIS_KW = _DRIVER_KW | {"fused_tick"}
_SHARDED_KW = _DRIVER_KW | {"mesh", "shard_cache_scan", "rebalance",
                            "rebalance_watermark", "rebalance_ratio",
                            "migrate_per_tick", "route_alpha"}
_SPANN_KW = {"seed", "round_size"}
_GRAPH_KW = {"max_nodes", "degree", "beam", "alpha", "consolidate_every"}


def _pick(kw: dict, allowed: set) -> dict:
    return {k: v for k, v in kw.items() if k in allowed}


def _with_mode(cfg: UBISConfig, mode: str) -> UBISConfig:
    return cfg if cfg.mode == mode else dataclasses.replace(cfg, mode=mode)


def make_index(engine: str, cfg: UBISConfig, seed_vectors, *,
               seed_ids=None, **kw) -> StreamingIndex:
    """Build any engine behind the ``StreamingIndex`` front door."""
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; choose from "
                         f"{ENGINES}")
    if engine in ("ubis", "spfresh"):
        from ..core.driver import UBISDriver
        return UBISDriver(_with_mode(cfg, engine), seed_vectors,
                          **_pick(kw, _UBIS_KW))
    if engine == "ubis-sharded":
        from .sharded_driver import ShardedUBISDriver
        return ShardedUBISDriver(_with_mode(cfg, "ubis"), seed_vectors,
                                 **_pick(kw, _SHARDED_KW))
    seeds = np.asarray(seed_vectors, np.float32)
    ids = (np.arange(len(seeds)) if seed_ids is None
           else np.asarray(seed_ids, np.int64))
    if engine == "spann":
        from ..core.spann import SPANNStatic
        return SPANNStatic(_with_mode(cfg, "ubis"), seeds, ids,
                           **_pick(kw, _SPANN_KW))
    from ..core.freshdiskann import FreshDiskANN, GraphConfig
    gkw = _pick(kw, _GRAPH_KW)
    gkw.setdefault("max_nodes", 1 << 17)
    gcfg = GraphConfig(dim=cfg.dim, **gkw)
    return FreshDiskANN(gcfg, seeds, ids)
