"""Host orchestration for the sharded index — the distributed driver.

``ShardedUBISDriver`` presents the *identical* ``StreamingIndex`` API as
the single-device ``UBISDriver``, with every data-plane call dispatched
to the jitted sharded programs (``core/sharded.py``) over a TPU-pod
mesh:

  * **insert** — padded replicated job rounds through
    ``make_sharded_insert``; the per-job accepted mask drives the
    retry-with-a-tick-between loop, and jobs still rejected after the
    retries park in the **host-mediated vector cache** (below);
  * **delete** — ``make_sharded_delete`` rounds (owner-shard tombstones,
    replicated id-map/cache updates, zero collectives);
  * **search** — ``make_sharded_search`` per (k, nprobe), queries padded
    to the data-axis multiple;
  * **tick**  — ONE ``make_sharded_background`` call (per-shard select →
    mark → execute → epoch GC, collective-free, reporting per-shard
    pressure rows), then the **cross-shard rebalance** stage (below),
    then the host cache drain, then the PQ codebook re-train on cadence.

**Cross-shard rebalance.**  Structural ownership makes every background
op shard-local — which is exactly why a skewed stream can saturate one
shard's sub-pool (splits defer until epoch GC frees a local slot,
inserts park in the cache) while cold shards sit on free capacity; with
contiguous pid seeding, a fresh index even starts with EVERY posting on
shard 0.  The tick's pressure rows feed a host-side
``rebalance.RebalancePlanner``; when a shard crosses the saturation
watermark (or the live-vector spread exceeds ``rebalance_ratio``), the
planner picks donor→receiver posting moves and ONE
``make_sharded_migrate`` round executes them (owner extraction,
free-stack-granted installation, replicated id-map rewrite).  The
background program itself stays collective-free — pressure rides out
through the sharded output layout, and migration is its own round.

**Host-mediated vector cache.**  The cache arrays are *replicated*
across model shards, so no shard may write them inside the SPMD
background/insert programs (replica divergence).  The host still OWNS
admission — it decides which jobs park — but executes it as one plain
jitted ``update.cache_append`` round: the program is deterministic over
the replicated arrays, so every replica computes identical bytes and
nothing round-trips through the host (the PR 3 follow-up; admission
used to pull all five cache arrays to numpy and re-replicate them).
Cached entries stay *searchable* — the sharded search's cache scan sees
them — and deletable; each tick drains up to ``drain_per_tick`` of them
back through the sharded insert round.

**Snapshot contract.**  The sharded rounds return the free stack
fail-safe EMPTY; ``snapshot()`` gathers the state and passes it through
``update.ensure_free_stack``, which rebuilds the canonical stack and
*asserts* it (the encoded form of the old sharded.py comment) — a
gathered state that would alias live postings cannot escape.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import tier as tier_mod, update
from ..core import version_manager as vm
from ..core.build import initial_state
from ..core.driver import SearchDispatch
from ..core.sharded import (index_specs, make_sharded_background,
                            make_sharded_delete, make_sharded_exact,
                            make_sharded_insert, make_sharded_migrate,
                            make_sharded_search)
from ..core.types import STATUS_NORMAL, IndexState, UBISConfig
from ..kernels import ops
from ..obs import Obs
from .rebalance import RebalancePlanner
from .types import SearchResult, TickReport, UpdateResult


def default_mesh(cfg: UBISConfig) -> Mesh:
    """All local devices on the ``model`` axis (posting-pool sharding),
    falling back toward fewer shards until ``max_postings`` divides."""
    n = len(jax.devices())
    m = n
    while m > 1 and (cfg.max_postings % m or n % m):
        m -= 1
    return jax.make_mesh((n // m, m), ("data", "model"))


class ShardedUBISDriver:
    """Streaming driver over a sharded index (a ``StreamingIndex``)."""

    def __init__(self, cfg: UBISConfig, seed_vectors=None, *,
                 mesh: Optional[Mesh] = None, seed: int = 0,
                 round_size: int = 1024, bg_ops_per_round: int = 8,
                 drain_per_tick: int = 256, insert_retries: int = 2,
                 gc_lag: int = 16, reassign_after_split: bool = True,
                 pq_retrain_every: int = 32,
                 shard_cache_scan: bool = True,
                 rebalance: bool = True,
                 rebalance_watermark: float = 0.85,
                 rebalance_ratio: float = 1.2,
                 migrate_per_tick: int = 8,
                 route_alpha: float = 0.0,
                 tier_moves_per_tick: int = 32,
                 tier_rerank_host: bool = True,
                 tier_async: bool = False,
                 obs: Optional[Obs] = None,
                 obs_profile_dir: Optional[str] = None):
        if not cfg.is_ubis:
            raise ValueError("ShardedUBISDriver is UBIS-mode only "
                             "(SPFresh's lock model is single-device)")
        if seed_vectors is None:
            raise ValueError("seed_vectors required (used for k-means seeds)")
        self.cfg = cfg
        self.mesh = mesh if mesh is not None else default_mesh(cfg)
        if cfg.max_postings % self.mesh.shape["model"]:
            raise ValueError("max_postings must divide the model axis")
        self.round_size = int(round_size)
        self.bg_ops = int(bg_ops_per_round)
        self.drain_n = int(drain_per_tick)
        self.retries = int(insert_retries)
        self.gc_lag = int(gc_lag)
        self.pq_retrain_every = int(pq_retrain_every)
        self._ticks = 0
        self._pq_key = jax.random.key(seed + 0x517C0DE)
        # observability plane: shared-schema stats facade + tracer (the
        # same key set as UBISDriver — pinned by tests/test_obs.py)
        self.obs = obs if obs is not None else Obs()
        ops.observe_fallbacks(self.obs)
        self.stats = self.obs.driver_stats()
        self._profile_dir = obs_profile_dir
        self._profiled = False

        specs = index_specs(cfg)
        self._shardings = jax.tree_util.tree_map(
            lambda sp: NamedSharding(self.mesh, sp), specs,
            is_leaf=lambda x: isinstance(x, P))
        self._rep = NamedSharding(self.mesh, P())
        state = initial_state(cfg, jnp.asarray(seed_vectors),
                              key=jax.random.key(seed))
        self.state: IndexState = jax.device_put(state, self._shardings)

        # cold-tier plane (cfg.use_tier): pinned host pool + planner;
        # per-shard accounting rides on contiguous pid blocks
        self.tier = (tier_mod.TierManager(
            cfg, max_moves=int(tier_moves_per_tick),
            rerank_host=tier_rerank_host, obs=self.obs)
            if cfg.use_tier else None)
        # dispatch the tier DMA at tick start, reconcile at tick end
        self.tier_async = bool(tier_async)
        self._insert_fn = make_sharded_insert(cfg, self.mesh,
                                              route_alpha=float(route_alpha))
        # replica-identical jitted cache admission (see module docstring)
        def _admit(state, vecs, ids, targets, want, _cfg=cfg):
            return update.cache_append(state, _cfg, vecs, ids, targets,
                                       want)
        self._cache_admit_fn = jax.jit(_admit)
        self._delete_fn = make_sharded_delete(cfg, self.mesh)
        self._background_fn = make_sharded_background(
            cfg, self.mesh, bg_ops=self.bg_ops,
            reassign=reassign_after_split)
        # cross-shard rebalance: host planner + one jitted migrate round
        self.n_shards = int(self.mesh.shape["model"])
        self.rebalance = bool(rebalance) and self.n_shards > 1
        self._pressure = None
        self.planner = RebalancePlanner(
            self.n_shards, cfg.max_postings // self.n_shards,
            watermark=rebalance_watermark, ratio_target=rebalance_ratio,
            max_moves=int(migrate_per_tick), min_gap=cfg.l_max)
        # built for every multi-shard mesh (compile is lazy), so
        # toggling ``self.rebalance`` after construction — as figskew's
        # on/off comparison does — can never hit a missing attribute
        self._migrate_jobs = int(migrate_per_tick)
        if self.n_shards > 1:
            self._migrate_fn = make_sharded_migrate(
                cfg, self.mesh, jobs=self._migrate_jobs)
        self._shard_cache_scan = shard_cache_scan
        self._search_fns = {}
        self._exact_fns = {}
        # queries shard over the data axes: batches pad to this multiple
        axes = self.mesh.axis_names
        qaxes = ("pod", "data") if "pod" in axes else ("data",)
        self._q_mult = 1
        for a in qaxes:
            self._q_mult *= self.mesh.shape[a]

    # ------------------------------------------------------------------
    # foreground
    # ------------------------------------------------------------------

    def insert(self, vecs, ids, *, tick_between: bool = True) -> UpdateResult:
        """Stream (vecs, ids) through padded sharded insert rounds.

        Rejected jobs retry up to ``insert_retries`` times with a
        background tick in between; survivors park in the host-mediated
        cache (searchable immediately, drained on later ticks) and only
        overflow beyond the cache is reported rejected.
        """
        vecs = np.asarray(vecs, np.float32)
        ids = np.asarray(ids, np.int64).astype(np.int32)
        if len(vecs) != len(ids):
            raise ValueError(f"vecs/ids length mismatch: {len(vecs)} vs "
                             f"{len(ids)}")
        if ids.size and (ids.min() < 0 or ids.max() >= self.cfg.max_ids):
            raise ValueError("ids out of range for cfg.max_ids")
        t0 = time.perf_counter()
        n_acc = 0
        pending, rej_t = (vecs, ids), None
        for attempt in range(self.retries + 1):
            acc, rej_v, rej_i, rej_t = self._insert_rounds(*pending)
            n_acc += acc
            if rej_i is None:
                pending = None
                break
            pending = (rej_v, rej_i)
            if tick_between:
                self.tick()
        n_cache = n_rej = 0
        if pending is not None:
            n_cache = self._cache_put(*pending, targets=rej_t)
            n_rej = len(pending[1]) - n_cache
        jax.block_until_ready(self.state.lengths)
        dt = time.perf_counter() - t0
        self.stats["insert_time"] += dt
        self.stats["inserted"] += n_acc + n_cache
        self.stats["rejected"] += n_rej
        self.obs.emit("insert", accepted=n_acc, cached=n_cache,
                      rejected=n_rej, seconds=round(dt, 6))
        return UpdateResult(accepted=n_acc, cached=n_cache, rejected=n_rej,
                            seconds=dt)

    def _insert_rounds(self, vecs, ids):
        """One pass of padded sharded insert rounds.  Returns
        (n_accepted, rej_vecs | None, rej_ids | None, rej_targets | None)
        — ``rej_targets`` is the global pid each rejected job was routed
        to (-1 if nothing insertable), carried into the cache so the
        pressure stats can attribute the parked backlog to its shard."""
        J = self.round_size
        n_acc = 0
        rej_v, rej_i, rej_t = [], [], []
        for off in range(0, len(ids), J):
            cv, ci = vecs[off:off + J], ids[off:off + J]
            n = len(ci)
            pad = J - n
            valid = np.concatenate([np.ones(n, bool), np.zeros(pad, bool)])
            cv = np.concatenate([cv, np.zeros((pad, self.cfg.dim),
                                              np.float32)])
            ci = np.concatenate([ci, np.zeros(pad, np.int32)])
            self.state, accm, routed = self._insert_fn(
                self.state, jnp.asarray(cv), jnp.asarray(ci),
                jnp.asarray(valid))
            accm = np.asarray(accm)[:n]
            n_acc += int(accm.sum())
            if self.tier is not None:       # appends heat their target
                self.tier.note_targets(np.asarray(routed)[:n][accm])
            if not accm.all():
                rej_v.append(cv[:n][~accm])
                rej_i.append(ci[:n][~accm])
                rej_t.append(np.asarray(routed)[:n][~accm])
        if not rej_i:
            return n_acc, None, None, None
        return (n_acc, np.concatenate(rej_v), np.concatenate(rej_i),
                np.concatenate(rej_t))

    def delete(self, ids) -> UpdateResult:
        ids = np.asarray(ids, np.int64).astype(np.int32)
        t0 = time.perf_counter()
        J = self.round_size
        n_done = 0
        for off in range(0, len(ids), J):
            ci = ids[off:off + J]
            pad = J - len(ci)
            valid = np.concatenate([np.ones(len(ci), bool),
                                    np.zeros(pad, bool)])
            ci = np.concatenate([ci, np.zeros(pad, np.int32)])
            self.state, done = self._delete_fn(
                self.state, jnp.asarray(ci), jnp.asarray(valid))
            n_done += int(np.asarray(done).sum())
        jax.block_until_ready(self.state.lengths)
        dt = time.perf_counter() - t0
        self.stats["delete_time"] += dt
        self.stats["deleted"] += n_done
        self.obs.emit("delete", deleted=n_done, blocked=0,
                      seconds=round(dt, 6))
        return UpdateResult(deleted=n_done, seconds=dt)

    def search(self, queries, k: int,
               nprobe: Optional[int] = None) -> SearchResult:
        return self.collect_search(self.dispatch_search(queries, k, nprobe))

    def dispatch_search(self, queries, k: int,
                        nprobe: Optional[int] = None) -> SearchDispatch:
        """Launch the jitted sharded search without awaiting it (the
        serving engine's overlap seam; pair with ``collect_search``)."""
        q = np.asarray(queries, np.float32)
        t0 = time.perf_counter()
        # cold tier + host rerank: widen the final candidate set to
        # rerank_k so the exact host pass has room to reorder (the
        # device top-k orders spilled candidates by ADC score; narrower
        # widths measurably cost recall on a mostly-cold index)
        k_eff = (max(k, self.cfg.rerank_k)
                 if self.tier is not None and self.tier.rerank_host
                 else k)
        key = (k_eff, nprobe)
        fn = self._search_fns.get(key)
        if fn is None:
            fn = self._search_fns[key] = make_sharded_search(
                self.cfg, self.mesh, k=k_eff, nprobe=nprobe,
                shard_cache_scan=self._shard_cache_scan)
        qp = q
        pad = (-q.shape[0]) % self._q_mult
        if pad:
            qp = np.concatenate([q, np.zeros((pad, q.shape[1]),
                                             np.float32)])
        # per-dispatch fallback accounting (see the single-device
        # driver): the signature covers routing, not batch shape
        sig = ("sharded-search", self.cfg.use_pallas, self.cfg.dim,
               self.cfg.capacity, self.cfg.use_pq, self.cfg.pq_ksub)
        with ops.count_fallback_dispatches(self.obs, sig):
            found, scores = fn(self.state, jnp.asarray(qp))
        return SearchDispatch(state=self.state, queries=q, k=k,
                              found=found, scores=scores, probe=None,
                              t0=t0)

    def collect_search(self, disp: SearchDispatch) -> SearchResult:
        """Await a dispatched sharded search and finish the host tail
        against the dispatch-time state."""
        Q = disp.queries.shape[0]
        found = np.asarray(disp.found)[:Q]
        scores = np.asarray(disp.scores)[:Q]
        if self.tier is not None:
            # search-heat: the postings holding the found candidates
            # (the sharded search does not export its probe list)
            safe = np.clip(found, 0, self.cfg.max_ids - 1)
            loc = np.asarray(disp.state.id_loc[jnp.asarray(safe)])
            pid = loc[(found >= 0) & (loc >= 0)] // self.cfg.capacity
            self.tier.note_probes(pid)
            if self.tier.rerank_host and len(self.tier.pool):
                found, scores, n_sp = tier_mod.host_rerank(
                    found, scores, disp.queries, self.tier.pool, loc,
                    np.asarray(disp.state.tier_spilled),
                    self.cfg.capacity)
                self.stats["search_spilled_hits"] += n_sp
            found, scores = found[:, :disp.k], scores[:, :disp.k]
        dt = time.perf_counter() - disp.t0
        self.stats["search_time"] += dt
        self.stats["queries"] += Q
        # introspection from the already-transferred result arrays (the
        # sharded search exports no probe list — see note_probes above)
        self.stats["search_results"] += int((found >= 0).sum())
        if self.cfg.use_pq:
            self.stats["search_adc_batches"] += 1
        else:
            self.stats["search_exact_batches"] += 1
        return SearchResult(ids=found, scores=scores, seconds=dt)

    # ------------------------------------------------------------------
    # background
    # ------------------------------------------------------------------

    def tick(self) -> TickReport:
        """One background round: the collective-free sharded
        select/mark/execute/GC program (which also reports per-shard
        pressure), then the cross-shard rebalance stage, then the host
        cache drain, then the PQ re-train on cadence."""
        if self._profile_dir and not self._profiled:
            self._profiled = True
            with self.obs.profile(self._profile_dir):
                return self._tick_impl()
        return self._tick_impl()

    def _tick_impl(self) -> TickReport:
        t0 = time.perf_counter()
        plan = None
        if self.tier is not None and self.tier_async:
            # tick-start dispatch: spill D2H + promote H2D overlap the
            # sharded background program; reconcile commits at tick end
            # (decayed=True — the sharded round decays every tick)
            st, plan = self.tier.dispatch(self.state, decayed=True)
            if st is not self.state:
                self.state = jax.device_put(st, self._shardings)
        executed, reclaimed, _ = self.exec_background()
        migrated = self._rebalance() if self.rebalance else 0
        drained = self.exec_drain()
        retrained = self._pq_retrain()
        if self.tier is not None and self.tier_async:
            st, n_s, n_p = self.tier.reconcile(self.state, plan)
            if st is not self.state:
                self.state = jax.device_put(st, self._shardings)
            self.stats["tier_spilled"] += n_s
            self.stats["tier_promoted"] += n_p
            self.stats["tier_resident"] = len(self.tier.pool)
            spilled, promoted = n_s, n_p
        else:
            spilled, promoted = self._tier_step()
        dt = time.perf_counter() - t0
        self.stats["bg_time"] += dt
        self.stats["drained"] += drained
        self.obs.emit("tick", executed=executed, drained=drained,
                      migrated=migrated, gc=reclaimed, pq=retrained,
                      spilled=spilled, promoted=promoted,
                      seconds=round(dt, 6))
        # marked=0, honestly: the sharded round selects and executes in
        # ONE atomic program, so there is no separate mark phase to
        # count — quiescence is executed == 0 (+ empty cache), and a
        # caller porting UBISDriver's flush check gets exactly that
        return TickReport(executed=executed, drained=drained,
                          migrated=migrated, gc=reclaimed,
                          pq_retrained=retrained, spilled=spilled,
                          promoted=promoted, seconds=dt)

    def flush(self, max_ticks: int = 200) -> int:
        """Tick until quiescent (no structural work, no migrations left
        to plan, cache empty, no tier moves in flight)."""
        for i in range(max_ticks):
            r = self.tick()
            cache_n = int(np.asarray(self.state.cache_valid).sum())
            if (r.executed == 0 and r.migrated == 0 and cache_n == 0
                    and r.spilled == 0 and r.promoted == 0):
                return i + 1
        return max_ticks

    # ---- plan/execute halves (the coordinator/worker seam) ------------
    # The cluster worker (``repro.cluster.worker``) drives these pieces
    # directly: observations (pressure, plan inputs) ship up to the
    # coordinator, plans (migrate moves, retrain slot, tier lanes) ship
    # back down, and ``_tick_impl`` above is just the in-process
    # composition of the same halves — one code path, two deployments.

    def exec_background(self):
        """Run ONE sharded background program (select/mark/execute/GC)
        and record the pressure rows.  Returns
        (executed, reclaimed, pressure)."""
        t0 = time.perf_counter()
        ver = int(jax.device_get(self.state.global_version))
        gc_min = ver - self.gc_lag if ver > self.gc_lag else 0
        self.state, ex, gc, press = self._background_fn(self.state,
                                                        jnp.uint32(gc_min))
        executed, reclaimed = int(ex), int(gc)
        self._pressure = np.asarray(press)
        self.stats["bg_exec_time"] += time.perf_counter() - t0
        self.stats["bg_ops"] += executed
        self.stats["bg_gc"] += reclaimed
        return executed, reclaimed, self._pressure

    def rebalance_inputs(self):
        """The migrate planner's (M,)-sized observation: live lengths
        plus the movable mask (allocated NORMAL postings).  Serializable
        — the cluster worker ships these to the coordinator."""
        lengths = np.asarray(self.state.lengths)
        status = np.asarray(vm.unpack_status(self.state.rec_meta))
        movable = (np.asarray(self.state.allocated)
                   & (status == STATUS_NORMAL))
        return lengths, movable

    def exec_migrate(self, src, dst) -> np.ndarray:
        """Execute one already-planned migration round (owner extract,
        free-stack install, id-map rewrite + tier-pool remap).  Returns
        the per-move committed mask."""
        src = np.asarray(src, np.int32)
        dst = np.asarray(dst, np.int32)
        B = self._migrate_jobs
        pad = B - len(src)
        valid = np.concatenate([np.ones(len(src), bool),
                                np.zeros(pad, bool)])
        src = np.concatenate([src, np.full(pad, -1, np.int32)])
        dst = np.concatenate([dst, np.zeros(pad, np.int32)])
        self.state, mig, new_pids = self._migrate_fn(
            self.state, jnp.asarray(src), jnp.asarray(dst),
            jnp.asarray(valid))
        mig = np.asarray(mig)[:B - pad] if pad else np.asarray(mig)
        if self.tier is not None:
            # spilled postings migrate WITHOUT promotion: the device
            # round carried codes + flags, the host pool entry follows
            new_pids = np.asarray(new_pids)
            for j in np.flatnonzero(mig):
                if int(src[j]) in self.tier.pool:
                    self.tier.pool.remap(int(src[j]), int(new_pids[j]))
        self.stats["migrated"] += int(mig.sum())
        return mig

    def _rebalance(self) -> int:
        """Plan + execute one migration round when the tick's pressure
        rows cross a trigger.  The planner's cheap ``needs`` gate keeps
        quiescent ticks free of the (M,)-sized host reads."""
        press = self._pressure
        if press is None or not self.planner.needs(press):
            return 0
        lengths, movable = self.rebalance_inputs()
        src, dst = self.planner.plan(press, lengths, movable)
        if len(src) == 0:
            return 0
        mig = self.exec_migrate(src, dst)
        n = int(mig.sum())
        # per-move decision trace: the planner recorded each accepted
        # move's trigger; mark which ones the device round committed
        self.obs.emit(
            "rebalance",
            trigger=(self.planner.last_moves[0]["trigger"]
                     if self.planner.last_moves else "none"),
            moves=[{**mv, "committed": bool(mig[j])}
                   for j, mv in enumerate(self.planner.last_moves)],
            migrated=n)
        return n

    def shard_pressure(self) -> Optional[np.ndarray]:
        """Last tick's (S, 4) pressure rows — ``(live_postings,
        free_slots, cache_backlog, live_vectors)`` per shard — or None
        before the first tick."""
        return self._pressure

    def shard_occupancy(self) -> np.ndarray:
        """Live vectors per posting-pool shard, computed host-side (no
        tick required) — the ``figskew`` spread metric."""
        from ..core.metrics import shard_live_vectors
        return shard_live_vectors(self.state, self.n_shards)

    # ---- host-mediated vector cache -----------------------------------

    def _replicate(self, x):
        return jax.device_put(jnp.asarray(x), self._rep)

    def _cache_put(self, vecs, ids, targets=None) -> int:
        """Park jobs in the replicated cache as ONE jitted
        ``update.cache_append`` round per chunk: the program is
        deterministic over the replicated cache arrays, so every replica
        writes identical bytes and no array ever round-trips through the
        host (id_loc takes the ``-2 - slot`` encoding, so the entries
        stay searchable and deletable).  ``targets`` carries the routed
        global pid per job — the pressure stats' backlog attribution
        (-1 when unknown)."""
        vecs = np.asarray(vecs, np.float32)
        ids = np.asarray(ids, np.int32)
        tgts = (np.full(len(ids), -1, np.int32) if targets is None
                else np.asarray(targets, np.int32))
        J = self.round_size
        n = 0
        for off in range(0, len(ids), J):
            cv, ci, ct = (vecs[off:off + J], ids[off:off + J],
                          tgts[off:off + J])
            pad = J - len(ci)
            want = np.concatenate([np.ones(len(ci), bool),
                                   np.zeros(pad, bool)])
            cv = np.concatenate([cv, np.zeros((pad, self.cfg.dim),
                                              np.float32)])
            ci = np.concatenate([ci, np.zeros(pad, np.int32)])
            ct = np.concatenate([ct, np.full(pad, -1, np.int32)])
            st, ok = self._cache_admit_fn(
                self.state, jnp.asarray(cv), jnp.asarray(ci),
                jnp.asarray(ct), jnp.asarray(want))
            self.state = jax.device_put(st, self._shardings)
            got = int(np.asarray(ok).sum())
            n += got
            if got < int(want.sum()):
                break                       # cache full — rest rejected
        self.stats["host_cached"] += n
        return n

    def _drain_cache(self) -> int:
        """Pop up to ``drain_per_tick`` cached vectors and feed them back
        through the sharded insert round; failures re-park."""
        cval = np.array(self.state.cache_valid)
        slots = np.flatnonzero(cval)[:self.drain_n]
        if slots.size == 0:
            return 0
        vecs = np.asarray(self.state.cache_vecs)[slots].astype(np.float32)
        ids = np.asarray(self.state.cache_ids)[slots]
        cval[slots] = False
        self.state = dataclasses.replace(
            self.state, cache_valid=self._replicate(cval))
        n_acc, rej_v, rej_i, rej_t = self._insert_rounds(vecs, ids)
        if rej_i is not None:
            self._cache_put(rej_v, rej_i, targets=rej_t)
        return n_acc

    # public plan/execute name for the cluster worker (same op)
    exec_drain = _drain_cache

    def _pq_retrain(self) -> int:
        """Versioned codebook re-train on tick cadence (quant plane):
        the cadence decision half; execution is ``exec_pq_retrain``.
        The cluster coordinator owns this counter instead — it sends an
        explicit retrain slot in the tick plan."""
        if not self.cfg.use_pq or self.pq_retrain_every <= 0:
            return 0
        self._ticks += 1
        if self._ticks % self.pq_retrain_every:
            return 0
        return self.exec_pq_retrain()

    def exec_pq_retrain(self) -> int:
        """Execute one codebook re-train round now.  ``retrain_round``
        is a plain jit program: GSPMD partitions it over the existing
        shardings; the output is re-pinned to the canonical specs so
        later shard_map calls see exact layouts."""
        from ..quant import pq
        if self.tier is not None:
            # promote spilled postings pinned to the evicted slot first
            # (see tier.TierManager.promote_retrain_pinned); the retrain
            # round below re-pins the canonical shardings
            self.state, n = self.tier.promote_retrain_pinned(self.state)
            self.stats["tier_promoted"] += n
        evict = (int(self.state.pq_active) + 1) % self.cfg.pq_versions
        self._pq_key, k = jax.random.split(self._pq_key)
        st = pq.retrain_round(self.state, self.cfg, k)
        self.state = jax.device_put(st, self._shardings)
        self.stats["pq_retrains"] += 1
        self.stats["pq_generation"] = int(
            self.state.pq_slot_gen[self.state.pq_active])
        self.obs.emit("pq_retrain", reason="cadence", evicted_slot=evict,
                      generation=int(self.stats["pq_generation"]))
        return 1

    # ---- cold-tier plane ----------------------------------------------

    def _tier_step(self) -> tuple:
        """Spill/promote planning + moves; re-pins the canonical
        shardings after any mutation (the tier rounds are plain jit)."""
        if self.tier is None:
            return 0, 0
        # decayed=True: the sharded background program runs (and decays
        # the heat counters) every tick
        st, n_s, n_p = self.tier.tick(self.state, decayed=True)
        if st is not self.state:
            self.state = jax.device_put(st, self._shardings)
        self.stats["tier_spilled"] += n_s
        self.stats["tier_promoted"] += n_p
        self.stats["tier_resident"] = len(self.tier.pool)
        return n_s, n_p

    def force_spill(self, n: int) -> int:
        """Spill the ``n`` coldest hot postings now (test hook)."""
        if self.tier is None:
            return 0
        st, moved = self.tier.force_spill(self.state, n)
        self.state = jax.device_put(st, self._shardings)
        self.stats["tier_spilled"] += moved
        self.stats["tier_resident"] = len(self.tier.pool)
        return moved

    def force_promote(self, n=None) -> int:
        """Promote up to ``n`` spilled postings (all when None)."""
        if self.tier is None:
            return 0
        st, moved = self.tier.force_promote(self.state, n)
        self.state = jax.device_put(st, self._shardings)
        self.stats["tier_promoted"] += moved
        self.stats["tier_resident"] = len(self.tier.pool)
        return moved

    def tier_host_bytes_by_shard(self) -> np.ndarray:
        """Host-pool bytes per shard (contiguous pid blocks) — the
        per-shard tier-pool accounting."""
        out = np.zeros(self.n_shards, np.int64)
        if self.tier is not None:
            pool_span = self.cfg.max_postings // self.n_shards
            from ..core.types import tile_bytes
            tb = tile_bytes(self.state)
            for pid in self.tier.pool.pids():
                out[int(pid) // pool_span] += tb
        return out

    # ---- StreamingIndex protocol surface ------------------------------

    def snapshot(self) -> IndexState:
        """Gather to a single-device state with a canonical free stack
        (``update.ensure_free_stack`` asserts the contract — the sharded
        rounds hand back a fail-safe EMPTY stack).  With the cold tier
        on, spilled float tiles are written back into the gathered copy
        (flags stay set) so the snapshot is self-contained."""
        host = jax.device_get(self.state)
        st = jax.tree_util.tree_map(jnp.asarray, host)
        if self.tier is not None:
            st = self.tier.snapshot_fill(st)
        return update.ensure_free_stack(st)

    def load_snapshot(self, state: IndexState) -> "ShardedUBISDriver":
        """Adopt a ``snapshot()`` state: tier residency is re-derived
        from the persisted flags (spilled tiles move back to the host
        pool, device copies re-zeroed), then the state is re-pinned to
        this driver's mesh.  Returns self."""
        if self.tier is not None:
            state = self.tier.adopt(state)
        self.state = jax.device_put(state, self._shardings)
        return self

    def memory_bytes(self) -> int:
        """Total bytes across BOTH tiers (see ``memory_tiers``)."""
        from ..core.types import state_memory_bytes
        return state_memory_bytes(self.state)

    def memory_tiers(self) -> dict:
        """Device/host byte split; sums to ``memory_bytes()``."""
        if self.tier is not None:
            return self.tier.memory_tiers(self.state)
        return {"device": self.memory_bytes(), "host": 0}

    def exact(self, queries, k: int) -> SearchResult:
        """Exact top-k over live contents (recall oracle) — a
        ``shard_map``'d brute force: each shard scans only the postings
        and cache slice it owns against ITS OWN id rows, so the
        replicated-id-row partial-sum hazard of a plain GSPMD
        ``brute_force`` (ids silently scaled by the data-axis size)
        cannot arise, and the oracle no longer gathers the whole index
        to one device per call."""
        fn = self._exact_fns.get(k)
        if fn is None:
            fn = self._exact_fns[k] = make_sharded_exact(self.cfg,
                                                         self.mesh, k)
        queries = np.asarray(queries, np.float32)
        found, scores = fn(self.state, jnp.asarray(queries))
        if self.tier is not None:
            # spilled postings were excluded device-side; merge the
            # host-pool scan so the oracle stays exact under tiering
            found, scores = self.tier.exact_merge(self.state, queries,
                                                  found, scores, k)
        return SearchResult(ids=np.asarray(found),
                            scores=np.asarray(scores))

    def posting_lengths(self) -> np.ndarray:
        from ..core.metrics import live_posting_lengths
        return live_posting_lengths(self.state)

    def live_count(self) -> int:
        """Vectors in visible postings + the (replicated) cache."""
        return int(self.state.live_vector_count()) + int(
            np.asarray(self.state.cache_valid).sum())

    def throughput(self) -> dict:
        from ..core.metrics import throughput_from_stats
        return throughput_from_stats(self.stats)

    def close(self) -> None:
        """Detach this driver's ``Obs`` bundle from the process-global
        kernel-fallback plane (weakly held; see ``UBISDriver.close``)."""
        ops.discard_fallback_sink(self.obs)
