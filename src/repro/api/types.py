"""The engine-agnostic streaming-index contract (one front door).

Every engine in the paper's comparison — UBIS, SPFresh, SPANN,
FreshDiskANN, and the sharded UBIS driver — answers the same five
questions: ingest fresh vectors, expire stale ones, search, advance
background maintenance, and report what happened.  ``StreamingIndex``
pins that contract structurally (``typing.Protocol``: no inheritance
required), and the three result dataclasses replace the ad-hoc
dict/tuple returns the engines used to hand back.

The protocol is **batch-first**: ``insert``/``delete``/``search`` take
whole arrays, because every device program underneath is a fixed-shape
padded round.  Per-request serving (one query, one ticket) is the
*serving engine*'s job (``repro.serving``): it folds single
:class:`SearchRequest`\\ s into padded batches and hands each caller a
:class:`Ticket`.  Engines never see individual requests.

The PR 3 tuple/dict-compat dunders (``found, _ = idx.search(...)``,
``r["accepted"]``) are GONE — use the named fields (``res.ids``,
``res.scores``, ``r.accepted``).  See CHANGES.md for the migration
note.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional, Protocol, runtime_checkable

import numpy as np


@dataclasses.dataclass
class SearchResult:
    """One search batch.  ``ids`` is (Q, k) int32 with -1 where fewer
    than k hits exist; ``scores`` follows the repo-wide convention
    ``||v||^2 - 2 q.v`` (add ``||q||^2`` for true squared distances)."""

    ids: np.ndarray
    scores: np.ndarray
    seconds: float = 0.0


@dataclasses.dataclass
class UpdateResult:
    """Outcome of one insert() or delete() call (counts over the batch).

    insert fills accepted/cached/rejected; delete fills deleted/blocked.
    ``applied`` is the number of jobs the index actually absorbed.
    """

    accepted: int = 0
    cached: int = 0
    rejected: int = 0
    deleted: int = 0
    blocked: int = 0
    seconds: float = 0.0

    @property
    def applied(self) -> int:
        return self.accepted + self.cached + self.deleted


@dataclasses.dataclass
class TickReport:
    """Outcome of one background tick.

    ``migrated`` counts cross-shard posting migrations (the sharded
    driver's rebalance stage); ``spilled``/``promoted`` count cold-tier
    moves (float tiles demoted to / restored from the pinned host pool,
    ``cfg.use_tier``).  Engines without those stages leave them 0.
    """

    executed: int = 0
    drained: int = 0
    marked: int = 0
    migrated: int = 0
    gc: int = 0
    pq_retrained: int = 0
    spilled: int = 0
    promoted: int = 0
    seconds: float = 0.0


# ---------------------------------------------------------------------------
# request-first serving types (consumed by repro.serving)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SearchRequest:
    """One enqueued query.  The serving engine folds requests into
    padded device batches (fill-or-deadline), so a request is the unit
    of *latency accounting*, never the unit of device dispatch.

    ``t_submit`` is the submit timestamp on the engine's clock —
    injectable, so arrival traces replay deterministically in tests and
    in the open-loop benchmark."""

    vector: np.ndarray
    k: int
    t_submit: float
    ticket: "Ticket"


@dataclasses.dataclass
class Ticket:
    """Caller-side handle for one in-flight serving request.

    Resolved by the serving engine when the batch carrying the request
    completes; ``latency_s`` is then (resolve time - submit time) on the
    engine's clock.  ``result()`` pumps the owning engine until the
    ticket resolves, so a caller that only holds tickets can still make
    progress without touching the engine directly.
    """

    kind: str                        # "search" | "insert" | "delete"
    seq: int                         # engine-unique, monotone
    t_submit: float
    _value: Any = None
    _done: bool = False
    _t_done: float = 0.0
    # backref used by result() to drive the queue; None once resolved
    _pump: Optional[Callable[[], Any]] = None

    def done(self) -> bool:
        return self._done

    @property
    def latency_s(self) -> float:
        if not self._done:
            raise RuntimeError(f"ticket {self.kind}#{self.seq} unresolved")
        return self._t_done - self.t_submit

    def result(self, max_pumps: int = 10_000):
        """The resolved value (``SearchResult`` row view for searches,
        ``UpdateResult`` for updates).  Pumps the owning engine until
        the ticket resolves."""
        pumps = 0
        while not self._done:
            if self._pump is None:
                raise RuntimeError(
                    f"ticket {self.kind}#{self.seq} unresolved and "
                    "detached from its engine")
            self._pump()
            pumps += 1
            if pumps > max_pumps:
                raise RuntimeError(
                    f"ticket {self.kind}#{self.seq} still unresolved "
                    f"after {pumps} pumps — engine wedged?")
        return self._value

    def _resolve(self, value, t_done: float) -> None:
        self._value = value
        self._t_done = t_done
        self._done = True
        self._pump = None


@runtime_checkable
class StreamingIndex(Protocol):
    """The one front door every engine presents.

    Engines conform structurally — ``isinstance(x, StreamingIndex)``
    checks method presence at runtime.  ``stats`` is a mapping of
    monotone counters (engine-specific keys allowed; the common ones are
    inserted/deleted/queries and the *_time accumulators feeding
    throughput).  ``snapshot()`` returns a single-device-usable state
    pytree — for sharded engines this implies the gather plus the
    canonical free-stack rebuild (``update.ensure_free_stack``).
    """

    def insert(self, vecs, ids) -> UpdateResult: ...

    def delete(self, ids) -> UpdateResult: ...

    def search(self, queries, k: int) -> SearchResult: ...

    def tick(self) -> TickReport: ...

    def flush(self, max_ticks: int = 200) -> int: ...

    def snapshot(self) -> Any: ...

    def memory_bytes(self) -> int: ...

    def memory_tiers(self) -> Mapping: ...

    def exact(self, queries, k: int) -> SearchResult: ...

    def posting_lengths(self) -> np.ndarray: ...

    def live_count(self) -> int: ...

    @property
    def stats(self) -> Mapping: ...
