"""The engine-agnostic streaming-index contract (one front door).

Every engine in the paper's comparison — UBIS, SPFresh, SPANN,
FreshDiskANN, and the sharded UBIS driver — answers the same five
questions: ingest fresh vectors, expire stale ones, search, advance
background maintenance, and report what happened.  ``StreamingIndex``
pins that contract structurally (``typing.Protocol``: no inheritance
required), and the three result dataclasses replace the ad-hoc
dict/tuple returns the engines used to hand back.

Compatibility dunders: ``SearchResult`` iterates as ``(ids, scores)``
and the update/tick results subscript like the dicts they replace, so
``found, _ = idx.search(q, k)`` and ``r["accepted"]`` keep working while
call sites migrate to attribute access.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator, Mapping, Protocol, runtime_checkable

import numpy as np


@dataclasses.dataclass
class SearchResult:
    """One search batch.  ``ids`` is (Q, k) int32 with -1 where fewer
    than k hits exist; ``scores`` follows the repo-wide convention
    ``||v||^2 - 2 q.v`` (add ``||q||^2`` for true squared distances)."""

    ids: np.ndarray
    scores: np.ndarray
    seconds: float = 0.0

    def __iter__(self) -> Iterator[np.ndarray]:
        # legacy tuple shape: ``found, scores = idx.search(q, k)``
        return iter((self.ids, self.scores))


@dataclasses.dataclass
class UpdateResult:
    """Outcome of one insert() or delete() call (counts over the batch).

    insert fills accepted/cached/rejected; delete fills deleted/blocked.
    ``applied`` is the number of jobs the index actually absorbed.
    """

    accepted: int = 0
    cached: int = 0
    rejected: int = 0
    deleted: int = 0
    blocked: int = 0
    seconds: float = 0.0

    @property
    def applied(self) -> int:
        return self.accepted + self.cached + self.deleted

    def __getitem__(self, key: str):
        # legacy dict shape: ``r["accepted"]``
        return getattr(self, key)


@dataclasses.dataclass
class TickReport:
    """Outcome of one background tick.

    ``migrated`` counts cross-shard posting migrations (the sharded
    driver's rebalance stage); ``spilled``/``promoted`` count cold-tier
    moves (float tiles demoted to / restored from the pinned host pool,
    ``cfg.use_tier``).  Engines without those stages leave them 0.
    """

    executed: int = 0
    drained: int = 0
    marked: int = 0
    migrated: int = 0
    gc: int = 0
    pq_retrained: int = 0
    spilled: int = 0
    promoted: int = 0
    seconds: float = 0.0

    def __getitem__(self, key: str):
        return getattr(self, key)


@runtime_checkable
class StreamingIndex(Protocol):
    """The one front door every engine presents.

    Engines conform structurally — ``isinstance(x, StreamingIndex)``
    checks method presence at runtime.  ``stats`` is a mapping of
    monotone counters (engine-specific keys allowed; the common ones are
    inserted/deleted/queries and the *_time accumulators feeding
    throughput).  ``snapshot()`` returns a single-device-usable state
    pytree — for sharded engines this implies the gather plus the
    canonical free-stack rebuild (``update.ensure_free_stack``).
    """

    def insert(self, vecs, ids) -> UpdateResult: ...

    def delete(self, ids) -> UpdateResult: ...

    def search(self, queries, k: int) -> SearchResult: ...

    def tick(self) -> TickReport: ...

    def flush(self, max_ticks: int = 200) -> int: ...

    def snapshot(self) -> Any: ...

    def memory_bytes(self) -> int: ...

    def memory_tiers(self) -> Mapping: ...

    def exact(self, queries, k: int) -> SearchResult: ...

    def posting_lengths(self) -> np.ndarray: ...

    def live_count(self) -> int: ...

    @property
    def stats(self) -> Mapping: ...
