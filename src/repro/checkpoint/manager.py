"""Fault-tolerant checkpointing (no orbax in this environment).

Properties required at fleet scale (DESIGN.md §7):
  * atomic   — write to ``step_XXXX.tmp`` then rename; a crash mid-write
               never corrupts the latest checkpoint;
  * async    — serialization runs on a background thread so the train
               loop keeps stepping (one outstanding save at a time);
  * keep-N   — bounded disk usage;
  * elastic  — checkpoints store *global* (host-assembled) arrays keyed
               by tree path, so a restore may target a different mesh /
               device count / sharding than the save (reshard-on-load);
  * resumable data — the data-pipeline cursor and python RNG state ride
               along, so a replacement host resumes mid-epoch.
"""
from __future__ import annotations

import json
import os
import pickle
import re
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"step_(\d+)$")


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_pytree(tree, path: str, extra: Optional[dict] = None):
    """Atomic single-file save (npz + pickled treedef + extras)."""
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    treedef = jax.tree_util.tree_structure(tree)
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    with open(tmp + ".meta", "wb") as f:
        pickle.dump({"treedef_repr": str(treedef),
                     "keys": sorted(flat.keys()),
                     "extra": extra or {}}, f)
    os.replace(tmp + ".meta", path + ".meta")
    os.replace(tmp, path)


def restore_pytree(template, path: str, *, shardings=None):
    """Restore into the structure of ``template``.

    ``shardings``: optional tree of NamedShardings — arrays are placed
    (and thereby resharded) onto the *current* mesh, which may differ
    from the mesh at save time (elastic restore).
    """
    data = np.load(path)
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    keys = ["/".join(_path_str(q) for q in p) for p, _ in leaves_p]
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(keys))
    out = []
    for key, (path_, tmpl), sh in zip(keys, leaves_p, shard_leaves):
        arr = data[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"checkpoint/template shape mismatch at {key}: "
                f"{arr.shape} vs {tmpl.shape}")
        arr = arr.astype(tmpl.dtype)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr))
    with open(path + ".meta", "rb") as f:
        meta = pickle.load(f)
    return jax.tree_util.tree_unflatten(treedef, out), meta.get("extra", {})


# ---------------------------------------------------------------------
# cluster checkpoints: per-worker snapshots + a digest-carrying manifest
# ---------------------------------------------------------------------

CLUSTER_MANIFEST = "manifest.json"


class ClusterManifestError(RuntimeError):
    """A cluster checkpoint is partial, corrupt, or from a different
    protocol schema — restores must fail LOUDLY, never half-load."""


def save_cluster_checkpoint(directory: str, states, digests,
                            extra: Optional[dict] = None) -> dict:
    """Write one npz per worker state plus ``manifest.json``.

    ``states`` are flat field->numpy dicts
    (``cluster.protocol.state_to_payload``); ``digests`` the matching
    live-multiset digests.  Worker files land first, the manifest is
    renamed into place LAST — a crash mid-save leaves either a complete
    checkpoint or one with no manifest (which restore rejects), never a
    silently-partial one.
    """
    from ..cluster import protocol as _proto
    os.makedirs(directory, exist_ok=True)
    paths = []
    for w, st in enumerate(states):
        name = f"worker_{w:03d}.npz"
        tmp = os.path.join(directory, name + ".tmp")
        with open(tmp, "wb") as f:
            np.savez(f, **{k: np.asarray(v) for k, v in st.items()})
        os.replace(tmp, os.path.join(directory, name))
        paths.append(name)
    manifest = {
        "schema_version": _proto.SCHEMA_VERSION,
        "n_workers": len(paths),
        "paths": paths,
        "digests": [int(d) for d in digests],
        "combined_digest": _proto.combine_digests(digests),
        "extra": extra or {},
    }
    tmp = os.path.join(directory, CLUSTER_MANIFEST + ".tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, os.path.join(directory, CLUSTER_MANIFEST))
    return manifest


def load_cluster_checkpoint(directory: str, *,
                            expect_workers: Optional[int] = None):
    """Load and VERIFY a cluster checkpoint -> (payloads, manifest).

    Raises :class:`ClusterManifestError` on a missing manifest (partial
    write), schema mismatch, missing worker file, worker-count mismatch,
    or a per-worker live-multiset digest that disagrees with the
    manifest (corrupt or swapped shard file).
    """
    from ..cluster import protocol as _proto
    mpath = os.path.join(directory, CLUSTER_MANIFEST)
    if not os.path.exists(mpath):
        raise ClusterManifestError(
            f"no {CLUSTER_MANIFEST} in {directory!r} — partial or "
            "foreign checkpoint")
    with open(mpath) as f:
        manifest = json.load(f)
    if manifest.get("schema_version") != _proto.SCHEMA_VERSION:
        raise ClusterManifestError(
            f"checkpoint schema {manifest.get('schema_version')!r} != "
            f"this build's {_proto.SCHEMA_VERSION}")
    if (expect_workers is not None
            and manifest.get("n_workers") != expect_workers):
        raise ClusterManifestError(
            f"checkpoint has {manifest.get('n_workers')} workers, "
            f"cluster has {expect_workers}")
    payloads = []
    for w, name in enumerate(manifest["paths"]):
        path = os.path.join(directory, name)
        if not os.path.exists(path):
            raise ClusterManifestError(
                f"worker file {name!r} missing from {directory!r} — "
                "partial checkpoint")
        with np.load(path) as data:
            payload = {k: data[k] for k in data.files}
        import types as _types
        digest = _proto.live_multiset_digest(
            _types.SimpleNamespace(**payload))
        if digest != manifest["digests"][w]:
            raise ClusterManifestError(
                f"worker {w} digest mismatch: file {digest} != "
                f"manifest {manifest['digests'][w]} (corrupt or "
                "swapped shard file)")
        payloads.append(payload)
    return payloads, manifest


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m and not name.endswith(".tmp"):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, extra: Optional[dict] = None):
        """Async (default) atomic save; blocks only if a save is already
        in flight (bounded staleness of one)."""
        self.wait()
        # device_get on the caller thread (cheap on CPU; on TPU this is
        # the D2H copy) so the background thread only does file IO.
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_pytree(host_tree, self._path(step), extra)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore_latest(self, template, *, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None, {}
        tree, extra = restore_pytree(template, self._path(step),
                                     shardings=shardings)
        return step, tree, extra

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            for suffix in ("", ".meta"):
                try:
                    os.remove(self._path(s) + suffix)
                except OSError:
                    pass
