"""Multi-host coordinator plane: coordinator/worker split over a
serializable command protocol with pluggable transports.

See ``cluster/coordinator.py`` for the control plane,
``cluster/worker.py`` for the data plane, ``cluster/protocol.py`` for
the wire format, and ``cluster/backend.py`` for the transports.
"""
from .backend import (ClusterBackend, LocalBackend, MultiProcessBackend,
                      WorkerError, WorkerLost)
from .coordinator import (ClusterCoordinator, ClusterSnapshot,
                          plan_insert_split)
from .protocol import (SCHEMA_VERSION, ProtocolError, combine_digests,
                       decode_message, encode_message,
                       live_multiset_digest)

# NOTE: cluster.worker is deliberately NOT imported here — the package
# import would otherwise pre-load it in the `python -m
# repro.cluster.worker` subprocess and trip runpy's double-import
# warning.  Import WorkerRuntime from repro.cluster.worker directly.

__all__ = [
    "SCHEMA_VERSION", "ClusterBackend", "ClusterCoordinator",
    "ClusterSnapshot", "LocalBackend", "MultiProcessBackend",
    "ProtocolError", "WorkerError", "WorkerLost",
    "combine_digests", "decode_message", "encode_message",
    "live_multiset_digest", "plan_insert_split",
]
