"""Pluggable cluster transports behind one ``ClusterBackend`` surface.

The coordinator never branches on deployment: it sends protocol
commands through a backend and the backend decides where the worker
lives.

* :class:`LocalBackend` — workers are in-process ``WorkerRuntime``
  objects, but every message STILL round-trips through the wire codec
  (encode → decode on both legs), so "it works locally" proves the
  payloads are serializable — and, because the codec is lossless raw
  bytes, the local cluster is bit-identical to an in-process
  ``ShardedUBISDriver``.  The default backend and the equivalence
  oracle.
* :class:`MultiProcessBackend` — each worker is a
  ``python -m repro.cluster.worker`` subprocess on its own device set
  (``XLA_FLAGS=--xla_force_host_platform_device_count`` for simulated
  hosts), frames over stdin/stdout pipes, a reader thread per worker
  feeding a reply queue so receives can time out.

Failure surface: a dead/unreachable worker raises :class:`WorkerLost`
(the coordinator's restart-from-snapshot path catches it); a handler
exception on a live worker raises :class:`WorkerError` (the command
failed, the worker is fine).

Both backends time every RPC into a per-worker
``distributed.straggler.StragglerMonitor``; a call that trips the EWMA
watermark fires the coordinator-installed ``on_slow`` hook (the
``worker_slow`` trace event).
"""
from __future__ import annotations

import os
import queue
import subprocess
import sys
import threading
import time
from typing import Callable, Optional

from ..distributed.straggler import StragglerMonitor
from . import protocol


class WorkerLost(RuntimeError):
    """The worker process/runtime is gone (crash, kill, EOF, timeout)."""

    def __init__(self, worker: int, reason: str):
        super().__init__(f"worker {worker} lost: {reason}")
        self.worker = int(worker)
        self.reason = reason


class WorkerError(RuntimeError):
    """A command failed on a live worker (its error reply, re-raised)."""

    def __init__(self, worker: int, command: str, error: str):
        super().__init__(f"worker {worker} {command!r} failed: {error}")
        self.worker = int(worker)
        self.command = command


class ClusterBackend:
    """Transport contract: seq-tagged send/recv plus lifecycle."""

    def __init__(self, n_workers: int):
        self.n_workers = int(n_workers)
        self._seq = 0
        self.monitors = [StragglerMonitor() for _ in range(n_workers)]
        #: installed by the coordinator: (worker, command, seconds,
        #: watermark) -> None, fired when an RPC trips the monitor
        self.on_slow: Optional[Callable] = None

    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    # lifecycle ---------------------------------------------------------

    def start(self) -> None:
        raise NotImplementedError

    def stop(self) -> None:
        raise NotImplementedError

    def restart_worker(self, worker: int) -> None:
        """Bring up a FRESH worker in slot ``worker`` (blank state —
        the coordinator re-inits and replays)."""
        raise NotImplementedError

    def kill_worker(self, worker: int) -> None:
        """Test hook: make the worker unreachable mid-stream."""
        raise NotImplementedError

    # messaging ---------------------------------------------------------

    def send(self, worker: int, kind: str, payload=None) -> int:
        raise NotImplementedError

    def recv(self, worker: int, seq: int,
             timeout: Optional[float] = None) -> dict:
        raise NotImplementedError

    def call(self, worker: int, kind: str, payload=None,
             timeout: Optional[float] = None) -> dict:
        """send + recv, timed into the worker's straggler monitor."""
        t0 = time.perf_counter()
        seq = self.send(worker, kind, payload)
        out = self.recv(worker, seq, timeout=timeout)
        dt = time.perf_counter() - t0
        mon = self.monitors[worker]
        if mon.record(dt) and self.on_slow is not None:
            self.on_slow(worker, kind, dt, mon.watermark)
        return out


class LocalBackend(ClusterBackend):
    """In-process workers behind the full wire codec (see module doc)."""

    def __init__(self, n_workers: int):
        super().__init__(n_workers)
        self._runtimes: list = [None] * n_workers
        self._dead = [False] * n_workers
        self._replies: list[dict] = [dict() for _ in range(n_workers)]

    def start(self) -> None:
        from .worker import WorkerRuntime
        self._runtimes = [WorkerRuntime() for _ in range(self.n_workers)]
        self._dead = [False] * self.n_workers

    def stop(self) -> None:
        self._runtimes = [None] * self.n_workers

    def restart_worker(self, worker: int) -> None:
        from .worker import WorkerRuntime
        self._runtimes[worker] = WorkerRuntime()
        self._dead[worker] = False

    def kill_worker(self, worker: int) -> None:
        # drop the runtime entirely — its un-checkpointed state is gone,
        # exactly like a crashed process
        self._runtimes[worker] = None
        self._dead[worker] = True

    def send(self, worker: int, kind: str, payload=None) -> int:
        if self._dead[worker] or self._runtimes[worker] is None:
            raise WorkerLost(worker, "killed")
        seq = self._next_seq()
        # full wire round-trip both ways: unserializable payloads fail
        # HERE, not first in production on the multi-process backend
        msg = protocol.decode_message(
            protocol.encode_message(kind, payload, seq))
        try:
            out = self._runtimes[worker].handle(msg["kind"],
                                                msg["payload"])
            reply = protocol.encode_message("ok", out, seq)
        except Exception as e:  # noqa: BLE001 - mirrors the serve loop
            reply = protocol.encode_message(
                "error", {"command": kind, "error": repr(e)}, seq)
        self._replies[worker][seq] = protocol.decode_message(reply)
        return seq

    def recv(self, worker: int, seq: int,
             timeout: Optional[float] = None) -> dict:
        msg = self._replies[worker].pop(seq)
        if msg["kind"] == "error":
            raise WorkerError(worker, msg["payload"]["command"],
                              msg["payload"]["error"])
        return msg["payload"]


class MultiProcessBackend(ClusterBackend):
    """Worker subprocesses over stdin/stdout pipe frames.

    ``worker_devices`` simulates an N-device host per worker via
    ``--xla_force_host_platform_device_count`` (the repo's multi-device
    test idiom); default timeouts are generous because a worker's first
    commands compile device programs.
    """

    def __init__(self, n_workers: int, *, worker_devices: int = 1,
                 timeout: Optional[float] = 600.0,
                 python: str = sys.executable):
        super().__init__(n_workers)
        self.worker_devices = int(worker_devices)
        self.timeout = timeout
        self.python = python
        self._procs: list = [None] * n_workers
        self._queues: list = [None] * n_workers

    # lifecycle ---------------------------------------------------------

    def _env(self) -> dict:
        env = os.environ.copy()
        # the worker must import repro from this checkout
        src = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH")
            else "")
        env.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")
        if self.worker_devices > 1:
            flag = ("--xla_force_host_platform_device_count="
                    f"{self.worker_devices}")
            env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " "
                                + flag).strip()
        return env

    def _spawn(self, worker: int) -> None:
        proc = subprocess.Popen(
            [self.python, "-m", "repro.cluster.worker"],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE,
            env=self._env())
        q: queue.Queue = queue.Queue()

        def pump(stdout=proc.stdout, q=q):
            try:
                while True:
                    buf = protocol.read_frame(stdout)
                    if buf is None:
                        break
                    q.put(protocol.decode_message(buf))
            except Exception:   # noqa: BLE001 - EOF/teardown races
                pass
            q.put(None)         # EOF sentinel
        threading.Thread(target=pump, daemon=True).start()
        self._procs[worker] = proc
        self._queues[worker] = q

    def start(self) -> None:
        for w in range(self.n_workers):
            self._spawn(w)

    def stop(self) -> None:
        for w, proc in enumerate(self._procs):
            if proc is None:
                continue
            try:
                protocol.write_frame(
                    proc.stdin,
                    protocol.encode_message("shutdown", {},
                                            self._next_seq()))
            except Exception:  # noqa: BLE001 - already dead is fine
                pass
        for proc in self._procs:
            if proc is None:
                continue
            try:
                proc.wait(timeout=5)
            except Exception:  # noqa: BLE001
                proc.kill()
        self._procs = [None] * self.n_workers

    def restart_worker(self, worker: int) -> None:
        self.kill_worker(worker)
        self._spawn(worker)

    def kill_worker(self, worker: int) -> None:
        proc = self._procs[worker]
        if proc is not None:
            proc.kill()
            proc.wait()
        self._procs[worker] = None

    # messaging ---------------------------------------------------------

    def send(self, worker: int, kind: str, payload=None) -> int:
        proc = self._procs[worker]
        if proc is None or proc.poll() is not None:
            raise WorkerLost(worker, "process dead")
        seq = self._next_seq()
        try:
            protocol.write_frame(
                proc.stdin, protocol.encode_message(kind, payload, seq))
        except (BrokenPipeError, OSError) as e:
            raise WorkerLost(worker, f"pipe: {e}") from e
        return seq

    def recv(self, worker: int, seq: int,
             timeout: Optional[float] = None) -> dict:
        timeout = self.timeout if timeout is None else timeout
        try:
            msg = self._queues[worker].get(timeout=timeout)
        except queue.Empty:
            raise WorkerLost(worker, f"no reply in {timeout}s") from None
        if msg is None:
            raise WorkerLost(worker, "EOF")
        if msg["seq"] != seq:
            raise WorkerLost(worker,
                             f"out-of-order reply {msg['seq']} != {seq}")
        if msg["kind"] == "error":
            raise WorkerError(worker, msg["payload"]["command"],
                              msg["payload"]["error"])
        return msg["payload"]
