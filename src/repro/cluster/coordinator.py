"""The cluster coordinator: every planner, no device state.

``ClusterCoordinator`` is a full ``StreamingIndex`` whose data plane
lives in N workers (``cluster.worker``) behind a pluggable transport
(``cluster.backend``).  The coordinator owns every host-side decision —
the per-worker ``RebalancePlanner`` and ``TierPlanner``, the PQ retrain
cadence counter, insert routing, and the cross-worker spread balance —
and drives workers through the serializable command protocol
(``cluster.protocol``).

**The tick** is three legs per worker, preserving the in-process
``ShardedUBISDriver._tick_impl`` mutation order exactly:

  1. ``tick_begin``  — worker runs the sharded background program and
     ships pressure rows up; the coordinator's rebalance planner gates
     (``needs``) and, when tripped, pulls plan inputs and plans moves;
  2. ``tick_exec``   — migrate moves + cache drain + (cadence-granted)
     PQ retrain execute; the tier observation rows ship up and the
     coordinator's ``plan_tier_moves`` picks spill/promote lanes;
  3. ``tick_end``    — the lanes dispatch + reconcile under staleness
     signatures; commits, cache backlog, and live counts ship up.

With ``workers=1`` on the ``LocalBackend`` this is **bit-identical** to
``ShardedUBISDriver`` on the same seeded interleaving (the codec is
lossless and the planners see byte-identical observations in the same
order) — the equivalence oracle ``tests/test_cluster.py`` pins.

**Multi-worker layout**: each worker owns ``max_postings / N`` postings
over the FULL id space; inserts route by least-loaded water-filling
(:func:`plan_insert_split`), deletes broadcast, searches fan out and
merge by score.  When worker live counts drift past ``spread_ratio``,
the coordinator moves vectors donor→receiver through the ``extract`` /
``insert_rounds`` pair (one logical migration — the live multiset is
conserved, traced as a ``rebalance`` event with trigger
``worker-spread``).

**Failure plane**: every RPC feeds the backend's per-worker straggler
monitor (``worker_slow`` events); a :class:`~.backend.WorkerLost`
triggers restart → re-init → (checkpoint base ``load_state``) → journal
replay → ``worker_restarted`` event → one retry of the failed command.
The journal records every state-mutating command since the last
checkpoint; ``checkpoint()`` writes per-worker snapshots plus the
digest-carrying manifest (``checkpoint.manager``) and resets the
journals.  Caveats (documented, test-pinned): replay is command-level
deterministic, but search-heat (``note_probes``) is advisory and not
journaled, and delivery is at-least-once — a worker that dies *inside*
a command may replay it twice; the tests kill between commands.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Union

import numpy as np

from ..api.rebalance import RebalancePlanner
from ..api.types import SearchResult, TickReport, UpdateResult
from ..core.tier import TierPlanner, plan_tier_moves
from ..core.types import UBISConfig
from ..obs import Obs
from . import protocol
from .backend import (ClusterBackend, LocalBackend, MultiProcessBackend,
                      WorkerLost)

#: commands that mutate worker state — exactly these are journaled for
#: restart replay (reads and searches are not; see module docstring)
MUTATING = frozenset({
    "insert_rounds", "cache_put", "delete", "tick_begin", "tick_exec",
    "tick_end", "force_spill", "force_promote", "extract"})


def plan_insert_split(live, n: int) -> np.ndarray:
    """Water-filling insert routing: give each of ``n`` new vectors to
    the currently-least-loaded worker.  Deterministic (ties break by
    worker index) and closed-form — no per-vector loop."""
    live = np.asarray(live, np.int64).astype(np.float64)
    counts = np.zeros(len(live), np.int64)
    remaining = int(n)
    lv = live.copy()
    while remaining > 0:
        m = lv.min()
        cand = np.flatnonzero(lv == m)
        higher = lv[lv > m]
        gap = int(higher.min() - m) if higher.size else remaining
        take = min(remaining, max(gap, 1) * len(cand))
        q, r = divmod(take, len(cand))
        add = np.full(len(cand), q, np.int64)
        add[:r] += 1
        counts[cand] += add
        lv[cand] += add
        remaining -= take
    return counts


@dataclasses.dataclass
class ClusterSnapshot:
    """A multi-worker snapshot: one self-contained ``IndexState`` per
    worker plus the combined live-multiset digest."""

    states: list
    digests: list

    @property
    def digest(self) -> int:
        return protocol.combine_digests(self.digests)


class ClusterCoordinator:
    """Coordinator/worker cluster index (a ``StreamingIndex``)."""

    def __init__(self, cfg: UBISConfig, seed_vectors=None, *,
                 workers: int = 1,
                 backend: Union[str, ClusterBackend] = "local",
                 worker_devices: int = 1,
                 mesh_shape=None,
                 seed: int = 0, round_size: int = 1024,
                 bg_ops_per_round: int = 8, drain_per_tick: int = 256,
                 insert_retries: int = 2, gc_lag: int = 16,
                 reassign_after_split: bool = True,
                 pq_retrain_every: int = 32,
                 shard_cache_scan: bool = True,
                 rebalance: bool = True,
                 rebalance_watermark: float = 0.85,
                 rebalance_ratio: float = 1.2,
                 migrate_per_tick: int = 8,
                 route_alpha: float = 0.0,
                 tier_moves_per_tick: int = 32,
                 tier_rerank_host: bool = True,
                 spread_ratio: float = 1.3,
                 spread_per_tick: int = 256,
                 rpc_timeout: Optional[float] = None,
                 obs: Optional[Obs] = None):
        if seed_vectors is None:
            raise ValueError("seed_vectors required (k-means seeds)")
        W = int(workers)
        if W < 1:
            raise ValueError("workers must be >= 1")
        if cfg.max_postings % W:
            raise ValueError("max_postings must divide the worker count")
        self.cfg = cfg
        self.n_workers = W
        self.retries = int(insert_retries)
        self.pq_retrain_every = int(pq_retrain_every)
        self.spread_ratio = float(spread_ratio)
        self.spread_per_tick = int(spread_per_tick)
        self.rpc_timeout = rpc_timeout
        self._pq_ticks = 0
        self.obs = obs if obs is not None else Obs()
        self.stats = self.obs.driver_stats()

        # worker-local config: each worker owns max_postings/W postings
        # over the FULL id space; nprobe clamps to the local pool
        if W == 1:
            self._worker_cfg = cfg          # bit-identity: untouched
        else:
            mp = cfg.max_postings // W
            self._worker_cfg = dataclasses.replace(
                cfg, max_postings=mp, nprobe=min(cfg.nprobe, mp))
        self._worker_kwargs = dict(
            seed=seed, round_size=round_size,
            bg_ops_per_round=bg_ops_per_round,
            drain_per_tick=drain_per_tick,
            insert_retries=insert_retries, gc_lag=gc_lag,
            reassign_after_split=reassign_after_split,
            pq_retrain_every=pq_retrain_every,
            shard_cache_scan=shard_cache_scan, rebalance=rebalance,
            rebalance_watermark=rebalance_watermark,
            rebalance_ratio=rebalance_ratio,
            migrate_per_tick=migrate_per_tick, route_alpha=route_alpha,
            tier_moves_per_tick=tier_moves_per_tick,
            tier_rerank_host=tier_rerank_host, tier_async=False)
        self._mesh_shape = (list(mesh_shape) if mesh_shape is not None
                            else None)
        sv = np.asarray(seed_vectors, np.float32)
        self._seed_slices = [sv[w::W] for w in range(W)]

        if isinstance(backend, ClusterBackend):
            self.backend = backend
        elif backend == "local":
            self.backend = LocalBackend(W)
        elif backend == "multiprocess":
            self.backend = MultiProcessBackend(
                W, worker_devices=worker_devices)
        else:
            raise ValueError(f"unknown backend {backend!r} "
                             "(local | multiprocess)")
        self.backend.on_slow = self._on_slow
        self.backend.start()

        # recovery plane: per-worker journal of mutating commands since
        # the last checkpoint base (None base = deterministic re-init)
        self._journal: list[list] = [[] for _ in range(W)]
        self._base_states: list = [None] * W
        self._n_shards = [1] * W
        self._est_live = np.zeros(W, np.int64)
        self._cache_backlog = np.zeros(W, np.int64)
        self._tier_resident = np.zeros(W, np.int64)
        for w in range(W):
            self._init_worker(w)

        # one planner pair per worker — decisions live HERE, observations
        # ship up (params mirror ShardedUBISDriver's exactly, which is
        # half of the workers=1 bit-identity story)
        self._rebalance_on = [bool(rebalance) and s > 1
                              for s in self._n_shards]
        self._planners = [RebalancePlanner(
            s, self._worker_cfg.max_postings // s,
            watermark=rebalance_watermark, ratio_target=rebalance_ratio,
            max_moves=int(migrate_per_tick), min_gap=cfg.l_max)
            for s in self._n_shards]
        self._tier_planners = ([TierPlanner(
            cfg.tier_hot_max, cfg.tier_cold_heat, cfg.tier_promote_heat,
            max_moves=int(tier_moves_per_tick)) for _ in range(W)]
            if cfg.use_tier else None)

    # ------------------------------------------------------------------
    # transport + recovery
    # ------------------------------------------------------------------

    def _on_slow(self, worker: int, command: str, seconds: float,
                 watermark: float) -> None:
        self.obs.emit("worker_slow", worker=int(worker), command=command,
                      seconds=round(float(seconds), 6),
                      watermark=round(float(watermark), 6))

    def _init_worker(self, w: int) -> None:
        r = self.backend.call(w, "init", {
            "cfg": protocol.cfg_to_payload(self._worker_cfg),
            "seed_vectors": self._seed_slices[w],
            "mesh_shape": self._mesh_shape,
            "kwargs": self._worker_kwargs,
            "worker": w, "n_workers": self.n_workers,
        }, timeout=self.rpc_timeout)
        self._n_shards[w] = int(r["n_shards"])

    def _recover(self, w: int) -> None:
        """Restart a lost worker and replay it back to the present:
        fresh process → ``init`` → checkpoint base (if any) → every
        journaled mutating command, in order."""
        self.backend.restart_worker(w)
        self._init_worker(w)
        if self._base_states[w] is not None:
            self.backend.call(w, "load_state",
                              {"state": self._base_states[w]},
                              timeout=self.rpc_timeout)
        for kind, payload in self._journal[w]:
            self.backend.call(w, kind, payload, timeout=self.rpc_timeout)
        self.obs.emit("worker_restarted", worker=int(w),
                      replayed=len(self._journal[w]),
                      from_checkpoint=self._base_states[w] is not None)

    def _call(self, w: int, kind: str, payload=None) -> dict:
        try:
            out = self.backend.call(w, kind, payload,
                                    timeout=self.rpc_timeout)
        except WorkerLost as e:
            self.obs.emit("worker_lost", worker=int(w), reason=e.reason,
                          command=kind)
            self._recover(w)
            out = self.backend.call(w, kind, payload,
                                    timeout=self.rpc_timeout)
        if kind in MUTATING:
            self._journal[w].append((kind, payload))
        return out

    def heartbeat(self, timeout: Optional[float] = 30.0) -> None:
        """Ping every worker; a missed heartbeat trips the same lost →
        restart → replay path as a failed command."""
        for w in range(self.n_workers):
            try:
                self.backend.call(w, "ping", {}, timeout=timeout)
            except WorkerLost as e:
                self.obs.emit("worker_lost", worker=int(w),
                              reason=e.reason, command="ping")
                self._recover(w)

    # ------------------------------------------------------------------
    # foreground
    # ------------------------------------------------------------------

    def _route(self, vecs: np.ndarray, ids: np.ndarray):
        """Split an insert batch across workers (least-loaded first)."""
        if self.n_workers == 1:
            return [(vecs, ids)]
        counts = plan_insert_split(self._est_live, len(ids))
        parts, off = [], 0
        for w in range(self.n_workers):
            c = int(counts[w])
            parts.append((vecs[off:off + c], ids[off:off + c]))
            off += c
        return parts

    def insert(self, vecs, ids, *, tick_between: bool = True
               ) -> UpdateResult:
        vecs = np.asarray(vecs, np.float32)
        ids = np.asarray(ids, np.int64).astype(np.int32)
        if len(vecs) != len(ids):
            raise ValueError(f"vecs/ids length mismatch: {len(vecs)} vs "
                             f"{len(ids)}")
        if ids.size and (ids.min() < 0 or ids.max() >= self.cfg.max_ids):
            raise ValueError("ids out of range for cfg.max_ids")
        t0 = time.perf_counter()
        n_acc = n_cache = n_rej = 0
        for w, (pv, pi) in enumerate(self._route(vecs, ids)):
            if not len(pi):
                continue
            # mirrors ShardedUBISDriver.insert: retry with a tick
            # between attempts, survivors park in the worker's cache
            pending, rej_t = (pv, pi), None
            for _attempt in range(self.retries + 1):
                r = self._call(w, "insert_rounds",
                               {"vecs": pending[0], "ids": pending[1]})
                n_acc += int(r["accepted"])
                self._est_live[w] += int(r["accepted"])
                if r["rej_ids"] is None:
                    pending = None
                    break
                pending = (np.asarray(r["rej_vecs"], np.float32),
                           np.asarray(r["rej_ids"], np.int32))
                rej_t = np.asarray(r["rej_targets"], np.int32)
                if tick_between:
                    self.tick()
            if pending is not None:
                rc = self._call(w, "cache_put",
                                {"vecs": pending[0], "ids": pending[1],
                                 "targets": rej_t})
                got = int(rc["cached"])
                n_cache += got
                self._est_live[w] += got
                n_rej += len(pending[1]) - got
                self.stats["host_cached"] += got
        dt = time.perf_counter() - t0
        self.stats["insert_time"] += dt
        self.stats["inserted"] += n_acc + n_cache
        self.stats["rejected"] += n_rej
        self.obs.emit("insert", accepted=n_acc, cached=n_cache,
                      rejected=n_rej, seconds=round(dt, 6))
        return UpdateResult(accepted=n_acc, cached=n_cache,
                            rejected=n_rej, seconds=dt)

    def delete(self, ids) -> UpdateResult:
        ids = np.asarray(ids, np.int64).astype(np.int32)
        t0 = time.perf_counter()
        total = 0
        for w in range(self.n_workers):
            r = self._call(w, "delete", {"ids": ids})
            total += int(r["deleted"])
            self._est_live[w] -= int(r["deleted"])
        dt = time.perf_counter() - t0
        self.stats["delete_time"] += dt
        self.stats["deleted"] += total
        self.obs.emit("delete", deleted=total, blocked=0,
                      seconds=round(dt, 6))
        return UpdateResult(deleted=total, seconds=dt)

    def _merge(self, ids_list, scores_list, k: int):
        all_i = np.concatenate(ids_list, axis=1)
        all_s = np.concatenate(scores_list, axis=1).astype(np.float32)
        keyed = np.where(all_i < 0, np.float32(np.inf), all_s)
        order = np.argsort(keyed, axis=1, kind="stable")[:, :k]
        return (np.take_along_axis(all_i, order, axis=1),
                np.take_along_axis(all_s, order, axis=1))

    def search(self, queries, k: int,
               nprobe: Optional[int] = None) -> SearchResult:
        q = np.asarray(queries, np.float32)
        t0 = time.perf_counter()
        ids_l, scores_l = [], []
        for w in range(self.n_workers):
            r = self._call(w, "search",
                           {"queries": q, "k": int(k), "nprobe": nprobe})
            ids_l.append(np.asarray(r["ids"]))
            scores_l.append(np.asarray(r["scores"]))
        if self.n_workers == 1:
            found, scores = ids_l[0], scores_l[0]
        else:
            found, scores = self._merge(ids_l, scores_l, k)
        dt = time.perf_counter() - t0
        self.stats["search_time"] += dt
        self.stats["queries"] += q.shape[0]
        self.stats["search_results"] += int((found >= 0).sum())
        if self.cfg.use_pq:
            self.stats["search_adc_batches"] += 1
        else:
            self.stats["search_exact_batches"] += 1
        return SearchResult(ids=found, scores=scores, seconds=dt)

    def exact(self, queries, k: int) -> SearchResult:
        q = np.asarray(queries, np.float32)
        ids_l, scores_l = [], []
        for w in range(self.n_workers):
            r = self._call(w, "exact", {"queries": q, "k": int(k)})
            ids_l.append(np.asarray(r["ids"]))
            scores_l.append(np.asarray(r["scores"]))
        if self.n_workers == 1:
            return SearchResult(ids=ids_l[0], scores=scores_l[0])
        found, scores = self._merge(ids_l, scores_l, k)
        return SearchResult(ids=found, scores=scores)

    # ------------------------------------------------------------------
    # background
    # ------------------------------------------------------------------

    def _absorb_commits(self, commits: list) -> None:
        """Re-emit worker tier commits on the coordinator's trace plane
        and fold them into the stats counters (the audit invariant:
        tier_commit events account 1:1 for the stats deltas)."""
        for c in commits:
            self.obs.emit("tier_commit", **c)
            self.stats["tier_spilled"] += len(c.get("spilled", ()))
            self.stats["tier_promoted"] += len(c.get("promoted", ()))

    def tick(self) -> TickReport:
        t0 = time.perf_counter()
        executed = reclaimed = migrated = drained = retrained = 0
        spilled = promoted = 0
        retrain = False
        if self.cfg.use_pq and self.pq_retrain_every > 0:
            # the coordinator owns the cadence counter the in-process
            # driver keeps in _pq_retrain — the retrain slot is an
            # explicit grant in the tick plan
            self._pq_ticks += 1
            retrain = self._pq_ticks % self.pq_retrain_every == 0
        for w in range(self.n_workers):
            r1 = self._call(w, "tick_begin", {})
            executed += int(r1["executed"])
            reclaimed += int(r1["gc"])
            press = np.asarray(r1["pressure"])
            planner = self._planners[w]
            src = dst = np.empty(0, np.int32)
            if self._rebalance_on[w] and planner.needs(press):
                pi = self._call(w, "plan_inputs", {})
                src, dst = planner.plan(press,
                                        np.asarray(pi["lengths"]),
                                        np.asarray(pi["movable"]))
            if len(src) or retrain:
                self.obs.emit("plan_sent", worker=w,
                              migrate=int(len(src)), retrain=retrain)
            r2 = self._call(w, "tick_exec",
                            {"src": src, "dst": dst, "retrain": retrain})
            mig = np.asarray(r2["migrated"], bool)
            n_mig = int(mig.sum())
            if len(src):
                self.stats["migrated"] += n_mig
                self.obs.emit(
                    "rebalance",
                    trigger=(planner.last_moves[0]["trigger"]
                             if planner.last_moves else "none"),
                    moves=[{**mv, "committed": bool(mig[j])}
                           for j, mv in enumerate(planner.last_moves)],
                    migrated=n_mig)
            migrated += n_mig
            drained += int(r2["drained"])
            retrained += int(r2["retrained"])
            if r2["retrained"]:
                self.stats["pq_retrains"] += 1
                self.obs.emit("pq_retrain", reason="cadence", worker=w)
            self._absorb_commits(r2["commits"])
            promos = spills = np.empty(0, np.int64)
            if self._tier_planners is not None and r2["tier_rows"]:
                tp = self._tier_planners[w]
                promos, spills = plan_tier_moves(tp, r2["tier_rows"],
                                                 self._worker_cfg)
                if len(promos) or len(spills):
                    reasons = tp.last_promote_reasons
                    self.obs.emit(
                        "tier_plan", worker=w,
                        promotes=[{"pid": int(p),
                                   "reason": reasons.get(int(p),
                                                         "search-heat")}
                                  for p in promos],
                        spills=[{"pid": int(p),
                                 "reason": "watermark-cold"}
                                for p in spills])
            r3 = self._call(w, "tick_end",
                            {"promotes": promos, "spills": spills})
            spilled += int(r3["spilled"])
            promoted += int(r3["promoted"])
            self._absorb_commits(r3["commits"])
            self._cache_backlog[w] = int(r3["cache_backlog"])
            self._tier_resident[w] = int(r3["tier_resident"])
            self._est_live[w] = int(r3["live"])
        if self._tier_planners is not None:
            self.stats["tier_resident"] = int(self._tier_resident.sum())
        if self.n_workers > 1 and self.spread_ratio > 0:
            migrated += self._spread_balance()
        dt = time.perf_counter() - t0
        self.stats["bg_time"] += dt
        self.stats["bg_ops"] += executed
        self.stats["bg_gc"] += reclaimed
        self.stats["drained"] += drained
        self.obs.emit("tick", executed=executed, drained=drained,
                      migrated=migrated, gc=reclaimed, pq=retrained,
                      spilled=spilled, promoted=promoted,
                      seconds=round(dt, 6))
        return TickReport(executed=executed, drained=drained,
                          migrated=migrated, gc=reclaimed,
                          pq_retrained=retrained, spilled=spilled,
                          promoted=promoted, seconds=dt)

    def _spread_balance(self) -> int:
        """Cross-worker occupancy balance: when worker live counts drift
        past ``spread_ratio``, move vectors from the heaviest worker to
        the lightest via ``extract`` → ``insert_rounds``.  The pair is
        one logical migration; anything the receiver cannot absorb
        parks in its cache, and a cache overflow falls back to the
        donor — the live multiset is conserved at every step."""
        live = self._est_live
        d, r = int(np.argmax(live)), int(np.argmin(live))
        hi, lo = int(live[d]), int(live[r])
        if hi - lo <= self.cfg.l_max or hi <= max(lo, 1) * self.spread_ratio:
            return 0
        n = min(self.spread_per_tick, (hi - lo) // 2)
        if n <= 0:
            return 0
        ex = self._call(d, "extract", {"n": int(n)})
        ids = np.asarray(ex["ids"], np.int32)
        if not len(ids):
            return 0
        vecs = np.asarray(ex["vecs"], np.float32)
        self._est_live[d] -= len(ids)
        rr = self._call(r, "insert_rounds", {"vecs": vecs, "ids": ids})
        installed = int(rr["accepted"])
        self._est_live[r] += installed
        if rr["rej_ids"] is not None:
            rv = np.asarray(rr["rej_vecs"], np.float32)
            ri = np.asarray(rr["rej_ids"], np.int32)
            rc = self._call(r, "cache_put",
                            {"vecs": rv, "ids": ri,
                             "targets": np.asarray(rr["rej_targets"],
                                                   np.int32)})
            got = int(rc["cached"])
            installed += got
            self._est_live[r] += got
            if got < len(ri):
                # receiver full: return the remainder home (donor just
                # freed capacity by deleting these very vectors)
                rv, ri = rv[got:], ri[got:]
                rd = self._call(d, "insert_rounds",
                                {"vecs": rv, "ids": ri})
                back = int(rd["accepted"])
                self._est_live[d] += back
                if rd["rej_ids"] is not None:
                    rc2 = self._call(
                        d, "cache_put",
                        {"vecs": np.asarray(rd["rej_vecs"], np.float32),
                         "ids": np.asarray(rd["rej_ids"], np.int32),
                         "targets": np.asarray(rd["rej_targets"],
                                               np.int32)})
                    got2 = int(rc2["cached"])
                    back += got2
                    self._est_live[d] += got2
                    if got2 < len(np.asarray(rd["rej_ids"])):
                        raise RuntimeError(
                            "spread balance dropped vectors: donor and "
                            "receiver both refused re-installation")
        if installed:
            self.stats["migrated"] += installed
            self.obs.emit(
                "rebalance", trigger="worker-spread",
                moves=[{"src_worker": d, "dst_worker": r,
                        "n": installed, "trigger": "worker-spread",
                        "committed": True}],
                migrated=installed)
        return installed

    def flush(self, max_ticks: int = 200) -> int:
        for i in range(max_ticks):
            r = self.tick()
            if (r.executed == 0 and r.migrated == 0
                    and int(self._cache_backlog.sum()) == 0
                    and r.spilled == 0 and r.promoted == 0):
                return i + 1
        return max_ticks

    # ------------------------------------------------------------------
    # tier hooks (contract-harness surface)
    # ------------------------------------------------------------------

    def force_spill(self, n: int) -> int:
        moved = 0
        for w in range(self.n_workers):
            r = self._call(w, "force_spill", {"n": int(n)})
            moved += int(r["moved"])
            self._tier_resident[w] = int(r["tier_resident"])
            self._absorb_commits(r["commits"])
        self.stats["tier_resident"] = int(self._tier_resident.sum())
        return moved

    def force_promote(self, n=None) -> int:
        moved = 0
        for w in range(self.n_workers):
            r = self._call(w, "force_promote",
                           {"n": None if n is None else int(n)})
            moved += int(r["moved"])
            self._tier_resident[w] = int(r["tier_resident"])
            self._absorb_commits(r["commits"])
        self.stats["tier_resident"] = int(self._tier_resident.sum())
        return moved

    # ------------------------------------------------------------------
    # state / StreamingIndex surface
    # ------------------------------------------------------------------

    @property
    def state(self):
        """The single worker's gathered state (``workers=1`` only — the
        contract harness's id-map fallback reads ``.state.id_loc``)."""
        if self.n_workers != 1:
            raise NotImplementedError(
                "per-worker states are not one pytree; use snapshot()")
        r = self._call(0, "snapshot", {})
        return protocol.payload_to_state(r["state"])

    def snapshot(self):
        """``workers=1``: the worker's self-contained ``IndexState``
        (drop-in for the single-host drivers).  Multi-worker: a
        :class:`ClusterSnapshot` of per-worker states + digests."""
        snaps, digests = [], []
        for w in range(self.n_workers):
            r = self._call(w, "snapshot", {})
            snaps.append(protocol.payload_to_state(r["state"]))
            digests.append(int(r["digest"]))
        if self.n_workers == 1:
            return snaps[0]
        return ClusterSnapshot(states=snaps, digests=digests)

    def load_snapshot(self, snap) -> "ClusterCoordinator":
        """Adopt a ``snapshot()`` result.  Resets the recovery journal:
        the loaded states become the new replay bases."""
        states = (snap.states if isinstance(snap, ClusterSnapshot)
                  else [snap])
        if len(states) != self.n_workers:
            raise ValueError(f"snapshot has {len(states)} worker states, "
                             f"cluster has {self.n_workers}")
        for w, st in enumerate(states):
            payload = protocol.state_to_payload(st)
            r = self._call(w, "load_state", {"state": payload})
            self._base_states[w] = payload
            self._journal[w] = []
            self._est_live[w] = int(r["live"])
        return self

    def checkpoint(self, directory: str) -> dict:
        """Write per-worker snapshots + the digest manifest, and reset
        the journals (the checkpoint becomes the new replay base)."""
        from ..checkpoint.manager import save_cluster_checkpoint
        payloads, digests = [], []
        for w in range(self.n_workers):
            r = self._call(w, "snapshot", {})
            payloads.append(r["state"])
            digests.append(int(r["digest"]))
        manifest = save_cluster_checkpoint(directory, payloads, digests)
        for w in range(self.n_workers):
            self._base_states[w] = payloads[w]
            self._journal[w] = []
        self.obs.emit("checkpoint", directory=str(directory),
                      workers=self.n_workers,
                      digest=int(manifest["combined_digest"]))
        return manifest

    def restore(self, directory: str) -> "ClusterCoordinator":
        """Load a ``checkpoint()`` directory into the running cluster
        (digest-verified; partial/mismatched checkpoints fail loudly)."""
        from ..checkpoint.manager import load_cluster_checkpoint
        payloads, manifest = load_cluster_checkpoint(
            directory, expect_workers=self.n_workers)
        for w, payload in enumerate(payloads):
            r = self._call(w, "load_state", {"state": payload})
            self._base_states[w] = payload
            self._journal[w] = []
            self._est_live[w] = int(r["live"])
        return self

    def live_count(self) -> int:
        total = 0
        for w in range(self.n_workers):
            total += int(self._call(w, "live_count", {})["live"])
        return total

    def worker_live(self) -> np.ndarray:
        """Live vectors per worker (the cross-host occupancy rows)."""
        return np.array([int(self._call(w, "live_count", {})["live"])
                         for w in range(self.n_workers)], np.int64)

    def shard_occupancy(self) -> np.ndarray:
        """Per-shard live vectors, all workers concatenated."""
        return np.concatenate([
            np.asarray(self._call(w, "occupancy", {})["occ"])
            for w in range(self.n_workers)])

    def posting_lengths(self) -> np.ndarray:
        return np.concatenate([
            np.asarray(self._call(w, "posting_lengths", {})["lengths"])
            for w in range(self.n_workers)])

    def memory_bytes(self) -> int:
        return sum(int(self._call(w, "memory", {})["bytes"])
                   for w in range(self.n_workers))

    def memory_tiers(self) -> dict:
        out: dict = {}
        for w in range(self.n_workers):
            for key, v in self._call(w, "memory", {})["tiers"].items():
                out[key] = out.get(key, 0) + int(v)
        return out

    def throughput(self) -> dict:
        from ..core.metrics import throughput_from_stats
        return throughput_from_stats(self.stats)

    def close(self) -> None:
        self.backend.stop()
