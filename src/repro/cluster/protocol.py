"""The coordinator/worker wire protocol: schema-versioned messages of
plain-numpy payloads.

Every message that crosses the process boundary is a dict

    {"v": SCHEMA_VERSION, "kind": <command>, "seq": <int>, "payload": {...}}

whose payload is built from JSON-native values plus numpy arrays.  The
codec separates the two: arrays are lifted out of the tree into a side
table and shipped as raw little-endian bytes (``tobytes`` — lossless,
which is what makes the LocalBackend's codec round-trip *bit-identical*
to the in-process driver), while the remaining tree plus the array
dtypes/shapes travel as a JSON header.  A frame on a byte stream is

    [u32 frame length][u32 header length][header JSON][array bytes...]

so a worker subprocess speaks the protocol over plain pipes with no
serialization dependencies.

Message catalog (worker commands; see ``cluster/worker.py``):

  control   — ``init``, ``ping``, ``sleep``, ``shutdown``
  foreground— ``insert_rounds``, ``cache_put``, ``delete``, ``search``,
              ``exact``
  tick legs — ``tick_begin`` (background program; observation up),
              ``tick_exec`` (migrate moves + drain + retrain slot down;
              tier observation up), ``tick_end`` (tier lanes down;
              commits + report up)
  tier      — ``force_spill``, ``force_promote``
  state     — ``snapshot``, ``load_state``, ``live_count``,
              ``posting_lengths``, ``memory``, ``occupancy``,
              ``extract`` (cross-worker balance donor), ``stats``

Schema versioning: ``decode_message`` refuses any frame whose ``v``
differs from :data:`SCHEMA_VERSION` — a coordinator can never silently
drive a worker speaking a different protocol revision, and snapshots
carry the same version in their manifest (``checkpoint/manager.py``).
"""
from __future__ import annotations

import dataclasses
import json
import struct
import zlib
from typing import Optional

import numpy as np

SCHEMA_VERSION = 1

_ND = "__nd__"


class ProtocolError(RuntimeError):
    """Malformed frame or schema-version mismatch."""


def _pack_tree(x, arrays: list):
    if isinstance(x, np.ndarray):
        a = np.ascontiguousarray(x)
        arrays.append(a)
        return {_ND: len(arrays) - 1, "dtype": a.dtype.name,
                "shape": list(a.shape)}
    if isinstance(x, np.generic):
        return x.item()
    if isinstance(x, dict):
        if _ND in x:
            raise ProtocolError("payload dicts may not use the "
                                f"reserved key {_ND!r}")
        return {str(k): _pack_tree(v, arrays) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_pack_tree(v, arrays) for v in x]
    if x is None or isinstance(x, (bool, int, float, str)):
        return x
    raise ProtocolError(f"unserializable payload value: {type(x)}")


def _unpack_tree(x, arrays: list):
    if isinstance(x, dict):
        if _ND in x:
            return arrays[x[_ND]]
        return {k: _unpack_tree(v, arrays) for k, v in x.items()}
    if isinstance(x, list):
        return [_unpack_tree(v, arrays) for v in x]
    return x


def encode_message(kind: str, payload: Optional[dict], seq: int,
                   v: int = SCHEMA_VERSION) -> bytes:
    """One serialized message (header JSON + raw array bytes)."""
    arrays: list = []
    tree = _pack_tree(payload or {}, arrays)
    header = json.dumps({
        "v": int(v), "kind": str(kind), "seq": int(seq),
        "payload": tree,
        "nbytes": [a.nbytes for a in arrays],
    }).encode()
    return b"".join([struct.pack("<I", len(header)), header]
                    + [a.tobytes() for a in arrays])


def decode_message(buf: bytes) -> dict:
    """Inverse of :func:`encode_message`; validates the schema version."""
    if len(buf) < 4:
        raise ProtocolError("truncated frame")
    (hlen,) = struct.unpack_from("<I", buf, 0)
    try:
        head = json.loads(buf[4:4 + hlen].decode())
    except Exception as e:  # noqa: BLE001 - re-raise as protocol error
        raise ProtocolError(f"bad frame header: {e}") from e
    if head.get("v") != SCHEMA_VERSION:
        raise ProtocolError(
            f"schema version mismatch: got {head.get('v')!r}, "
            f"this build speaks {SCHEMA_VERSION}")
    # rebuild the array table from the concatenated raw bytes
    arrays = []
    off = 4 + hlen
    meta = _array_meta(head["payload"])
    for i, nb in enumerate(head["nbytes"]):
        dtype, shape = meta[i]
        arrays.append(np.frombuffer(buf[off:off + nb],
                                    dtype=np.dtype(dtype)).reshape(shape)
                      .copy())
        off += nb
    return {"v": head["v"], "kind": head["kind"], "seq": head["seq"],
            "payload": _unpack_tree(head["payload"], arrays)}


def _array_meta(tree, out=None):
    out = {} if out is None else out
    if isinstance(tree, dict):
        if _ND in tree:
            out[tree[_ND]] = (tree["dtype"], tree["shape"])
        else:
            for v in tree.values():
                _array_meta(v, out)
    elif isinstance(tree, list):
        for v in tree:
            _array_meta(v, out)
    return out


# ---------------------------------------------------------------- framing


def write_frame(fh, buf: bytes) -> None:
    fh.write(struct.pack("<Q", len(buf)))
    fh.write(buf)
    fh.flush()


def read_frame(fh) -> Optional[bytes]:
    """Read one length-prefixed frame; None on clean EOF."""
    head = fh.read(8)
    if not head:
        return None
    if len(head) < 8:
        raise ProtocolError("truncated frame length")
    (n,) = struct.unpack("<Q", head)
    buf = b""
    while len(buf) < n:
        chunk = fh.read(n - len(buf))
        if not chunk:
            raise ProtocolError("EOF mid-frame")
        buf += chunk
    return buf


# ------------------------------------------------------- state transport


def state_to_payload(state) -> dict:
    """An ``IndexState`` as a flat field->numpy dict (protocol-safe)."""
    return {f.name: np.asarray(getattr(state, f.name))
            for f in dataclasses.fields(state)}


def payload_to_state(payload: dict):
    """Rebuild an ``IndexState`` from :func:`state_to_payload` output."""
    import jax.numpy as jnp

    from ..core.types import IndexState
    names = {f.name for f in dataclasses.fields(IndexState)}
    if set(payload) != names:
        raise ProtocolError(
            f"state payload fields mismatch: missing "
            f"{sorted(names - set(payload))}, "
            f"unexpected {sorted(set(payload) - names)}")
    return IndexState(**{k: jnp.asarray(v) for k, v in payload.items()})


def cfg_to_payload(cfg) -> dict:
    """A ``UBISConfig`` as a JSON-safe dict (dtype by name)."""
    d = dataclasses.asdict(cfg)
    d["dtype"] = np.dtype(d["dtype"]).name
    return d


def payload_to_cfg(payload: dict):
    from ..core.types import UBISConfig
    d = dict(payload)
    d["dtype"] = np.dtype(d["dtype"])
    return UBISConfig(**d)


# ------------------------------------------------------ multiset digest


def live_multiset_digest(state) -> int:
    """Order-independent digest of the live id->vector multiset
    (postings + cache), combinable across workers by uint64 addition.

    This is the checkpoint manifest's integrity field: a restore that
    loads a mismatched / partially-written shard set produces a digest
    that disagrees with the manifest and fails LOUDLY
    (``checkpoint.manager.load_cluster_checkpoint``).
    """
    from ..core import version_manager as vm
    status = np.asarray(vm.unpack_status(np.asarray(state.rec_meta)))
    vis = np.asarray(state.allocated) & (status != 3)
    ids = np.asarray(state.ids)
    sv = np.asarray(state.slot_valid)
    vecs = np.asarray(state.vectors)
    total = 0
    for p in np.flatnonzero(vis):
        for c in np.flatnonzero(sv[p]):
            row = struct.pack("<q", int(ids[p, c])) + vecs[p, c].tobytes()
            total = (total + zlib.crc32(row)) & 0xFFFFFFFFFFFFFFFF
    cv = np.asarray(state.cache_valid)
    cids = np.asarray(state.cache_ids)
    cvecs = np.asarray(state.cache_vecs)
    for s in np.flatnonzero(cv):
        row = struct.pack("<q", int(cids[s])) + cvecs[s].tobytes()
        total = (total + zlib.crc32(row)) & 0xFFFFFFFFFFFFFFFF
    return total


def combine_digests(digests) -> int:
    total = 0
    for d in digests:
        total = (total + int(d)) & 0xFFFFFFFFFFFFFFFF
    return total
