"""The cluster worker: device programs behind the command protocol.

A worker owns ONE ``ShardedUBISDriver`` (its local mesh = the "host's"
device set) and exposes the driver's plan/execute halves as protocol
commands — it makes **no planning decisions**.  The coordinator owns
every planner (rebalance, tier, PQ cadence, insert routing) and drives
the worker through the three tick legs:

  ``tick_begin``  — run the sharded background program; ship the
                    pressure rows up (plus executed/GC counts);
  ``tick_exec``   — execute the coordinator's migrate moves, drain the
                    cache, run the retrain slot if granted; ship the
                    tier observation rows up;
  ``tick_end``    — execute the coordinator's spill/promote lanes
                    (dispatch + reconcile under staleness signatures);
                    ship the commit log + occupancy report up.

The worker's driver is built with ``Obs(enabled=False)``: the stats
mapping stays live (the device programs need it) but tracing is a
no-op — *decisions* are traced on the coordinator's plane, and the
worker ships its tier ``commit_log`` up so commit outcomes land there
too.

Run as a subprocess via ``python -m repro.cluster.worker``: frames in
on stdin, frames out on stdout.  The real fd 1 is duplicated into a
private handle and then pointed at stderr, so any stray ``print`` (or
library chatter) inside handlers cannot corrupt the frame stream.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from . import protocol


class WorkerRuntime:
    """Command dispatch over one driver (backend-agnostic: the
    LocalBackend calls ``handle`` in-process, the subprocess ``main``
    loop calls it behind stdin/stdout frames)."""

    def __init__(self):
        self.drv = None
        self.worker = 0
        self._tier_rows: Optional[dict] = None

    # ------------------------------------------------------------- util

    def handle(self, kind: str, payload: dict) -> dict:
        fn = getattr(self, "_cmd_" + kind, None)
        if fn is None:
            raise protocol.ProtocolError(f"unknown command {kind!r}")
        if self.drv is None and kind not in ("init", "ping", "sleep",
                                             "shutdown"):
            raise protocol.ProtocolError(f"{kind!r} before init")
        return fn(payload)

    def _repin(self, state) -> None:
        """Adopt a tier-mutated state re-pinned to the driver's mesh."""
        import jax
        if state is not self.drv.state:
            self.drv.state = jax.device_put(state, self.drv._shardings)

    # ---------------------------------------------------------- control

    def _cmd_init(self, p: dict) -> dict:
        import jax

        from ..api.sharded_driver import ShardedUBISDriver, default_mesh
        from ..obs import Obs
        cfg = protocol.payload_to_cfg(p["cfg"])
        mesh_shape = p.get("mesh_shape")
        mesh = (jax.make_mesh(tuple(mesh_shape), ("data", "model"))
                if mesh_shape else default_mesh(cfg))
        kw = dict(p.get("kwargs") or {})
        self.worker = int(p.get("worker", 0))
        self.drv = ShardedUBISDriver(
            cfg, np.asarray(p["seed_vectors"], np.float32), mesh=mesh,
            obs=Obs(enabled=False), **kw)
        return {"n_shards": self.drv.n_shards,
                "devices": len(jax.devices())}

    def _cmd_ping(self, p: dict) -> dict:
        return {"ok": True, "worker": self.worker}

    def _cmd_sleep(self, p: dict) -> dict:
        # test hook: fake a straggling worker
        time.sleep(float(p["seconds"]))
        return {"ok": True}

    def _cmd_shutdown(self, p: dict) -> dict:
        return {"ok": True}

    # ------------------------------------------------------- foreground

    def _cmd_insert_rounds(self, p: dict) -> dict:
        n_acc, rej_v, rej_i, rej_t = self.drv._insert_rounds(
            np.asarray(p["vecs"], np.float32),
            np.asarray(p["ids"], np.int32))
        return {"accepted": int(n_acc),
                "rej_vecs": rej_v, "rej_ids": rej_i, "rej_targets": rej_t}

    def _cmd_cache_put(self, p: dict) -> dict:
        tg = p.get("targets")
        n = self.drv._cache_put(np.asarray(p["vecs"], np.float32),
                                np.asarray(p["ids"], np.int32),
                                targets=tg)
        return {"cached": int(n)}

    def _cmd_delete(self, p: dict) -> dict:
        r = self.drv.delete(np.asarray(p["ids"], np.int64))
        return {"deleted": int(r.deleted)}

    def _cmd_search(self, p: dict) -> dict:
        r = self.drv.search(np.asarray(p["queries"], np.float32),
                            int(p["k"]), p.get("nprobe"))
        return {"ids": np.asarray(r.ids), "scores": np.asarray(r.scores)}

    def _cmd_exact(self, p: dict) -> dict:
        r = self.drv.exact(np.asarray(p["queries"], np.float32),
                           int(p["k"]))
        return {"ids": np.asarray(r.ids), "scores": np.asarray(r.scores)}

    # -------------------------------------------------------- tick legs

    def _cmd_tick_begin(self, p: dict) -> dict:
        executed, reclaimed, press = self.drv.exec_background()
        return {"executed": int(executed), "gc": int(reclaimed),
                "pressure": np.asarray(press)}

    def _cmd_plan_inputs(self, p: dict) -> dict:
        lengths, movable = self.drv.rebalance_inputs()
        return {"lengths": lengths, "movable": movable}

    def _cmd_tick_exec(self, p: dict) -> dict:
        drv = self.drv
        src = np.asarray(p.get("src", []), np.int32)
        dst = np.asarray(p.get("dst", []), np.int32)
        mig = (drv.exec_migrate(src, dst) if len(src)
               else np.zeros(0, bool))
        drained = drv.exec_drain()
        retrained = drv.exec_pq_retrain() if p.get("retrain") else 0
        rows = None
        if drv.tier is not None:
            # decayed=True — the sharded background round ran in leg 1
            st, rows = drv.tier.observe(drv.state, decayed=True)
            self._repin(st)
            self._tier_rows = rows
        return {"migrated": np.asarray(mig, bool), "drained": int(drained),
                "retrained": int(retrained), "tier_rows": rows,
                "commits": (drv.tier.drain_commits()
                            if drv.tier is not None else [])}

    def _cmd_tick_end(self, p: dict) -> dict:
        drv = self.drv
        n_s = n_p = 0
        commits: list = []
        if drv.tier is not None:
            rows = self._tier_rows
            if rows is None:
                raise protocol.ProtocolError("tick_end before tick_exec")
            self._tier_rows = None
            st, plan = drv.tier.dispatch_planned(
                drv.state, rows,
                np.asarray(p.get("promotes", []), np.int64),
                np.asarray(p.get("spills", []), np.int64))
            self._repin(st)
            st, n_s, n_p = drv.tier.reconcile(drv.state, plan)
            self._repin(st)
            drv.stats["tier_spilled"] += n_s
            drv.stats["tier_promoted"] += n_p
            drv.stats["tier_resident"] = len(drv.tier.pool)
            commits = drv.tier.drain_commits()
        return {"spilled": int(n_s), "promoted": int(n_p),
                "commits": commits,
                "cache_backlog": int(np.asarray(
                    drv.state.cache_valid).sum()),
                "tier_resident": (len(drv.tier.pool)
                                  if drv.tier is not None else 0),
                "live": int(drv.live_count())}

    # ------------------------------------------------------------- tier

    def _cmd_force_spill(self, p: dict) -> dict:
        moved = self.drv.force_spill(int(p["n"]))
        tier = self.drv.tier
        return {"moved": int(moved),
                "commits": tier.drain_commits() if tier is not None else [],
                "tier_resident": len(tier.pool) if tier is not None else 0}

    def _cmd_force_promote(self, p: dict) -> dict:
        n = p.get("n")
        moved = self.drv.force_promote(None if n is None else int(n))
        tier = self.drv.tier
        return {"moved": int(moved),
                "commits": tier.drain_commits() if tier is not None else [],
                "tier_resident": len(tier.pool) if tier is not None else 0}

    # ------------------------------------------------------------ state

    def _cmd_snapshot(self, p: dict) -> dict:
        snap = self.drv.snapshot()
        return {"state": protocol.state_to_payload(snap),
                "digest": protocol.live_multiset_digest(snap)}

    def _cmd_load_state(self, p: dict) -> dict:
        self.drv.load_snapshot(protocol.payload_to_state(p["state"]))
        self._tier_rows = None
        return {"ok": True, "live": int(self.drv.live_count())}

    def _cmd_live_count(self, p: dict) -> dict:
        return {"live": int(self.drv.live_count())}

    def _cmd_posting_lengths(self, p: dict) -> dict:
        return {"lengths": np.asarray(self.drv.posting_lengths())}

    def _cmd_occupancy(self, p: dict) -> dict:
        return {"occ": np.asarray(self.drv.shard_occupancy()),
                "live": int(self.drv.live_count())}

    def _cmd_memory(self, p: dict) -> dict:
        tiers = self.drv.memory_tiers()
        return {"bytes": int(self.drv.memory_bytes()),
                "tiers": {k: int(v) for k, v in tiers.items()}}

    def _cmd_stats(self, p: dict) -> dict:
        return {"stats": {k: float(self.drv.stats[k])
                          for k in self.drv.stats}}

    def _cmd_extract(self, p: dict) -> dict:
        """Cross-worker balance donor: hand over up to ``n`` live
        vectors from this worker's longest float-resident NORMAL
        postings (ids + float32 vectors), deleting them locally.  The
        coordinator re-inserts them on the receiving worker — together
        one logical migration, so the live multiset is conserved."""
        from ..core import version_manager as vm
        from ..core.types import STATUS_NORMAL
        drv = self.drv
        want = int(p["n"])
        st = drv.state
        status = np.asarray(vm.unpack_status(st.rec_meta))
        ok = (np.asarray(vm.visible(st.rec_meta, st.allocated,
                                    st.global_version))
              & (status == STATUS_NORMAL)
              & ~np.asarray(st.tier_spilled))
        lengths = np.asarray(st.lengths)
        order = np.flatnonzero(ok)
        order = order[np.argsort(-lengths[order], kind="stable")]
        ids_rows = np.asarray(st.ids)
        sv = np.asarray(st.slot_valid)
        vecs_all = np.asarray(st.vectors)
        sel_ids, sel_vecs = [], []
        got = 0
        for pid in order:
            if got >= want:
                break
            slots = np.flatnonzero(sv[pid])[:want - got]
            if slots.size == 0:
                continue
            sel_ids.append(ids_rows[pid, slots])
            sel_vecs.append(vecs_all[pid, slots].astype(np.float32))
            got += slots.size
        if not got:
            return {"ids": np.empty(0, np.int32),
                    "vecs": np.empty((0, drv.cfg.dim), np.float32)}
        ids = np.concatenate(sel_ids).astype(np.int32)
        vecs = np.concatenate(sel_vecs)
        r = drv.delete(ids)
        if int(r.deleted) != len(ids):
            # tombstoning raced something structural — hand over only
            # what actually left this worker (never duplicate a vector)
            raise protocol.ProtocolError(
                f"extract deleted {r.deleted} of {len(ids)} planned ids")
        return {"ids": ids, "vecs": vecs}


def serve(inp, out) -> None:
    """Frame loop: one reply frame per command frame.  Errors reply as
    ``kind="error"`` (the coordinator raises); only a transport-level
    failure kills the loop."""
    rt = WorkerRuntime()
    while True:
        buf = protocol.read_frame(inp)
        if buf is None:
            break
        msg = protocol.decode_message(buf)
        try:
            payload = rt.handle(msg["kind"], msg["payload"])
            reply = protocol.encode_message("ok", payload, msg["seq"])
        except Exception as e:  # noqa: BLE001 - ship the failure up
            reply = protocol.encode_message(
                "error", {"command": msg["kind"], "error": repr(e)},
                msg["seq"])
        protocol.write_frame(out, reply)
        if msg["kind"] == "shutdown":
            break


def main() -> None:
    import os
    import sys
    # claim the frame stream before anything can print to it: keep a
    # private handle on the real stdout, then point fd 1 at stderr so
    # stray prints (ours or a library's) never corrupt a frame
    out = os.fdopen(os.dup(1), "wb")
    os.dup2(2, 1)
    sys.stdout = sys.stderr
    inp = os.fdopen(os.dup(0), "rb")
    serve(inp, out)


if __name__ == "__main__":
    main()
