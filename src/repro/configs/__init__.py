"""One config module per assigned architecture (exact assignment numbers)."""
