"""gemma3-4b [dense]: 5:1 local:global interleave, 128k context
[hf:google/gemma-3-1b-pt].  Sliding window 1024 on local layers; tied
embeddings; head_dim 256.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv=4,
    d_ff=10240, vocab=262144, head_dim=256,
    sliding_window=1024, local_global_pattern="LLLLLG",
    tie_embeddings=True, rope_theta=1e6,
)
