"""jamba-1.5-large-398b [hybrid]: Mamba+attention 1:7 interleave, MoE
16 experts top-2 [arXiv:2403.19887].  72 layers = 9 periods of 8
(attention at period position 4, MoE every 2nd layer).
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv=8,
    d_ff=24576, vocab=65536, head_dim=128,
    attn_every_k=8,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576,
                  every_k_layers=2),
    mamba_d_state=16, mamba_expand=2, mamba_conv=4,
)
