"""llava-next-34b [vlm]: anyres tiling [hf:llava-hf/llava-v1.6].

Backbone only; the vision frontend is a STUB — input_specs provides
precomputed patch embeddings (prefix_len tokens).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv=8,
    d_ff=20480, vocab=64000, head_dim=128, prefix_len=1152,
)
