"""rwkv6-3b [ssm]: Finch — data-dependent decay [arXiv:2404.05892]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=0, n_kv=0,
    d_ff=8960, vocab=65536, rwkv_head_dim=64,
)
