"""seamless-m4t-medium [audio]: enc-dec multimodal [arXiv:2308.11596].

12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206.  The audio
frontend is a STUB: input_specs provides precomputed frame embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, d_model=1024, n_heads=16, n_kv=16,
    d_ff=4096, vocab=256206, encoder_layers=12,
)
