"""UBIS core: updatable balanced cluster index (the paper's contribution)."""
from .types import (BackgroundRound, IndexState, RoundResult, UBISConfig,
                    empty_state, state_memory_bytes, state_tier_bytes,
                    STATUS_NORMAL,
                    STATUS_SPLITTING, STATUS_MERGING, STATUS_DELETED,
                    KIND_NONE, KIND_SPLIT, KIND_MERGE, KIND_COMPACT)
from .driver import UBISDriver
from .search import search, brute_force
from .build import initial_state, kmeans
from .balance import background_round, select_candidates
from . import balance, tier, update, version_manager, metrics

__all__ = [
    "BackgroundRound", "IndexState", "RoundResult", "UBISConfig",
    "empty_state", "state_memory_bytes", "state_tier_bytes", "UBISDriver",
    "search", "brute_force", "initial_state", "kmeans", "balance", "tier",
    "update", "version_manager", "metrics", "background_round",
    "select_candidates",
    "STATUS_NORMAL", "STATUS_SPLITTING", "STATUS_MERGING", "STATUS_DELETED",
    "KIND_NONE", "KIND_SPLIT", "KIND_MERGE", "KIND_COMPACT",
]
