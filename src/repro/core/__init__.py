"""UBIS core: updatable balanced cluster index (the paper's contribution)."""
from .types import (IndexState, RoundResult, UBISConfig, empty_state,
                    state_memory_bytes, STATUS_NORMAL, STATUS_SPLITTING,
                    STATUS_MERGING, STATUS_DELETED)
from .driver import UBISDriver
from .search import search, brute_force
from .build import initial_state, kmeans
from . import balance, update, version_manager, metrics

__all__ = [
    "IndexState", "RoundResult", "UBISConfig", "empty_state",
    "state_memory_bytes", "UBISDriver", "search", "brute_force",
    "initial_state", "kmeans", "balance", "update", "version_manager",
    "metrics", "STATUS_NORMAL", "STATUS_SPLITTING", "STATUS_MERGING",
    "STATUS_DELETED",
]
