"""Balance Detector + structural background operations (paper IV-C).

Key contribution of the paper: SPFresh's strict split/merge triggers
leave small postings stranded (Fig. 5); UBIS (a) *relaxes restrictions*
by keeping posting lengths in memory and scanning them periodically,
and (b) *identifies the root* — splits that produce an extremely small
side — via the balance factor ``f`` (Alg. 1 BalanceSplit).

Two layers of ops live here:
  * single-posting jitted transforms (``balance_split`` / ``merge_postings``
    / ``compact_posting`` / ``reassign_check``) — the reference semantics,
    kept as the sequential oracle the equivalence tests check against;
  * ``background_round`` — the production path: the WHOLE marked batch
    (kinds encoded as an int lane) executes as one SPMD program per tick.
The driver sequences rounds two-phase:
  round t   : mark SPLITTING/MERGING  (foreground traffic diverts to cache)
  round t+1 : execute; old posting -> DELETED with successor pointers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..kernels.posting_scan import BIG
from . import version_manager as vm
from .types import (KIND_COMPACT, KIND_MERGE, KIND_NONE, KIND_SPLIT, NO_ID,
                    NO_SUCC, STATUS_DELETED, STATUS_MERGING, STATUS_NORMAL,
                    STATUS_SPLITTING, BackgroundRound, IndexState, UBISConfig)
from .update import (alloc_postings, batched_append, cache_append,
                     dataclasses_replace, free_postings, oob, _flat_set)


# ---------------------------------------------------------------------------
# detection (the in-memory length table scan)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def detect(state: IndexState, cfg: UBISConfig):
    """Vectorized scan of the posting-length table.

    Returns (split_due, merge_due, compact_due) boolean masks over M.

    Spilled postings are never due: structural ops rewrite float tiles,
    which a spilled posting does not have on device — the tier planner
    force-promotes a structurally-due spilled posting first, and the
    detector picks it up the tick after (tests/test_tier.py).
    """
    status = vm.unpack_status(state.rec_meta)
    normal = (state.allocated & (status == STATUS_NORMAL)
              & ~state.tier_spilled)
    split_due = normal & (state.lengths > cfg.l_max)
    merge_due = normal & (state.lengths < cfg.l_min)
    compact_due = (normal & (state.used >= cfg.capacity)
                   & (state.lengths <= cfg.l_max))
    return split_due, merge_due, compact_due


# ---------------------------------------------------------------------------
# pool pressure (the saturation signal behind cross-shard rebalance)
# ---------------------------------------------------------------------------

def shard_pressure(state: IndexState, cfg: UBISConfig, base_pid=0):
    """Pressure stats for ONE posting pool: ``(live_postings, free_slots,
    cache_backlog, live_vectors)`` as a (4,) int32 vector.

    ``base_pid`` is the pool's global pid offset: cache targets are
    stored as global pids, so the backlog column counts parked jobs
    bound for THIS pool's postings.  Shared by the sharded background
    round (per shard, local state under ``shard_map``) and the
    single-device ``UBISDriver.shard_pressure`` (base 0, whole pool) so
    both planes report the same saturation signal in the same format.
    Pure local computation — contributes zero collectives to the round
    it rides in.
    """
    M_local = state.allocated.shape[0]
    status = vm.unpack_status(state.rec_meta)
    alive = state.allocated & (status != STATUS_DELETED)
    live = jnp.sum(alive)
    free = jnp.sum(~state.allocated)
    t = state.cache_target
    lo = jnp.asarray(base_pid, jnp.int32)
    backlog = jnp.sum(state.cache_valid & (t >= lo) & (t < lo + M_local))
    live_vecs = jnp.sum(jnp.where(alive, state.lengths, 0))
    return jnp.stack([live, free, backlog, live_vecs]).astype(jnp.int32)


# ---------------------------------------------------------------------------
# masked 2-means (the split clustering step)
# ---------------------------------------------------------------------------

def _median_bisect(tile, mask):
    """Deterministic balanced bisection: split the valid rows at the
    median of the maximum-variance axis (ties broken by rank, so the two
    sides differ by at most one point).  Used (a) to initialise 2-means
    and (b) as the termination guard when Lloyd collapses to an
    outlier-vs-rest split — a failure mode the paper's Alg. 1 does not
    handle (it would re-split the oversized survivor forever).
    """
    C = tile.shape[0]
    x = tile.astype(jnp.float32)
    n = jnp.maximum(jnp.sum(mask), 1)
    mean = jnp.sum(jnp.where(mask[:, None], x, 0), 0) / n
    var = jnp.sum(jnp.where(mask[:, None], (x - mean) ** 2, 0), 0)
    axis = jnp.argmax(var)
    vals = jnp.where(mask, x[:, axis], BIG)
    order = jnp.argsort(vals)            # valid rows first, ascending
    rank = jnp.zeros((C,), jnp.int32).at[order].set(
        jnp.arange(C, dtype=jnp.int32))
    assign = jnp.where(mask, (rank >= (n + 1) // 2).astype(jnp.int32), -1)
    return assign


def _two_means(tile, mask, iters: int, init: str = "median"):
    """2-means over the valid rows of one posting tile.

    init="median": deterministic median-split init (balanced starting
    point that avoids outlier-capture optima) — the UBIS path.
    init="farthest": classic farthest-point init — the SPFresh-faithful
    path, which DOES collapse to outlier-vs-rest splits on real data;
    that is precisely the small-posting generator behind the paper's
    Fig. 5, so the baseline must keep it.
    Returns (assign (C,) int32 in {0,1}, c0, c1)."""
    x = tile.astype(jnp.float32)
    if init == "median":
        ini = _median_bisect(tile, mask)
        c0 = _masked_mean(tile, (ini == 0) & mask, x[jnp.argmax(mask)])
        c1 = _masked_mean(tile, (ini == 1) & mask, x[jnp.argmax(mask)])
    else:
        first = jnp.argmax(mask)
        c0 = x[first]
        d0 = jnp.where(mask, jnp.sum((x - c0) ** 2, -1), -BIG)
        c1 = x[jnp.argmax(d0)]

    def body(_, carry):
        c0, c1 = carry
        d0 = jnp.sum((x - c0) ** 2, -1)
        d1 = jnp.sum((x - c1) ** 2, -1)
        a = (d1 < d0).astype(jnp.int32)        # 1 -> cluster 1
        w1 = (a == 1) & mask
        w0 = (a == 0) & mask
        n0 = jnp.maximum(jnp.sum(w0), 1)
        n1 = jnp.maximum(jnp.sum(w1), 1)
        m0 = jnp.sum(jnp.where(w0[:, None], x, 0), 0) / n0
        m1 = jnp.sum(jnp.where(w1[:, None], x, 0), 0) / n1
        c0 = jnp.where(jnp.any(w0), m0, c0)
        c1 = jnp.where(jnp.any(w1), m1, c1)
        return c0, c1

    c0, c1 = jax.lax.fori_loop(0, iters, body, (c0, c1))
    d0 = jnp.sum((x - c0) ** 2, -1)
    d1 = jnp.sum((x - c1) ** 2, -1)
    assign = jnp.where(mask, (d1 < d0).astype(jnp.int32), -1)
    return assign, c0, c1


def _masked_mean(tile, mask, fallback):
    n = jnp.maximum(jnp.sum(mask), 1)
    m = jnp.sum(jnp.where(mask[:, None], tile.astype(jnp.float32), 0), 0) / n
    return jnp.where(jnp.any(mask), m, fallback)


def _encode_written(state, cfg, rows):
    """Codes for freshly packed tile rows, under the ACTIVE codebook —
    every tile rewrite (split child, merge product, compact) is the lazy
    re-encode point of the versioned-codebook scheme."""
    from ..quant import pq
    cb = state.pq_codebooks[state.pq_active]
    stored = rows.astype(state.vectors.dtype).astype(jnp.float32)
    return pq.encode_tiles(cb, stored)


def _write_members(state, cfg, pid, tile, tids, member_mask):
    """Compact ``member_mask`` rows of a source tile into posting ``pid``
    (freshly allocated, empty).  Returns state with id_loc repointed.
    Row packing is shared with the batched round via ``_pack_rows`` so
    the sequential oracle and production path cannot drift."""
    C = cfg.capacity
    rows, rids, keep, n = _pack_rows(tile, tids, member_mask)
    vectors = state.vectors.at[pid].set(rows.astype(state.vectors.dtype))
    ids = state.ids.at[pid].set(rids)
    slot_valid = state.slot_valid.at[pid].set(keep)
    used = state.used.at[pid].set(n)
    lengths = state.lengths.at[pid].set(n)
    flat = pid * C + jnp.arange(C, dtype=jnp.int32)
    id_loc = state.id_loc.at[oob(rids, keep, cfg.max_ids)].set(flat,
                                                               mode="drop")
    state = dataclasses_replace(state, vectors=vectors, ids=ids,
                                slot_valid=slot_valid, used=used,
                                lengths=lengths, id_loc=id_loc)
    if cfg.use_pq:
        codes = state.codes.at[pid].set(
            _encode_written(state, cfg, rows[None])[0])
        state = dataclasses_replace(
            state, codes=codes,
            pq_posting_slot=state.pq_posting_slot.at[pid].set(
                state.pq_active))
    return state


# ---------------------------------------------------------------------------
# BalanceSplit — paper Algorithm 1
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def balance_split(state: IndexState, cfg: UBISConfig, pid):
    """Split posting ``pid`` (status SPLITTING, marked a round earlier).

    Follows Alg. 1: filter deleted vectors; if the filtered posting no
    longer exceeds l_max, just compact it in place (lines 1-4).  Else run
    2-means; in UBIS mode, if the small side is under ``f * total``,
    reassign its points to nearer existing postings and fold the rest
    into the big side (lines 7-15) so no small posting is ever persisted.
    SPFresh mode keeps both sides unconditionally (the Fig. 5 failure).

    Two posting slots are consumed in the worst case; the driver checks
    ``free_top >= 2`` before scheduling.
    """
    C = cfg.capacity
    tile = state.vectors[pid]
    tids = state.ids[pid]
    mask = state.slot_valid[pid]
    n = state.lengths[pid]
    ver = state.global_version + jnp.uint32(1)

    assign, c0, c1 = _two_means(
        tile, mask, cfg.kmeans_iters,
        init="median" if cfg.is_ubis else "farthest")
    n0 = jnp.sum((assign == 0) & mask)
    n1 = jnp.sum((assign == 1) & mask)
    small_is_0 = n0 <= n1
    nmin = jnp.minimum(n0, n1)
    ntot = jnp.maximum(n0 + n1, 1)

    imbalanced = cfg.is_ubis & (
        nmin.astype(jnp.float32) < cfg.balance_factor *
        ntot.astype(jnp.float32))

    small_side = jnp.where(small_is_0, 0, 1)
    big_side = 1 - small_side
    small_mask = (assign == small_side) & mask
    big_mask = (assign == big_side) & mask
    c_big = jnp.where(small_is_0, c1, c0)
    c_small = jnp.where(small_is_0, c0, c1)

    # --- Alg.1 lines 10-13: nearer-posting search for the small side ----
    status = vm.unpack_status(state.rec_meta)
    other = (state.allocated & (status == STATUS_NORMAL)
             & ~state.tier_spilled)
    other = other.at[pid].set(False)
    sc = ops.centroid_score(tile.astype(jnp.float32), state.centroids, other,
                            backend=cfg.use_pallas)           # (C, M)
    best_other = jnp.argmin(sc, -1).astype(jnp.int32)
    best_d = jnp.min(sc, -1)
    d_big = (jnp.sum(tile.astype(jnp.float32) ** 2, -1)
             - 2 * tile.astype(jnp.float32) @ c_big
             + jnp.sum(c_big ** 2))
    # score convention: sc already excludes ||p||^2, so compare apples:
    d_big_score = d_big - jnp.sum(tile.astype(jnp.float32) ** 2, -1)
    move_out = imbalanced & small_mask & (best_d < d_big_score)
    fold_in = imbalanced & small_mask & ~(best_d < d_big_score)

    # membership of the surviving side(s)
    members_a = jnp.where(imbalanced, big_mask | fold_in, big_mask)
    members_b = jnp.where(imbalanced, jnp.zeros_like(small_mask), small_mask)

    # --- termination guard (beyond-paper robustness, DESIGN.md §1) ------
    # If either surviving side still exceeds l_max (Lloyd collapsed to an
    # outlier-vs-rest split and the fold-in restored the oversize), the
    # paper's Alg. 1 would re-split that survivor forever.  Fall back to
    # the deterministic median bisection: both halves <= capacity/2 <=
    # l_max, so every split strictly reduces posting size.
    oversized = cfg.is_ubis & (
        (jnp.sum(members_a) > cfg.l_max)
        | (jnp.sum(members_b) > cfg.l_max))
    med = _median_bisect(tile, mask)
    med_a = (med == 0) & mask
    med_b = (med == 1) & mask
    members_a = jnp.where(oversized, med_a, members_a)
    members_b = jnp.where(oversized, med_b, members_b)
    move_out = move_out & ~oversized
    c_big = jnp.where(oversized, _masked_mean(tile, med_a, c_big), c_big)
    c_small = jnp.where(oversized, _masked_mean(tile, med_b, c_small),
                        c_small)

    cent_a = _masked_mean(tile, members_a, c_big)
    cent_b = _masked_mean(tile, members_b, c_small)

    # allocate both slots unconditionally (fixed shape); slot b is
    # returned to the free list when the imbalanced branch leaves it empty.
    state, pids_new = alloc_postings(
        state, cfg, 2, jnp.stack([cent_a, cent_b]), ver)
    pa, pb = pids_new[0], pids_new[1]
    state = _write_members(state, cfg, pa, tile, tids, members_a)
    state = _write_members(state, cfg, pb, tile, tids, members_b)

    b_empty = ~jnp.any(members_b)
    state = free_postings(state,
                          jnp.stack([pb, jnp.asarray(-1, jnp.int32)]),
                          jnp.array([True, False]) & b_empty)

    # move-out appends (may divert to cache when targets are full)
    state, ok, _ = batched_append(state, cfg, tile, tids,
                                  jnp.where(move_out, best_other, -1),
                                  move_out)
    spill = move_out & ~ok
    state, _ = cache_append(state, cfg, tile, tids,
                            jnp.where(spill, best_other, -1), spill)

    # retire the parent: DELETED with successor pointers
    succ_b = jnp.where(b_empty, -1, pb)
    rec_meta = vm.transition(state.rec_meta, pid[None], STATUS_DELETED,
                             ver[None])
    rec_succ = vm.set_successors(state.rec_succ, pid[None], pa[None],
                                 succ_b[None])
    # neighbourhood graph: children point at each other + parent's nbrs
    pn = state.nbrs[pid]
    nbrs = state.nbrs.at[pa].set(
        jnp.concatenate([jnp.where(b_empty, pa, pb)[None], pn[:-1]]))
    nbrs = nbrs.at[pb].set(jnp.concatenate([pa[None], pn[:-1]]))
    state = dataclasses_replace(state, rec_meta=rec_meta, rec_succ=rec_succ,
                                nbrs=nbrs, global_version=ver)
    return state, pids_new


@functools.partial(jax.jit, static_argnames=("cfg",))
def compact_posting(state: IndexState, cfg: UBISConfig, pid):
    """Alg. 1 lines 1-4: drop tombstones, rewrite in place."""
    tile = state.vectors[pid]
    tids = state.ids[pid]
    mask = state.slot_valid[pid]
    state = _write_members(state, cfg, pid, tile, tids, mask)
    return dataclasses_replace(
        state, global_version=state.global_version + jnp.uint32(1))


# ---------------------------------------------------------------------------
# merge (paper III-B2) — small posting folds into its nearest neighbour
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def merge_postings(state: IndexState, cfg: UBISConfig, pid):
    """Merge posting ``pid`` with the nearest posting whose combined size
    stays under l_max.  Produces ONE new posting; both parents retire
    with successor pointers to it.  Consumes one slot."""
    C = cfg.capacity
    status = vm.unpack_status(state.rec_meta)
    n_me = state.lengths[pid]
    eligible = (state.allocated & (status == STATUS_NORMAL)
                & ~state.tier_spilled
                & (state.lengths + n_me < cfg.l_max))
    eligible = eligible.at[pid].set(False)
    sc = ops.centroid_score(state.centroids[pid][None], state.centroids,
                            eligible, backend=cfg.use_pallas)[0]
    partner = jnp.argmin(sc).astype(jnp.int32)
    has_partner = sc[partner] < BIG / 2
    ver = state.global_version + jnp.uint32(1)

    t1, i1, m1 = state.vectors[pid], state.ids[pid], state.slot_valid[pid]
    t2 = state.vectors[partner]
    i2 = state.ids[partner]
    m2 = state.slot_valid[partner] & has_partner
    n1 = jnp.sum(m1)
    n2 = jnp.sum(m2)
    cent = (_masked_mean(t1, m1, state.centroids[pid].astype(jnp.float32))
            * n1 + _masked_mean(t2, m2, 0.0) * n2) / jnp.maximum(n1 + n2, 1)

    state, pids_new = alloc_postings(state, cfg, 1, cent[None], ver)
    pnew = pids_new[0]
    # write both parents' members (total < l_max <= C by eligibility);
    # packing shared with the batched round via _merge_rows (no drift)
    rows, rids, keepm, n = _merge_rows(t1, i1, m1, t2, i2, m2)
    vectors = state.vectors.at[pnew].set(rows.astype(state.vectors.dtype))
    ids = state.ids.at[pnew].set(rids)
    slot_valid = state.slot_valid.at[pnew].set(keepm)
    used = state.used.at[pnew].set(n)
    lengths = state.lengths.at[pnew].set(n)
    flat = pnew * C + jnp.arange(C, dtype=jnp.int32)
    id_loc = state.id_loc.at[oob(rids, keepm, cfg.max_ids)].set(flat,
                                                                mode="drop")
    state = dataclasses_replace(state, vectors=vectors, ids=ids,
                                slot_valid=slot_valid, used=used,
                                lengths=lengths, id_loc=id_loc)
    if cfg.use_pq:
        state = dataclasses_replace(
            state,
            codes=state.codes.at[pnew].set(
                _encode_written(state, cfg, rows[None])[0]),
            pq_posting_slot=state.pq_posting_slot.at[pnew].set(
                state.pq_active))

    parents = jnp.stack([pid, jnp.where(has_partner, partner, -1)])
    rec_meta = vm.transition(state.rec_meta, parents, STATUS_DELETED,
                             jnp.stack([ver, ver]))
    rec_succ = vm.set_successors(state.rec_succ, parents,
                                 jnp.stack([pnew, pnew]),
                                 jnp.array([-1, -1]))
    nbrs = state.nbrs.at[pnew].set(state.nbrs[pid])
    state = dataclasses_replace(state, rec_meta=rec_meta, rec_succ=rec_succ,
                                nbrs=nbrs, global_version=ver)
    return state, pnew, has_partner


# ---------------------------------------------------------------------------
# LIRE reassign (paper III-B2) — post split/merge closure maintenance
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def reassign_check(state: IndexState, cfg: UBISConfig, pid):
    """For each vector of ``pid``: if a strictly nearer NORMAL posting
    exists, move it there (append + tombstone here)."""
    C = cfg.capacity
    tile = state.vectors[pid].astype(jnp.float32)
    tids = state.ids[pid]
    mask = state.slot_valid[pid]
    status = vm.unpack_status(state.rec_meta)
    other = (state.allocated & (status == STATUS_NORMAL)
             & ~state.tier_spilled)
    other = other.at[pid].set(False)
    sc = ops.centroid_score(tile, state.centroids, other,
                            backend=cfg.use_pallas)
    best_other = jnp.argmin(sc, -1).astype(jnp.int32)
    best_d = jnp.min(sc, -1)
    own = state.centroids[pid].astype(jnp.float32)
    d_own = jnp.sum(own * own) - 2 * tile @ own
    move = mask & (best_d < d_own)

    state, ok, _ = batched_append(state, cfg, tile, tids,
                                  jnp.where(move, best_other, -1), move)
    moved = move & ok
    # tombstone moved rows here
    slot_valid = state.slot_valid.at[pid].set(
        state.slot_valid[pid] & ~moved)
    lengths = state.lengths.at[pid].add(
        -jnp.sum(moved).astype(jnp.int32))
    state = dataclasses_replace(
        state, slot_valid=slot_valid, lengths=lengths,
        global_version=state.global_version + jnp.uint32(1))
    return state, jnp.sum(moved)


# ---------------------------------------------------------------------------
# epoch GC — reclaim retired postings (TPU-native RCU analogue)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "k"))
def gc_round(state: IndexState, cfg: UBISConfig, min_live_version, k: int):
    """Reclaim up to ``k`` DELETED postings whose retirement version is
    older than the oldest live snapshot; their ids return to the free
    list and successor words are cleared (chasers then re-locate)."""
    status = vm.unpack_status(state.rec_meta)
    weight = vm.unpack_weight(state.rec_meta)
    dead = (state.allocated & (status == STATUS_DELETED)
            & (weight < jnp.asarray(min_live_version, jnp.uint32)))
    # pick up to k by argsort (dead first)
    order = jnp.argsort(~dead, stable=True)[:k]
    valid = dead[order]
    state = free_postings(state, order.astype(jnp.int32), valid)
    return state, jnp.sum(valid)


# ---------------------------------------------------------------------------
# batched background round — the whole marked batch in ONE device program
# ---------------------------------------------------------------------------
# The driver used to sequence split/merge/compact one posting at a time,
# with a host status read, a free-list read, and a separate jit dispatch
# per op.  ``background_round`` replaces that loop: the batch of marked
# (kind, pid) ops executes as a single SPMD program — vmapped masked
# 2-means over a (B, C, d) gather, ranked free-list pops so concurrent
# allocations never collide, one scatter installing every successor
# pointer, and a fused post-op reassign pass.  Conflicts that the
# sequential order used to resolve implicitly are resolved explicitly:
#   * duplicate pids        -> first occurrence wins (recorder CAS rule);
#   * two merges, 1 partner -> first in batch order wins, loser defers;
#   * free-list exhaustion  -> a sequential grant scan admits ops in
#                              batch order while slots last, later ops
#                              defer (revert to NORMAL, re-marked later);
#   * postings retiring this round are excluded from every move-out /
#     reassign target set, so no vector can land in a dying tile.


def _pack_rows(tile, tids, member_mask):
    """Compact ``member_mask`` rows of one tile to the front (the
    vmappable core of ``_write_members``, minus the state scatter)."""
    C = tile.shape[0]
    order = jnp.argsort(~member_mask, stable=True)
    n = jnp.sum(member_mask)
    rows = tile[order]
    rids = tids[order]
    keep = jnp.arange(C) < n
    rows = jnp.where(keep[:, None], rows, 0)
    rids = jnp.where(keep, rids, NO_ID)
    return rows, rids, keep, n.astype(jnp.int32)


def _merge_rows(t1, i1, m1, t2, i2, m2):
    """Stable-compact the live members of two tiles into one (the
    vmappable core of ``merge_postings``' tile construction)."""
    C = t1.shape[0]
    o1 = jnp.argsort(~m1, stable=True)
    o2 = jnp.argsort(~m2, stable=True)
    rows = jnp.concatenate([t1[o1], t2[o2]])
    rids = jnp.concatenate([i1[o1], i2[o2]])
    keepm = jnp.concatenate([m1[o1], m2[o2]])
    order = jnp.argsort(~keepm, stable=True)[:C]
    rows, rids, keepm = rows[order], rids[order], keepm[order]
    rows = jnp.where(keepm[:, None], rows, 0)
    rids = jnp.where(keepm, rids, NO_ID)
    return rows, rids, keepm, jnp.sum(keepm).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("cfg", "reassign", "use_cache"))
def background_round(state: IndexState, cfg: UBISConfig, kinds, pids,
                     reassign: bool = True, use_cache: bool = True):
    """Execute a padded batch of marked background ops in one device call.

    kinds: (B,) int32 in {KIND_NONE, KIND_SPLIT, KIND_MERGE, KIND_COMPACT}
    pids:  (B,) int32 posting ids (-1 = padding)

    Ops must have been marked (SPLITTING for split/compact, MERGING for
    merge) in an earlier round — the two-phase window the vector cache
    depends on.  ``use_cache=False`` folds split-side spills back into
    child ``a`` instead of the cache (the sharded path, where the
    replicated cache cannot be written per-shard).  Returns
    (state, BackgroundRound).  Works on a sharded sub-pool too: all
    shapes derive from ``state`` and ``cfg.max_postings`` is only used
    as an out-of-bounds scatter sentinel (>= any local pool size).
    """
    B = kinds.shape[0]
    C = cfg.capacity
    M = state.lengths.shape[0]
    MS = cfg.max_postings           # OOB sentinel, >= M under shard_map
    d = cfg.dim
    ver = state.global_version + jnp.uint32(1)

    kinds = jnp.asarray(kinds, jnp.int32)
    pids = jnp.asarray(pids, jnp.int32)
    safe = jnp.clip(pids, 0, M - 1)
    status = vm.unpack_status(state.rec_meta)

    want = jnp.where(kinds == KIND_MERGE, STATUS_MERGING, STATUS_SPLITTING)
    # ~tier_spilled: a spilled posting has no device float tile to split/
    # merge/compact — the tier planner must promote it first (detect()
    # never marks one; this guards stale external batches)
    valid = ((pids >= 0) & (kinds != KIND_NONE)
             & vm.first_occurrence_mask(pids)
             & state.allocated[safe] & (status[safe] == want)
             & ~state.tier_spilled[safe])

    lengths0 = state.lengths[safe]
    # a split whose live length no longer exceeds l_max demotes to compact
    # (Alg. 1 lines 1-4) — decided on device, no host length read
    kind = jnp.where(valid & (kinds == KIND_SPLIT) & (lengths0 <= cfg.l_max),
                     KIND_COMPACT, jnp.where(valid, kinds, KIND_NONE))
    is_split = kind == KIND_SPLIT
    is_merge = kind == KIND_MERGE

    # append-target eligibility: spilled postings excluded (no device
    # float tile to append into) — all-False mask when tiering is off
    normal0 = (state.allocated & (status == STATUS_NORMAL)
               & ~state.tier_spilled)

    # ---- merge partner selection (conflicts: first in batch order wins)
    n_me = jnp.where(is_merge, lengths0, 0)
    psc = ops.centroid_score(state.centroids[safe].astype(jnp.float32),
                             state.centroids, normal0,
                             backend=cfg.use_pallas)            # (B, M)
    psc = jnp.where(state.lengths[None, :] + n_me[:, None] < cfg.l_max,
                    psc, BIG)
    partner = jnp.argmin(psc, -1).astype(jnp.int32)
    has_partner = (jnp.min(psc, -1) < BIG / 2) & is_merge
    pkey = jnp.where(has_partner, partner,
                     -2 - jnp.arange(B, dtype=jnp.int32))
    merge_ok = is_merge & (vm.first_occurrence_mask(pkey) | ~has_partner)
    kind = jnp.where(is_merge & ~merge_ok, KIND_NONE, kind)
    is_merge = kind == KIND_MERGE
    is_compact = kind == KIND_COMPACT

    # ---- free-slot budget: sequential grant scan over the batch -------
    demand = jnp.where(is_split, 2, jnp.where(is_merge, 1, 0))

    def grant_step(off, dem):
        g = off + dem <= state.free_top
        return off + jnp.where(g, dem, 0), (g, off)

    _, (granted, starts) = jax.lax.scan(grant_step, jnp.int32(0), demand)
    exec_ = (kind != KIND_NONE) & granted
    split_exec = is_split & exec_
    merge_exec = is_merge & exec_
    compact_exec = is_compact & exec_
    deferred = valid & ~exec_            # revert to NORMAL, re-mark later
    total = jnp.sum(jnp.where(exec_, demand, 0))

    # ---- ranked free-list pops: op i takes slots [start_i, start_i+dem)
    idx1 = state.free_top - 1 - starts
    pa = jnp.where(split_exec | merge_exec,
                   state.free_list[jnp.clip(idx1, 0, M - 1)], -1)
    pb = jnp.where(split_exec,
                   state.free_list[jnp.clip(idx1 - 1, 0, M - 1)], -1)

    partner = jnp.where(merge_exec & has_partner, partner, -1)
    has_partner = partner >= 0
    # postings retiring this round: split/merge parents + merge partners;
    # excluded from every append-target set below
    retiring = jnp.zeros((M,), bool)
    retiring = retiring.at[oob(pids, split_exec | merge_exec, MS)].set(
        True, mode="drop")
    retiring = retiring.at[oob(partner, has_partner, MS)].set(
        True, mode="drop")

    tiles = state.vectors[safe].astype(jnp.float32)      # (B, C, d)
    tids_all = state.ids[safe]                           # (B, C)
    masks = state.slot_valid[safe]                       # (B, C)

    # ---- split planning: vmapped masked 2-means + Alg. 1 balance ------
    # The 2-means sweep and the (B*C, M) nearer-posting matmul are the
    # round's dominant FLOPs but only splits consume them: an all-compact
    # / all-merge batch skips the whole block via lax.cond (ROADMAP
    # follow-up; the skip is observable as bg_ms_per_op in fig8).
    vmean = jax.vmap(_masked_mean)

    def split_plan(tile, mask):
        assign, c0, c1 = _two_means(
            tile, mask, cfg.kmeans_iters,
            init="median" if cfg.is_ubis else "farthest")
        n0 = jnp.sum((assign == 0) & mask)
        n1 = jnp.sum((assign == 1) & mask)
        small_is_0 = n0 <= n1
        imbalanced = cfg.is_ubis & (
            jnp.minimum(n0, n1).astype(jnp.float32)
            < cfg.balance_factor * jnp.maximum(n0 + n1, 1).astype(
                jnp.float32))
        small_side = jnp.where(small_is_0, 0, 1)
        small_mask = (assign == small_side) & mask
        big_mask = (assign == 1 - small_side) & mask
        c_big = jnp.where(small_is_0, c1, c0)
        c_small = jnp.where(small_is_0, c0, c1)
        return small_mask, big_mask, c_big, c_small, imbalanced

    def plan_splits(_):
        small_mask, big_mask, c_big, c_small, imbalanced = jax.vmap(
            split_plan)(tiles, masks)
        # nearer-posting search per small-side row, one flat score call
        sc = ops.centroid_score(tiles.reshape(B * C, d), state.centroids,
                                normal0 & ~retiring,
                                backend=cfg.use_pallas)
        best_other = jnp.argmin(sc, -1).astype(jnp.int32).reshape(B, C)
        best_d = jnp.min(sc, -1).reshape(B, C)
        d_big_score = (jnp.sum(c_big ** 2, -1)[:, None]
                       - 2 * jnp.einsum("bcd,bd->bc", tiles, c_big))
        nearer = best_d < d_big_score
        move_out = (imbalanced[:, None] & small_mask & nearer
                    & split_exec[:, None])
        fold_in = imbalanced[:, None] & small_mask & ~nearer
        members_a = jnp.where(imbalanced[:, None], big_mask | fold_in,
                              big_mask)
        members_b = jnp.where(imbalanced[:, None],
                              jnp.zeros_like(small_mask), small_mask)
        # termination guard: median bisection when a survivor stays
        # oversize
        oversized = cfg.is_ubis & (
            (jnp.sum(members_a, -1) > cfg.l_max)
            | (jnp.sum(members_b, -1) > cfg.l_max))
        med = jax.vmap(_median_bisect)(tiles, masks)
        med_a = (med == 0) & masks
        med_b = (med == 1) & masks
        members_a = jnp.where(oversized[:, None], med_a, members_a)
        members_b = jnp.where(oversized[:, None], med_b, members_b)
        move_out = move_out & ~oversized[:, None]
        c_big = jnp.where(oversized[:, None], vmean(tiles, med_a, c_big),
                          c_big)
        c_small = jnp.where(oversized[:, None],
                            vmean(tiles, med_b, c_small), c_small)
        cent_a = vmean(tiles, members_a, c_big)
        cent_b = vmean(tiles, members_b, c_small)
        return members_a, members_b, move_out, best_other, cent_a, cent_b

    def plan_nothing(_):
        zc = jnp.zeros((B, C), bool)
        return (zc, zc, zc, jnp.zeros((B, C), jnp.int32),
                jnp.zeros((B, d), jnp.float32),
                jnp.zeros((B, d), jnp.float32))

    (members_a, members_b, move_out, best_other, cent_a,
     cent_b) = jax.lax.cond(jnp.any(split_exec), plan_splits, plan_nothing,
                            None)
    b_empty = ~jnp.any(members_b, -1) & split_exec

    # ---- merge tile construction --------------------------------------
    safe_partner = jnp.clip(partner, 0, M - 1)
    pt = state.vectors[safe_partner].astype(jnp.float32)
    pi = state.ids[safe_partner]
    pmask = state.slot_valid[safe_partner] & has_partner[:, None]
    m_rows, m_rids, m_keep, m_n = jax.vmap(_merge_rows)(
        tiles, tids_all, masks, pt, pi, pmask)
    n1 = jnp.sum(masks, -1)
    n2 = jnp.sum(pmask, -1)
    mean1 = vmean(tiles, masks, state.centroids[safe].astype(jnp.float32))
    mean2 = vmean(pt, pmask, jnp.zeros((B, d), jnp.float32))
    cent_m = ((mean1 * n1[:, None] + mean2 * n2[:, None])
              / jnp.maximum(n1 + n2, 1)[:, None])

    # ---- compact + split children tile packing ------------------------
    vpack = jax.vmap(_pack_rows)
    a_rows, a_rids, a_keep, a_n = vpack(tiles, tids_all, members_a)
    b_rows, b_rids, b_keep, b_n = vpack(tiles, tids_all, members_b)
    c_rows, c_rids, c_keep, c_n = vpack(tiles, tids_all, masks)

    # ---- one unified scatter writes every produced tile ---------------
    w_pid = jnp.concatenate([jnp.where(split_exec, pa, -1),
                             jnp.where(split_exec, pb, -1),
                             jnp.where(merge_exec, pa, -1),
                             jnp.where(compact_exec, pids, -1)])
    w_valid = jnp.concatenate([split_exec, split_exec, merge_exec,
                               compact_exec])
    w_rows = jnp.concatenate([a_rows, b_rows, m_rows, c_rows])
    w_rids = jnp.concatenate([a_rids, b_rids, m_rids, c_rids])
    w_keep = jnp.concatenate([a_keep, b_keep, m_keep, c_keep])
    w_keep = w_keep & w_valid[:, None]
    w_rids = jnp.where(w_keep, w_rids, NO_ID)
    w_n = jnp.concatenate([a_n, b_n, m_n, c_n])
    w_cent = jnp.concatenate([cent_a, cent_b, cent_m,
                              state.centroids[safe].astype(jnp.float32)])

    # claim the popped slots (recorder word + allocated + free_top)
    new_pids = jnp.concatenate([pa, pb])
    np_safe = oob(new_pids, new_pids >= 0, MS)
    rec_meta = state.rec_meta.at[np_safe].set(
        vm.pack_meta(jnp.uint32(STATUS_NORMAL), ver), mode="drop")
    rec_succ = state.rec_succ.at[np_safe].set(
        jnp.uint32((NO_SUCC << 16) | NO_SUCC), mode="drop")
    allocated = state.allocated.at[np_safe].set(True, mode="drop")
    # cold-tier plane: decay every touch counter (the per-round half-
    # life the tier planner's cold-age trigger reads — pure local math,
    # zero collectives under shard_map), children inherit the parent's
    # decayed heat, and every posting born this round is float-resident.
    heat = state.heat
    tier_spilled = state.tier_spilled
    if cfg.use_tier:
        heat = heat >> 1
        parents2 = jnp.clip(jnp.concatenate([pids, pids]), 0, M - 1)
        heat = heat.at[np_safe].set(heat[parents2], mode="drop")
        tier_spilled = tier_spilled.at[np_safe].set(False, mode="drop")

    wt = oob(w_pid, w_valid, MS)
    vectors = state.vectors.at[wt].set(
        w_rows.astype(state.vectors.dtype), mode="drop")
    ids_arr = state.ids.at[wt].set(w_rids, mode="drop")
    slot_valid = state.slot_valid.at[wt].set(w_keep, mode="drop")
    used = state.used.at[wt].set(w_n, mode="drop")
    lengths = state.lengths.at[wt].set(w_n, mode="drop")
    centroids = state.centroids.at[wt].set(
        w_cent.astype(state.centroids.dtype), mode="drop")
    flat = wt[:, None] * C + jnp.arange(C, dtype=jnp.int32)[None, :]
    id_loc = state.id_loc.at[
        oob(w_rids.reshape(-1), w_keep.reshape(-1), cfg.max_ids)].set(
        flat.reshape(-1), mode="drop")
    codes = state.codes
    pq_posting_slot = state.pq_posting_slot
    if cfg.use_pq:
        # every tile produced this round (split children, merge product,
        # compacted survivors) re-encodes under the ACTIVE codebook —
        # the lazy upgrade point of the versioned-codebook scheme
        codes = codes.at[wt].set(_encode_written(state, cfg, w_rows),
                                 mode="drop")
        pq_posting_slot = pq_posting_slot.at[wt].set(state.pq_active,
                                                     mode="drop")

    # ---- batched retirement: DELETED + successor installation ---------
    succ_b = jnp.where(b_empty, -1, pb)
    ret_pids = jnp.concatenate([jnp.where(split_exec, pids, -1),
                                jnp.where(merge_exec, pids, -1),
                                partner])
    ret_s1 = jnp.concatenate([jnp.where(split_exec, pa, -1),
                              jnp.where(merge_exec, pa, -1),
                              jnp.where(has_partner, pa, -1)])
    ret_s2 = jnp.concatenate([succ_b,
                              jnp.full((2 * B,), -1, jnp.int32)])
    rec_meta, rec_succ = vm.retire(rec_meta, rec_succ, ret_pids,
                                   ret_s1, ret_s2, ver)
    # Rescue rule: no mark may outlive a round it rode in.  A lane can be
    # invalid (stale kind, duplicate pid) while its posting still carries
    # SPLITTING/MERGING — e.g. a posting double-marked compact+merge: the
    # first lane fails the status check, the second dies to the dedup.
    # If no *other* lane handles that posting this round, revert it to
    # NORMAL so the detector can re-mark it (else it is wedged forever:
    # detect() only considers NORMAL postings).
    handled = jnp.zeros((M,), bool).at[
        oob(pids, exec_ | deferred, MS)].set(True, mode="drop")
    st0 = status[safe]
    stuck = ((pids >= 0) & ~exec_ & ~deferred & ~handled[safe]
             & state.allocated[safe]
             & ((st0 == STATUS_SPLITTING) | (st0 == STATUS_MERGING)))
    # deferred ops, rescued stragglers + finished compacts return to NORMAL
    rec_meta = vm.transition(
        rec_meta,
        jnp.concatenate([jnp.where(deferred | stuck, pids, -1),
                         jnp.where(compact_exec, pids, -1)]),
        STATUS_NORMAL)

    # ---- neighbourhood graph: children adopt the parent's edges -------
    pn = state.nbrs[safe]
    nb_pid = jnp.concatenate([jnp.where(split_exec, pa, -1),
                              jnp.where(split_exec, pb, -1),
                              jnp.where(merge_exec, pa, -1)])
    nb_rows = jnp.concatenate([
        jnp.concatenate([jnp.where(b_empty, pa, pb)[:, None], pn[:, :-1]], 1),
        jnp.concatenate([pa[:, None], pn[:, :-1]], 1),
        pn])
    nbrs = state.nbrs.at[oob(nb_pid, nb_pid >= 0, MS)].set(
        nb_rows, mode="drop")

    state = dataclasses_replace(
        state, vectors=vectors, ids=ids_arr, slot_valid=slot_valid,
        used=used, lengths=lengths, centroids=centroids, rec_meta=rec_meta,
        rec_succ=rec_succ, allocated=allocated, nbrs=nbrs, id_loc=id_loc,
        codes=codes, pq_posting_slot=pq_posting_slot,
        heat=heat, tier_spilled=tier_spilled,
        free_top=state.free_top - total, global_version=ver)

    # empty b-sides go straight back to the free list
    state = free_postings(state, pb, b_empty)

    # ---- small-side move-outs (one conflict-free append for the batch)
    mo_vecs = tiles.reshape(B * C, d)
    mo_ids = tids_all.reshape(B * C)
    mo = move_out.reshape(B * C)
    mo_tgt = jnp.where(mo, best_other.reshape(B * C), -1)
    state, mo_ok, _ = batched_append(state, cfg, mo_vecs, mo_ids, mo_tgt, mo)
    spill = mo & ~mo_ok
    if use_cache:
        state, cache_ok = cache_append(state, cfg, mo_vecs, mo_ids,
                                       jnp.where(spill, mo_tgt, -1), spill)
        lost = spill & ~cache_ok
        n_spill = jnp.sum(spill & cache_ok)
    else:  # no cache (sharded path): every spill folds back
        lost = spill
        n_spill = jnp.int32(0)
    # spills the cache could not hold (or cache-less mode) fold back into
    # child a — always fits (|members_a| + |move_out| <= parent length <=
    # capacity), so a full cache degrades to a lopsided split instead of
    # silently dropping the vector with id_loc dangling into the retired
    # parent (the sequential oracle's latent flaw, not replicated here)
    pa_row = jnp.broadcast_to(pa[:, None], (B, C)).reshape(B * C)
    state, _, _ = batched_append(state, cfg, mo_vecs, mo_ids,
                                 jnp.where(lost, pa_row, -1), lost)

    # ---- fused post-op reassign over every posting born this round ----
    # Gated by lax.cond: the (3B*C, M) score matmul only runs when the
    # batch actually produced a posting (all-compact batches skip it).
    if reassign:
        r_pid = jnp.concatenate([jnp.where(split_exec, pa, -1),
                                 jnp.where(split_exec & ~b_empty, pb, -1),
                                 jnp.where(merge_exec, pa, -1)])

        def do_reassign(state):
            rs = jnp.clip(r_pid, 0, M - 1)
            r_tiles = state.vectors[rs].astype(jnp.float32)
            r_ids = state.ids[rs]
            r_mask = state.slot_valid[rs] & (r_pid >= 0)[:, None]
            status2 = vm.unpack_status(state.rec_meta)
            sc2 = ops.centroid_score(
                r_tiles.reshape(3 * B * C, d), state.centroids,
                state.allocated & (status2 == STATUS_NORMAL)
                & ~state.tier_spilled,
                backend=cfg.use_pallas)
            own = jnp.broadcast_to(rs[:, None], (3 * B, C)).reshape(-1)
            sc2 = sc2.at[jnp.arange(3 * B * C), own].set(BIG)
            r_best = jnp.argmin(sc2, -1).astype(jnp.int32)
            r_bd = jnp.min(sc2, -1)
            own_c = state.centroids[rs].astype(jnp.float32)
            d_own = (jnp.sum(own_c ** 2, -1)[:, None]
                     - 2 * jnp.einsum("bcd,bd->bc", r_tiles,
                                      own_c)).reshape(-1)
            mv = r_mask.reshape(-1) & (r_bd < d_own)
            state, mv_ok, _ = batched_append(
                state, cfg, r_tiles.reshape(-1, d), r_ids.reshape(-1),
                jnp.where(mv, r_best, -1), mv)
            moved = mv & mv_ok
            src_flat = (own * C
                        + jnp.tile(jnp.arange(C, dtype=jnp.int32), 3 * B))
            slot_valid2 = _flat_set(state.slot_valid,
                                    oob(src_flat, moved, MS * C),
                                    jnp.zeros_like(moved))
            lengths2 = state.lengths.at[oob(own, moved, MS)].add(
                -1, mode="drop")
            state = dataclasses_replace(state, slot_valid=slot_valid2,
                                        lengths=lengths2)
            return state, jnp.sum(moved).astype(jnp.int32)

        state, n_re = jax.lax.cond(
            jnp.any(r_pid >= 0), do_reassign,
            lambda state: (state, jnp.int32(0)), state)
    else:
        n_re = jnp.int32(0)

    i32 = lambda x: jnp.asarray(x, jnp.int32)
    rr = BackgroundRound(
        executed=i32(jnp.sum(exec_)), n_split=i32(jnp.sum(split_exec)),
        n_merge=i32(jnp.sum(merge_exec)),
        n_compact=i32(jnp.sum(compact_exec)),
        deferred=i32(jnp.sum(deferred) + jnp.sum(stuck)),
        moved_out=i32(jnp.sum(mo & mo_ok)),
        spilled=i32(n_spill), reassigned=i32(n_re),
        freed=i32(jnp.sum(b_empty)))
    return state, rr


def mark_selected(rec_meta, kinds, pids):
    """Transition the selected batch to its window status on device
    (SPLITTING for split/compact lanes, MERGING for merge lanes) — the
    mark half of the two-phase window, shared by the sharded round and
    the single-device ``fused_tick`` path."""
    split_like = (kinds == KIND_SPLIT) | (kinds == KIND_COMPACT)
    rec_meta = vm.transition(rec_meta, jnp.where(split_like, pids, -1),
                             STATUS_SPLITTING)
    return vm.transition(rec_meta, jnp.where(kinds == KIND_MERGE, pids, -1),
                         STATUS_MERGING)


@functools.partial(jax.jit, static_argnames=("cfg", "k"))
def mark_round(state: IndexState, cfg: UBISConfig, k: int):
    """Device-side candidate selection + mark in one program: the
    ``fused_tick`` replacement for the driver's ``detect()`` host
    round-trip.  Returns (state, kinds, pids, n_marked) — kinds/pids
    stay on device and feed the next tick's ``background_round``; only
    the scalar count crosses to the host (for scheduling/quiescence).
    """
    kinds, pids = select_candidates(state, cfg, k)
    rec_meta = mark_selected(state.rec_meta, kinds, pids)
    state = dataclasses_replace(
        state, rec_meta=rec_meta,
        global_version=state.global_version + jnp.uint32(1))
    return state, kinds, pids, jnp.sum(kinds != KIND_NONE)


@functools.partial(jax.jit, static_argnames=("cfg", "k"))
def select_candidates(state: IndexState, cfg: UBISConfig, k: int):
    """Device-side candidate pick: top-k due ops by the driver's priority
    (splits by length desc, then compacts, then merges by length asc).
    Returns (kinds (k,), pids (k,)) ready for ``background_round`` — used
    by the sharded path, where selection must not round-trip the host."""
    split_due, merge_due, compact_due = detect(state, cfg)
    L = jnp.int32(1) << 20
    key = jnp.where(split_due, -state.lengths,
                    jnp.where(compact_due, L,
                              jnp.where(merge_due, 2 * L + state.lengths,
                                        3 * L)))
    order = jnp.argsort(key, stable=True)[:k].astype(jnp.int32)
    due = key[order] < 3 * L
    kinds = jnp.where(split_due[order], KIND_SPLIT,
                      jnp.where(compact_due[order], KIND_COMPACT,
                                KIND_MERGE))
    kinds = jnp.where(due, kinds, KIND_NONE)
    return kinds, jnp.where(due, order, -1)
