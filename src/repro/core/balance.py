"""Balance Detector + structural background operations (paper IV-C).

Key contribution of the paper: SPFresh's strict split/merge triggers
leave small postings stranded (Fig. 5); UBIS (a) *relaxes restrictions*
by keeping posting lengths in memory and scanning them periodically,
and (b) *identifies the root* — splits that produce an extremely small
side — via the balance factor ``f`` (Alg. 1 BalanceSplit).

All ops here are single-posting jitted transforms (the background
'thread pool'); the driver sequences them, two-phase:
  round t   : mark SPLITTING/MERGING  (foreground traffic diverts to cache)
  round t+1 : execute; old posting -> DELETED with successor pointers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..kernels.posting_scan import BIG
from . import version_manager as vm
from .types import (NO_ID, STATUS_DELETED, STATUS_NORMAL, IndexState,
                    UBISConfig)
from .update import (alloc_postings, batched_append, cache_append,
                     dataclasses_replace, free_postings, oob, _flat_set)


# ---------------------------------------------------------------------------
# detection (the in-memory length table scan)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def detect(state: IndexState, cfg: UBISConfig):
    """Vectorized scan of the posting-length table.

    Returns (split_due, merge_due, compact_due) boolean masks over M.
    """
    status = vm.unpack_status(state.rec_meta)
    normal = state.allocated & (status == STATUS_NORMAL)
    split_due = normal & (state.lengths > cfg.l_max)
    merge_due = normal & (state.lengths < cfg.l_min)
    compact_due = (normal & (state.used >= cfg.capacity)
                   & (state.lengths <= cfg.l_max))
    return split_due, merge_due, compact_due


# ---------------------------------------------------------------------------
# masked 2-means (the split clustering step)
# ---------------------------------------------------------------------------

def _median_bisect(tile, mask):
    """Deterministic balanced bisection: split the valid rows at the
    median of the maximum-variance axis (ties broken by rank, so the two
    sides differ by at most one point).  Used (a) to initialise 2-means
    and (b) as the termination guard when Lloyd collapses to an
    outlier-vs-rest split — a failure mode the paper's Alg. 1 does not
    handle (it would re-split the oversized survivor forever).
    """
    C = tile.shape[0]
    x = tile.astype(jnp.float32)
    n = jnp.maximum(jnp.sum(mask), 1)
    mean = jnp.sum(jnp.where(mask[:, None], x, 0), 0) / n
    var = jnp.sum(jnp.where(mask[:, None], (x - mean) ** 2, 0), 0)
    axis = jnp.argmax(var)
    vals = jnp.where(mask, x[:, axis], BIG)
    order = jnp.argsort(vals)            # valid rows first, ascending
    rank = jnp.zeros((C,), jnp.int32).at[order].set(
        jnp.arange(C, dtype=jnp.int32))
    assign = jnp.where(mask, (rank >= (n + 1) // 2).astype(jnp.int32), -1)
    return assign


def _two_means(tile, mask, iters: int, init: str = "median"):
    """2-means over the valid rows of one posting tile.

    init="median": deterministic median-split init (balanced starting
    point that avoids outlier-capture optima) — the UBIS path.
    init="farthest": classic farthest-point init — the SPFresh-faithful
    path, which DOES collapse to outlier-vs-rest splits on real data;
    that is precisely the small-posting generator behind the paper's
    Fig. 5, so the baseline must keep it.
    Returns (assign (C,) int32 in {0,1}, c0, c1)."""
    x = tile.astype(jnp.float32)
    if init == "median":
        ini = _median_bisect(tile, mask)
        c0 = _masked_mean(tile, (ini == 0) & mask, x[jnp.argmax(mask)])
        c1 = _masked_mean(tile, (ini == 1) & mask, x[jnp.argmax(mask)])
    else:
        first = jnp.argmax(mask)
        c0 = x[first]
        d0 = jnp.where(mask, jnp.sum((x - c0) ** 2, -1), -BIG)
        c1 = x[jnp.argmax(d0)]

    def body(_, carry):
        c0, c1 = carry
        d0 = jnp.sum((x - c0) ** 2, -1)
        d1 = jnp.sum((x - c1) ** 2, -1)
        a = (d1 < d0).astype(jnp.int32)        # 1 -> cluster 1
        w1 = (a == 1) & mask
        w0 = (a == 0) & mask
        n0 = jnp.maximum(jnp.sum(w0), 1)
        n1 = jnp.maximum(jnp.sum(w1), 1)
        m0 = jnp.sum(jnp.where(w0[:, None], x, 0), 0) / n0
        m1 = jnp.sum(jnp.where(w1[:, None], x, 0), 0) / n1
        c0 = jnp.where(jnp.any(w0), m0, c0)
        c1 = jnp.where(jnp.any(w1), m1, c1)
        return c0, c1

    c0, c1 = jax.lax.fori_loop(0, iters, body, (c0, c1))
    d0 = jnp.sum((x - c0) ** 2, -1)
    d1 = jnp.sum((x - c1) ** 2, -1)
    assign = jnp.where(mask, (d1 < d0).astype(jnp.int32), -1)
    return assign, c0, c1


def _masked_mean(tile, mask, fallback):
    n = jnp.maximum(jnp.sum(mask), 1)
    m = jnp.sum(jnp.where(mask[:, None], tile.astype(jnp.float32), 0), 0) / n
    return jnp.where(jnp.any(mask), m, fallback)


def _write_members(state, cfg, pid, tile, tids, member_mask):
    """Compact ``member_mask`` rows of a source tile into posting ``pid``
    (freshly allocated, empty).  Returns state with id_loc repointed."""
    C = cfg.capacity
    order = jnp.argsort(~member_mask, stable=True)   # members first
    n = jnp.sum(member_mask)
    in_rows = order
    rows = tile[in_rows]
    rids = tids[in_rows]
    keep = jnp.arange(C) < n
    rids = jnp.where(keep, rids, NO_ID)
    vectors = state.vectors.at[pid].set(
        jnp.where(keep[:, None], rows, 0).astype(state.vectors.dtype))
    ids = state.ids.at[pid].set(rids)
    slot_valid = state.slot_valid.at[pid].set(keep)
    used = state.used.at[pid].set(n.astype(jnp.int32))
    lengths = state.lengths.at[pid].set(n.astype(jnp.int32))
    flat = pid * C + jnp.arange(C, dtype=jnp.int32)
    id_loc = state.id_loc.at[oob(rids, keep, cfg.max_ids)].set(flat,
                                                               mode="drop")
    return dataclasses_replace(state, vectors=vectors, ids=ids,
                               slot_valid=slot_valid, used=used,
                               lengths=lengths, id_loc=id_loc)


# ---------------------------------------------------------------------------
# BalanceSplit — paper Algorithm 1
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def balance_split(state: IndexState, cfg: UBISConfig, pid):
    """Split posting ``pid`` (status SPLITTING, marked a round earlier).

    Follows Alg. 1: filter deleted vectors; if the filtered posting no
    longer exceeds l_max, just compact it in place (lines 1-4).  Else run
    2-means; in UBIS mode, if the small side is under ``f * total``,
    reassign its points to nearer existing postings and fold the rest
    into the big side (lines 7-15) so no small posting is ever persisted.
    SPFresh mode keeps both sides unconditionally (the Fig. 5 failure).

    Two posting slots are consumed in the worst case; the driver checks
    ``free_top >= 2`` before scheduling.
    """
    C = cfg.capacity
    tile = state.vectors[pid]
    tids = state.ids[pid]
    mask = state.slot_valid[pid]
    n = state.lengths[pid]
    ver = state.global_version + jnp.uint32(1)

    assign, c0, c1 = _two_means(
        tile, mask, cfg.kmeans_iters,
        init="median" if cfg.is_ubis else "farthest")
    n0 = jnp.sum((assign == 0) & mask)
    n1 = jnp.sum((assign == 1) & mask)
    small_is_0 = n0 <= n1
    nmin = jnp.minimum(n0, n1)
    ntot = jnp.maximum(n0 + n1, 1)

    imbalanced = cfg.is_ubis & (
        nmin.astype(jnp.float32) < cfg.balance_factor *
        ntot.astype(jnp.float32))

    small_side = jnp.where(small_is_0, 0, 1)
    big_side = 1 - small_side
    small_mask = (assign == small_side) & mask
    big_mask = (assign == big_side) & mask
    c_big = jnp.where(small_is_0, c1, c0)
    c_small = jnp.where(small_is_0, c0, c1)

    # --- Alg.1 lines 10-13: nearer-posting search for the small side ----
    status = vm.unpack_status(state.rec_meta)
    other = state.allocated & (status == STATUS_NORMAL)
    other = other.at[pid].set(False)
    sc = ops.centroid_score(tile.astype(jnp.float32), state.centroids, other,
                            backend=cfg.use_pallas)           # (C, M)
    best_other = jnp.argmin(sc, -1).astype(jnp.int32)
    best_d = jnp.min(sc, -1)
    d_big = (jnp.sum(tile.astype(jnp.float32) ** 2, -1)
             - 2 * tile.astype(jnp.float32) @ c_big
             + jnp.sum(c_big ** 2))
    # score convention: sc already excludes ||p||^2, so compare apples:
    d_big_score = d_big - jnp.sum(tile.astype(jnp.float32) ** 2, -1)
    move_out = imbalanced & small_mask & (best_d < d_big_score)
    fold_in = imbalanced & small_mask & ~(best_d < d_big_score)

    # membership of the surviving side(s)
    members_a = jnp.where(imbalanced, big_mask | fold_in, big_mask)
    members_b = jnp.where(imbalanced, jnp.zeros_like(small_mask), small_mask)

    # --- termination guard (beyond-paper robustness, DESIGN.md §1) ------
    # If either surviving side still exceeds l_max (Lloyd collapsed to an
    # outlier-vs-rest split and the fold-in restored the oversize), the
    # paper's Alg. 1 would re-split that survivor forever.  Fall back to
    # the deterministic median bisection: both halves <= capacity/2 <=
    # l_max, so every split strictly reduces posting size.
    oversized = cfg.is_ubis & (
        (jnp.sum(members_a) > cfg.l_max)
        | (jnp.sum(members_b) > cfg.l_max))
    med = _median_bisect(tile, mask)
    med_a = (med == 0) & mask
    med_b = (med == 1) & mask
    members_a = jnp.where(oversized, med_a, members_a)
    members_b = jnp.where(oversized, med_b, members_b)
    move_out = move_out & ~oversized
    c_big = jnp.where(oversized, _masked_mean(tile, med_a, c_big), c_big)
    c_small = jnp.where(oversized, _masked_mean(tile, med_b, c_small),
                        c_small)

    cent_a = _masked_mean(tile, members_a, c_big)
    cent_b = _masked_mean(tile, members_b, c_small)

    # allocate both slots unconditionally (fixed shape); slot b is
    # returned to the free list when the imbalanced branch leaves it empty.
    state, pids_new = alloc_postings(
        state, cfg, 2, jnp.stack([cent_a, cent_b]), ver)
    pa, pb = pids_new[0], pids_new[1]
    state = _write_members(state, cfg, pa, tile, tids, members_a)
    state = _write_members(state, cfg, pb, tile, tids, members_b)

    b_empty = ~jnp.any(members_b)
    state = free_postings(state,
                          jnp.stack([pb, jnp.asarray(-1, jnp.int32)]),
                          jnp.array([True, False]) & b_empty)

    # move-out appends (may divert to cache when targets are full)
    state, ok, _ = batched_append(state, cfg, tile, tids,
                                  jnp.where(move_out, best_other, -1),
                                  move_out)
    spill = move_out & ~ok
    state, _ = cache_append(state, cfg, tile, tids,
                            jnp.where(spill, best_other, -1), spill)

    # retire the parent: DELETED with successor pointers
    succ_b = jnp.where(b_empty, -1, pb)
    rec_meta = vm.transition(state.rec_meta, pid[None], STATUS_DELETED,
                             ver[None])
    rec_succ = vm.set_successors(state.rec_succ, pid[None], pa[None],
                                 succ_b[None])
    # neighbourhood graph: children point at each other + parent's nbrs
    pn = state.nbrs[pid]
    nbrs = state.nbrs.at[pa].set(
        jnp.concatenate([jnp.where(b_empty, pa, pb)[None], pn[:-1]]))
    nbrs = nbrs.at[pb].set(jnp.concatenate([pa[None], pn[:-1]]))
    state = dataclasses_replace(state, rec_meta=rec_meta, rec_succ=rec_succ,
                                nbrs=nbrs, global_version=ver)
    return state, pids_new


@functools.partial(jax.jit, static_argnames=("cfg",))
def compact_posting(state: IndexState, cfg: UBISConfig, pid):
    """Alg. 1 lines 1-4: drop tombstones, rewrite in place."""
    tile = state.vectors[pid]
    tids = state.ids[pid]
    mask = state.slot_valid[pid]
    state = _write_members(state, cfg, pid, tile, tids, mask)
    return dataclasses_replace(
        state, global_version=state.global_version + jnp.uint32(1))


# ---------------------------------------------------------------------------
# merge (paper III-B2) — small posting folds into its nearest neighbour
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def merge_postings(state: IndexState, cfg: UBISConfig, pid):
    """Merge posting ``pid`` with the nearest posting whose combined size
    stays under l_max.  Produces ONE new posting; both parents retire
    with successor pointers to it.  Consumes one slot."""
    C = cfg.capacity
    status = vm.unpack_status(state.rec_meta)
    n_me = state.lengths[pid]
    eligible = (state.allocated & (status == STATUS_NORMAL)
                & (state.lengths + n_me < cfg.l_max))
    eligible = eligible.at[pid].set(False)
    sc = ops.centroid_score(state.centroids[pid][None], state.centroids,
                            eligible, backend=cfg.use_pallas)[0]
    partner = jnp.argmin(sc).astype(jnp.int32)
    has_partner = sc[partner] < BIG / 2
    ver = state.global_version + jnp.uint32(1)

    t1, i1, m1 = state.vectors[pid], state.ids[pid], state.slot_valid[pid]
    t2 = state.vectors[partner]
    i2 = state.ids[partner]
    m2 = state.slot_valid[partner] & has_partner
    n1 = jnp.sum(m1)
    n2 = jnp.sum(m2)
    cent = (_masked_mean(t1, m1, state.centroids[pid].astype(jnp.float32))
            * n1 + _masked_mean(t2, m2, 0.0) * n2) / jnp.maximum(n1 + n2, 1)

    state, pids_new = alloc_postings(state, cfg, 1, cent[None], ver)
    pnew = pids_new[0]
    # write both parents' members (total < l_max <= C by eligibility)
    order1 = jnp.argsort(~m1, stable=True)
    order2 = jnp.argsort(~m2, stable=True)
    rows = jnp.concatenate([t1[order1], t2[order2]])
    rids = jnp.concatenate([i1[order1], i2[order2]])
    keepm = jnp.concatenate([m1[order1], m2[order2]])
    # stable-compact the concatenated members into the first n slots
    order = jnp.argsort(~keepm, stable=True)[:C]
    rows, rids, keepm = rows[order], rids[order], keepm[order]
    rids = jnp.where(keepm, rids, NO_ID)
    vectors = state.vectors.at[pnew].set(
        jnp.where(keepm[:, None], rows, 0).astype(state.vectors.dtype))
    ids = state.ids.at[pnew].set(rids)
    slot_valid = state.slot_valid.at[pnew].set(keepm)
    n = jnp.sum(keepm).astype(jnp.int32)
    used = state.used.at[pnew].set(n)
    lengths = state.lengths.at[pnew].set(n)
    flat = pnew * C + jnp.arange(C, dtype=jnp.int32)
    id_loc = state.id_loc.at[oob(rids, keepm, cfg.max_ids)].set(flat,
                                                                mode="drop")
    state = dataclasses_replace(state, vectors=vectors, ids=ids,
                                slot_valid=slot_valid, used=used,
                                lengths=lengths, id_loc=id_loc)

    parents = jnp.stack([pid, jnp.where(has_partner, partner, -1)])
    rec_meta = vm.transition(state.rec_meta, parents, STATUS_DELETED,
                             jnp.stack([ver, ver]))
    rec_succ = vm.set_successors(state.rec_succ, parents,
                                 jnp.stack([pnew, pnew]),
                                 jnp.array([-1, -1]))
    nbrs = state.nbrs.at[pnew].set(state.nbrs[pid])
    state = dataclasses_replace(state, rec_meta=rec_meta, rec_succ=rec_succ,
                                nbrs=nbrs, global_version=ver)
    return state, pnew, has_partner


# ---------------------------------------------------------------------------
# LIRE reassign (paper III-B2) — post split/merge closure maintenance
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def reassign_check(state: IndexState, cfg: UBISConfig, pid):
    """For each vector of ``pid``: if a strictly nearer NORMAL posting
    exists, move it there (append + tombstone here)."""
    C = cfg.capacity
    tile = state.vectors[pid].astype(jnp.float32)
    tids = state.ids[pid]
    mask = state.slot_valid[pid]
    status = vm.unpack_status(state.rec_meta)
    other = state.allocated & (status == STATUS_NORMAL)
    other = other.at[pid].set(False)
    sc = ops.centroid_score(tile, state.centroids, other,
                            backend=cfg.use_pallas)
    best_other = jnp.argmin(sc, -1).astype(jnp.int32)
    best_d = jnp.min(sc, -1)
    own = state.centroids[pid].astype(jnp.float32)
    d_own = jnp.sum(own * own) - 2 * tile @ own
    move = mask & (best_d < d_own)

    state, ok, _ = batched_append(state, cfg, tile, tids,
                                  jnp.where(move, best_other, -1), move)
    moved = move & ok
    # tombstone moved rows here
    slot_valid = state.slot_valid.at[pid].set(
        state.slot_valid[pid] & ~moved)
    lengths = state.lengths.at[pid].add(
        -jnp.sum(moved).astype(jnp.int32))
    state = dataclasses_replace(
        state, slot_valid=slot_valid, lengths=lengths,
        global_version=state.global_version + jnp.uint32(1))
    return state, jnp.sum(moved)


# ---------------------------------------------------------------------------
# epoch GC — reclaim retired postings (TPU-native RCU analogue)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "k"))
def gc_round(state: IndexState, cfg: UBISConfig, min_live_version, k: int):
    """Reclaim up to ``k`` DELETED postings whose retirement version is
    older than the oldest live snapshot; their ids return to the free
    list and successor words are cleared (chasers then re-locate)."""
    status = vm.unpack_status(state.rec_meta)
    weight = vm.unpack_weight(state.rec_meta)
    dead = (state.allocated & (status == STATUS_DELETED)
            & (weight < jnp.asarray(min_live_version, jnp.uint32)))
    # pick up to k by argsort (dead first)
    order = jnp.argsort(~dead, stable=True)[:k]
    valid = dead[order]
    state = free_postings(state, order.astype(jnp.int32), valid)
    return state, jnp.sum(valid)
