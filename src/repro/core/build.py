"""Initial index construction (SPANN-style, paper III-B1).

Seeds the posting pool with k-means centroids over a sample, builds the
centroid neighbourhood graph, then streams every vector through the
*production* insert path — so construction exercises exactly the same
machinery as the streaming workload (splits included), and the built
index automatically satisfies the structural invariants the property
tests check.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels import ops
from .types import IndexState, UBISConfig, empty_state
from .update import alloc_postings, dataclasses_replace


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(points: jax.Array, k: int, iters: int, key: jax.Array):
    """Plain Lloyd k-means; empty clusters keep their previous centroid
    (they become zero-length postings and the merge path sweeps them)."""
    n, d = points.shape
    idx = jax.random.choice(key, n, (k,), replace=False)
    cents = points[idx].astype(jnp.float32)

    def body(_, cents):
        assign, _ = ops.kmeans_assign(points, cents, backend="ref")
        sums = jnp.zeros((k, d), jnp.float32).at[assign].add(points)
        counts = jnp.zeros((k,), jnp.float32).at[assign].add(1.0)
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        return jnp.where(counts[:, None] > 0, new, cents)

    return jax.lax.fori_loop(0, iters, body, cents)


@functools.partial(jax.jit, static_argnames=("cfg", "k"))
def seed_postings(state: IndexState, cfg: UBISConfig, centroids_k, k: int):
    """Allocate ``k`` empty postings at the given centroids and wire the
    centroid neighbourhood graph (top-G mutual neighbours)."""
    state, pids = alloc_postings(state, cfg, k, centroids_k,
                                 jnp.uint32(0))
    sc = ops.centroid_score(centroids_k, centroids_k, backend="ref")
    sc = sc + jnp.eye(k) * 1e30  # exclude self
    g = min(cfg.graph_degree, max(k - 1, 1))
    _, nn = jax.lax.top_k(-sc, g)
    nbr_rows = jnp.full((k, cfg.graph_degree), -1, jnp.int32)
    nbr_rows = nbr_rows.at[:, :g].set(pids[nn])
    nbrs = state.nbrs.at[pids].set(nbr_rows)
    return dataclasses_replace(state, nbrs=nbrs), pids


def initial_state(cfg: UBISConfig, seed_vectors, *, key=None,
                  sample_cap: int = 20000, target_fill: float = 0.7):
    """Empty index seeded with centroids fit on (a sample of) the data.

    The vectors themselves are NOT inserted here — the driver streams
    them through insert rounds (DESIGN.md §4).
    """
    if key is None:
        key = jax.random.key(0)
    n = seed_vectors.shape[0]
    k0 = max(1, min(int(round(n / (target_fill * cfg.l_max))),
                    cfg.max_postings // 4))
    sample = jnp.asarray(seed_vectors[:sample_cap], jnp.float32)
    cents = kmeans(sample, k0, cfg.kmeans_iters, key)
    state = empty_state(cfg)
    state, _ = seed_postings(state, cfg, cents, k0)
    if cfg.use_pq:
        # generation-0 codebooks fit on the same seed sample; every
        # insert round encodes against them from the first vector on
        from ..quant import pq
        key, pk = jax.random.split(key)
        cb0 = pq.init_codebooks(sample, cfg.pq_m, cfg.pq_ksub,
                                cfg.kmeans_iters, pk,
                                backend=cfg.use_pallas)
        state = dataclasses_replace(
            state, pq_codebooks=state.pq_codebooks.at[0].set(cb0))
    return state
