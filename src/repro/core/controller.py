"""High-Concurrency Controller (paper IV-B2) — entry-point shim.

DEPRECATED entry point: new code should go through the engine-agnostic
front door, ``repro.api.make_index`` (the ``StreamingIndex`` protocol),
which covers every engine — not just the UBIS driver re-exported here.

The controller is split across two layers:
  * data plane (jitted rounds; the three status branches, conflict-free
    scatters, the vector cache):     ``core/update.py``
  * control plane (job queues, the two-phase SPLITTING/MERGING window,
    cache drains, GC scheduling):    ``core/driver.py``
This module re-exports the public pieces under the paper's name.
"""
from .update import (batched_append, cache_append, cache_take,
                     delete_round, insert_round, mark_status)
from .driver import UBISDriver

__all__ = ["batched_append", "cache_append", "cache_take", "delete_round",
           "insert_round", "mark_status", "UBISDriver"]
