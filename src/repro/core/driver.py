"""Host-side orchestration: job queues + the background scheduler.

The paper's runtime is a foreground thread feeding a job queue and
background threads executing split/merge/reassign.  Here the *data
plane* is entirely jitted device code (update.py / balance.py /
search.py); this module is the *control plane*: it sequences rounds,
implements the two-phase SPLITTING/MERGING window, drains the vector
cache, garbage-collects retired postings, and carries the accounting
(TPS/QPS/recall inputs) the benchmarks read.

Mode differences (cfg.mode):
  * ``ubis``     — periodic balance-detector scan (relaxed restrictions),
                   vector cache for blocked jobs, balanced splits.
  * ``spfresh``  — strict triggers only (split on insert overflow, merge
                   on search touching a small posting), posting-lock
                   rejection of blocked jobs, unconditional 2-means splits.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..api.types import SearchResult, TickReport, UpdateResult
from ..kernels import ops
from ..obs import Obs
from . import balance, search as search_mod, tier as tier_mod, update
from .build import initial_state
from .types import (KIND_COMPACT, KIND_MERGE, KIND_SPLIT, IndexState,
                    UBISConfig)

KIND_CODES = {"split": KIND_SPLIT, "merge": KIND_MERGE,
              "compact": KIND_COMPACT}


@dataclasses.dataclass
class SearchDispatch:
    """An in-flight search: the jitted program has been LAUNCHED but not
    awaited.  ``collect_search`` blocks on the device values, runs the
    host tier rerank against the state *captured at dispatch* (the
    result answers for the index as of dispatch time, even if a tick ran
    in between), and returns the ``SearchResult``.

    This is the overlap seam the serving engine uses: dispatch a search
    batch, run an insert round or a background tick while the device
    works, then collect.
    """

    state: Any                       # IndexState captured at dispatch
    queries: np.ndarray              # host copy, for the tier rerank
    k: int
    found: Any                       # device (Q, k_eff) int32
    scores: Any                      # device (Q, k_eff) f32
    probe: Any                       # device probed pids
    t0: float


class UBISDriver:
    """Streaming driver for one index instance (a ``StreamingIndex``).

    ``fused_tick=True`` (UBIS mode only) moves background candidate
    selection on device: ``balance.mark_round`` replaces the
    ``detect()`` host round-trip — the kinds/pids batch stays on device
    and feeds the next tick's ``background_round`` directly, exactly as
    the sharded round already selects.  SPFresh's strict triggers are
    host-noted by construction, so the flag is ignored in that mode.
    """

    def __init__(self, cfg: UBISConfig, seed_vectors=None, *,
                 seed: int = 0, round_size: int = 1024,
                 bg_ops_per_round: int = 4, drain_per_tick: int = 256,
                 insert_retries: int = 2, gc_lag: int = 16,
                 reassign_after_split: bool = True,
                 pq_retrain_every: int = 32,
                 fused_tick: bool = False,
                 tier_moves_per_tick: int = 32,
                 tier_rerank_host: bool = True,
                 tier_async: bool = False,
                 obs: Optional[Obs] = None,
                 obs_profile_dir: Optional[str] = None):
        self.cfg = cfg
        self.round_size = int(round_size)
        self.bg_ops = int(bg_ops_per_round)
        self.drain_n = int(drain_per_tick)
        self.retries = int(insert_retries)
        self.gc_lag = int(gc_lag)
        self.reassign_after_split = reassign_after_split
        # observability plane: metrics registry + structured tracer; the
        # stats mapping below is a schema-seeded facade registered with
        # it, so every engine exposes the same key set
        self.obs = obs if obs is not None else Obs()
        ops.observe_fallbacks(self.obs)
        # opt-in jax.profiler capture: the FIRST tick after construction
        # is wrapped in a device trace written under this directory
        self._profile_dir = obs_profile_dir
        self._profiled = False
        # quant plane: codebook re-train cadence in ticks (0 = never);
        # only meaningful with cfg.use_pq
        self.pq_retrain_every = int(pq_retrain_every)
        self.fused_tick = bool(fused_tick) and cfg.is_ubis
        # cold-tier plane (cfg.use_tier): pinned host pool + planner
        self.tier = (tier_mod.TierManager(
            cfg, max_moves=int(tier_moves_per_tick),
            rerank_host=tier_rerank_host, obs=self.obs)
            if cfg.use_tier else None)
        # tier_async: dispatch the tick's spill/promote DMA at tick
        # START (overlapping the background round) and reconcile at tick
        # end, instead of the synchronous plan+move at tick end
        self.tier_async = bool(tier_async)
        self._bg_ran = False
        self._ticks = 0
        self._pq_key = jax.random.key(seed + 0x517C0DE)

        if seed_vectors is None:
            raise ValueError("seed_vectors required (used for k-means seeds)")
        self.state: IndexState = initial_state(
            cfg, jnp.asarray(seed_vectors), key=jax.random.key(seed))
        # ops marked SPLITTING/MERGING last tick, executed this tick
        self._marked: list[tuple[str, int]] = []
        self._marked_set: set[int] = set()
        # fused_tick: device-resident (kinds, pids) marked last tick
        self._marked_dev = None
        # SPFresh strict-trigger candidate sets
        self._sp_split: set[int] = set()
        self._sp_merge: set[int] = set()
        self.stats = self.obs.driver_stats()

    # ------------------------------------------------------------------
    # foreground
    # ------------------------------------------------------------------

    def insert(self, vecs, ids, *, tick_between: bool = True) -> UpdateResult:
        """Stream (vecs, ids) through padded insert rounds.

        Rejected jobs (SPFresh lock model / full cache) are retried up to
        ``insert_retries`` times with a background tick in between —
        mirroring the paper's blocked-then-retried updates; every retry
        costs wall time, which is how contention degrades TPS.
        """
        vecs = np.asarray(vecs, np.float32)
        ids = np.asarray(ids, np.int64).astype(np.int32)
        if len(vecs) != len(ids):
            raise ValueError(f"vecs/ids length mismatch: {len(vecs)} vs "
                             f"{len(ids)}")
        if ids.size and (ids.min() < 0 or ids.max() >= self.cfg.max_ids):
            raise ValueError("ids out of range for cfg.max_ids")
        t0 = time.perf_counter()
        n_acc = n_cache = n_rej = 0
        J = self.round_size
        pending = (vecs, ids, np.full(ids.shape, -1, np.int32))
        for attempt in range(self.retries + 1):
            pv, pi, ph = pending
            rej_v, rej_i, rej_h = [], [], []
            for off in range(0, len(pi), J):
                cv, ci, ch = pv[off:off + J], pi[off:off + J], ph[off:off + J]
                pad = J - len(ci)
                valid = np.concatenate([np.ones(len(ci), bool),
                                        np.zeros(pad, bool)])
                cv = np.concatenate([cv, np.zeros((pad, self.cfg.dim),
                                                  np.float32)])
                ci = np.concatenate([ci, np.zeros(pad, np.int32)])
                ch = np.concatenate([ch, np.full(pad, -1, np.int32)])
                self.state, res, _touched = update.insert_round(
                    self.state, self.cfg, jnp.asarray(cv), jnp.asarray(ci),
                    jnp.asarray(valid), jnp.asarray(ch))
                acc, cac, rej = (np.asarray(res.accepted),
                                 np.asarray(res.cached),
                                 np.asarray(res.rejected))
                n_acc += int(acc.sum())
                n_cache += int(cac.sum())
                if self.tier is not None:       # appends heat their target
                    self.tier.note_targets(np.asarray(res.target)[acc])
                if rej.any():
                    rej_v.append(cv[rej])
                    rej_i.append(ci[rej])
                    rej_h.append(np.full(int(rej.sum()), -1, np.int32))
                if not self.cfg.is_ubis:
                    self._note_spfresh_overflow(np.asarray(res.target)[acc])
            if not rej_v:
                pending = None
                break
            pending = (np.concatenate(rej_v), np.concatenate(rej_i),
                       np.concatenate(rej_h))
            if tick_between:
                self.tick()
        if pending is not None:
            n_rej = len(pending[1])
        jax.block_until_ready(self.state.lengths)
        dt = time.perf_counter() - t0
        self.stats["insert_time"] += dt
        self.stats["inserted"] += n_acc + n_cache
        self.stats["rejected"] += n_rej
        self.obs.emit("insert", accepted=n_acc, cached=n_cache,
                      rejected=n_rej, seconds=round(dt, 6))
        return UpdateResult(accepted=n_acc, cached=n_cache, rejected=n_rej,
                            seconds=dt)

    def delete(self, ids) -> UpdateResult:
        ids = np.asarray(ids, np.int64).astype(np.int32)
        t0 = time.perf_counter()
        J = self.round_size
        n_done = n_blocked = 0
        for off in range(0, len(ids), J):
            ci = ids[off:off + J]
            pad = J - len(ci)
            valid = np.concatenate([np.ones(len(ci), bool),
                                    np.zeros(pad, bool)])
            ci = np.concatenate([ci, np.zeros(pad, np.int32)])
            self.state, done, blocked = update.delete_round(
                self.state, self.cfg, jnp.asarray(ci), jnp.asarray(valid))
            n_done += int(np.asarray(done).sum())
            n_blocked += int(np.asarray(blocked).sum())
        jax.block_until_ready(self.state.lengths)
        dt = time.perf_counter() - t0
        self.stats["delete_time"] += dt
        self.stats["deleted"] += n_done
        self.stats["blocked"] += n_blocked
        self.obs.emit("delete", deleted=n_done, blocked=n_blocked,
                      seconds=round(dt, 6))
        return UpdateResult(deleted=n_done, blocked=n_blocked, seconds=dt)

    def search(self, queries, k: int,
               nprobe: Optional[int] = None) -> SearchResult:
        return self.collect_search(self.dispatch_search(queries, k, nprobe))

    def dispatch_search(self, queries, k: int,
                        nprobe: Optional[int] = None) -> SearchDispatch:
        """Launch the jitted search WITHOUT waiting for it (JAX async
        dispatch: the call returns as soon as the program is enqueued).
        The serving engine overlaps inserts/ticks here; pair with
        ``collect_search``."""
        queries = np.asarray(queries, np.float32)
        t0 = time.perf_counter()
        # host rerank widens the final candidate set to rerank_k (the
        # device top-k orders spilled candidates by ADC score, so the
        # exact host pass must see the full rerank budget to matter —
        # cutting this below rerank_k measurably costs recall on a
        # mostly-cold index)
        k_eff = (max(k, self.cfg.rerank_k)
                 if self.tier is not None and self.tier.rerank_host
                 else k)
        # per-dispatch fallback accounting: the signature carries every
        # routing decision (backend knob + plane shape); the query batch
        # size is deliberately omitted — re-traces of the same signature
        # route identically (see ops.count_fallback_dispatches)
        sig = ("ubis-search", self.cfg.use_pallas, self.cfg.dim,
               self.cfg.capacity, self.cfg.use_pq, self.cfg.pq_ksub)
        with ops.count_fallback_dispatches(self.obs, sig):
            found, scores, probe = search_mod.search(
                self.state, self.cfg, jnp.asarray(queries), k_eff, nprobe)
        return SearchDispatch(state=self.state, queries=queries, k=k,
                              found=found, scores=scores, probe=probe,
                              t0=t0)

    def collect_search(self, disp: SearchDispatch) -> SearchResult:
        """Await a dispatched search and finish the host-side tail
        (heat notes, tier rerank, stats) against the dispatch-time
        state."""
        found = np.asarray(disp.found)
        scores = np.asarray(disp.scores)
        probe = np.asarray(disp.probe)
        if self.tier is not None:
            # probes are the search-heat signal (promote trigger), and
            # spilled candidates in the final candidate set get their
            # true distance from the pinned pool (optional host rerank)
            self.tier.note_probes(probe)
            found, scores = self.tier.rerank(disp.state, disp.queries,
                                             found, scores)
            found, scores = found[:, :disp.k], scores[:, :disp.k]
        dt = time.perf_counter() - disp.t0
        self.stats["search_time"] += dt
        self.stats["queries"] += disp.queries.shape[0]
        # search introspection, piggybacked on arrays the result path
        # already transferred (no added device syncs)
        self.stats["search_probed"] += int((probe >= 0).sum())
        self.stats["search_results"] += int((found >= 0).sum())
        if self.cfg.use_pq:
            self.stats["search_adc_batches"] += 1
        else:
            self.stats["search_exact_batches"] += 1
        if not self.cfg.is_ubis:
            self._note_spfresh_small(probe)
        return SearchResult(ids=found, scores=scores, seconds=dt)

    # ------------------------------------------------------------------
    # background
    # ------------------------------------------------------------------

    def tick(self) -> TickReport:
        """One background round: execute marked ops, drain the cache,
        detect + mark new candidates, GC, (quant plane) re-train the PQ
        codebooks on cadence, and (cold tier) run the spill/promote
        planner."""
        if self._profile_dir and not self._profiled:
            self._profiled = True
            with self.obs.profile(self._profile_dir):
                return self._tick_impl()
        return self._tick_impl()

    def _tick_impl(self) -> TickReport:
        t0 = time.perf_counter()
        plan = None
        if self.tier is not None and self.tier_async:
            # tick-start dispatch: the spill tiles' D2H copy and the
            # promote tiles' H2D staging run while the background round
            # executes below; reconcile validates + commits at tick end.
            # Whether the round will carry the decay is known now — the
            # marked batch was chosen LAST tick.
            will_decay = (self._marked_dev is not None if self.fused_tick
                          else bool(self._marked))
            self.state, plan = self.tier.dispatch(self.state,
                                                  decayed=will_decay)
        executed = self._execute_marked()
        self.stats["bg_exec_time"] += time.perf_counter() - t0
        drained = self._drain_cache() if self.cfg.is_ubis else 0
        marked = self._mark_candidates()
        reclaimed = self._gc()
        retrained = self._pq_retrain()
        if self.tier is not None and self.tier_async:
            self.state, n_s, n_p = self.tier.reconcile(self.state, plan)
            self.stats["tier_spilled"] += n_s
            self.stats["tier_promoted"] += n_p
            self.stats["tier_resident"] = len(self.tier.pool)
            spilled, promoted = n_s, n_p
        else:
            spilled, promoted = self._tier_step()
        dt = time.perf_counter() - t0
        self.stats["bg_time"] += dt
        self.stats["bg_ops"] += executed
        self.stats["bg_gc"] += reclaimed
        self.stats["drained"] += drained
        self.obs.emit("tick", executed=executed, drained=drained,
                      marked=marked, gc=reclaimed, pq=retrained,
                      spilled=spilled, promoted=promoted,
                      seconds=round(dt, 6))
        return TickReport(executed=executed, drained=drained,
                          marked=marked, gc=reclaimed,
                          pq_retrained=retrained, spilled=spilled,
                          promoted=promoted, seconds=dt)

    def flush(self, max_ticks: int = 200) -> int:
        """Tick until quiescent (no marked ops, no due candidates, cache
        empty, no tier moves in flight — a forced promotion must get its
        follow-up structural op before flush returns).  Returns number
        of ticks."""
        for i in range(max_ticks):
            r = self.tick()
            cache_n = int(jnp.sum(self.state.cache_valid))
            if (r.executed == 0 and r.marked == 0
                    and r.spilled == 0 and r.promoted == 0
                    and (cache_n == 0 or not self.cfg.is_ubis)):
                return i + 1
        return max_ticks

    # ------------------------------------------------------------------

    def _execute_marked(self) -> int:
        """Execute the whole marked batch as ONE jitted background round.

        No per-op host reads: status/length/free-slot checks, slot
        budgeting and conflict resolution all happen on device; the only
        transfer is the small ``BackgroundRound`` counter struct.
        """
        self._bg_ran = False
        if self.fused_tick:
            md, self._marked_dev = self._marked_dev, None
            if md is None:
                return 0
            kinds, pids = md
        else:
            marked, self._marked = self._marked, []
            self._marked_set.clear()
            if not marked:
                return 0
            # every marked op MUST ride in this batch: truncating would
            # leave its SPLITTING/MERGING mark set with nothing queued to
            # clear it (the detector only re-marks NORMAL postings ->
            # wedged forever)
            B = max(self.bg_ops, len(marked), 1)
            kinds_np = np.zeros(B, np.int32)
            pids_np = np.full(B, -1, np.int32)
            for i, (kind, pid) in enumerate(marked):
                kinds_np[i] = KIND_CODES[kind]
                pids_np[i] = pid
            kinds, pids = jnp.asarray(kinds_np), jnp.asarray(pids_np)
        self.state, rr = balance.background_round(
            self.state, self.cfg, kinds, pids,
            reassign=self.reassign_after_split)
        self._bg_ran = True        # the round carried the heat decay
        rr = jax.device_get(rr)
        self.stats["bg_split"] += int(rr.n_split)
        self.stats["bg_merge"] += int(rr.n_merge)
        self.stats["bg_compact"] += int(rr.n_compact)
        self.stats["bg_deferred"] += int(rr.deferred)
        self.stats["bg_reassigned"] += int(rr.reassigned)
        self.obs.emit("bg_exec", split=int(rr.n_split),
                      merge=int(rr.n_merge), compact=int(rr.n_compact),
                      deferred=int(rr.deferred),
                      reassigned=int(rr.reassigned),
                      executed=int(rr.executed))
        return int(rr.executed)

    def _drain_cache(self) -> int:
        cache_n = int(jnp.sum(self.state.cache_valid))
        if cache_n == 0:
            return 0
        n = min(self.drain_n, self.round_size)
        self.state, vecs, ids, targets, taken = update.cache_take(
            self.state, self.cfg, n)
        pad = self.round_size - n
        vecs = jnp.pad(vecs, ((0, pad), (0, 0)))
        ids = jnp.pad(ids, (0, pad))
        targets = jnp.pad(targets, (0, pad), constant_values=-1)
        taken = jnp.pad(taken, (0, pad))
        self.state, res, _ = update.insert_round(
            self.state, self.cfg, vecs, ids, taken, targets)
        return int(jnp.sum(res.accepted))

    def _mark_candidates(self) -> int:
        from .types import STATUS_MERGING, STATUS_SPLITTING
        if self.fused_tick:
            # device-side selection + mark (one program, no detect()
            # host round-trip); the kinds/pids batch never leaves the
            # device — only the scalar count does, for flush quiescence
            self.state, kinds, pids, n = balance.mark_round(
                self.state, self.cfg, self.bg_ops)
            n = int(n)
            self._marked_dev = (kinds, pids) if n else None
            if n:
                # pids stay on device by design — only the count leaves
                self.obs.emit("bg_mark", reason="fused-device-round",
                              marked=n)
            return n
        if self.cfg.is_ubis:
            split_due, merge_due, compact_due = jax.device_get(
                balance.detect(self.state, self.cfg))
            lengths = np.asarray(self.state.lengths)
            split_pids = np.flatnonzero(split_due)
            split_pids = split_pids[np.argsort(-lengths[split_pids])]
            merge_pids = np.flatnonzero(merge_due)
            merge_pids = merge_pids[np.argsort(lengths[merge_pids])]
            compact_pids = np.flatnonzero(compact_due)
        else:
            from . import version_manager as vm_
            lengths = np.asarray(self.state.lengths)
            alloc = np.asarray(self.state.allocated)
            # candidates were noted at search/insert time; a posting may
            # have been retired since — marking a DELETED posting would
            # RESURRECT its stale tile (duplicate vectors), so require
            # NORMAL status now (found by the invariant property test)
            status = np.asarray(vm_.unpack_status(self.state.rec_meta))
            normal = alloc & (status == 0)
            split_pids = np.array(
                [p for p in self._sp_split
                 if normal[p] and lengths[p] > self.cfg.l_max], int)
            merge_pids = np.array(
                [p for p in self._sp_merge
                 if normal[p] and lengths[p] < self.cfg.l_min], int)
            compact_pids = np.array(
                [p for p in self._sp_split
                 if normal[p] and lengths[p] <= self.cfg.l_max], int)
            self._sp_split.clear()
            self._sp_merge.clear()

        jobs = ([("split", int(p)) for p in split_pids]
                + [("compact", int(p)) for p in compact_pids]
                + [("merge", int(p)) for p in merge_pids])
        # one job per posting: a hollowed-out full tile is both
        # compact_due and merge_due — double-marking would leave the
        # second kind's mark with a dead first lane in the batch
        seen = set(self._marked_set)
        deduped = []
        for j in jobs:
            if j[1] not in seen:
                seen.add(j[1])
                deduped.append(j)
        jobs = deduped[:self.bg_ops]
        if not jobs:
            return 0
        split_like = [p for k_, p in jobs if k_ in ("split", "compact")]
        merge_like = [p for k_, p in jobs if k_ == "merge"]
        if split_like:
            self.state = update.mark_status(
                self.state, jnp.asarray(split_like, jnp.int32),
                STATUS_SPLITTING)
        if merge_like:
            self.state = update.mark_status(
                self.state, jnp.asarray(merge_like, jnp.int32),
                STATUS_MERGING)
        self._marked.extend(jobs)
        self._marked_set.update(p for _, p in jobs)
        self.obs.emit(
            "bg_mark",
            reason=("balance-detector" if self.cfg.is_ubis
                    else "strict-trigger"),
            split=[p for kk, p in jobs if kk == "split"],
            merge=[p for kk, p in jobs if kk == "merge"],
            compact=[p for kk, p in jobs if kk == "compact"])
        return len(jobs)

    def _gc(self) -> int:
        ver = int(self.state.global_version)
        if ver <= self.gc_lag:
            return 0
        self.state, n = balance.gc_round(
            self.state, self.cfg, jnp.uint32(ver - self.gc_lag), 64)
        return int(n)

    def _pq_retrain(self) -> int:
        """Versioned codebook re-train on tick cadence (quant plane)."""
        if not self.cfg.use_pq or self.pq_retrain_every <= 0:
            return 0
        self._ticks += 1
        if self._ticks % self.pq_retrain_every:
            return 0
        from ..quant import pq
        self._promote_retrain_pinned()
        evict = (int(self.state.pq_active) + 1) % self.cfg.pq_versions
        self._pq_key, k = jax.random.split(self._pq_key)
        self.state = pq.retrain_round(self.state, self.cfg, k)
        self.stats["pq_retrains"] += 1
        # live codebook generation, for monitors (throughput() readers)
        self.stats["pq_generation"] = int(
            self.state.pq_slot_gen[self.state.pq_active])
        self.obs.emit("pq_retrain", reason="cadence",
                      evicted_slot=evict,
                      generation=int(self.stats["pq_generation"]))
        return 1

    def _promote_retrain_pinned(self) -> None:
        """Cold-tier x quant interplay: promote spilled postings pinned
        to the slot the retrain is about to evict (see
        ``tier.TierManager.promote_retrain_pinned``)."""
        if self.tier is None:
            return
        self.state, n = self.tier.promote_retrain_pinned(self.state)
        self.stats["tier_promoted"] += n

    def _tier_step(self) -> tuple:
        """Cold-tier plane: apply accumulated touches, run the
        spill/promote planner, execute the moves."""
        if self.tier is None:
            return 0, 0
        self.state, n_s, n_p = self.tier.tick(self.state,
                                              decayed=self._bg_ran)
        self.stats["tier_spilled"] += n_s
        self.stats["tier_promoted"] += n_p
        self.stats["tier_resident"] = len(self.tier.pool)
        return n_s, n_p

    def force_spill(self, n: int) -> int:
        """Spill the ``n`` coldest hot postings now (test/benchmark
        hook — the planner's watermark path uses the same machinery)."""
        if self.tier is None:
            return 0
        self.state, moved = self.tier.force_spill(self.state, n)
        self.stats["tier_spilled"] += moved
        self.stats["tier_resident"] = len(self.tier.pool)
        return moved

    def force_promote(self, n=None) -> int:
        """Promote up to ``n`` spilled postings (all when None)."""
        if self.tier is None:
            return 0
        self.state, moved = self.tier.force_promote(self.state, n)
        self.stats["tier_promoted"] += moved
        self.stats["tier_resident"] = len(self.tier.pool)
        return moved

    # ---- SPFresh strict-trigger bookkeeping ---------------------------

    def _note_spfresh_overflow(self, pids: np.ndarray):
        lengths = np.asarray(self.state.lengths)
        for p in np.unique(pids):
            if p >= 0 and lengths[p] > self.cfg.l_max:
                self._sp_split.add(int(p))

    def _note_spfresh_small(self, probe: np.ndarray):
        lengths = np.asarray(self.state.lengths)
        small = np.unique(probe[lengths[probe] < self.cfg.l_min])
        for p in small:
            if p >= 0:
                self._sp_merge.add(int(p))

    # ---- StreamingIndex protocol surface ------------------------------

    def snapshot(self) -> IndexState:
        """A single-device-usable state.  With the cold tier on, the
        spilled float tiles are written back into a COPY (flags stay
        set), so the snapshot is self-contained and checkpoint-safe;
        ``load_snapshot`` re-derives residency from the flags."""
        if self.tier is not None:
            return self.tier.snapshot_fill(self.state)
        return self.state

    def load_snapshot(self, state: IndexState) -> "UBISDriver":
        """Adopt a ``snapshot()`` state (possibly restored from a
        checkpoint): with the cold tier on, spilled tiles move back to
        the host pool and their device copies are re-zeroed, so the
        restored index answers search identically to the one that
        snapshotted.  Returns self (chaining convenience)."""
        if self.tier is not None:
            state = self.tier.adopt(state)
        self.state = state
        self._marked, self._marked_dev = [], None
        self._marked_set.clear()
        return self

    def memory_bytes(self) -> int:
        """Total bytes held by the index across BOTH tiers (the untiered
        figure; see ``memory_tiers`` for the device/host split)."""
        from .types import state_memory_bytes
        return state_memory_bytes(self.state)

    def memory_tiers(self) -> dict:
        """Device/host byte split; sums to ``memory_bytes()``."""
        if self.tier is not None:
            return self.tier.memory_tiers(self.state)
        return {"device": self.memory_bytes(), "host": 0}

    def exact(self, queries, k: int) -> SearchResult:
        """Exact top-k over the index's live contents (recall oracle).
        Spilled postings are scanned host-side from the pinned pool and
        merged with the device scan, so the oracle stays exact under
        tiering."""
        queries = np.asarray(queries, np.float32)
        found, scores = search_mod.brute_force(
            self.state, self.cfg, jnp.asarray(queries), k)
        if self.tier is not None:
            found, scores = self.tier.exact_merge(self.state, queries,
                                                  found, scores, k)
        return SearchResult(ids=np.asarray(found),
                            scores=np.asarray(scores))

    def posting_lengths(self) -> np.ndarray:
        from .metrics import live_posting_lengths
        return live_posting_lengths(self.state)

    def shard_pressure(self) -> np.ndarray:
        """The (1, 4) single-pool pressure row — the same
        ``balance.shard_pressure`` signal the sharded background round
        reports per shard, so monitors read one format either way."""
        return np.asarray(balance.shard_pressure(self.state,
                                                 self.cfg))[None]

    def live_count(self) -> int:
        """Vectors in visible postings + the cache (protocol surface)."""
        return int(self.state.live_vector_count()) + int(
            jnp.sum(self.state.cache_valid))

    # ------------------------------------------------------------------

    def throughput(self) -> dict:
        from .metrics import throughput_from_stats
        return throughput_from_stats(self.stats)

    def close(self) -> None:
        """Detach this driver's ``Obs`` bundle from the process-global
        kernel-fallback plane (the sinks are weakly held, so this only
        matters when the caller keeps the bundle alive past the driver —
        test suites and notebooks building many indexes call it, or
        ``ops.reset_fallback_state()`` between builds)."""
        ops.discard_fallback_sink(self.obs)
