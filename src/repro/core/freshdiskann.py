"""FreshDiskANN-lite: the graph-based comparison baseline (paper V-A).

A reduced-scale but behaviourally-faithful Vamana/FreshDiskANN: fixed
out-degree proximity graph, greedy beam search, RobustPrune(alpha)
insertion with back-edges, lazy tombstone deletes with periodic
consolidation.  Pure JAX: the beam search is a bounded ``fori_loop``
over a fixed-size candidate list, vmapped over the query batch.

The paper's observations this must reproduce: (a) competitive QPS,
(b) recall degradation under heavy streaming churn (fresh inserts
re-wire neighbourhoods and tombstones break navigability until
consolidation), (c) higher memory than the cluster-based index.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import defaultdict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..api.types import SearchResult, TickReport, UpdateResult

BIG = 1e30


@dataclasses.dataclass(frozen=True)
class GraphConfig:
    dim: int = 64
    max_nodes: int = 1 << 17
    degree: int = 32              # R (memory-index out-degree)
    beam: int = 40                # L (search candidate list)
    alpha: float = 1.2            # RobustPrune slack
    consolidate_every: int = 4096  # deletes between consolidations


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GraphState:
    vectors: jax.Array    # (N, d)
    nbrs: jax.Array       # (N, R) int32, -1 pad
    valid: jax.Array      # (N,) bool (tombstones False)
    ids: jax.Array        # (N,) int32 external ids
    n_used: jax.Array     # () int32
    entry: jax.Array      # () int32 medoid / entry point


def empty_graph(cfg: GraphConfig) -> GraphState:
    return GraphState(
        vectors=jnp.zeros((cfg.max_nodes, cfg.dim), jnp.float32),
        nbrs=jnp.full((cfg.max_nodes, cfg.degree), -1, jnp.int32),
        valid=jnp.zeros((cfg.max_nodes,), bool),
        ids=jnp.full((cfg.max_nodes,), -1, jnp.int32),
        n_used=jnp.zeros((), jnp.int32),
        entry=jnp.zeros((), jnp.int32),
    )


def _dist(a, b):
    d = a - b
    return jnp.sum(d * d, -1)


@functools.partial(jax.jit, static_argnames=("cfg", "iters"))
def beam_search(state: GraphState, cfg: GraphConfig, queries,
                iters: Optional[int] = None):
    """Batched greedy beam search.  Returns (cand_ids (Q, L) node
    indices sorted by distance, cand_dists)."""
    L = cfg.beam
    R = cfg.degree
    if iters is None:
        iters = L

    def one(q):
        cand = jnp.full((L,), -1, jnp.int32).at[0].set(state.entry)
        dist = jnp.full((L,), BIG).at[0].set(
            _dist(q, state.vectors[state.entry]))
        expanded = jnp.zeros((L,), bool)

        def body(_, carry):
            cand, dist, expanded = carry
            # best unexpanded candidate
            score = jnp.where(expanded | (cand < 0), BIG, dist)
            i = jnp.argmin(score)
            has = score[i] < BIG / 2
            expanded = expanded.at[i].set(True)
            node = jnp.maximum(cand[i], 0)
            nb = state.nbrs[node]                       # (R,)
            nb_ok = (nb >= 0) & has
            nbv = state.vectors[jnp.maximum(nb, 0)]
            nd = jnp.where(nb_ok, _dist(q[None], nbv), BIG)
            # skip neighbours already in the list
            dup = (nb[:, None] == cand[None, :]).any(1)
            nd = jnp.where(dup, BIG, nd)
            # merge: keep top-L by distance
            all_c = jnp.concatenate([cand, nb])
            all_d = jnp.concatenate([dist, nd])
            all_e = jnp.concatenate([expanded, jnp.zeros((R,), bool)])
            order = jnp.argsort(all_d)[:L]
            return all_c[order], all_d[order], all_e[order]

        cand, dist, expanded = jax.lax.fori_loop(
            0, iters, body, (cand, dist, expanded))
        return cand, dist

    return jax.vmap(one)(queries.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("cfg", "k"))
def _search_topk(state: GraphState, cfg: GraphConfig, queries, k: int):
    cand, dist = beam_search(state, cfg, queries)
    ok = (cand >= 0) & state.valid[jnp.maximum(cand, 0)]
    dist = jnp.where(ok, dist, BIG)
    order = jnp.argsort(dist, axis=1)[:, :k]
    ids = jnp.take_along_axis(
        state.ids[jnp.maximum(cand, 0)], order, axis=1)
    d = jnp.take_along_axis(dist, order, axis=1)
    return jnp.where(d < BIG / 2, ids, -1), d


def robust_prune(q_vec, cand_idx, cand_dist, vectors, R, alpha):
    """NumPy RobustPrune (host-side insert path)."""
    order = np.argsort(cand_dist)
    chosen: list = []
    for i in order:
        c = int(cand_idx[i])
        if c < 0 or cand_dist[i] >= BIG / 2:
            continue
        if any(c == x for x in chosen):
            continue
        ok = True
        for x in chosen:
            dxc = float(np.sum((vectors[x] - vectors[c]) ** 2))
            if alpha * dxc < cand_dist[i]:
                ok = False
                break
        if ok:
            chosen.append(c)
        if len(chosen) >= R:
            break
    return chosen


class FreshDiskANN:
    """Host-driven streaming graph index (insert path mirrors the
    paper's in-memory index + periodic consolidation)."""

    def __init__(self, cfg: GraphConfig, seed_vectors: np.ndarray,
                 seed_ids: np.ndarray, *, obs=None):
        from ..obs import Obs
        self.cfg = cfg
        self.state = empty_graph(cfg)
        self._host_vec = np.zeros((cfg.max_nodes, cfg.dim), np.float32)
        self._host_nbrs = np.full((cfg.max_nodes, cfg.degree), -1,
                                  np.int32)
        self._id2node: dict = {}
        self._deletes_pending = 0
        # same stats schema as every other engine (tests/test_obs.py);
        # graph-irrelevant keys simply stay 0
        self.obs = obs if obs is not None else Obs()
        self.stats = self.obs.driver_stats()
        if len(seed_vectors):
            self.insert(seed_vectors, seed_ids)

    # -- helpers -----------------------------------------------------------

    def _sync_device(self):
        n = int(self.state.n_used)
        self.state = dataclasses.replace(
            self.state,
            vectors=jnp.asarray(self._host_vec),
            nbrs=jnp.asarray(self._host_nbrs))

    def insert(self, vecs: np.ndarray, ids: np.ndarray,
               _chunk: int = 128) -> UpdateResult:
        """Chunked internally: each sub-batch links against a graph that
        already contains its predecessors (sequential-insert fidelity)."""
        if len(vecs) > _chunk:
            t0 = time.perf_counter()
            n_acc = 0
            for off in range(0, len(vecs), _chunk):
                n_acc += self.insert(vecs[off:off + _chunk],
                                     ids[off:off + _chunk]).accepted
            return UpdateResult(accepted=n_acc,
                                seconds=time.perf_counter() - t0)
        t0 = time.perf_counter()
        vecs = np.asarray(vecs, np.float32)
        ids = np.asarray(ids, np.int64)
        cfg = self.cfg
        # upsert semantics: re-inserting a live external id retires its
        # old node first — otherwise the stale duplicate stays valid
        # forever (deletes only track the newest node per id)
        stale = [self._id2node[int(i)] for i in ids
                 if int(i) in self._id2node]
        if stale:
            self.state = dataclasses.replace(
                self.state,
                valid=self.state.valid.at[jnp.asarray(stale)].set(False))
            self._deletes_pending += len(stale)
        n0 = int(self.state.n_used)
        n_new = len(vecs)
        # batched candidate search against the current graph
        if n0 > 0:
            cand, cd = beam_search(self.state, cfg, jnp.asarray(vecs))
            cand = np.asarray(cand)
            cd = np.asarray(cd)
        else:
            cand = np.full((n_new, cfg.beam), -1, np.int32)
            cd = np.full((n_new, cfg.beam), BIG, np.float32)
        valid_np = np.asarray(self.state.valid)
        new_nodes = np.arange(n0, n0 + n_new)
        self._host_vec[new_nodes] = vecs
        back: dict = defaultdict(list)
        for j, node in enumerate(new_nodes):
            cj = cand[j]
            dj = np.where((cj >= 0) & valid_np[np.maximum(cj, 0)],
                          cd[j], BIG)
            chosen = robust_prune(vecs[j], cj, dj, self._host_vec,
                                  cfg.degree, cfg.alpha)
            self._host_nbrs[node, :len(chosen)] = chosen
            for c in chosen:
                back[c].append(node)
        # back-edges with prune-on-overflow
        for c, incoming in back.items():
            row = [x for x in self._host_nbrs[c] if x >= 0]
            row.extend(incoming)
            if len(row) > cfg.degree:
                dists = np.sum(
                    (self._host_vec[row] - self._host_vec[c]) ** 2, -1)
                chosen = robust_prune(
                    self._host_vec[c], np.array(row), dists,
                    self._host_vec, cfg.degree, cfg.alpha)
                row = chosen
            self._host_nbrs[c, :] = -1
            self._host_nbrs[c, :len(row)] = row[:cfg.degree]
        for j, node in enumerate(new_nodes):
            self._id2node[int(ids[j])] = int(node)
        self.state = dataclasses.replace(
            self.state,
            valid=self.state.valid.at[jnp.asarray(new_nodes)].set(True),
            ids=self.state.ids.at[jnp.asarray(new_nodes)].set(
                jnp.asarray(ids.astype(np.int32))),
            n_used=jnp.asarray(n0 + n_new, jnp.int32))
        self._sync_device()
        if n0 == 0:
            # entry point: medoid of the first batch
            med = int(np.argmin(np.sum(
                (vecs - vecs.mean(0)) ** 2, -1)))
            self.state = dataclasses.replace(
                self.state, entry=jnp.asarray(med, jnp.int32))
        dt = time.perf_counter() - t0
        self.stats["insert_time"] += dt
        self.stats["inserted"] += n_new
        return UpdateResult(accepted=n_new, seconds=dt)

    def delete(self, ids: np.ndarray) -> UpdateResult:
        t0 = time.perf_counter()
        nodes = [self._id2node[i] for i in np.asarray(ids, np.int64)
                 if int(i) in self._id2node]
        if nodes:
            self.state = dataclasses.replace(
                self.state,
                valid=self.state.valid.at[jnp.asarray(nodes)].set(False))
            for i in np.asarray(ids, np.int64):
                self._id2node.pop(int(i), None)
        self._deletes_pending += len(nodes)
        if self._deletes_pending >= self.cfg.consolidate_every:
            self.consolidate()
        dt = time.perf_counter() - t0
        self.stats["delete_time"] += dt
        self.stats["deleted"] += len(nodes)
        return UpdateResult(deleted=len(nodes), seconds=dt)

    def consolidate(self):
        """FreshDiskANN's StreamingMerge analogue: splice tombstoned
        nodes out of neighbour lists (one-hop patch + prune)."""
        valid = np.asarray(self.state.valid)
        n = int(self.state.n_used)
        for u in range(n):
            if not valid[u]:
                continue
            row = self._host_nbrs[u]
            dead = [x for x in row if x >= 0 and not valid[x]]
            if not dead:
                continue
            keep = [x for x in row if x >= 0 and valid[x]]
            # adopt the dead neighbours' live neighbours
            for dnode in dead:
                keep.extend(x for x in self._host_nbrs[dnode]
                            if x >= 0 and valid[x])
            keep = list(dict.fromkeys(keep))[:4 * self.cfg.degree]
            if keep:
                dists = np.sum(
                    (self._host_vec[keep] - self._host_vec[u]) ** 2, -1)
                keep = robust_prune(self._host_vec[u], np.array(keep),
                                    dists, self._host_vec,
                                    self.cfg.degree, self.cfg.alpha)
            self._host_nbrs[u, :] = -1
            self._host_nbrs[u, :len(keep)] = keep
        self._deletes_pending = 0
        self._sync_device()

    def search(self, queries: np.ndarray, k: int) -> SearchResult:
        t0 = time.perf_counter()
        ids, d = _search_topk(self.state, self.cfg,
                              jnp.asarray(queries, jnp.float32), k)
        dt = time.perf_counter() - t0
        self.stats["search_time"] += dt
        self.stats["queries"] += len(queries)
        return SearchResult(ids=np.asarray(ids), scores=np.asarray(d),
                            seconds=dt)

    def tick(self) -> TickReport:
        return TickReport()

    def flush(self, max_ticks: int = 0) -> int:
        self.consolidate()
        return 1

    # ---- StreamingIndex protocol surface ------------------------------

    def snapshot(self) -> GraphState:
        return self.state

    def memory_bytes(self) -> int:
        return int(sum(x.size * x.dtype.itemsize for x in
                       jax.tree_util.tree_leaves(self.state)))

    def memory_tiers(self) -> dict:
        return {"device": self.memory_bytes(), "host": 0}

    def exact(self, queries: np.ndarray, k: int) -> SearchResult:
        """Exact top-k over the live (non-tombstoned) nodes."""
        valid = np.asarray(self.state.valid)
        live = np.flatnonzero(valid)
        q = np.asarray(queries, np.float32)
        if live.size == 0:
            shape = (len(q), k)
            return SearchResult(ids=np.full(shape, -1, np.int32),
                                scores=np.full(shape, BIG, np.float32))
        vecs = np.asarray(self.state.vectors)[live]
        ids = np.asarray(self.state.ids)[live]
        d2 = ((q[:, None, :] - vecs[None]) ** 2).sum(-1)
        order = np.argsort(d2, axis=1)[:, :k]
        found = ids[order]
        scores = np.take_along_axis(d2, order, axis=1)
        if found.shape[1] < k:   # fewer live nodes than k
            padn = k - found.shape[1]
            found = np.pad(found, ((0, 0), (0, padn)), constant_values=-1)
            scores = np.pad(scores, ((0, 0), (0, padn)),
                            constant_values=BIG)
        return SearchResult(ids=found, scores=scores)

    def posting_lengths(self) -> np.ndarray:
        return np.empty((0,), np.int32)

    def live_count(self) -> int:
        return int(np.asarray(self.state.valid).sum())

    def throughput(self) -> dict:
        from .metrics import throughput_from_stats
        return throughput_from_stats(self.stats)
