"""Evaluation metrics (paper Section V-A)."""
from __future__ import annotations

import numpy as np


def recall_at_k(found_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Mean |found ∩ truth| / |truth| over the query batch (recall k@k).

    -1 entries (padding / missing) never count as hits.
    """
    found_ids = np.asarray(found_ids)
    true_ids = np.asarray(true_ids)
    hits = 0
    total = 0
    for f, t in zip(found_ids, true_ids):
        t = set(int(x) for x in t if x >= 0)
        if not t:
            continue
        f = set(int(x) for x in f if x >= 0)
        hits += len(f & t)
        total += len(t)
    return hits / total if total else 1.0


def live_posting_lengths(state) -> np.ndarray:
    """Live lengths of visible postings (posting-CDF statistics) —
    shared by the single-device and sharded drivers so their benchmark
    metrics can never diverge."""
    from .types import STATUS_DELETED
    from .version_manager import unpack_status
    status = np.asarray(unpack_status(state.rec_meta))
    alive = np.asarray(state.allocated) & (status != STATUS_DELETED)
    lens = np.asarray(state.lengths)[alive]
    return lens[lens > 0]


def shard_live_vectors(state, n_shards: int) -> np.ndarray:
    """Live vectors per posting-pool shard (contiguous pid blocks over
    the ``model`` axis).  The occupancy signal behind ``figskew`` and
    the rebalance acceptance ratio — shared by the sharded driver and
    the benchmarks so the spread metric cannot drift."""
    from .types import STATUS_DELETED
    from .version_manager import unpack_status
    status = np.asarray(unpack_status(state.rec_meta))
    alive = np.asarray(state.allocated) & (status != STATUS_DELETED)
    lens = np.where(alive, np.asarray(state.lengths), 0)
    return lens.reshape(n_shards, -1).sum(axis=1)


def occupancy_spread(occ) -> dict:
    """Spread statistics over per-shard occupancy: ``occ_ratio`` is the
    acceptance metric max/min (min clamped to 1 so an empty shard reads
    as a huge, not infinite, ratio); ``occ_spread`` = max/mean is the
    bounded form the regression check pins."""
    occ = np.asarray(occ, float)
    mx, mn, mean = occ.max(), occ.min(), occ.mean()
    return {"occ_min": int(mn), "occ_max": int(mx),
            "occ_ratio": float(mx / max(mn, 1.0)),
            "occ_spread": float(mx / max(mean, 1.0))}


def throughput_from_stats(stats) -> dict:
    """TPS/QPS derived from a driver's counter mapping (shared engine
    formula: updates over insert+delete+background wall time)."""
    upd = stats["insert_time"] + stats["delete_time"] + stats["bg_time"]
    tps = (stats["inserted"] + stats["deleted"]) / upd if upd else 0.0
    qps = (stats["queries"] / stats["search_time"]
           if stats["search_time"] else 0.0)
    return {"tps": tps, "qps": qps, **dict(stats)}


def posting_length_cdf(lengths: np.ndarray, alive: np.ndarray,
                       edges=None) -> tuple:
    """CDF of live posting lengths (paper Fig. 5)."""
    ls = np.sort(np.asarray(lengths)[np.asarray(alive)])
    if edges is None:
        edges = np.arange(0, ls.max() + 2) if len(ls) else np.array([0, 1])
    cdf = np.searchsorted(ls, edges, side="right") / max(len(ls), 1)
    return edges, cdf
