"""Two-phase k-NN search over the UBIS index (paper II-A, IV-B2).

Phase 1 scores every *visible* centroid (Posting Recorder visibility:
allocated, not DELETED, weight <= snapshot version) and keeps the top
``nprobe``.  Phase 2 scans the probed posting tiles (masked by slot
validity) *and the vector cache* — vectors parked during splits/merges
are searchable exactly as the paper requires — then merges a global
top-k.  One jitted program; query batches pad to a fixed size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..kernels.posting_scan import BIG
from . import version_manager as vm
from .types import IndexState, UBISConfig


@functools.partial(jax.jit, static_argnames=("cfg", "k", "nprobe"))
def search(state: IndexState, cfg: UBISConfig, queries: jax.Array,
           k: int, nprobe: int | None = None):
    """Returns (ids (Q,k) int32, scores (Q,k) f32, probe (Q,P) int32).

    Scores follow the kernel convention ``||v||^2 - 2 q.v``; add
    ``||q||^2`` for true squared distances.  ``probe`` feeds SPFresh's
    search-triggered merge rule.
    """
    if nprobe is None:
        nprobe = cfg.nprobe
    Q = queries.shape[0]
    queries = queries.astype(jnp.float32)

    vis = vm.visible(state.rec_meta, state.allocated, state.global_version)
    csc = ops.centroid_score(queries, state.centroids, vis,
                             backend=cfg.use_pallas)          # (Q, M)
    _, probe = jax.lax.top_k(-csc, nprobe)
    probe = probe.astype(jnp.int32)

    if cfg.use_pq:
        # two-stage quant-plane scan: ADC over the probed code tiles
        # (C*m bytes per posting instead of C*d*4), then exact rerank of
        # the top ``rerank_k`` float candidates.  The float path below
        # stays the oracle — use_pq=False is bit-identical to it.
        pscores, pids = _pq_stage(state, cfg, queries, probe, vis)
    else:
        pscores = ops.posting_scan_gather(
            queries, state.vectors, state.slot_valid, vis, probe,
            backend=cfg.use_pallas).reshape(Q, -1)            # (Q, P*C)
        pids = state.ids[probe].reshape(Q, -1)                # (Q, P*C)

    cscores = ops.centroid_score(queries, state.cache_vecs,
                                 state.cache_valid,
                                 backend=cfg.use_pallas)      # (Q, K)
    cids = jnp.broadcast_to(state.cache_ids[None, :],
                            (Q, cfg.cache_capacity))

    all_scores = jnp.concatenate([pscores, cscores], axis=1)
    all_ids = jnp.concatenate([pids, cids], axis=1)
    neg, idx = jax.lax.top_k(-all_scores, k)
    found = jnp.take_along_axis(all_ids, idx, axis=1)
    scores = -neg
    found = jnp.where(scores < BIG / 2, found, -1)  # fewer than k hits
    return found, scores, probe


def _pq_stage(state: IndexState, cfg: UBISConfig, queries: jax.Array,
              probe: jax.Array, vis: jax.Array):
    """ADC scan + candidate gather + exact rerank.

    Returns (scores (Q, R), ids (Q, R)) of the exact-reranked float
    candidates, ready to merge with the cache scan.  R = rerank_k.
    """
    from ..quant import pq
    Q = queries.shape[0]
    M, C, _ = state.vectors.shape
    P = probe.shape[1]
    R = min(cfg.rerank_k, P * C)

    luts = pq.lookup_tables(state.pq_codebooks, queries)     # (Q, V, m, ksub)
    adc = ops.pq_scan_gather(luts, state.codes, state.pq_posting_slot,
                             state.slot_valid, vis, probe,
                             backend=cfg.use_pallas)          # (Q, P, C)
    neg, ridx = jax.lax.top_k(-adc.reshape(Q, -1), R)
    adc_top = -neg
    flat_all = (probe[:, :, None] * C
                + jnp.arange(C, dtype=jnp.int32)[None, None, :])
    cand = jnp.take_along_axis(flat_all.reshape(Q, -1), ridx, axis=1)
    cand_vecs = state.vectors.reshape(M * C, -1)[cand].astype(jnp.float32)
    exact = (jnp.sum(cand_vecs * cand_vecs, -1)
             - 2.0 * jnp.einsum("qd,qrd->qr", queries, cand_vecs))
    # cold-tier plane: candidates in spilled postings have no device
    # float tile (zeroed) — they keep their ADC score and are served
    # codes-only; the driver may exact-rerank them host-side from the
    # pinned pool.  All-False mask when tiering is off (bit-identical).
    exact = jnp.where(state.tier_spilled[cand // C], adc_top, exact)
    exact = jnp.where(adc_top < BIG / 2, exact, BIG)
    cand_ids = state.ids.reshape(-1)[cand]
    cand_ids = jnp.where(adc_top < BIG / 2, cand_ids, -1)
    return exact, cand_ids


@functools.partial(jax.jit, static_argnames=("cfg", "k"))
def brute_force(state: IndexState, cfg: UBISConfig, queries: jax.Array,
                k: int):
    """Exact top-k over the index's live contents (ground truth for
    recall).  Scans every posting slot + the cache with full masking.

    Spilled postings are excluded (their device tiles are zeroed); the
    tiered drivers merge a host-side scan of the pinned pool on top
    (``tier.host_exact_candidates``), so their ``exact()`` stays a true
    oracle.  All-False mask when tiering is off."""
    M, C, d = state.vectors.shape
    queries = queries.astype(jnp.float32)
    vis = vm.visible(state.rec_meta, state.allocated, state.global_version)
    valid = state.slot_valid & (vis & ~state.tier_spilled)[:, None]
    s = ops.posting_scan(queries, state.vectors, valid,
                         backend=cfg.use_pallas)              # (Q, M*C)
    cs = ops.centroid_score(queries, state.cache_vecs, state.cache_valid,
                            backend=cfg.use_pallas)
    all_scores = jnp.concatenate([s, cs], axis=1)
    flat = jnp.concatenate([state.ids.reshape(-1), state.cache_ids])
    # broadcast, don't materialize Q copies of the (M*C + K) id row
    flat_ids = jnp.broadcast_to(flat[None, :],
                                (queries.shape[0], flat.shape[0]))
    neg, idx = jax.lax.top_k(-all_scores, k)
    found = jnp.take_along_axis(flat_ids, idx, axis=1)
    return jnp.where(-neg < BIG / 2, found, -1), -neg
