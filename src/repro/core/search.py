"""Two-phase k-NN search over the UBIS index (paper II-A, IV-B2).

Phase 1 scores every *visible* centroid (Posting Recorder visibility:
allocated, not DELETED, weight <= snapshot version) and keeps the top
``nprobe``.  Phase 2 scans the probed posting tiles (masked by slot
validity) *and the vector cache* — vectors parked during splits/merges
are searchable exactly as the paper requires — then merges a global
top-k.  One jitted program; query batches pad to a fixed size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..kernels.posting_scan import BIG
from . import version_manager as vm
from .types import IndexState, UBISConfig


@functools.partial(jax.jit, static_argnames=("cfg", "k", "nprobe"))
def search(state: IndexState, cfg: UBISConfig, queries: jax.Array,
           k: int, nprobe: int | None = None):
    """Returns (ids (Q,k) int32, scores (Q,k) f32, probe (Q,P) int32).

    Scores follow the kernel convention ``||v||^2 - 2 q.v``; add
    ``||q||^2`` for true squared distances.  ``probe`` feeds SPFresh's
    search-triggered merge rule.
    """
    if nprobe is None:
        nprobe = cfg.nprobe
    Q = queries.shape[0]
    queries = queries.astype(jnp.float32)

    vis = vm.visible(state.rec_meta, state.allocated, state.global_version)
    # fused phase 1: centroid scores + running top-nprobe in one kernel
    # (no (Q, M) score matrix on the pallas path)
    _, probe = ops.centroid_topk(queries, state.centroids, vis, k=nprobe,
                                 backend=cfg.use_pallas)
    probe = probe.astype(jnp.int32)

    if cfg.use_pq:
        # two-stage quant-plane scan: ADC over the probed code tiles
        # (C*m bytes per posting instead of C*d*4), then exact rerank of
        # the top ``rerank_k`` float candidates.  The float path below
        # stays the oracle — use_pq=False is bit-identical to it.
        pscores, pids = _pq_stage(state, cfg, queries, probe, vis, k)
    else:
        C = state.vectors.shape[1]
        kf = min(k, probe.shape[1] * C)
        pscores, cand = ops.posting_scan_topk(
            queries, state.vectors, state.slot_valid, vis, probe, k=kf,
            backend=cfg.use_pallas)                           # (Q, kf)
        pids = state.ids.reshape(-1)[cand]

    kc = min(k, cfg.cache_capacity)
    cscores, cpos = ops.centroid_topk(queries, state.cache_vecs,
                                      state.cache_valid, k=kc,
                                      backend=cfg.use_pallas)  # (Q, kc)
    cids = state.cache_ids[cpos]

    # final merge over the two already-selected candidate lists (kf + kc
    # entries, not P*C + cache_capacity): both lists preserve the
    # position-major tie order of the unfused full-matrix top_k, so the
    # merged result is bit-identical to it.
    all_scores = jnp.concatenate([pscores, cscores], axis=1)
    all_ids = jnp.concatenate([pids, cids], axis=1)
    neg, idx = jax.lax.top_k(-all_scores, k)
    found = jnp.take_along_axis(all_ids, idx, axis=1)
    scores = -neg
    found = jnp.where(scores < BIG / 2, found, -1)  # fewer than k hits
    return found, scores, probe


def _pq_stage(state: IndexState, cfg: UBISConfig, queries: jax.Array,
              probe: jax.Array, vis: jax.Array, k: int):
    """ADC scan + fused exact rerank.

    Returns (scores (Q, kk), ids (Q, kk)) of the exact-reranked float
    candidates, kk = min(k, rerank_k-capped R), ready to merge with the
    cache scan.  Selecting the top kk here (instead of handing all R
    candidates to the final merge) is bit-identical: the merge keeps at
    most k entries from this list, and top-k-of-top-k preserves both the
    multiset and the tie order of the one-shot selection.
    """
    from ..quant import pq
    M, C, _ = state.vectors.shape
    P = probe.shape[1]
    R = min(cfg.rerank_k, P * C)

    luts = pq.lookup_tables(state.pq_codebooks, queries)     # (Q, V, m, ksub)
    # fused ADC scan + on-chip top-R: the (Q, P, C) ADC score tensor is
    # never materialized on the pallas path — the kernel streams probed
    # code tiles and returns the R best (score, flat-slot) pairs
    adc_top, cand = ops.pq_scan_topk(
        luts, state.codes, state.pq_posting_slot, state.slot_valid, vis,
        probe, k=R, backend=cfg.use_pallas)                   # (Q, R)
    # fused rerank: candidate gather + ``||v||^2 - 2 q.v`` + the
    # cold-tier ADC passthrough (spilled postings have no device float
    # tile — they are served codes-only; the driver may exact-rerank
    # them host-side from the pinned pool) + final top-kk, one kernel —
    # the (Q, R, d) candidate gather never hits HBM on the pallas path
    kk = min(k, R)
    exact, cand_sel = ops.rerank_topk(
        queries, state.vectors, state.tier_spilled, cand, adc_top,
        k=kk, backend=cfg.use_pallas)                         # (Q, kk)
    cand_ids = jnp.where(exact < BIG / 2,
                         state.ids.reshape(-1)[cand_sel], -1)
    return exact, cand_ids


@functools.partial(jax.jit, static_argnames=("cfg", "k"))
def brute_force(state: IndexState, cfg: UBISConfig, queries: jax.Array,
                k: int):
    """Exact top-k over the index's live contents (ground truth for
    recall).  Scans every posting slot + the cache with full masking.

    Spilled postings are excluded (their device tiles are zeroed); the
    tiered drivers merge a host-side scan of the pinned pool on top
    (``tier.host_exact_candidates``), so their ``exact()`` stays a true
    oracle.  All-False mask when tiering is off."""
    M, C, d = state.vectors.shape
    queries = queries.astype(jnp.float32)
    vis = vm.visible(state.rec_meta, state.allocated, state.global_version)
    valid = state.slot_valid & (vis & ~state.tier_spilled)[:, None]
    s = ops.posting_scan(queries, state.vectors, valid,
                         backend=cfg.use_pallas)              # (Q, M*C)
    cs = ops.centroid_score(queries, state.cache_vecs, state.cache_valid,
                            backend=cfg.use_pallas)
    all_scores = jnp.concatenate([s, cs], axis=1)
    flat = jnp.concatenate([state.ids.reshape(-1), state.cache_ids])
    # broadcast, don't materialize Q copies of the (M*C + K) id row
    flat_ids = jnp.broadcast_to(flat[None, :],
                                (queries.shape[0], flat.shape[0]))
    neg, idx = jax.lax.top_k(-all_scores, k)
    found = jnp.take_along_axis(flat_ids, idx, axis=1)
    return jnp.where(-neg < BIG / 2, found, -1), -neg
