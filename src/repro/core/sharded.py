"""Distributed UBIS: the index sharded over a TPU pod (beyond-paper).

The paper's conclusion lists distributed update as future work; here it
is a first-class feature.  Layout: the posting pool (M postings) shards
over the ``model`` mesh axis; query/job batches shard over ``data``
(× ``pod``).  One shard owns each posting, so *structural* updates
(split/merge/compact/GC) stay shard-local and embarrassingly parallel —
the Posting Recorder's one-winner-per-word rule needs no cross-shard
coordination.  Only two operations communicate:

  * search  — per-shard phase-1 top-nprobe, all-gather the (score, id)
              candidates, global re-rank, per-shard phase-2 scan of the
              postings it owns, all-gather per-shard top-k, final merge;
  * insert  — per-shard locate (scores vs. local centroids), global
              argmin over the gathered per-shard bests routes each job
              to its owner shard, which applies the conflict-free append.

Collective cost per search batch: 2 all-gathers of O(Q·(nprobe + k))
scalars over the model axis — independent of M and dim, which is what
makes the index scale to thousands of chips (§Roofline has the terms).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.sharding import axis_size as _axis_size, shard_map
from ..kernels import ops, ref
from ..kernels.posting_scan import BIG
from . import balance, version_manager as vm
from .types import NO_SUCC, IndexState, UBISConfig
from .update import (_flat_set, dataclasses_replace, oob,
                     rebuild_free_stack)


def index_specs(cfg: UBISConfig):
    """PartitionSpecs for every IndexState field (postings over 'model').

    The id->location map and the vector cache are replicated: the cache
    is small and hot (every search scans it); id_loc updates are
    broadcast with the round's results.
    """
    return IndexState(
        vectors=P("model"), ids=P("model"), slot_valid=P("model"),
        used=P("model"), lengths=P("model"), centroids=P("model"),
        rec_meta=P("model"), rec_succ=P("model"), allocated=P("model"),
        nbrs=P("model"),
        cache_vecs=P(), cache_ids=P(), cache_target=P(), cache_valid=P(),
        free_list=P("model"), free_top=P(), global_version=P(),
        id_loc=P(),
        # quant plane: codes follow their posting's shard; the (small)
        # versioned codebooks are replicated so any shard can encode
        codes=P("model"), pq_codebooks=P(), pq_slot_gen=P(),
        pq_active=P(), pq_posting_slot=P("model"),
    )


def _local_topk(scores, ids, k):
    neg, idx = jax.lax.top_k(-scores, k)
    return -neg, jnp.take_along_axis(ids, idx, axis=-1)


def _rebase_succ(rec_succ, offset, limit):
    """Shift stored successor pids by ``offset``; anything landing
    outside [0, limit) becomes no-successor."""
    s1, s2 = vm.succ_ids(rec_succ)

    def shift(s):
        t = jnp.where(s >= 0, s + offset, -1)
        return jnp.where((t >= 0) & (t < limit), t, -1)

    t1, t2 = shift(s1), shift(s2)
    return vm.pack_succ(jnp.where(t1 < 0, NO_SUCC, t1),
                        jnp.where(t2 < 0, NO_SUCC, t2))


def _pq_phase2(state: IndexState, cfg: UBISConfig, queries, probe, mine,
               vis, k: int):
    """Sharded search phase 2 served from PQ codes (``cfg.use_pq``).

    Per shard: ADC-scan the owned probed tiles' codes (``C * m`` bytes
    per posting instead of ``C * d * 4``), then gather the local top
    ``cfg.rerank_k`` candidates' float vectors for an exact rerank —
    the shard-local form of ``search._pq_stage``.  The (small) versioned
    codebooks are replicated, so every shard builds the same per-query
    lookup tables.  Returns this shard's (scores, ids) candidate lists,
    ready for the existing merge all-gather.
    """
    from ..quant import pq
    Q = queries.shape[0]
    M_local, C, d = state.vectors.shape
    R = min(cfg.rerank_k, probe.shape[1] * C)
    luts = pq.lookup_tables(state.pq_codebooks, queries)  # (Q, V, m, ksub)
    adc = ops.pq_scan_gather(luts, state.codes, state.pq_posting_slot,
                             state.slot_valid, vis, probe,
                             backend=cfg.use_pallas)       # (Q, P, C)
    adc = jnp.where(mine[..., None], adc, BIG)
    neg, ridx = jax.lax.top_k(-adc.reshape(Q, -1), R)
    adc_top = -neg
    flat_all = (probe[:, :, None] * C
                + jnp.arange(C, dtype=jnp.int32)[None, None, :])
    cand = jnp.take_along_axis(flat_all.reshape(Q, -1), ridx, axis=1)
    cand_vecs = state.vectors.reshape(M_local * C, d)[cand].astype(
        jnp.float32)
    exact = (jnp.sum(cand_vecs * cand_vecs, -1)
             - 2.0 * jnp.einsum("qd,qrd->qr", queries, cand_vecs))
    exact = jnp.where(adc_top < BIG / 2, exact, BIG)
    cand_ids = jnp.where(adc_top < BIG / 2,
                         state.ids.reshape(-1)[cand], -1)
    return _local_topk(exact, cand_ids, min(k, R))


def make_sharded_search(cfg: UBISConfig, mesh: Mesh, k: int,
                        nprobe: int | None = None,
                        shard_cache_scan: bool = True):
    """Builds a jitted sharded search: (state, queries) -> (ids, scores).

    queries shard over the data axes; the index shards over 'model'.
    ``shard_cache_scan``: each model shard scans only its 1/S slice of
    the (replicated) vector cache and the merge all-gather already in
    flight combines the partial top-ks — S-fold less cache compute for
    zero extra collective traffic (EXPERIMENTS.md §Perf, ubis-index).
    """
    if nprobe is None:
        nprobe = cfg.nprobe
    axes = mesh.axis_names
    qspec = P(("pod", "data") if "pod" in axes else "data")
    st_specs = index_specs(cfg)
    probe_cap = getattr(cfg, "shard_probe_cap", 0)

    def local(state: IndexState, queries):
        n_shard = _axis_size("model")
        my = jax.lax.axis_index("model")
        M_local = state.centroids.shape[0]
        Q = queries.shape[0]
        queries = queries.astype(jnp.float32)

        vis = vm.visible(state.rec_meta, state.allocated,
                         state.global_version)
        sc = ref.centroid_score(queries, state.centroids)
        sc = jnp.where(vis[None, :], sc, BIG)
        # phase 1 local: per-shard top-nprobe candidates
        p_local = min(nprobe, M_local)
        s1, local_pid = _local_topk(
            sc, jnp.broadcast_to(jnp.arange(M_local), sc.shape), p_local)
        # global re-rank of gathered candidates
        s1_all = jax.lax.all_gather(s1, "model", axis=1, tiled=True)
        pid_all = jax.lax.all_gather(
            local_pid + my * 0, "model", axis=1, tiled=True)
        owner = jnp.repeat(jnp.arange(n_shard), p_local)[None, :]
        owner = jnp.broadcast_to(owner, s1_all.shape)
        s_sel, sel_idx = jax.lax.top_k(-s1_all, nprobe)
        probe_owner = jnp.take_along_axis(owner, sel_idx, axis=1)
        probe_pid = jnp.take_along_axis(pid_all, sel_idx, axis=1)
        # phase 2: scan the selected postings THIS shard owns.  A query's
        # nprobe probes spread ~uniformly over S shards (~nprobe/S each),
        # so the scan is COMPACTED to the first `probe_cap` owned probes
        # (phase-1 order = best-first): the gather and distance scan
        # shrink by nprobe/probe_cap with negligible recall impact
        # (only hurts when > probe_cap probes land on one shard).
        mine = probe_owner == my
        cap = probe_cap if probe_cap else nprobe
        if cap < nprobe:
            order = jnp.argsort(~mine, axis=1, stable=True)[:, :cap]
            pid_cap = jnp.take_along_axis(probe_pid, order, axis=1)
            mine_cap = jnp.take_along_axis(mine, order, axis=1)
        else:
            pid_cap, mine_cap = probe_pid, mine
        safe_pid = jnp.where(mine_cap, pid_cap, 0)
        if cfg.use_pq:
            # quant plane: serve phase 2 from the owned probes' CODES
            # (ADC scan + per-shard exact rerank) instead of the float
            # tiles — the sharded form of ``search._pq_stage``
            s2, i2 = _pq_phase2(state, cfg, queries, safe_pid, mine_cap,
                                vis, k)
        else:
            scores2 = ref.posting_scan_gather(
                queries, state.vectors, state.slot_valid, vis, safe_pid)
            scores2 = jnp.where(mine_cap[..., None], scores2, BIG)
            ids2 = state.ids[safe_pid]
            k_local = min(k, scores2.shape[1] * scores2.shape[2])
            s2, i2 = _local_topk(scores2.reshape(Q, -1),
                                 ids2.reshape(Q, -1), k_local)
        # cache scan: each shard takes a 1/S slice of the replicated
        # cache (or shard 0 scans everything when disabled)
        if shard_cache_scan:
            K_all = state.cache_vecs.shape[0]
            Ks = -(-K_all // n_shard)
            start = jnp.minimum(my * Ks, K_all - Ks)
            cvs = jax.lax.dynamic_slice_in_dim(state.cache_vecs, start,
                                               Ks, axis=0)
            cval = jax.lax.dynamic_slice_in_dim(state.cache_valid, start,
                                                Ks, axis=0)
            cid = jax.lax.dynamic_slice_in_dim(state.cache_ids, start,
                                               Ks, axis=0)
            # overlap rows (from the clamp) deduplicate in the final
            # top-k merge only if scores tie; mask non-owned overlap:
            own = (jnp.arange(Ks) + start) >= my * Ks
            csc = ref.centroid_score(queries, cvs)
            csc = jnp.where((cval & own)[None, :], csc, BIG)
            ck = min(k, csc.shape[1])
            s3, i3 = _local_topk(csc, jnp.broadcast_to(
                cid[None, :], csc.shape), ck)
        else:
            csc = ref.centroid_score(queries, state.cache_vecs)
            csc = jnp.where(state.cache_valid[None, :] & (my == 0), csc,
                            BIG)
            ck = min(k, csc.shape[1])
            s3, i3 = _local_topk(csc, jnp.broadcast_to(
                state.cache_ids[None, :], csc.shape), ck)
        s2 = jnp.concatenate([s2, s3], axis=1)
        i2 = jnp.concatenate([i2, i3], axis=1)
        # global merge
        s2_all = jax.lax.all_gather(s2, "model", axis=1, tiled=True)
        i2_all = jax.lax.all_gather(i2, "model", axis=1, tiled=True)
        sf, idf = _local_topk(s2_all, i2_all, k)
        found = jnp.where(sf < BIG / 2, idf, -1)
        return found, sf

    in_specs = (st_specs, qspec)
    fn = shard_map(local, mesh, in_specs, (qspec, qspec))
    return jax.jit(fn)


def make_sharded_insert(cfg: UBISConfig, mesh: Mesh):
    """Builds a jitted sharded insert round:
    (state, vecs, ids, valid) -> (state, accepted (J,) bool).

    Each shard locates jobs against its local centroids; a global argmin
    routes each job to its owner shard, which runs the conflict-free
    batched append on its local state.  Blocked jobs (non-NORMAL status)
    are *rejected* here — the vector cache is host-mediated in
    ``ShardedUBISDriver`` (replicated cache writes would race), which is
    why the per-job accepted mask (not a count) comes back: the driver
    owns the retry/park decision for every rejected lane.
    """
    jspec = P()     # jobs replicated: every shard sees all jobs
    st_specs = index_specs(cfg)

    def local(state: IndexState, vecs, ids, valid):
        import dataclasses as _dc
        from .update import batched_append
        my = jax.lax.axis_index("model")
        M_local = state.centroids.shape[0]
        status = vm.unpack_status(state.rec_meta)
        insertable = state.allocated & (status == 0)
        sc = ref.centroid_score(vecs.astype(jnp.float32), state.centroids)
        sc = jnp.where(insertable[None, :], sc, BIG)
        best_local = jnp.min(sc, axis=1)
        best_pid = jnp.argmin(sc, axis=1).astype(jnp.int32)
        # global owner = argmin over shards
        all_best = jax.lax.all_gather(best_local, "model", axis=0)  # (S, J)
        owner = jnp.argmin(all_best, axis=0).astype(jnp.int32)
        mine = valid & (owner == my) & (best_local < BIG / 2)
        state, ok, flat_local = batched_append(
            state, cfg, vecs, ids, jnp.where(mine, best_pid, -1), mine,
            update_id_loc=False)
        # id_loc is REPLICATED across model shards: merge the per-job
        # global flat locations (exactly one shard wins each job, so a
        # psum of one-hot contributions keeps the replicas identical).
        won = mine & ok
        flat_global = jnp.where(won, my * (M_local * cfg.capacity)
                                + flat_local, 0)
        flat_global = jax.lax.psum(flat_global, "model")
        any_won = jax.lax.psum(won.astype(jnp.int32), "model") > 0
        safe_ids = jnp.where(valid & any_won, ids, cfg.max_ids)
        id_loc = state.id_loc.at[safe_ids].set(
            flat_global.astype(jnp.int32), mode="drop")
        state = _dc.replace(
            state, id_loc=id_loc,
            global_version=state.global_version + jnp.uint32(1))
        return state, valid & any_won

    fn = shard_map(local, mesh, (st_specs, jspec, jspec, jspec),
                   (st_specs, P()))
    return jax.jit(fn, donate_argnums=(0,))


def make_sharded_delete(cfg: UBISConfig, mesh: Mesh):
    """Builds a jitted sharded delete round:
    (state, del_ids, valid) -> (state, done (J,) bool).

    Locations come from the replicated ``id_loc`` map, so routing is
    free: the owner shard (flat location // local pool span) tombstones
    its tiles and decrements its lengths; the cache and ``id_loc``
    updates are computed identically on every shard from replicated
    inputs, so the replicas stay in sync with zero collectives.
    UBIS semantics only — the SPFresh lock model lives in the
    single-device ``delete_round``.
    """
    jspec = P()
    st_specs = index_specs(cfg)
    C = cfg.capacity

    def local(state: IndexState, del_ids, valid):
        my = jax.lax.axis_index("model")
        M_local = state.lengths.shape[0]
        span = M_local * C
        base = my.astype(jnp.int32) * span
        safe = jnp.clip(del_ids, 0, cfg.max_ids - 1)
        loc = state.id_loc[safe]
        first = vm.first_occurrence_mask(safe) & valid
        in_post = first & (loc >= 0)
        in_cache = first & (loc <= -2)
        # owner shard writes its tiles; other shards' lanes are masked
        lloc = loc - base
        mine = in_post & (lloc >= 0) & (lloc < span)
        flat = oob(lloc, mine, span)
        slot_valid = _flat_set(state.slot_valid, flat,
                               jnp.zeros(loc.shape, jnp.bool_))
        pid = oob(lloc // C, mine, M_local)
        lengths = state.lengths.at[pid].add(-1, mode="drop")
        # cache + id_loc are replicated: identical update on every shard
        cslot = oob(-2 - loc, in_cache, cfg.cache_capacity)
        cache_valid = state.cache_valid.at[cslot].set(False, mode="drop")
        done = in_post | in_cache
        id_loc = state.id_loc.at[oob(safe, done, cfg.max_ids)].set(
            -1, mode="drop")
        state = dataclasses_replace(
            state, slot_valid=slot_valid, lengths=lengths,
            cache_valid=cache_valid, id_loc=id_loc,
            global_version=state.global_version + jnp.uint32(1))
        return state, done

    fn = shard_map(local, mesh, (st_specs, jspec, jspec), (st_specs, P()))
    return jax.jit(fn, donate_argnums=(0,))


def make_sharded_background(cfg: UBISConfig, mesh: Mesh,
                            bg_ops: int = 8, reassign: bool = True,
                            gc_k: int = 64):
    """Builds a jitted sharded background tick:
    (state, gc_min_version) -> (state, executed, reclaimed).

    The SAME ``balance.background_round`` program runs on every model
    shard over the postings it owns — structural work is shard-local, so
    the whole pod's split/merge/compact batch is one collective-free
    device call.  Per shard: detect -> pick top ``bg_ops`` candidates ->
    mark -> execute, all on device.  Two shard-specific adaptations:

      * the global free stack is meaningless per shard (its slices hold
        arbitrary global ids), so each shard derives a local free view
        from ``allocated`` on entry and the state returns with an EMPTY
        (fail-safe) stack — gather + ``update.rebuild_free_stack`` before
        single-device use;
      * ``id_loc`` is replicated, so each shard's (local-flat) rewrites
        are rebased by its pool offset and merged with one psum — every
        id is owned by exactly one shard, so contributions never collide;
      * successor pointers (``rec_succ``) are stored global, used local:
        localized on entry (cross-shard successors dead-end, the safe
        fallback) and rebased back to global pids on exit.

    The vector cache is replicated and therefore unwritable per shard:
    the round runs with ``use_cache=False`` (small-side spills fold back
    into child ``a`` instead — nothing is dropped).

    Epoch GC rides in the same program: after the structural batch, each
    shard reclaims up to ``gc_k`` of its own retired postings older than
    ``gc_min_version`` (pass 0 to skip).  Structural ownership makes
    this collective-free too; the per-shard successor sweep covers every
    reference the sharded rounds themselves can create (they only link
    same-shard successors).
    """
    st_specs = index_specs(cfg)
    C = cfg.capacity

    def local(state: IndexState, gc_min_version):
        my = jax.lax.axis_index("model")
        M_local = state.allocated.shape[0]
        base_pid = my.astype(jnp.int32) * M_local
        # local free view: unallocated local pids, stack top at the end
        state = rebuild_free_stack(state)
        # successor pointers are stored as GLOBAL pids; the local round
        # reads/writes local ones.  Localize on entry (cross-shard
        # successors become -1: the round treats them as absent, the
        # designed-safe dead-end) and on exit rebase only the words the
        # round actually rewrote — untouched postings keep their
        # original global words, cross-shard pointers included.
        old_succ_global = state.rec_succ
        succ_local0 = _rebase_succ(old_succ_global, -base_pid, M_local)
        state = dataclasses_replace(state, rec_succ=succ_local0)
        old_id_loc = state.id_loc

        kinds, pids = balance.select_candidates(state, cfg, bg_ops)
        # mark + execute in one program: atomic within this device call,
        # so the two-phase window collapses without a race window
        state = dataclasses_replace(
            state, rec_meta=balance.mark_selected(state.rec_meta, kinds,
                                                  pids))
        state, rr = balance.background_round(
            state, cfg, kinds, pids, reassign=reassign, use_cache=False)
        # epoch GC on the shard's own retired postings, same device call
        state, n_gc = balance.gc_round(state, cfg, gc_min_version, gc_k)

        # merge the replicated id map: rebase local tile flats to global
        base = my.astype(jnp.int32) * (M_local * C)
        changed = state.id_loc != old_id_loc
        rebased = jnp.where(changed & (state.id_loc >= 0),
                            state.id_loc + base, state.id_loc)
        delta = jnp.where(changed, rebased - old_id_loc, 0)
        id_loc = old_id_loc + jax.lax.psum(delta, "model")
        # free stack on exit: per-shard local views cannot form one
        # canonical global stack, so return it fail-safe EMPTY — any
        # consumer that pops from it gets nothing instead of an aliased
        # live posting.  Each bg call re-derives its local view from
        # ``allocated``; a gathered single-device state must pass
        # through update.ensure_free_stack (the ShardedUBISDriver
        # snapshot path enforces this) before driver/alloc/GC use.
        succ_changed = state.rec_succ != succ_local0
        rec_succ = jnp.where(
            succ_changed,
            _rebase_succ(state.rec_succ, base_pid, cfg.max_postings),
            old_succ_global)
        state = dataclasses_replace(
            state, id_loc=id_loc, free_top=jnp.int32(0), rec_succ=rec_succ,
            global_version=jax.lax.pmax(state.global_version, "model"))
        executed = jax.lax.psum(rr.executed, "model")
        reclaimed = jax.lax.psum(jnp.asarray(n_gc, jnp.int32), "model")
        return state, executed, reclaimed

    fn = shard_map(local, mesh, (st_specs, P()), (st_specs, P(), P()))
    return jax.jit(fn)
