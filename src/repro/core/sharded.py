"""Distributed UBIS: the index sharded over a TPU pod (beyond-paper).

The paper's conclusion lists distributed update as future work; here it
is a first-class feature.  Layout: the posting pool (M postings) shards
over the ``model`` mesh axis; query/job batches shard over ``data``
(× ``pod``).  One shard owns each posting, so *structural* updates
(split/merge/compact/GC) stay shard-local and embarrassingly parallel —
the Posting Recorder's one-winner-per-word rule needs no cross-shard
coordination.  Only two operations communicate:

  * search  — per-shard phase-1 top-nprobe, all-gather the (score, id)
              candidates, global re-rank, per-shard phase-2 scan of the
              postings it owns, all-gather per-shard top-k, final merge;
  * insert  — per-shard locate (scores vs. local centroids), global
              argmin over the gathered per-shard bests routes each job
              to its owner shard, which applies the conflict-free append.

Collective cost per search batch: 2 all-gathers of O(Q·(nprobe + k))
scalars over the model axis — independent of M and dim, which is what
makes the index scale to thousands of chips (§Roofline has the terms).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.sharding import axis_size as _axis_size, shard_map
from ..kernels import ops, ref
from ..kernels.posting_scan import BIG
from . import balance, version_manager as vm
from .types import (NO_SUCC, STATUS_DELETED, STATUS_NORMAL, IndexState,
                    UBISConfig)
from .update import (apply_tombstones, dataclasses_replace, oob,
                     rebuild_free_stack)


def index_specs(cfg: UBISConfig):
    """PartitionSpecs for every IndexState field (postings over 'model').

    The id->location map and the vector cache are replicated: the cache
    is small and hot (every search scans it); id_loc updates are
    broadcast with the round's results.
    """
    return IndexState(
        vectors=P("model"), ids=P("model"), slot_valid=P("model"),
        used=P("model"), lengths=P("model"), centroids=P("model"),
        rec_meta=P("model"), rec_succ=P("model"), allocated=P("model"),
        nbrs=P("model"),
        cache_vecs=P(), cache_ids=P(), cache_target=P(), cache_valid=P(),
        free_list=P("model"), free_top=P(), global_version=P(),
        id_loc=P(),
        # quant plane: codes follow their posting's shard; the (small)
        # versioned codebooks are replicated so any shard can encode
        codes=P("model"), pq_codebooks=P(), pq_slot_gen=P(),
        pq_active=P(), pq_posting_slot=P("model"),
        # cold-tier plane: heat + residency flags follow their posting
        heat=P("model"), tier_spilled=P("model"),
    )


def _local_topk(scores, ids, k):
    neg, idx = jax.lax.top_k(-scores, k)
    return -neg, jnp.take_along_axis(ids, idx, axis=-1)


def _owned_cache_slice(state: IndexState, my, n_shard):
    """This shard's 1/S slice of the replicated vector cache:
    (vecs, valid, ids) with the clamp-overlap rows masked OUT of
    ``valid``.  Ceil-div slices of a non-divisible capacity overlap at
    the end of the pool (the ``start`` clamp); the ownership mask keeps
    every cache slot scanned by exactly one shard, so the merge
    all-gather can never double-count an entry.  Shared by the sharded
    search and the ``make_sharded_exact`` oracle — the two scans must
    agree on this discipline or recall metrics lie."""
    K_all = state.cache_vecs.shape[0]
    Ks = -(-K_all // n_shard)
    start = jnp.minimum(my * Ks, K_all - Ks)
    cvs = jax.lax.dynamic_slice_in_dim(state.cache_vecs, start, Ks, 0)
    cval = jax.lax.dynamic_slice_in_dim(state.cache_valid, start, Ks, 0)
    cid = jax.lax.dynamic_slice_in_dim(state.cache_ids, start, Ks, 0)
    own = (jnp.arange(Ks) + start) >= my * Ks
    return cvs, cval & own, cid


def _rebase_succ(rec_succ, offset, limit):
    """Shift stored successor pids by ``offset``; anything landing
    outside [0, limit) becomes no-successor."""
    s1, s2 = vm.succ_ids(rec_succ)

    def shift(s):
        t = jnp.where(s >= 0, s + offset, -1)
        return jnp.where((t >= 0) & (t < limit), t, -1)

    t1, t2 = shift(s1), shift(s2)
    return vm.pack_succ(jnp.where(t1 < 0, NO_SUCC, t1),
                        jnp.where(t2 < 0, NO_SUCC, t2))


def _pq_phase2(state: IndexState, cfg: UBISConfig, queries, probe, mine,
               vis, k: int):
    """Sharded search phase 2 served from PQ codes (``cfg.use_pq``).

    Per shard: ADC-scan the owned probed tiles' codes (``C * m`` bytes
    per posting instead of ``C * d * 4``), then fused-rerank the local
    top ``cfg.rerank_k`` candidates against their float rows — the
    shard-local form of ``search._pq_stage``.  The (small) versioned
    codebooks are replicated, so every shard builds the same per-query
    lookup tables.  Returns this shard's (scores, ids) candidate lists,
    ready for the existing merge all-gather.
    """
    from ..quant import pq
    M_local, C, d = state.vectors.shape
    R = min(cfg.rerank_k, probe.shape[1] * C)
    luts = pq.lookup_tables(state.pq_codebooks, queries)  # (Q, V, m, ksub)
    # fused ADC scan + on-chip top-R with the ownership mask applied
    # in-kernel — no (Q, P, C) score tensor on the pallas path
    adc_top, cand = ops.pq_scan_topk(
        luts, state.codes, state.pq_posting_slot, state.slot_valid, vis,
        probe, k=R, qp_ok=mine, backend=cfg.use_pallas)    # (Q, R)
    # fused rerank: gather + exact rescore + cold-tier ADC passthrough
    # (spilled postings have no device float tile — codes-only serving;
    # the driver's optional host rerank refines them from the pinned
    # pool) + local top-k, one kernel — no (Q, R, d) gather in HBM
    exact, cand_sel = ops.rerank_topk(
        queries, state.vectors, state.tier_spilled, cand, adc_top,
        k=min(k, R), backend=cfg.use_pallas)
    cand_ids = jnp.where(exact < BIG / 2,
                         state.ids.reshape(-1)[cand_sel], -1)
    return exact, cand_ids


def make_sharded_search(cfg: UBISConfig, mesh: Mesh, k: int,
                        nprobe: int | None = None,
                        shard_cache_scan: bool = True):
    """Builds a jitted sharded search: (state, queries) -> (ids, scores).

    queries shard over the data axes; the index shards over 'model'.
    ``shard_cache_scan``: each model shard scans only its 1/S slice of
    the (replicated) vector cache and the merge all-gather already in
    flight combines the partial top-ks — S-fold less cache compute for
    zero extra collective traffic (EXPERIMENTS.md §Perf, ubis-index).
    """
    if nprobe is None:
        nprobe = cfg.nprobe
    axes = mesh.axis_names
    qspec = P(("pod", "data") if "pod" in axes else "data")
    st_specs = index_specs(cfg)
    probe_cap = getattr(cfg, "shard_probe_cap", 0)

    def local(state: IndexState, queries):
        n_shard = _axis_size("model")
        my = jax.lax.axis_index("model")
        M_local = state.centroids.shape[0]
        Q = queries.shape[0]
        queries = queries.astype(jnp.float32)

        vis = vm.visible(state.rec_meta, state.allocated,
                         state.global_version)
        # phase 1 local: fused centroid score + per-shard top-nprobe
        # (no (Q, M_local) score matrix on the pallas path)
        p_local = min(nprobe, M_local)
        s1, local_pid = ops.centroid_topk(queries, state.centroids, vis,
                                          k=p_local,
                                          backend=cfg.use_pallas)
        # global re-rank of gathered candidates
        s1_all = jax.lax.all_gather(s1, "model", axis=1, tiled=True)
        pid_all = jax.lax.all_gather(
            local_pid + my * 0, "model", axis=1, tiled=True)
        owner = jnp.repeat(jnp.arange(n_shard), p_local)[None, :]
        owner = jnp.broadcast_to(owner, s1_all.shape)
        s_sel, sel_idx = jax.lax.top_k(-s1_all, nprobe)
        probe_owner = jnp.take_along_axis(owner, sel_idx, axis=1)
        probe_pid = jnp.take_along_axis(pid_all, sel_idx, axis=1)
        # phase 2: scan the selected postings THIS shard owns.  A query's
        # nprobe probes spread ~uniformly over S shards (~nprobe/S each),
        # so the scan is COMPACTED to the first `probe_cap` owned probes
        # (phase-1 order = best-first): the gather and distance scan
        # shrink by nprobe/probe_cap with negligible recall impact
        # (only hurts when > probe_cap probes land on one shard).
        mine = probe_owner == my
        cap = probe_cap if probe_cap else nprobe
        if cap < nprobe:
            order = jnp.argsort(~mine, axis=1, stable=True)[:, :cap]
            pid_cap = jnp.take_along_axis(probe_pid, order, axis=1)
            mine_cap = jnp.take_along_axis(mine, order, axis=1)
        else:
            pid_cap, mine_cap = probe_pid, mine
        safe_pid = jnp.where(mine_cap, pid_cap, 0)
        if cfg.use_pq:
            # quant plane: serve phase 2 from the owned probes' CODES
            # (ADC scan + per-shard exact rerank) instead of the float
            # tiles — the sharded form of ``search._pq_stage``
            s2, i2 = _pq_phase2(state, cfg, queries, safe_pid, mine_cap,
                                vis, k)
        else:
            C_ = state.vectors.shape[1]
            k_local = min(k, safe_pid.shape[1] * C_)
            # fused gather scan + top-k with the ownership mask applied
            # in-kernel (no (Q, P, C) score tensor on the pallas path)
            s2, cand2 = ops.posting_scan_topk(
                queries, state.vectors, state.slot_valid, vis, safe_pid,
                k=k_local, qp_ok=mine_cap, backend=cfg.use_pallas)
            i2 = state.ids.reshape(-1)[cand2]
        # cache scan: each shard takes a 1/S slice of the replicated
        # cache (or shard 0 scans everything when disabled)
        if shard_cache_scan:
            cvs, cval_own, cid = _owned_cache_slice(state, my, n_shard)
            ck = min(k, cvs.shape[0])
            s3, cpos = ops.centroid_topk(queries, cvs, cval_own, k=ck,
                                         backend=cfg.use_pallas)
            i3 = cid[cpos]
        else:
            cval = state.cache_valid & (my == 0)
            ck = min(k, state.cache_vecs.shape[0])
            s3, cpos = ops.centroid_topk(queries, state.cache_vecs, cval,
                                         k=ck, backend=cfg.use_pallas)
            i3 = state.cache_ids[cpos]
        s2 = jnp.concatenate([s2, s3], axis=1)
        i2 = jnp.concatenate([i2, i3], axis=1)
        # global merge
        s2_all = jax.lax.all_gather(s2, "model", axis=1, tiled=True)
        i2_all = jax.lax.all_gather(i2, "model", axis=1, tiled=True)
        sf, idf = _local_topk(s2_all, i2_all, k)
        found = jnp.where(sf < BIG / 2, idf, -1)
        return found, sf

    in_specs = (st_specs, qspec)
    fn = shard_map(local, mesh, in_specs, (qspec, qspec))
    return jax.jit(fn)


def make_sharded_insert(cfg: UBISConfig, mesh: Mesh,
                        route_alpha: float = 0.0):
    """Builds a jitted sharded insert round:
    (state, vecs, ids, valid) -> (state, accepted (J,) bool,
    routed (J,) int32).

    Each shard locates jobs against its local centroids; a global argmin
    routes each job to its owner shard, which runs the conflict-free
    batched append on its local state.  Blocked jobs (non-NORMAL status)
    are *rejected* here — the vector cache is host-mediated in
    ``ShardedUBISDriver`` (replicated cache writes would race), which is
    why the per-job accepted mask (not a count) comes back: the driver
    owns the retry/park decision for every rejected lane.  ``routed``
    is the GLOBAL pid the round located for each job (-1 when nothing
    was insertable): parked jobs carry it as their cache target, which
    is what lets the background plane's pressure stats attribute the
    parked backlog to the saturated shard.

    ``route_alpha`` enables **pressure-aware routing** (prefer colder
    shards at locate time, the ROADMAP follow-up that cuts migration
    volume on skewed streams): each job's per-shard best score is
    penalized by ``route_alpha * saturation * range`` where saturation
    is the shard's live-sub-pool fraction and ``range`` is that job's
    finite score spread — so a nearly-full shard only wins a job it is
    decisively closest to, and ties break toward shards with free
    capacity.  Costs one (S,)-scalar all-gather in a round that already
    gathers per-job rows; ``route_alpha=0`` (default) is bit-identical
    to the unpenalized round.
    """
    jspec = P()     # jobs replicated: every shard sees all jobs
    st_specs = index_specs(cfg)

    def local(state: IndexState, vecs, ids, valid):
        import dataclasses as _dc
        from .update import batched_append
        my = jax.lax.axis_index("model")
        M_local = state.centroids.shape[0]
        status = vm.unpack_status(state.rec_meta)
        # spilled postings cannot take appends (float tile host-resident)
        insertable = (state.allocated & (status == 0)
                      & ~state.tier_spilled)
        sc = ref.centroid_score(vecs.astype(jnp.float32), state.centroids)
        sc = jnp.where(insertable[None, :], sc, BIG)
        best_local = jnp.min(sc, axis=1)
        best_pid = jnp.argmin(sc, axis=1).astype(jnp.int32)
        # global owner = argmin over shards
        all_best = jax.lax.all_gather(best_local, "model", axis=0)  # (S, J)
        if route_alpha:
            # saturation = live vector mass over the shard's pool
            # capacity (smoother than the posting count: it climbs with
            # every accepted append, not only on splits)
            alive = state.allocated & (status != STATUS_DELETED)
            sat = (jnp.sum(jnp.where(alive, state.lengths, 0))
                   .astype(jnp.float32) / (M_local * cfg.l_max))
            sat_all = jax.lax.all_gather(sat, "model")          # (S,)
            finite = all_best < BIG / 2
            vmin = jnp.min(jnp.where(finite, all_best, BIG), axis=0)
            vmax = jnp.max(jnp.where(finite, all_best, -BIG), axis=0)
            rng_j = jnp.maximum(vmax - vmin, 0.0)
            all_best = jnp.where(
                finite,
                all_best + route_alpha * sat_all[:, None] * rng_j[None, :],
                all_best)
        owner = jnp.argmin(all_best, axis=0).astype(jnp.int32)
        mine = valid & (owner == my) & (best_local < BIG / 2)
        # routed GLOBAL pid per job (one-hot psum: exactly one shard is
        # the argmin owner) — the cache-target hint for parked jobs
        claim = (owner == my) & (best_local < BIG / 2)
        routed = jax.lax.psum(
            jnp.where(claim, best_pid + my.astype(jnp.int32) * M_local, 0),
            "model")
        routable = jax.lax.psum(claim.astype(jnp.int32), "model") > 0
        routed = jnp.where(valid & routable, routed, -1)
        state, ok, flat_local = batched_append(
            state, cfg, vecs, ids, jnp.where(mine, best_pid, -1), mine,
            update_id_loc=False)
        # id_loc is REPLICATED across model shards: merge the per-job
        # global flat locations (exactly one shard wins each job, so a
        # psum of one-hot contributions keeps the replicas identical).
        won = mine & ok
        flat_global = jnp.where(won, my * (M_local * cfg.capacity)
                                + flat_local, 0)
        flat_global = jax.lax.psum(flat_global, "model")
        any_won = jax.lax.psum(won.astype(jnp.int32), "model") > 0
        safe_ids = jnp.where(valid & any_won, ids, cfg.max_ids)
        id_loc = state.id_loc.at[safe_ids].set(
            flat_global.astype(jnp.int32), mode="drop")
        state = _dc.replace(
            state, id_loc=id_loc,
            global_version=state.global_version + jnp.uint32(1))
        return state, valid & any_won, routed

    fn = shard_map(local, mesh, (st_specs, jspec, jspec, jspec),
                   (st_specs, P(), P()))
    return jax.jit(fn, donate_argnums=(0,))


def make_sharded_delete(cfg: UBISConfig, mesh: Mesh):
    """Builds a jitted sharded delete round:
    (state, del_ids, valid) -> (state, done (J,) bool).

    Locations come from the replicated ``id_loc`` map, so routing is
    free: the owner shard (flat location // local pool span) tombstones
    its tiles and decrements its lengths; the cache and ``id_loc``
    updates are computed identically on every shard from replicated
    inputs, so the replicas stay in sync with zero collectives.  The
    tombstone writes themselves are ``update.apply_tombstones`` — ONE
    kernel parameterized by the owner span, shared with the single-device
    ``delete_round`` (base 0) so the two paths cannot drift.  UBIS
    semantics only — the SPFresh lock model lives in ``delete_round``.
    """
    jspec = P()
    st_specs = index_specs(cfg)
    C = cfg.capacity

    def local(state: IndexState, del_ids, valid):
        my = jax.lax.axis_index("model")
        M_local = state.lengths.shape[0]
        base = my.astype(jnp.int32) * (M_local * C)
        safe = jnp.clip(del_ids, 0, cfg.max_ids - 1)
        loc = state.id_loc[safe]
        first = vm.first_occurrence_mask(safe) & valid
        in_post = first & (loc >= 0)
        in_cache = first & (loc <= -2)
        state, done = apply_tombstones(state, cfg, safe, loc, in_post,
                                       in_cache, base=base)
        return state, done

    fn = shard_map(local, mesh, (st_specs, jspec, jspec), (st_specs, P()))
    return jax.jit(fn, donate_argnums=(0,))


def make_sharded_background(cfg: UBISConfig, mesh: Mesh,
                            bg_ops: int = 8, reassign: bool = True,
                            gc_k: int = 64):
    """Builds a jitted sharded background tick:
    (state, gc_min_version) -> (state, executed, reclaimed, pressure).

    ``pressure`` is the (S, 4) int32 per-shard saturation report —
    ``balance.shard_pressure`` rows ``(live_postings, free_slots,
    cache_backlog, live_vectors)`` computed AFTER the structural batch
    and GC.  Each shard writes its own row through the ``P("model")``
    output layout, so the stats ride out of the same program with zero
    added collectives; the host-side ``RebalancePlanner`` reads them to
    pick donor->receiver migrations for ``make_sharded_migrate``.

    The SAME ``balance.background_round`` program runs on every model
    shard over the postings it owns — structural work is shard-local, so
    the whole pod's split/merge/compact batch is one collective-free
    device call.  Per shard: detect -> pick top ``bg_ops`` candidates ->
    mark -> execute, all on device.  Two shard-specific adaptations:

      * the global free stack is meaningless per shard (its slices hold
        arbitrary global ids), so each shard derives a local free view
        from ``allocated`` on entry and the state returns with an EMPTY
        (fail-safe) stack — gather + ``update.rebuild_free_stack`` before
        single-device use;
      * ``id_loc`` is replicated, so each shard's (local-flat) rewrites
        are rebased by its pool offset and merged with one psum — every
        id is owned by exactly one shard, so contributions never collide;
      * successor pointers (``rec_succ``) are stored global, used local:
        localized on entry (cross-shard successors dead-end, the safe
        fallback) and rebased back to global pids on exit.

    The vector cache is replicated and therefore unwritable per shard:
    the round runs with ``use_cache=False`` (small-side spills fold back
    into child ``a`` instead — nothing is dropped).

    Epoch GC rides in the same program: after the structural batch, each
    shard reclaims up to ``gc_k`` of its own retired postings older than
    ``gc_min_version`` (pass 0 to skip).  Structural ownership makes
    this collective-free too; the per-shard successor sweep covers every
    reference the sharded rounds themselves can create (they only link
    same-shard successors).
    """
    st_specs = index_specs(cfg)
    C = cfg.capacity

    def local(state: IndexState, gc_min_version):
        my = jax.lax.axis_index("model")
        M_local = state.allocated.shape[0]
        base_pid = my.astype(jnp.int32) * M_local
        # local free view: unallocated local pids, stack top at the end
        state = rebuild_free_stack(state)
        # successor pointers are stored as GLOBAL pids; the local round
        # reads/writes local ones.  Localize on entry (cross-shard
        # successors become -1: the round treats them as absent, the
        # designed-safe dead-end) and on exit rebase only the words the
        # round actually rewrote — untouched postings keep their
        # original global words, cross-shard pointers included.
        old_succ_global = state.rec_succ
        succ_local0 = _rebase_succ(old_succ_global, -base_pid, M_local)
        state = dataclasses_replace(state, rec_succ=succ_local0)
        old_id_loc = state.id_loc

        kinds, pids = balance.select_candidates(state, cfg, bg_ops)
        # mark + execute in one program: atomic within this device call,
        # so the two-phase window collapses without a race window
        state = dataclasses_replace(
            state, rec_meta=balance.mark_selected(state.rec_meta, kinds,
                                                  pids))
        state, rr = balance.background_round(
            state, cfg, kinds, pids, reassign=reassign, use_cache=False)
        # epoch GC on the shard's own retired postings, same device call
        state, n_gc = balance.gc_round(state, cfg, gc_min_version, gc_k)

        # merge the replicated id map: rebase local tile flats to global
        base = my.astype(jnp.int32) * (M_local * C)
        changed = state.id_loc != old_id_loc
        rebased = jnp.where(changed & (state.id_loc >= 0),
                            state.id_loc + base, state.id_loc)
        delta = jnp.where(changed, rebased - old_id_loc, 0)
        id_loc = old_id_loc + jax.lax.psum(delta, "model")
        # free stack on exit: per-shard local views cannot form one
        # canonical global stack, so return it fail-safe EMPTY — any
        # consumer that pops from it gets nothing instead of an aliased
        # live posting.  Each bg call re-derives its local view from
        # ``allocated``; a gathered single-device state must pass
        # through update.ensure_free_stack (the ShardedUBISDriver
        # snapshot path enforces this) before driver/alloc/GC use.
        succ_changed = state.rec_succ != succ_local0
        rec_succ = jnp.where(
            succ_changed,
            _rebase_succ(state.rec_succ, base_pid, cfg.max_postings),
            old_succ_global)
        state = dataclasses_replace(
            state, id_loc=id_loc, free_top=jnp.int32(0), rec_succ=rec_succ,
            global_version=jax.lax.pmax(state.global_version, "model"))
        executed = jax.lax.psum(rr.executed, "model")
        reclaimed = jax.lax.psum(jnp.asarray(n_gc, jnp.int32), "model")
        # per-shard pressure row (pure local math; the P("model") output
        # layout stacks the rows — no collective)
        pressure = balance.shard_pressure(state, cfg, base_pid=base_pid)
        return state, executed, reclaimed, pressure[None]

    fn = shard_map(local, mesh, (st_specs, P()),
                   (st_specs, P(), P(), P("model")))
    return jax.jit(fn)


def make_sharded_migrate(cfg: UBISConfig, mesh: Mesh, jobs: int = 8):
    """Builds a jitted cross-shard posting migration round:
    (state, src_pids (B,), dst_shards (B,), valid (B,)) ->
    (state, migrated (B,) bool, new_pids (B,) int32).

    ``new_pids`` is the landing GLOBAL pid per job (-1 when the job did
    not move) — the cold-tier driver uses it to remap its host-pool
    entries: a **spilled** posting migrates WITHOUT being promoted (its
    zeroed device tile, codes, heat and ``tier_spilled`` flag all travel
    verbatim; only the host-side pool key changes).

    The rebalance data plane (the paper's "imbalanced distribution"
    countermeasure lifted to the pod level): a saturated shard's hot
    sub-pool hands whole postings to shards with free capacity, picked
    host-side by ``api.rebalance.RebalancePlanner`` from the pressure
    stats the background round reports.  One round, three phases:

      * **extraction** — the owner shard gathers each migrating tile
        (vectors, ids, slot validity, lengths, centroid, PQ codes +
        pinned codebook slot) and replicates it with a one-hot psum
        (exactly one shard contributes per job, the same discipline as
        the insert round's id-map merge).  Only postings that are
        allocated + NORMAL move — a posting the background round marked
        or retired in the meantime is silently skipped.  The neighbour
        row is NOT carried: its pids are shard-local (the sharded
        background rounds write local ids), so on the receiver they
        would alias unrelated postings — the landed posting starts with
        an empty row, like the NO_SUCC treatment of its recorder word;
      * **installation** — the receiver shard admits jobs through the
        same sequential free-stack grant scan the background round uses
        (jobs granted in batch order while local slots last), writes the
        tile verbatim into the popped slot (no repacking: PQ codes stay
        byte-identical to their pinned-slot encode), and claims the
        recorder word at the round's version;
      * **hand-off** — the donor retires its copy (DELETED at this
        version, NO successors: ``id_loc`` is repointed in this same
        program, and cross-shard successor pointers would break the
        per-shard GC sweep's locality contract), and every shard
        computes the identical ``id_loc`` rewrite from the replicated
        payload — the ``make_sharded_delete`` replica discipline, so the
        id map needs no extra merge.

    Tiles move through psums sized (B, C, d) etc. with B = ``jobs`` —
    a few postings per tick, independent of pool size.  The free stack
    returns fail-safe EMPTY per the sharded-state contract.
    """
    st_specs = index_specs(cfg)
    C = cfg.capacity

    def local(state: IndexState, src_pids, dst_shards, valid):
        my = jax.lax.axis_index("model").astype(jnp.int32)
        n_shard = _axis_size("model")
        M_local = state.lengths.shape[0]
        base_pid = my * M_local
        ver = state.global_version + jnp.uint32(1)
        B = src_pids.shape[0]
        src_pids = jnp.asarray(src_pids, jnp.int32)
        dst_shards = jnp.asarray(dst_shards, jnp.int32)

        # local free view (same entry discipline as the background round)
        state = rebuild_free_stack(state)

        # replicated job sanity: in-range, deduped, actually cross-shard
        src_shard = src_pids // M_local
        job_ok = (valid & (src_pids >= 0)
                  & (src_pids < n_shard * M_local)
                  & vm.first_occurrence_mask(src_pids)
                  & (dst_shards >= 0) & (dst_shards < n_shard)
                  & (dst_shards != src_shard))

        # ---- donor extraction: one-hot psum replicates each payload ---
        src_local = src_pids - base_pid
        sl = jnp.clip(src_local, 0, M_local - 1)
        status = vm.unpack_status(state.rec_meta)
        donate = (job_ok & (src_local >= 0) & (src_local < M_local)
                  & state.allocated[sl] & (status[sl] == STATUS_NORMAL))

        def rep(x, mask):
            contrib = jnp.where(mask.reshape((B,) + (1,) * (x.ndim - 1)),
                                x, jnp.zeros((), x.dtype))
            return jax.lax.psum(contrib, "model")

        vec_b = rep(state.vectors[sl], donate)
        ids_b = rep(state.ids[sl], donate)
        sv_b = rep(state.slot_valid[sl].astype(jnp.int32), donate) > 0
        used_b = rep(state.used[sl], donate)
        len_b = rep(state.lengths[sl], donate)
        cent_b = rep(state.centroids[sl], donate)
        codes_b = rep(state.codes[sl].astype(jnp.int32),
                      donate).astype(jnp.uint8)
        pslot_b = rep(state.pq_posting_slot[sl], donate)
        heat_b = rep(state.heat[sl].astype(jnp.int32),
                     donate).astype(jnp.uint32)
        sp_b = rep(state.tier_spilled[sl].astype(jnp.int32), donate) > 0
        movable = jax.lax.psum(donate.astype(jnp.int32), "model") > 0

        # ---- receiver admission: sequential free-stack grant scan -----
        want = movable & (dst_shards == my)

        def grant_step(off, w):
            g = w & (off < state.free_top)
            return off + g.astype(jnp.int32), (g, off)

        _, (grant_l, starts) = jax.lax.scan(grant_step, jnp.int32(0), want)
        idx = state.free_top - 1 - starts
        new_local = jnp.where(
            grant_l, state.free_list[jnp.clip(idx, 0, M_local - 1)], -1)
        # replicate the landing pid (one-hot psum from the receiver)
        new_global = jax.lax.psum(
            jnp.where(grant_l, new_local + base_pid, 0), "model")
        migrated = jax.lax.psum(grant_l.astype(jnp.int32), "model") > 0
        new_global = jnp.where(migrated, new_global, -1)

        # ---- install on the receiver ----------------------------------
        tgt = oob(new_local, grant_l, M_local)
        vectors = state.vectors.at[tgt].set(vec_b, mode="drop")
        ids_arr = state.ids.at[tgt].set(ids_b, mode="drop")
        slot_valid = state.slot_valid.at[tgt].set(sv_b, mode="drop")
        used = state.used.at[tgt].set(used_b, mode="drop")
        lengths = state.lengths.at[tgt].set(len_b, mode="drop")
        centroids = state.centroids.at[tgt].set(cent_b, mode="drop")
        # fresh empty neighbour row: the donor's row holds shard-LOCAL
        # pids, meaningless (aliasing) in the receiver's pool
        nbrs = state.nbrs.at[tgt].set(
            jnp.full((B, state.nbrs.shape[1]), -1, jnp.int32),
            mode="drop")
        codes = state.codes.at[tgt].set(codes_b, mode="drop")
        pq_posting_slot = state.pq_posting_slot.at[tgt].set(pslot_b,
                                                            mode="drop")
        # tier residency travels with the posting (no promotion: a
        # spilled posting lands spilled, its pool entry is remapped
        # host-side by the driver via ``new_pids``)
        heat = state.heat.at[tgt].set(heat_b, mode="drop")
        tier_spilled = state.tier_spilled.at[tgt].set(sp_b, mode="drop")
        rec_meta = state.rec_meta.at[tgt].set(
            vm.pack_meta(jnp.uint32(STATUS_NORMAL), ver), mode="drop")
        rec_succ = state.rec_succ.at[tgt].set(
            jnp.uint32((NO_SUCC << 16) | NO_SUCC), mode="drop")
        allocated = state.allocated.at[tgt].set(True, mode="drop")

        # ---- donor retirement (no successors: id_loc is already new) --
        retire = donate & migrated
        rec_meta = vm.transition(rec_meta, jnp.where(retire, sl, -1),
                                 STATUS_DELETED,
                                 jnp.broadcast_to(ver, (B,)))
        rec_succ = vm.set_successors(rec_succ, jnp.where(retire, sl, -1),
                                     jnp.full((B,), -1, jnp.int32),
                                     jnp.full((B,), -1, jnp.int32))
        # the retired donor copy is no longer host-resident anywhere
        tier_spilled = tier_spilled.at[oob(sl, retire, M_local)].set(
            False, mode="drop")

        # ---- replicated id map: identical rewrite on every shard ------
        ids_flat = ids_b.reshape(B * C)
        live_flat = ((sv_b & migrated[:, None]).reshape(B * C)
                     & (ids_flat >= 0))
        new_flat = (new_global[:, None] * C
                    + jnp.arange(C, dtype=jnp.int32)[None, :]).reshape(-1)
        id_loc = state.id_loc.at[
            oob(jnp.clip(ids_flat, 0, cfg.max_ids - 1), live_flat,
                cfg.max_ids)].set(new_flat, mode="drop")

        state = dataclasses_replace(
            state, vectors=vectors, ids=ids_arr, slot_valid=slot_valid,
            used=used, lengths=lengths, centroids=centroids, nbrs=nbrs,
            codes=codes, pq_posting_slot=pq_posting_slot,
            heat=heat, tier_spilled=tier_spilled,
            rec_meta=rec_meta, rec_succ=rec_succ, allocated=allocated,
            id_loc=id_loc, free_top=jnp.int32(0),  # fail-safe EMPTY
            global_version=ver)
        return state, migrated, new_global

    fn = shard_map(local, mesh, (st_specs, P(), P(), P()),
                   (st_specs, P(), P()))
    jfn = jax.jit(fn, donate_argnums=(0,))

    def checked(state, src_pids, dst_shards, valid):
        # the batch width is baked into the compiled program; a caller
        # passing a different width would silently recompile per shape
        if src_pids.shape[0] != jobs:
            raise ValueError(f"migrate round built for jobs={jobs}, "
                             f"got batch of {src_pids.shape[0]}")
        return jfn(state, src_pids, dst_shards, valid)

    return checked


def make_sharded_exact(cfg: UBISConfig, mesh: Mesh, k: int):
    """Builds a jitted exact top-k oracle over the sharded live contents:
    (state, queries) -> (ids, scores) — the ``shard_map``'d form of
    ``search.brute_force``.

    Each shard brute-force scans the posting slots it owns (full
    slot-validity + visibility masking) plus its 1/S slice of the
    replicated vector cache, takes a local top-k FROM ITS OWN id rows
    (no take-along-axis on a replicated row under GSPMD — the
    partial-sum id-scaling trap this replaces), and one all-gather +
    merge produces the global result.  Queries are replicated: the
    oracle is eval-only, so data-axis padding buys nothing.
    """
    st_specs = index_specs(cfg)

    def local(state: IndexState, queries):
        n_shard = _axis_size("model")
        my = jax.lax.axis_index("model")
        queries = queries.astype(jnp.float32)
        vis = vm.visible(state.rec_meta, state.allocated,
                         state.global_version)
        # spilled postings excluded (device tiles zeroed) — the tiered
        # driver merges a host-pool scan on top, same as single-device
        valid = state.slot_valid & (vis & ~state.tier_spilled)[:, None]
        s = ref.posting_scan(queries, state.vectors, valid)  # (Q, M_local*C)
        ids_row = state.ids.reshape(-1)
        # cache slice: the same ownership split as the sharded search
        cvs, cval_own, cid = _owned_cache_slice(state, my, n_shard)
        cs = ref.centroid_score(queries, cvs)
        cs = jnp.where(cval_own[None, :], cs, BIG)
        scores = jnp.concatenate([s, cs], axis=1)
        flat = jnp.concatenate([ids_row, cid])
        ids2d = jnp.broadcast_to(flat[None, :],
                                 (queries.shape[0], flat.shape[0]))
        kl = min(k, scores.shape[1])
        s_loc, i_loc = _local_topk(scores, ids2d, kl)
        s_all = jax.lax.all_gather(s_loc, "model", axis=1, tiled=True)
        i_all = jax.lax.all_gather(i_loc, "model", axis=1, tiled=True)
        sf, idf = _local_topk(s_all, i_all, k)
        return jnp.where(sf < BIG / 2, idf, -1), sf

    fn = shard_map(local, mesh, (st_specs, P()), (P(), P()))
    return jax.jit(fn)
