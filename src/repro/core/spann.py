"""SPANN static baseline (paper III-B1): build once, search only.

Table I: SPANN supports neither incremental nor streaming update — this
wrapper *refuses* updates, which is exactly its role in the comparison
(a quality ceiling for a freshly-built index).  Refusals are reported
through the ``StreamingIndex`` result types (every insert job counts as
``rejected``, every delete as ``blocked``) instead of raising, so the
engine rides the same comparison loop as the updatable engines and its
staleness shows up honestly as recall decay against the stream.
"""
from __future__ import annotations

import numpy as np

from ..api.types import SearchResult, TickReport, UpdateResult
from .driver import UBISDriver
from .types import UBISConfig


class SPANNStatic:
    """Build-once cluster index (k-means seed + one bulk load); a
    ``StreamingIndex`` whose update surface always refuses."""

    def __init__(self, cfg: UBISConfig, vectors: np.ndarray,
                 ids: np.ndarray, *, round_size: int = 1024,
                 seed: int = 0, obs=None):
        # bulk-load through the same machinery, then freeze (the inner
        # driver also supplies the shared-schema stats/obs plane)
        self._drv = UBISDriver(cfg, vectors, round_size=round_size,
                               seed=seed, obs=obs)
        self._drv.insert(vectors, ids)
        self._drv.flush()
        self.state = self._drv.state
        self.cfg = cfg

    def search(self, queries, k: int, nprobe=None) -> SearchResult:
        return self._drv.search(queries, k, nprobe)

    def insert(self, vecs, ids, **_) -> UpdateResult:
        return UpdateResult(rejected=len(np.asarray(ids)))

    def delete(self, ids) -> UpdateResult:
        return UpdateResult(blocked=len(np.asarray(ids)))

    def tick(self) -> TickReport:
        return TickReport()

    def flush(self, max_ticks: int = 0) -> int:
        return 0

    # ---- StreamingIndex protocol surface ------------------------------

    @property
    def stats(self):
        return self._drv.stats

    @property
    def obs(self):
        return self._drv.obs

    def snapshot(self):
        return self.state

    def memory_bytes(self) -> int:
        return self._drv.memory_bytes()

    def memory_tiers(self) -> dict:
        return {"device": self.memory_bytes(), "host": 0}

    def exact(self, queries, k: int) -> SearchResult:
        return self._drv.exact(queries, k)

    def posting_lengths(self) -> np.ndarray:
        return self._drv.posting_lengths()

    def live_count(self) -> int:
        return self._drv.live_count()

    def throughput(self) -> dict:
        return self._drv.throughput()
