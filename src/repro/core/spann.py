"""SPANN static baseline (paper III-B1): build once, search only.

Table I: SPANN supports neither incremental nor streaming update — this
wrapper simply refuses updates, which is exactly its role in the
comparison (a quality ceiling for a freshly-built index).
"""
from __future__ import annotations

import numpy as np

from .driver import UBISDriver
from .types import UBISConfig


class SPANNStatic:
    """Build-once cluster index (k-means seed + one bulk load)."""

    def __init__(self, cfg: UBISConfig, vectors: np.ndarray,
                 ids: np.ndarray):
        # bulk-load through the same machinery, then freeze
        self._drv = UBISDriver(cfg, vectors)
        self._drv.insert(vectors, ids)
        self._drv.flush()
        self.state = self._drv.state
        self.cfg = cfg

    def search(self, queries, k: int):
        return self._drv.search(queries, k)

    def insert(self, *a, **k):
        raise NotImplementedError("SPANN is static (paper Table I); "
                                  "use UBISDriver for updates")

    delete = insert
