"""SPFresh baseline (paper III-B2): the in-place LIRE protocol with
posting-level locking and strict split/merge triggers.

The substrate is shared with UBIS; ``mode="spfresh"`` switches the
driver/balance semantics (DESIGN.md §1):
  * blocked jobs (target not NORMAL) are rejected + retried — the lock;
  * splits trigger only on insert overflow; merges only when a search
    touches an undersized posting;
  * plain farthest-init 2-means splits, no balance-factor branch —
    which is what litters small postings (paper Fig. 5).
"""
from __future__ import annotations

import dataclasses

from .driver import UBISDriver
from .types import UBISConfig


def spfresh_config(cfg: UBISConfig) -> UBISConfig:
    return dataclasses.replace(cfg, mode="spfresh")


def SPFreshDriver(cfg: UBISConfig, seed_vectors, **kw) -> UBISDriver:
    """A UBISDriver with SPFresh semantics."""
    return UBISDriver(spfresh_config(cfg), seed_vectors, **kw)
