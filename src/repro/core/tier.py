"""Cold-tier host spill: codes-only device residency for cold postings.

The FreshDiskANN billion-scale tier grafted onto the UBIS posting pool:
under streaming traffic most postings are cold (never probed, never
appended to), yet their float tiles are the index's dominant HBM cost.
With ``cfg.use_tier`` the driver moves cold postings' float tiles to a
**pinned host pool** and keeps only their PQ codes (plus centroid and
recorder word) device-resident; search serves them ADC-only with an
optional host-side exact rerank of the final candidate set, while hot
postings keep the bit-identical float path.

Three cooperating pieces:

  * **heat tracking** — ``state.heat`` counts probes and accepted
    appends per posting (the driver accumulates touches host-side and
    applies one elementwise ``touch_round`` per tick); the counters are
    halved inside ``balance.background_round`` (and therefore inside
    the sharded round) — pure local math, zero added collectives;
  * **the planner** — :class:`TierPlanner` (pure host-side numpy, the
    ``RebalancePlanner`` discipline): spill when the float-resident live
    posting count crosses the device high-watermark
    (``cfg.tier_hot_max``), coldest-first among postings whose heat
    decayed to ``cfg.tier_cold_heat``; promote on search-heat
    (``cfg.tier_promote_heat``) — and *forcibly* promote any spilled
    posting that became structurally due (over ``l_max``, under
    ``l_min``, or tombstone-saturated): split/merge/compact never run on
    a spilled posting (``balance.detect`` masks them), so promotion must
    come first;
  * **the move rounds** — ``spill_round`` zeroes the device tiles and
    raises ``tier_spilled`` (the driver has already copied the bytes to
    the host pool); ``promote_round`` writes the pooled bytes back
    verbatim, so a promote restores the float tile **bit-identically**.

Residency invariants (property-tested in ``tests/test_tier.py``):

  * a spilled posting's device tile is all-zero and its pool tile
    satisfies ``codes == encode(codebooks[slot], pool_tile)`` — the code
    plane never diverges from the (host-resident) float plane;
  * spilled postings are excluded from every float-write path: locate
    (``update.insert_round``), successor chasing, merge partners,
    move-out and reassign targets, and structural marking;
  * ``memory_tiers()['device'] + ['host']`` equals the untiered total.

The sharded plane shards ``heat``/``tier_spilled`` with their postings;
``make_sharded_migrate`` moves spilled postings **without promoting
them** (codes + flags travel, the driver remaps the pool entry to the
landing pid).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .types import STATUS_DELETED, STATUS_NORMAL, IndexState, UBISConfig
from .update import dataclasses_replace, oob


# ---------------------------------------------------------------------------
# jitted rounds (all elementwise / small scatters — no collectives)
# ---------------------------------------------------------------------------

@jax.jit
def touch_round(state: IndexState, counts: jax.Array) -> IndexState:
    """Apply host-accumulated touch counts: ``heat += counts``.

    ``counts`` is a full (M,) vector, so the round is one elementwise
    add — fixed shape (no per-batch retrace) and trivially partitioned
    over a sharded ``heat``.  Saturating add keeps the counter sane
    under pathological probe storms.
    """
    heat = state.heat + jnp.minimum(counts.astype(jnp.uint32),
                                    jnp.uint32(1) << 20)
    return dataclasses_replace(state, heat=heat)


@jax.jit
def decay_round(state: IndexState) -> IndexState:
    """Halve every touch counter — the driver's fallback for ticks that
    executed no background round (which normally carries the decay)."""
    return dataclasses_replace(state, heat=state.heat >> 1)


@jax.jit
def gather_tiles(state: IndexState, pids) -> jax.Array:
    """The *dispatch half* of a spill: gather the planned postings'
    float tiles as one device array.  The caller starts the async
    device→host copy (``copy_to_host_async``) on the result and commits
    the spill later with :func:`spill_round` — which is what lets the
    DMA overlap the tick's background round instead of blocking at the
    ``np.asarray`` seam."""
    M = state.lengths.shape[0]
    return state.vectors[jnp.clip(jnp.asarray(pids, jnp.int32), 0, M - 1)]


@functools.partial(jax.jit, static_argnames=("cfg",))
def spill_round(state: IndexState, cfg: UBISConfig, pids, valid):
    """The *reconcile half* of a spill: zero the device float tiles and
    raise ``tier_spilled``.  The caller MUST have copied the tile bytes
    to the host pool first (``gather_tiles`` + async copy) — this round
    destroys the device copy."""
    M = state.lengths.shape[0]
    tgt = oob(jnp.asarray(pids, jnp.int32), valid, M)
    vectors = state.vectors.at[tgt].set(
        jnp.zeros(state.vectors.shape[1:], state.vectors.dtype),
        mode="drop")
    tier_spilled = state.tier_spilled.at[tgt].set(True, mode="drop")
    return dataclasses_replace(state, vectors=vectors,
                               tier_spilled=tier_spilled)


@functools.partial(jax.jit, static_argnames=("cfg",))
def promote_round(state: IndexState, cfg: UBISConfig, pids, tiles, valid):
    """Restore pooled float tiles to the device (bit-identical bytes)
    and clear ``tier_spilled``.  Promoted postings land warm
    (``heat = tier_promote_heat``) so the very next spill plan does not
    immediately re-evict them."""
    M = state.lengths.shape[0]
    tgt = oob(jnp.asarray(pids, jnp.int32), valid, M)
    vectors = state.vectors.at[tgt].set(
        tiles.astype(state.vectors.dtype), mode="drop")
    tier_spilled = state.tier_spilled.at[tgt].set(False, mode="drop")
    heat = state.heat.at[tgt].set(jnp.uint32(cfg.tier_promote_heat),
                                  mode="drop")
    return dataclasses_replace(state, vectors=vectors,
                               tier_spilled=tier_spilled, heat=heat)


# ---------------------------------------------------------------------------
# the pinned host pool
# ---------------------------------------------------------------------------

class HostTierPool:
    """Host-resident float tiles of spilled postings, keyed by pid.

    On TPU hosts this is the pinned-DRAM side of the tier; here it is
    plain numpy.  Tiles are stored verbatim (storage dtype), so a
    promote restores bit-identical bytes.
    """

    def __init__(self):
        self._tiles: dict[int, np.ndarray] = {}

    def put(self, pid: int, tile: np.ndarray) -> None:
        self._tiles[int(pid)] = np.ascontiguousarray(tile)

    def take(self, pid: int) -> np.ndarray:
        return self._tiles.pop(int(pid))

    def get(self, pid: int) -> np.ndarray:
        return self._tiles[int(pid)]

    def remap(self, src: int, dst: int) -> None:
        """Migration hand-off: the posting moved pids without promoting."""
        self._tiles[int(dst)] = self._tiles.pop(int(src))

    def pids(self) -> np.ndarray:
        return np.asarray(sorted(self._tiles), np.int32)

    def __len__(self) -> int:
        return len(self._tiles)

    def __contains__(self, pid) -> bool:
        return int(pid) in self._tiles

    def nbytes(self) -> int:
        return sum(t.nbytes for t in self._tiles.values())


# ---------------------------------------------------------------------------
# the spill/promote planner (pure host-side numpy)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TierPlanner:
    """Picks per-tick spill and promote batches from host views.

    ``hot_max`` is the device high-watermark in float-resident live
    postings (0 disables watermark spilling); ``cold_heat`` /
    ``promote_heat`` are the decayed-counter thresholds; ``max_moves``
    bounds the per-tick batch (the jitted rounds compile at this width).
    """

    hot_max: int
    cold_heat: int
    promote_heat: int
    max_moves: int = 32

    #: pid -> reason for the most recent ``plan_promotes`` picks
    #: ("structural-due" | "search-heat" | "wedge-recovery"), consumed by
    #: the TierManager's trace events
    last_promote_reasons: dict = dataclasses.field(default_factory=dict)

    def plan_promotes(self, heat, spilled, allocated, status, lengths,
                      used, *, l_min: int, l_max: int,
                      capacity: int) -> np.ndarray:
        """Spilled postings to promote this tick: structurally-due ones
        FIRST (split/merge/compact require float residency — the
        forced-promotion rule), then by search-heat, hottest first."""
        self.last_promote_reasons = {}
        alive = np.asarray(allocated) & (np.asarray(status)
                                         != STATUS_DELETED)
        sp = np.asarray(spilled) & alive
        if not sp.any():
            return np.empty(0, np.int32)
        heat = np.asarray(heat)
        lengths = np.asarray(lengths)
        due = sp & ((lengths > l_max) | (lengths < l_min)
                    | (np.asarray(used) >= capacity))
        hot = sp & ~due & (heat >= self.promote_heat)
        due_pids = np.flatnonzero(due)
        hot_pids = np.flatnonzero(hot)
        hot_pids = hot_pids[np.argsort(-heat[hot_pids], kind="stable")]
        picks = np.concatenate([due_pids, hot_pids])
        reasons = (["structural-due"] * len(due_pids)
                   + ["search-heat"] * len(hot_pids))
        # wedge guard: with NO float-resident insertable posting left
        # (e.g. everything force-spilled), inserts can only park in the
        # cache — promote a batch unconditionally so the index recovers
        n_hot = int((np.asarray(allocated)
                     & (np.asarray(status) == STATUS_NORMAL)
                     & ~np.asarray(spilled)).sum())
        if n_hot == 0 and picks.size == 0:
            rest = np.flatnonzero(sp)
            picks = rest[np.argsort(-heat[rest], kind="stable")]
            reasons = ["wedge-recovery"] * len(picks)
        picks = picks.astype(np.int32)[:self.max_moves]
        self.last_promote_reasons = {int(p): r for p, r
                                     in zip(picks, reasons)}
        return picks

    def plan_spills(self, heat, spilled, allocated, status) -> np.ndarray:
        """Hot postings to spill this tick: only while the float-resident
        live count exceeds the watermark, only NORMAL postings (a marked
        posting is mid-structural-op), only ones whose heat has decayed
        to ``cold_heat``, coldest first."""
        if self.hot_max <= 0:
            return np.empty(0, np.int32)
        hot = (np.asarray(allocated)
               & (np.asarray(status) == STATUS_NORMAL)
               & ~np.asarray(spilled))
        over = int(hot.sum()) - self.hot_max
        if over <= 0:
            return np.empty(0, np.int32)
        heat = np.asarray(heat)
        cand = np.flatnonzero(hot & (heat <= self.cold_heat))
        cand = cand[np.argsort(heat[cand], kind="stable")]
        return cand.astype(np.int32)[:min(over, self.max_moves)]

    def force_spills(self, n, heat, spilled, allocated,
                     status) -> np.ndarray:
        """Coldest ``n`` hot NORMAL postings regardless of watermark and
        cold threshold (test/benchmark hook; same safety rules)."""
        hot = (np.asarray(allocated)
               & (np.asarray(status) == STATUS_NORMAL)
               & ~np.asarray(spilled))
        cand = np.flatnonzero(hot)
        heat = np.asarray(heat)
        cand = cand[np.argsort(heat[cand], kind="stable")]
        return cand.astype(np.int32)[:n]


# ---------------------------------------------------------------------------
# host-side exact serving for spilled postings
# ---------------------------------------------------------------------------

def host_rerank(found, scores, queries, pool: HostTierPool, loc,
                tier_spilled, capacity: int):
    """Exact rerank of the FINAL candidate set against the host pool.

    ``found``/``scores`` are a search's (Q, k) result where candidates
    from spilled postings carry ADC scores; ``loc`` is the id->flat
    location of each found id (same shape).  Spilled candidates get
    their true ``||v||^2 - 2 q.v`` recomputed from the pooled tile and
    each row is re-sorted — the set cannot grow, only re-rank, which is
    exactly the 'optional host-side exact rerank' contract.

    Returns ``(found, scores, n_spilled_hits)`` — the hit count is the
    obs plane's spilled-candidate signal, computed from the mask this
    function builds anyway (no extra transfers).
    """
    found = np.asarray(found)
    scores = np.array(scores, np.float32, copy=True)
    loc = np.asarray(loc)
    queries = np.asarray(queries, np.float32)
    tier_spilled = np.asarray(tier_spilled)
    in_post = (found >= 0) & (loc >= 0)
    pid = np.where(in_post, loc // capacity, 0)
    # membership guard: with dispatch/collect overlap the flags can be a
    # tick stale — a posting promoted in between has no pool tile any
    # more (its candidate keeps the device score, which is now exact)
    member = np.zeros(tier_spilled.shape[0], bool)
    pp = pool.pids()
    if pp.size:
        member[pp] = True
    sp = in_post & tier_spilled[pid] & member[pid]
    if not sp.any():
        return found, scores, 0
    qi, ci = np.nonzero(sp)
    # bulk-gather: one tile fetch per UNIQUE spilled posting, then one
    # fancy-index — the rerank stays cheap even when most of the final
    # candidate set is cold
    upids, inv = np.unique(pid[qi, ci], return_inverse=True)
    tiles = np.stack([pool.get(int(p)) for p in upids]).astype(np.float32)
    vs = tiles[inv, loc[qi, ci] % capacity]
    qs = queries[qi]
    scores[qi, ci] = (vs * vs).sum(-1) - 2.0 * (qs * vs).sum(-1)
    order = np.argsort(scores, axis=1, kind="stable")
    return (np.take_along_axis(found, order, axis=1),
            np.take_along_axis(scores, order, axis=1), int(sp.sum()))


def host_exact_candidates(pool: HostTierPool, sp_pids, ids_rows,
                          valid_rows, queries):
    """Brute-force scores over the pooled tiles of ``sp_pids``.

    Returns (scores (Q, n*C), ids (n*C,)) in the repo-wide score
    convention, invalid slots masked to +BIG — ready to merge with a
    device ``brute_force`` restricted to hot postings.
    """
    from ..kernels.posting_scan import BIG
    queries = np.asarray(queries, np.float32)
    Q = queries.shape[0]
    if len(sp_pids) == 0:
        return np.empty((Q, 0), np.float32), np.empty((0,), np.int32)
    tiles = np.stack([pool.get(int(p)) for p in sp_pids]).astype(
        np.float32)                                     # (n, C, d)
    n, C, d = tiles.shape
    flat = tiles.reshape(n * C, d)
    s = (flat * flat).sum(-1)[None, :] - 2.0 * queries @ flat.T
    valid = np.asarray(valid_rows).reshape(n * C)
    s = np.where(valid[None, :], s, BIG).astype(np.float32)
    ids = np.where(valid, np.asarray(ids_rows).reshape(n * C), -1)
    return s, ids.astype(np.int32)


@dataclasses.dataclass
class TierPlan:
    """An in-flight tier tick: planned moves whose DMA was dispatched at
    tick start (``TierManager.dispatch``) and will be committed at tick
    end (``TierManager.reconcile``).

    Spill tiles are gathered on-device and their host copy started with
    ``copy_to_host_async`` — the D2H DMA overlaps the background round.
    Because the round can mutate the very postings we planned against
    (reassign appends, compaction, structural marking), each spill lane
    carries a *staleness signature* (length + used-slots at dispatch);
    reconcile drops any lane whose signature no longer matches, or whose
    posting is no longer a hot NORMAL one.  Promote lanes are validated
    by pool membership (``promote_retrain_pinned`` can pop entries
    mid-tick) — the pooled bytes themselves cannot go stale, spilled
    postings are excluded from every float-write path.
    """

    spill_pids: np.ndarray           # (B,) int32, -1 padded
    spill_tiles: jax.Array           # (B, C, d) device gather, D2H started
    spill_sig_len: np.ndarray        # (B,) lengths at dispatch
    spill_sig_used: np.ndarray       # (B,) used-slots at dispatch
    promote_pids: np.ndarray         # (P,) int32, -1 padded
    promote_tiles: Optional[jax.Array]   # (P, C, d) staged H2D, or None

    @property
    def n_planned(self) -> int:
        return int((self.spill_pids >= 0).sum()
                   + (self.promote_pids >= 0).sum())


def plan_tier_moves(planner: TierPlanner, rows: dict, cfg: UBISConfig):
    """The tier tick's *decision half*, as a pure function of observed
    rows — runnable by a process that does not hold the index.

    ``rows`` is the numpy observation ``TierManager.observe`` returns
    (heat / spilled / alloc / status / lengths / used); the output is
    ``(promote_pids, spill_pids)``.  Extracted from ``dispatch`` so the
    cluster coordinator can own the plan (the worker ships rows up and
    receives pids back) while the in-process drivers keep the identical
    decision path — including the promote-heat mirroring and the
    same-tick promote/spill exclusion that prevent the
    promote->re-evict livelock.
    """
    promos = planner.plan_promotes(
        rows["heat"], rows["spilled"], rows["alloc"], rows["status"],
        rows["lengths"], rows["used"],
        l_min=cfg.l_min, l_max=cfg.l_max, capacity=cfg.capacity)
    spilled = rows["spilled"].copy()
    spilled[promos] = False
    # mirror promote_round's device heat write (promoted postings land
    # warm) in the host view, or the spill plan below would see the
    # STALE cold heat and re-evict a just-promoted posting in the same
    # tick — with promote_heat <= cold_heat that is a permanent
    # promote/spill livelock
    heat = rows["heat"].copy()
    heat[promos] = planner.promote_heat
    spills = planner.plan_spills(heat, spilled, rows["alloc"],
                                 rows["status"])
    # hard guarantee regardless of the knob ordering (a degenerate
    # promote_heat <= cold_heat config must not livelock either):
    # nothing promoted this tick may be spilled in the same tick
    if len(promos):
        spills = spills[~np.isin(spills, promos)]
    return promos, spills


class TierManager:
    """Host orchestration of the cold tier, shared by both drivers.

    Owns the pinned :class:`HostTierPool`, the :class:`TierPlanner`, and
    the host-side touch accumulator (an (M,) count vector, so the
    per-tick ``touch_round`` is one fixed-shape elementwise add — no
    per-batch retraces, no collectives).  All methods are pure
    ``state -> (state, n)`` at the driver's call sites; the sharded
    driver re-pins shardings after the tick's tier mutations.

    The per-tick step comes in two shapes: the synchronous ``tick`` (plan
    and move in one call, the PR 5 behavior) and the split
    ``dispatch``/``reconcile`` pair that lets a driver start the move DMA
    before its background round and commit after it (``tier_async``).
    """

    def __init__(self, cfg: UBISConfig, *, max_moves: int = 32,
                 rerank_host: bool = True, obs=None):
        self.cfg = cfg
        self.pool = HostTierPool()
        self.planner = TierPlanner(cfg.tier_hot_max, cfg.tier_cold_heat,
                                   cfg.tier_promote_heat,
                                   max_moves=max_moves)
        self.rerank_host = bool(rerank_host)
        self._counts = np.zeros(cfg.max_postings, np.int64)
        # shared obs plane (owned by the driver): tier_plan/tier_commit
        # trace events + the spilled-hit search counter
        self.obs = obs
        self._stats = obs.driver_stats() if obs is not None else None
        # every commit decision (reconcile + the force/adopt/retrain
        # paths) is also appended here so a remote coordinator can drain
        # and re-emit it on ITS obs plane; in-process drivers may ignore
        # it (bounded: drained per cluster command, cleared on adopt)
        self.commit_log: list = []

    def _emit(self, kind: str, **fields) -> None:
        if self.obs is not None:
            self.obs.emit(kind, **fields)

    def _commit(self, **fields) -> None:
        self.commit_log.append(fields)
        self._emit("tier_commit", **fields)

    def drain_commits(self) -> list:
        out, self.commit_log = self.commit_log, []
        return out

    # ---- heat bookkeeping (host-side accumulation) --------------------

    def note_probes(self, probe) -> None:
        """Search touched these postings (any int array of pids)."""
        p = np.asarray(probe).ravel()
        p = p[(p >= 0) & (p < self._counts.shape[0])]
        np.add.at(self._counts, p, 1)

    note_targets = note_probes     # accepted appends touch the same way

    # ---- the per-tick tier step ---------------------------------------

    def tick(self, state: IndexState, *, decayed: bool):
        """Apply accumulated touches, decay (when the background round
        did not run this tick), promote, then spill.  Returns
        (state, n_spilled, n_promoted).

        Synchronous shape: dispatch + immediate reconcile.  Every
        signature is trivially fresh, so this is the exact PR 5
        behavior."""
        state, plan = self.dispatch(state, decayed=decayed)
        return self.reconcile(state, plan)

    def dispatch(self, state: IndexState, *, decayed: bool):
        """Tick-start half: apply touches/decay, plan this tick's moves,
        and START their DMA — the spill tiles' device gather plus async
        device→host copy, and the promote tiles' host→device staging.
        Returns (state, plan); the plan is None when nothing moves.

        ``decayed`` says whether a background round will carry (or, for
        the sync tick, carried) the heat decay this tick.

        Decomposed into ``observe`` (rows out) + module-level
        ``plan_tier_moves`` (decision) + ``dispatch_planned`` (DMA) so
        the cluster coordinator can run the decision remotely.
        """
        state, rows = self.observe(state, decayed=decayed)
        promos, spills = plan_tier_moves(self.planner, rows, self.cfg)
        return self.dispatch_planned(
            state, rows, promos, spills,
            reasons=self.planner.last_promote_reasons)

    def observe(self, state: IndexState, *, decayed: bool):
        """Apply accumulated touches/decay, then read the planner's
        observation rows (plain numpy, serializable).  Returns
        (state, rows)."""
        from . import version_manager as vm
        if self._counts.any():
            state = touch_round(state, jnp.asarray(self._counts))
            self._counts[:] = 0
        if not decayed:
            state = decay_round(state)
        rows = {
            "heat": np.asarray(state.heat),
            "spilled": np.asarray(state.tier_spilled),
            "alloc": np.asarray(state.allocated),
            "status": np.asarray(vm.unpack_status(state.rec_meta)),
            "lengths": np.asarray(state.lengths),
            "used": np.asarray(state.used),
        }
        return state, rows

    def dispatch_planned(self, state: IndexState, rows: dict, promos,
                         spills, reasons: Optional[dict] = None):
        """Execution half of ``dispatch``: start the DMA for an
        already-planned move set (``rows`` must be the observation the
        plan was made from — its lengths/used become the spill staleness
        signatures).  Returns (state, plan | None)."""
        promos = np.asarray(promos, np.int64).ravel()
        spills = np.asarray(spills, np.int64).ravel()
        lengths, used = rows["lengths"], rows["used"]
        if not len(promos) and not len(spills):
            return state, None
        if self.obs is not None and (len(promos) or len(spills)):
            reasons = reasons or {}
            self._emit(
                "tier_plan",
                promotes=[{"pid": int(p),
                           "reason": reasons.get(int(p), "search-heat")}
                          for p in promos],
                spills=[{"pid": int(p), "reason": "watermark-cold"}
                        for p in spills])
        B = self.planner.max_moves
        spill_pids = np.full(B, -1, np.int32)
        spill_pids[:len(spills)] = spills
        spill_tiles = gather_tiles(state, jnp.asarray(spill_pids))
        spill_tiles.copy_to_host_async()
        promote_pids = np.full(B, -1, np.int32)
        promote_pids[:len(promos)] = promos
        promote_tiles = None
        if len(promos):
            C, d = state.vectors.shape[1:]
            staged = np.zeros((B, C, d), np.float32)
            for i, pid in enumerate(promos):
                staged[i] = self.pool.get(int(pid))
            promote_tiles = jax.device_put(staged)
        safe = np.clip(spill_pids, 0, self.cfg.max_postings - 1)
        plan = TierPlan(
            spill_pids=spill_pids, spill_tiles=spill_tiles,
            spill_sig_len=lengths[safe].copy(),
            spill_sig_used=used[safe].copy(),
            promote_pids=promote_pids, promote_tiles=promote_tiles)
        return state, plan

    def reconcile(self, state: IndexState, plan: Optional[TierPlan]):
        """Tick-end half: validate the dispatched plan against the
        CURRENT state and commit the still-fresh lanes.  Returns
        (state, n_spilled, n_promoted).

        Promotes first (structurally-due postings unblock the round's
        split/merge next tick), validated by pool membership — a
        mid-tick ``promote_retrain_pinned`` may have promoted a planned
        pid already.  Spills are validated by the staleness signature:
        a lane whose posting was appended to, compacted, marked, or
        already spilled since dispatch is dropped (its tile bytes are
        stale) and simply re-planned next tick.
        """
        from . import version_manager as vm
        if plan is None:
            return state, 0, 0
        cfg = self.cfg
        p_pids = plan.promote_pids
        p_valid = np.array([int(p) >= 0 and int(p) in self.pool
                            for p in p_pids])
        n_p = int(p_valid.sum())
        if n_p:
            for pid in p_pids[p_valid]:
                self.pool.take(int(pid))     # bytes already staged
            state = promote_round(state, cfg, jnp.asarray(p_pids),
                                  plan.promote_tiles,
                                  jnp.asarray(p_valid))
        s_pids = plan.spill_pids
        safe = np.clip(s_pids, 0, cfg.max_postings - 1)
        status = np.asarray(vm.unpack_status(state.rec_meta))
        s_valid = ((s_pids >= 0)
                   & (status[safe] == STATUS_NORMAL)
                   & ~np.asarray(state.tier_spilled)[safe]
                   & np.asarray(state.allocated)[safe]
                   & (np.asarray(state.lengths)[safe]
                      == plan.spill_sig_len)
                   & (np.asarray(state.used)[safe]
                      == plan.spill_sig_used))
        n_s = int(s_valid.sum())
        if n_s:
            tiles = np.asarray(plan.spill_tiles)   # async copy landed
            for i in np.flatnonzero(s_valid):
                self.pool.put(int(s_pids[i]), tiles[i])
            state = spill_round(state, cfg, jnp.asarray(s_pids),
                                jnp.asarray(s_valid))
        self._commit(
            spilled=[int(p) for p in s_pids[s_valid]],
            promoted=[int(p) for p in p_pids[p_valid]],
            dropped_spills=[{"pid": int(p),
                             "reason": "stale-signature"}
                            for p in s_pids[(s_pids >= 0) & ~s_valid]],
            dropped_promotes=[{"pid": int(p),
                               "reason": "pool-missing"}
                              for p in p_pids[(p_pids >= 0)
                                              & ~p_valid]])
        return state, n_s, n_p

    def force_spill(self, state: IndexState, n: int):
        """Spill the ``n`` coldest hot NORMAL postings now (test and
        benchmark hook; ignores the watermark and cold threshold)."""
        from . import version_manager as vm
        pids = self.planner.force_spills(
            int(n), np.asarray(state.heat), np.asarray(state.tier_spilled),
            np.asarray(state.allocated),
            np.asarray(vm.unpack_status(state.rec_meta)))
        return self._spill(state, pids, reason="forced")

    def force_promote(self, state: IndexState, n=None):
        """Promote up to ``n`` spilled postings (all of them when None),
        hottest first."""
        pids = self.pool.pids()
        if len(pids):
            heat = np.asarray(state.heat)
            pids = pids[np.argsort(-heat[pids], kind="stable")]
        if n is not None:
            pids = pids[:int(n)]
        return self._promote(state, pids, reason="forced")

    def promote_retrain_pinned(self, state: IndexState):
        """Quant interplay, shared by both drivers: ``pq.retrain_round``
        re-encodes postings pinned to the slot it is about to evict FROM
        THEIR DEVICE FLOAT TILES — a spilled posting's tile is zeroed,
        so any spilled posting pinned to the evicted slot must be
        promoted first (it re-spills later if still cold).  Returns
        (state, n_promoted); call immediately before the retrain."""
        if not len(self.pool):
            return state, 0
        evict = (int(state.pq_active) + 1) % self.cfg.pq_versions
        pslot = np.asarray(state.pq_posting_slot)
        sp = self.pool.pids()
        pinned = sp[pslot[sp] == evict]
        if not pinned.size:
            return state, 0
        return self._promote(state, pinned, reason="retrain-pinned")

    # ---- move execution (chunked at the planner's batch width) --------

    def _spill(self, state: IndexState, pids, reason: str = ""):
        # no reason = internal re-derivation (``adopt``), which carries
        # no stats delta and therefore must not trace as a decision
        B = self.planner.max_moves
        M = self.cfg.max_postings
        n = 0
        for off in range(0, len(pids), B):
            chunk = np.asarray(pids[off:off + B], np.int32)
            padded = np.full(B, -1, np.int32)
            padded[:len(chunk)] = chunk
            valid = padded >= 0
            tiles = np.asarray(
                state.vectors[jnp.asarray(np.clip(padded, 0, M - 1))])
            for i, pid in enumerate(chunk):
                self.pool.put(int(pid), tiles[i])
            state = spill_round(state, self.cfg, jnp.asarray(padded),
                                jnp.asarray(valid))
            n += len(chunk)
        if reason and n:
            self._commit(spilled=[int(p) for p in pids[:n]], promoted=[],
                         dropped_spills=[], dropped_promotes=[],
                         reason=reason)
        return state, n

    def _promote(self, state: IndexState, pids, reason: str = ""):
        B = self.planner.max_moves
        C, d = state.vectors.shape[1:]
        n = 0
        for off in range(0, len(pids), B):
            chunk = np.asarray(pids[off:off + B], np.int32)
            padded = np.full(B, -1, np.int32)
            padded[:len(chunk)] = chunk
            # f32 staging; promote_round casts back to the storage dtype,
            # which is exact for every storage dtype narrower than f32
            tiles = np.zeros((B, C, d), np.float32)
            for i, pid in enumerate(chunk):
                tiles[i] = self.pool.take(int(pid))
            state = promote_round(state, self.cfg, jnp.asarray(padded),
                                  jnp.asarray(tiles),
                                  jnp.asarray(padded >= 0))
            n += len(chunk)
        if reason and n:
            self._commit(spilled=[], promoted=[int(p) for p in pids[:n]],
                         dropped_spills=[], dropped_promotes=[],
                         reason=reason)
        return state, n

    # ---- host-side exact serving --------------------------------------

    def rerank(self, state: IndexState, queries, found, scores):
        """Host exact rerank of a search's final candidate set."""
        if not self.rerank_host or not len(self.pool):
            return np.asarray(found), np.asarray(scores)
        found = np.asarray(found)
        safe = np.clip(found, 0, self.cfg.max_ids - 1)
        loc = np.asarray(state.id_loc[jnp.asarray(safe)])
        found, scores, n_sp = host_rerank(
            found, scores, queries, self.pool, loc,
            np.asarray(state.tier_spilled), self.cfg.capacity)
        if self._stats is not None:
            self._stats["search_spilled_hits"] += n_sp
        return found, scores

    def exact_merge(self, state: IndexState, queries, found, scores,
                    k: int):
        """Merge a device oracle result (spilled postings excluded) with
        a host scan of the pooled tiles."""
        from . import version_manager as vm
        sp = self.pool.pids()
        if len(sp) == 0:
            return np.asarray(found), np.asarray(scores)
        vis = np.asarray(vm.visible(state.rec_meta, state.allocated,
                                    state.global_version))
        sp = sp[vis[sp]]
        if len(sp) == 0:
            return np.asarray(found), np.asarray(scores)
        jsp = jnp.asarray(sp)
        ids_rows = np.asarray(state.ids[jsp])
        valid_rows = np.asarray(state.slot_valid[jsp])
        es, ei = host_exact_candidates(self.pool, sp, ids_rows,
                                       valid_rows, queries)
        return merge_topk(found, scores, es, ei, k)

    # ---- snapshot / restore -------------------------------------------

    def snapshot_fill(self, state: IndexState) -> IndexState:
        """A self-contained snapshot: spilled float tiles written back
        into a COPY of the device state (``tier_spilled`` stays set, so
        a restore re-derives residency).  Checkpoint-safe: the saved
        pytree holds every byte."""
        pids = self.pool.pids()
        if len(pids) == 0:
            return state
        tiles = np.stack([self.pool.get(int(p)) for p in pids])
        vectors = state.vectors.at[jnp.asarray(pids)].set(
            jnp.asarray(tiles).astype(state.vectors.dtype))
        return dataclasses_replace(state, vectors=vectors)

    def adopt(self, state: IndexState) -> IndexState:
        """Restore path: rebuild the host pool from a filled snapshot
        (see ``snapshot_fill``) and re-zero the spilled device tiles."""
        self.pool = HostTierPool()
        self._counts[:] = 0
        self.commit_log = []
        sp = np.flatnonzero(np.asarray(state.tier_spilled)
                            & np.asarray(state.allocated))
        # clear the flags, then re-spill through the normal path: the
        # pool captures the snapshot's exact tile bytes and the device
        # copies are re-zeroed — residency is fully re-derived from the
        # persisted ``tier_spilled`` flags
        state = dataclasses_replace(
            state, tier_spilled=jnp.zeros_like(state.tier_spilled))
        if sp.size:
            state, _ = self._spill(state, sp.astype(np.int32))
        return state

    def memory_tiers(self, state: IndexState) -> dict:
        from .types import state_tier_bytes
        return state_tier_bytes(state)


def merge_topk(found, scores, extra_scores, extra_ids, k: int):
    """Merge a device (Q, k) result with host candidate lists into the
    final top-k (scores ascending, -1 ids for missing)."""
    from ..kernels.posting_scan import BIG
    found = np.asarray(found)
    scores = np.asarray(scores, np.float32)
    all_s = np.concatenate([scores, extra_scores], axis=1)
    all_i = np.concatenate(
        [found, np.broadcast_to(extra_ids[None, :],
                                (found.shape[0], len(extra_ids)))], axis=1)
    order = np.argsort(all_s, axis=1, kind="stable")[:, :k]
    s = np.take_along_axis(all_s, order, axis=1)
    i = np.take_along_axis(all_i, order, axis=1)
    return np.where(s < BIG / 2, i, -1).astype(np.int32), s
