"""Core datatypes for the UBIS updatable cluster-based index.

The index is a fixed-shape JAX pytree so that every operation (search,
insert round, split, merge, reassign) is a jit-compiled SPMD program.
Postings are fixed-capacity tiles of a pooled ``(max_postings, capacity,
dim)`` array; a free-list provides allocation; the paper's 8-byte
*Posting Recorder* word is packed into two ``uint32`` lanes per posting
(see ``version_manager.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Posting status codes (paper Section IV-B1: 2 bits, four states).
# ---------------------------------------------------------------------------
STATUS_NORMAL = 0
STATUS_SPLITTING = 1
STATUS_MERGING = 2
STATUS_DELETED = 3

# Sentinel for "no successor" in the recorder's new-postings region.
NO_SUCC = 0xFFFF
# Sentinel for empty id slots.
NO_ID = -1

# ---------------------------------------------------------------------------
# Background-op kind codes (the int lane of a batched background round).
# ---------------------------------------------------------------------------
KIND_NONE = 0
KIND_SPLIT = 1
KIND_MERGE = 2
KIND_COMPACT = 3


@dataclasses.dataclass(frozen=True)
class UBISConfig:
    """Static configuration (hashable; safe as a jit static argument)."""

    dim: int = 64
    max_postings: int = 4096          # posting pool size (must be < 0xFFFF)
    capacity: int = 96                # physical tile size (>= l_max slack)
    l_min: int = 10                   # merge threshold  (paper Section V-A)
    l_max: int = 80                   # split threshold  (paper Section V-A)
    balance_factor: float = 0.15      # paper Fig. 9 default
    nprobe: int = 32                  # postings probed per query (paper: 32)
    cache_capacity: int = 2048        # vector cache (Section IV-B2)
    graph_degree: int = 8             # centroid neighbourhood graph degree
    kmeans_iters: int = 6             # Lloyd iterations for (2-)means
    max_ids: int = 1 << 20            # id -> location map size
    succ_chase_depth: int = 4         # bounded DELETED pointer chasing
    dtype: Any = jnp.float32          # vector storage dtype
    mode: str = "ubis"                # "ubis" | "spfresh" (baseline semantics)
    use_pallas: str = "auto"          # "auto" | "on" | "off"  (kernel backend)
    # distributed search: cap owned probes scanned per shard (0 = nprobe);
    # ~4x phase-2 work reduction on a 16-way pod (EXPERIMENTS.md §Perf)
    shard_probe_cap: int = 0
    # --- product-quantization plane (quant/pq.py) ----------------------
    use_pq: bool = False              # two-stage ADC search + code upkeep
    pq_m: int = 8                     # subspaces per vector (codes: m bytes)
    pq_ksub: int = 256                # centroids per subspace (uint8 codes)
    pq_versions: int = 2              # codebook version slots kept live
    pq_sample: int = 2048             # training sample size (re-train)
    rerank_k: int = 64                # float candidates exact-reranked
    # --- cold-tier host spill (core/tier.py) ---------------------------
    # Spilled postings keep centroids + PQ codes device-resident; their
    # float tiles move to a pinned host pool (the FreshDiskANN
    # billion-scale tier).  Requires use_pq: the codes are what serves a
    # spilled posting at search time (ADC-only, optional host rerank).
    use_tier: bool = False            # enable cold-tier float-tile spill
    tier_hot_max: int = 0             # device high-watermark: max float-
    #                                   resident live postings (0 = no cap;
    #                                   spill only via force_spill)
    tier_cold_heat: int = 1           # heat <= this -> spill candidate
    tier_promote_heat: int = 8        # heat >= this -> promote (search-heat)

    def __post_init__(self):
        assert self.max_postings < NO_SUCC, "successor ids are 16-bit"
        assert self.capacity >= self.l_max, "tile must hold an over-full posting"
        assert self.capacity <= 2 * self.l_max, \
            "median-bisection split guard needs capacity/2 <= l_max"
        assert self.mode in ("ubis", "spfresh")
        if self.use_pq:
            assert self.dim % self.pq_m == 0, "pq_m must divide dim"
        assert 2 <= self.pq_ksub <= 256, "codes are uint8"
        assert self.pq_versions >= 2, "need >= 2 slots for lazy re-encode"
        assert self.rerank_k >= 1
        if self.use_tier:
            assert self.use_pq, \
                "use_tier requires use_pq (spilled postings serve ADC-only)"

    @property
    def pq_m_eff(self) -> int:
        """Subspace count actually used for array shapes.  With the
        quant plane off the (always-present, fixed-pytree-shape) code
        arrays are dead weight, so they shrink to one subspace; with it
        on, the __post_init__ assert guarantees pq_m divides dim."""
        return self.pq_m if self.use_pq else 1

    @property
    def pq_dsub(self) -> int:
        return self.dim // self.pq_m_eff

    @property
    def is_ubis(self) -> bool:
        return self.mode == "ubis"


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class IndexState:
    """The full index as a pytree of device arrays (all fixed shape).

    Shapes use ``M = max_postings``, ``C = capacity``, ``d = dim``,
    ``K = cache_capacity``, ``N = max_ids``.
    """

    # --- posting tiles -----------------------------------------------------
    vectors: jax.Array        # (M, C, d) vector payloads
    ids: jax.Array            # (M, C) int32 external ids, NO_ID = empty slot
    slot_valid: jax.Array     # (M, C) bool, live (non-tombstoned) slots
    used: jax.Array           # (M,) int32 append high-water mark per tile
    lengths: jax.Array        # (M,) int32 live vector count per posting
    centroids: jax.Array      # (M, d)
    # --- posting recorder (version manager) -------------------------------
    rec_meta: jax.Array       # (M,) uint32: status(2) | weight(30)
    rec_succ: jax.Array       # (M,) uint32: succ1(16) | succ2(16)
    allocated: jax.Array      # (M,) bool, slot is in use (not on free list)
    # --- centroid neighbourhood graph --------------------------------------
    nbrs: jax.Array           # (M, G) int32 neighbour posting ids, -1 pad
    # --- vector cache (Section IV-B2, splitting/merging branch) -----------
    cache_vecs: jax.Array     # (K, d)
    cache_ids: jax.Array      # (K,) int32
    cache_target: jax.Array   # (K,) int32 posting the vector was bound for
    cache_valid: jax.Array    # (K,) bool
    # --- allocation + versions ---------------------------------------------
    free_list: jax.Array      # (M,) int32 stack of free posting ids
    free_top: jax.Array       # () int32 number of entries on the free stack
    global_version: jax.Array  # () uint32 monotone version counter
    # --- id -> flat location (pid * C + slot), -1 if absent ---------------
    id_loc: jax.Array         # (N,) int32
    # --- product-quantization plane (quant/pq.py; V = pq_versions) ---------
    # codes are subspace-major (m before C) so the ADC kernel streams
    # (1, m, C) tiles with the lane dim = capacity, like the float tiles.
    codes: jax.Array          # (M, m, C) uint8 PQ codes per slot
    pq_codebooks: jax.Array   # (V, m, ksub, dsub) f32 versioned codebooks
    pq_slot_gen: jax.Array    # (V,) uint32 generation held by each slot
    pq_active: jax.Array      # () int32 slot new codes are written under
    pq_posting_slot: jax.Array  # (M,) int32 codebook slot of each posting
    # --- cold-tier residency (core/tier.py) --------------------------------
    # heat: per-posting touch counter (probes + accepted appends), decayed
    # inside the background round; tier_spilled marks postings whose float
    # tile lives in the driver's pinned host pool (device copy zeroed,
    # codes/centroid stay device-resident).
    heat: jax.Array           # (M,) uint32 touch counter
    tier_spilled: jax.Array   # (M,) bool float tile is host-resident

    def num_alive(self) -> jax.Array:
        from .version_manager import unpack_status
        status = unpack_status(self.rec_meta)
        return jnp.sum((status != STATUS_DELETED) & self.allocated)

    def live_vector_count(self) -> jax.Array:
        """Vectors in *visible* postings (retired postings keep their tile
        data until GC but no longer own any live vectors)."""
        from .version_manager import unpack_status
        status = unpack_status(self.rec_meta)
        vis = self.allocated & (status != STATUS_DELETED)
        return jnp.sum(self.lengths * vis)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BackgroundRound:
    """Outcome of one batched background round (all int32 scalars).

    One of these is the *only* device->host transfer the driver makes per
    background tick; every counter the scheduler/benchmarks need rides in
    the same small struct.
    """

    executed: jax.Array    # ops that ran (splits + merges + compacts)
    n_split: jax.Array     # true 2-means splits
    n_merge: jax.Array     # merges (incl. partnerless self-rebuilds)
    n_compact: jax.Array   # compactions (incl. split ops demoted in-round)
    deferred: jax.Array    # ops reverted to NORMAL (no slots / conflicts)
    moved_out: jax.Array   # small-side vectors appended to nearer postings
    spilled: jax.Array     # move-outs that diverted to the vector cache
    reassigned: jax.Array  # fused post-op reassign moves
    freed: jax.Array       # empty split-b slots returned to the free list


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RoundResult:
    """Outcome of one foreground update round (fixed shape, padded)."""

    accepted: jax.Array   # (J,) bool appended directly to a posting
    cached: jax.Array     # (J,) bool parked in the vector cache
    rejected: jax.Array   # (J,) bool dropped (SPFresh lock model / cache full)
    target: jax.Array     # (J,) int32 resolved posting id (-1 if rejected)


def empty_state(cfg: UBISConfig) -> IndexState:
    """A fully-deallocated index (build() populates it)."""
    M, C, d = cfg.max_postings, cfg.capacity, cfg.dim
    K, G, N = cfg.cache_capacity, cfg.graph_degree, cfg.max_ids
    return IndexState(
        vectors=jnp.zeros((M, C, d), cfg.dtype),
        ids=jnp.full((M, C), NO_ID, jnp.int32),
        slot_valid=jnp.zeros((M, C), jnp.bool_),
        used=jnp.zeros((M,), jnp.int32),
        lengths=jnp.zeros((M,), jnp.int32),
        centroids=jnp.zeros((M, d), cfg.dtype),
        rec_meta=jnp.full((M,), 3, jnp.uint32),  # STATUS_DELETED, weight 0
        rec_succ=jnp.full((M,), (NO_SUCC << 16) | NO_SUCC, jnp.uint32),
        allocated=jnp.zeros((M,), jnp.bool_),
        nbrs=jnp.full((M, G), -1, jnp.int32),
        cache_vecs=jnp.zeros((K, d), cfg.dtype),
        cache_ids=jnp.full((K,), NO_ID, jnp.int32),
        cache_target=jnp.full((K,), -1, jnp.int32),
        cache_valid=jnp.zeros((K,), jnp.bool_),
        free_list=jnp.arange(M - 1, -1, -1, dtype=jnp.int32),
        free_top=jnp.array(M, jnp.int32),
        global_version=jnp.array(0, jnp.uint32),
        id_loc=jnp.full((N,), -1, jnp.int32),
        codes=jnp.zeros((M, cfg.pq_m_eff, C), jnp.uint8),
        pq_codebooks=jnp.zeros(
            (cfg.pq_versions, cfg.pq_m_eff, cfg.pq_ksub, cfg.pq_dsub),
            jnp.float32),
        pq_slot_gen=jnp.zeros((cfg.pq_versions,), jnp.uint32),
        pq_active=jnp.array(0, jnp.int32),
        pq_posting_slot=jnp.zeros((M,), jnp.int32),
        heat=jnp.zeros((M,), jnp.uint32),
        tier_spilled=jnp.zeros((M,), jnp.bool_),
    )


def state_memory_bytes(state: IndexState) -> int:
    """Host-side accounting of device bytes held by the index."""
    return int(
        sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(state))
    )


def tile_bytes(state: IndexState) -> int:
    """Bytes of ONE float posting tile (the unit the cold tier moves)."""
    C, d = state.vectors.shape[1:]
    return int(C * d * state.vectors.dtype.itemsize)


def state_tier_bytes(state: IndexState) -> dict:
    """Device/host byte split under cold-tier residency.

    ``host`` is the float bytes of spilled tiles (they live in the
    driver's pinned host pool; the device copies are zeroed); ``device``
    is everything else, so ``device + host == state_memory_bytes`` — the
    untiered total — by construction.  JAX pytrees are fixed-shape, so
    the zeroed device tiles still occupy their allocation; this split
    reports what a paging allocator holds per tier, which is the honest
    HBM figure for the tier's effect (benchmarks additionally report the
    live-tile payload split, see ``benchmarks.figures.figmem``).
    """
    host = int(jax.device_get(jnp.sum(state.tier_spilled))) * \
        tile_bytes(state)
    return {"device": state_memory_bytes(state) - host, "host": host}
