"""Update data path: conflict-free batched appends, insert/delete rounds.

This implements the *high-concurrency controller* (paper IV-B2).  A
round is one jitted SPMD program processing a padded batch of jobs:

  1. resolve targets (hinted jobs chase DELETED successor pointers;
     fresh jobs locate the nearest insertable centroid);
  2. branch on Posting Recorder status — NORMAL -> direct append,
     SPLITTING/MERGING -> vector cache (UBIS) or reject (SPFresh's
     posting-lock model), DELETED dead-end -> relocate;
  3. resolve conflicts *ahead of* the scatter: jobs are ranked within
     their target-posting group (stable job order) and accepted while
     capacity lasts — the deterministic equivalent of the paper's CAS
     (exactly one winner per slot, no retries);
  4. one batched scatter applies all winners; losers divert to the
     cache or are rejected, never silently dropped.

All functions are pure ``state -> state`` transforms; ``cfg`` is static.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels import ops
from . import version_manager as vm
from .types import (NO_ID, NO_SUCC, STATUS_DELETED, STATUS_MERGING,
                    STATUS_NORMAL, STATUS_SPLITTING, IndexState, RoundResult,
                    UBISConfig)


# ---------------------------------------------------------------------------
# small combinators
# ---------------------------------------------------------------------------

def group_ranks(keys: jax.Array, valid: jax.Array) -> jax.Array:
    """Rank of each job within its equal-key group, stable job order.

    Invalid jobs get arbitrary (large) ranks.  O(J log J).
    """
    J = keys.shape[0]
    big = jnp.int32(jnp.iinfo(jnp.int32).max)
    key = jnp.where(valid, keys, big)
    order = jnp.argsort(key, stable=True)
    ks = key[order]
    idx = jnp.arange(J, dtype=jnp.int32)
    seg_start = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    seg_first = jax.lax.associative_scan(
        jnp.maximum, jnp.where(seg_start, idx, 0))
    ranks_sorted = idx - seg_first
    return jnp.zeros((J,), jnp.int32).at[order].set(ranks_sorted)


def oob(idx, mask, size):
    """Scatter-index sentinel: ``mode="drop"`` only drops OUT-OF-BOUNDS
    indices, and -1 is *in bounds* (wraps).  Masked entries therefore map
    to ``size``, which is genuinely out of range."""
    return jnp.where(mask, idx, jnp.asarray(size, idx.dtype))


def _flat_set(arr2d, flat_idx, values):
    """Scatter rows into a (M, C, ...) array viewed as (M*C, ...)."""
    shp = arr2d.shape
    flat = arr2d.reshape((shp[0] * shp[1],) + shp[2:])
    flat = flat.at[flat_idx].set(values, mode="drop")
    return flat.reshape(shp)


# ---------------------------------------------------------------------------
# allocation
# ---------------------------------------------------------------------------

def alloc_postings(state: IndexState, cfg: UBISConfig, k: int,
                   centroids_new: jax.Array, weight) -> tuple:
    """Pop ``k`` posting slots from the free list and initialise them.

    Returns (state, pids (k,) int32).  Caller must ensure free_top >= k
    (the driver checks host-side before enqueuing structural ops).
    """
    idx = state.free_top - 1 - jnp.arange(k, dtype=jnp.int32)
    pids = state.free_list[idx]
    meta = vm.pack_meta(jnp.full((k,), STATUS_NORMAL, jnp.uint32),
                        jnp.broadcast_to(jnp.asarray(weight, jnp.uint32), (k,)))
    succ = jnp.full((k,), (NO_SUCC << 16) | NO_SUCC, jnp.uint32)
    state = dataclasses_replace(
        state,
        ids=state.ids.at[pids].set(NO_ID),
        slot_valid=state.slot_valid.at[pids].set(False),
        used=state.used.at[pids].set(0),
        lengths=state.lengths.at[pids].set(0),
        centroids=state.centroids.at[pids].set(
            centroids_new.astype(state.centroids.dtype)),
        rec_meta=state.rec_meta.at[pids].set(meta),
        rec_succ=state.rec_succ.at[pids].set(succ),
        allocated=state.allocated.at[pids].set(True),
        free_top=state.free_top - k,
        # fresh postings write codes under the active codebook generation
        pq_posting_slot=state.pq_posting_slot.at[pids].set(state.pq_active),
        # fresh postings are float-resident and born warm (cold-tier plane)
        heat=state.heat.at[pids].set(jnp.uint32(cfg.tier_promote_heat)),
        tier_spilled=state.tier_spilled.at[pids].set(False),
    )
    return state, pids


def free_postings(state: IndexState, pids: jax.Array,
                  valid: jax.Array) -> IndexState:
    """Push reclaimed posting ids back onto the free stack (GC).

    Also sweeps the Posting Recorder: any successor pointer referencing
    a reclaimed id is cleared, so a chaser can never follow a recycled
    slot into an unrelated posting — it dead-ends and re-locates.
    """
    k = pids.shape[0]
    M = state.rec_succ.shape[0]
    rank = group_ranks(jnp.zeros_like(pids), valid)
    slot = state.free_top + rank
    tgt = oob(slot, valid, M)
    free_list = state.free_list.at[tgt].set(pids, mode="drop")
    n = jnp.sum(valid.astype(jnp.int32))
    safe_pids = oob(pids, valid, M)
    allocated = state.allocated.at[safe_pids].set(False, mode="drop")
    succ = jnp.full((k,), (NO_SUCC << 16) | NO_SUCC, jnp.uint32)
    rec_succ = state.rec_succ.at[safe_pids].set(succ, mode="drop")
    # recycled slots re-enter the pool float-resident and cold
    heat = state.heat.at[safe_pids].set(jnp.uint32(0), mode="drop")
    tier_spilled = state.tier_spilled.at[safe_pids].set(False, mode="drop")
    # sweep dangling successor references to the reclaimed ids
    freed_mask = jnp.zeros((M,), bool).at[safe_pids].set(True, mode="drop")
    s1, s2 = vm.succ_ids(rec_succ)
    s1 = jnp.where((s1 >= 0) & freed_mask[jnp.clip(s1, 0)], -1, s1)
    s2 = jnp.where((s2 >= 0) & freed_mask[jnp.clip(s2, 0)], -1, s2)
    rec_succ = vm.pack_succ(jnp.where(s1 < 0, NO_SUCC, s1),
                            jnp.where(s2 < 0, NO_SUCC, s2))
    return dataclasses_replace(state, free_list=free_list,
                               free_top=state.free_top + n,
                               allocated=allocated, rec_succ=rec_succ,
                               heat=heat, tier_spilled=tier_spilled)


def dataclasses_replace(state: IndexState, **kw) -> IndexState:
    import dataclasses
    return dataclasses.replace(state, **kw)


def rebuild_free_stack(state: IndexState) -> IndexState:
    """Recompute a canonical free stack from ``allocated``.

    The sharded background round leaves ``free_list``/``free_top``
    fail-safe-empty (per-shard local views cannot form one global
    stack); call this after gathering such a state back to one device
    before handing it to any free-stack consumer (driver, alloc, GC).
    """
    order = jnp.argsort(state.allocated, stable=True)   # free pids first
    n_free = jnp.sum(~state.allocated).astype(jnp.int32)
    return dataclasses_replace(state, free_list=order.astype(jnp.int32),
                               free_top=n_free)


def ensure_free_stack(state: IndexState, check: bool = True) -> IndexState:
    """Snapshot-path guard: rebuild the free stack and *assert* it is
    canonical before any single-device reuse of a gathered state.

    The sharded background/GC round returns ``free_list``/``free_top``
    fail-safe EMPTY (per-shard local views cannot form one global
    stack).  This is the encoded form of that contract: every gather ->
    single-device hand-off (``ShardedUBISDriver.snapshot``) goes through
    here, so a state whose stack would alias live postings can never
    escape to the driver/alloc/GC free-stack consumers.
    """
    state = rebuild_free_stack(state)
    if check:
        import numpy as np
        allocated = np.asarray(state.allocated)
        top = int(state.free_top)
        free = np.asarray(state.free_list)[:top]
        assert top + int(allocated.sum()) == allocated.shape[0], \
            "free stack disagrees with the allocated bitmap"
        assert len(np.unique(free)) == top, "free stack holds duplicates"
        assert not allocated[free].any(), \
            "free stack aliases a live posting"
    return state


# ---------------------------------------------------------------------------
# the conflict-free batched append (shared by every write path)
# ---------------------------------------------------------------------------

def batched_append(state: IndexState, cfg: UBISConfig, vecs, ids, pids,
                   valid, update_id_loc: bool = True):
    """Append jobs to their target postings; winners determined by
    group rank vs. remaining tile capacity.  Returns (state, ok, flat)
    where ``flat`` is the written slot index pid*C+slot (OOB sentinel for
    losers).  ``update_id_loc=False`` lets the sharded path merge the
    (replicated) id map across shards itself."""
    C = cfg.capacity
    ranks = group_ranks(pids, valid)
    safe_pid = jnp.clip(pids, 0, cfg.max_postings - 1)
    slot = state.used[safe_pid] + ranks
    ok = valid & (pids >= 0) & (slot < C)
    MC = cfg.max_postings * C
    flat = oob(safe_pid * C + slot, ok, MC)
    vectors = _flat_set(state.vectors, flat, vecs.astype(state.vectors.dtype))
    ids_arr = _flat_set(state.ids, flat, ids.astype(jnp.int32))
    slot_valid = _flat_set(state.slot_valid, flat,
                           jnp.ones(ids.shape, jnp.bool_))
    add_pid = oob(pids, ok, cfg.max_postings)
    used = state.used.at[add_pid].add(1, mode="drop")
    lengths = state.lengths.at[add_pid].add(1, mode="drop")
    id_loc = state.id_loc
    if update_id_loc:
        id_loc = id_loc.at[oob(ids, ok, cfg.max_ids)].set(flat, mode="drop")
    codes = state.codes
    if cfg.use_pq:
        # quant-plane invariant: every float write carries its code,
        # encoded under the TARGET posting's codebook slot (postings pin
        # a codebook generation; appends must match it, not the active
        # one).  Encode under every slot (V small, static), select per
        # job.  Encode the post-storage-cast value so decode agrees with
        # the stored bytes under non-f32 dtypes.
        from ..quant import pq
        x = vecs.astype(state.vectors.dtype).astype(jnp.float32)
        codes_all = pq.encode_all_versions(state.pq_codebooks, x)
        tslot = jnp.clip(state.pq_posting_slot[safe_pid], 0,
                         cfg.pq_versions - 1)
        code_j = jnp.take_along_axis(
            codes_all.transpose(1, 0, 2), tslot[:, None, None], axis=1
        )[:, 0]                                             # (J, m)
        codes = codes.at[oob(pids, ok, cfg.max_postings), :, slot].set(
            code_j, mode="drop")
    state = dataclasses_replace(state, vectors=vectors, ids=ids_arr,
                                slot_valid=slot_valid, used=used,
                                lengths=lengths, id_loc=id_loc,
                                codes=codes)
    return state, ok, flat


def cache_append(state: IndexState, cfg: UBISConfig, vecs, ids, targets,
                 want):
    """Park jobs in the vector cache (paper IV-B2 branch 3).

    id_loc encoding for cached vectors: ``-2 - cache_slot``."""
    K = cfg.cache_capacity
    ranks = group_ranks(jnp.zeros_like(targets), want)
    slot_order = jnp.argsort(state.cache_valid, stable=True)  # free first
    nfree = jnp.sum(~state.cache_valid)
    ok = want & (ranks < nfree)
    slot = slot_order[jnp.clip(ranks, 0, K - 1)].astype(jnp.int32)
    tgt = oob(slot, ok, K)
    cache_vecs = state.cache_vecs.at[tgt].set(
        vecs.astype(state.cache_vecs.dtype), mode="drop")
    cache_ids = state.cache_ids.at[tgt].set(ids.astype(jnp.int32),
                                            mode="drop")
    cache_target = state.cache_target.at[tgt].set(targets.astype(jnp.int32),
                                                  mode="drop")
    cache_valid = state.cache_valid.at[tgt].set(True, mode="drop")
    id_loc = state.id_loc.at[oob(ids, ok, cfg.max_ids)].set(
        -2 - slot, mode="drop")
    state = dataclasses_replace(
        state, cache_vecs=cache_vecs, cache_ids=cache_ids,
        cache_target=cache_target, cache_valid=cache_valid, id_loc=id_loc)
    return state, ok


def cache_take(state: IndexState, cfg: UBISConfig, n: int):
    """Pop up to ``n`` cached vectors for re-insertion (background drain).

    Returns (state, vecs, ids, targets, taken)."""
    prio = jnp.argsort(~state.cache_valid, stable=True)  # valid entries first
    slots = prio[:n]
    taken = state.cache_valid[slots]
    vecs = state.cache_vecs[slots]
    ids = state.cache_ids[slots]
    targets = state.cache_target[slots]
    cache_valid = state.cache_valid.at[oob(slots, taken, cfg.cache_capacity)
                                        ].set(False, mode="drop")
    # in-flight: id_loc repointed by the follow-up insert round
    state = dataclasses_replace(state, cache_valid=cache_valid)
    return state, vecs, ids, targets, taken


# ---------------------------------------------------------------------------
# foreground rounds
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def insert_round(state: IndexState, cfg: UBISConfig, vecs, ids, valid,
                 hints):
    """One foreground insert round over a padded job batch.

    hints: (J,) int32 precomputed target posting (-1 = locate fresh);
    used by cache drains and reassignments, and the path that exercises
    the paper's DELETED-branch pointer chasing.
    """
    status = vm.unpack_status(state.rec_meta)
    # spilled postings cannot take appends (their float tile is host-
    # resident): locate routes around them, so fresh vectors always land
    # in a float-resident posting.  All-False mask when tiering is off.
    insertable = (state.allocated & (status != STATUS_DELETED)
                  & ~state.tier_spilled)

    has_hint = hints >= 0
    chased, dead_end = vm.chase_successors(
        state.rec_meta, state.rec_succ, state.allocated, state.centroids,
        jnp.maximum(hints, 0), vecs, cfg.succ_chase_depth)
    chased_ok = (has_hint & ~dead_end & state.allocated[chased]
                 & ~state.tier_spilled[chased])

    scores = ops.centroid_score(vecs, state.centroids, insertable,
                                backend=cfg.use_pallas)
    located = jnp.argmin(scores, axis=-1).astype(jnp.int32)
    pid = jnp.where(chased_ok, chased, located)

    st = status[pid]
    # a resolved pid can still be spilled when NO insertable posting
    # exists (locate's argmin over an all-masked row is arbitrary): a
    # spilled posting must never take a direct float append, so such
    # jobs take the in-flux branch (cache / reject) instead
    sp_pid = state.tier_spilled[pid]
    normal = (st == STATUS_NORMAL) & ~sp_pid
    in_flux = ((st == STATUS_SPLITTING) | (st == STATUS_MERGING)
               | ((st == STATUS_NORMAL) & sp_pid))

    direct = valid & normal
    state, ok, _ = batched_append(state, cfg, vecs, ids,
                                  jnp.where(direct, pid, -1), direct)
    overflow = direct & ~ok

    if cfg.is_ubis:
        to_cache = valid & (in_flux | overflow)
        state, cached = cache_append(state, cfg, vecs, ids, pid, to_cache)
    else:  # SPFresh lock model: blocked jobs fail this round
        cached = jnp.zeros_like(valid)

    accepted = direct & ok
    rejected = valid & ~accepted & ~cached
    state = dataclasses_replace(
        state, global_version=state.global_version + jnp.uint32(1))
    touched = jnp.zeros((cfg.max_postings,), bool).at[
        oob(pid, accepted, cfg.max_postings)].set(True, mode="drop")
    result = RoundResult(accepted=accepted, cached=cached, rejected=rejected,
                         target=jnp.where(valid, pid, -1))
    return state, result, touched


def apply_tombstones(state: IndexState, cfg: UBISConfig, safe_ids, loc,
                     in_post, in_cache, *, base=0):
    """The shared delete kernel (UBIS semantics), parameterized by the
    caller's owner span.

    ``loc`` carries GLOBAL flat tile locations; only locations inside
    ``[base, base + span)`` (``span`` = this state's local pool in flat
    slots) are written to the tile arrays — the owner-span masking the
    sharded round needs, a no-op for the single-device caller
    (``base=0``, span = the whole pool).  The cache and ``id_loc``
    updates are computed from the (replicated) inputs unconditionally,
    which is what keeps the sharded replicas in sync with zero
    collectives.  Used by both ``delete_round`` and
    ``sharded.make_sharded_delete`` so the two cannot drift.
    """
    C = cfg.capacity
    M_local = state.lengths.shape[0]
    span = M_local * C
    lloc = loc - base
    mine = in_post & (lloc >= 0) & (lloc < span)
    flat = oob(lloc, mine, span)
    slot_valid = _flat_set(state.slot_valid, flat,
                           jnp.zeros(loc.shape, jnp.bool_))
    pid = oob(lloc // C, mine, M_local)
    lengths = state.lengths.at[pid].add(-1, mode="drop")
    cslot = oob(-2 - loc, in_cache, cfg.cache_capacity)
    cache_valid = state.cache_valid.at[cslot].set(False, mode="drop")
    done = in_post | in_cache
    id_loc = state.id_loc.at[oob(safe_ids, done, cfg.max_ids)].set(
        -1, mode="drop")
    state = dataclasses_replace(
        state, slot_valid=slot_valid, lengths=lengths,
        cache_valid=cache_valid, id_loc=id_loc,
        global_version=state.global_version + jnp.uint32(1))
    return state, done


@functools.partial(jax.jit, static_argnames=("cfg",))
def delete_round(state: IndexState, cfg: UBISConfig, del_ids, valid):
    """Mark a padded batch of external ids as deleted (tombstones)."""
    C = cfg.capacity
    safe = jnp.clip(del_ids, 0, cfg.max_ids - 1)
    loc = state.id_loc[safe]
    first = vm.first_occurrence_mask(safe) & valid
    in_post = first & (loc >= 0)
    in_cache = first & (loc <= -2)

    if not cfg.is_ubis:
        # SPFresh lock model: deletes on non-NORMAL postings are blocked.
        pid_all = jnp.clip(loc, 0) // C
        st = vm.unpack_status(state.rec_meta[pid_all])
        blocked = in_post & (st != STATUS_NORMAL)
        in_post = in_post & ~blocked
    else:
        blocked = jnp.zeros_like(valid)

    state, done = apply_tombstones(state, cfg, safe, loc, in_post, in_cache)
    return state, done, blocked


# ---------------------------------------------------------------------------
# recorder transitions used by the background scheduler
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("status",))
def mark_status(state: IndexState, pids, status: int):
    """Transition a batch of postings to SPLITTING/MERGING/NORMAL (the
    'window' phase that makes the vector cache functionally necessary)."""
    rec_meta = vm.transition(state.rec_meta, pids, status)
    return dataclasses_replace(
        state, rec_meta=rec_meta,
        global_version=state.global_version + jnp.uint32(1))
