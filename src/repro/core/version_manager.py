"""Fine-grained version manager — the *Posting Recorder* (paper IV-B1).

The paper stores one 8-byte word per posting, mutated with CAS:

    status (2 bits) | weight/version (16 bits) | new-posting ids (rest)

We keep the same 8-byte budget as two ``uint32`` lanes per posting:

    rec_meta = status(2 bits) | weight(30 bits)
    rec_succ = succ1(16 bits) | succ2(16 bits)

and replace CAS with *deterministic batched transitions*: every round
computes, for each posting word, at most one winning write (first writer
in job order), applied with a single functional scatter.  This preserves
the CAS guarantee — exactly one successful mutation per word per round —
without retry loops, which is the TPU-native form of lock-freedom
(DESIGN.md Section 2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import NO_SUCC, STATUS_DELETED, STATUS_NORMAL

_STATUS_BITS = 2
_STATUS_MASK = jnp.uint32((1 << _STATUS_BITS) - 1)
_WEIGHT_MASK = jnp.uint32((1 << 30) - 1)


# --- packing ---------------------------------------------------------------

def pack_meta(status, weight):
    status = jnp.asarray(status, jnp.uint32)
    weight = jnp.asarray(weight, jnp.uint32)
    return (status & _STATUS_MASK) | ((weight & _WEIGHT_MASK) << _STATUS_BITS)


def unpack_status(meta):
    return (meta & _STATUS_MASK).astype(jnp.int32)


def unpack_weight(meta):
    return ((meta >> _STATUS_BITS) & _WEIGHT_MASK).astype(jnp.uint32)


def pack_succ(succ1, succ2):
    s1 = jnp.asarray(succ1, jnp.uint32) & jnp.uint32(0xFFFF)
    s2 = jnp.asarray(succ2, jnp.uint32) & jnp.uint32(0xFFFF)
    return (s1 << 16) | s2


def unpack_succ(succ):
    s1 = ((succ >> 16) & jnp.uint32(0xFFFF)).astype(jnp.int32)
    s2 = (succ & jnp.uint32(0xFFFF)).astype(jnp.int32)
    return s1, s2


def succ_ids(succ):
    """Successor ids as int32, -1 where absent."""
    s1, s2 = unpack_succ(succ)
    s1 = jnp.where(s1 == NO_SUCC, -1, s1)
    s2 = jnp.where(s2 == NO_SUCC, -1, s2)
    return s1, s2


# --- snapshot visibility (paper: weight vs. global version) ---------------

def visible(meta, allocated, global_version):
    """A posting is visible to a snapshot iff it is allocated, not
    deleted, and its weight (creation version) <= the snapshot version."""
    status = unpack_status(meta)
    weight = unpack_weight(meta)
    return (
        allocated
        & (status != STATUS_DELETED)
        & (weight <= jnp.asarray(global_version, jnp.uint32))
    )


# --- batched transitions ---------------------------------------------------

def transition(rec_meta, pids, new_status, new_weight=None):
    """Set status (and optionally weight) for a batch of posting ids.

    ``pids`` may contain -1 entries (padding); those are dropped.  When the
    same pid appears twice, the *first* occurrence wins (CAS semantics:
    one winner per word per round).
    """
    pids = jnp.asarray(pids, jnp.int32)
    M = rec_meta.shape[0]
    valid = pids >= 0
    # first-writer-wins: keep only the first occurrence of each pid;
    # losers/padding are routed OUT OF BOUNDS so ``mode="drop"`` discards
    # them (aliasing them to slot 0 would race the real write on pid 0)
    first = first_occurrence_mask(pids) & valid
    safe = jnp.where(first, pids, M)
    cur = rec_meta[jnp.clip(pids, 0, M - 1)]
    weight = unpack_weight(cur) if new_weight is None else jnp.asarray(
        jnp.broadcast_to(new_weight, pids.shape), jnp.uint32)
    status = jnp.broadcast_to(jnp.asarray(new_status, jnp.uint32), pids.shape)
    packed = pack_meta(status, weight)
    return rec_meta.at[safe].set(packed, mode="drop")


def set_successors(rec_succ, pids, succ1, succ2):
    pids = jnp.asarray(pids, jnp.int32)
    M = rec_succ.shape[0]
    valid = pids >= 0
    first = first_occurrence_mask(pids) & valid
    safe = jnp.where(first, pids, M)     # losers/padding dropped, see above
    packed = pack_succ(
        jnp.where(jnp.asarray(succ1) < 0, NO_SUCC, jnp.asarray(succ1)),
        jnp.where(jnp.asarray(succ2) < 0, NO_SUCC, jnp.asarray(succ2)),
    )
    return rec_succ.at[safe].set(packed, mode="drop")


def retire(rec_meta, rec_succ, pids, succ1, succ2, version):
    """Retire a batch of postings: DELETED + retirement version + successor
    pointers, in one pair of scatters.  ``pids`` may contain -1 padding;
    duplicate pids resolve first-writer-wins (same CAS rule as
    ``transition``)."""
    pids = jnp.asarray(pids, jnp.int32)
    rec_meta = transition(rec_meta, pids, STATUS_DELETED,
                          jnp.broadcast_to(jnp.asarray(version, jnp.uint32),
                                           pids.shape))
    rec_succ = set_successors(rec_succ, pids, succ1, succ2)
    return rec_meta, rec_succ


def first_occurrence_mask(x):
    """Boolean mask marking the first occurrence of each value in ``x``.

    O(J log J); used for the deterministic one-winner-per-word rule.
    """
    x = jnp.asarray(x)
    n = x.shape[0]
    order = jnp.argsort(x, stable=True)
    xs = x[order]
    firsts = jnp.concatenate([jnp.ones((1,), bool), xs[1:] != xs[:-1]])
    out = jnp.zeros((n,), bool).at[order].set(firsts)
    return out


def chase_successors(rec_meta, rec_succ, allocated, centroids, pids, points,
                     depth: int):
    """Resolve DELETED postings to a live successor (paper IV-B2, branch 2).

    For each (pid, point): while the target posting is DELETED and has
    successors, move to the successor whose centroid is nearer to the
    point.  Bounded by ``depth``; returns (resolved_pid, still_deleted).
    ``still_deleted`` marks jobs whose chain ended in a dead end -> the
    controller turns them into reassign jobs.
    """

    def body(_, pid):
        status = unpack_status(rec_meta[pid])
        s1, s2 = succ_ids(rec_succ[pid])
        dead = (status == STATUS_DELETED)
        has1 = s1 >= 0
        has2 = s2 >= 0
        c1 = centroids[jnp.maximum(s1, 0)]
        c2 = centroids[jnp.maximum(s2, 0)]
        d1 = jnp.where(has1, jnp.sum((points - c1) ** 2, -1), jnp.inf)
        d2 = jnp.where(has2, jnp.sum((points - c2) ** 2, -1), jnp.inf)
        nxt = jnp.where(d1 <= d2, s1, s2)
        take = dead & (has1 | has2)
        return jnp.where(take, nxt, pid)

    pid = jnp.asarray(pids, jnp.int32)
    for i in range(depth):
        pid = body(i, pid)
    status = unpack_status(rec_meta[jnp.maximum(pid, 0)])
    dead_end = (pid < 0) | ((status == STATUS_DELETED))
    return pid, dead_end
