"""Data pipeline: deterministic, cursor-resumable synthetic streams."""
from .tokens import TokenStream
from .vectors import DriftingVectorStream, StaticVectorSet, make_queries

__all__ = ["TokenStream", "DriftingVectorStream", "StaticVectorSet",
           "make_queries"]
