"""Deterministic token stream for LM training.

Cursor-addressed: batch ``i`` for host ``h`` of ``H`` is a pure function
of (seed, i, h), so (a) any host can be replaced and resume mid-epoch
from the checkpointed cursor with zero skew, and (b) straggler-replaced
hosts regenerate exactly their shard (DESIGN.md §7).

The synthetic distribution is a Zipfian unigram mixed with a small
Markov component — enough structure that a ~100M model visibly learns
(loss falls well below the unigram entropy), with no external corpora.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab: int
    seq_len: int
    batch_per_host: int
    seed: int = 0
    host_index: int = 0
    num_hosts: int = 1
    cursor: int = 0            # batches already served (checkpointable)

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed, self.host_index, step))

    def _zipf_probs(self):
        ranks = np.arange(1, self.vocab + 1)
        p = 1.0 / ranks
        return p / p.sum()

    def next_batch(self):
        rng = self._rng(self.cursor)
        p = self._zipf_probs()
        B, L = self.batch_per_host, self.seq_len
        base = rng.choice(self.vocab, size=(B, L + 1), p=p)
        # Markov component: with prob .5 next token = f(prev) (learnable)
        follow = (base[:, :-1] * 31 + 7) % self.vocab
        mask = rng.random((B, L)) < 0.5
        base[:, 1:] = np.where(mask, follow, base[:, 1:])
        self.cursor += 1
        return {"tokens": base[:, :-1].astype(np.int32),
                "targets": base[:, 1:].astype(np.int32)}

    # -- checkpoint integration -----------------------------------------

    def state_dict(self) -> dict:
        return {"cursor": self.cursor, "seed": self.seed,
                "host_index": self.host_index}

    def load_state_dict(self, d: dict):
        assert d["seed"] == self.seed, "stream seed mismatch"
        self.cursor = int(d["cursor"])
