"""Vector workloads for the UBIS experiments (paper Section V-A).

Two dataset kinds, mirroring the paper's two families:

* ``DriftingVectorStream`` — the Argoverse2 analogue: timestamped
  vectors whose underlying mixture *drifts* over time (cluster centres
  random-walk and new clusters are born), so later batches shift the
  centroid distribution exactly the way streaming trajectories do.
  Vectors arrive in timestamp order.

* ``StaticVectorSet`` — the SIFT/Cohere/GLOVE analogue: a fixed
  Gaussian-mixture set; the update order is simulated (paper: sorted by
  a Gaussian draw), so batches are near-uniform over the space.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class DriftingVectorStream:
    dim: int = 64
    n_clusters: int = 32
    drift: float = 0.35          # per-batch random-walk step of centres
    birth_rate: float = 0.05     # chance a cluster teleports (new region)
    spread: float = 1.0
    scale: float = 8.0
    seed: int = 0
    cursor: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._centres = rng.normal(size=(self.n_clusters, self.dim)) \
            * self.scale

    def next_batch(self, n: int):
        rng = np.random.default_rng((self.seed, 7, self.cursor))
        # drift
        self._centres += rng.normal(
            size=self._centres.shape) * self.drift
        reborn = rng.random(self.n_clusters) < self.birth_rate
        self._centres[reborn] = rng.normal(
            size=(int(reborn.sum()), self.dim)) * self.scale
        a = rng.integers(0, self.n_clusters, n)
        x = self._centres[a] + rng.normal(size=(n, self.dim)) * self.spread
        self.cursor += 1
        return x.astype(np.float32)

    def queries(self, n: int, seed: int = 999):
        rng = np.random.default_rng((self.seed, seed))
        a = rng.integers(0, self.n_clusters, n)
        x = self._centres[a] + rng.normal(size=(n, self.dim)) * self.spread
        return x.astype(np.float32)


@dataclasses.dataclass
class StaticVectorSet:
    n: int = 100_000
    dim: int = 64
    n_clusters: int = 64
    scale: float = 8.0
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._centres = rng.normal(size=(self.n_clusters, self.dim)) \
            * self.scale
        a = rng.integers(0, self.n_clusters, self.n)
        self.vectors = (self._centres[a] + rng.normal(
            size=(self.n, self.dim))).astype(np.float32)
        # simulated update order (paper: Gaussian-sorted -> near-uniform
        # batch sizes); equivalent to a fixed random permutation
        self.order = np.argsort(rng.normal(size=self.n))

    def batches(self, n_batches: int):
        per = self.n // n_batches
        for i in range(n_batches):
            idx = self.order[i * per:(i + 1) * per]
            yield idx.astype(np.int64), self.vectors[idx]

    def queries(self, nq: int, seed: int = 999):
        rng = np.random.default_rng((self.seed, seed))
        a = rng.integers(0, self.n_clusters, nq)
        return (self._centres[a] + rng.normal(
            size=(nq, self.dim))).astype(np.float32)


def make_queries(centres: np.ndarray, nq: int, spread: float = 1.0,
                 seed: int = 0):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, len(centres), nq)
    return (centres[a] + rng.normal(size=(nq, centres.shape[1]))
            * spread).astype(np.float32)
