"""Distribution: sharding rules, mesh helpers, fault-tolerance utilities."""
from .sharding import (make_rules, to_named_sharding, logical_to_spec,
                       batch_sharding)
from .straggler import StragglerMonitor

__all__ = ["make_rules", "to_named_sharding", "logical_to_spec",
           "batch_sharding", "StragglerMonitor"]
