"""Logical-axis -> mesh-axis rules, per workload kind.

One table drives everything: parameter shardings (pjit in_shardings),
optimizer-state shardings (mirrors params), activation constraints
(models/layers.shard), and batch shardings.

Production layout (DESIGN.md §7):
  * params: 2-D sharded — "embed" over the FSDP axes (data [+pod]),
    "heads_flat"/"ffn"/"vocab"/"experts" over "model" (TP/EP);
  * activations: "batch" over FSDP axes; TP internals over "model";
  * decode KV caches: "kv_seq" over "model" (sequence-parallel decode —
    GQA kv-head counts don't divide a 16-way model axis);
  * long_500k (global_batch=1): batch unshardable, so "kv_seq" spreads
    over ("data","model") = the whole pod.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def shard_map(f, mesh: Mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` (without replication checking).

    ``jax.shard_map`` only exists on newer jax (and its no-check kwarg
    was renamed ``check_rep`` -> ``check_vma`` along the way); older
    releases ship it under ``jax.experimental.shard_map``.  Pinning
    either spelling breaks one side of the CI matrix, so dispatch here.
    """
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def axis_size(name: str):
    """``jax.lax.axis_size`` portability shim (absent before jax 0.5).
    Must be called inside a shard_map/pmap context."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def make_rules(mesh: Mesh, kind: str = "train",
               long_context: bool = False) -> Dict[str, Any]:
    axes = mesh.axis_names
    fsdp: Any = ("pod", "data") if "pod" in axes else "data"
    rules: Dict[str, Any] = {
        "batch": fsdp,
        "embed": fsdp,          # FSDP parameter dim
        "embed_out": None,
        "vocab": "model",
        "heads_flat": "model",
        "heads": "model",
        "ffn": "model",
        "experts": "model",
        "expert_ffn": None,
        "expert_cap": fsdp,
        "kv_seq": "model" if kind == "decode" else None,
        "layers": None,
    }
    if kind == "decode" and long_context:
        rules["batch"] = None
        rules["expert_cap"] = None
        rules["kv_seq"] = ("data", "model")
    return rules


def logical_to_spec(logical: PartitionSpec,
                    rules: Dict[str, Any]) -> PartitionSpec:
    """Map a PartitionSpec of *logical* names to mesh axes."""
    out = []
    for entry in logical:
        if entry is None:
            out.append(None)
        else:
            out.append(rules.get(entry))
    return PartitionSpec(*out)


def to_named_sharding(mesh: Mesh, logical_tree,
                      rules: Dict[str, Any]):
    """Tree of logical PartitionSpecs -> tree of NamedShardings."""
    return jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh, logical_to_spec(sp, rules)),
        logical_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec))


def batch_sharding(mesh: Mesh, ax_tree, rules: Dict[str, Any]):
    """Tree of logical-axes tuples (or PartitionSpecs) -> NamedShardings."""

    def conv(ax):
        if isinstance(ax, PartitionSpec):
            return NamedSharding(mesh, logical_to_spec(ax, rules))
        spec = PartitionSpec(
            *[rules.get(a) if a is not None else None for a in ax])
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(
        conv, ax_tree,
        is_leaf=lambda x: isinstance(x, (tuple, PartitionSpec)))
