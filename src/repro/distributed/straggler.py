"""Straggler detection (host-side control plane).

In SPMD data parallelism a straggler host delays every collective; the
cure at fleet scale is detect -> flag -> replace + deterministic resume
(the data pipeline is cursor-addressed, so a replacement host rejoins
mid-epoch without skew).  This monitor implements the detect/flag part:
an EWMA watermark over per-step wall times with an outlier multiplier.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional


@dataclasses.dataclass
class StragglerMonitor:
    ewma_alpha: float = 0.1
    trigger_ratio: float = 2.0     # step > ratio * ewma -> flag
    warmup_steps: int = 5
    _ewma: Optional[float] = None
    _steps: int = 0
    flagged: int = 0

    def record(self, step_seconds: float) -> bool:
        """Record one step; returns True if this step looks straggled."""
        self._steps += 1
        if self._ewma is None:
            self._ewma = step_seconds
            return False
        slow = (self._steps > self.warmup_steps
                and step_seconds > self.trigger_ratio * self._ewma)
        if slow:
            self.flagged += 1
        else:
            # stragglers don't poison the watermark
            self._ewma = (1 - self.ewma_alpha) * self._ewma \
                + self.ewma_alpha * step_seconds
        return slow

    @property
    def watermark(self) -> float:
        return self._ewma or 0.0


class StepTimer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
