"""Pallas TPU kernel: phase-1 centroid scoring (coarse filter).

Every search *and* every insert locate step scores the full centroid
table: (Q, d) x (M, d) -> (Q, M).  This is a blocked GEMM with a fused
``+||c||^2`` epilogue and visibility masking — centroid norms are
computed in-kernel from the resident tile, saving one HBM stream.

The visibility mask encodes the Posting Recorder rule (allocated, not
DELETED, weight <= snapshot version), evaluated by the caller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .posting_scan import BIG

DEFAULT_BQ = 128
DEFAULT_BM = 512


def _kernel(q_ref, c_ref, vis_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)          # (BQ, d)
    c = c_ref[...].astype(jnp.float32)          # (BM, d)
    vis = vis_ref[...]                          # (1, BM)
    cn = jnp.sum(c * c, axis=-1)                # fused norm epilogue
    dots = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    out_ref[...] = jnp.where(vis, cn[None, :] - 2.0 * dots, BIG)


@functools.partial(jax.jit, static_argnames=("bq", "bm", "interpret"))
def centroid_score(q: jax.Array, c: jax.Array, vis: jax.Array,
                   *, bq: int = DEFAULT_BQ, bm: int = DEFAULT_BM,
                   interpret: bool = False) -> jax.Array:
    Q, d = q.shape
    M = c.shape[0]
    grid = (Q // bq, M // bm)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bm), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bq, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, M), jnp.float32),
        interpret=interpret,
    )(q, c, vis)
