"""Pallas TPU kernel: fused phase-1 centroid scoring + running top-k.

The unfused phase 1 (``centroid_score`` + ``lax.top_k``) writes the full
(Q, M) score matrix to HBM only for top-k to immediately throw away all
but ``nprobe`` entries per query.  This kernel keeps a running
(score, index) top-k list per query block in the *output* refs instead —
the TPU grid is sequential over the centroid axis, so out-ref carry is
the same online-reduction idiom flash attention uses for its running
softmax (and ``kmeans_assign`` uses for its k=1 argmin): no (Q, M)
intermediate ever leaves VMEM.

    q   : (Q, d)        queries (VMEM-resident per block)
    c   : (M, d)        centroids, streamed in (bm, d) tiles
    vis : (1, M) bool   visibility mask (False -> BIG sentinel)
    ->  scores (Q, k) f32 ascending, idx (Q, k) int32

Tie discipline: candidates are visited in index order and the running
list orders equal scores by arrival, so ties break lowest-index-first —
exactly ``lax.top_k``'s rule.  The ref twin (``ref.centroid_topk``) is
therefore bit-identical, selection order included.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .posting_scan import BIG

DEFAULT_BQ = 128
DEFAULT_BM = 512


def merge_topk(run_s, run_i, tile_s, tile_i, k: int):
    """Merge a running top-k with a tile of fresh candidates.

    run_s/run_i: (rows, k) current best scores (ascending) and indices;
    tile_s/tile_i: (rows, n) this tile's candidate scores and indices.
    Returns the new (rows, k) pair, ascending by (score, arrival).

    Selection is k rounds of (min, argmin, mask) over the concatenated
    candidate row — VPU-only primitives, no sort/top_k lowering needed.
    ``argmin`` returns the lowest position on ties, and running entries
    (earlier candidates) sit before tile entries in the concatenation,
    so the global tie order is lowest-candidate-index-first, matching
    ``lax.top_k`` on the full score row.  Empty running slots hold
    +inf (> BIG), so masked-but-real candidates always win over them.
    """
    s = jnp.concatenate([run_s, tile_s], axis=1)        # (rows, k + n)
    idx = jnp.concatenate([run_i, tile_i], axis=1)
    rows, n_all = s.shape
    pos = jax.lax.broadcasted_iota(jnp.int32, (rows, n_all), 1)
    out_s, out_i = [], []
    for _ in range(k):                                  # k static, small
        best = jnp.min(s, axis=1)
        arg = jnp.argmin(s, axis=1).astype(jnp.int32)
        hit = pos == arg[:, None]
        out_s.append(best)
        out_i.append(jnp.sum(jnp.where(hit, idx, 0), axis=1))
        s = jnp.where(hit, jnp.inf, s)                  # retire the pick
    return jnp.stack(out_s, axis=1), jnp.stack(out_i, axis=1)


def _kernel(q_ref, c_ref, vis_ref, s_ref, i_ref, *, k):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        # +inf (not BIG): real-but-masked candidates carry BIG and must
        # outrank empty slots, or the sentinel indices would leak.
        s_ref[...] = jnp.full_like(s_ref, jnp.inf)
        i_ref[...] = jnp.zeros_like(i_ref)

    q = q_ref[...].astype(jnp.float32)                  # (bq, d)
    c = c_ref[...].astype(jnp.float32)                  # (bm, d)
    vis = vis_ref[...]                                  # (1, bm)
    cn = jnp.sum(c * c, axis=-1)                        # fused norm epilogue
    dots = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    score = jnp.where(vis, cn[None, :] - 2.0 * dots, BIG)
    bq, bm = score.shape
    tile_i = (jax.lax.broadcasted_iota(jnp.int32, (bq, bm), 1)
              + j * bm)
    s, i = merge_topk(s_ref[...], i_ref[...], score, tile_i, k)
    s_ref[...] = s
    i_ref[...] = i


@functools.partial(jax.jit, static_argnames=("k", "bq", "bm", "interpret"))
def centroid_topk(q: jax.Array, c: jax.Array, vis: jax.Array, *, k: int,
                  bq: int = DEFAULT_BQ, bm: int = DEFAULT_BM,
                  interpret: bool = False):
    """Padded-shape Pallas entry.  Q % bq == 0, M % bm == 0, d % 128 == 0
    are guaranteed by the ops.py wrapper; padded centroid rows arrive
    with vis=False."""
    Q, d = q.shape
    M = c.shape[0]
    grid = (Q // bq, M // bm)
    return pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bm), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(q, c, vis)
