"""Pallas TPU kernel: fused flash attention (training / prefill).

The LM substrate's compute hot-spot.  Classic online-softmax blocking:
a (BQ, D) query tile stays VMEM-resident; (BK, D) key/value tiles stream
through the last grid axis (sequential on TPU), carrying running
(max, denom, accumulator) in VMEM scratch.  Supports GQA (kv-head block
index = q-head // rep via the BlockSpec index map), causal masking with
end-alignment (decode-friendly), sliding windows (gemma3-style local
layers), and block-level skipping of fully-masked tiles (``pl.when``),
which is what makes the local-attention layers sub-quadratic in compute,
not just in memory.

Shapes: q (B, Hq, Lq, D); k, v (B, Hkv, Lk, D) -> out (B, Hq, Lq, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG = -1e30
DEFAULT_BQ = 128
DEFAULT_BK = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, scale, causal, window, q_offset, kv_len, bq, bk, nk):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    # ---- block-level skip predicate (compute saving, not just masking) ----
    q_start = iq * bq + q_offset          # global position of first q row
    q_end = q_start + bq - 1
    k_start = ik * bk
    k_end = k_start + bk - 1
    live = k_start < kv_len
    if causal:
        live &= k_start <= q_end
    if window is not None:
        live &= k_end > q_start - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)              # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                # (BQ, BK)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]                              # (BQ, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "bq", "bk", "kv_len",
                     "interpret"),
)
def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    kv_len=None, bq=DEFAULT_BQ, bk=DEFAULT_BK,
                    interpret=False):
    """Padded entry: Lq % bq == 0 and Lk % bk == 0 (ops.py pads + slices).

    ``kv_len``: true (unpadded) key count; defaults to padded Lk.
    """
    B, Hq, Lq, D = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    if kv_len is None:
        kv_len = Lk
    nq, nk = Lq // bq, Lk // bk
    grid = (B, Hq, nq, nk)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        q_offset=kv_len - Lq, kv_len=kv_len, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
