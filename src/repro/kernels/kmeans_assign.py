"""Pallas TPU kernel: k-means assignment step.

BalanceSplit (paper Alg. 1) runs 2-means on every split, and the initial
build runs full k-means; the assignment step (argmin over centroids) is
its compute hot-spot.  The kernel streams centroid tiles while a point
tile stays VMEM-resident, carrying a running (best score, best index)
pair across the centroid grid dimension in the *output* refs — the TPU
grid is executed sequentially over the last axis, so out-ref carry is
the idiomatic accumulator pattern.

    points    : (N, d)
    centroids : (K, d)
    ->  assign (N, 1) int32, best (N, 1) f32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .posting_scan import BIG

DEFAULT_BN = 256
DEFAULT_BK = 128


def _kernel(p_ref, c_ref, assign_ref, best_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        assign_ref[...] = jnp.full_like(assign_ref, -1)
        best_ref[...] = jnp.full_like(best_ref, BIG)

    p = p_ref[...].astype(jnp.float32)          # (BN, d)
    c = c_ref[...].astype(jnp.float32)          # (BK, d)
    cn = jnp.sum(c * c, axis=-1)
    dots = jax.lax.dot_general(
        p, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    score = cn[None, :] - 2.0 * dots            # (BN, BK)
    blk_best = jnp.min(score, axis=-1)
    blk_arg = jnp.argmin(score, axis=-1).astype(jnp.int32)
    blk_arg = blk_arg + j * score.shape[1]
    prev_best = best_ref[...][:, 0]
    prev_arg = assign_ref[...][:, 0]
    take = blk_best < prev_best
    best_ref[...] = jnp.where(take, blk_best, prev_best)[:, None]
    assign_ref[...] = jnp.where(take, blk_arg, prev_arg)[:, None]


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def kmeans_assign(points: jax.Array, centroids: jax.Array,
                  *, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                  interpret: bool = False):
    N, d = points.shape
    K = centroids.shape[0]
    grid = (N // bn, K // bk)
    assign, best = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, 1), jnp.int32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
        ],
        interpret=interpret,
    )(points, centroids)
    return assign[:, 0], best[:, 0]
