"""Public kernel entry points: padding, backend dispatch, jit wrappers.

Every op has three backends:
  * ``ref``     — pure-jnp oracle (``ref.py``), always correct, XLA-fused;
  * ``pallas``  — the TPU kernel (compiled on TPU, interpret=True on CPU);
  * ``auto``    — pallas on TPU backends, ref elsewhere (the multi-pod
                  dry-run therefore lowers the XLA path, per DESIGN.md §5).

Callers pass logical shapes; wrappers pad to hardware-aligned tiles
(lane dim 128, sublane 8) and slice results back.
"""
from __future__ import annotations

import functools
import warnings
import weakref
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .posting_scan import BIG, posting_scan as _ps_pallas
from .centroid_score import centroid_score as _cs_pallas
from .centroid_topk import centroid_topk as _ct_pallas
from .kmeans_assign import kmeans_assign as _ka_pallas
from .flash_attention import flash_attention as _fa_pallas

_PAD_CENTROID = 1e6  # padded rows score ~1e14 >> any real score


def _use_pallas(backend: str) -> bool:
    if backend == "auto":
        return jax.default_backend() == "tpu"
    return backend == "pallas"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Kernel-fallback observability.  The gather/topk kernels require the
# TPU storage layout (C/ksub/d multiples of 128); a misconfigured
# deployment that requests the pallas backend with misaligned shapes
# silently serves the slow jnp path.  Alignment is checked at trace
# time (shapes are static), so the signal rides the PR 7 obs plane:
# every registered Obs gets a ``kernel_fallback`` counter bump per
# fallback dispatch and a one-time trace event per (kernel, reason).
# ---------------------------------------------------------------------------

_FALLBACK_SINKS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
_FALLBACK_WARNED: set = set()


def observe_fallbacks(obs) -> None:
    """Register an ``Obs`` bundle to receive kernel-fallback signals
    (drivers call this at construction).  Weakly held."""
    if obs not in _FALLBACK_SINKS:
        _FALLBACK_SINKS[obs] = set()


def _note_fallback(kernel: str, reason: str) -> None:
    key = (kernel, reason)
    for obs, emitted in _FALLBACK_SINKS.items():
        obs.counter("kernel_fallback").inc()
        if key not in emitted:
            emitted.add(key)
            obs.emit("kernel_fallback", kernel=kernel, reason=reason)
    if not _FALLBACK_SINKS and key not in _FALLBACK_WARNED:
        _FALLBACK_WARNED.add(key)
        warnings.warn(f"kernel {kernel} fell back to the jnp reference "
                      f"({reason}); the pallas path requires 128-aligned "
                      "storage shapes", stacklevel=3)


def _ceil(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad2(x, rows: int, cols: int, value=0.0):
    """Pad columns (feature dim) with zeros, then extra rows with ``value``
    — so sentinel row-padding never corrupts real rows' norms."""
    r, c = x.shape
    x = jnp.pad(x, ((0, 0), (0, cols - c)))
    return jnp.pad(x, ((0, rows - r), (0, 0)), constant_values=value)


# ---------------------------------------------------------------------------


def centroid_score(q: jax.Array, c: jax.Array,
                   vis: Optional[jax.Array] = None,
                   *, backend: str = "auto") -> jax.Array:
    """(Q, d), (M, d)[, (M,) bool] -> (Q, M) scores; masked -> BIG."""
    Q, d = q.shape
    M = c.shape[0]
    if vis is None:
        vis = jnp.ones((M,), bool)
    if not _use_pallas(backend):
        s = ref.centroid_score(q, c)
        return jnp.where(vis[None, :], s, BIG)
    bq = 128 if Q >= 128 else _ceil(Q, 8)
    bm = 512 if M >= 512 else _ceil(M, 128)
    Qp, Mp, dp = _ceil(Q, bq), _ceil(M, bm), _ceil(d, 128)
    qp = _pad2(q, Qp, dp)
    cp = _pad2(c, Mp, dp, value=_PAD_CENTROID)
    vp = jnp.pad(vis, (0, Mp - M))[None, :]
    out = _cs_pallas(qp, cp, vp, bq=bq, bm=bm, interpret=_interpret())
    return out[:Q, :M]


def centroid_topk(q: jax.Array, c: jax.Array,
                  vis: Optional[jax.Array] = None, *, k: int,
                  backend: str = "auto"):
    """Fused phase 1: (Q, d), (M, d)[, (M,) bool] -> (scores (Q, k)
    ascending, idx (Q, k) int32); masked centroids -> BIG.

    Replaces ``centroid_score`` + ``lax.top_k``: on the pallas path no
    (Q, M) score matrix is materialized.  Both backends break ties
    lowest-index-first, so the pair is bit-identical."""
    Q, d = q.shape
    M = c.shape[0]
    assert k <= M, (k, M)
    if vis is None:
        vis = jnp.ones((M,), bool)
    if not _use_pallas(backend):
        return ref.centroid_topk(q, c, vis, k)
    bq = 128 if Q >= 128 else _ceil(Q, 8)
    bm = 512 if M >= 512 else _ceil(M, 128)
    Qp, Mp, dp = _ceil(Q, bq), _ceil(M, bm), _ceil(d, 128)
    qp = _pad2(q, Qp, dp)
    cp = _pad2(c, Mp, dp, value=_PAD_CENTROID)
    vp = jnp.pad(vis, (0, Mp - M))[None, :]   # padded rows masked -> BIG;
    # k <= M real candidates always outrank the padded tail on ties
    s, i = _ct_pallas(qp, cp, vp, k=k, bq=bq, bm=bm,
                      interpret=_interpret())
    return s[:Q], i[:Q]


def posting_scan(q: jax.Array, tiles: jax.Array, valid: jax.Array,
                 *, backend: str = "auto") -> jax.Array:
    """(Q, d), (G, C, d), (G, C) -> (Q, G*C) scores; invalid -> BIG."""
    Q, d = q.shape
    G, C, _ = tiles.shape
    if not _use_pallas(backend):
        s = ref.posting_scan(q, tiles, valid)
        return jnp.where(jnp.isfinite(s), s, BIG)
    V = G * C
    bq = 128 if Q >= 128 else _ceil(Q, 8)
    bv = 512 if V >= 512 else _ceil(V, 128)
    Qp, Vp, dp = _ceil(Q, bq), _ceil(V, bv), _ceil(d, 128)
    qp = _pad2(q, Qp, dp)
    vp = _pad2(tiles.reshape(V, d), Vp, dp)
    mp = jnp.pad(valid.reshape(V), (0, Vp - V))[None, :]
    out = _ps_pallas(qp, vp, mp, bq=bq, bv=bv, interpret=_interpret())
    return out[:Q, :V]


def kmeans_assign(points: jax.Array, centroids: jax.Array,
                  mask: Optional[jax.Array] = None,
                  *, backend: str = "auto"):
    """(N, d), (K, d)[, (N,) bool] -> (assign (N,) int32, best (N,) f32)."""
    N, d = points.shape
    K = centroids.shape[0]
    if not _use_pallas(backend):
        a, b = ref.kmeans_assign(points, centroids, mask)
        return a, jnp.where(jnp.isfinite(b), b, BIG)
    bn = 256 if N >= 256 else _ceil(N, 8)
    bk = 128  # lane-width tile; K pads up to a multiple (sentinel rows)
    Np, Kp, dp = _ceil(N, bn), _ceil(K, bk), _ceil(d, 128)
    pp = _pad2(points, Np, dp)
    cp = _pad2(centroids, Kp, dp, value=_PAD_CENTROID)
    a, b = _ka_pallas(pp, cp, bn=bn, bk=bk, interpret=_interpret())
    a, b = a[:N], b[:N]
    if mask is not None:
        a = jnp.where(mask, a, -1)
        b = jnp.where(mask, b, BIG)
    return a, b


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    backend: str = "auto"):
    """(B,Hq,Lq,D), (B,Hkv,Lk,D) x2 -> (B,Hq,Lq,D)."""
    B, Hq, Lq, D = q.shape
    Lk = k.shape[2]
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    if not _use_pallas(backend):
        return ref.flash_attention(q, k, v, causal=causal, window=window,
                                   scale=scale)
    bq = 128 if Lq >= 128 else _ceil(Lq, 8)
    bk = 128 if Lk >= 128 else _ceil(Lk, 8)
    Lqp, Lkp, Dp = _ceil(Lq, bq), _ceil(Lk, bk), _ceil(D, 128)
    # q is padded at the FRONT so that the last real row keeps its
    # end-aligned position (kv_len - Lq + i); k/v pad at the back and are
    # masked by kv_len inside the kernel.
    qp = jnp.pad(q, ((0, 0), (0, 0), (Lqp - Lq, 0), (0, Dp - D)))
    pad_kv = lambda x: jnp.pad(
        x, ((0, 0), (0, 0), (0, Lkp - x.shape[2]), (0, Dp - x.shape[3])))
    out = _fa_pallas(qp, pad_kv(k), pad_kv(v),
                     causal=causal, window=window, scale=scale, kv_len=Lk,
                     bq=bq, bk=bk, interpret=_interpret())
    return out[:, :, Lqp - Lq:, :D]


def pq_scan_gather(luts: jax.Array, codes: jax.Array,
                   posting_slot: jax.Array, slot_valid: jax.Array,
                   vis: jax.Array, probe: jax.Array,
                   *, backend: str = "auto"):
    """ADC scan of probed PQ-code tiles (quant plane, DESIGN: two-stage
    search).  luts: (Q, V, m, ksub); codes: (M, m, C) uint8;
    posting_slot: (M,) int32; probe: (Q, P) -> (Q, P, C) scores, BIG at
    invalid slots / invisible postings.

    Kernel path requires C % 128 == 0 and ksub % 128 == 0 (the TPU
    storage layout, as for posting_scan_gather); ref fallback otherwise.
    """
    from .pq_scan import pq_scan_gather as _pq_pallas
    V = luts.shape[1]
    C = codes.shape[2]
    ksub = luts.shape[3]
    slot = jnp.clip(posting_slot.astype(jnp.int32), 0, V - 1)
    if not _use_pallas(backend) or C % 128 or ksub % 128:
        if _use_pallas(backend):
            _note_fallback("pq_scan_gather",
                           f"C={C}, ksub={ksub} not 128-aligned")
        raw = ref.pq_scan_gather(luts, codes, slot, probe)
    else:
        raw = _pq_pallas(luts, codes, slot, probe.astype(jnp.int32),
                         interpret=_interpret())
    ok = slot_valid[probe] & vis[probe][..., None]
    return jnp.where(ok, raw, BIG)


def pq_scan_topk(luts: jax.Array, codes: jax.Array,
                 posting_slot: jax.Array, slot_valid: jax.Array,
                 vis: jax.Array, probe: jax.Array, *, k: int,
                 qp_ok: Optional[jax.Array] = None,
                 backend: str = "auto"):
    """Fused ADC scan + top-k (quant-plane phase 2).

    Same inputs as :func:`pq_scan_gather` plus ``k`` and an optional
    per-(query, probe) mask ``qp_ok`` (the sharded plane's ownership
    mask); returns (scores (Q, k) ascending, cand (Q, k) int32 flat
    slot index ``probe*C + c``) with BIG at masked candidates.  On the
    pallas path the (Q, P, C) score tensor is never materialized —
    selection runs on-chip against the streamed code tiles.  Alignment
    gates as for ``pq_scan_gather``; misaligned pallas requests fall
    back to the ref twin with a ``kernel_fallback`` obs signal."""
    from .pq_scan import pq_scan_topk as _pqt_pallas
    Q, V, m, ksub = luts.shape
    C = codes.shape[2]
    P = probe.shape[1]
    assert k <= P * C, (k, P, C)
    slot = jnp.clip(posting_slot.astype(jnp.int32), 0, V - 1)
    valid = slot_valid & vis[:, None]
    if qp_ok is None:
        qp_ok = jnp.ones((Q, P), jnp.int32)
    qp_ok = qp_ok.astype(jnp.int32)
    if not _use_pallas(backend) or C % 128 or ksub % 128:
        if _use_pallas(backend):
            _note_fallback("pq_scan_topk",
                           f"C={C}, ksub={ksub} not 128-aligned")
        return ref.pq_scan_topk(luts, codes, slot, valid, qp_ok, probe, k)
    return _pqt_pallas(luts, codes, slot, valid, qp_ok,
                       probe.astype(jnp.int32), k=k,
                       interpret=_interpret())


def posting_scan_gather(q: jax.Array, vectors: jax.Array,
                        slot_valid: jax.Array, vis: jax.Array,
                        probe: jax.Array, *, backend: str = "auto"):
    """Search phase 2 with in-kernel HBM gather (DESIGN.md §5).

    Kernel path requires d % 128 == 0 and C % 128 == 0 (storage is laid
    out that way on TPU deployments); otherwise falls back to ref.
    """
    from .posting_scan import posting_scan_gather as _psg_pallas
    Q, d = q.shape
    M, C, _ = vectors.shape
    if not _use_pallas(backend) or d % 128 or C % 128:
        if _use_pallas(backend):
            _note_fallback("posting_scan_gather",
                           f"d={d}, C={C} not 128-aligned")
        return ref.posting_scan_gather(q, vectors, slot_valid, vis, probe)
    raw = _psg_pallas(q, vectors, probe.astype(jnp.int32),
                      interpret=_interpret())
    ok = slot_valid[probe] & vis[probe][..., None]
    return jnp.where(ok, raw, BIG)


def posting_scan_topk(q: jax.Array, vectors: jax.Array,
                      slot_valid: jax.Array, vis: jax.Array,
                      probe: jax.Array, *, k: int,
                      qp_ok: Optional[jax.Array] = None,
                      backend: str = "auto"):
    """Fused float phase 2: probe scan + top-k in one kernel.

    Same inputs as :func:`posting_scan_gather` plus ``k`` and an
    optional per-(query, probe) mask; returns (scores (Q, k) ascending,
    cand (Q, k) int32 flat slot index) — no (Q, P, C) score tensor on
    the pallas path.  Alignment gates as for ``posting_scan_gather``;
    misaligned pallas requests fall back with a ``kernel_fallback``
    obs signal."""
    from .posting_scan import posting_scan_topk as _pst_pallas
    Q, d = q.shape
    M, C, _ = vectors.shape
    P = probe.shape[1]
    assert k <= P * C, (k, P, C)
    valid = slot_valid & vis[:, None]
    if qp_ok is None:
        qp_ok = jnp.ones((Q, P), jnp.int32)
    qp_ok = qp_ok.astype(jnp.int32)
    if not _use_pallas(backend) or d % 128 or C % 128:
        if _use_pallas(backend):
            _note_fallback("posting_scan_topk",
                           f"d={d}, C={C} not 128-aligned")
        return ref.posting_scan_topk(q, vectors, valid, qp_ok, probe, k)
    return _pst_pallas(q, vectors, valid, qp_ok,
                       probe.astype(jnp.int32), k=k,
                       interpret=_interpret())
