"""Public kernel entry points: padding, backend dispatch, jit wrappers.

Every op has three backends:
  * ``ref``     — pure-jnp oracle (``ref.py``), always correct, XLA-fused;
  * ``pallas``  — the TPU kernel (compiled on TPU, interpret=True on CPU);
  * ``auto``    — pallas on TPU backends, ref elsewhere (the multi-pod
                  dry-run therefore lowers the XLA path, per DESIGN.md §5).

Callers pass logical shapes; wrappers pad to hardware-aligned tiles
(lane dim 128, sublane 8) and slice results back.
"""
from __future__ import annotations

import contextlib
import warnings
import weakref
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .posting_scan import BIG, posting_scan as _ps_pallas
from .centroid_score import centroid_score as _cs_pallas
from .centroid_topk import centroid_topk as _ct_pallas
from .kmeans_assign import kmeans_assign as _ka_pallas
from .flash_attention import flash_attention as _fa_pallas

_PAD_CENTROID = 1e6  # padded rows score ~1e14 >> any real score


def _use_pallas(backend: str) -> bool:
    if backend == "auto":
        return jax.default_backend() == "tpu"
    return backend == "pallas"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Kernel-fallback observability.  All fused kernels are alignment-free
# (wrappers pad storage shapes to the TPU layout and mask in-kernel), so
# today NO pallas request ever falls back — but the plane stays wired so
# any future gate that re-opens the silent-slow-path hole is loud.
#
# Two signals with different clocks:
#   * ``kernel_fallback_traces`` — bumped by ``_note_fallback`` at TRACE
#     time (shape checks are static, so the note runs once per
#     compilation of the enclosing jitted program), plus a one-shot
#     ``kernel_fallback`` trace event per (kernel, reason);
#   * ``kernel_fallback``        — per-DISPATCH count.  Python inside a
#     jitted function does not re-run on cache-warm calls, so drivers
#     wrap each dispatch in ``count_fallback_dispatches``: the first
#     wrap of a signature captures the keys noted while the program
#     traces, and every wrap bumps the counter by the memoized count —
#     under steady-state serving the counter now moves every call
#     instead of freezing after the first compilation.
# ---------------------------------------------------------------------------

_FALLBACK_SINKS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
_FALLBACK_WARNED: set = set()
_CAPTURE_STACK: list = []       # active trace-capture sets (LIFO)
_DISPATCH_MEMO: dict = {}       # signature -> frozenset[(kernel, reason)]


def observe_fallbacks(obs) -> None:
    """Register an ``Obs`` bundle to receive kernel-fallback signals
    (drivers call this at construction).  Weakly held."""
    if obs not in _FALLBACK_SINKS:
        _FALLBACK_SINKS[obs] = set()


def discard_fallback_sink(obs) -> None:
    """Unregister one ``Obs`` bundle (driver teardown)."""
    _FALLBACK_SINKS.pop(obs, None)


def reset_fallback_state() -> None:
    """Clear ALL process-global fallback bookkeeping: sinks, one-shot
    warn/event dedup sets, capture scopes and the dispatch memo.
    Back-to-back driver constructions in one process (a test suite, a
    notebook) call this between indexes so one index's one-shot state
    never suppresses the next one's signals."""
    _FALLBACK_SINKS.clear()
    _FALLBACK_WARNED.clear()
    _CAPTURE_STACK.clear()
    _DISPATCH_MEMO.clear()


def _note_fallback(kernel: str, reason: str) -> None:
    """Record one kernel-fallback decision.  Runs at TRACE time."""
    key = (kernel, reason)
    for cap in _CAPTURE_STACK:
        cap.add(key)
    for obs, emitted in _FALLBACK_SINKS.items():
        obs.counter("kernel_fallback_traces").inc()
        if key not in emitted:
            emitted.add(key)
            obs.emit("kernel_fallback", kernel=kernel, reason=reason)
    if not _FALLBACK_SINKS and key not in _FALLBACK_WARNED:
        _FALLBACK_WARNED.add(key)
        warnings.warn(f"kernel {kernel} fell back to the jnp reference "
                      f"({reason})", stacklevel=3)


@contextlib.contextmanager
def count_fallback_dispatches(obs, signature):
    """Wrap ONE dispatch of a jitted program and count its fallbacks.

    ``signature`` must cover everything that decides backend routing for
    the wrapped program (backend knob + the plane identity) — shapes
    that merely retrigger jit tracing (e.g. the query-batch size) may be
    omitted, since re-traces of the same signature make the same
    routing decisions.  The first wrap of a signature captures the
    (kernel, reason) keys ``_note_fallback`` records while the program
    traces; every wrap bumps ``obs.counter("kernel_fallback")`` by the
    memoized key count.  Caveat: if the program was first compiled
    OUTSIDE any wrap, the first wrap sees a warm cache and memoizes an
    empty set — drivers avoid this by wrapping every dispatch.
    """
    first = signature not in _DISPATCH_MEMO
    if first:
        cap: set = set()
        _CAPTURE_STACK.append(cap)
    try:
        yield
    finally:
        if first:
            _CAPTURE_STACK.remove(cap)
            _DISPATCH_MEMO[signature] = frozenset(cap)
    n = len(_DISPATCH_MEMO[signature])
    if n and obs is not None:
        obs.counter("kernel_fallback").inc(n)


def _ceil(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad2(x, rows: int, cols: int, value=0.0):
    """Pad columns (feature dim) with zeros, then extra rows with ``value``
    — so sentinel row-padding never corrupts real rows' norms."""
    r, c = x.shape
    x = jnp.pad(x, ((0, 0), (0, cols - c)))
    return jnp.pad(x, ((0, rows - r), (0, 0)), constant_values=value)


# ---------------------------------------------------------------------------


def centroid_score(q: jax.Array, c: jax.Array,
                   vis: Optional[jax.Array] = None,
                   *, backend: str = "auto") -> jax.Array:
    """(Q, d), (M, d)[, (M,) bool] -> (Q, M) scores; masked -> BIG."""
    Q, d = q.shape
    M = c.shape[0]
    if vis is None:
        vis = jnp.ones((M,), bool)
    if not _use_pallas(backend):
        s = ref.centroid_score(q, c)
        return jnp.where(vis[None, :], s, BIG)
    bq = 128 if Q >= 128 else _ceil(Q, 8)
    bm = 512 if M >= 512 else _ceil(M, 128)
    Qp, Mp, dp = _ceil(Q, bq), _ceil(M, bm), _ceil(d, 128)
    qp = _pad2(q, Qp, dp)
    cp = _pad2(c, Mp, dp, value=_PAD_CENTROID)
    vp = jnp.pad(vis, (0, Mp - M))[None, :]
    out = _cs_pallas(qp, cp, vp, bq=bq, bm=bm, interpret=_interpret())
    return out[:Q, :M]


def centroid_topk(q: jax.Array, c: jax.Array,
                  vis: Optional[jax.Array] = None, *, k: int,
                  backend: str = "auto"):
    """Fused phase 1: (Q, d), (M, d)[, (M,) bool] -> (scores (Q, k)
    ascending, idx (Q, k) int32); masked centroids -> BIG.

    Replaces ``centroid_score`` + ``lax.top_k``: on the pallas path no
    (Q, M) score matrix is materialized.  Both backends break ties
    lowest-index-first, so the pair is bit-identical."""
    Q, d = q.shape
    M = c.shape[0]
    assert k <= M, (k, M)
    if vis is None:
        vis = jnp.ones((M,), bool)
    if not _use_pallas(backend):
        return ref.centroid_topk(q, c, vis, k)
    bq = 128 if Q >= 128 else _ceil(Q, 8)
    bm = 512 if M >= 512 else _ceil(M, 128)
    Qp, Mp, dp = _ceil(Q, bq), _ceil(M, bm), _ceil(d, 128)
    qp = _pad2(q, Qp, dp)
    cp = _pad2(c, Mp, dp, value=_PAD_CENTROID)
    vp = jnp.pad(vis, (0, Mp - M))[None, :]   # padded rows masked -> BIG;
    # k <= M real candidates always outrank the padded tail on ties
    s, i = _ct_pallas(qp, cp, vp, k=k, bq=bq, bm=bm,
                      interpret=_interpret())
    return s[:Q], i[:Q]


def posting_scan(q: jax.Array, tiles: jax.Array, valid: jax.Array,
                 *, backend: str = "auto") -> jax.Array:
    """(Q, d), (G, C, d), (G, C) -> (Q, G*C) scores; invalid -> BIG."""
    Q, d = q.shape
    G, C, _ = tiles.shape
    if not _use_pallas(backend):
        s = ref.posting_scan(q, tiles, valid)
        return jnp.where(jnp.isfinite(s), s, BIG)
    V = G * C
    bq = 128 if Q >= 128 else _ceil(Q, 8)
    bv = 512 if V >= 512 else _ceil(V, 128)
    Qp, Vp, dp = _ceil(Q, bq), _ceil(V, bv), _ceil(d, 128)
    qp = _pad2(q, Qp, dp)
    vp = _pad2(tiles.reshape(V, d), Vp, dp)
    mp = jnp.pad(valid.reshape(V), (0, Vp - V))[None, :]
    out = _ps_pallas(qp, vp, mp, bq=bq, bv=bv, interpret=_interpret())
    return out[:Q, :V]


def kmeans_assign(points: jax.Array, centroids: jax.Array,
                  mask: Optional[jax.Array] = None,
                  *, backend: str = "auto"):
    """(N, d), (K, d)[, (N,) bool] -> (assign (N,) int32, best (N,) f32)."""
    N, d = points.shape
    K = centroids.shape[0]
    if not _use_pallas(backend):
        a, b = ref.kmeans_assign(points, centroids, mask)
        return a, jnp.where(jnp.isfinite(b), b, BIG)
    bn = 256 if N >= 256 else _ceil(N, 8)
    bk = 128  # lane-width tile; K pads up to a multiple (sentinel rows)
    Np, Kp, dp = _ceil(N, bn), _ceil(K, bk), _ceil(d, 128)
    pp = _pad2(points, Np, dp)
    cp = _pad2(centroids, Kp, dp, value=_PAD_CENTROID)
    a, b = _ka_pallas(pp, cp, bn=bn, bk=bk, interpret=_interpret())
    a, b = a[:N], b[:N]
    if mask is not None:
        a = jnp.where(mask, a, -1)
        b = jnp.where(mask, b, BIG)
    return a, b


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    backend: str = "auto"):
    """(B,Hq,Lq,D), (B,Hkv,Lk,D) x2 -> (B,Hq,Lq,D)."""
    B, Hq, Lq, D = q.shape
    Lk = k.shape[2]
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    if not _use_pallas(backend):
        return ref.flash_attention(q, k, v, causal=causal, window=window,
                                   scale=scale)
    bq = 128 if Lq >= 128 else _ceil(Lq, 8)
    bk = 128 if Lk >= 128 else _ceil(Lk, 8)
    Lqp, Lkp, Dp = _ceil(Lq, bq), _ceil(Lk, bk), _ceil(D, 128)
    # q is padded at the FRONT so that the last real row keeps its
    # end-aligned position (kv_len - Lq + i); k/v pad at the back and are
    # masked by kv_len inside the kernel.
    qp = jnp.pad(q, ((0, 0), (0, 0), (Lqp - Lq, 0), (0, Dp - D)))
    pad_kv = lambda x: jnp.pad(
        x, ((0, 0), (0, 0), (0, Lkp - x.shape[2]), (0, Dp - x.shape[3])))
    out = _fa_pallas(qp, pad_kv(k), pad_kv(v),
                     causal=causal, window=window, scale=scale, kv_len=Lk,
                     bq=bq, bk=bk, interpret=_interpret())
    return out[:, :, Lqp - Lq:, :D]


def pq_scan_gather(luts: jax.Array, codes: jax.Array,
                   posting_slot: jax.Array, slot_valid: jax.Array,
                   vis: jax.Array, probe: jax.Array,
                   *, backend: str = "auto"):
    """ADC scan of probed PQ-code tiles (quant plane, DESIGN: two-stage
    search).  luts: (Q, V, m, ksub); codes: (M, m, C) uint8;
    posting_slot: (M,) int32; probe: (Q, P) -> (Q, P, C) scores, BIG at
    invalid slots / invisible postings.

    Alignment-free: C and ksub zero-pad up to the TPU storage layout
    (128 lanes) here — padded lut columns are unreachable (codes < the
    logical ksub) and padded code lanes are sliced back off, so any
    C/ksub serves the fused kernel.  Aligned storage makes both pads
    no-ops; misaligned storage pays one codes-layout copy per call.
    """
    from .pq_scan import pq_scan_gather as _pq_pallas
    V = luts.shape[1]
    C = codes.shape[2]
    ksub = luts.shape[3]
    slot = jnp.clip(posting_slot.astype(jnp.int32), 0, V - 1)
    if not _use_pallas(backend):
        raw = ref.pq_scan_gather(luts, codes, slot, probe)
    else:
        Cp, ksubp = _ceil(C, 128), _ceil(ksub, 128)
        lp = jnp.pad(luts, ((0, 0), (0, 0), (0, 0), (0, ksubp - ksub)))
        cdp = jnp.pad(codes, ((0, 0), (0, 0), (0, Cp - C)))
        raw = _pq_pallas(lp, cdp, slot, probe.astype(jnp.int32),
                         interpret=_interpret())[:, :, :C]
    ok = slot_valid[probe] & vis[probe][..., None]
    return jnp.where(ok, raw, BIG)


def pq_scan_topk(luts: jax.Array, codes: jax.Array,
                 posting_slot: jax.Array, slot_valid: jax.Array,
                 vis: jax.Array, probe: jax.Array, *, k: int,
                 qp_ok: Optional[jax.Array] = None,
                 backend: str = "auto"):
    """Fused ADC scan + top-k (quant-plane phase 2).

    Same inputs as :func:`pq_scan_gather` plus ``k`` and an optional
    per-(query, probe) mask ``qp_ok`` (the sharded plane's ownership
    mask); returns (scores (Q, k) ascending, cand (Q, k) int32 flat
    slot index ``probe*C + c``) with BIG at masked candidates.  On the
    pallas path the (Q, P, C) score tensor is never materialized —
    selection runs on-chip against the streamed code tiles.
    Alignment-free (same padding as ``pq_scan_gather``; padded lanes are
    masked to +inf in-kernel so the BIG-tie order stays bit-identical to
    the ref twin)."""
    from .pq_scan import pq_scan_topk as _pqt_pallas
    Q, V, m, ksub = luts.shape
    C = codes.shape[2]
    P = probe.shape[1]
    assert k <= P * C, (k, P, C)
    slot = jnp.clip(posting_slot.astype(jnp.int32), 0, V - 1)
    valid = slot_valid & vis[:, None]
    if qp_ok is None:
        qp_ok = jnp.ones((Q, P), jnp.int32)
    qp_ok = qp_ok.astype(jnp.int32)
    if not _use_pallas(backend):
        return ref.pq_scan_topk(luts, codes, slot, valid, qp_ok, probe, k)
    Cp, ksubp = _ceil(C, 128), _ceil(ksub, 128)
    lp = jnp.pad(luts, ((0, 0), (0, 0), (0, 0), (0, ksubp - ksub)))
    cdp = jnp.pad(codes, ((0, 0), (0, 0), (0, Cp - C)))
    vp = jnp.pad(valid, ((0, 0), (0, Cp - C)))    # pad lanes False
    return _pqt_pallas(lp, cdp, slot, vp, qp_ok,
                       probe.astype(jnp.int32), k=k, c=C,
                       interpret=_interpret())


def posting_scan_gather(q: jax.Array, vectors: jax.Array,
                        slot_valid: jax.Array, vis: jax.Array,
                        probe: jax.Array, *, backend: str = "auto"):
    """Search phase 2 with in-kernel HBM gather (DESIGN.md §5).

    Alignment-free: d and C zero-pad up to the TPU storage layout here
    (zero-padding d is fp-exact; padded C lanes slice back off), so any
    real-world dim serves the fused kernel.  Aligned storage makes the
    pads no-ops; misaligned storage pays one pool-layout copy per call.
    """
    from .posting_scan import posting_scan_gather as _psg_pallas
    Q, d = q.shape
    M, C, _ = vectors.shape
    if not _use_pallas(backend):
        return ref.posting_scan_gather(q, vectors, slot_valid, vis, probe)
    Cp, dp = _ceil(C, 128), _ceil(d, 128)
    qp = jnp.pad(q, ((0, 0), (0, dp - d)))
    vecp = jnp.pad(vectors, ((0, 0), (0, Cp - C), (0, dp - d)))
    raw = _psg_pallas(qp, vecp, probe.astype(jnp.int32),
                      interpret=_interpret())[:, :, :C]
    ok = slot_valid[probe] & vis[probe][..., None]
    return jnp.where(ok, raw, BIG)


def posting_scan_topk(q: jax.Array, vectors: jax.Array,
                      slot_valid: jax.Array, vis: jax.Array,
                      probe: jax.Array, *, k: int,
                      qp_ok: Optional[jax.Array] = None,
                      backend: str = "auto"):
    """Fused float phase 2: probe scan + top-k in one kernel.

    Same inputs as :func:`posting_scan_gather` plus ``k`` and an
    optional per-(query, probe) mask; returns (scores (Q, k) ascending,
    cand (Q, k) int32 flat slot index) — no (Q, P, C) score tensor on
    the pallas path.  Alignment-free (same padding as
    ``posting_scan_gather``; padded lanes are masked to +inf in-kernel
    so the BIG-tie order stays bit-identical to the ref twin)."""
    from .posting_scan import posting_scan_topk as _pst_pallas
    Q, d = q.shape
    M, C, _ = vectors.shape
    P = probe.shape[1]
    assert k <= P * C, (k, P, C)
    valid = slot_valid & vis[:, None]
    if qp_ok is None:
        qp_ok = jnp.ones((Q, P), jnp.int32)
    qp_ok = qp_ok.astype(jnp.int32)
    if not _use_pallas(backend):
        return ref.posting_scan_topk(q, vectors, valid, qp_ok, probe, k)
    Cp, dp = _ceil(C, 128), _ceil(d, 128)
    qp = jnp.pad(q, ((0, 0), (0, dp - d)))
    vecp = jnp.pad(vectors, ((0, 0), (0, Cp - C), (0, dp - d)))
    vp = jnp.pad(valid, ((0, 0), (0, Cp - C)))    # pad lanes False
    return _pst_pallas(qp, vecp, vp, qp_ok,
                       probe.astype(jnp.int32), k=k, c=C,
                       interpret=_interpret())


def rerank_topk(q: jax.Array, vectors: jax.Array, tier_spilled: jax.Array,
                cand: jax.Array, adc: jax.Array, *, k: int,
                backend: str = "auto"):
    """Fused exact rerank of the quant plane's ADC survivors.

    q: (Q, d); vectors: (M, C, d); tier_spilled: (M,) bool; cand:
    (Q, R) int32 flat slot candidates from :func:`pq_scan_topk`; adc:
    (Q, R) their ADC scores.  Exact-rescores each candidate
    (``||v||^2 - 2 q.v``), keeps the ADC score for tier-spilled
    postings (codes-only serving), carries BIG through empty ADC slots,
    and returns the top-k (scores (Q, k) ascending, cand (Q, k) int32).
    On the pallas path the candidate rows stream HBM->VMEM one at a
    time — no (Q, R, d) gather is ever materialized.  Alignment-free
    (d zero-pads, fp-exact); ties break lowest-ADC-rank-first on both
    backends, so the pair is bit-identical."""
    from .rerank import rerank_topk as _rr_pallas
    Q, d = q.shape
    M, C, _ = vectors.shape
    R = cand.shape[1]
    assert 0 < k <= R, (k, R)
    cand = cand.astype(jnp.int32)
    if not _use_pallas(backend):
        return ref.rerank_topk(q, vectors, tier_spilled, cand, adc, k)
    dp = _ceil(d, 128)
    qp = jnp.pad(q.astype(jnp.float32), ((0, 0), (0, dp - d)))
    vflat = jnp.pad(vectors.reshape(M * C, d).astype(jnp.float32),
                    ((0, 0), (0, dp - d)))
    spilled = tier_spilled[cand // C].astype(jnp.int32)
    return _rr_pallas(qp, vflat, cand, adc.astype(jnp.float32), spilled,
                      k=k, interpret=_interpret())
