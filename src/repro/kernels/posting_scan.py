"""Pallas TPU kernel: phase-2 masked distance scan over gathered postings.

This is the search hot-spot of a cluster-based index (paper Section V:
search efficiency): for a batch of queries and the posting tiles chosen
by phase-1, compute masked L2 scores for every (query, slot) pair.

TPU mapping (DESIGN.md Section 5): the query tile (BQ x d) stays resident
in VMEM while posting-vector tiles (BV x d) stream through; the
``-2 q.v`` term runs on the MXU (block shapes are 128-aligned), the
``||v||^2`` epilogue and the tombstone masking run on the VPU.  Scores
accumulate in fp32 regardless of storage dtype.

Inputs are pre-flattened by ``ops.posting_scan``:
    q     : (Q, d)      queries
    v     : (V, d)      V = G * C gathered posting slots
    valid : (1, V)      live-slot mask (tombstones + tail padding False)
Output:
    score : (Q, V) f32  ``||v||^2 - 2 q.v``; +BIG at invalid slots.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

BIG = 1e30  # stand-in for +inf that survives top-k arithmetic

DEFAULT_BQ = 128
DEFAULT_BV = 512


def _kernel(q_ref, v_ref, valid_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)          # (BQ, d)
    v = v_ref[...].astype(jnp.float32)          # (BV, d)
    valid = valid_ref[...]                      # (1, BV)
    vn = jnp.sum(v * v, axis=-1)                # (BV,)
    # MXU: (BQ, d) @ (d, BV)
    dots = jax.lax.dot_general(
        q, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    score = vn[None, :] - 2.0 * dots
    out_ref[...] = jnp.where(valid, score, BIG)


@functools.partial(jax.jit, static_argnames=("bq", "bv", "interpret"))
def posting_scan(q: jax.Array, v: jax.Array, valid: jax.Array,
                 *, bq: int = DEFAULT_BQ, bv: int = DEFAULT_BV,
                 interpret: bool = False) -> jax.Array:
    """Padded-shape Pallas entry.  Q % bq == 0, V % bv == 0, d % 128 == 0
    are guaranteed by the ops.py wrapper."""
    Q, d = q.shape
    V = v.shape[0]
    grid = (Q // bq, V // bv)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bv, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bv), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bq, bv), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, V), jnp.float32),
        interpret=interpret,
    )(q, v, valid)


# ---------------------------------------------------------------------------
# Scalar-prefetch gather variant: postings stream from HBM by probe index.
#
# The search phase-2 working set is per-query: each query scans only the
# ``nprobe`` postings its phase-1 filter chose.  Materialising the gather
# (Q, P, C, d) in HBM doubles traffic; instead the probe table is scalar-
# prefetched and each grid step DMAs exactly one posting tile HBM->VMEM
# (Pallas double-buffers consecutive steps).  Arithmetic intensity of the
# scan is ~1 FLOP/byte, so this kernel is *bandwidth*-bound by design —
# the win is eliminating the gather round-trip, not MXU utilisation.
# ---------------------------------------------------------------------------


def _gather_kernel(probe_ref, q_ref, v_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)            # (1, d)
    v = v_ref[0].astype(jnp.float32)              # (C, d)
    vn = jnp.sum(v * v, axis=-1)                  # (C,)
    dots = jax.lax.dot_general(
        v, q, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                             # (C, 1)
    o_ref[0, 0] = vn - 2.0 * dots[:, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def posting_scan_gather(q: jax.Array, vectors: jax.Array, probe: jax.Array,
                        *, interpret: bool = False) -> jax.Array:
    """q: (Q, dp); vectors: (M, Cp, dp); probe: (Q, P) int32 posting ids.

    Returns raw scores (Q, P, Cp); validity masking is applied by the
    ops.py wrapper (slot/visibility masks never enter the kernel), which
    also zero-pads d and C up to 128 multiples (zero-padding d is
    fp-exact for both the norm and the dot) and slices the logical
    (Q, P, C) block back out — the assertion below never fires.
    """
    Q, d = q.shape
    M, C, _ = vectors.shape
    P = probe.shape[1]
    assert d % 128 == 0 and C % 128 == 0, (d, C)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, P),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j, probe: (i, 0)),
            pl.BlockSpec((1, C, d), lambda i, j, probe: (probe[i, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, C), lambda i, j, probe: (i, j, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Q, P, C), jnp.float32),
        interpret=interpret,
    )(probe, q, vectors)


# ---------------------------------------------------------------------------
# Fused gather scan + on-chip top-k (float phase-2 twin of
# ``pq_scan.pq_scan_topk``): same double-buffered probe-indexed tile
# streaming as the gather kernel above, but the (Q, P, C) score tensor
# never hits HBM — a running top-k (score, flat-slot) list per query is
# carried in the output refs (``merge_topk``, the flash-attention
# online-reduction idiom), with validity and per-(query, probe)
# ownership masks applied in-kernel before selection.
# ---------------------------------------------------------------------------


def _gather_topk_kernel(probe_ref, ok_ref, q_ref, v_ref, valid_ref,
                        s_ref, i_ref, *, k, c):
    from .centroid_topk import merge_topk
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_ref[...] = jnp.full_like(s_ref, jnp.inf)
        i_ref[...] = jnp.zeros_like(i_ref)

    q = q_ref[...].astype(jnp.float32)            # (1, dp)
    v = v_ref[0].astype(jnp.float32)              # (Cp, dp)
    Cp = v.shape[0]
    vn = jnp.sum(v * v, axis=-1)                  # (Cp,)
    dots = jax.lax.dot_general(
        v, q, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                             # (Cp, 1)
    # slots beyond the LOGICAL capacity ``c`` are wrapper padding: +inf
    # (never selectable — the wrapper guarantees k <= P*c real
    # candidates, all <= BIG < inf) keeps the BIG-tie order of real
    # masked slots intact, and the candidate index uses the logical
    # stride so flat ids match the ref twin bit-for-bit.
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, Cp), 1)
    in_lane = lane < c
    ok = valid_ref[...] & (ok_ref[i, j] != 0) & in_lane   # (1, Cp)
    score = jnp.where(ok, (vn - 2.0 * dots[:, 0])[None, :],
                      jnp.where(in_lane, BIG, jnp.inf))
    cand = lane + probe_ref[i, j] * c
    s, ids = merge_topk(s_ref[...], i_ref[...], score, cand, k)
    s_ref[...] = s
    i_ref[...] = ids


@functools.partial(jax.jit, static_argnames=("k", "c", "interpret"))
def posting_scan_topk(q: jax.Array, vectors: jax.Array, valid: jax.Array,
                      qp_ok: jax.Array, probe: jax.Array,
                      *, k: int, c: int, interpret: bool = False):
    """Fused probe scan + running top-k.

    q: (Q, dp); vectors: (M, Cp, dp); valid: (M, Cp) bool (slot validity
    & posting visibility, precombined; padding lanes False); qp_ok:
    (Q, P) int32 per-(query, probe) mask; probe: (Q, P) int32.  ``c`` is
    the LOGICAL posting capacity — lanes in [c, Cp) are wrapper padding,
    masked in-kernel via an iota-vs-extent mask.  Returns (scores (Q, k)
    f32 ascending, cand (Q, k) int32 flat slot index ``probe*c + lane``);
    masked candidates carry BIG.  Bit-identical to
    ``ref.posting_scan_topk`` including tie order.  Storage shapes
    arrive 128-aligned from the ops.py wrapper (the assertions below
    never fire).
    """
    Q, d = q.shape
    M, C, _ = vectors.shape
    P = probe.shape[1]
    assert d % 128 == 0 and C % 128 == 0, (d, C)
    assert 0 < c <= C, (c, C)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Q, P),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j, probe, ok: (i, 0)),
            pl.BlockSpec((1, C, d),
                         lambda i, j, probe, ok: (probe[i, j], 0, 0)),
            pl.BlockSpec((1, C),
                         lambda i, j, probe, ok: (probe[i, j], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i, j, probe, ok: (i, 0)),
            pl.BlockSpec((1, k), lambda i, j, probe, ok: (i, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_gather_topk_kernel, k=k, c=c),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(probe, qp_ok, q, vectors, valid)
