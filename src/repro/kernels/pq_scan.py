"""Pallas TPU kernel: ADC lookup-table scan over packed PQ codes.

The quant-plane twin of ``posting_scan.py``'s gather kernel: for each
(query, probe) pair, stream one posting's uint8 code tile HBM->VMEM and
accumulate per-subspace lookup-table entries.  The probe table AND the
per-posting codebook-slot table are scalar-prefetched, so the lookup
table block for grid step (i, j) is selected by the *probed posting's*
codebook version — versioned codebooks cost one extra scalar indirection,
not a second pass.

The in-kernel gather is expressed as ``m`` small one-hot matmuls
(code -> one-hot (C, ksub) on the VPU, one-hot @ lut[j] on the MXU):
TPU has no per-lane dynamic gather, but ksub <= 256 keeps each one-hot
block a single (C, 256) tile.  Arithmetic intensity is higher than the
float scan by design — C*m bytes of codes per posting instead of
C*d*4 — which is the whole point of the quant plane.

    luts  : (Q, V, m, ksub) f32   per-query per-slot ADC tables
    codes : (M, m, C) uint8       subspace-major code tiles
    slot  : (M,) int32            codebook slot per posting (prefetched)
    probe : (Q, P) int32          posting ids per query (prefetched)
Output:
    score : (Q, P, C) f32 raw ADC scores (masking done by the wrapper)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .posting_scan import BIG


def _kernel(probe_ref, slot_ref, lut_ref, codes_ref, o_ref):
    del probe_ref, slot_ref                       # consumed by index maps
    lut = lut_ref[0, 0].astype(jnp.float32)       # (m, ksub)
    code = codes_ref[0].astype(jnp.int32)         # (m, C)
    m, C = code.shape
    ksub = lut.shape[1]
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (C, ksub), 1)
    acc = jnp.zeros((C,), jnp.float32)
    for j in range(m):                            # static unroll, m small
        onehot = (code[j][:, None] == k_iota).astype(jnp.float32)
        acc = acc + jax.lax.dot_general(
            onehot, lut[j], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    o_ref[0, 0] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def pq_scan_gather(luts: jax.Array, codes: jax.Array, slot: jax.Array,
                   probe: jax.Array, *, interpret: bool = False
                   ) -> jax.Array:
    """Padded-shape Pallas entry.  The ops.py wrapper zero-pads ``C``
    and ``ksub`` up to 128 multiples (exactly neutral: codes < logical
    ksub never hit padded lut columns) and slices the logical (Q, P, C)
    block back out — so the assertions below never fire."""
    Q, V, m, ksub = luts.shape
    M, _, C = codes.shape
    P = probe.shape[1]
    assert C % 128 == 0 and ksub % 128 == 0, (C, ksub)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Q, P),
        in_specs=[
            pl.BlockSpec((1, 1, m, ksub),
                         lambda i, j, probe, slot: (i, slot[probe[i, j]],
                                                    0, 0)),
            pl.BlockSpec((1, m, C),
                         lambda i, j, probe, slot: (probe[i, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, C),
                               lambda i, j, probe, slot: (i, j, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Q, P, C), jnp.float32),
        interpret=interpret,
    )(probe, slot, luts, codes)


# ---------------------------------------------------------------------------
# Fused ADC scan + on-chip top-k: the (Q, P, C) score tensor above only
# exists to feed ``lax.top_k`` — at nprobe=32, C=128 that is 16 KiB of
# HBM write+read per query for <= rerank_k survivors.  This variant
# keeps a running top-k (score, flat-candidate) list per query in the
# output refs (``merge_topk``, the flash-attention online-reduction
# idiom) while the scalar-prefetched probe list streams exactly one
# posting's code tile HBM->VMEM per grid step; Pallas double-buffers
# consecutive steps' tile DMAs against the current step's compute.  No
# score matrix ever hits HBM: the kernel writes 2*k words per query.
#
# The validity mask (slot_valid & vis, precombined by ops.py into one
# (M, C) row table) and the per-(query, probe) mask (the sharded plane's
# ``mine``) are applied in-kernel *before* selection — post-hoc masking
# is impossible once top-k is fused.
# ---------------------------------------------------------------------------


def _topk_kernel(probe_ref, slot_ref, ok_ref, lut_ref, codes_ref,
                 valid_ref, s_ref, i_ref, *, k, c):
    from .centroid_topk import merge_topk
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_ref[...] = jnp.full_like(s_ref, jnp.inf)
        i_ref[...] = jnp.zeros_like(i_ref)

    lut = lut_ref[0, 0].astype(jnp.float32)       # (m, ksub)
    code = codes_ref[0].astype(jnp.int32)         # (m, Cp)
    m, Cp = code.shape
    ksub = lut.shape[1]
    k_iota = jax.lax.broadcasted_iota(jnp.int32, (Cp, ksub), 1)
    acc = jnp.zeros((Cp,), jnp.float32)
    for jj in range(m):                           # static unroll, m small
        onehot = (code[jj][:, None] == k_iota).astype(jnp.float32)
        acc = acc + jax.lax.dot_general(
            onehot, lut[jj], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    # lanes beyond the LOGICAL capacity ``c`` are wrapper padding: mask
    # them to +inf (never selectable: the wrapper guarantees k <= P*c
    # real candidates, all <= BIG < inf) so they cannot perturb the
    # BIG-tie order of masked-but-real candidates, and index candidates
    # with the logical stride so flat ids match the ref twin exactly.
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, Cp), 1)
    in_lane = lane < c
    ok = valid_ref[...] & (ok_ref[i, j] != 0) & in_lane   # (1, Cp)
    score = jnp.where(ok, acc[None, :],
                      jnp.where(in_lane, BIG, jnp.inf))   # (1, Cp)
    cand = lane + probe_ref[i, j] * c
    s, ids = merge_topk(s_ref[...], i_ref[...], score, cand, k)
    s_ref[...] = s
    i_ref[...] = ids


@functools.partial(jax.jit, static_argnames=("k", "c", "interpret"))
def pq_scan_topk(luts: jax.Array, codes: jax.Array, slot: jax.Array,
                 valid: jax.Array, qp_ok: jax.Array, probe: jax.Array,
                 *, k: int, c: int, interpret: bool = False):
    """Fused ADC scan + running top-k.

    luts: (Q, V, m, ksub) f32; codes: (M, m, Cp) uint8; slot: (M,) int32;
    valid: (M, Cp) bool (slot_valid & posting visibility, precombined,
    padding lanes False); qp_ok: (Q, P) int32 per-(query, probe) mask;
    probe: (Q, P) int32.  ``c`` is the LOGICAL posting capacity; lanes
    in [c, Cp) are wrapper padding, masked in-kernel via an
    iota-vs-extent mask.  Returns (scores (Q, k) f32 ascending, cand
    (Q, k) int32 flat slot index ``probe*c + lane``); masked candidates
    carry BIG.  Bit-identical to ``ref.pq_scan_topk`` including tie
    order (probe-position-major).  Storage shapes arrive 128-aligned
    from the ops.py wrapper (assertions below never fire).
    """
    Q, V, m, ksub = luts.shape
    M, _, C = codes.shape
    P = probe.shape[1]
    assert C % 128 == 0 and ksub % 128 == 0, (C, ksub)
    assert 0 < c <= C, (c, C)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(Q, P),
        in_specs=[
            pl.BlockSpec((1, 1, m, ksub),
                         lambda i, j, probe, slot, ok: (i,
                                                        slot[probe[i, j]],
                                                        0, 0)),
            pl.BlockSpec((1, m, C),
                         lambda i, j, probe, slot, ok: (probe[i, j], 0, 0)),
            pl.BlockSpec((1, C),
                         lambda i, j, probe, slot, ok: (probe[i, j], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i, j, probe, slot, ok: (i, 0)),
            pl.BlockSpec((1, k), lambda i, j, probe, slot, ok: (i, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_topk_kernel, k=k, c=c),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(probe, slot, qp_ok, luts, codes, valid)
