"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth: tests assert the Pallas
kernels (run with ``interpret=True`` on CPU) match these to tolerance,
sweeping shapes and dtypes.  ``ops.py`` routes to these implementations
on non-TPU backends.

Distance convention: all ANN kernels return *scores*
``s(q, v) = ||v||^2 - 2 q.v`` which order identically to squared L2
(``||q||^2`` is constant per query).  True squared distance is
``s + ||q||^2``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BIG = 1e30  # masked-score sentinel shared with the Pallas kernels


def centroid_score(queries: jax.Array, centroids: jax.Array) -> jax.Array:
    """Phase-1 scoring.  (Q, d), (M, d) -> (Q, M) float32 scores."""
    q = queries.astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    cn = jnp.sum(c * c, axis=-1)
    return cn[None, :] - 2.0 * (q @ c.T)


def posting_scan(queries: jax.Array, tiles: jax.Array,
                 valid: jax.Array) -> jax.Array:
    """Phase-2 masked scan.

    queries: (Q, d); tiles: (G, C, d) gathered posting tiles;
    valid: (G, C) bool live-slot mask.
    Returns (Q, G*C) float32 scores with +inf at invalid slots.
    """
    q = queries.astype(jnp.float32)
    G, C, d = tiles.shape
    v = tiles.reshape(G * C, d).astype(jnp.float32)
    vn = jnp.sum(v * v, axis=-1)
    s = vn[None, :] - 2.0 * (q @ v.T)
    return jnp.where(valid.reshape(1, G * C), s, BIG)


def kmeans_assign(points: jax.Array, centroids: jax.Array,
                  mask: jax.Array | None = None):
    """Nearest-centroid assignment.

    points: (N, d); centroids: (K, d); mask: (N,) bool or None.
    Returns (assign (N,) int32, score (N,) f32); masked points get
    assignment -1 and score +inf.
    """
    s = centroid_score(points, centroids)  # (N, K)
    assign = jnp.argmin(s, axis=-1).astype(jnp.int32)
    best = jnp.min(s, axis=-1)
    if mask is not None:
        assign = jnp.where(mask, assign, -1)
        best = jnp.where(mask, best, BIG)
    return assign, best


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True, window: int | None = None,
                    scale: float | None = None) -> jax.Array:
    """Reference attention.  q: (B, Hq, Lq, D), k/v: (B, Hkv, Lk, D).

    GQA: Hq must be a multiple of Hkv.  ``window``: sliding-window size
    (keys attend within [i - window + 1, i]); None = full.
    """
    B, Hq, Lq, D = q.shape
    Hkv = k.shape[1]
    rep = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    kf = jnp.repeat(k, rep, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=1).astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) * scale
    Lk = k.shape[2]
    qpos = jnp.arange(Lq)[:, None] + (Lk - Lq)  # align ends (decode-friendly)
    kpos = jnp.arange(Lk)[None, :]
    m = jnp.ones((Lq, Lk), bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    logits = jnp.where(m[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    return out.astype(q.dtype)


def pq_scan_gather(luts: jax.Array, codes: jax.Array, slot: jax.Array,
                   probe: jax.Array) -> jax.Array:
    """ADC scan over probed PQ-code tiles (quant plane phase 2).

    luts: (Q, V, m, ksub) per-query per-codebook-slot lookup tables;
    codes: (M, m, C) uint8 subspace-major codes; slot: (M,) int32
    codebook slot of each posting; probe: (Q, P) int32.
    Returns raw (Q, P, C) scores ``sum_j lut[slot[p], j, code[j, c]]``
    (validity masking is the wrapper's job, as in posting_scan_gather).
    """
    Q, V, m, ksub = luts.shape
    codes_g = codes[probe].astype(jnp.int32)                # (Q, P, m, C)
    # one flat gather per (q, p, j, c): index = slot*m*ksub + j*ksub + code
    # (avoids materializing the (Q, P, m, ksub) per-probe table slice)
    base = (jnp.clip(slot[probe], 0)[:, :, None] * (m * ksub)
            + jnp.arange(m, dtype=jnp.int32)[None, None, :] * ksub)
    idx = base[..., None] + codes_g                         # (Q, P, m, C)
    flat = luts.reshape(Q, V * m * ksub)
    picked = jnp.take_along_axis(flat, idx.reshape(Q, -1), axis=1)
    return jnp.sum(picked.reshape(codes_g.shape), axis=2)   # (Q, P, C)


def centroid_topk(queries: jax.Array, centroids: jax.Array,
                  vis: jax.Array, k: int):
    """Fused phase-1 oracle: masked centroid scores + top-k.

    queries: (Q, d); centroids: (M, d); vis: (M,) bool.
    Returns (scores (Q, k) f32 ascending, idx (Q, k) int32); masked
    centroids carry BIG.  ``lax.top_k`` breaks ties lowest-index-first;
    the Pallas twin reproduces that order bit-identically.
    """
    s = centroid_score(queries, centroids)
    s = jnp.where(vis[None, :], s, BIG)
    neg, idx = jax.lax.top_k(-s, k)
    return -neg, idx.astype(jnp.int32)


def pq_scan_topk(luts: jax.Array, codes: jax.Array, slot: jax.Array,
                 valid: jax.Array, qp_ok: jax.Array, probe: jax.Array,
                 k: int):
    """Fused ADC-scan oracle: masked probe scores + top-k.

    luts: (Q, V, m, ksub); codes: (M, m, C) uint8; slot: (M,) int32;
    valid: (M, C) bool (slot validity & posting visibility combined);
    qp_ok: (Q, P) per-(query, probe) mask; probe: (Q, P) int32.
    Returns (scores (Q, k) ascending, cand (Q, k) int32 flat slot index
    ``probe*C + c``); masked candidates carry BIG.  Tie order is
    probe-position-major (the flattened (P, C) order), matching the
    running-merge order of the Pallas twin bit-identically.
    """
    raw = pq_scan_gather(luts, codes, slot, probe)          # (Q, P, C)
    Q, P, C = raw.shape
    ok = valid[probe] & (qp_ok != 0)[:, :, None]
    s = jnp.where(ok, raw, BIG)
    neg, pos = jax.lax.top_k(-s.reshape(Q, P * C), k)
    cand_all = (probe[:, :, None] * C
                + jnp.arange(C, dtype=jnp.int32)[None, None, :])
    cand = jnp.take_along_axis(cand_all.reshape(Q, P * C), pos, axis=1)
    return -neg, cand.astype(jnp.int32)


def posting_scan_topk(queries: jax.Array, vectors: jax.Array,
                      valid: jax.Array, qp_ok: jax.Array,
                      probe: jax.Array, k: int):
    """Fused float phase-2 oracle: masked probe scan + top-k.

    queries: (Q, d); vectors: (M, C, d); valid: (M, C) bool; qp_ok:
    (Q, P); probe: (Q, P) int32.  Returns (scores (Q, k) ascending,
    cand (Q, k) int32 flat slot index); same tie discipline as
    :func:`pq_scan_topk`.
    """
    q = queries.astype(jnp.float32)
    tiles = vectors[probe].astype(jnp.float32)              # (Q, P, C, d)
    Q, P, C, _ = tiles.shape
    vn = jnp.sum(tiles * tiles, axis=-1)
    dots = jnp.einsum("qd,qpcd->qpc", q, tiles)
    ok = valid[probe] & (qp_ok != 0)[:, :, None]
    s = jnp.where(ok, vn - 2.0 * dots, BIG)
    neg, pos = jax.lax.top_k(-s.reshape(Q, P * C), k)
    cand_all = (probe[:, :, None] * C
                + jnp.arange(C, dtype=jnp.int32)[None, None, :])
    cand = jnp.take_along_axis(cand_all.reshape(Q, P * C), pos, axis=1)
    return -neg, cand.astype(jnp.int32)


def rerank_topk(queries: jax.Array, vectors: jax.Array,
                tier_spilled: jax.Array, cand: jax.Array,
                adc: jax.Array, k: int):
    """Fused exact-rerank oracle (quant plane stage 2).

    queries: (Q, d); vectors: (M, C, d); tier_spilled: (M,) bool; cand:
    (Q, R) int32 flat slot candidates from ``pq_scan_topk``; adc: (Q, R)
    their ADC scores.  Exact-rescores each candidate's float row,
    keeps the ADC score for tier-spilled postings (codes-only serving),
    carries BIG through empty ADC slots, and returns the top-k
    (scores (Q, k) ascending, cand (Q, k) int32).  Ties break
    lowest-ADC-rank-first (``lax.top_k`` over the R row), matching the
    arrival order of the Pallas twin bit-identically.
    """
    M, C, d = vectors.shape
    q = queries.astype(jnp.float32)
    cv = vectors.reshape(M * C, d)[cand].astype(jnp.float32)  # (Q, R, d)
    exact = (jnp.sum(cv * cv, -1)
             - 2.0 * jnp.einsum("qd,qrd->qr", q, cv))
    exact = jnp.where(tier_spilled[cand // C], adc, exact)
    exact = jnp.where(adc < BIG / 2, exact, BIG)
    neg, pos = jax.lax.top_k(-exact, k)
    return -neg, jnp.take_along_axis(cand, pos, axis=1).astype(jnp.int32)


def posting_scan_gather(queries: jax.Array, vectors: jax.Array,
                        slot_valid: jax.Array, vis: jax.Array,
                        probe: jax.Array) -> jax.Array:
    """Per-query probe scan (search phase 2).

    queries: (Q, d); vectors: (M, C, d); slot_valid: (M, C) bool;
    vis: (M,) bool posting visibility; probe: (Q, P) int32.
    Returns (Q, P, C) scores; invalid slots / invisible postings -> BIG.
    """
    q = queries.astype(jnp.float32)
    tiles = vectors[probe].astype(jnp.float32)          # (Q, P, C, d)
    vn = jnp.sum(tiles * tiles, axis=-1)
    dots = jnp.einsum("qd,qpcd->qpc", q, tiles)
    s = vn - 2.0 * dots
    ok = slot_valid[probe] & vis[probe][..., None]
    return jnp.where(ok, s, BIG)
