"""Pallas TPU kernel: fused exact rerank of the quant plane's ADC
survivors (the second stage of the two-stage PQ search).

After ``pq_scan_topk`` picks the top-R candidates by ADC score, the
float rerank used to be an XLA gather materialising (Q, R, d) candidate
rows in HBM, an einsum, two ``where`` fixups and a ``top_k``.  This
kernel fuses the whole tail: the candidate table is scalar-prefetched
and each grid step (i, r) DMAs exactly ONE candidate's float row
HBM->VMEM (Pallas double-buffers consecutive steps), computes
``||v||^2 - 2 q.v`` on the VPU, substitutes the ADC score for
tier-spilled candidates (cold-tier plane: their device float tile is
zeroed, so the ADC score IS their serving score), masks empty ADC slots
to BIG, and merges into a running per-query top-k carried in the output
refs (``merge_topk``, the same online-reduction idiom as the other
fused kernels).  No (Q, R, d) gather and no (Q, R) score row ever hit
HBM: the kernel writes 2*Q*k words.

    q       : (Q, dp) f32        queries (d zero-padded to 128)
    vflat   : (M*C, dp) f32      posting pool viewed as flat slot rows
    cand    : (Q, R) int32       flat slot candidates (prefetched)
    adc     : (Q, R) f32         the candidates' ADC scores
    spilled : (Q, R) int32       1 where the candidate's posting is
                                 tier-spilled (serve the ADC score)
Output:
    scores  : (Q, k) f32 ascending;  cand_out : (Q, k) int32

Tie discipline: candidates are visited in ADC-rank order r and the
running list orders equal scores by arrival, so ties break
lowest-r-first — exactly ``lax.top_k`` over the (Q, R) exact row, which
makes the ref twin (``ref.rerank_topk``) bit-identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .posting_scan import BIG


def _kernel(cand_ref, q_ref, v_ref, adc_ref, sp_ref, s_ref, i_ref, *, k):
    from .centroid_topk import merge_topk
    i = pl.program_id(0)
    r = pl.program_id(1)

    @pl.when(r == 0)
    def _init():
        s_ref[...] = jnp.full_like(s_ref, jnp.inf)
        i_ref[...] = jnp.zeros_like(i_ref)

    q = q_ref[...].astype(jnp.float32)            # (1, dp)
    v = v_ref[...].astype(jnp.float32)            # (1, dp)
    adc = adc_ref[0, 0]
    exact = jnp.sum(v * v) - 2.0 * jnp.sum(q * v)
    # cold-tier passthrough: spilled candidates keep their ADC score
    # (their float row is zeroed); empty ADC slots stay BIG so the
    # final merge's ``score < BIG/2`` id masking keeps working.
    score = jnp.where(sp_ref[0, 0] != 0, adc, exact)
    score = jnp.where(adc < BIG / 2, score, BIG)
    tile_s = jnp.full((1, 1), score, jnp.float32)
    tile_i = jnp.full((1, 1), cand_ref[i, r], jnp.int32)
    s, ids = merge_topk(s_ref[...], i_ref[...], tile_s, tile_i, k)
    s_ref[...] = s
    i_ref[...] = ids


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def rerank_topk(q: jax.Array, vflat: jax.Array, cand: jax.Array,
                adc: jax.Array, spilled: jax.Array,
                *, k: int, interpret: bool = False):
    """Padded-shape Pallas entry.  q: (Q, dp); vflat: (M*C, dp); cand:
    (Q, R) int32 in [0, M*C); adc/spilled: (Q, R).  The ops.py wrapper
    zero-pads d up to a 128 multiple (fp-exact) — the assertion below
    never fires.  Returns (scores (Q, k) ascending, cand (Q, k))."""
    Q, d = q.shape
    R = cand.shape[1]
    assert d % 128 == 0, d
    assert 0 < k <= R, (k, R)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Q, R),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, r, cand: (i, 0)),
            pl.BlockSpec((1, d), lambda i, r, cand: (cand[i, r], 0)),
            pl.BlockSpec((1, 1), lambda i, r, cand: (i, r)),
            pl.BlockSpec((1, 1), lambda i, r, cand: (i, r)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i, r, cand: (i, 0)),
            pl.BlockSpec((1, k), lambda i, r, cand: (i, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(cand.astype(jnp.int32), q, vflat, adc, spilled.astype(jnp.int32))
