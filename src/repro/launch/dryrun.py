import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh)
combination lowers AND compiles under the production sharding — with
memory and cost analysis recorded for the roofline (EXPERIMENTS.md).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --cell train_4k [--multi-pod] [--out dryrun.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--out ...]

Nothing here allocates device memory: parameters, optimizer state,
caches and batches are ShapeDtypeStructs end to end.
"""
import argparse
import json
import re
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import (batch_sharding, make_rules,
                                        to_named_sharding)
from repro.models import SHAPE_CELLS, cells_for, get_model, ARCH_IDS
from repro.models.layers import sharding_rules
from repro.optim import AdamW, AdamWConfig, cosine_warmup
from .mesh import make_production_mesh

# archs whose optimizer state must be sub-fp32 to fit 16 GB/chip
_INT8_OPT = {"jamba-1.5-large-398b", "deepseek-67b", "llava-next-34b"}

_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes of every collective op in compiled HLO text.

    Output shapes appear on the LHS of ``%name = <shapes> op(...)``;
    layouts ``{1,0}`` may follow each shape.  Async pairs are counted at
    the ``-start`` op only (the ``-done`` output aliases it).
    """
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        for op in _COLLECTIVE_OPS:
            tok = f" {op}("
            tok_start = f" {op}-start("
            if tok_start in line:
                lhs = line.split(tok_start)[0]
            elif tok in line and f"{op}-done" not in line:
                lhs = line.split(tok)[0]
            else:
                continue
            if "=" in lhs:
                lhs = lhs.split("=", 1)[1]
            total = 0
            for dt, dims in _SHAPE_RE.findall(lhs):
                nbytes = _DTYPE_BYTES.get(dt)
                if nbytes is None:
                    continue
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                total += n * nbytes
            out[op] = out.get(op, 0) + total
            break
    return out


def _bytes_of(tree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def lower_cell(arch_id: str, cell_name: str, mesh, *,
               dtype=jnp.bfloat16, remat: str = "full",
               compile_: bool = True, unroll: bool = False,
               rules_override: Optional[dict] = None,
               **cfg_overrides) -> Dict[str, Any]:
    """Lower (and compile) one cell on one mesh; return the record.

    ``unroll`` + ``n_layers=...`` overrides drive the roofline's
    small-depth exact-cost variants (benchmarks/roofline.py)."""
    t0 = time.perf_counter()
    cell = SHAPE_CELLS[cell_name]
    model = get_model(arch_id, remat=remat, unroll=unroll,
                      **cfg_overrides)
    kind = "decode" if cell.kind == "decode" else "train"
    rules = make_rules(mesh, kind, long_context=cell.seq_len > 100_000)
    model_size = dict(zip(mesh.axis_names,
                          mesh.devices.shape)).get("model", 1)
    if (model.cfg.moe is not None
            and model.cfg.moe.e_pad % model_size != 0):
        # EP needs experts % model == 0; fall back to TP-within-expert
        # (or pad the expert count via MoEConfig.padded_experts -> EP)
        rules["experts"] = None
        rules["expert_ffn"] = "model"
    if rules_override:
        rules.update(rules_override)
    ctx_rules = dict(rules, __mesh__=mesh)

    pvals, paxes = model.param_shapes(dtype)
    pshard = to_named_sharding(mesh, paxes, rules)
    batch_sds, batch_ax = model.input_specs(cell, dtype)
    bshard = batch_sharding(mesh, batch_ax, rules)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(pvals))

    if cell.kind == "train":
        opt = AdamW(
            AdamWConfig(state_dtype="int8" if arch_id in _INT8_OPT
                        else "f32"),
            lr=cosine_warmup(3e-4, 2000, 100_000))
        ostate = jax.eval_shape(opt.init, pvals)
        oshard = to_named_sharding(
            mesh, opt.state_axes(paxes), rules)

        def step(params, opt_state, batch):
            with sharding_rules(ctx_rules):
                (loss, metrics), grads = jax.value_and_grad(
                    model.train_loss, has_aux=True)(params, batch)
                params, opt_state, om = opt.apply(params, grads, opt_state)
            return params, opt_state, loss, om["grad_norm"]

        jitted = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None, None),
                         donate_argnums=(0, 1))
        args = (pvals, ostate, batch_sds)
    elif cell.kind == "prefill":
        _, cax = model.cache_shapes(cell.global_batch, cell.seq_len, dtype)
        cshard = to_named_sharding(mesh, cax, rules)

        def step(params, batch):
            with sharding_rules(ctx_rules):
                return model.prefill(params, batch)

        jitted = jax.jit(step, in_shardings=(pshard, bshard),
                         out_shardings=(None, cshard))
        args = (pvals, batch_sds)
    else:  # decode
        cshard = bshard["cache"]

        def step(params, cache, token, pos):
            with sharding_rules(ctx_rules):
                return model.decode_step(params, cache, token, pos)

        jitted = jax.jit(
            step,
            in_shardings=(pshard, cshard, bshard["token"], bshard["pos"]),
            out_shardings=(None, cshard), donate_argnums=(1,))
        args = (pvals, batch_sds["cache"], batch_sds["token"],
                batch_sds["pos"])

    lowered = jitted.lower(*args)
    t_lower = time.perf_counter() - t0
    rec: Dict[str, Any] = {
        "arch": arch_id, "cell": cell_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": mesh.devices.size,
        "n_params": int(n_params),
        "param_bytes": int(_bytes_of(pvals)),
        "lower_s": round(t_lower, 1),
    }
    if not compile_:
        return rec
    compiled = lowered.compile()
    rec["compile_s"] = round(time.perf_counter() - t0 - t_lower, 1)
    try:
        mem = compiled.memory_analysis()
        if mem is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    rec[k] = int(v)
    except Exception as e:  # backend may not support it
        rec["memory_analysis_error"] = str(e)[:100]
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        if cost:
            rec["hlo_flops"] = float(cost.get("flops", -1))
            rec["hlo_bytes"] = float(cost.get("bytes accessed", -1))
            rec["hlo_transcendentals"] = float(
                cost.get("transcendentals", -1))
    except Exception as e:
        rec["cost_analysis_error"] = str(e)[:100]
    try:
        txt = compiled.as_text()
    except Exception:
        txt = lowered.as_text()
    rec["collective_bytes"] = collective_bytes(txt)
    return rec


def lower_ubis(mesh, *, queries: int = 4096, dim: int = 768,
               compile_: bool = True) -> Dict[str, Any]:
    """Dry-run the paper's technique itself at production scale: the
    UBIS index sharded over the pod (65534 postings x 128 x dim vectors
    ~ 8.4M base vectors), sharded search + insert rounds."""
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.core import UBISConfig, empty_state
    from repro.core.sharded import (index_specs, make_sharded_insert,
                                    make_sharded_search)
    t0 = time.perf_counter()
    cfg = UBISConfig(dim=dim, max_postings=65024, capacity=128,
                     l_min=10, l_max=112, cache_capacity=8192,
                     max_ids=1 << 24, use_pallas="off")
    state_sds = jax.eval_shape(lambda: empty_state(cfg))
    sspec = jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh, sp), index_specs(cfg),
        is_leaf=lambda x: isinstance(x, PartitionSpec))
    state_sds = jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        state_sds, sspec)
    dax = ("pod", "data") if "pod" in mesh.axis_names else "data"
    qsh = NamedSharding(mesh, PartitionSpec(dax))
    q_sds = jax.ShapeDtypeStruct((queries, dim), jnp.float32, sharding=qsh)
    rec: Dict[str, Any] = {
        "arch": "ubis-index", "cell": f"search_q{queries}_d{dim}",
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": mesh.devices.size,
        "param_bytes": int(_bytes_of(state_sds)),
    }
    search = make_sharded_search(cfg, mesh, k=10)
    lowered = search.lower(state_sds, q_sds)
    rec["lower_s"] = round(time.perf_counter() - t0, 1)
    if compile_:
        compiled = lowered.compile()
        rec["compile_s"] = round(
            time.perf_counter() - t0 - rec["lower_s"], 1)
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        if cost:
            rec["hlo_flops"] = float(cost.get("flops", -1))
            rec["hlo_bytes"] = float(cost.get("bytes accessed", -1))
        rec["collective_bytes"] = collective_bytes(compiled.as_text())
    # insert round
    ins = make_sharded_insert(cfg, mesh)
    jsh = NamedSharding(mesh, PartitionSpec())
    J = 4096
    ins_low = ins.lower(
        state_sds,
        jax.ShapeDtypeStruct((J, dim), jnp.float32, sharding=jsh),
        jax.ShapeDtypeStruct((J,), jnp.int32, sharding=jsh),
        jax.ShapeDtypeStruct((J,), jnp.bool_, sharding=jsh))
    if compile_:
        ins_c = ins_low.compile()
        cost = ins_c.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        rec["insert_hlo_flops"] = float(cost.get("flops", -1)) if cost else -1
        rec["insert_collective_bytes"] = collective_bytes(ins_c.as_text())
    return rec


def iter_all_cells():
    for arch in ARCH_IDS:
        for cell in cells_for(arch):
            yield arch, cell


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--cell")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--ubis", action="store_true",
                    help="dry-run the sharded UBIS index itself")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--out", default=None)
    ap.add_argument("--no-compile", action="store_true")
    args = ap.parse_args(argv)

    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(multi_pod=False),
                  make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    cells = (list(iter_all_cells()) if args.all
             else ([(args.arch, args.cell)] if args.arch else []))
    records = []
    for mesh in meshes:
        if args.all or args.ubis:
            try:
                rec = lower_ubis(mesh, compile_=not args.no_compile)
                rec["status"] = "ok"
                print(f"[OK] ubis-index @ {mesh.devices.shape}: "
                      f"flops={rec.get('hlo_flops', 0):.3e}", flush=True)
            except Exception as e:
                rec = {"arch": "ubis-index", "status": "fail",
                       "mesh": "x".join(str(s) for s in mesh.devices.shape),
                       "error": f"{type(e).__name__}: {str(e)[:500]}"}
                print(f"[FAIL] ubis-index @ {mesh.devices.shape}: "
                      f"{rec['error'][:200]}", flush=True)
            records.append(rec)
        for arch, cell in cells:
            tag = f"{arch} x {cell} @ {mesh.devices.shape}"
            try:
                rec = lower_cell(arch, cell, mesh, remat=args.remat,
                                 compile_=not args.no_compile)
                rec["status"] = "ok"
                print(f"[OK] {tag}: flops={rec.get('hlo_flops', 0):.3e} "
                      f"lower={rec['lower_s']}s "
                      f"compile={rec.get('compile_s', '-')}s", flush=True)
            except Exception as e:
                rec = {"arch": arch, "cell": cell,
                       "mesh": "x".join(str(s) for s in mesh.devices.shape),
                       "status": "fail", "error": f"{type(e).__name__}: "
                       f"{str(e)[:500]}"}
                print(f"[FAIL] {tag}: {rec['error'][:200]}", flush=True)
            records.append(rec)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {args.out}")
    n_fail = sum(r["status"] != "ok" for r in records)
    print(f"{len(records) - n_fail}/{len(records)} cells OK")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
