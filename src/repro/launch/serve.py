"""Serving driver: batched generation + streaming UBIS retrieval.

This is the paper-kind end-to-end path: an embedding model produces
vectors for a *fresh* document stream, UBIS indexes them online
(insert/delete/split/merge concurrent with search), and queries are
answered with retrieve(-then-generate).

The server batches requests (fixed batch, padded), embeds with the LM
backbone (mean-pooled final hidden states), and drives the UBIS driver's
foreground/background phases exactly like the paper's thread pools
(DESIGN.md §2: threads -> phases).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import make_index
from repro.core import UBISConfig, metrics as ubis_metrics
from repro.models import get_model
from repro.models.layers import values


@dataclasses.dataclass
class ServeConfig:
    arch: str = "tinyllama-1.1b"
    reduced: bool = True
    embed_dim: int = 64              # PCA-ish projection of hidden states
    batch_size: int = 32
    k: int = 10
    index_dim: int = 64
    seed: int = 0


class EmbeddingServer:
    """Embeds token sequences with the LM backbone; random projection to
    the index dimensionality (frozen, seeded)."""

    def __init__(self, cfg: ServeConfig):
        self.cfg = cfg
        self.model = get_model(cfg.arch, reduced=cfg.reduced)
        self.params = values(self.model.init(jax.random.key(cfg.seed)))
        d_model = self.model.cfg.d_model
        self.proj = jax.random.normal(
            jax.random.key(cfg.seed + 1),
            (d_model, cfg.embed_dim)) / (d_model ** 0.5)
        self._embed = jax.jit(self._embed_fn)

    def _embed_fn(self, params, tokens):
        # mean-pooled final hidden state -> fixed-dim embedding
        from repro.models.transformer import run_segments
        from repro.models.layers import rms_norm
        x = jnp.take(params["emb"], tokens, axis=0)
        x, _ = run_segments(params, self.model.cfg, self.model.segments,
                            x, jnp.arange(tokens.shape[1]),
                            remat="none")
        x = rms_norm(x, params["ln_f"], self.model.cfg.norm_eps)
        return jnp.mean(x, axis=1) @ self.proj

    def embed(self, tokens: np.ndarray) -> np.ndarray:
        return np.asarray(self._embed(self.params, jnp.asarray(tokens)))


class RetrievalServer:
    """Batched streaming retrieval endpoint over any ``StreamingIndex``
    engine (``repro.api.make_index``; default the single-device UBIS
    driver, ``engine="ubis-sharded"`` for the pod-sharded one)."""

    def __init__(self, cfg: ServeConfig, index_cfg: Optional[UBISConfig]
                 = None, seed_vectors: Optional[np.ndarray] = None,
                 engine: str = "ubis", **engine_kw):
        self.cfg = cfg
        self.embedder = EmbeddingServer(cfg)
        if index_cfg is None:
            index_cfg = UBISConfig(dim=cfg.embed_dim, max_postings=2048,
                                   capacity=96, max_ids=1 << 20,
                                   use_pallas="off")
        if seed_vectors is None:
            seed_vectors = np.random.default_rng(cfg.seed).normal(
                size=(1024, index_cfg.dim)).astype(np.float32)
        self.index = make_index(engine, index_cfg, seed_vectors,
                                **engine_kw)
        self._next_id = 0
        self.stats = {"ingested": 0, "queries": 0}

    # -- streaming ingestion ------------------------------------------------

    def ingest_tokens(self, token_batch: np.ndarray) -> np.ndarray:
        """Embed + insert a batch of fresh documents; returns their ids."""
        vecs = self.embedder.embed(token_batch)
        return self.ingest_vectors(vecs)

    def ingest_vectors(self, vecs: np.ndarray) -> np.ndarray:
        ids = np.arange(self._next_id, self._next_id + len(vecs))
        self._next_id += len(vecs)
        self.index.insert(vecs, ids)
        self.index.tick()
        self.stats["ingested"] += len(vecs)
        return ids

    def delete(self, ids: np.ndarray):
        self.index.delete(ids)

    # -- queries -------------------------------------------------------------

    def query_tokens(self, token_batch: np.ndarray, k: Optional[int] = None):
        return self.query_vectors(self.embedder.embed(token_batch), k)

    def query_vectors(self, vecs: np.ndarray, k: Optional[int] = None):
        k = k or self.cfg.k
        found, scores = self.index.search(vecs, k)
        self.stats["queries"] += len(vecs)
        return found, scores

    def recall_check(self, vecs: np.ndarray, k: int = 10) -> float:
        found, _ = self.index.search(vecs, k)
        true, _ = self.index.exact(vecs, k)
        return ubis_metrics.recall_at_k(found, np.asarray(true))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--engine", default="ubis",
                    help="any repro.api.ENGINES name")
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--queries", type=int, default=128)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--batch", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = ServeConfig(arch=args.arch)
    server = RetrievalServer(cfg, engine=args.engine)
    rng = np.random.default_rng(0)
    vocab = server.embedder.model.cfg.vocab
    t0 = time.time()
    for off in range(0, args.docs, args.batch):
        n = min(args.batch, args.docs - off)
        toks = rng.integers(0, vocab, (n, args.seq)).astype(np.int32)
        server.ingest_tokens(toks)
    server.index.flush()
    t_ing = time.time() - t0
    qt = rng.integers(0, vocab, (args.queries, args.seq)).astype(np.int32)
    t0 = time.time()
    found, _ = server.query_tokens(qt)
    t_q = time.time() - t0
    qv = server.embedder.embed(qt)
    rec = server.recall_check(qv)
    print(f"ingested {server.stats['ingested']} docs in {t_ing:.1f}s "
          f"({server.stats['ingested']/t_ing:.0f} docs/s); "
          f"{args.queries} queries in {t_q:.2f}s; recall@10 {rec:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
