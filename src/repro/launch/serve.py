"""Serving driver: batched generation + streaming UBIS retrieval.

This is the paper-kind end-to-end path: an embedding model produces
vectors for a *fresh* document stream, UBIS indexes them online
(insert/delete/split/merge concurrent with search), and queries are
answered with retrieve(-then-generate).

``RetrievalServer`` is a thin client of the serving layer: every ingest
batch and query goes through a ``repro.serving.ServingEngine`` (request
queue, fill-or-deadline batching, dispatch/collect overlap); the
synchronous shape the old server had — embed → insert → tick → search,
one tick per ingest — is the ``tick_every=1`` default of the engine's
cadence knob, so the default behavior is unchanged while ``--async-mode``
(or a custom ``ServingConfig``) turns on real overlap.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import make_index
from repro.core import UBISConfig, metrics as ubis_metrics
from repro.models import get_model
from repro.models.layers import values
from repro.obs import Obs
from repro.serving import ServingConfig, ServingEngine


@dataclasses.dataclass
class ServeConfig:
    arch: str = "tinyllama-1.1b"
    reduced: bool = True
    embed_dim: int = 64              # PCA-ish projection of hidden states
    batch_size: int = 32
    k: int = 10
    index_dim: int = 64
    seed: int = 0
    # background-tick cadence: one index.tick() per N ingest batches
    # (0 = never; the old server ticked unconditionally per ingest)
    tick_every: int = 1
    # observability plane: sampled live-recall probe fraction, optional
    # JSONL trace sink, optional jax.profiler capture directory
    recall_probe: float = 0.0
    obs_trace_path: Optional[str] = None
    obs_profile_dir: Optional[str] = None


class EmbeddingServer:
    """Embeds token sequences with the LM backbone; random projection to
    the index dimensionality (frozen, seeded).  The backbone builds
    lazily on first use — vector-only serving never pays for it."""

    def __init__(self, cfg: ServeConfig):
        self.cfg = cfg
        self.model = get_model(cfg.arch, reduced=cfg.reduced)
        self.params = values(self.model.init(jax.random.key(cfg.seed)))
        d_model = self.model.cfg.d_model
        self.proj = jax.random.normal(
            jax.random.key(cfg.seed + 1),
            (d_model, cfg.embed_dim)) / (d_model ** 0.5)
        self._embed = jax.jit(self._embed_fn)

    def _embed_fn(self, params, tokens):
        # mean-pooled final hidden state -> fixed-dim embedding
        from repro.models.transformer import run_segments
        from repro.models.layers import rms_norm
        x = jnp.take(params["emb"], tokens, axis=0)
        x = run_segments(params, self.model.cfg, self.model.segments,
                         x, jnp.arange(tokens.shape[1]),
                         remat="none")
        x = rms_norm(x, params["ln_f"], self.model.cfg.norm_eps)
        return jnp.mean(x, axis=1) @ self.proj

    def embed(self, tokens: np.ndarray) -> np.ndarray:
        return np.asarray(self._embed(self.params, jnp.asarray(tokens)))


class RetrievalServer:
    """Batched streaming retrieval endpoint over any ``StreamingIndex``
    engine (``repro.api.make_index``; default the single-device UBIS
    driver, ``engine="ubis-sharded"`` for the pod-sharded one).

    All traffic rides the serving engine's queue.  The default
    ``serving_cfg`` preserves the classic synchronous loop (each ingest
    batch flushes immediately and ticks per ``ServeConfig.tick_every``);
    pass a ``ServingConfig`` with real deadlines for open-loop serving.
    """

    def __init__(self, cfg: ServeConfig, index_cfg: Optional[UBISConfig]
                 = None, seed_vectors: Optional[np.ndarray] = None,
                 engine: str = "ubis",
                 serving_cfg: Optional[ServingConfig] = None,
                 **engine_kw):
        self.cfg = cfg
        self._embedder: Optional[EmbeddingServer] = None
        if index_cfg is None:
            index_cfg = UBISConfig(dim=cfg.embed_dim, max_postings=2048,
                                   capacity=96, max_ids=1 << 20,
                                   use_pallas="off")
        if seed_vectors is None:
            seed_vectors = np.random.default_rng(cfg.seed).normal(
                size=(1024, index_cfg.dim)).astype(np.float32)
        # one plane covers the driver's internals AND the request spans
        self.obs = engine_kw.pop("obs", None) or Obs(
            trace_path=cfg.obs_trace_path)
        self.index = make_index(engine, index_cfg, seed_vectors,
                                obs=self.obs,
                                obs_profile_dir=cfg.obs_profile_dir,
                                **engine_kw)
        if serving_cfg is None:
            serving_cfg = ServingConfig(default_k=cfg.k,
                                        tick_every=cfg.tick_every,
                                        recall_probe=cfg.recall_probe,
                                        obs_profile_dir=cfg.obs_profile_dir)
        self.engine = ServingEngine(self.index, serving_cfg, obs=self.obs)
        self._next_id = 0
        self.stats = {"ingested": 0, "queries": 0}

    @property
    def embedder(self) -> EmbeddingServer:
        if self._embedder is None:
            self._embedder = EmbeddingServer(self.cfg)
        return self._embedder

    # -- streaming ingestion ------------------------------------------------

    def ingest_tokens(self, token_batch: np.ndarray) -> np.ndarray:
        """Embed + insert a batch of fresh documents; returns their ids."""
        vecs = self.embedder.embed(token_batch)
        return self.ingest_vectors(vecs)

    def ingest_vectors(self, vecs: np.ndarray) -> np.ndarray:
        """Enqueue + flush one ingest batch.  Background ticks follow
        the engine's ``tick_every`` cadence (the old unconditional
        tick-per-ingest is the default, ``tick_every=1``)."""
        ids = np.arange(self._next_id, self._next_id + len(vecs))
        self._next_id += len(vecs)
        self.engine.submit_insert(vecs, ids)
        self.engine.drain()
        self.stats["ingested"] += len(vecs)
        return ids

    def delete(self, ids: np.ndarray):
        self.engine.submit_delete(ids)
        self.engine.drain()

    # -- queries -------------------------------------------------------------

    def query_tokens(self, token_batch: np.ndarray, k: Optional[int] = None):
        return self.query_vectors(self.embedder.embed(token_batch), k)

    def query_vectors(self, vecs: np.ndarray,
                      k: Optional[int] = None):
        """Queue + resolve a query batch; returns a ``SearchResult``
        (named fields — the old tuple unpacking is gone)."""
        k = k or self.cfg.k
        tickets = [self.engine.submit_search(v, k) for v in
                   np.atleast_2d(np.asarray(vecs, np.float32))]
        self.engine.drain()
        rows = [t.result() for t in tickets]
        self.stats["queries"] += len(rows)
        from repro.api import SearchResult
        return SearchResult(
            ids=np.concatenate([r.ids for r in rows]),
            scores=np.concatenate([r.scores for r in rows]))

    def recall_check(self, vecs: np.ndarray, k: int = 10) -> float:
        found = self.index.search(vecs, k).ids
        true = self.index.exact(vecs, k).ids
        return ubis_metrics.recall_at_k(found, np.asarray(true))

    # -- observability -------------------------------------------------------

    def metrics_text(self) -> str:
        """Prometheus text exposition of the whole plane (driver stats,
        request-span histograms, live-recall gauge)."""
        return self.obs.to_prometheus()

    def metrics_snapshot(self) -> dict:
        """JSON-ready flat snapshot of every registered series."""
        return self.obs.snapshot()

    def trace_events(self, kind: Optional[str] = None):
        """Structured planner/request trace events (newest-capped ring)."""
        return self.obs.events(kind)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--engine", default="ubis",
                    help="any repro.api.ENGINES name")
    ap.add_argument("--docs", type=int, default=2000)
    ap.add_argument("--queries", type=int, default=128)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--tick-every", type=int, default=1,
                    help="background tick per N ingest batches (0=never)")
    ap.add_argument("--recall-probe", type=float, default=0.0,
                    help="shadow-execute this fraction of served query "
                         "batches against exact() (live recall gauge)")
    ap.add_argument("--obs-trace-path", default=None,
                    help="append structured trace events to this JSONL file")
    ap.add_argument("--obs-profile-dir", default=None,
                    help="capture a jax.profiler trace of the first "
                         "working pump/tick into this directory")
    ap.add_argument("--metrics", action="store_true",
                    help="print the Prometheus exposition at exit")
    args = ap.parse_args(argv)

    cfg = ServeConfig(arch=args.arch, tick_every=args.tick_every,
                      recall_probe=args.recall_probe,
                      obs_trace_path=args.obs_trace_path,
                      obs_profile_dir=args.obs_profile_dir)
    server = RetrievalServer(cfg, engine=args.engine)
    rng = np.random.default_rng(0)
    vocab = server.embedder.model.cfg.vocab
    t0 = time.time()
    for off in range(0, args.docs, args.batch):
        n = min(args.batch, args.docs - off)
        toks = rng.integers(0, vocab, (n, args.seq)).astype(np.int32)
        server.ingest_tokens(toks)
    server.index.flush()
    t_ing = time.time() - t0
    qt = rng.integers(0, vocab, (args.queries, args.seq)).astype(np.int32)
    t0 = time.time()
    res = server.query_tokens(qt)
    t_q = time.time() - t0
    qv = server.embedder.embed(qt)
    rec = server.recall_check(qv)
    print(f"ingested {server.stats['ingested']} docs in {t_ing:.1f}s "
          f"({server.stats['ingested']/t_ing:.0f} docs/s); "
          f"{res.ids.shape[0]} queries in {t_q:.2f}s; recall@10 {rec:.3f}")
    if args.metrics:
        print(server.metrics_text())
    return 0


if __name__ == "__main__":
    sys.exit(main())
