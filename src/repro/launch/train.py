"""Production training driver.

Features (DESIGN.md §7): pjit-sharded train step with FSDP+TP rules,
microbatch gradient accumulation, activation checkpointing, atomic
async keep-N checkpoints with auto-resume (params + optimizer + data
cursor), straggler watermark monitoring, SIGTERM preemption handling
(final checkpoint + clean exit), optional int8 optimizer state.

Example (CPU, reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 50 --batch 8 --seq 128 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import TokenStream
from repro.distributed.sharding import (batch_sharding, make_rules,
                                        to_named_sharding)
from repro.distributed.straggler import StragglerMonitor, StepTimer
from repro.models import get_model
from repro.models.layers import sharding_rules, values
from repro.optim import AdamW, AdamWConfig, cosine_warmup


def build_train_step(model, opt, rules, mesh, grad_accum: int):
    ctx_rules = dict(rules, __mesh__=mesh) if mesh is not None else rules

    def loss_fn(params, batch):
        loss, metrics = model.train_loss(params, batch)
        return loss, metrics

    def step_fn(params, opt_state, batch):
        with sharding_rules(ctx_rules):
            if grad_accum == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            else:
                # microbatch accumulation: batch leaves are
                # (grad_accum, per_micro, ...); scan keeps peak memory at
                # one microbatch
                def micro(carry, mb):
                    acc, loss_sum = carry
                    (loss, _), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mb)
                    acc = jax.tree_util.tree_map(jnp.add, acc, g)
                    return (acc, loss_sum + loss), None

                zeros = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss_sum), _ = jax.lax.scan(
                    micro, (zeros, 0.0), batch)
                grads = jax.tree_util.tree_map(
                    lambda g: g / grad_accum, grads)
                loss = loss_sum / grad_accum
                metrics = {}
            params, opt_state, om = opt.apply(params, grads, opt_state)
        return params, opt_state, loss, om

    return step_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="model config overrides, e.g. --override "
                         "d_model=768 --override n_layers=12")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--state-dtype", default="f32",
                    choices=["f32", "bf16", "int8"])
    ap.add_argument("--remat", default="full")
    ap.add_argument("--data", type=int, default=1, help="data mesh axis")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        overrides[k] = int(v) if v.lstrip("-").isdigit() else (
            float(v) if "." in v else v)
    model = get_model(args.arch, reduced=args.reduced, remat=args.remat,
                      **overrides)
    cfg = model.cfg
    use_mesh = args.data * args.model_axis > 1
    mesh = None
    rules = {}
    if use_mesh:
        mesh = jax.make_mesh((args.data, args.model_axis),
                             ("data", "model"))
        rules = make_rules(mesh, "train")
        if cfg.moe is not None and cfg.moe.num_experts % args.model_axis:
            rules["experts"] = None
            rules["expert_ffn"] = "model"

    opt = AdamW(AdamWConfig(state_dtype=args.state_dtype),
                lr=cosine_warmup(args.lr, args.warmup, args.steps))

    # --- init or resume -------------------------------------------------
    ptree = model.init(jax.random.key(args.seed))
    params = values(ptree)
    opt_state = opt.init(params)
    if use_mesh:
        from repro.models.layers import axes_of
        pshard = to_named_sharding(mesh, axes_of(ptree), rules)
        params = jax.device_put(params, pshard)

    stream = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                         batch_per_host=args.batch * args.grad_accum,
                         seed=args.seed)
    mgr = CheckpointManager(args.ckpt) if args.ckpt else None
    start_step = 0
    if mgr is not None:
        step0, restored, extra = mgr.restore_latest(
            {"params": params, "opt": opt_state})
        if step0 is not None:
            params, opt_state = restored["params"], restored["opt"]
            stream.load_state_dict(extra["stream"])
            start_step = step0
            print(f"[resume] from step {step0}", flush=True)

    step_fn = jax.jit(build_train_step(model, opt, rules, mesh,
                                       args.grad_accum),
                      donate_argnums=(0, 1))

    # --- preemption handling ---------------------------------------------
    preempted = {"flag": False}

    def on_sigterm(sig, frame):
        preempted["flag"] = True

    signal.signal(signal.SIGTERM, on_sigterm)

    monitor = StragglerMonitor()
    losses = []
    t_start = time.time()
    for step in range(start_step, args.steps):
        raw = stream.next_batch()
        if args.grad_accum > 1:
            batch = {k: v.reshape(args.grad_accum, args.batch,
                                  *v.shape[1:])
                     for k, v in raw.items()}
        else:
            batch = raw
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        with StepTimer() as t:
            params, opt_state, loss, om = step_fn(params, opt_state, batch)
            jax.block_until_ready(loss)
        straggled = monitor.record(t.seconds)
        losses.append(float(loss))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(loss):.4f} "
                  f"lr {float(om['lr']):.2e} gnorm {float(om['grad_norm']):.2f} "
                  f"{t.seconds*1e3:.0f} ms"
                  + (" [STRAGGLER]" if straggled else ""), flush=True)
        want_ckpt = (mgr is not None
                     and ((step + 1) % args.ckpt_every == 0
                          or preempted["flag"]
                          or step == args.steps - 1))
        if want_ckpt:
            mgr.save(step + 1, {"params": params, "opt": opt_state},
                     extra={"stream": stream.state_dict(),
                            "losses_tail": losses[-20:]})
        if preempted["flag"]:
            print("[preempt] checkpoint written, exiting", flush=True)
            mgr and mgr.wait()
            return 0
    if mgr:
        mgr.wait()
    dt = time.time() - t_start
    print(f"done: {args.steps - start_step} steps in {dt:.1f}s; "
          f"first loss {losses[0]:.4f} -> last {losses[-1]:.4f}; "
          f"straggler flags {monitor.flagged}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
