"""Architecture zoo (pure JAX)."""
from .config import ModelConfig, MoEConfig, ShapeCell, SHAPE_CELLS, cells_for
from .registry import LM, ARCH_IDS, get_config, get_model

__all__ = ["ModelConfig", "MoEConfig", "ShapeCell", "SHAPE_CELLS",
           "cells_for", "LM", "ARCH_IDS", "get_config", "get_model"]
