"""Serving embed backbone (pure JAX, attention-only)."""
from .config import ModelConfig
from .registry import LM, ARCH_IDS, get_config, get_model

__all__ = ["ModelConfig", "LM", "ARCH_IDS", "get_config", "get_model"]
