"""Attention for the zoo: chunked-causal (train/prefill), blocked-local
(sliding window), bidirectional (encoder), cross, and cached decode.

Pure-JAX implementations are memory-bounded by construction (online
softmax over KV chunks — the XLA analogue of flash attention) so the
32k-prefill cells fit; the Pallas kernel (kernels/flash_attention.py) is
the TPU fast path, selected by ``backend``.

Decode uses a KV cache whose *sequence* axis carries the logical axis
"kv_seq"; the production sharding rules map it onto the ``model`` mesh
axis (sequence-parallel decode: GQA kv-head counts (4-16) do not divide
the 16-way model axis, so heads stay local and XLA inserts the partial
softmax reductions across sequence shards — DESIGN.md §7).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels import ops
from .layers import shard

NEG = -1e30


def _gqa_shape(q, n_kv):
    B, Hq, L, D = q.shape
    return q.reshape(B, n_kv, Hq // n_kv, L, D)


def chunked_attention(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None, q_offset: int = 0,
                      chunk_q: int = 512, chunk_k: int = 512,
                      backend: str = "auto"):
    """q (B,Hq,Lq,D); k,v (B,Hkv,Lk,D) -> (B,Hq,Lq,D).

    ``q_offset``: global position of q row 0 (Lk - Lq for end-aligned
    decode/prefill continuation)."""
    B, Hq, Lq, D = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    if backend != "off" and jax.default_backend() == "tpu":
        return ops.flash_attention(q, k, v, causal=causal, window=window,
                                   backend=backend)
    cq = min(chunk_q, Lq)
    ck = min(chunk_k, Lk)
    # pad to chunk multiples (q at front to keep end alignment, k at back)
    pq = (-Lq) % cq
    pk = (-Lk) % ck
    qp = jnp.pad(q, ((0, 0), (0, 0), (pq, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq, nk = qp.shape[2] // cq, kp.shape[2] // ck
    G = Hq // Hkv
    qg = qp.reshape(B, Hkv, G, nq, cq, D).transpose(3, 0, 1, 2, 4, 5)
    kg = kp.reshape(B, Hkv, nk, ck, D).transpose(2, 0, 1, 3, 4)
    vg = vp.reshape(B, Hkv, nk, ck, D).transpose(2, 0, 1, 3, 4)
    scale = 1.0 / (D ** 0.5)
    q_off = q_offset - pq

    def q_step(_, qi_qc):
        qi, qc = qi_qc
        # mixed precision (flash-standard): matmul INPUTS stay in the
        # storage dtype (bf16 on TPU -> half the HBM traffic of an
        # upcast), accumulation in f32 via preferred_element_type
        qc = qc * jnp.asarray(scale, qc.dtype)
        qpos = q_off + qi * cq + jnp.arange(cq)

        def k_step(carry, ki_kc):
            m, l, acc = carry
            ki, kc, vc = ki_kc
            kpos = ki * ck + jnp.arange(ck)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qc, kc,
                           preferred_element_type=jnp.float32)
            mask = (kpos[None, :] < Lk)
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window is not None:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG)
            m_new = jnp.maximum(m, jnp.max(s, -1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, -1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(qc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, cq), NEG, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, cq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, cq, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0), (jnp.arange(nk), kg, vg))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qg))
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hq, nq * cq, D)
    return out[:, :, pq:, :]


def local_attention(q, k, v, window: int, backend: str = "auto"):
    """Blocked sliding-window causal attention, O(L * 2w) compute.

    Exact for self-attention (Lq == Lk) when blocks = window size: query
    block i attends key blocks {i-1, i} with the band mask."""
    B, Hq, L, D = q.shape
    Hkv = k.shape[1]
    if backend != "off" and jax.default_backend() == "tpu":
        return ops.flash_attention(q, k, v, causal=True, window=window,
                                   backend=backend)
    w = window
    p = (-L) % w
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, p), (0, 0)))
    # one extra leading key block of zeros stands in for "block -1"
    kp = jnp.pad(k, ((0, 0), (0, 0), (w, p), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (w, p), (0, 0)))
    Lp = L + p
    nb = Lp // w
    G = Hq // Hkv
    qb = qp.reshape(B, Hkv, G, nb, w, D).transpose(3, 0, 1, 2, 4, 5)
    scale = 1.0 / (D ** 0.5)
    qpos_in = jnp.arange(w)[:, None]
    kpos_in = jnp.arange(2 * w)[None, :] - w
    band = (kpos_in <= qpos_in) & (kpos_in > qpos_in - w)

    def step(_, i_qc):
        i, qc = i_qc                                    # qc (B,Hkv,G,w,D)
        k2 = jax.lax.dynamic_slice_in_dim(kp, i * w, 2 * w, axis=2)
        v2 = jax.lax.dynamic_slice_in_dim(vp, i * w, 2 * w, axis=2)
        s = jnp.einsum("bhgqd,bhkd->bhgqk",
                       qc * jnp.asarray(scale, qc.dtype), k2,
                       preferred_element_type=jnp.float32)
        gq = i * w + qpos_in                            # (w, 1) global
        gk = i * w + kpos_in                            # (1, 2w) global
        valid = band & (gk >= 0) & (gk < L) & (gq < L)
        s = jnp.where(valid[None, None, None], s, NEG)
        pr = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", pr.astype(q.dtype), v2,
                       preferred_element_type=jnp.float32)
        return None, o.astype(q.dtype)

    _, outs = jax.lax.scan(step, None, (jnp.arange(nb), qb))
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hq, Lp, D)
    return out[:, :, :L]


def decode_attention(q1, k_cache, v_cache, pos, window: Optional[int] = None):
    """One-token attention against a cache.

    q1 (B,Hq,D); caches (B,Hkv,S,D); pos (): index of the current token
    (cache entries 0..pos valid).  The cache seq axis may be sharded
    ("kv_seq" -> model); XLA inserts the cross-shard softmax reductions.
    """
    B, Hq, D = q1.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qg = q1.reshape(B, Hkv, G, D).astype(jnp.float32) / (D ** 0.5)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg, k_cache.astype(jnp.float32))
    kpos = jnp.arange(S)
    mask = kpos <= pos
    if window is not None:
        mask = mask & (kpos > pos - window)
    s = jnp.where(mask[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bhsd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, D).astype(q1.dtype)


def cache_update(k_cache, v_cache, k1, v1, pos):
    """Write the new token's k/v at ``pos`` (dynamic-update-slice; on a
    seq-sharded cache GSPMD keeps the update local to the owning shard)."""
    k1 = k1[:, :, None, :].astype(k_cache.dtype)
    v1 = v1[:, :, None, :].astype(v_cache.dtype)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k1, pos, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v1, pos, axis=2)
    return k_cache, v_cache
