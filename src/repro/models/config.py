"""Model configuration schema + the assigned input-shape cells."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    every_k_layers: int = 1          # MoE replaces the FFN every k layers
    capacity_factor: float = 1.25
    # pad the expert dimension so EP divides the model axis (dead experts
    # are masked out of the router); beyond-paper perf fix for expert
    # counts like granite's 40 on a 16-way mesh (EXPERIMENTS.md §Perf)
    padded_experts: int = 0
    # GShard-style local dispatch: tokens compete for per-(group, expert)
    # capacity and never leave their data shard for dispatch/combine
    # (set to the data-parallel degree; 1 = global dispatch)
    dispatch_groups: int = 1

    @property
    def e_pad(self) -> int:
        return max(self.num_experts, self.padded_experts)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One assigned architecture (exact numbers from the assignment)."""

    name: str
    family: str                       # dense | encdec | ssm | moe | vlm | hybrid
    n_layers: int
    d_model: int
    n_heads: int                      # query heads (0 for attention-free)
    n_kv: int                         # kv heads (GQA)
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None    # default d_model // n_heads
    qk_norm: bool = False             # qwen3-style
    rope_theta: float = 10000.0
    # local/global interleave (gemma3): window size + pattern period;
    # pattern "LLLLLG" means 5 local then 1 global
    sliding_window: Optional[int] = None
    local_global_pattern: Optional[str] = None
    moe: Optional[MoEConfig] = None
    # hybrid (jamba): attention every k layers, the rest Mamba
    attn_every_k: Optional[int] = None
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_conv: int = 4
    # encoder-decoder (seamless): encoder layer count (decoder = n_layers)
    encoder_layers: int = 0
    # multimodal stubs: number of prefix embeddings supplied by frontend
    prefix_len: int = 0
    # rwkv
    rwkv_head_dim: int = 64
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def vocab_padded(self) -> int:
        """Embedding tables pad the vocab to a multiple of 256 so the
        vocab axis divides any mesh axis (pad logits are masked out of
        the loss and the decode head)."""
        return -(-self.vocab // 256) * 256

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        kw: dict = dict(
            n_layers=min(self.n_layers, 2 if self.attn_every_k is None
                         else self.attn_every_k),
            d_model=128,
            n_heads=min(self.n_heads, 4) or 0,
            n_kv=min(self.n_kv, 2) or 0,
            d_ff=256,
            vocab=min(self.vocab, 512),
            head_dim=32 if self.n_heads else None,
            encoder_layers=min(self.encoder_layers, 2),
            prefix_len=min(self.prefix_len, 4),
            sliding_window=(64 if self.sliding_window else None),
            rwkv_head_dim=32,
        )
        if self.moe is not None:
            kw["moe"] = MoEConfig(
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=64,
                every_k_layers=self.moe.every_k_layers,
            )
        if self.attn_every_k is not None:
            kw["n_layers"] = self.attn_every_k  # one full hybrid period
        if self.local_global_pattern is not None:
            kw["local_global_pattern"] = self.local_global_pattern
            kw["n_layers"] = len(self.local_global_pattern)
        kw.update(overrides)
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                         # "train" | "prefill" | "decode"


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic decode state; DESIGN.md §6)
LONG_CONTEXT_ARCHS = ("rwkv6-3b", "jamba-1.5-large-398b", "gemma3-4b")


def cells_for(arch_name: str):
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch_name in LONG_CONTEXT_ARCHS:
        out.append("long_500k")
    return out
