"""Model configuration schema (attention-only; the Mamba/MoE/RWKV
training zoo this schema once covered is gone with the training stack)."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture of the serving embed backbone."""

    name: str
    family: str                       # dense | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                      # query heads
    n_kv: int                         # kv heads (GQA)
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None    # default d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # local/global interleave: window size + pattern period; pattern
    # "LLLLLG" means 5 local then 1 global
    sliding_window: Optional[int] = None
    local_global_pattern: Optional[str] = None
    # encoder-decoder: encoder layer count (decoder = n_layers)
    encoder_layers: int = 0
    # multimodal stubs: number of prefix embeddings supplied by frontend
    prefix_len: int = 0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def vocab_padded(self) -> int:
        """Embedding tables pad the vocab to a multiple of 256 so the
        vocab axis divides any mesh axis (pad logits are masked out of
        the loss and the decode head)."""
        return -(-self.vocab // 256) * 256

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        kw: dict = dict(
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=min(self.n_heads, 4) or 0,
            n_kv=min(self.n_kv, 2) or 0,
            d_ff=256,
            vocab=min(self.vocab, 512),
            head_dim=32 if self.n_heads else None,
            encoder_layers=min(self.encoder_layers, 2),
            prefix_len=min(self.prefix_len, 4),
            sliding_window=(64 if self.sliding_window else None),
        )
        if self.local_global_pattern is not None:
            kw["local_global_pattern"] = self.local_global_pattern
            kw["n_layers"] = len(self.local_global_pattern)
        kw.update(overrides)
        return dataclasses.replace(self, **kw)
