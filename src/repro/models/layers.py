"""Shared building blocks for the architecture zoo (pure JAX, no flax).

Parameters are pytrees whose leaves are ``Param(value, axes)`` — the
``axes`` tuple names each dimension with a *logical* axis ("embed",
"heads", "ffn", "vocab", "experts", ...).  ``distributed/sharding.py``
maps logical axes onto mesh axes, both for parameter shardings (pjit
in_shardings) and for in-graph activation constraints (``shard()``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Param:
    value: Any
    axes: Tuple[Optional[str], ...] = dataclasses.field(
        metadata=dict(static=True), default=())


def param(key, shape, axes, scale=0.02, dtype=jnp.float32, init="normal"):
    assert len(shape) == len(axes), (shape, axes)
    if init == "normal":
        v = jax.random.normal(key, shape, dtype) * scale
    elif init == "zeros":
        v = jnp.zeros(shape, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    else:
        raise ValueError(init)
    return Param(v, tuple(axes))


def is_param(x) -> bool:
    return isinstance(x, Param)


def values(tree):
    return jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=is_param)


def axes_of(tree):
    """Logical axes as PartitionSpec leaves (PartitionSpec is an atomic
    pytree leaf, so downstream tree_maps do not descend into the names)."""
    return jax.tree_util.tree_map(
        lambda p: jax.sharding.PartitionSpec(*p.axes), tree,
        is_leaf=is_param)


# --- activation sharding annotations ---------------------------------------
# A context-managed mapping logical-axis -> mesh-axis (or None).  When no
# context is installed (single-device tests), ``shard`` is a no-op.

_RULES: list = []


class sharding_rules:
    def __init__(self, rules: dict):
        self.rules = rules

    def __enter__(self):
        _RULES.append(self.rules)
        return self

    def __exit__(self, *a):
        _RULES.pop()


def shard(x, *axes):
    """Constrain activation ``x`` with logical axes (None = replicated).

    No-op unless a rules context with a ``__mesh__`` entry is installed
    (single-device tests and mesh-less training skip constraints)."""
    if not _RULES:
        return x
    rules = _RULES[-1]
    mesh = rules.get("__mesh__")
    if mesh is None:
        return x
    spec = jax.sharding.PartitionSpec(
        *[rules.get(a) if a is not None else None for a in axes])
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


# --- primitive layers -------------------------------------------------------

def rms_norm(x, w, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x, w, b, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, -1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def rope(x, positions, theta=10000.0):
    """Rotary embedding.  x: (..., L, D) with D even; positions: (..., L)."""
    D = x.shape[-1]
    half = D // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., L, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP.  x: (..., D); w_gate/up: (D, F); w_down: (F, D)."""
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    h = shard(h, "batch", *([None] * (h.ndim - 2)), "ffn")
    return h @ w_down


def init_mlp(key, d_model, d_ff, n_layers_scale=1.0, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    s = 0.02
    return {
        "w_gate": param(k1, (d_model, d_ff), ("embed", "ffn"), s, dtype),
        "w_up": param(k2, (d_model, d_ff), ("embed", "ffn"), s, dtype),
        "w_down": param(k3, (d_ff, d_model), ("ffn", "embed"),
                        s * n_layers_scale, dtype),
    }


def apply_mlp(p, x):
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])

