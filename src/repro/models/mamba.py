"""Mamba-1 selective SSM block (the jamba hybrid's workhorse mixer).

The diagonal selective scan ``h_t = a_t ⊙ h_{t-1} + b_t`` is evaluated
with ``jax.lax.associative_scan`` *within* fixed-size chunks (parallel
depth log T_M) and a ``lax.scan`` carry *across* chunks, which bounds
the materialised (B, T_M, d_inner, d_state) tensors — the adaptation of
the CUDA selective-scan kernel's SRAM blocking to XLA/TPU (DESIGN.md §2).
Decode is the O(1) single-token recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import param, shard

T_M = 256  # chunk length for the associative scan


def init_mamba(key, d_model: int, d_state: int, expand: int, d_conv: int,
               out_scale=0.02, dtype=jnp.float32):
    d_inner = expand * d_model
    dt_rank = max(1, d_model // 16)
    ks = jax.random.split(key, 7)
    # A initialised to -[1..N] (S4D-real), stored as log
    a_init = jnp.log(jnp.broadcast_to(
        jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_inner, d_state)))
    return {
        "in_proj": param(ks[0], (d_model, 2 * d_inner), ("embed", "ffn"),
                         0.02, dtype),
        "conv_w": param(ks[1], (d_conv, d_inner), (None, "ffn"), 0.02,
                        dtype),
        "conv_b": param(ks[2], (d_inner,), ("ffn",), 0.0, dtype,
                        init="zeros"),
        "x_proj": param(ks[3], (d_inner, dt_rank + 2 * d_state),
                        ("ffn", None), 0.02, dtype),
        "dt_proj": param(ks[4], (dt_rank, d_inner), (None, "ffn"), 0.02,
                         dtype),
        "dt_bias": param(ks[5], (d_inner,), ("ffn",), 0.02, dtype),
        "a_log": Paramlike(a_init),
        "d_skip": param(ks[6], (d_inner,), ("ffn",), 1.0, dtype,
                        init="ones"),
        "out_proj": param(jax.random.fold_in(key, 7), (d_inner, d_model),
                          ("ffn", "embed"), out_scale, dtype),
    }


def Paramlike(v):
    from .layers import Param
    return Param(v, ("ffn", None))


def _ssm_scan(a, b, h0):
    """a, b: (B, L, E, N); h0: (B, E, N).  Chunked associative scan."""
    B, L, E, N = a.shape
    pad = (-L) % T_M
    a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (L + pad) // T_M
    a = a.reshape(B, nc, T_M, E, N).transpose(1, 0, 2, 3, 4)
    b = b.reshape(B, nc, T_M, E, N).transpose(1, 0, 2, 3, 4)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    def chunk(h, ab):
        ac, bc = ab                       # (B, T_M, E, N)
        aa, bb = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = aa * h[:, None] + bb      # (B, T_M, E, N)
        return h_all[:, -1], h_all

    h_last, hs = jax.lax.scan(chunk, h0, (a, b))
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(B, nc * T_M, E, N)[:, :L]
    return h_last, hs


def apply_mamba(p, x, d_state: int, conv_state=None, ssm_state=None):
    """x (B, L, D) -> (out, (conv_state, ssm_state))."""
    B, L, D = x.shape
    d_inner = p["in_proj"].shape[1] // 2
    d_conv = p["conv_w"].shape[0]
    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)     # (B, L, E)
    xs = shard(xs, "batch", None, "ffn")

    # causal depthwise conv1d
    if conv_state is None:
        conv_state = jnp.zeros((B, d_conv - 1, d_inner), x.dtype)
    xc = jnp.concatenate([conv_state, xs], axis=1)
    new_conv_state = xc[:, -(d_conv - 1):] if d_conv > 1 else conv_state
    xs = sum(xc[:, i:i + L] * p["conv_w"][i] for i in range(d_conv))
    xs = jax.nn.silu(xs + p["conv_b"])

    proj = xs @ p["x_proj"]               # (B, L, R + 2N)
    dt_rank = p["dt_proj"].shape[0]
    dt, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])  # (B, L, E)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))            # (E, N)
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A)      # (B, L, E, N)
    b = (dt * xs).astype(jnp.float32)[..., None] * \
        Bm.astype(jnp.float32)[:, :, None, :]               # (B, L, E, N)

    if ssm_state is None:
        ssm_state = jnp.zeros((B, d_inner, d_state), jnp.float32)
    h_last, hs = _ssm_scan(a, b, ssm_state)
    y = jnp.einsum("blen,bln->ble", hs, Cm.astype(jnp.float32))
    y = (y + xs.astype(jnp.float32) * p["d_skip"]).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = shard(y, "batch", None, "ffn")
    out = y @ p["out_proj"]
    return out, (new_conv_state, h_last)


def decode_mamba(p, x1, conv_state, ssm_state, d_state: int):
    """x1 (B, D); conv_state (B, d_conv-1, E); ssm_state (B, E, N)."""
    B, D = x1.shape
    d_inner = p["in_proj"].shape[1] // 2
    d_conv = p["conv_w"].shape[0]
    xz = x1 @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)     # (B, E)
    xc = jnp.concatenate([conv_state, xs[:, None]], axis=1)  # (B, d_conv, E)
    new_conv_state = xc[:, 1:]
    xs = jnp.einsum("bke,ke->be", xc, p["conv_w"])
    xs = jax.nn.silu(xs + p["conv_b"])
    proj = xs @ p["x_proj"]
    dt_rank = p["dt_proj"].shape[0]
    dt, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])   # (B, E)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A)       # (B, E, N)
    b = (dt * xs).astype(jnp.float32)[..., None] * \
        Bm.astype(jnp.float32)[:, None, :]
    h = a * ssm_state + b
    y = jnp.einsum("ben,bn->be", h, Cm.astype(jnp.float32))
    y = (y + xs.astype(jnp.float32) * p["d_skip"]).astype(x1.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], (new_conv_state, h)
