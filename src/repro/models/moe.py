"""Mixture-of-Experts FFN with sort-based capacity dispatch (EP-shardable).

Dispatch avoids the (T, E, C) one-hot tensor of the Mesh-TF lineage:
tokens are ranked within their routed expert (stable argsort — the same
conflict-free grouping primitive the UBIS controller uses), dropped past
capacity, and gathered into an (E, C, D) buffer.  Logical shardings:
experts -> model axis (EP); capacity rows -> data axis; so under pjit the
gather/scatter lower to the expected all-to-alls.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .config import MoEConfig
from .layers import Param, param, shard


def _ranks_in_group(keys: jax.Array) -> jax.Array:
    """Stable rank of each element within its equal-key group."""
    n = keys.shape[0]
    order = jnp.argsort(keys, stable=True)
    ks = keys[order]
    idx = jnp.arange(n, dtype=jnp.int32)
    seg_start = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    seg_first = jax.lax.associative_scan(
        jnp.maximum, jnp.where(seg_start, idx, 0))
    return jnp.zeros((n,), jnp.int32).at[order].set(idx - seg_first)


def init_moe(key, d_model: int, mcfg: MoEConfig, out_scale=0.02,
             dtype=jnp.float32):
    kr, k1, k2, k3 = jax.random.split(key, 4)
    E, F = mcfg.e_pad, mcfg.d_ff_expert
    return {
        "router": param(kr, (d_model, E), ("embed", "experts"), 0.02, dtype),
        # per-expert FFN dims carry their own logical axis: when experts
        # shard over "model" (EP) it stays replicated; when the expert
        # count doesn't divide the model axis the rules flip to
        # TP-within-expert (experts->None, expert_ffn->model).
        "w_gate": param(k1, (E, d_model, F),
                        ("experts", "embed", "expert_ffn"), 0.02, dtype),
        "w_up": param(k2, (E, d_model, F),
                      ("experts", "embed", "expert_ffn"), 0.02, dtype),
        "w_down": param(k3, (E, F, d_model),
                        ("experts", "expert_ffn", "embed"),
                        out_scale, dtype),
    }


def apply_moe(p, x: jax.Array, mcfg: MoEConfig) -> Tuple[jax.Array, jax.Array]:
    """x (B, L, D) -> (out (B, L, D), aux_loss ()).

    With ``dispatch_groups == G > 1`` the token axis is pre-split into G
    groups (aligned with the data shards): routing ranks, the dispatch
    gather and the combine scatter all stay group-local, so the only
    cross-shard traffic left is the experts' FSDP parameter movement —
    the GShard/Switch 2-D dispatch (EXPERIMENTS.md §Perf, granite)."""
    B, L, D = x.shape
    if mcfg.dispatch_groups > 1:
        return _apply_moe_grouped(p, x, mcfg)
    E, K = mcfg.e_pad, mcfg.top_k
    T = B * L
    xf = x.reshape(T, D)

    logits = (xf @ p["router"]).astype(jnp.float32)       # (T, E_pad)
    if E > mcfg.num_experts:                              # padded EP
        dead = jnp.arange(E) >= mcfg.num_experts
        logits = jnp.where(dead[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, K)                  # (T, K)
    topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style; over real experts only)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * mean_prob)

    C = int(math.ceil(T * K * mcfg.capacity_factor
                      / mcfg.num_experts))
    C = max(8, -(-C // 8) * 8)                            # pad to sublanes

    flat_e = topi.reshape(T * K).astype(jnp.int32)        # routed expert
    flat_w = topv.reshape(T * K)
    flat_tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    rank = _ranks_in_group(flat_e)
    ok = rank < C
    slot = flat_e * C + rank                              # (T*K,) in [0, E*C)
    slot = jnp.where(ok, slot, E * C)                     # OOB -> dropped

    # dispatch: which flat (token) row sits in each (e, c) seat
    seat_tok = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        flat_tok, mode="drop")[:E * C]
    seat_ok = seat_tok < T
    xg = jnp.where(seat_ok[:, None],
                   xf[jnp.minimum(seat_tok, T - 1)], 0.0)
    xg = xg.reshape(E, C, D)
    xg = shard(xg, "experts", "expert_cap", None)

    h = jnp.einsum("ecd,edf->ecf", xg, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xg, p["w_up"])
    h = jax.nn.silu(h) * u
    h = shard(h, "experts", "expert_cap", "expert_ffn")
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"])        # (E, C, D)
    y = shard(y, "experts", "expert_cap", None)

    # combine: scatter-add weighted expert outputs back to tokens
    yf = y.reshape(E * C, D)
    seat_w = jnp.zeros((E * C + 1,), flat_w.dtype).at[slot].set(
        flat_w, mode="drop")[:E * C]
    out = jnp.zeros((T, D), y.dtype).at[
        jnp.where(seat_ok, seat_tok, T)].add(
            yf * seat_w[:, None], mode="drop")
    return out.reshape(B, L, D).astype(x.dtype), aux


def _apply_moe_grouped(p, x: jax.Array, mcfg: MoEConfig):
    B, L, D = x.shape
    E, K, G = mcfg.e_pad, mcfg.top_k, mcfg.dispatch_groups
    T = B * L
    assert T % G == 0, (T, G)
    Tg = T // G
    xf = x.reshape(G, Tg, D)
    xf = shard(xf, "batch", None, None)

    logits = jnp.einsum("gtd,de->gte", xf, p["router"]).astype(jnp.float32)
    if E > mcfg.num_experts:
        dead = jnp.arange(E) >= mcfg.num_experts
        logits = jnp.where(dead[None, None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, K)                  # (G, Tg, K)
    topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)
    frac_tokens = jnp.mean(jax.nn.one_hot(
        topi[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * jnp.mean(probs, axis=(0, 1)))

    C = int(math.ceil(Tg * K * mcfg.capacity_factor / mcfg.num_experts))
    C = max(8, -(-C // 8) * 8)

    flat_e = topi.reshape(G, Tg * K).astype(jnp.int32)
    flat_w = topv.reshape(G, Tg * K)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), K)[None],
        (G, Tg * K))
    rank = jax.vmap(_ranks_in_group)(flat_e)
    ok = rank < C
    slot = jnp.where(ok, flat_e * C + rank, E * C)

    seat_tok = jnp.full((G, E * C + 1), Tg, jnp.int32)
    seat_tok = jax.vmap(lambda st, sl, ft: st.at[sl].set(ft, mode="drop"))(
        seat_tok, slot, flat_tok)[:, :E * C]
    seat_ok = seat_tok < Tg
    xg = jax.vmap(lambda xfg, st, so: jnp.where(
        so[:, None], xfg[jnp.minimum(st, Tg - 1)], 0.0))(
            xf, seat_tok, seat_ok)
    xg = xg.reshape(G, E, C, D)
    xg = shard(xg, "batch", "experts", None, None)

    h = jnp.einsum("gecd,edf->gecf", xg, p["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", xg, p["w_up"])
    h = jax.nn.silu(h) * u
    h = shard(h, "batch", "experts", None, "expert_ffn")
    y = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    y = shard(y, "batch", "experts", None, None)

    yf = y.reshape(G, E * C, D)
    seat_w = jnp.zeros((G, E * C + 1), flat_w.dtype)
    seat_w = jax.vmap(lambda sw, sl, fw: sw.at[sl].set(fw, mode="drop"))(
        seat_w, slot, flat_w)[:, :E * C]
    out = jax.vmap(lambda yfg, st, so, sw: jnp.zeros(
        (Tg, D), yfg.dtype).at[jnp.where(so, st, Tg)].add(
            yfg * sw[:, None], mode="drop"))(yf, seat_tok, seat_ok, seat_w)
    out = out.reshape(B, L, D).astype(x.dtype)
    return shard(out, "batch", None, None), aux
