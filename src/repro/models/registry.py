"""Model registry: config -> LM object (init / train_loss / prefill /
decode_step), plus the architecture catalogue.

The catalogue is inlined here: the serving embed backbone
(``launch/serve.py``) is the only consumer, and it only ever builds
``tinyllama-1.1b`` (usually ``reduced=True``).  The old per-arch config
modules under ``repro/configs/`` are gone with the training stack.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Param, axes_of, param, rms_norm, shard, values
from .transformer import (SubLayer, init_layer_cache, init_segment,
                          plan_segments, run_decode, run_segments)

ENC_SRC_LEN = 1024  # audio-frontend stub length (encdec)


def chunked_lm_loss(x, head, targets, mask, chunk: int = 1024,
                    vocab_real: int | None = None):
    """Cross-entropy without materialising (B, L, V) logits at once.
    ``vocab_real``: mask padded-vocab logits out of the softmax."""
    B, L, D = x.shape
    pad = (-L) % chunk
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    tp = jnp.pad(targets, ((0, 0), (0, pad)))
    mp = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = (L + pad) // chunk
    xc = xp.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    tc = tp.reshape(B, nc, chunk).transpose(1, 0, 2)
    mc = mp.reshape(B, nc, chunk).transpose(1, 0, 2)

    def step(carry, xtm):
        s, n = carry
        xch, tch, mch = xtm
        logits = (xch @ head).astype(jnp.float32)
        logits = shard(logits, "batch", None, "vocab")
        if vocab_real is not None and vocab_real < logits.shape[-1]:
            pad_mask = jnp.arange(logits.shape[-1]) < vocab_real
            logits = jnp.where(pad_mask, logits, -1e30)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, tch[..., None], axis=-1)[..., 0]
        m = mch.astype(jnp.float32)
        return (s + jnp.sum((lse - ll) * m), n + jnp.sum(m)), None

    (s, n), _ = jax.lax.scan(step, (0.0, 0.0), (xc, tc, mc))
    return s / jnp.maximum(n, 1.0)


class LM:
    """One architecture, fully assembled."""

    def __init__(self, cfg: ModelConfig, remat: str = "full",
                 unroll: bool = False):
        self.cfg = cfg
        self.remat = remat
        self.unroll = unroll  # unrolled scans (exact HLO cost analysis)
        self.segments = plan_segments(cfg)
        if cfg.family == "encdec":
            self.enc_segments = [
                ((SubLayer("attn", "mlp", causal=False),),
                 cfg.encoder_layers)]
        else:
            self.enc_segments = []

    # -- parameters ------------------------------------------------------

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        n_total = max(cfg.n_layers + cfg.encoder_layers, 1)
        out_scale = 1.0 / (2.0 * n_total) ** 0.5
        ks = iter(jax.random.split(key, 8 + len(self.segments)
                                   + len(self.enc_segments)))
        tree: Dict[str, Any] = {
            "emb": param(next(ks), (cfg.vocab_padded, cfg.d_model),
                         ("vocab", "embed")),
            "ln_f": param(next(ks), (cfg.d_model,), ("embed",),
                          init="zeros"),
        }
        if not cfg.tie_embeddings:
            tree["head"] = param(next(ks), (cfg.d_model, cfg.vocab_padded),
                                 ("embed", "vocab"))
        for si, (descrs, repeat) in enumerate(self.segments):
            tree[f"seg{si}"] = init_segment(next(ks), cfg, descrs, repeat,
                                            out_scale)
        if self.enc_segments:
            enc = {"ln_f": param(next(ks), (cfg.d_model,), ("embed",),
                                 init="zeros")}
            for si, (descrs, repeat) in enumerate(self.enc_segments):
                enc[f"seg{si}"] = init_segment(next(ks), cfg, descrs,
                                               repeat, out_scale)
            tree["enc"] = enc
        return tree

    def param_shapes(self, dtype=jnp.float32):
        """(ShapeDtypeStruct values, logical PartitionSpec axes) without
        allocating anything."""
        tree = jax.eval_shape(self.init, jax.random.key(0))
        vals = values(tree)
        if dtype is not None:
            vals = jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape, dtype), vals)
        return vals, axes_of(tree)

    # -- forward paths (value trees) --------------------------------------

    def _encode(self, pv, src):
        x = run_segments(pv["enc"], self.cfg, self.enc_segments, src,
                         jnp.arange(src.shape[1]), remat=self.remat,
                         unroll=self.unroll)
        return rms_norm(x, pv["enc"]["ln_f"], self.cfg.norm_eps)

    def _inputs(self, pv, batch):
        tokens = batch["tokens"]
        x = jnp.take(pv["emb"], tokens, axis=0)
        x = shard(x, "batch", None, None)
        enc_out = None
        prefix_len = 0
        if "prefix" in batch:                      # vlm patch embeddings
            x = jnp.concatenate([batch["prefix"].astype(x.dtype), x],
                                axis=1)
            prefix_len = batch["prefix"].shape[1]
        if "src" in batch:                         # audio frames (encdec)
            enc_out = self._encode(pv, batch["src"].astype(x.dtype))
        return x, enc_out, prefix_len

    def _head(self, pv):
        if self.cfg.tie_embeddings:
            return pv["emb"].T
        return pv["head"]

    def _mask_pad_vocab(self, logits):
        if self.cfg.vocab_padded > self.cfg.vocab:
            keep = jnp.arange(logits.shape[-1]) < self.cfg.vocab
            logits = jnp.where(keep, logits, -1e30)
        return logits

    def train_loss(self, pv, batch):
        cfg = self.cfg
        x, enc_out, prefix_len = self._inputs(pv, batch)
        positions = jnp.arange(x.shape[1])
        x = run_segments(pv, cfg, self.segments, x, positions,
                         enc_out=enc_out, remat=self.remat,
                         unroll=self.unroll)
        x = rms_norm(x, pv["ln_f"], cfg.norm_eps)
        if prefix_len:
            x = x[:, prefix_len:]
        targets = batch["targets"]
        mask = (targets >= 0).astype(jnp.float32)
        loss = chunked_lm_loss(x, self._head(pv),
                               jnp.maximum(targets, 0), mask,
                               vocab_real=cfg.vocab)
        return loss, {"lm_loss": loss}

    def prefill(self, pv, batch):
        cfg = self.cfg
        x, enc_out, _ = self._inputs(pv, batch)
        positions = jnp.arange(x.shape[1])
        x, caches = run_segments(pv, cfg, self.segments, x, positions,
                                 enc_out=enc_out, remat=self.remat,
                                 collect_cache=True, unroll=self.unroll)
        x = rms_norm(x, pv["ln_f"], cfg.norm_eps)
        logits = (x[:, -1] @ self._head(pv)).astype(jnp.float32)
        logits = self._mask_pad_vocab(logits)
        return logits, caches

    def decode_step(self, pv, caches_v, token, pos):
        """token (B,) int32; pos () int32; caches as returned by
        init_cache/prefill.  Returns (logits (B, V), new caches)."""
        cfg = self.cfg
        x1 = jnp.take(pv["emb"], token, axis=0)
        x1 = shard(x1, "batch", None)
        x1, caches = run_decode(pv, cfg, self.segments, caches_v, x1, pos,
                                unroll=self.unroll)
        x1 = rms_norm(x1, pv["ln_f"], cfg.norm_eps)
        logits = (x1 @ self._head(pv)).astype(jnp.float32)
        logits = shard(logits, "batch", "vocab")
        logits = self._mask_pad_vocab(logits)
        return logits, caches

    # -- caches ------------------------------------------------------------

    def init_cache(self, batch: int, seq_len: int, dtype=jnp.float32):
        """Param-tree of zeroed caches (list per segment, stacked)."""
        out = []
        for descrs, repeat in self.segments:
            one = {str(i): init_layer_cache(self.cfg, d, batch, seq_len,
                                            dtype)
                   for i, d in enumerate(descrs)}
            stacked = jax.tree_util.tree_map(
                lambda p: Param(
                    jnp.zeros((repeat,) + p.value.shape, p.value.dtype),
                    ("layers",) + p.axes),
                one, is_leaf=lambda x: isinstance(x, Param))
            out.append(stacked)
        return out

    def cache_shapes(self, batch: int, seq_len: int, dtype=jnp.float32):
        tree = jax.eval_shape(
            lambda: self.init_cache(batch, seq_len, dtype))
        return values(tree), axes_of(tree)


# ---------------------------------------------------------------------------
# catalogue (inlined; the serving backbone's only arch)
# ---------------------------------------------------------------------------

_CONFIGS: Dict[str, ModelConfig] = {
    "tinyllama-1.1b": ModelConfig(
        name="tinyllama-1.1b", family="dense", n_layers=22, d_model=2048,
        n_heads=32, n_kv=4, d_ff=5632, vocab=32000),
}

ARCH_IDS = list(_CONFIGS)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _CONFIGS:
        raise ValueError(f"unknown arch {arch_id!r}; choose from "
                         f"{ARCH_IDS}")
    return _CONFIGS[arch_id]


def get_model(arch_id: str, *, reduced: bool = False,
              remat: str = "full", unroll: bool = False,
              **overrides) -> LM:
    cfg = get_config(arch_id)
    if reduced:
        cfg = cfg.reduced(**overrides)
    elif overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return LM(cfg, remat=remat, unroll=unroll)
