"""Model registry: config -> LM object (init / train_loss / prefill /
decode_step / input_specs), plus the architecture catalogue."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig, ShapeCell, SHAPE_CELLS, cells_for
from .layers import (Param, axes_of, param, rms_norm, shard,
                     softmax_cross_entropy, values)
from .transformer import (SubLayer, init_layer_cache, init_segment,
                          plan_segments, run_decode, run_segments,
                          MOE_AUX_COEF)

ENC_SRC_LEN = 1024  # audio-frontend stub length (seamless)


def chunked_lm_loss(x, head, targets, mask, chunk: int = 1024,
                    vocab_real: int | None = None):
    """Cross-entropy without materialising (B, L, V) logits at once.
    ``vocab_real``: mask padded-vocab logits out of the softmax."""
    B, L, D = x.shape
    pad = (-L) % chunk
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    tp = jnp.pad(targets, ((0, 0), (0, pad)))
    mp = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = (L + pad) // chunk
    xc = xp.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    tc = tp.reshape(B, nc, chunk).transpose(1, 0, 2)
    mc = mp.reshape(B, nc, chunk).transpose(1, 0, 2)

    def step(carry, xtm):
        s, n = carry
        xch, tch, mch = xtm
        logits = (xch @ head).astype(jnp.float32)
        logits = shard(logits, "batch", None, "vocab")
        if vocab_real is not None and vocab_real < logits.shape[-1]:
            pad_mask = jnp.arange(logits.shape[-1]) < vocab_real
            logits = jnp.where(pad_mask, logits, -1e30)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, tch[..., None], axis=-1)[..., 0]
        m = mch.astype(jnp.float32)
        return (s + jnp.sum((lse - ll) * m), n + jnp.sum(m)), None

    (s, n), _ = jax.lax.scan(step, (0.0, 0.0), (xc, tc, mc))
    return s / jnp.maximum(n, 1.0)


class LM:
    """One architecture, fully assembled."""

    def __init__(self, cfg: ModelConfig, remat: str = "full",
                 unroll: bool = False):
        self.cfg = cfg
        self.remat = remat
        self.unroll = unroll  # unrolled scans (exact HLO cost analysis)
        self.segments = plan_segments(cfg)
        if cfg.family == "encdec":
            self.enc_segments = [
                ((SubLayer("attn", "mlp", causal=False),),
                 cfg.encoder_layers)]
        else:
            self.enc_segments = []

    # -- parameters ------------------------------------------------------

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        n_total = max(cfg.n_layers + cfg.encoder_layers, 1)
        out_scale = 1.0 / (2.0 * n_total) ** 0.5
        ks = iter(jax.random.split(key, 8 + len(self.segments)
                                   + len(self.enc_segments)))
        tree: Dict[str, Any] = {
            "emb": param(next(ks), (cfg.vocab_padded, cfg.d_model),
                         ("vocab", "embed")),
            "ln_f": param(next(ks), (cfg.d_model,), ("embed",),
                          init="zeros"),
        }
        if not cfg.tie_embeddings:
            tree["head"] = param(next(ks), (cfg.d_model, cfg.vocab_padded),
                                 ("embed", "vocab"))
        for si, (descrs, repeat) in enumerate(self.segments):
            tree[f"seg{si}"] = init_segment(next(ks), cfg, descrs, repeat,
                                            out_scale)
        if self.enc_segments:
            enc = {"ln_f": param(next(ks), (cfg.d_model,), ("embed",),
                                 init="zeros")}
            for si, (descrs, repeat) in enumerate(self.enc_segments):
                enc[f"seg{si}"] = init_segment(next(ks), cfg, descrs,
                                               repeat, out_scale)
            tree["enc"] = enc
        return tree

    def param_shapes(self, dtype=jnp.float32):
        """(ShapeDtypeStruct values, logical PartitionSpec axes) without
        allocating anything."""
        tree = jax.eval_shape(self.init, jax.random.key(0))
        vals = values(tree)
        if dtype is not None:
            vals = jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape, dtype), vals)
        return vals, axes_of(tree)

    # -- forward paths (value trees) --------------------------------------

    def _encode(self, pv, src):
        x, _ = run_segments(pv["enc"], self.cfg, self.enc_segments, src,
                            jnp.arange(src.shape[1]), remat=self.remat,
                            unroll=self.unroll)
        return rms_norm(x, pv["enc"]["ln_f"], self.cfg.norm_eps)

    def _inputs(self, pv, batch):
        tokens = batch["tokens"]
        x = jnp.take(pv["emb"], tokens, axis=0)
        x = shard(x, "batch", None, None)
        enc_out = None
        prefix_len = 0
        if "prefix" in batch:                      # vlm patch embeddings
            x = jnp.concatenate([batch["prefix"].astype(x.dtype), x],
                                axis=1)
            prefix_len = batch["prefix"].shape[1]
        if "src" in batch:                         # audio frames (encdec)
            enc_out = self._encode(pv, batch["src"].astype(x.dtype))
        return x, enc_out, prefix_len

    def _head(self, pv):
        if self.cfg.tie_embeddings:
            return pv["emb"].T
        return pv["head"]

    def _mask_pad_vocab(self, logits):
        if self.cfg.vocab_padded > self.cfg.vocab:
            keep = jnp.arange(logits.shape[-1]) < self.cfg.vocab
            logits = jnp.where(keep, logits, -1e30)
        return logits

    def train_loss(self, pv, batch):
        cfg = self.cfg
        x, enc_out, prefix_len = self._inputs(pv, batch)
        positions = jnp.arange(x.shape[1])
        x, aux = run_segments(pv, cfg, self.segments, x, positions,
                              enc_out=enc_out, remat=self.remat,
                              unroll=self.unroll)
        x = rms_norm(x, pv["ln_f"], cfg.norm_eps)
        if prefix_len:
            x = x[:, prefix_len:]
        targets = batch["targets"]
        mask = (targets >= 0).astype(jnp.float32)
        loss = chunked_lm_loss(x, self._head(pv),
                               jnp.maximum(targets, 0), mask,
                               vocab_real=cfg.vocab)
        return loss + MOE_AUX_COEF * aux, {"lm_loss": loss, "moe_aux": aux}

    def prefill(self, pv, batch):
        cfg = self.cfg
        x, enc_out, _ = self._inputs(pv, batch)
        positions = jnp.arange(x.shape[1])
        x, _aux, caches = run_segments(pv, cfg, self.segments, x, positions,
                                       enc_out=enc_out, remat=self.remat,
                                       collect_cache=True,
                                       unroll=self.unroll)
        x = rms_norm(x, pv["ln_f"], cfg.norm_eps)
        logits = (x[:, -1] @ self._head(pv)).astype(jnp.float32)
        logits = self._mask_pad_vocab(logits)
        return logits, caches

    def decode_step(self, pv, caches_v, token, pos):
        """token (B,) int32; pos () int32; caches as returned by
        init_cache/prefill.  Returns (logits (B, V), new caches)."""
        cfg = self.cfg
        x1 = jnp.take(pv["emb"], token, axis=0)
        x1 = shard(x1, "batch", None)
        x1, caches = run_decode(pv, cfg, self.segments, caches_v, x1, pos,
                                unroll=self.unroll)
        x1 = rms_norm(x1, pv["ln_f"], cfg.norm_eps)
        logits = (x1 @ self._head(pv)).astype(jnp.float32)
        logits = shard(logits, "batch", "vocab")
        logits = self._mask_pad_vocab(logits)
        return logits, caches

    # -- caches ------------------------------------------------------------

    def init_cache(self, batch: int, seq_len: int, dtype=jnp.float32):
        """Param-tree of zeroed caches (list per segment, stacked)."""
        out = []
        for descrs, repeat in self.segments:
            one = {str(i): init_layer_cache(self.cfg, d, batch, seq_len,
                                            dtype)
                   for i, d in enumerate(descrs)}
            stacked = jax.tree_util.tree_map(
                lambda p: Param(
                    jnp.zeros((repeat,) + p.value.shape, p.value.dtype),
                    ("layers",) + p.axes),
                one, is_leaf=lambda x: isinstance(x, Param))
            out.append(stacked)
        return out

    def cache_shapes(self, batch: int, seq_len: int, dtype=jnp.float32):
        tree = jax.eval_shape(
            lambda: self.init_cache(batch, seq_len, dtype))
        return values(tree), axes_of(tree)

    # -- assigned input-shape cells ---------------------------------------

    def input_specs(self, cell: ShapeCell, dtype=jnp.float32):
        """(ShapeDtypeStruct tree, logical-axes tree) for one cell."""
        cfg = self.cfg
        B, L = cell.global_batch, cell.seq_len
        i32 = jnp.int32
        f32 = dtype
        sds = jax.ShapeDtypeStruct
        if cell.kind in ("train", "prefill"):
            L_tok = L
            batch: Dict[str, Any] = {}
            ax: Dict[str, Any] = {}
            if cfg.family == "vlm":
                P = cfg.prefix_len
                L_tok = L - P
                batch["prefix"] = sds((B, P, cfg.d_model), f32)
                ax["prefix"] = ("batch", None, None)
            if cfg.family == "encdec":
                batch["src"] = sds((B, ENC_SRC_LEN, cfg.d_model), f32)
                ax["src"] = ("batch", None, None)
            batch["tokens"] = sds((B, L_tok), i32)
            ax["tokens"] = ("batch", None)
            if cell.kind == "train":
                batch["targets"] = sds((B, L_tok), i32)
                ax["targets"] = ("batch", None)
            return batch, ax
        # decode: one token against a seq_len cache
        cache_vals, cache_ax = self.cache_shapes(B, L, dtype)
        batch = {"token": sds((B,), i32), "pos": sds((), i32),
                 "cache": cache_vals}
        ax = {"token": ("batch",), "pos": (), "cache": cache_ax}
        return batch, ax


# ---------------------------------------------------------------------------
# catalogue
# ---------------------------------------------------------------------------

ARCH_IDS = [
    "seamless-m4t-medium", "tinyllama-1.1b", "qwen3-4b", "gemma3-4b",
    "deepseek-67b", "rwkv6-3b", "granite-moe-3b-a800m",
    "moonshot-v1-16b-a3b", "llava-next-34b", "jamba-1.5-large-398b",
]


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(
        f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


def get_model(arch_id: str, *, reduced: bool = False,
              remat: str = "full", unroll: bool = False,
              **overrides) -> LM:
    cfg = get_config(arch_id)
    if reduced:
        cfg = cfg.reduced(**overrides)
    elif overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return LM(cfg, remat=remat, unroll=unroll)
