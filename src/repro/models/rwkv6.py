"""RWKV-6 (Finch) — attention-free token mixing with data-dependent decay.

Chunkwise-parallel formulation: within a chunk of ``T_C`` tokens the
per-channel decay factorises, so the intra-chunk term is two matmuls
(the standard linear-attention chunk trick); the chunk-to-chunk state
(B, H, dk, dv) propagates through a ``lax.scan``.  Decode is the O(1)
single-token recurrence on the same state.

Recurrence (per head, channels c, state S):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t^T (diag(u) k_t v_t^T + S_{t-1})
with w_t = exp(-exp(w0 + tanh(x_t A) B)) — the data-dependent decay that
distinguishes Finch from RWKV-5.  Token shift uses learned per-channel
lerp coefficients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import param, shard

T_C = 16            # chunk length; bounds exp(|cumulative log-decay|)
LOGW_MIN = -5.0     # per-token decay clamp (keeps the factorisation in f32)
LORA_R = 64


def init_time_mix(key, d_model: int, head_dim: int, out_scale=0.02,
                  dtype=jnp.float32):
    H = d_model // head_dim
    ks = jax.random.split(key, 10)
    D = d_model
    return {
        "mu": param(ks[0], (5, D), (None, "embed"), 0.5, dtype, init="ones"),
        "w_r": param(ks[1], (D, D), ("embed", "heads_flat"), 0.02, dtype),
        "w_k": param(ks[2], (D, D), ("embed", "heads_flat"), 0.02, dtype),
        "w_v": param(ks[3], (D, D), ("embed", "heads_flat"), 0.02, dtype),
        "w_g": param(ks[4], (D, D), ("embed", "heads_flat"), 0.02, dtype),
        "w_o": param(ks[5], (D, D), ("heads_flat", "embed"), out_scale,
                     dtype),
        "w0": param(ks[6], (D,), ("heads_flat",), 0.5, dtype),
        "lora_a": param(ks[7], (D, LORA_R), ("embed", None), 0.02, dtype),
        "lora_b": param(ks[8], (LORA_R, D), (None, "heads_flat"), 0.02,
                        dtype),
        "u": param(ks[9], (D,), ("heads_flat",), 0.02, dtype),
    }


def init_channel_mix(key, d_model: int, d_ff: int, out_scale=0.02,
                     dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    return {
        "mu": param(ks[0], (2, d_model), (None, "embed"), 0.5, dtype,
                    init="ones"),
        "w_k": param(ks[1], (d_model, d_ff), ("embed", "ffn"), 0.02, dtype),
        "w_v": param(ks[2], (d_ff, d_model), ("ffn", "embed"), out_scale,
                     dtype),
        "w_r": param(ks[3], (d_model, d_model), ("embed", "embed_out"),
                     0.02, dtype),
    }


def _token_shift(x, x_last):
    """x (B, L, D); x_last (B, D) = final token of the previous segment."""
    prev = jnp.concatenate([x_last[:, None], x[:, :-1]], axis=1)
    return prev


def _lerp(x, prev, mu):
    m = jax.nn.sigmoid(mu)
    return x * m + prev * (1.0 - m)


def apply_time_mix(p, x, head_dim: int, state=None, x_last=None):
    """x (B, L, D).  Returns (out, (state, x_last_new)).

    state: (B, H, dk, dv) f32; x_last: (B, D)."""
    B, L, D = x.shape
    H = D // head_dim
    dk = dv = head_dim
    if x_last is None:
        x_last = jnp.zeros((B, D), x.dtype)
    if state is None:
        state = jnp.zeros((B, H, dk, dv), jnp.float32)
    prev = _token_shift(x, x_last)
    xr = _lerp(x, prev, p["mu"][0])
    xk = _lerp(x, prev, p["mu"][1])
    xv = _lerp(x, prev, p["mu"][2])
    xw = _lerp(x, prev, p["mu"][3])
    xg = _lerp(x, prev, p["mu"][4])

    r = (xr @ p["w_r"]).reshape(B, L, H, dk)
    k = (xk @ p["w_k"]).reshape(B, L, H, dk)
    v = (xv @ p["w_v"]).reshape(B, L, H, dv)
    g = jax.nn.silu(xg @ p["w_g"])
    logw = -jnp.exp(
        (p["w0"] + jnp.tanh(xw @ p["lora_a"]) @ p["lora_b"]).astype(
            jnp.float32))
    logw = jnp.clip(logw, LOGW_MIN, -1e-4).reshape(B, L, H, dk)
    u = p["u"].reshape(H, dk)

    # pad L to chunk multiple
    pad = (-L) % T_C
    Lp = L + pad
    padT = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
    rc = padT(r.astype(jnp.float32)).reshape(B, Lp // T_C, T_C, H, dk)
    kc = padT(k.astype(jnp.float32)).reshape(B, Lp // T_C, T_C, H, dk)
    vc = padT(v.astype(jnp.float32)).reshape(B, Lp // T_C, T_C, H, dv)
    wc = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)),
                 constant_values=-1e-4).reshape(B, Lp // T_C, T_C, H, dk)
    # scan over chunks; swap to (nc, B, T_C, H, *)
    xs = (rc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4), wc.transpose(1, 0, 2, 3, 4))

    tri_lo = jnp.tril(jnp.ones((T_C, T_C), bool), -1)

    def chunk(S, rkvw):
        rr, kk, vv, ww = rkvw            # (B, T_C, H, dk/dv)
        W = jnp.cumsum(ww, axis=1)       # inclusive cumulative log decay
        Wm1 = W - ww                     # exclusive (decay up to t-1)
        r_d = rr * jnp.exp(Wm1)          # r_t * P_{t-1}
        k_d = kk * jnp.exp(-W)           # k_j / P_j
        # intra-chunk: A[t, j] = sum_c r_d[t,c] k_d[j,c],  j < t
        A = jnp.einsum("bthc,bjhc->bhtj", r_d, k_d)
        A = jnp.where(tri_lo[None, None], A, 0.0)
        o = jnp.einsum("bhtj,bjhd->bthd", A, vv)
        # bonus (current token)
        o = o + jnp.einsum("bthc,bthc,bthd->bthd",
                           rr, u[None, None] * kk, vv)
        # inter-chunk: r_d @ S
        o = o + jnp.einsum("bthc,bhcd->bthd", r_d, S)
        # state update: S' = diag(P_end) S + sum_j (k_j P_end/P_j) v_j^T
        Pend = jnp.exp(W[:, -1])         # (B, H, dk)
        k_s = kk * jnp.exp(W[:, -1][:, None] - W)
        S_new = Pend[..., None] * S + jnp.einsum("bjhc,bjhd->bhcd", k_s, vv)
        return S_new, o

    state, outs = jax.lax.scan(chunk, state, xs)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Lp, H * dv)[:, :L]
    out = out.astype(x.dtype) * g
    out = shard(out, "batch", None, None)
    out = out @ p["w_o"]
    return out, (state, x[:, -1])


def decode_time_mix(p, x1, state, x_last, head_dim: int):
    """Single-token recurrence.  x1 (B, D); returns (out, (state, x1))."""
    B, D = x1.shape
    H = D // head_dim
    dk = dv = head_dim
    xr = _lerp(x1, x_last, p["mu"][0])
    xk = _lerp(x1, x_last, p["mu"][1])
    xv = _lerp(x1, x_last, p["mu"][2])
    xw = _lerp(x1, x_last, p["mu"][3])
    xg = _lerp(x1, x_last, p["mu"][4])
    r = (xr @ p["w_r"]).reshape(B, H, dk).astype(jnp.float32)
    k = (xk @ p["w_k"]).reshape(B, H, dk).astype(jnp.float32)
    v = (xv @ p["w_v"]).reshape(B, H, dv).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["w_g"])
    logw = -jnp.exp((p["w0"] + jnp.tanh(xw @ p["lora_a"]) @ p["lora_b"]
                     ).astype(jnp.float32))
    w = jnp.exp(jnp.clip(logw, LOGW_MIN, -1e-4)).reshape(B, H, dk)
    u = p["u"].reshape(H, dk)
    kv = jnp.einsum("bhc,bhd->bhcd", k, v)
    o = jnp.einsum("bhc,bhcd->bhd", r, u[None, ..., None] * kv + state)
    state = w[..., None] * state + kv
    out = (o.reshape(B, H * dv).astype(x1.dtype) * g) @ p["w_o"]
    return out, (state, x1)


def apply_channel_mix(p, x, x_last=None):
    B, L, D = x.shape
    if x_last is None:
        x_last = jnp.zeros((B, D), x.dtype)
    prev = _token_shift(x, x_last)
    xk = _lerp(x, prev, p["mu"][0])
    xr = _lerp(x, prev, p["mu"][1])
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    k = shard(k, "batch", None, "ffn")
    kv = k @ p["w_v"]
    return jax.nn.sigmoid(xr @ p["w_r"]) * kv, x[:, -1]


def decode_channel_mix(p, x1, x_last):
    xk = _lerp(x1, x_last, p["mu"][0])
    xr = _lerp(x1, x_last, p["mu"][1])
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    kv = k @ p["w_v"]
    return jax.nn.sigmoid(xr @ p["w_r"]) * kv, x1
