"""Decoder stack for the serving embed backbone.

A config is compiled into *segments*: ``(period_descriptors, repeat)``.
Each period is a tuple of sub-layer descriptors (mixer + ffn kind);
parameters for the period are stacked over ``repeat`` and the stack is
driven by ``lax.scan`` (keeps HLO size O(period), not O(layers)).
Heterogeneous local/global interleaves (5 local : 1 global) become
periods with several descriptors.

Modes: train (causal LM loss), prefill (returns logits of last position
+ KV caches), decode (one token against caches).

Historically this module also carried Mamba/MoE/RWKV mixers for a
training architecture zoo; that stack is gone — only the attention
paths the serving backbone (``launch/serve.py`` via
``models/registry.py``) can reach remain.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from .config import ModelConfig
from .layers import Param, apply_mlp, init_mlp, param, rms_norm, rope, shard


@dataclasses.dataclass(frozen=True)
class SubLayer:
    mixer: str                   # attn | attn_local
    ffn: str                     # mlp
    cross: bool = False          # cross-attention (enc-dec decoder)
    causal: bool = True


def plan_segments(cfg: ModelConfig) -> List[Tuple[Tuple[SubLayer, ...], int]]:
    if cfg.local_global_pattern is not None:
        pat = cfg.local_global_pattern
        descrs = tuple(
            SubLayer("attn_local" if c == "L" else "attn", "mlp")
            for c in pat)
        reps = cfg.n_layers // len(pat)
        segs = [(descrs, reps)]
        tail = cfg.n_layers - reps * len(pat)
        if tail:
            segs.append(((SubLayer("attn_local", "mlp"),), tail))
        return segs
    cross = cfg.family == "encdec"
    return [((SubLayer("attn", "mlp", cross=cross),), cfg.n_layers)]


# ---------------------------------------------------------------------------
# per-sublayer init
# ---------------------------------------------------------------------------

def _init_attn(key, cfg: ModelConfig, out_scale, cross=False):
    D, hd = cfg.d_model, cfg.hd
    Hq, Hkv = cfg.n_heads, cfg.n_kv
    ks = jax.random.split(key, 6)
    p = {
        "wq": param(ks[0], (D, Hq * hd), ("embed", "heads_flat")),
        "wk": param(ks[1], (D, Hkv * hd), ("embed", "heads_flat")),
        "wv": param(ks[2], (D, Hkv * hd), ("embed", "heads_flat")),
        "wo": param(ks[3], (Hq * hd, D), ("heads_flat", "embed"),
                    scale=0.02 * out_scale),
    }
    if cfg.qk_norm:
        p["q_norm"] = param(ks[4], (hd,), (None,), init="zeros")
        p["k_norm"] = param(ks[5], (hd,), (None,), init="zeros")
    return p


def _init_sublayer(key, cfg: ModelConfig, d: SubLayer, out_scale):
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"ln1": param(ks[0], (cfg.d_model,), ("embed",),
                                      init="zeros")}
    p["attn"] = _init_attn(ks[1], cfg, out_scale)
    if d.cross:
        p["ln_x"] = param(ks[2], (cfg.d_model,), ("embed",), init="zeros")
        p["cross"] = _init_attn(ks[3], cfg, out_scale, cross=True)
    p["ln2"] = param(ks[4], (cfg.d_model,), ("embed",), init="zeros")
    p["mlp"] = init_mlp(ks[5], cfg.d_model, cfg.d_ff, out_scale)
    return p


def _stack_axes(tree):
    """Add the leading scan ('layers') axis to every Param's axes."""
    return jax.tree_util.tree_map(
        lambda p: Param(p.value, ("layers",) + p.axes),
        tree, is_leaf=lambda x: isinstance(x, Param))


def init_segment(key, cfg: ModelConfig, descrs, repeat: int, out_scale):
    def one(k):
        kk = jax.random.split(k, len(descrs))
        return {str(i): _init_sublayer(kk[i], cfg, d, out_scale)
                for i, d in enumerate(descrs)}
    stacked = jax.vmap(one)(jax.random.split(key, repeat))
    return _stack_axes(stacked)


# ---------------------------------------------------------------------------
# per-sublayer apply (value trees, not Param trees)
# ---------------------------------------------------------------------------

def _qk(p, cfg, h, positions):
    B, L, D = h.shape
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv
    q = (h @ p["wq"]).reshape(B, L, Hq, hd)
    k = (h @ p["wk"]).reshape(B, L, Hkv, hd)
    v = (h @ p["wv"]).reshape(B, L, Hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q.transpose(0, 2, 1, 3), positions[None, None], cfg.rope_theta)
    k = rope(k.transpose(0, 2, 1, 3), positions[None, None], cfg.rope_theta)
    v = v.transpose(0, 2, 1, 3)
    q = shard(q, "batch", "heads", None, None)
    k = shard(k, "batch", None, None, None)
    return q, k, v


def _apply_attn(p, cfg: ModelConfig, x, d: SubLayer, positions,
                enc_out=None):
    B, L, D = x.shape
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qk(p["attn"], cfg, h, positions)
    window = cfg.sliding_window if d.mixer == "attn_local" else None
    if window is not None:
        o = attn_mod.local_attention(q, k, v, window)
    else:
        o = attn_mod.chunked_attention(q, k, v, causal=d.causal)
    o = shard(o, "batch", "heads", None, None)
    o = o.transpose(0, 2, 1, 3).reshape(B, L, cfg.n_heads * cfg.hd)
    return x + o @ p["attn"]["wo"]


def _apply_cross(p, cfg: ModelConfig, x, enc_out):
    """Cross-attention: q from decoder x, k/v from encoder output."""
    B, L, D = x.shape
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv
    h = rms_norm(x, p["ln_x"], cfg.norm_eps)
    q = (h @ p["cross"]["wq"]).reshape(B, L, Hq, hd).transpose(0, 2, 1, 3)
    S = enc_out.shape[1]
    k = (enc_out @ p["cross"]["wk"]).reshape(B, S, Hkv, hd).transpose(
        0, 2, 1, 3)
    v = (enc_out @ p["cross"]["wv"]).reshape(B, S, Hkv, hd).transpose(
        0, 2, 1, 3)
    o = attn_mod.chunked_attention(q, k, v, causal=False)
    o = o.transpose(0, 2, 1, 3).reshape(B, L, Hq * hd)
    return x + o @ p["cross"]["wo"]


def _apply_ffn(p, cfg: ModelConfig, x, d: SubLayer):
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if d.ffn == "mlp":
        return x + apply_mlp(p["mlp"], h)
    raise ValueError(d.ffn)


def _apply_attn_collect(p, cfg: ModelConfig, x, d: SubLayer, positions):
    """Attention that also returns the cache entry (prefill path)."""
    B, L, D = x.shape
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qk(p["attn"], cfg, h, positions)
    window = cfg.sliding_window if d.mixer == "attn_local" else None
    if window is not None:
        o = attn_mod.local_attention(q, k, v, window)
        w = window
        kc = jnp.roll(k[:, :, -w:], L % w, axis=2)
        vc = jnp.roll(v[:, :, -w:], L % w, axis=2)
    else:
        o = attn_mod.chunked_attention(q, k, v, causal=d.causal)
        kc, vc = k, v
    o = shard(o, "batch", "heads", None, None)
    o = o.transpose(0, 2, 1, 3).reshape(B, L, cfg.n_heads * cfg.hd)
    return x + o @ p["attn"]["wo"], {"k": kc, "v": vc}


def _apply_sublayer(p, cfg, x, d: SubLayer, positions, enc_out,
                    collect: bool = False):
    cache = {}
    if collect:
        x, cache = _apply_attn_collect(p, cfg, x, d, positions)
    else:
        x = _apply_attn(p, cfg, x, d, positions)
    if d.cross and enc_out is not None:
        x = _apply_cross(p, cfg, x, enc_out)
        if collect:
            hd, Hkv = cfg.hd, cfg.n_kv
            S = enc_out.shape[1]
            B = x.shape[0]
            cache["xk"] = (enc_out @ p["cross"]["wk"]).reshape(
                B, S, Hkv, hd).transpose(0, 2, 1, 3)
            cache["xv"] = (enc_out @ p["cross"]["wv"]).reshape(
                B, S, Hkv, hd).transpose(0, 2, 1, 3)
    x = _apply_ffn(p, cfg, x, d)
    return x, cache


def run_segments(params_v, cfg: ModelConfig, segments, x, positions,
                 enc_out=None, remat: str = "full",
                 collect_cache: bool = False, unroll: bool = False):
    """Forward through all segments.  With ``collect_cache`` the per-layer
    cache entries (stacked over the scan axis) are returned as well."""
    all_caches = []
    for si, (descrs, repeat) in enumerate(segments):
        seg_p = params_v[f"seg{si}"]

        def body(x, layer_p, descrs=descrs):
            caches = {}
            for i, d in enumerate(descrs):
                x, c = _apply_sublayer(layer_p[str(i)], cfg, x, d,
                                       positions, enc_out,
                                       collect=collect_cache)
                caches[str(i)] = c
            x = shard(x, "batch", None, None)
            return x, (caches if collect_cache else None)

        if remat != "none" and not collect_cache:
            policy = (jax.checkpoint_policies.nothing_saveable
                      if remat == "full" else
                      jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
            body = jax.checkpoint(body, policy=policy,
                                  prevent_cse=False)
        x, ys = jax.lax.scan(body, x, seg_p,
                             unroll=repeat if unroll else 1)
        all_caches.append(ys)
    if collect_cache:
        return x, all_caches
    return x


# ---------------------------------------------------------------------------
# decode path (cache in / cache out)
# ---------------------------------------------------------------------------

def init_layer_cache(cfg: ModelConfig, d: SubLayer, batch: int,
                     seq_len: int, dtype=jnp.float32):
    """Cache pytree (of Param, for axes) for one sub-layer."""
    hd, Hkv = cfg.hd, cfg.n_kv
    c: Dict[str, Any] = {}
    if d.mixer == "attn":
        c["k"] = Param(jnp.zeros((batch, Hkv, seq_len, hd), dtype),
                       ("batch", None, "kv_seq", None))
        c["v"] = Param(jnp.zeros((batch, Hkv, seq_len, hd), dtype),
                       ("batch", None, "kv_seq", None))
    elif d.mixer == "attn_local":
        w = cfg.sliding_window
        c["k"] = Param(jnp.zeros((batch, Hkv, w, hd), dtype),
                       ("batch", None, None, None))
        c["v"] = Param(jnp.zeros((batch, Hkv, w, hd), dtype),
                       ("batch", None, None, None))
    if d.cross:
        S_src = max(1, cfg.prefix_len)
        c["xk"] = Param(jnp.zeros((batch, Hkv, S_src, hd), dtype),
                        ("batch", None, None, None))
        c["xv"] = Param(jnp.zeros((batch, Hkv, S_src, hd), dtype),
                        ("batch", None, None, None))
    return c


def _decode_sublayer(p, cfg, c, x1, d: SubLayer, pos):
    """x1 (B, D) one token; c = this layer's cache (values)."""
    B, D = x1.shape
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv
    h = rms_norm(x1, p["ln1"], cfg.norm_eps)
    q = (h @ p["attn"]["wq"]).reshape(B, Hq, hd)
    k1 = (h @ p["attn"]["wk"]).reshape(B, Hkv, hd)
    v1 = (h @ p["attn"]["wv"]).reshape(B, Hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["attn"]["q_norm"], cfg.norm_eps)
        k1 = rms_norm(k1, p["attn"]["k_norm"], cfg.norm_eps)
    posv = jnp.full((1,), pos)
    q = rope(q[:, :, None], posv[None, None], cfg.rope_theta)[:, :, 0]
    k1 = rope(k1[:, :, None], posv[None, None], cfg.rope_theta)[:, :, 0]
    if d.mixer == "attn_local":
        w = cfg.sliding_window
        slot = pos % w
        kc, vc = attn_mod.cache_update(c["k"], c["v"], k1, v1, slot)
        # ring: entries hold positions (pos-w, pos]; all valid once warm
        o = attn_mod.decode_attention(q, kc, vc, pos, window=None)
    else:
        kc, vc = attn_mod.cache_update(c["k"], c["v"], k1, v1, pos)
        o = attn_mod.decode_attention(q, kc, vc, pos)
    c = dict(c, k=kc, v=vc)
    x1 = x1 + o.reshape(B, Hq * hd) @ p["attn"]["wo"]
    if d.cross:
        h = rms_norm(x1, p["ln_x"], cfg.norm_eps)
        q = (h @ p["cross"]["wq"]).reshape(B, Hq, hd)
        S = c["xk"].shape[2]
        o = attn_mod.decode_attention(q, c["xk"], c["xv"],
                                      jnp.asarray(S - 1))
        x1 = x1 + o.reshape(B, Hq * hd) @ p["cross"]["wo"]
    # ffn
    h = rms_norm(x1, p["ln2"], cfg.norm_eps)
    x1 = x1 + apply_mlp(p["mlp"], h)
    return x1, c


def run_decode(params_v, cfg: ModelConfig, segments, caches_v, x1, pos,
               unroll: bool = False):
    """One-token decode through all segments; returns (x1, new caches)."""
    new_caches = []
    for si, (descrs, repeat) in enumerate(segments):
        seg_p = params_v[f"seg{si}"]
        seg_c = caches_v[si]

        def body(x1, pc, descrs=descrs):
            layer_p, layer_c = pc
            new_c = {}
            for i, d in enumerate(descrs):
                x1, c = _decode_sublayer(layer_p[str(i)], cfg,
                                         layer_c[str(i)], x1, d, pos)
                new_c[str(i)] = c
            return x1, new_c

        x1, seg_c_new = jax.lax.scan(body, x1, (seg_p, seg_c),
                                     unroll=repeat if unroll else 1)
        new_caches.append(seg_c_new)
    return x1, new_caches
