"""Unified observability plane.

One :class:`Obs` object bundles the three planes every layer shares:

* :class:`~repro.obs.metrics.MetricsRegistry` — counters, gauges,
  log-bucket latency histograms; Prometheus text exposition + JSON
  snapshot export; the shared :data:`DRIVER_STAT_SCHEMA` behind every
  engine's ``stats`` mapping.
* :class:`~repro.obs.trace.Tracer` — per-tick structured trace events
  (JSONL ring buffer + optional file sink) emitted by every planner
  with reasons.
* :class:`~repro.obs.probe.RecallProbe` — sampled live-recall probe
  (built by the serving engine on demand via :meth:`Obs.make_probe`).

Drivers default-construct an ``Obs()`` when none is injected; the
serving engine reuses its index's plane so one exposition covers driver
internals and request spans.  ``Obs(enabled=False)`` keeps the stats
mapping (the drivers need it) but turns tracing and span recording into
no-ops — that delta is what the figserve obs-overhead row measures.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Callable, Optional

from .metrics import (DRIVER_STAT_SCHEMA, GAUGE_STAT_KEYS, Counter, Gauge,
                      Histogram, MetricsRegistry, StatsMap, parse_exposition,
                      required_series)
from .probe import RecallProbe
from .trace import Tracer

__all__ = [
    "Obs", "MetricsRegistry", "Tracer", "RecallProbe", "Counter", "Gauge",
    "Histogram", "StatsMap", "DRIVER_STAT_SCHEMA", "GAUGE_STAT_KEYS",
    "parse_exposition", "required_series",
]


class Obs:
    """Bundle of metrics registry + tracer (+ profiler hook)."""

    def __init__(self, *, enabled: bool = True,
                 trace_capacity: int = 4096,
                 trace_path: Optional[str] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.enabled = enabled
        self.registry = MetricsRegistry()
        self.tracer = Tracer(capacity=trace_capacity, path=trace_path,
                             clock=clock, enabled=enabled)

    # ---- construction passthrough ------------------------------------

    def driver_stats(self, prefix: str = "index") -> StatsMap:
        """The shared-schema stats mapping a driver exposes as
        ``.stats`` — registered so every key rides the exposition."""
        return self.registry.stats_map(prefix, DRIVER_STAT_SCHEMA)

    def counter(self, name: str) -> Counter:
        return self.registry.counter(name)

    def gauge(self, name: str) -> Gauge:
        return self.registry.gauge(name)

    def histogram(self, name: str, **kw) -> Histogram:
        return self.registry.histogram(name, **kw)

    def make_probe(self, index, **kw) -> RecallProbe:
        return RecallProbe(index, self.registry, **kw)

    # ---- tracing ------------------------------------------------------

    def emit(self, kind: str, **fields) -> None:
        self.tracer.emit(kind, **fields)

    def events(self, kind: Optional[str] = None):
        return self.tracer.events(kind)

    # ---- export -------------------------------------------------------

    def snapshot(self):
        return self.registry.snapshot()

    def to_prometheus(self) -> str:
        return self.registry.to_prometheus()

    # ---- device profiler hook -----------------------------------------

    @contextmanager
    def profile(self, trace_dir: Optional[str]):
        """Wrap a block in a ``jax.profiler`` trace capture.

        Best-effort: if the profiler backend is unavailable (e.g. a
        second concurrent capture) the block still runs untraced.
        """
        started = False
        if trace_dir:
            try:
                import jax
                jax.profiler.start_trace(str(trace_dir))
                started = True
            except Exception:
                started = False
        try:
            yield
        finally:
            if started:
                try:
                    import jax
                    jax.profiler.stop_trace()
                except Exception:
                    pass
