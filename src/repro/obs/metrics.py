"""Metrics registry: counters, gauges, log-bucket latency histograms.

One shared metric plane for every layer (drivers, tier, rebalance,
serving, benchmarks) so benchmark and production metric definitions can
never diverge.  Design constraints:

  * **low overhead** — recording a counter is one dict add, recording a
    histogram sample is one ``bisect`` into a precomputed edge table;
    nothing allocates on the hot path;
  * **shared schema** — both streaming drivers initialize their
    ``stats`` mapping from :data:`DRIVER_STAT_SCHEMA`, so the key set is
    identical across every ``make_index`` engine (the PR 6 drift —
    ``migrated``/``host_cached``/``bg_gc`` existing only on the sharded
    driver — cannot recur; ``tests/test_obs.py`` pins it);
  * **two exports** — Prometheus-style text exposition
    (:meth:`MetricsRegistry.to_prometheus`, parseable back with
    :func:`parse_exposition` for smoke checks) and a JSON-able snapshot
    (:meth:`MetricsRegistry.snapshot`).

Histograms use geometric ("log") buckets: relative quantization error
is bounded by the growth factor (default ``2 ** 0.25`` ~ 19% bucket
width, ~9% worst-case error at the geometric midpoint), and the exact
observed min/max clamp the estimate so small stable samples report
near-exact quantiles.
"""
from __future__ import annotations

import json
import math
from bisect import bisect_left
from collections.abc import MutableMapping
from typing import Dict, Iterable, List, Optional, Tuple

# ---------------------------------------------------------------------------
# the shared driver-stats schema (satellite: fix driver stats drift)
# ---------------------------------------------------------------------------

#: Every ``StreamingIndex`` engine initializes ``stats`` with exactly
#: these keys.  Keys an engine never updates stay 0.0 (e.g. ``migrated``
#: on the single-device driver) — present, not missing, so
#: engine-generic consumers can read any key without KeyError.
DRIVER_STAT_SCHEMA: Tuple[str, ...] = (
    # foreground counts
    "inserted", "deleted", "rejected", "blocked", "queries",
    # wall-time accumulators (feed throughput_from_stats)
    "insert_time", "delete_time", "search_time", "bg_time",
    "bg_exec_time",
    # background-plane counts
    "bg_ops", "bg_split", "bg_merge", "bg_compact", "bg_deferred",
    "bg_reassigned", "bg_gc", "drained",
    # sharded-plane counts (0 on single-device)
    "migrated", "host_cached",
    # quant plane
    "pq_retrains", "pq_generation",
    # cold-tier plane
    "tier_spilled", "tier_promoted", "tier_resident",
    # device-search introspection (piggybacked on existing transfers)
    "search_probed", "search_results", "search_spilled_hits",
    "search_adc_batches", "search_exact_batches",
)

#: stats keys that are levels, not monotone counts (typed gauge in the
#: exposition)
GAUGE_STAT_KEYS = frozenset({"tier_resident", "pq_generation"})


def _sanitize(name: str) -> str:
    """Prometheus metric-name charset: [a-zA-Z_:][a-zA-Z0-9_:]*."""
    out = "".join(c if (c.isalnum() or c in "_:") else "_" for c in name)
    if not out or not (out[0].isalpha() or out[0] in "_:"):
        out = "_" + out
    return out


class Counter:
    """Monotone float counter."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount


class Gauge:
    """Last-value gauge."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


class Histogram:
    """Geometric-bucket histogram with streaming quantile extraction.

    ``record`` is one bisect into the precomputed edge table; quantiles
    walk the cumulative counts and return the bucket's geometric
    midpoint clamped to the exact observed [min, max].  Usable
    standalone (the benchmarks build throwaway instances for timed-loop
    spans) or through a :class:`MetricsRegistry`.
    """

    __slots__ = ("name", "_edges", "_counts", "count", "sum",
                 "_min", "_max")

    def __init__(self, name: str = "", *, lo: float = 1e-6,
                 hi: float = 3600.0, growth: float = 2 ** 0.25):
        if not (lo > 0 and hi > lo and growth > 1):
            raise ValueError("need 0 < lo < hi and growth > 1")
        self.name = name
        edges = [lo]
        while edges[-1] < hi:
            edges.append(edges[-1] * growth)
        self._edges = edges
        self._counts = [0] * (len(edges) + 1)   # +1 overflow bucket
        self.count = 0
        self.sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def record(self, value: float) -> None:
        v = float(value)
        self._counts[bisect_left(self._edges, v)] += 1
        self.count += 1
        self.sum += v
        if v < self._min:
            self._min = v
        if v > self._max:
            self._max = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Approximate q-quantile (relative error bounded by the bucket
        growth factor, exact when all samples share one bucket)."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        for i, c in enumerate(self._counts):
            cum += c
            if cum >= target and c:
                if i >= len(self._edges):          # overflow bucket
                    est = self._max
                elif i == 0:
                    est = self._edges[0] / 2.0
                else:
                    est = math.sqrt(self._edges[i - 1] * self._edges[i])
                return min(max(est, self._min), self._max)
        return self._max

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "sum": self.sum, "mean": self.mean,
                "p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}

    def buckets(self) -> Iterable[Tuple[float, int]]:
        """(upper_edge, cumulative_count) pairs, only non-empty prefixes
        trimmed — the Prometheus ``le`` series."""
        cum = 0
        for edge, c in zip(self._edges, self._counts):
            cum += c
            if c:
                yield edge, cum


class StatsMap(MutableMapping):
    """Mapping facade for a driver's ``stats`` attribute.

    Behaves like the old ``defaultdict(float)`` (missing reads return
    0.0) but is pre-seeded from a schema so the key SET is identical
    across engines, and is registered with the owning
    :class:`MetricsRegistry` so every key rides the exposition.
    """

    __slots__ = ("prefix", "_d")

    def __init__(self, prefix: str, schema: Iterable[str]):
        self.prefix = prefix
        self._d: Dict[str, float] = dict.fromkeys(schema, 0.0)

    def __getitem__(self, key):
        return self._d.get(key, 0.0)

    def __setitem__(self, key, value):
        self._d[key] = value

    def __delitem__(self, key):
        del self._d[key]

    def __iter__(self):
        return iter(self._d)

    def __len__(self):
        return len(self._d)

    def __repr__(self):
        return f"StatsMap({self.prefix!r}, {self._d!r})"


class MetricsRegistry:
    """Names -> metric instances, plus registered stats maps.

    ``counter``/``gauge``/``histogram`` are get-or-create (idempotent,
    so layers can look metrics up by name without coordination).
    """

    def __init__(self):
        self._metrics: Dict[str, object] = {}
        self._maps: List[StatsMap] = []

    # ---- construction -------------------------------------------------

    def _get(self, name: str, cls, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, **kw)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, **kw) -> Histogram:
        return self._get(name, Histogram, **kw)

    def stats_map(self, prefix: str,
                  schema: Iterable[str] = DRIVER_STAT_SCHEMA) -> StatsMap:
        """A schema-seeded stats facade exported under ``prefix``."""
        for m in self._maps:
            if m.prefix == prefix:
                return m
        m = StatsMap(prefix, schema)
        self._maps.append(m)
        return m

    # ---- export -------------------------------------------------------

    def snapshot(self) -> Dict[str, object]:
        """JSON-able view of every metric (histograms as summaries)."""
        out: Dict[str, object] = {}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Histogram):
                out[name] = m.summary()
            else:
                out[name] = m.value
        for sm in self._maps:
            for k in sorted(sm):
                out[f"{sm.prefix}_{k}"] = sm[k]
        return out

    def snapshot_json(self, **kw) -> str:
        return json.dumps(self.snapshot(), **kw)

    def to_prometheus(self) -> str:
        """Prometheus text exposition (format 0.0.4 subset)."""
        lines: List[str] = []
        for sm in self._maps:
            for k in sorted(sm):
                name = _sanitize(f"{sm.prefix}_{k}")
                typ = "gauge" if k in GAUGE_STAT_KEYS else "counter"
                lines.append(f"# TYPE {name} {typ}")
                lines.append(f"{name} {sm[k]:g}")
        for name, m in sorted(self._metrics.items()):
            pname = _sanitize(name)
            if isinstance(m, Counter):
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {m.value:g}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {pname} gauge")
                lines.append(f"{pname} {m.value:g}")
            else:
                lines.append(f"# TYPE {pname} histogram")
                for edge, cum in m.buckets():
                    lines.append(
                        f'{pname}_bucket{{le="{edge:.6g}"}} {cum}')
                lines.append(f'{pname}_bucket{{le="+Inf"}} {m.count}')
                lines.append(f"{pname}_sum {m.sum:g}")
                lines.append(f"{pname}_count {m.count}")
        return "\n".join(lines) + "\n"


def parse_exposition(text: str) -> Dict[str, float]:
    """Parse a Prometheus text exposition back to {series_name: value}.

    Labels are folded into the series key (``name{le="0.1"}``), which is
    all the smoke checks need.  Raises ``ValueError`` on malformed
    lines, so "the exposition parses" is a real assertion.
    """
    out: Dict[str, float] = {}
    for ln in text.splitlines():
        ln = ln.strip()
        if not ln or ln.startswith("#"):
            if ln.startswith("#") and not ln.startswith(("# TYPE",
                                                         "# HELP")):
                raise ValueError(f"malformed comment line: {ln!r}")
            continue
        parts = ln.rsplit(" ", 1)
        if len(parts) != 2:
            raise ValueError(f"malformed sample line: {ln!r}")
        name, val = parts
        out[name] = float(val)      # raises on non-numeric values
    return out


def required_series(snapshot_keys: Iterable[str],
                    required: Iterable[str]) -> List[str]:
    """Names in ``required`` that no snapshot/exposition key starts
    with — empty means every required series is present."""
    keys = list(snapshot_keys)
    return [r for r in required
            if not any(k == r or k.startswith(r + "_") or
                       k.startswith(r + "{") for k in keys)]
