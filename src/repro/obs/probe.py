"""Sampled live-recall probe.

The paper's accuracy-stability claim — recall holds while the index
churns — is only observable offline today (benchmark ground-truth
sweeps).  ``RecallProbe`` makes it a production signal: a configurable
fraction of *served* query batches is shadow-executed against the
engine's ``exact()`` oracle off the hot path, and the rolling mean over
the last ``window`` probes is exported as a gauge.

Sampling is seeded (deterministic per run) and decided per served
batch with one RNG draw, so the obs-off / probe-off cost is zero and
the probe-on cost is bounded by ``fraction`` exact scans.
"""
from __future__ import annotations

from collections import deque
from typing import Optional

import numpy as np

from .metrics import MetricsRegistry


class RecallProbe:
    """Shadow-execute sampled query batches against ``exact()``."""

    def __init__(self, index, registry: MetricsRegistry, *,
                 fraction: float = 0.0, window: int = 64,
                 max_rows: int = 8, seed: int = 0):
        self.index = index
        self.fraction = float(fraction)
        self.max_rows = int(max_rows)
        self._rng = np.random.default_rng(seed)
        self._window: deque = deque(maxlen=window)
        self.gauge = registry.gauge("live_recall")
        self.gauge.set(float("nan"))
        self.samples = registry.counter("live_recall_probes")

    def maybe_probe(self, queries: np.ndarray, k: int,
                    found_ids: np.ndarray) -> Optional[float]:
        """Sample this served batch with probability ``fraction``.

        Probes at most ``max_rows`` rows of the batch (uniformly
        chosen) so probe cost is independent of batch size.  Returns
        the batch recall when probed, else ``None``.
        """
        # lazy: repro.core imports repro.obs at package load, so the
        # oracle metric has to be resolved at probe time, not import time
        from ..core.metrics import recall_at_k

        if self.fraction <= 0.0:
            return None
        if float(self._rng.random()) >= self.fraction:
            return None
        n = min(len(queries), len(found_ids))
        if n == 0:
            return None
        rows = (np.arange(n) if n <= self.max_rows else
                self._rng.choice(n, size=self.max_rows, replace=False))
        true = self.index.exact(np.asarray(queries)[rows], k)
        true_ids = getattr(true, "ids", true)
        r = recall_at_k(np.asarray(found_ids)[rows], true_ids)
        self._window.append(r)
        self.samples.inc()
        self.gauge.set(float(np.mean(self._window)))
        return r

    @property
    def rolling_recall(self) -> float:
        return float(np.mean(self._window)) if self._window else float("nan")
