"""Structured trace events: a JSONL ring buffer with an optional file sink.

Every planner decision (background mark/exec, rebalance moves, tier
spill/promote commits and drops, PQ retrain slot evictions) emits one
event *with a stated reason*, so a tick's behavior is reconstructable
after the fact.  Events are plain dicts::

    {"seq": 17, "t": 0.482913, "kind": "rebalance",
     "trigger": "watermark", "moves": [...], "migrated": 4}

Recording is append-to-deque (bounded, oldest dropped) plus an optional
line write to a JSONL sink.  A disabled tracer short-circuits ``emit``
before touching its arguments' values, so the obs-off cost is one
attribute check.
"""
from __future__ import annotations

import io
import json
import time
from collections import deque
from typing import Callable, Dict, Iterator, List, Optional


def _jsonable(x):
    """Best-effort conversion of numpy/jax scalars and arrays."""
    if isinstance(x, (str, int, float, bool)) or x is None:
        return x
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    item = getattr(x, "item", None)
    if item is not None and getattr(x, "ndim", 1) == 0:
        return item()
    tolist = getattr(x, "tolist", None)
    if tolist is not None:
        return tolist()
    return repr(x)


class Tracer:
    """Bounded in-memory event log + optional JSONL file sink."""

    def __init__(self, capacity: int = 4096,
                 path: Optional[str] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 enabled: bool = True):
        self.enabled = enabled
        self.capacity = capacity
        self.clock = clock
        self._buf: deque = deque(maxlen=capacity)
        self._seq = 0
        self._fh: Optional[io.TextIOBase] = None
        if path is not None:
            self._fh = open(path, "a", encoding="utf-8")

    def emit(self, kind: str, **fields) -> None:
        if not self.enabled:
            return
        ev: Dict[str, object] = {"seq": self._seq,
                                 "t": round(float(self.clock()), 6),
                                 "kind": kind}
        for k, v in fields.items():
            ev[k] = _jsonable(v)
        self._seq += 1
        self._buf.append(ev)
        if self._fh is not None:
            self._fh.write(json.dumps(ev) + "\n")

    def events(self, kind: Optional[str] = None) -> List[Dict[str, object]]:
        if kind is None:
            return list(self._buf)
        return [e for e in self._buf if e["kind"] == kind]

    def __iter__(self) -> Iterator[Dict[str, object]]:
        return iter(self._buf)

    def __len__(self) -> int:
        return len(self._buf)

    def to_jsonl(self) -> str:
        return "\n".join(json.dumps(e) for e in self._buf)

    def clear(self) -> None:
        self._buf.clear()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.flush()
            self._fh.close()
            self._fh = None
