"""Optimizers + schedules (self-contained; no optax in this environment)."""
from .adamw import AdamW, AdamWConfig
from .schedule import cosine_warmup
from .compress import ef_int8_allreduce, CompressionState

__all__ = ["AdamW", "AdamWConfig", "cosine_warmup", "ef_int8_allreduce",
           "CompressionState"]
