"""AdamW with configurable moment-state precision.

``state_dtype``:
  * "f32"  — classic fp32 moments;
  * "bf16" — halves optimizer HBM;
  * "int8" — blockwise-quantised moments (128-wide blocks, per-block f32
             scales).  For jamba-398B this is what makes a single v5e pod
             feasible: 12 bytes/param (fp32 m+v+master) -> ~2.1 bytes.

Moment decode/encode happens inside the (jitted) update, so quantisation
error is re-absorbed every step (the classic 8-bit-optimizer recipe).
Optimizer state shardings mirror the parameter shardings.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

QBLOCK = 128


def _q8_shape(shape):
    if not shape:
        return (1,), (1,)
    last = shape[-1]
    nb = -(-last // QBLOCK)
    return shape[:-1] + (nb * QBLOCK,), shape[:-1] + (nb,)


def q8_encode(x):
    """x (..., d) f32 -> (int8 (..., d_pad), scales (..., nb) f32)."""
    shape = x.shape
    if not shape:
        x = x[None]
        shape = x.shape
    pad_shape, sc_shape = _q8_shape(shape)
    xp = jnp.pad(x, [(0, p - s) for s, p in zip(shape, pad_shape)])
    xb = xp.reshape(sc_shape + (QBLOCK,))
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127).astype(
        jnp.int8)
    return q.reshape(pad_shape), scale


def q8_decode(q, scale, shape):
    if not shape:
        out = (q.reshape(scale.shape + (QBLOCK,)).astype(jnp.float32)
               * scale[..., None]).reshape(-1)[:1]
        return out[0]
    xb = q.reshape(scale.shape + (QBLOCK,)).astype(jnp.float32)
    x = (xb * scale[..., None]).reshape(
        shape[:-1] + (scale.shape[-1] * QBLOCK,))
    return x[..., :shape[-1]]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: str = "f32"          # f32 | bf16 | int8

    def __post_init__(self):
        assert self.state_dtype in ("f32", "bf16", "int8")


class AdamW:
    def __init__(self, cfg: AdamWConfig = AdamWConfig(),
                 lr: Callable[[jax.Array], jax.Array] | float = 1e-3):
        self.cfg = cfg
        self.lr = lr if callable(lr) else (lambda step, v=lr: v)

    # -- state -------------------------------------------------------------

    def _zeros_like_moment(self, p):
        if self.cfg.state_dtype == "f32":
            return jnp.zeros(p.shape, jnp.float32)
        if self.cfg.state_dtype == "bf16":
            return jnp.zeros(p.shape, jnp.bfloat16)
        pad_shape, sc_shape = _q8_shape(p.shape)
        return {"q": jnp.zeros(pad_shape, jnp.int8),
                "scale": jnp.zeros(sc_shape, jnp.float32)}

    def init(self, params):
        zeros = lambda tree: jax.tree_util.tree_map(
            self._zeros_like_moment, tree)
        return {"m": zeros(params), "v": zeros(params),
                "step": jnp.zeros((), jnp.int32)}

    # -- second-moment companding (int8) ----------------------------------
    # Linear int8 decodes tiny v entries in a large-max block to exactly
    # 0, and m/(sqrt(0)+eps) explodes.  Quantising sqrt(v) (companding)
    # gives small v entries quadratically finer resolution — the classic
    # 8-bit-optimizer fix.

    def state_axes(self, param_axes):
        """Optimizer-state logical axes mirroring the params.

        int8 per-block scales keep the leading axes but replicate the
        (short) block axis."""
        import jax.sharding as shd

        def mom(spec):
            if self.cfg.state_dtype != "int8":
                return spec
            lead = tuple(spec)[:-1] if len(spec) else ()
            return {"q": spec,
                    "scale": shd.PartitionSpec(*lead, None)}
        return {"m": jax.tree_util.tree_map(mom, param_axes),
                "v": jax.tree_util.tree_map(mom, param_axes),
                "step": shd.PartitionSpec()}

    # -- update ------------------------------------------------------------

    def _decode(self, mo, shape, compand=False):
        if self.cfg.state_dtype == "int8":
            out = q8_decode(mo["q"], mo["scale"], shape)
            return jnp.square(out) if compand else out
        return mo.astype(jnp.float32)

    def _encode(self, x, compand=False):
        if self.cfg.state_dtype == "f32":
            return x
        if self.cfg.state_dtype == "bf16":
            return x.astype(jnp.bfloat16)
        if compand:
            x = jnp.sqrt(jnp.maximum(x, 0.0))
        q, s = q8_encode(x)
        return {"q": q, "scale": s}

    def apply(self, params, grads, state):
        cfg = self.cfg
        step = state["step"] + 1
        lr = self.lr(step)
        b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

        if cfg.clip_norm is not None:
            gn = jnp.sqrt(sum(
                jnp.sum(g.astype(jnp.float32) ** 2)
                for g in jax.tree_util.tree_leaves(grads)))
            scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-9))
        else:
            gn = jnp.zeros(())
            scale = 1.0

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        new_p, new_m, new_v = [], [], []
        for p, g, m_, v_ in zip(flat_p, flat_g, flat_m, flat_v):
            g = g.astype(jnp.float32) * scale
            m = cfg.b1 * self._decode(m_, p.shape) + (1 - cfg.b1) * g
            v = cfg.b2 * self._decode(v_, p.shape, compand=True) \
                + (1 - cfg.b2) * g * g
            upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
            if p.ndim >= 2:  # no decay on norms/biases
                upd = upd + cfg.weight_decay * p.astype(jnp.float32)
            p2 = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
            new_p.append(p2)
            new_m.append(self._encode(m))
            new_v.append(self._encode(v, compand=True))
        unflat = jax.tree_util.tree_unflatten
        return (unflat(treedef, new_p),
                {"m": unflat(treedef, new_m), "v": unflat(treedef, new_v),
                 "step": step},
                {"grad_norm": gn, "lr": lr})
