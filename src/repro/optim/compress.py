"""Int8 error-feedback gradient all-reduce (distributed-optimization trick).

``ef_int8_allreduce`` is a *shard-local* primitive: call it inside a
``shard_map``-decorated train step where each shard holds its partial
gradients.  The data-parallel reduction then runs on blockwise-quantised
int8 payloads (psum of int32 sums of int8 lanes); the local quantisation
residual is carried in an error-feedback buffer and re-added next step,
so the accumulated gradient is unbiased (EF-SGD / 1-bit-Adam lineage).
Wire traffic: 1 byte/grad + 4/128 bytes of scales ≈ 4x less than fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .adamw import q8_decode, q8_encode


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompressionState:
    error: Any  # pytree matching grads (f32 residuals, shard-local)


def init_compression(grads_shape_tree) -> CompressionState:
    return CompressionState(error=jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, jnp.float32), grads_shape_tree))


def ef_int8_allreduce(grads, comp: CompressionState, axis: str = "data"):
    """Shard-local: (partial grads, EF state) -> (summed grads, state').

    Must run inside shard_map with ``axis`` a mesh axis name.  The
    summed result equals sum_i Q(g_i + e_i) decoded with the mean scale;
    the EF buffer absorbs each shard's own quantisation error.
    """
    n = jax.lax.psum(jnp.ones(()), axis)

    def one(g, err):
        g = g.astype(jnp.float32) + err
        q, s = q8_encode(g)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis)
        smean = jax.lax.psum(s, axis) / n
        approx = q8_decode(qsum, smean, g.shape)
        new_err = g - q8_decode(q, s, g.shape)
        return approx, new_err

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(comp.error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    red = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    err = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return red, CompressionState(error=err)
