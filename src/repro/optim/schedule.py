"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_warmup(peak: float, warmup_steps: int, total_steps: int,
                  floor_frac: float = 0.1):
    """Linear warmup -> cosine decay to ``floor_frac * peak``."""

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps)
                     / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak * (floor_frac + (1 - floor_frac)
                      * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)

    return lr
