"""Streaming product-quantization plane (PQ codes beside float tiles)."""
from .pq import (encode, encode_all_versions, decode, lookup_tables,
                 train_codebooks, init_codebooks, retrain_round, encode_tiles)

__all__ = ["encode", "encode_all_versions", "decode", "lookup_tables",
           "train_codebooks", "init_codebooks", "retrain_round",
           "encode_tiles"]
