"""Product quantization for the posting tiles (FreshDiskANN-style tier).

The quant plane keeps an ``(M, m, C)`` uint8 code array beside the float
posting tiles: search can scan compressed codes with an ADC lookup-table
kernel (``kernels/pq_scan.py``) and exact-rerank only the top
``cfg.rerank_k`` float candidates, cutting phase-2 posting bytes by
``4 * dim / m`` (16x at dim=32, m=8).

Codebooks are **versioned** so a background re-train never invalidates
codes written under an older generation: ``state.pq_codebooks`` holds
``V = cfg.pq_versions`` slots, each posting records the slot its codes
were written under (``pq_posting_slot``), and search builds one lookup
table per live slot.  A re-train writes the new generation into the
*oldest* slot; postings still pinned to that slot are re-encoded inside
the same device program (nothing is ever undecodable), while postings on
other slots upgrade lazily the next time a split/merge/compact rewrites
their tile.  This is the streaming-codebook regime of "Quantization for
Vector Search under Streaming Updates" (PAPERS.md): local refresh from a
fresh sample, never a global rebuild.

Invariant (property-tested in tests/test_pq.py): for every *valid* slot
of every live posting, ``codes[p, :, c] == encode(codebooks[slot[p]],
vectors[p, c])`` — the code plane and the float plane never diverge.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels import ops


# ---------------------------------------------------------------------------
# encode / decode / lookup tables (pure functions of one codebook set)
# ---------------------------------------------------------------------------

def encode(codebooks: jax.Array, x: jax.Array) -> jax.Array:
    """Nearest-centroid codes per subspace.

    codebooks: (m, ksub, dsub) f32; x: (N, d) -> (N, m) uint8.
    """
    m, ksub, dsub = codebooks.shape
    n = x.shape[0]
    xs = x.astype(jnp.float32).reshape(n, m, dsub).transpose(1, 0, 2)
    cn = jnp.sum(codebooks * codebooks, axis=-1)            # (m, ksub)
    dots = jnp.einsum("jnd,jkd->jnk", xs, codebooks)        # (m, N, ksub)
    scores = cn[:, None, :] - 2.0 * dots
    return jnp.argmin(scores, axis=-1).astype(jnp.uint8).T  # (N, m)


def encode_all_versions(codebooks_v: jax.Array, x: jax.Array) -> jax.Array:
    """Encode under every codebook slot at once: (V, N, m) uint8.

    Appends target postings pinned to arbitrary slots; encoding under all
    ``V`` (small, static) slots then selecting per job beats a per-job
    codebook gather.
    """
    return jax.vmap(encode, in_axes=(0, None))(codebooks_v, x)


def decode(codebooks: jax.Array, codes: jax.Array) -> jax.Array:
    """codebooks: (m, ksub, dsub); codes: (N, m) -> (N, m*dsub) f32."""
    m, ksub, dsub = codebooks.shape
    n = codes.shape[0]
    sub = codebooks[jnp.arange(m)[None, :], codes.astype(jnp.int32)]
    return sub.reshape(n, m * dsub)


def encode_tiles(codebooks: jax.Array, tiles: jax.Array) -> jax.Array:
    """Encode whole posting tiles: (B, C, d) -> (B, m, C) subspace-major."""
    B, C, d = tiles.shape
    codes = encode(codebooks, tiles.reshape(B * C, d))      # (B*C, m)
    return codes.reshape(B, C, -1).transpose(0, 2, 1)       # (B, m, C)


def lookup_tables(codebooks_v: jax.Array, queries: jax.Array) -> jax.Array:
    """ADC tables for every codebook slot.

    codebooks_v: (V, m, ksub, dsub); queries: (Q, d).
    Returns (Q, V, m, ksub) f32 with ``T[q,s,j,k] = ||cb||^2 - 2 q_j.cb``
    so that ``sum_j T[q, s, j, code_j]`` follows the repo-wide score
    convention ``||v||^2 - 2 q.v`` on the decoded vector.
    """
    V, m, ksub, dsub = codebooks_v.shape
    Q = queries.shape[0]
    qs = queries.astype(jnp.float32).reshape(Q, m, dsub)
    cn = jnp.sum(codebooks_v * codebooks_v, axis=-1)        # (V, m, ksub)
    dots = jnp.einsum("qjd,sjkd->qsjk", qs, codebooks_v)
    return cn[None] - 2.0 * dots


# ---------------------------------------------------------------------------
# codebook training — vmapped masked Lloyd per subspace
# ---------------------------------------------------------------------------

def train_codebooks(sample: jax.Array, mask: jax.Array, init: jax.Array,
                    iters: int, *, backend: str = "ref") -> jax.Array:
    """Refine codebooks on a (masked) sample, one k-means per subspace.

    sample: (S, d); mask: (S,) bool; init: (m, ksub, dsub) warm-start
    codebooks (the streaming-updates regime: each re-train refines the
    previous generation on fresh data; empty clusters keep their old
    centroid instead of collapsing).  The assignment step reuses the
    ``kernels/kmeans_assign`` op per subspace; ``backend`` follows the
    repo-wide dispatch (vmap over subspaces batches the Pallas call).
    """
    m, ksub, dsub = init.shape
    S = sample.shape[0]
    pts = sample.astype(jnp.float32).reshape(S, m, dsub).transpose(1, 0, 2)

    def lloyd(points, cents):                # (S, dsub), (ksub, dsub)
        def body(_, cents):
            assign, _ = ops.kmeans_assign(points, cents, mask,
                                          backend=backend)
            tgt = jnp.where(mask, assign, ksub)  # masked rows dropped
            sums = jnp.zeros((ksub, dsub), jnp.float32).at[tgt].add(
                points, mode="drop")
            counts = jnp.zeros((ksub,), jnp.float32).at[tgt].add(
                1.0, mode="drop")
            new = sums / jnp.maximum(counts, 1.0)[:, None]
            return jnp.where(counts[:, None] > 0, new, cents)

        return jax.lax.fori_loop(0, iters, body, cents)

    return jax.vmap(lloyd)(pts, init.astype(jnp.float32))


def init_codebooks(vectors: jax.Array, m: int, ksub: int, iters: int,
                   key: jax.Array, *, backend: str = "ref") -> jax.Array:
    """Generation-0 codebooks from a seed sample (build time)."""
    n, d = vectors.shape
    dsub = d // m
    idx = jax.random.choice(key, n, (ksub,), replace=n < ksub)
    init = vectors[idx].astype(jnp.float32).reshape(
        ksub, m, dsub).transpose(1, 0, 2)
    mask = jnp.ones((n,), bool)
    return train_codebooks(vectors, mask, init, iters, backend=backend)


# ---------------------------------------------------------------------------
# background re-train round (scheduled from UBISDriver.tick())
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def retrain_round(state, cfg, key):
    """Train the next codebook generation and install it in the oldest
    slot — one device program, float plane untouched.

    Steps: (1) sample up to ``cfg.pq_sample`` live vectors; (2) warm-start
    Lloyd from the active codebooks; (3) postings still pinned to the
    evicted slot are re-encoded under the new generation (their old
    codebook is being overwritten — everything else upgrades lazily);
    (4) rotate ``pq_active``.  Touches only codes/codebooks/slots, so the
    live id->vector multiset and search visibility cannot change
    (property-tested in tests/test_background_round.py).

    Cold-tier interplay (``cfg.use_tier``): step (3) re-encodes from the
    DEVICE float tiles — a spilled posting's tile is zeroed, so the
    drivers promote any spilled posting pinned to the evicted slot
    *before* calling this round (``_promote_retrain_pinned``), and the
    training sample masks spilled rows out explicitly (their zeroed
    device rows would otherwise collapse the codebooks toward 0).
    """
    from ..core.update import dataclasses_replace
    M, C, d = state.vectors.shape
    V = cfg.pq_versions
    S = cfg.pq_sample

    # spilled postings' device rows are zeroed (cold tier) — exclude
    # them from the training sample or the codebooks collapse on zeros
    flat_valid = (state.slot_valid
                  & ~state.tier_spilled[:, None]).reshape(-1)
    # uniform draw over the LIVE rows: random keys, invalid rows pushed
    # past every valid one, take the first S — an unbiased sample even
    # when live rows cluster at low posting ids (low flat indices)
    keys = jax.random.uniform(key, (M * C,))
    order = jnp.argsort(jnp.where(flat_valid, keys, 2.0))[:S]
    sample = state.vectors.reshape(M * C, d)[order].astype(jnp.float32)
    smask = flat_valid[order]

    active_cb = state.pq_codebooks[state.pq_active]
    new_cb = train_codebooks(sample, smask, active_cb, cfg.kmeans_iters,
                             backend=cfg.use_pallas)
    evict = (state.pq_active + 1) % V

    codebooks = state.pq_codebooks.at[evict].set(new_cb)
    gen = state.pq_slot_gen[state.pq_active] + jnp.uint32(1)
    slot_gen = state.pq_slot_gen.at[evict].set(gen)

    pinned = state.allocated & (state.pq_posting_slot == evict)
    n_pinned = jnp.sum(pinned)
    # steady-state churn lazily upgrades most postings to the active
    # slot, so the pinned set is usually small: gather it into a fixed
    # budget and encode only those tiles; the full-index encode is the
    # rare fallback (cold index where nothing was rewritten since the
    # evicted generation was active)
    R = min(M, 128)

    def reencode_few(codes):
        order = jnp.argsort(~pinned, stable=True)[:R]   # pinned first
        sel = pinned[order]
        fresh = encode_tiles(new_cb,
                             state.vectors[order].astype(jnp.float32))
        return codes.at[jnp.where(sel, order, M)].set(fresh, mode="drop")

    def reencode_all(codes):
        fresh = encode_tiles(new_cb, state.vectors.astype(jnp.float32))
        return jnp.where(pinned[:, None, None], fresh, codes)

    codes = jax.lax.cond(
        n_pinned == 0, lambda c: c,
        lambda c: jax.lax.cond(n_pinned <= R, reencode_few, reencode_all,
                               c),
        state.codes)
    posting_slot = jnp.where(pinned, evict, state.pq_posting_slot)
    return dataclasses_replace(
        state, codes=codes, pq_codebooks=codebooks, pq_slot_gen=slot_gen,
        pq_active=evict, pq_posting_slot=posting_slot)
