"""Async serving over the ``StreamingIndex`` contract.

    from repro.serving import ServingConfig, ServingEngine

    engine = ServingEngine(make_index("ubis", cfg, seeds))
    t = engine.submit_search(q, k=10)        # returns a Ticket now
    engine.submit_insert(vecs, ids)
    ...
    res = t.result()                         # pumps until resolved

Continuous batching (fill-or-deadline, separate search/insert lanes),
dispatch/collect overlap of searches with updates and background ticks,
and engine-owned tick cadence — see ``engine.py``.  ``QueuedIndex``
re-presents the batch API through the queue (the contract harness runs
through it); ``benchmarks/figserve.py`` measures p50/p99/QPS under a
Poisson open-loop load.
"""
from .engine import ServingConfig, ServingEngine  # noqa: F401
from .queued import QueuedIndex                   # noqa: F401

__all__ = ["ServingConfig", "ServingEngine", "QueuedIndex"]
