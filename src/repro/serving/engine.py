"""The open-loop serving engine: continuous batching over any engine.

``ServingEngine`` sits between per-request callers and the batch-first
``StreamingIndex`` contract.  Callers submit single queries or ingest
batches and get a :class:`~repro.api.types.Ticket` back immediately;
the engine folds pending requests into padded device batches and fires
a batch when it FILLS (``search_batch`` requests / ``insert_batch``
jobs) or when the OLDEST pending request hits the lane's deadline —
whichever comes first.  Two lanes, scheduled independently:

  * **search lane** — single-query requests folded into one padded
    ``(B, d)`` batch per fire; each ticket resolves to a one-row
    ``SearchResult`` whose ``seconds`` is the request's queue+service
    latency;
  * **update lane** — insert/delete submissions kept in FIFO order
    (interleaving inserts and deletes of the same id must replay in
    submission order); consecutive insert submissions are concatenated
    into one driver call.  A ticket whose submission was folded with
    others resolves to the *group's* aggregate ``UpdateResult`` — exact
    per-op results come from draining after each submit, which is what
    the contract harness does (``repro.serving.QueuedIndex``).

**Overlap.**  When both lanes are due and the engine supports the
non-blocking seam (``dispatch_search``/``collect_search``), the engine
dispatches the search batch first, runs the update flush (and, on
cadence, the background tick) while the device executes the search, and
only then collects — JAX's async dispatch makes the launch free, and
``collect_search`` is the one explicit ``block_until_ready`` boundary.
The collected result answers for the index as of dispatch time, so
overlap never changes what a search observes.

**Tick cadence.**  The engine owns background-tick cadence:
``tick_every = N`` runs one ``index.tick()`` after every N update-lane
flushes (0 = never — the caller ticks).  The synchronous
``RetrievalServer`` path keeps its old tick-per-ingest behavior as the
default of its own knob; see ``launch/serve.py``.

**Clock.**  Every timestamp comes from the injectable ``clock``
callable, so a seeded arrival trace replays deterministically in tests
and the open-loop benchmark can run on a *virtual* clock (advance time
by measured service seconds, never sleep).
"""
from __future__ import annotations

import time
from collections import defaultdict, deque
from typing import Callable, List, Optional, Tuple

import dataclasses

import numpy as np

from ..api.types import (SearchRequest, SearchResult, Ticket,
                         UpdateResult)
from ..obs import Obs


@dataclasses.dataclass
class ServingConfig:
    """Knobs for the two batching lanes (see the module docstring).

    ``search_batch`` is the padded device batch width — every fired
    search costs exactly one (B, d) program call, short batches ride
    with zero-padded rows.  Deadlines bound the queueing delay the
    batching may add to the OLDEST request in a lane.

    Observability knobs: ``recall_probe`` shadow-executes that fraction
    of served search batches against ``index.exact()`` off the hot path
    (rolling ``live_recall`` gauge — the paper's accuracy-stability
    claim as a production signal); ``obs_profile_dir`` wraps the first
    pump that fires work in a ``jax.profiler`` trace capture.
    """

    search_batch: int = 32
    insert_batch: int = 256
    search_deadline_s: float = 2e-3
    insert_deadline_s: float = 10e-3
    tick_every: int = 1          # background tick per N update flushes
    overlap: bool = True         # use dispatch/collect when available
    default_k: int = 10
    recall_probe: float = 0.0    # fraction of served batches probed
    recall_probe_window: int = 64
    recall_probe_rows: int = 8   # max queries probed per sampled batch
    obs_profile_dir: Optional[str] = None


@dataclasses.dataclass
class _UpdateJob:
    kind: str                    # "insert" | "delete"
    vecs: Optional[np.ndarray]
    ids: np.ndarray
    ticket: Ticket


class ServingEngine:
    """Request queue + dynamic batcher over one ``StreamingIndex``."""

    def __init__(self, index, config: Optional[ServingConfig] = None, *,
                 clock: Callable[[], float] = time.perf_counter,
                 obs: Optional[Obs] = None):
        self.index = index
        self.cfg = config if config is not None else ServingConfig()
        self.clock = clock
        self._search_q: deque[SearchRequest] = deque()
        self._update_q: deque[_UpdateJob] = deque()
        self._seq = 0
        self._flushes_since_tick = 0
        self.counters = defaultdict(int)
        # (lane, n_requests_or_jobs, reason) per fired batch — the
        # determinism tests replay a seeded trace against this log
        self.batch_log: List[Tuple[str, int, str]] = []
        self._can_overlap = (hasattr(index, "dispatch_search")
                             and hasattr(index, "collect_search"))
        # obs plane: reuse the index's so ONE exposition covers driver
        # internals and request spans; fall back to a private one
        self.obs = (obs if obs is not None
                    else getattr(index, "obs", None) or Obs())
        # request-span histograms (engine-clock seconds): queue wait
        # (submit → fire), service (fire → resolve), end-to-end latency,
        # and the update-flush work overlapped inside dispatch→collect
        self._h_queue = self.obs.histogram("serve_queue_wait_seconds")
        self._h_service = self.obs.histogram("serve_service_seconds")
        self._h_latency = self.obs.histogram("serve_latency_seconds")
        self._h_overlap = self.obs.histogram("serve_flush_overlap_seconds")
        self._g_fill = self.obs.gauge("serve_batch_fill")
        self.probe = (self.obs.make_probe(
            index, fraction=self.cfg.recall_probe,
            window=self.cfg.recall_probe_window,
            max_rows=self.cfg.recall_probe_rows)
            if self.cfg.recall_probe > 0 and hasattr(index, "exact")
            else None)
        self._profiled = False

    # ------------------------------------------------------------------
    # submission (returns immediately; tickets resolve on pump)
    # ------------------------------------------------------------------

    def _ticket(self, kind: str) -> Ticket:
        self._seq += 1
        return Ticket(kind=kind, seq=self._seq, t_submit=self.clock(),
                      _pump=self.pump)

    def submit_search(self, vector, k: Optional[int] = None) -> Ticket:
        """Enqueue ONE query; the ticket resolves to a one-row
        ``SearchResult``."""
        vec = np.asarray(vector, np.float32).reshape(-1)
        t = self._ticket("search")
        self._search_q.append(SearchRequest(
            vector=vec, k=int(k if k is not None else self.cfg.default_k),
            t_submit=t.t_submit, ticket=t))
        return t

    def submit_insert(self, vecs, ids) -> Ticket:
        vecs = np.asarray(vecs, np.float32)
        ids = np.asarray(ids, np.int64)
        t = self._ticket("insert")
        self._update_q.append(_UpdateJob("insert", vecs, ids, t))
        return t

    def submit_delete(self, ids) -> Ticket:
        ids = np.asarray(ids, np.int64)
        t = self._ticket("delete")
        self._update_q.append(_UpdateJob("delete", None, ids, t))
        return t

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    @property
    def idle(self) -> bool:
        return not self._search_q and not self._update_q

    def pending(self) -> Tuple[int, int]:
        """(queued search requests, queued update jobs)."""
        return (len(self._search_q),
                sum(len(j.ids) for j in self._update_q))

    def next_deadline(self) -> Optional[float]:
        """The earliest absolute clock time at which a lane fires
        without further arrivals — ``clock()`` itself when a lane is
        already due, None when both lanes are empty.  The virtual-clock
        benchmark advances time to ``min(next arrival, this)``."""
        now = self.clock()
        times = []
        if self._search_q:
            if len(self._search_q) >= self.cfg.search_batch:
                return now
            times.append(self._search_q[0].t_submit
                         + self.cfg.search_deadline_s)
        if self._update_q:
            if (sum(len(j.ids) for j in self._update_q)
                    >= self.cfg.insert_batch):
                return now
            times.append(self._update_q[0].ticket.t_submit
                         + self.cfg.insert_deadline_s)
        return min(times) if times else None

    # ------------------------------------------------------------------
    # the pump: one scheduling step
    # ------------------------------------------------------------------

    def pump(self, *, force: bool = False) -> int:
        """Fire every lane that is due (``force=True``: fire non-empty
        lanes regardless of fill/deadline).  Returns the number of
        tickets resolved.  When both lanes are due and the index has
        the non-blocking seam, the update flush (and cadence tick) runs
        INSIDE the search's dispatch→collect window."""
        now = self.clock()
        s_reason = self._search_due(now, force)
        u_reason = self._update_due(now, force)
        if ((s_reason or u_reason) and self.cfg.obs_profile_dir
                and not self._profiled):
            # opt-in device profiling: capture exactly one working pump
            self._profiled = True
            with self.obs.profile(self.cfg.obs_profile_dir):
                return self._pump_lanes(s_reason, u_reason)
        return self._pump_lanes(s_reason, u_reason)

    def _pump_lanes(self, s_reason: Optional[str],
                    u_reason: Optional[str]) -> int:
        resolved = 0
        if s_reason:
            reqs = self._take_search_batch()
            box = [0]
            work = None
            if u_reason:
                def work(u_reason=u_reason):
                    box[0] = self._flush_updates(u_reason)
            resolved += self._fire_search(reqs, s_reason,
                                          overlap_work=work)
            resolved += box[0]
        elif u_reason:
            resolved += self._flush_updates(u_reason)
        return resolved

    def drain(self) -> int:
        """Pump with force until both lanes are empty."""
        resolved = 0
        while not self.idle:
            resolved += self.pump(force=True)
        return resolved

    def tick(self):
        """Run one background tick on the wrapped index now (on top of
        whatever ``tick_every`` cadence the engine runs itself)."""
        self.counters["ticks"] += 1
        return self.index.tick()

    # ------------------------------------------------------------------

    def _search_due(self, now: float, force: bool) -> Optional[str]:
        if not self._search_q:
            return None
        if len(self._search_q) >= self.cfg.search_batch:
            return "fill"
        if now >= self._search_q[0].t_submit + self.cfg.search_deadline_s:
            return "deadline"
        return "force" if force else None

    def _update_due(self, now: float, force: bool) -> Optional[str]:
        if not self._update_q:
            return None
        if (sum(len(j.ids) for j in self._update_q)
                >= self.cfg.insert_batch):
            return "fill"
        if (now >= self._update_q[0].ticket.t_submit
                + self.cfg.insert_deadline_s):
            return "deadline"
        return "force" if force else None

    def _take_search_batch(self) -> List[SearchRequest]:
        """Pop the longest FIFO prefix sharing one ``k`` (a padded
        device batch runs at a single k), capped at ``search_batch``."""
        reqs = [self._search_q.popleft()]
        while (self._search_q and len(reqs) < self.cfg.search_batch
               and self._search_q[0].k == reqs[0].k):
            reqs.append(self._search_q.popleft())
        return reqs

    def _fire_search(self, reqs: List[SearchRequest], reason: str,
                     overlap_work: Optional[Callable[[], None]] = None
                     ) -> int:
        B = self.cfg.search_batch
        vecs = np.stack([r.vector for r in reqs])
        if len(reqs) < B:
            vecs = np.concatenate(
                [vecs, np.zeros((B - len(reqs), vecs.shape[1]),
                                np.float32)])
        t_fire = self.clock()
        obs_on = self.obs.enabled
        if obs_on:
            for r in reqs:
                self._h_queue.record(max(t_fire - r.t_submit, 0.0))
            self._g_fill.set(len(reqs) / B)
        if self._can_overlap and self.cfg.overlap:
            disp = self.index.dispatch_search(vecs, reqs[0].k)
            if overlap_work is not None:
                t_w = self.clock()
                overlap_work()          # runs while the device searches
                if obs_on:
                    self._h_overlap.record(max(self.clock() - t_w, 0.0))
            res = self.index.collect_search(disp)
        else:
            res = self.index.search(vecs, reqs[0].k)
            if overlap_work is not None:
                overlap_work()
        now = self.clock()
        for i, r in enumerate(reqs):
            r.ticket._resolve(
                SearchResult(ids=res.ids[i:i + 1],
                             scores=res.scores[i:i + 1],
                             seconds=now - r.t_submit), now)
        if obs_on:
            self._h_service.record(max(now - t_fire, 0.0))
            for r in reqs:
                self._h_latency.record(max(now - r.t_submit, 0.0))
        if self.probe is not None:
            # shadow-execute a sampled fraction against exact() — AFTER
            # the tickets resolved, so the probe is off the hot path
            self.probe.maybe_probe(vecs[:len(reqs)], reqs[0].k,
                                   np.asarray(res.ids)[:len(reqs)])
        self.counters["search_batches"] += 1
        self.counters["search_requests"] += len(reqs)
        self.counters["search_padded"] += B - len(reqs)
        self.counters[f"search_{reason}"] += 1
        self.batch_log.append(("search", len(reqs), reason))
        return len(reqs)

    def _flush_updates(self, reason: str) -> int:
        """Execute up to ``insert_batch`` queued update jobs in FIFO
        order, concatenating consecutive insert submissions into one
        driver call; then run the cadence tick."""
        budget = self.cfg.insert_batch
        n_jobs = 0
        resolved = 0
        while self._update_q and n_jobs < budget:
            if self._update_q[0].kind == "insert":
                group = [self._update_q.popleft()]
                n_jobs += len(group[0].ids)
                while (self._update_q and n_jobs < budget
                       and self._update_q[0].kind == "insert"):
                    g = self._update_q.popleft()
                    group.append(g)
                    n_jobs += len(g.ids)
                res = self.index.insert(
                    np.concatenate([g.vecs for g in group]),
                    np.concatenate([g.ids for g in group]))
                now = self.clock()
                for g in group:
                    g.ticket._resolve(dataclasses.replace(
                        res, seconds=now - g.ticket.t_submit), now)
                resolved += len(group)
            else:
                job = self._update_q.popleft()
                n_jobs += len(job.ids)
                res = self.index.delete(job.ids)
                now = self.clock()
                job.ticket._resolve(dataclasses.replace(
                    res, seconds=now - job.ticket.t_submit), now)
                resolved += 1
        self.counters["update_flushes"] += 1
        self.counters["update_jobs"] += n_jobs
        self.counters[f"update_{reason}"] += 1
        self.batch_log.append(("update", n_jobs, reason))
        self._flushes_since_tick += 1
        if (self.cfg.tick_every
                and self._flushes_since_tick >= self.cfg.tick_every):
            self._flushes_since_tick = 0
            self.tick()
        return resolved
