"""``QueuedIndex``: the batch API re-expressed through the queue.

An adapter that presents the ``StreamingIndex`` surface while routing
every insert/delete/search through a :class:`ServingEngine` — submit,
drain, return the resolved ticket values.  Draining after every op
keeps per-op results exact (no cross-ticket folding), so the adapter is
behaviorally identical to the wrapped engine; the contract-property
harness runs through it unchanged, which is what proves the queue adds
no semantics (only scheduling).

Searches are submitted ONE ROW PER REQUEST, so a (Q, d) batch genuinely
exercises the fold-into-padded-batch path rather than bypassing it.
Everything not reimplemented here (snapshot, exact, stats, ...)
delegates to the wrapped index.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import numpy as np

from ..api.types import SearchResult
from .engine import ServingConfig, ServingEngine


class QueuedIndex:
    """StreamingIndex adapter over a ``ServingEngine`` queue."""

    def __init__(self, index, config: Optional[ServingConfig] = None, *,
                 clock: Callable[[], float] = time.perf_counter):
        # tick_every=0 by default: the caller (harness/driver of this
        # adapter) owns background cadence, exactly like a bare engine
        self.engine = ServingEngine(
            index,
            config if config is not None else ServingConfig(tick_every=0),
            clock=clock)
        self.index = index

    def insert(self, vecs, ids):
        t = self.engine.submit_insert(vecs, ids)
        self.engine.drain()
        return t.result()

    def delete(self, ids):
        t = self.engine.submit_delete(ids)
        self.engine.drain()
        return t.result()

    def search(self, queries, k: int) -> SearchResult:
        qs = np.atleast_2d(np.asarray(queries, np.float32))
        tickets = [self.engine.submit_search(q, k) for q in qs]
        self.engine.drain()
        rows = [t.result() for t in tickets]
        return SearchResult(
            ids=np.concatenate([r.ids for r in rows]),
            scores=np.concatenate([r.scores for r in rows]))

    def tick(self):
        return self.engine.tick()

    def flush(self, max_ticks: int = 200) -> int:
        self.engine.drain()
        return self.index.flush(max_ticks)

    def __getattr__(self, name):
        return getattr(self.index, name)
