import os
import sys

# Tests run single-device (the dry-run owns the 512-device fake platform;
# multi-device tests spawn subprocesses that set XLA_FLAGS themselves).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _reset_kernel_fallback_state():
    """The kernel-fallback plane keeps process-global one-shot state
    (warn dedup, dispatch memo, registered sinks); clear it between
    tests so one test's captures never leak into the next."""
    from repro.kernels import ops
    ops.reset_fallback_state()
    yield
    ops.reset_fallback_state()


def make_clustered(n, d=16, k=20, seed=1, scale=5.0):
    r = np.random.default_rng(seed)
    cents = r.normal(size=(k, d)) * scale
    a = r.integers(0, k, n)
    return (cents[a] + r.normal(size=(n, d))).astype(np.float32)
