"""Differential harness for the ``StreamingIndex`` engine contract.

A random (seed-deterministic) interleaving of insert / delete / search /
tick / flush runs against any ``make_index`` engine while a pure-Python
oracle tracks the live id -> vector multiset.  After every tick the
engine's approximate search is scored against its own ``exact()``
oracle (recall@k floor); at every flush the live multiset is audited.
Engines with the cold tier enabled additionally get forced
spill/promote ops (each followed by the recall + multiset audits) and
an optional snapshot -> restore equivalence check at the end, so tier
transitions must be indistinguishable from the all-float program.

Importable without pytest so the multi-shard subprocess tests
(``test_rebalance.py``) can drive the same program against a real
multi-device mesh — where the interleaving also exercises the
cross-shard migrate round.

Engine audit tiers come from the registry's ``EngineSpec.audit``
capability flag (``repro.api.engine_spec``):
  * ``state``  — engines exposing the full ``IndexState`` pytree
    (ubis / spfresh / ubis-sharded): exact multiset equality, id AND
    vector bytes, postings + cache;
  * ``count``  — graph engines (freshdiskann): ``live_count`` equality
    plus deleted ids never resurfacing in search results;
  * ``static`` — build-once engines (spann): every update refused
    through the result types, seed corpus intact.
"""
from __future__ import annotations

import numpy as np
# Floors are per-engine honesty bounds, not aspirations: the cluster
# engines probe every posting (nprobe = max_postings) so anything under
# 0.9 means the update plane corrupted the index; the graph baseline's
# greedy single-entry search genuinely strands isolated clusters on
# drifting/clustered streams (the paper's motivation), so its floor only
# guards against catastrophic breakage (empty/garbage results).
RECALL_FLOOR = {"ubis": 0.9, "spfresh": 0.9, "ubis-sharded": 0.9,
                "ubis-cluster": 0.9, "freshdiskann": 0.15, "spann": 0.8}


def make_clustered(n, d=16, k=10, seed=1, scale=5.0):
    r = np.random.default_rng(seed)
    cents = r.normal(size=(k, d)) * scale
    a = r.integers(0, k, n)
    return (cents[a] + r.normal(size=(n, d))).astype(np.float32)


def live_map(state):
    """id -> vector bytes over every live slot (postings + cache)."""
    from repro.core import version_manager as vm
    status = np.asarray(vm.unpack_status(state.rec_meta))
    vis = np.asarray(state.allocated) & (status != 3)
    ids = np.asarray(state.ids)
    sv = np.asarray(state.slot_valid)
    vecs = np.asarray(state.vectors)
    out = {}
    for p in np.flatnonzero(vis):
        for c in np.flatnonzero(sv[p]):
            i = int(ids[p, c])
            assert i not in out, f"duplicate id {i} (posting {p})"
            out[i] = vecs[p, c].tobytes()
    cv = np.asarray(state.cache_valid)
    cids = np.asarray(state.cache_ids)
    cvecs = np.asarray(state.cache_vecs)
    for s in np.flatnonzero(cv):
        i = int(cids[s])
        assert i not in out, f"duplicate cached id {i}"
        out[i] = cvecs[s].tobytes()
    return out


def recall_at_k(found, true):
    hits = total = 0
    for f, t in zip(np.asarray(found), np.asarray(true)):
        ts = set(int(x) for x in t if x >= 0)
        if not ts:
            continue
        hits += len(set(int(x) for x in f if x >= 0) & ts)
        total += len(ts)
    return hits / total if total else 1.0


def trace_baseline(idx):
    """Capture the trace/stats watermark the end-of-run audit diffs
    against (constructor-time events — e.g. SPANN's bulk build — and
    any prior program on the same index are excluded by sequence
    number).  Returns None when the index has no enabled obs plane."""
    obs = getattr(idx, "obs", None)
    if obs is None or not getattr(obs, "enabled", False):
        return None
    seqs = [e["seq"] for e in obs.events()]
    s = idx.stats
    return {"seq": max(seqs) if seqs else -1,
            "tier_spilled": float(s["tier_spilled"]),
            "tier_promoted": float(s["tier_promoted"]),
            "migrated": float(s["migrated"])}


def audit_trace(engine, idx, base, live0):
    """Cross-check the structured trace stream against ground truth.

    The trace events are *claims* about what the planners did; this
    audit makes them load-bearing: (1) net insert/delete event sums must
    equal the index's live-count delta — an insert that lied about
    ``accepted`` or an unreported delete fails here; (2) every tier
    spill/promote commit event must account 1:1 for the stats counters
    (an untraced residency change, or a traced-but-uncommitted one,
    both fail); (3) every cross-shard migrate the sharded driver counted
    must appear in a ``rebalance`` event with its donor decision.
    """
    obs = idx.obs
    if len(obs.tracer) >= obs.tracer.capacity:
        return  # ring wrapped: sums would under-count, not meaningful
    evs = [e for e in obs.events() if e["seq"] > base["seq"]]
    by = {}
    for e in evs:
        by.setdefault(e["kind"], []).append(e)
    net = (sum(e["accepted"] + e["cached"] for e in by.get("insert", []))
           - sum(e["deleted"] for e in by.get("delete", [])))
    assert net == idx.live_count() - live0, (
        engine, "insert/delete trace events disagree with the live "
        "multiset delta", net, idx.live_count() - live0)
    ev_sp = sum(len(e["spilled"]) for e in by.get("tier_commit", []))
    ev_pr = sum(len(e["promoted"]) for e in by.get("tier_commit", []))
    st = idx.stats
    assert ev_sp == float(st["tier_spilled"]) - base["tier_spilled"], (
        engine, "tier_commit spill events disagree with stats", ev_sp)
    assert ev_pr == float(st["tier_promoted"]) - base["tier_promoted"], (
        engine, "tier_commit promote events disagree with stats", ev_pr)
    ev_mig = sum(e["migrated"] for e in by.get("rebalance", []))
    assert ev_mig == float(st["migrated"]) - base["migrated"], (
        engine, "rebalance trace events disagree with stats", ev_mig)


def random_ops(rng, n_ops, tiered: bool = False):
    """A seed-deterministic op tape.  Weights favour updates; ticks and
    searches interleave; one flush rides near the end so the audit sees
    both mid-churn and quiescent states.  ``tiered`` adds forced
    spill/promote ops (engines with the cold tier enabled), so the
    interleaving exercises tier transitions between every other op."""
    if tiered:
        kinds = rng.choice(
            ["insert", "delete", "search", "tick", "spill", "promote"],
            size=n_ops, p=[0.32, 0.16, 0.16, 0.16, 0.12, 0.08])
    else:
        kinds = rng.choice(["insert", "delete", "search", "tick"],
                           size=n_ops, p=[0.40, 0.20, 0.20, 0.20])
    tape = list(kinds) + (["spill"] if tiered else []) + ["flush", "search"]
    return tape


def run_program(engine, idx, data, seed, *, n_ops=12, k=8,
                max_batch=96, recall_floor=None, seed_ids=None,
                restore_fn=None):
    """Run one random interleaving; returns (oracle, stats dict).

    ``data`` is the vector pool (fresh inserts draw monotone slices);
    ``seed_ids`` are the ids the build-once engines ingested at
    construction (their oracle starting point).

    Engines built with the cold tier (``cfg.use_tier``) get forced
    spill/promote ops woven into the tape; after each the recall floor
    and (strict) live-multiset audit re-run, so a tier transition that
    loses/duplicates a vector or wrecks ADC-only serving fails here.
    ``restore_fn`` (optional): a callable ``snapshot -> fresh index``;
    when given, the final quiescent snapshot is round-tripped through it
    and the restored index must answer search identically and hold the
    identical live multiset (tier state included).
    """
    rng = np.random.default_rng(seed)
    from repro.api import engine_spec
    spec = engine_spec(engine)
    audit = spec.audit
    # the spec says whether the engine CAN tier; the built instance's
    # cfg says whether this run actually enabled it
    tiered = (spec.supports_tier
              and bool(getattr(getattr(idx, "cfg", None), "use_tier",
                               False)))
    floor = RECALL_FLOOR[engine] if recall_floor is None else recall_floor
    oracle = {}
    if audit in ("static", "count") and seed_ids is not None:
        # build-once / graph engines ingested the seed corpus at
        # construction; the cluster engines use seeds for k-means only
        for i in np.asarray(seed_ids):
            oracle[int(i)] = data[int(i)].tobytes()
    next_id = 0 if seed_ids is None else int(np.asarray(seed_ids).max()) + 1
    queries = data[rng.integers(0, len(data), 24)]
    deleted_ever = set()
    n_checks = 0
    # trace audit baseline: only state-audit engines report exact
    # per-call accepted/cached/deleted counts in their events
    trace_base = trace_baseline(idx) if audit == "state" else None
    live0 = idx.live_count()

    def check_recall():
        found = idx.search(queries, k).ids
        true = idx.exact(queries, k).ids
        rec = recall_at_k(found, true)
        assert rec >= floor, (engine, rec, floor)
        if audit == "count" and deleted_ever:
            hits = set(int(x) for x in np.asarray(found).ravel() if x >= 0)
            assert not (hits & deleted_ever), "deleted ids resurfaced"
        return rec

    def check_multiset(strict):
        nonlocal n_checks
        n_checks += 1
        assert idx.live_count() == len(oracle), (
            engine, idx.live_count(), len(oracle))
        if audit == "state" and strict:
            m = live_map(idx.snapshot())
            assert m == oracle, (
                f"{engine}: multiset diverged "
                f"({len(m)} live vs {len(oracle)} oracle, "
                f"{len(set(m) ^ set(oracle))} id mismatches)")

    for op in random_ops(rng, n_ops, tiered=tiered):
        if op == "spill":
            idx.force_spill(int(rng.integers(1, 8)))
            check_recall()                # ADC-only serving holds the floor
            check_multiset(strict=True)   # snapshot fill-back is exact
        elif op == "promote":
            idx.force_promote()
            check_recall()
            check_multiset(strict=False)
        elif op == "insert":
            n = int(rng.integers(8, max_batch))
            if next_id + n > len(data):
                continue
            vecs = data[next_id:next_id + n]
            ids = np.arange(next_id, next_id + n)
            next_id += n
            r = idx.insert(vecs, ids)
            if audit == "static":
                assert (r.accepted, r.cached, r.rejected) == (0, 0, n)
            else:
                assert r.accepted + r.cached + r.rejected == n
                if r.rejected == 0:
                    applied = np.ones(n, bool)
                else:
                    # the lock-model engine (spfresh) legitimately drops
                    # jobs that kept hitting in-flux postings; counts
                    # alone cannot say WHICH, but the id map can: these
                    # ids are fresh, so id_loc != -1 iff applied
                    assert audit == "state", (engine, "untrackable", r)
                    il = np.asarray(idx.state.id_loc)[ids]
                    applied = il != -1
                    assert int(applied.sum()) == r.accepted + r.cached, (
                        engine, int(applied.sum()), r)
                for i, v in zip(ids[applied], vecs[applied]):
                    oracle[int(i)] = v.tobytes()
        elif op == "delete":
            live = sorted(oracle) if audit != "static" else []
            if audit == "static":
                r = idx.delete(np.arange(5))
                assert (r.deleted, r.blocked) == (0, 5)
                continue
            if not live:
                continue
            n = int(rng.integers(1, max(len(live) // 4, 2)))
            picks = rng.choice(live, size=min(n, len(live)), replace=False)
            r = idx.delete(picks)
            # lock-model engines may block deletes on in-flux postings;
            # blocked ids stay live (their identity is not reported, so
            # the oracle can only stay exact when nothing blocked —
            # retry the blocked remainder after a flush instead)
            if r.blocked:
                idx.flush(max_ticks=40)
                r2 = idx.delete(picks)
                assert r.deleted + r2.deleted == len(picks), (r, r2)
            else:
                assert r.deleted == len(picks), (r, len(picks))
            for i in picks:
                oracle.pop(int(i), None)
                deleted_ever.add(int(i))
        elif op == "search":
            s = idx.search(queries, k)
            assert s.ids.shape == (len(queries), k)
        elif op == "tick":
            t = idx.tick()
            assert t.executed >= 0 and t.migrated >= 0
            check_recall()
            check_multiset(strict=False)
        else:  # flush
            idx.flush(max_ticks=60)
            check_recall()
            check_multiset(strict=True)
    idx.flush(max_ticks=60)
    rec = check_recall()
    check_multiset(strict=True)
    if trace_base is not None:
        audit_trace(engine, idx, trace_base, live0)
    if restore_fn is not None:
        # snapshot -> restore round-trip: the restored index answers
        # search identically (scores included) and holds the identical
        # live multiset — with tiering, residency is re-derived from the
        # snapshot's tier flags, so this proves the tier state persists
        s0 = idx.search(queries, k)
        idx2 = restore_fn(idx.snapshot())
        s1 = idx2.search(queries, k)
        np.testing.assert_array_equal(np.asarray(s0.ids),
                                      np.asarray(s1.ids))
        np.testing.assert_allclose(np.asarray(s0.scores),
                                   np.asarray(s1.scores),
                                   rtol=1e-5, atol=1e-5)
        if audit == "state":
            assert live_map(idx2.snapshot()) == oracle, \
                "restored index diverged from the oracle multiset"
    assert n_checks > 0
    return oracle, {"recall": rec, "inserted": next_id,
                    "deleted": len(deleted_ever)}
