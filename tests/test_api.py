"""API-parity tests for the ``StreamingIndex`` front door.

Three layers:
  * contract churn — ONE mixed insert/delete/search/tick/flush workload
    run through ``make_index`` for EVERY engine, asserting the shared
    result shapes/types (no engine-specific branches in the loop);
  * equivalence — ``ubis-sharded`` on a 1-shard mesh must end a mixed
    workload with the *identical* live id->vector multiset as the
    single-device driver, and (with exhaustive probing) identical
    search results after ``flush()``;
  * coverage — ``ShardedUBISDriver.tick()`` exercises the host cache
    drain, the in-round GC, and the PQ codebook re-train; the
    single-device ``fused_tick`` path converges like the host path.
"""
import numpy as np
import pytest

from repro.api import (ENGINES, SearchResult, StreamingIndex, TickReport,
                       UpdateResult, make_index)
from repro.core import UBISConfig, UBISDriver, metrics
from conftest import make_clustered

DIM = 16


def _cfg(**kw):
    base = dict(dim=DIM, max_postings=256, capacity=96, l_min=10,
                l_max=80, max_ids=1 << 14, use_pallas="off")
    base.update(kw)
    return UBISConfig(**base)


@pytest.mark.parametrize("engine", ENGINES)
def test_engine_contract_churn(engine):
    """Every engine: same churn loop, same typed results, same shapes."""
    data = make_clustered(1200, d=DIM, k=12, seed=7)
    q = make_clustered(24, d=DIM, k=12, seed=8)
    idx = make_index(engine, _cfg(), data[:400],
                     seed_ids=np.arange(400), round_size=256,
                     bg_ops_per_round=4, max_nodes=4096, beam=24)
    assert isinstance(idx, StreamingIndex)

    r = idx.insert(data[400:900], np.arange(400, 900))
    assert isinstance(r, UpdateResult)
    assert r.accepted + r.cached + r.rejected == 500
    with pytest.raises(TypeError):
        r["accepted"]                        # PR 3 dict shim is gone

    t = idx.tick()
    assert isinstance(t, TickReport)
    assert t.executed >= 0
    with pytest.raises(TypeError):
        t["executed"]                        # PR 3 dict shim is gone

    s = idx.search(q, 5)
    assert isinstance(s, SearchResult)
    assert s.ids.shape == (24, 5) and s.scores.shape == (24, 5)
    assert np.issubdtype(s.ids.dtype, np.integer)
    with pytest.raises(TypeError):
        iter(s)                              # PR 3 tuple shim is gone

    d = idx.delete(np.arange(410, 430))
    assert isinstance(d, UpdateResult)
    assert d.deleted + d.blocked <= 20

    n_ticks = idx.flush(max_ticks=30)
    assert isinstance(n_ticks, int)
    assert idx.snapshot() is not None
    assert idx.memory_bytes() > 0
    assert isinstance(idx.posting_lengths(), np.ndarray)
    ex = idx.exact(q, 5)
    assert ex.ids.shape == (24, 5)
    assert isinstance(idx.live_count(), int)
    assert float(idx.stats["queries"]) >= 24


def test_spann_refuses_updates_as_counts():
    """The static baseline reports refusals through the result types
    (rejected/blocked), never raises — so it rides the comparison loop."""
    data = make_clustered(600, d=DIM, seed=9)
    idx = make_index("spann", _cfg(), data, seed_ids=np.arange(600))
    r = idx.insert(data[:50], np.arange(1000, 1050))
    assert (r.accepted, r.cached, r.rejected) == (0, 0, 50)
    d = idx.delete(np.arange(10))
    assert (d.deleted, d.blocked) == (0, 10)
    # the seed corpus itself is searchable
    found = idx.search(data[:8], 1).ids
    assert (found[:, 0] == np.arange(8)).all()


def _churn(drv, data, seed=0):
    """One deterministic mixed workload through the protocol surface."""
    rng = np.random.default_rng(seed)
    n = len(data)
    third = n // 3
    drv.insert(data[:third], np.arange(third))
    drv.tick()
    drv.insert(data[third:2 * third], np.arange(third, 2 * third))
    dels = rng.choice(2 * third, size=third // 2, replace=False)
    drv.delete(dels)
    drv.tick()
    drv.insert(data[2 * third:], np.arange(2 * third, n))
    drv.flush(max_ticks=60)
    return set(range(n)) - set(int(x) for x in dels)


def _live_map(state, cfg):
    """id -> vector bytes for every live slot (postings + cache)."""
    from repro.core import version_manager as vm
    status = np.asarray(vm.unpack_status(state.rec_meta))
    vis = np.asarray(state.allocated) & (status != 3)
    ids = np.asarray(state.ids)
    sv = np.asarray(state.slot_valid)
    vecs = np.asarray(state.vectors)
    out = {}
    for p in np.flatnonzero(vis):
        for c in np.flatnonzero(sv[p]):
            i = int(ids[p, c])
            assert i not in out, f"duplicate id {i}"
            out[i] = vecs[p, c].tobytes()
    cv = np.asarray(state.cache_valid)
    cids = np.asarray(state.cache_ids)
    cvecs = np.asarray(state.cache_vecs)
    for s in np.flatnonzero(cv):
        i = int(cids[s])
        assert i not in out, f"duplicate cached id {i}"
        out[i] = cvecs[s].tobytes()
    return out


@pytest.mark.parametrize("seed", [0, 3])
def test_sharded_one_shard_matches_single_device(seed):
    """Property: ubis-sharded on a 1-shard mesh ends the same mixed
    workload with the single-device driver's live id->vector multiset,
    and — probing every posting — identical search results."""
    import jax
    # nprobe = max_postings: search degenerates to exact over the live
    # contents, so results depend on WHAT is indexed, not how the two
    # drivers' different background schedules shaped the postings
    cfg = _cfg(max_postings=128, nprobe=128, max_ids=1 << 13)
    data = make_clustered(2200, d=DIM, k=10, seed=30 + seed)
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    single = UBISDriver(cfg, data[:500], round_size=256,
                        bg_ops_per_round=8, seed=seed)
    sharded = make_index("ubis-sharded", cfg, data[:500], mesh=mesh,
                         round_size=256, bg_ops_per_round=8, seed=seed)
    live_expect = _churn(single, data, seed)
    live_expect2 = _churn(sharded, data, seed)
    assert live_expect == live_expect2

    m_single = _live_map(single.state, cfg)
    snap = sharded.snapshot()        # asserts the canonical free stack
    m_sharded = _live_map(snap, cfg)
    assert set(m_single) == live_expect, "single driver lost/kept ids"
    assert m_single == m_sharded, (
        f"multisets diverge: {len(m_single)} vs {len(m_sharded)} live, "
        f"{sum(m_single[i] != m_sharded[i] for i in m_single if i in m_sharded)} vector mismatches")

    q = make_clustered(48, d=DIM, k=10, seed=99)
    rs = single.search(q, 10)
    rd = sharded.search(q, 10)
    np.testing.assert_allclose(rs.scores, rd.scores, rtol=1e-4, atol=1e-4)
    for row_s, row_d in zip(rs.ids, rd.ids):
        assert set(row_s.tolist()) == set(row_d.tolist())


def test_sharded_tick_exercises_drain_gc_pq():
    """Acceptance: ShardedUBISDriver.tick() = host cache drain + in-round
    GC + PQ retrain, all observable."""
    import jax
    cfg = _cfg(max_postings=128, max_ids=1 << 13, use_pq=True,
               pq_m=4, pq_ksub=16, pq_sample=512, rerank_k=256)
    # a handful of clusters over ~3 seeded postings: tiles overflow
    # fast, forcing rejects -> host cache; the follow-up splits retire
    # parents, feeding the GC (clusters stay separated so the coarse
    # m=4 codes still rank candidates sanely)
    data = make_clustered(1400, d=DIM, k=4, seed=5, scale=10.0)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    drv = make_index("ubis-sharded", cfg, data[:200], mesh=mesh,
                     round_size=256, bg_ops_per_round=8,
                     insert_retries=0, gc_lag=2, pq_retrain_every=1)
    drv.insert(data, np.arange(1400), tick_between=False)
    assert drv.stats["host_cached"] > 0, \
        "workload never parked a job in the host-mediated cache"
    drained = gc = retrained = 0
    for _ in range(40):
        t = drv.tick()
        drained += t.drained
        gc += t.gc
        retrained += t.pq_retrained
        if (t.executed == 0
                and not int(np.asarray(drv.state.cache_valid).sum())):
            break
    assert drained > 0, "cache drain never re-inserted a parked job"
    assert gc > 0, "in-round GC never reclaimed a retired posting"
    assert retrained > 0, "PQ retrain never ran on cadence"
    # nothing lost: every streamed id is live exactly once
    live = _live_map(drv.snapshot(), cfg)
    assert set(live) == set(range(1400)), len(live)
    # search still answers through the PQ phase-2 path
    found = drv.search(data[:8], 5).ids
    rec = metrics.recall_at_k(
        np.asarray(found), np.asarray(drv.exact(data[:8], 5).ids))
    assert rec > 0.9, rec


def test_fused_tick_matches_host_scheduling():
    """The device-side mark path (fused_tick) converges the same churn
    to the same live contents and a balanced index — without detect()
    host reads."""
    data = make_clustered(2000, d=DIM, k=12, seed=11)
    live = {}
    for fused in (False, True):
        cfg = _cfg()
        drv = UBISDriver(cfg, data[:400], round_size=256,
                         bg_ops_per_round=8, fused_tick=fused)
        expected = _churn(drv, data, seed=1)
        lens = drv.posting_lengths()
        assert (lens <= cfg.l_max).all(), lens.max()
        assert drv.stats["bg_ops"] > 0
        m = _live_map(drv.state, cfg)
        assert set(m) == expected
        live[fused] = m
    assert live[False] == live[True]


def test_freshdiskann_reinsert_is_upsert():
    """Re-inserting a live external id retires the old node: deletes
    and searches never resurrect a stale duplicate (the seed-corpus +
    batch-0 overlap every streaming benchmark produces)."""
    data = make_clustered(300, d=DIM, seed=17)
    idx = make_index("freshdiskann", _cfg(), data[:100],
                     seed_ids=np.arange(100), max_nodes=2048)
    idx.insert(data[:100], np.arange(100))       # same ids again
    assert idx.live_count() == 100, idx.live_count()
    idx.delete(np.arange(40))
    idx.flush()
    found = idx.search(data[:40], 3).ids
    hits = set(int(f) for f in np.asarray(found).ravel() if f >= 0)
    assert not (hits & set(range(40))), "deleted ids resurfaced"
    assert idx.live_count() == 60


def test_registry_capabilities():
    """list_engines() exposes one EngineSpec per engine with honest
    capability flags — the probe-with-try/except pattern's replacement."""
    from repro.api import EngineSpec, engine_spec, list_engines
    specs = list_engines()
    assert tuple(s.name for s in specs) == ENGINES
    assert all(isinstance(s, EngineSpec) for s in specs)
    ubis = engine_spec("ubis")
    assert ubis.supports_tier and ubis.supports_pq
    assert not ubis.supports_shards and ubis.updatable
    sharded = engine_spec("ubis-sharded")
    assert sharded.supports_shards and sharded.supports_tier
    spann = engine_spec("spann")
    assert not spann.updatable and spann.audit == "static"
    assert engine_spec("freshdiskann").audit == "count"
    with pytest.raises(ValueError):
        engine_spec("hnswlib")


def test_quickstart_example_runs_every_engine():
    """The quickstart path (make_index + typed results + snapshot +
    live_count) stays runnable for every updatable engine."""
    data = make_clustered(800, d=DIM, seed=13)
    for engine in ("ubis", "ubis-sharded", "freshdiskann"):
        idx = make_index(engine, _cfg(), data[:200],
                         seed_ids=np.arange(200), round_size=256,
                         max_nodes=4096)
        idx.insert(data, np.arange(800))
        idx.flush(max_ticks=30)
        assert idx.snapshot() is not None
        assert idx.live_count() == 800, (engine, idx.live_count())
