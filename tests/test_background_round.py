"""Batched background round vs. the sequential per-op oracle.

The tentpole guarantee: ONE ``balance.background_round`` call over a
mixed split/merge/compact batch leaves the index *equivalent* to the old
one-op-at-a-time execution — same live id -> vector multiset, same
structural invariants — while never touching the host mid-batch.
Positions/posting ids may differ (conflict resolution is explicit rather
than order-implicit), which is exactly why the comparison is multiset-
level, not state-level.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (UBISConfig, UBISDriver, balance, update,
                        version_manager as vm)
from repro.core.types import (KIND_COMPACT, KIND_MERGE, KIND_SPLIT,
                              STATUS_MERGING, STATUS_SPLITTING)
from conftest import make_clustered

KIND_CODE = {"split": KIND_SPLIT, "merge": KIND_MERGE,
             "compact": KIND_COMPACT}


def _mk_cfg(mode="ubis", max_postings=128):
    return UBISConfig(dim=8, max_postings=max_postings, capacity=64,
                      l_min=6, l_max=48, cache_capacity=512,
                      max_ids=1 << 13, use_pallas="off", mode=mode)


def live_multiset(state, cfg):
    """id -> exact vector bytes for every live id (postings + cache)."""
    C = cfg.capacity
    il = np.asarray(state.id_loc)
    vecs = np.asarray(state.vectors)
    cvecs = np.asarray(state.cache_vecs)
    out = {}
    for i in np.flatnonzero(il != -1):
        loc = int(il[i])
        if loc >= 0:
            out[int(i)] = vecs[loc // C, loc % C].tobytes()
        else:
            out[int(i)] = cvecs[-2 - loc].tobytes()
    return out


def check_invariants(state, cfg):
    status = np.asarray(vm.unpack_status(state.rec_meta))
    alloc = np.asarray(state.allocated)
    sv = np.asarray(state.slot_valid)
    ids = np.asarray(state.ids)
    lengths = np.asarray(state.lengths)
    used = np.asarray(state.used)
    # audit postings + cache, assert no duplicate ids and id_loc agreement
    where, dup = {}, 0
    for p in np.flatnonzero(alloc & (status != 3)):
        assert lengths[p] == sv[p].sum(), f"length mismatch at {p}"
        assert used[p] >= lengths[p] and used[p] <= cfg.capacity
        for c in np.flatnonzero(sv[p]):
            i = int(ids[p, c])
            dup += i in where
            where[i] = p * cfg.capacity + c
    cv = np.asarray(state.cache_valid)
    ci = np.asarray(state.cache_ids)
    for s in np.flatnonzero(cv):
        i = int(ci[s])
        dup += i in where
        where[i] = -2 - s
    assert dup == 0, "duplicated live id"
    il = np.asarray(state.id_loc)
    tracked = {int(i): int(il[i]) for i in np.flatnonzero(il != -1)}
    assert tracked == where, (
        f"id_loc desync: tracks {len(tracked)}, audit found {len(where)}")
    # free-list integrity
    top = int(state.free_top)
    free = np.asarray(state.free_list)[:top]
    assert len(np.unique(free)) == top
    assert not alloc[free].any()
    assert top + alloc.sum() == cfg.max_postings


def sequential_execute(state, cfg, jobs, reassign=True):
    """The retired driver loop, verbatim: the oracle the batch must match."""
    for kind, pid in jobs:
        st_now = int(vm.unpack_status(state.rec_meta[pid]))
        want = STATUS_MERGING if kind == "merge" else STATUS_SPLITTING
        if st_now != want or not bool(state.allocated[pid]):
            continue
        free_top = int(state.free_top)
        pid_j = jnp.asarray(pid, jnp.int32)
        if kind == "split":
            if free_top < 2:
                state = update.mark_status(state, pid_j[None], 0)
                continue
            if int(state.lengths[pid]) <= cfg.l_max:
                state = balance.compact_posting(state, cfg, pid_j)
                state = update.mark_status(state, pid_j[None], 0)
            else:
                state, new_pids = balance.balance_split(state, cfg, pid_j)
                if reassign:
                    for np_ in np.asarray(new_pids):
                        if int(np_) >= 0 and bool(state.allocated[int(np_)]):
                            state, _ = balance.reassign_check(
                                state, cfg, jnp.asarray(int(np_), jnp.int32))
        elif kind == "merge":
            if free_top < 1:
                state = update.mark_status(state, pid_j[None], 0)
                continue
            state, pnew, _ = balance.merge_postings(state, cfg, pid_j)
            if reassign:
                state, _ = balance.reassign_check(state, cfg, pnew)
        elif kind == "compact":
            state = balance.compact_posting(state, cfg, pid_j)
            state = update.mark_status(state, pid_j[None], 0)
    return state


def _marked_state(cfg, seed, n=1200, n_del=300, bg_ops=8):
    """Drive inserts (no ticks -> oversize postings) + deletes (-> small
    postings and tombstones), then mark a mixed candidate batch exactly
    the way the driver does."""
    rng = np.random.default_rng(seed)
    data = make_clustered(n, d=cfg.dim, k=6, seed=seed)
    drv = UBISDriver(cfg, data[:150], round_size=128, bg_ops_per_round=bg_ops)
    drv.insert(data, np.arange(n), tick_between=False)
    dels = rng.choice(n, size=n_del, replace=False)
    drv.delete(dels)
    state = drv.state
    split_due, merge_due, compact_due = (np.asarray(x) for x in
                                         balance.detect(state, cfg))
    lengths = np.asarray(state.lengths)
    split_pids = np.flatnonzero(split_due)
    split_pids = split_pids[np.argsort(-lengths[split_pids])]
    merge_pids = np.flatnonzero(merge_due)
    merge_pids = merge_pids[np.argsort(lengths[merge_pids])]
    compact_pids = np.flatnonzero(compact_due)
    jobs = ([("split", int(p)) for p in split_pids]
            + [("compact", int(p)) for p in compact_pids]
            + [("merge", int(p)) for p in merge_pids])[:bg_ops]
    split_like = [p for k, p in jobs if k in ("split", "compact")]
    merge_like = [p for k, p in jobs if k == "merge"]
    if split_like:
        state = update.mark_status(
            state, jnp.asarray(split_like, jnp.int32), STATUS_SPLITTING)
    if merge_like:
        state = update.mark_status(
            state, jnp.asarray(merge_like, jnp.int32), STATUS_MERGING)
    return state, jobs


def _run_batched(state, cfg, jobs, bg_ops, **kw):
    kinds = np.zeros(bg_ops, np.int32)
    pids = np.full(bg_ops, -1, np.int32)
    for i, (k, p) in enumerate(jobs):
        kinds[i], pids[i] = KIND_CODE[k], p
    return balance.background_round(
        state, cfg, jnp.asarray(kinds), jnp.asarray(pids), **kw)


@pytest.mark.parametrize("mode", ["ubis", "spfresh"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_batched_equals_sequential(mode, seed):
    """Property: over randomized mixed batches, one background_round is
    multiset-equivalent to the sequential execution order."""
    cfg = _mk_cfg(mode)
    state, jobs = _marked_state(cfg, seed)
    assert jobs, "schedule produced no background candidates"
    before = live_multiset(state, cfg)

    st_seq = sequential_execute(state, cfg, list(jobs))
    st_bat, rr = _run_batched(state, cfg, list(jobs), bg_ops=8)

    check_invariants(st_seq, cfg)
    check_invariants(st_bat, cfg)
    seq_ms = live_multiset(st_seq, cfg)
    bat_ms = live_multiset(st_bat, cfg)
    # structural ops move vectors, never create or destroy them
    assert seq_ms == before
    assert bat_ms == before
    assert int(rr.executed) > 0


def test_mixed_batch_executes_all_kinds():
    """One round containing splits AND merges AND compacts at once; the
    merge half is forced by hollowing out two postings below l_min."""
    cfg = _mk_cfg("ubis")
    rng = np.random.default_rng(11)
    data = make_clustered(1200, d=cfg.dim, k=6, seed=11)
    drv = UBISDriver(cfg, data[:150], round_size=128, bg_ops_per_round=8)
    drv.insert(data, np.arange(1200), tick_between=False)
    state = drv.state
    lengths = np.asarray(state.lengths)
    status = np.asarray(vm.unpack_status(state.rec_meta))
    normal = np.asarray(state.allocated) & (status == 0)
    mid = np.flatnonzero(normal & (lengths >= cfg.l_min))[:2]
    assert len(mid) == 2
    ids = np.asarray(state.ids)
    sv = np.asarray(state.slot_valid)
    doomed = np.concatenate(
        [ids[p][sv[p]][: int(lengths[p]) - cfg.l_min + 1] for p in mid])
    drv.state = state
    drv.delete(doomed)
    state = drv.state
    jobs = [("merge", int(p)) for p in mid]
    lengths = np.asarray(state.lengths)
    split_pids = np.flatnonzero(np.asarray(balance.detect(state, cfg)[0]))
    jobs += [("split", int(p)) for p in split_pids[:4]]
    state = update.mark_status(state, jnp.asarray(mid, jnp.int32),
                               STATUS_MERGING)
    state = update.mark_status(
        state, jnp.asarray(split_pids[:4], jnp.int32), STATUS_SPLITTING)
    before = live_multiset(state, cfg)
    st_seq = sequential_execute(state, cfg, list(jobs))
    st, rr = _run_batched(state, cfg, jobs, bg_ops=8)
    check_invariants(st, cfg)
    check_invariants(st_seq, cfg)
    assert live_multiset(st, cfg) == before
    assert live_multiset(st_seq, cfg) == before
    assert int(rr.n_merge) > 0 and int(rr.n_split) > 0, (
        int(rr.n_merge), int(rr.n_split))


def test_free_exhaustion_defers_not_corrupts():
    """With almost no free slots, later ops defer (revert to NORMAL) and
    the state stays consistent — the batched grant scan must match the
    sequential free_top checks."""
    cfg = _mk_cfg("ubis", max_postings=32)
    state, jobs = _marked_state(cfg, 3, n=1500, n_del=0)
    free_top = int(state.free_top)
    st_bat, rr = _run_batched(state, cfg, jobs, bg_ops=8)
    check_invariants(st_bat, cfg)
    assert live_multiset(st_bat, cfg) == live_multiset(state, cfg)
    demand = int(rr.n_split) * 2 + int(rr.n_merge)
    assert demand <= free_top
    # nothing may stay stuck in a marked state
    status = np.asarray(vm.unpack_status(st_bat.rec_meta))
    alloc = np.asarray(st_bat.allocated)
    assert not ((status == 1) | (status == 2))[alloc].any()


def test_empty_and_stale_batch_is_noop():
    cfg = _mk_cfg("ubis")
    state, jobs = _marked_state(cfg, 4)
    # all-padding batch
    st, rr = _run_batched(state, cfg, [], bg_ops=4)
    assert int(rr.executed) == 0
    assert live_multiset(st, cfg) == live_multiset(state, cfg)
    # a stale op (posting not carrying the mark) is skipped
    unmarked = int(np.flatnonzero(np.asarray(
        vm.unpack_status(state.rec_meta)) == 0)[0])
    st2, rr2 = _run_batched(state, cfg, [("split", unmarked)], bg_ops=4)
    assert int(rr2.executed) == 0
    check_invariants(st2, cfg)


def test_double_marked_posting_never_wedges():
    """A full tile hollowed out by deletes is compact_due AND merge_due.
    If both lanes land in one batch (stale compact lane + deduped merge
    lane), neither executes — the rescue rule must revert the posting to
    NORMAL instead of leaving it marked forever.  Also exercised end to
    end through the driver, which must quiesce."""
    cfg = _mk_cfg("ubis")
    data = make_clustered(1500, d=cfg.dim, k=5, seed=21)
    drv = UBISDriver(cfg, data[:150], round_size=128, bg_ops_per_round=8)
    drv.insert(data, np.arange(1500), tick_between=False)
    state = drv.state
    used = np.asarray(state.used)
    status = np.asarray(vm.unpack_status(state.rec_meta))
    full = np.flatnonzero(np.asarray(state.allocated) & (status == 0)
                          & (used >= cfg.capacity))
    assert len(full), "no full tile in schedule"
    p = int(full[0])
    ids = np.asarray(state.ids)
    sv = np.asarray(state.slot_valid)
    live = ids[p][sv[p]]
    drv.delete(live[: len(live) - cfg.l_min + 1])  # now len < l_min
    state = drv.state
    sd, md, cd = (np.asarray(x) for x in balance.detect(state, cfg))
    assert cd[p] and md[p], "scenario must be compact_due AND merge_due"
    # adversarial: double-mark (compact then merge -> status MERGING)
    state = update.mark_status(state, jnp.asarray([p], jnp.int32),
                               STATUS_SPLITTING)
    state = update.mark_status(state, jnp.asarray([p], jnp.int32),
                               STATUS_MERGING)
    st2, rr = _run_batched(state, cfg, [("compact", p), ("merge", p)],
                           bg_ops=8)
    st_after = int(np.asarray(vm.unpack_status(st2.rec_meta))[p])
    assert st_after in (0, 3), f"posting wedged in status {st_after}"
    check_invariants(st2, cfg)
    # and through the driver: marking dedupes, flush quiesces unstuck
    ticks = drv.flush(max_ticks=60)
    assert ticks < 60
    status = np.asarray(vm.unpack_status(drv.state.rec_meta))
    alloc = np.asarray(drv.state.allocated)
    assert not (((status == 1) | (status == 2)) & alloc).any()


def test_cache_full_spill_folds_back_lossless():
    """Move-out spills that a full cache cannot hold must fold back into
    child a instead of vanishing with a dangling id_loc (the sequential
    oracle's latent flaw, fixed in the batched path)."""
    hit = False
    for seed in (31, 32, 33, 34):
        cfg = UBISConfig(dim=8, max_postings=128, capacity=64, l_min=6,
                         l_max=48, cache_capacity=8, balance_factor=0.45,
                         max_ids=1 << 13, use_pallas="off")
        state, jobs = _marked_state(cfg, seed)
        if not jobs:
            continue
        before = live_multiset(state, cfg)
        st, rr = _run_batched(state, cfg, jobs, bg_ops=8)
        check_invariants(st, cfg)   # catches any dangling id_loc
        assert live_multiset(st, cfg) == before
        hit = hit or int(rr.n_split) > 0
    assert hit, "no split executed across seeds — scenario too weak"


def test_all_compact_batch_skips_split_plan_but_executes():
    """The lax.cond gate on the 2-means/reassign matmuls must not change
    semantics: a batch of ONLY compacts executes, stays multiset-equal to
    the sequential oracle, and leaves every posting NORMAL."""
    cfg = _mk_cfg("ubis")
    state, jobs = _marked_state(cfg, 6)
    # strip the batch down to compact lanes only; unmark the rest so no
    # mark outlives the round
    compacts = [j for j in jobs if j[0] == "compact"]
    others = [p for k_, p in jobs if k_ != "compact"]
    if others:
        state = update.mark_status(state, jnp.asarray(others, jnp.int32), 0)
    if not compacts:  # synthesize: every marked split whose length fits
        compacts = [("compact", p) for k_, p in jobs if k_ == "split"]
    assert compacts, "no compact-able candidates in schedule"
    before = live_multiset(state, cfg)
    st_seq = sequential_execute(state, cfg, list(compacts))
    st_bat, rr = _run_batched(state, cfg, list(compacts), bg_ops=8)
    check_invariants(st_bat, cfg)
    assert live_multiset(st_bat, cfg) == before
    assert live_multiset(st_seq, cfg) == before
    assert int(rr.executed) > 0 and int(rr.n_split) == 0
    assert int(rr.reassigned) == 0 and int(rr.moved_out) == 0


def test_codebook_retrain_mid_stream_is_invisible():
    """Quant plane: a codebook re-train landing between a mark round and
    its execute round (the adversarial interleaving) never changes the
    live id->vector multiset, search visibility, or the structural
    invariants — and the executed round still matches the oracle."""
    import jax
    from repro.quant import pq
    cfg = UBISConfig(dim=8, max_postings=128, capacity=64, l_min=6,
                     l_max=48, cache_capacity=512, max_ids=1 << 13,
                     use_pallas="off", use_pq=True, pq_m=4, pq_ksub=32)
    state, jobs = _marked_state(cfg, 7)
    assert jobs
    before = live_multiset(state, cfg)
    vis_before = np.asarray(vm.visible(state.rec_meta, state.allocated,
                                       state.global_version))
    state2 = pq.retrain_round(state, cfg, jax.random.key(3))
    vis_after = np.asarray(vm.visible(state2.rec_meta, state2.allocated,
                                      state2.global_version))
    assert live_multiset(state2, cfg) == before
    np.testing.assert_array_equal(vis_before, vis_after)
    check_invariants(state2, cfg)
    # the marked batch still executes equivalently on the re-trained state
    st_seq = sequential_execute(state2, cfg, list(jobs))
    st_bat, rr = _run_batched(state2, cfg, list(jobs), bg_ops=8)
    check_invariants(st_seq, cfg)
    check_invariants(st_bat, cfg)
    assert live_multiset(st_bat, cfg) == before
    assert live_multiset(st_seq, cfg) == before
    assert int(rr.executed) > 0


def test_select_candidates_matches_detect():
    cfg = _mk_cfg("ubis")
    state, _ = _marked_state(cfg, 5)
    # unmark so select sees NORMAL postings again
    status = np.asarray(vm.unpack_status(state.rec_meta))
    marked = np.flatnonzero((status == 1) | (status == 2))
    if len(marked):
        state = update.mark_status(state, jnp.asarray(marked, jnp.int32), 0)
    kinds, pids = (np.asarray(x) for x in
                   balance.select_candidates(state, cfg, 8))
    split_due, merge_due, compact_due = (np.asarray(x) for x in
                                         balance.detect(state, cfg))
    due = split_due | merge_due | compact_due
    n_due = int(due.sum())
    assert (kinds != 0).sum() == min(8, n_due)
    for k, p in zip(kinds, pids):
        if k == 0:
            continue
        assert due[p]
        if k == KIND_SPLIT:
            assert split_due[p]
