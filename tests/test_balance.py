"""Balance Detector behaviour (paper IV-C): Alg. 1 semantics, the
Fig. 5 reproduction (SPFresh accumulates small postings; UBIS does not),
and the beyond-paper termination guard."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import UBISConfig, UBISDriver, balance
from repro.core import version_manager as vm
from conftest import make_clustered


def _live_lengths(state):
    status = np.asarray(vm.unpack_status(state.rec_meta))
    alive = np.asarray(state.allocated) & (status != 3)
    return np.asarray(state.lengths)[alive]


def test_split_preserves_members():
    cfg = UBISConfig(dim=8, max_postings=64, capacity=64, l_min=4,
                     l_max=48, max_ids=1 << 12, use_pallas="off")
    rng = np.random.default_rng(0)
    vecs = make_clustered(600, d=8, k=4, seed=2)
    drv = UBISDriver(cfg, vecs[:100], round_size=64, bg_ops_per_round=4)
    drv.insert(vecs[:400], np.arange(400))
    # force one split manually on the fullest posting
    lengths = np.asarray(drv.state.lengths)
    pid = int(np.argmax(lengths))
    if lengths[pid] > cfg.l_max:
        before = set(np.asarray(drv.state.ids[pid])[
            np.asarray(drv.state.slot_valid[pid])].tolist())
        from repro.core.update import mark_status
        from repro.core.types import STATUS_SPLITTING
        drv.state = mark_status(drv.state, jnp.array([pid]),
                                STATUS_SPLITTING)
        drv.state, new_pids = balance.balance_split(
            drv.state, cfg, jnp.asarray(pid, jnp.int32))
        # every member is findable afterwards (posting or cache)
        il = np.asarray(drv.state.id_loc)
        for i in before:
            assert il[i] != -1, f"id {i} lost by split"
        # parent retired with successor pointers
        status = np.asarray(vm.unpack_status(drv.state.rec_meta))
        assert status[pid] == 3
        s1, _ = vm.succ_ids(drv.state.rec_succ)
        assert int(np.asarray(s1)[pid]) >= 0


def test_termination_guard_halves_outlier_cluster():
    """A tight cluster + one outlier used to livelock the paper's Alg. 1
    (95/1 splits forever); the median-bisection guard halves it."""
    cfg = UBISConfig(dim=4, max_postings=32, capacity=64, l_min=4,
                     l_max=48, max_ids=1 << 10, use_pallas="off")
    rng = np.random.default_rng(1)
    tight = rng.normal(size=(60, 4)).astype(np.float32) * 0.01
    tight[0] += 50.0  # one outlier
    drv = UBISDriver(cfg, tight, round_size=64, bg_ops_per_round=2)
    drv.insert(tight, np.arange(60))
    from repro.core.update import mark_status
    from repro.core.types import STATUS_SPLITTING
    lengths = np.asarray(drv.state.lengths)
    pid = int(np.argmax(lengths))
    assert lengths[pid] > cfg.l_max
    drv.state = mark_status(drv.state, jnp.array([pid]), STATUS_SPLITTING)
    drv.state, new_pids = balance.balance_split(
        drv.state, cfg, jnp.asarray(pid, jnp.int32))
    new_lens = np.asarray(drv.state.lengths)[np.asarray(new_pids)]
    alloc = np.asarray(drv.state.allocated)[np.asarray(new_pids)]
    for ln, al in zip(new_lens, alloc):
        if al:
            assert ln <= cfg.l_max, "split did not reduce below l_max"


@pytest.mark.slow
def test_fig5_small_posting_accumulation():
    """The paper's Fig. 5: after streaming updates, SPFresh leaves a
    higher fraction of small postings than UBIS."""
    ratios = {}
    data = make_clustered(6000, d=12, k=24, seed=5)
    for mode in ("spfresh", "ubis"):
        cfg = UBISConfig(dim=12, max_postings=512, capacity=96, l_min=10,
                         l_max=80, cache_capacity=1024, max_ids=1 << 13,
                         use_pallas="off", mode=mode)
        drv = UBISDriver(cfg, data[:800], round_size=256,
                         bg_ops_per_round=8)
        for off in range(0, 6000, 1000):
            drv.insert(data[off:off + 1000], np.arange(off, off + 1000),
                       tick_between=True)
            # searches drive SPFresh's merge trigger
            drv.search(data[:64], 10)
            drv.tick()
        drv.flush(max_ticks=30)
        lens = _live_lengths(drv.state)
        lens = lens[lens > 0]
        ratios[mode] = float((lens < cfg.l_min).sum()) / max(len(lens), 1)
    assert ratios["ubis"] <= ratios["spfresh"] + 1e-9, ratios


def test_merge_absorbs_small_posting():
    cfg = UBISConfig(dim=8, max_postings=64, capacity=64, l_min=8,
                     l_max=48, max_ids=1 << 12, use_pallas="off")
    vecs = make_clustered(500, d=8, k=3, seed=7)
    drv = UBISDriver(cfg, vecs[:80], round_size=64, bg_ops_per_round=4)
    drv.insert(vecs, np.arange(500))
    drv.flush(max_ticks=40)
    lens = _live_lengths(drv.state)
    lens = lens[lens > 0]
    # after quiescence no posting sits below the merge threshold
    assert (lens >= cfg.l_min).all() or len(lens) <= 1, lens
