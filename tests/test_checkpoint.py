"""Checkpoint manager: atomic roundtrip, keep-N GC, resume extras,
elastic dtype/placement restore — plus the cold-tier snapshot contract
(a tiered index's snapshot round-trips through the checkpoint files and
restores to identical search answers)."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree


def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {"a": jnp.asarray(r.normal(size=(4, 8)).astype(np.float32)),
            "b": {"c": jnp.arange(5), "d": jnp.asarray(2.0)}}


def test_roundtrip(tmp_path):
    t = _tree()
    path = str(tmp_path / "ck")
    save_pytree(t, path, extra={"step": 7})
    out, extra = restore_pytree(t, path)
    assert extra["step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manager_keep_n_and_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for step in (10, 20, 30):
        mgr.save(step, _tree(step), extra={"stream": {"cursor": step}})
    assert mgr.all_steps() == [20, 30]
    step, tree, extra = mgr.restore_latest(_tree())
    assert step == 30 and extra["stream"]["cursor"] == 30
    leaves = jax.tree_util.tree_leaves(tree)
    ref = jax.tree_util.tree_leaves(_tree(30))
    np.testing.assert_allclose(np.asarray(leaves[0]), np.asarray(ref[0]))


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(1, _tree(1))
    mgr.wait()
    assert mgr.latest_step() == 1


def test_atomicity_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(5, _tree())
    files = os.listdir(tmp_path)
    assert not any(f.endswith(".tmp") for f in files)


def test_tiered_snapshot_roundtrips_through_checkpoint(tmp_path):
    """Cold-tier snapshot contract: ``snapshot()`` writes the spilled
    float tiles back into the saved pytree (flags stay set), so the
    checkpoint is self-contained; ``load_snapshot`` on a fresh driver
    re-derives residency and answers search IDENTICALLY — ids, scores,
    live multiset, and the device/host byte split all survive."""
    from repro.core import UBISConfig, UBISDriver

    rng = np.random.default_rng(2)
    cents = rng.normal(size=(8, 16)) * 6
    data = (cents[rng.integers(0, 8, 1200)]
            + rng.normal(size=(1200, 16))).astype(np.float32)
    cfg = UBISConfig(dim=16, max_postings=128, capacity=96, l_min=10,
                     l_max=80, nprobe=128, max_ids=1 << 13,
                     use_pallas="off", use_pq=True, pq_m=4, pq_ksub=16,
                     rerank_k=256, use_tier=True, tier_hot_max=8)
    drv = UBISDriver(cfg, data[:300], round_size=256, bg_ops_per_round=8)
    drv.insert(data, np.arange(1200))
    drv.flush(max_ticks=60)
    drv.force_spill(6)
    assert len(drv.tier.pool) > 0

    q = data[:24]
    s0 = drv.search(q, 10)
    snap = drv.snapshot()
    # spilled tiles are PRESENT in the snapshot (self-contained) while
    # the live state keeps them zeroed
    sp = np.flatnonzero(np.asarray(snap.tier_spilled))
    assert sp.size and np.asarray(snap.vectors)[sp].any()
    assert not np.asarray(drv.state.vectors)[sp].any()

    path = str(tmp_path / "tiered")
    save_pytree(snap, path, extra={"spilled": int(sp.size)})
    restored, extra = restore_pytree(snap, path)
    assert extra["spilled"] == sp.size

    drv2 = UBISDriver(cfg, data[:300], round_size=256,
                      bg_ops_per_round=8).load_snapshot(restored)
    assert len(drv2.tier.pool) == sp.size
    s1 = drv2.search(q, 10)
    np.testing.assert_array_equal(s0.ids, s1.ids)
    np.testing.assert_allclose(s0.scores, s1.scores, rtol=1e-5,
                               atol=1e-5)
    e0, e1 = drv.exact(q, 10), drv2.exact(q, 10)
    np.testing.assert_array_equal(np.asarray(e0.ids), np.asarray(e1.ids))
    assert drv2.memory_tiers() == drv.memory_tiers()
    assert drv2.live_count() == drv.live_count() == 1200
