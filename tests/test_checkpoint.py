"""Checkpoint manager: atomic roundtrip, keep-N GC, resume extras,
elastic dtype/placement restore."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree


def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {"a": jnp.asarray(r.normal(size=(4, 8)).astype(np.float32)),
            "b": {"c": jnp.arange(5), "d": jnp.asarray(2.0)}}


def test_roundtrip(tmp_path):
    t = _tree()
    path = str(tmp_path / "ck")
    save_pytree(t, path, extra={"step": 7})
    out, extra = restore_pytree(t, path)
    assert extra["step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manager_keep_n_and_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for step in (10, 20, 30):
        mgr.save(step, _tree(step), extra={"stream": {"cursor": step}})
    assert mgr.all_steps() == [20, 30]
    step, tree, extra = mgr.restore_latest(_tree())
    assert step == 30 and extra["stream"]["cursor"] == 30
    leaves = jax.tree_util.tree_leaves(tree)
    ref = jax.tree_util.tree_leaves(_tree(30))
    np.testing.assert_allclose(np.asarray(leaves[0]), np.asarray(ref[0]))


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(1, _tree(1))
    mgr.wait()
    assert mgr.latest_step() == 1


def test_atomicity_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(5, _tree())
    files = os.listdir(tmp_path)
    assert not any(f.endswith(".tmp") for f in files)


@pytest.mark.slow
def test_train_resume_continuity(tmp_path):
    """train.py resumes from checkpoint: run 6 steps, kill, resume to 10;
    the loss trajectory continues (data cursor restored)."""
    from repro.launch import train as train_mod
    ck = str(tmp_path / "run")
    train_mod.main(["--arch", "tinyllama-1.1b", "--reduced", "--steps",
                    "6", "--batch", "4", "--seq", "32", "--ckpt", ck,
                    "--ckpt-every", "3", "--log-every", "100"])
    mgr = CheckpointManager(ck)
    assert mgr.latest_step() == 6
    train_mod.main(["--arch", "tinyllama-1.1b", "--reduced", "--steps",
                    "10", "--batch", "4", "--seq", "32", "--ckpt", ck,
                    "--ckpt-every", "100", "--log-every", "100"])
    assert CheckpointManager(ck).latest_step() == 10
