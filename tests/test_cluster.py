"""Cluster plane tests: protocol codec, LocalBackend bit-identity vs
the in-process ShardedUBISDriver, straggler/kill/restart recovery, the
checkpoint manifest's loud failure modes, and (slow) the multi-process
backend: separate-process contract harness, Local==MultiProcess
equivalence, mid-stream worker kill, and 2-worker occupancy balance.
"""
import dataclasses
import io
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))
from contract_harness import live_map, make_clustered, run_program  # noqa: E402

from repro.api.sharded_driver import ShardedUBISDriver  # noqa: E402
from repro.checkpoint.manager import (ClusterManifestError,  # noqa: E402
                                      load_cluster_checkpoint)
from repro.cluster import (ClusterCoordinator, LocalBackend,  # noqa: E402
                           MultiProcessBackend, ProtocolError,
                           WorkerLost, combine_digests, plan_insert_split,
                           protocol)
from repro.core.types import UBISConfig  # noqa: E402
from repro.obs import Obs  # noqa: E402


def _cfg(**kw):
    base = dict(dim=16, max_postings=64, capacity=96, l_min=10, l_max=80,
                nprobe=64, max_ids=1 << 13, cache_capacity=2048,
                use_pallas="off")
    base.update(kw)
    return UBISConfig(**base)


TIER_KW = dict(use_pq=True, pq_m=4, pq_ksub=16, rerank_k=256,
               use_tier=True, tier_hot_max=8)


# ---------------------------------------------------------------- protocol


def test_codec_roundtrip_is_lossless():
    rng = np.random.default_rng(0)
    payload = {
        "f32": rng.standard_normal((3, 5)).astype(np.float32),
        "i64": rng.integers(-5, 5, 7),
        "bools": np.array([True, False]),
        "nested": {"x": np.arange(4, dtype=np.int32), "s": "hi",
                   "none": None, "f": 1.5, "list": [1, "a", None]},
        "scalar": np.float32(2.5),
    }
    msg = protocol.decode_message(
        protocol.encode_message("test", payload, 7))
    assert msg["kind"] == "test" and msg["seq"] == 7
    out = msg["payload"]
    assert out["f32"].tobytes() == payload["f32"].tobytes()
    assert out["f32"].dtype == np.float32
    assert np.array_equal(out["i64"], payload["i64"])
    assert np.array_equal(out["bools"], payload["bools"])
    assert np.array_equal(out["nested"]["x"], payload["nested"]["x"])
    assert out["nested"]["s"] == "hi" and out["nested"]["none"] is None
    assert out["nested"]["list"] == [1, "a", None]
    assert out["scalar"] == 2.5


def test_codec_rejects_foreign_schema_version():
    buf = protocol.encode_message("ping", {}, 1, v=protocol.SCHEMA_VERSION + 1)
    with pytest.raises(ProtocolError, match="schema version"):
        protocol.decode_message(buf)


def test_frame_roundtrip_and_truncation():
    bio = io.BytesIO()
    for seq in range(3):
        protocol.write_frame(bio, protocol.encode_message(
            "m", {"a": np.arange(seq + 1)}, seq))
    bio.seek(0)
    for seq in range(3):
        msg = protocol.decode_message(protocol.read_frame(bio))
        assert msg["seq"] == seq
        assert np.array_equal(msg["payload"]["a"], np.arange(seq + 1))
    assert protocol.read_frame(bio) is None           # clean EOF
    trunc = io.BytesIO(bio.getvalue()[:-3])           # mid-frame EOF
    trunc.read(0)
    protocol.read_frame(trunc)
    protocol.read_frame(trunc)
    with pytest.raises(ProtocolError):
        protocol.read_frame(trunc)


def test_digest_is_order_independent_and_combinable():
    rng = np.random.default_rng(1)
    sv = make_clustered(400)
    cfg = _cfg()
    drv = ShardedUBISDriver(cfg, sv[:100], round_size=128, seed=0)
    vecs, ids = sv[100:300], np.arange(200, dtype=np.int64)
    perm = rng.permutation(200)
    drv.insert(vecs[perm], ids[perm])
    d1 = protocol.live_multiset_digest(drv.snapshot())
    drv2 = ShardedUBISDriver(cfg, sv[:100], round_size=128, seed=1)
    drv2.insert(vecs, ids)
    assert d1 == protocol.live_multiset_digest(drv2.snapshot())
    assert combine_digests([d1, 0]) == d1
    assert combine_digests([d1, d1]) != d1


def test_plan_insert_split_waterfills():
    counts = plan_insert_split([100, 10, 10], 30)
    assert counts.sum() == 30
    assert counts[0] == 0 and counts[1] + counts[2] == 30
    assert abs(int(counts[1]) - int(counts[2])) <= 1
    counts = plan_insert_split([0, 0], 5)
    assert counts.tolist() == [3, 2]
    assert plan_insert_split([7, 3], 4).tolist() == [0, 4]
    big = plan_insert_split([5, 900, 40], 2000)
    assert big.sum() == 2000 and big.max() - big.min() <= 1 + 900 - 5


# --------------------------------------------- LocalBackend == in-process


def _interleaving(idx, data, seed, *, tiered=False):
    """Drive one seeded op tape; return per-op search results."""
    rng = np.random.default_rng(seed)
    out = []
    next_id = 0
    live = []
    for _ in range(10):
        op = rng.choice(["insert", "delete", "tick", "search"]
                        + (["spill", "promote"] if tiered else []))
        if op == "insert":
            n = int(rng.integers(8, 64))
            r = idx.insert(data[next_id:next_id + n],
                           np.arange(next_id, next_id + n))
            live.extend(range(next_id, next_id + n))
            next_id += n
            out.append(("insert", r.accepted, r.cached, r.rejected))
        elif op == "delete" and live:
            take = rng.choice(len(live), size=min(9, len(live)),
                              replace=False)
            ids = [live[i] for i in take]
            live = [x for x in live if x not in set(ids)]
            r = idx.delete(np.asarray(ids))
            out.append(("delete", r.deleted))
        elif op == "tick":
            r = idx.tick()
            out.append(("tick", r.executed, r.migrated, r.spilled,
                        r.promoted))
        elif op == "spill":
            out.append(("spill", idx.force_spill(int(rng.integers(1, 6)))))
        elif op == "promote":
            out.append(("promote", idx.force_promote()))
        else:
            q = data[rng.integers(0, next_id + 300, 6)]
            r = idx.search(q, 8)
            out.append(("search", np.asarray(r.ids).copy(),
                        np.asarray(r.scores).copy()))
    idx.flush()
    return out


def _assert_tapes_equal(a, b):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert ra[0] == rb[0]
        if ra[0] == "search":
            np.testing.assert_array_equal(ra[1], rb[1])
            np.testing.assert_array_equal(ra[2], rb[2])
        else:
            assert ra[1:] == rb[1:], (ra, rb)


@pytest.mark.parametrize("tiered", [False, True],
                         ids=["plain", "tiered"])
def test_local_w1_bit_identical_to_sharded_driver(tiered):
    cfg = _cfg(**(TIER_KW if tiered else {}))
    data = make_clustered(1600, seed=3)
    kw = dict(round_size=128, bg_ops_per_round=8, insert_retries=2,
              pq_retrain_every=4, seed=0)
    drv = ShardedUBISDriver(cfg, data[:200], **kw)
    coord = ClusterCoordinator(cfg, data[:200], workers=1,
                               backend="local", **kw)
    tape_a = _interleaving(drv, data[200:], 11, tiered=tiered)
    tape_b = _interleaving(coord, data[200:], 11, tiered=tiered)
    _assert_tapes_equal(tape_a, tape_b)
    sa, sb = drv.snapshot(), coord.snapshot()
    for f in dataclasses.fields(sa):
        np.testing.assert_array_equal(
            np.asarray(getattr(sa, f.name)),
            np.asarray(getattr(sb, f.name)), err_msg=f.name)
    assert (protocol.live_multiset_digest(sa)
            == protocol.live_multiset_digest(sb))
    coord.close()


# ------------------------------------------------------ failure plane


def test_straggler_rpc_fires_worker_slow_event():
    cfg = _cfg()
    sv = make_clustered(300, seed=5)
    obs = Obs()
    coord = ClusterCoordinator(cfg, sv, workers=1, backend="local",
                               round_size=128, obs=obs)
    # drop the build/compile RPCs from the EWMA: measure steady state
    from repro.distributed.straggler import StragglerMonitor
    coord.backend.monitors[0] = StragglerMonitor()
    for _ in range(6):                       # past monitor warmup
        coord.backend.call(0, "ping", {})
    coord.backend.call(0, "sleep", {"seconds": 0.25})
    slow = obs.events("worker_slow")
    assert slow and slow[-1]["command"] == "sleep"
    assert slow[-1]["seconds"] >= 0.25
    coord.close()


def test_worker_kill_recovers_via_journal_replay():
    cfg = _cfg()
    data = make_clustered(900, seed=7)
    obs = Obs()
    coord = ClusterCoordinator(cfg, data[:200], workers=1,
                               backend="local", round_size=128, obs=obs)
    coord.insert(data[200:500], np.arange(300))
    coord.delete(np.arange(40))
    coord.tick()
    before = protocol.live_multiset_digest(coord.snapshot())
    live_before = coord.live_count()
    coord.backend.kill_worker(0)
    with pytest.raises(WorkerLost):
        coord.backend.call(0, "ping", {})
    # next coordinator call trips WorkerLost -> restart -> replay
    assert coord.live_count() == live_before
    assert protocol.live_multiset_digest(coord.snapshot()) == before
    assert obs.events("worker_lost")
    restarts = obs.events("worker_restarted")
    assert restarts and restarts[-1]["replayed"] > 0
    assert not restarts[-1]["from_checkpoint"]
    coord.close()


def test_checkpoint_restore_and_kill_after_checkpoint(tmp_path):
    cfg = _cfg()
    data = make_clustered(900, seed=9)
    obs = Obs()
    coord = ClusterCoordinator(cfg, data[:200], workers=1,
                               backend="local", round_size=128, obs=obs)
    coord.insert(data[200:500], np.arange(300))
    coord.flush()
    manifest = coord.checkpoint(str(tmp_path / "ck"))
    assert manifest["n_workers"] == 1
    # post-checkpoint mutations live only in the journal
    coord.delete(np.arange(25))
    digest = protocol.live_multiset_digest(coord.snapshot())
    coord.backend.kill_worker(0)
    assert protocol.live_multiset_digest(coord.snapshot()) == digest
    assert obs.events("worker_restarted")[-1]["from_checkpoint"]
    # a fresh cluster restores the manifest exactly
    coord2 = ClusterCoordinator(cfg, data[:200], workers=1,
                                backend="local", round_size=128)
    coord2.restore(str(tmp_path / "ck"))
    assert (protocol.live_multiset_digest(coord2.snapshot())
            == manifest["combined_digest"])
    coord.close()
    coord2.close()


def test_partial_or_corrupt_checkpoint_fails_loudly(tmp_path):
    cfg = _cfg()
    data = make_clustered(600, seed=13)
    coord = ClusterCoordinator(cfg, data[:200], workers=1,
                               backend="local", round_size=128)
    coord.insert(data[200:400], np.arange(200))
    ck = str(tmp_path / "ck")
    coord.checkpoint(ck)
    coord.close()
    # no manifest at all (partial write)
    with pytest.raises(ClusterManifestError, match="manifest"):
        load_cluster_checkpoint(str(tmp_path / "empty"))
    # missing worker file
    import json
    import shutil
    broken = str(tmp_path / "broken")
    shutil.copytree(ck, broken)
    os.remove(os.path.join(broken, "worker_000.npz"))
    with pytest.raises(ClusterManifestError, match="missing"):
        load_cluster_checkpoint(broken)
    # digest mismatch (tampered manifest)
    tampered = str(tmp_path / "tampered")
    shutil.copytree(ck, tampered)
    mp = os.path.join(tampered, "manifest.json")
    with open(mp) as f:
        m = json.load(f)
    m["digests"][0] = (m["digests"][0] + 1) & 0xFFFFFFFFFFFFFFFF
    with open(mp, "w") as f:
        json.dump(m, f)
    with pytest.raises(ClusterManifestError, match="digest mismatch"):
        load_cluster_checkpoint(tampered)
    # foreign schema version
    foreign = str(tmp_path / "foreign")
    shutil.copytree(ck, foreign)
    mp = os.path.join(foreign, "manifest.json")
    with open(mp) as f:
        m = json.load(f)
    m["schema_version"] += 1
    with open(mp, "w") as f:
        json.dump(m, f)
    with pytest.raises(ClusterManifestError, match="schema"):
        load_cluster_checkpoint(foreign)
    # worker-count mismatch
    with pytest.raises(ClusterManifestError, match="workers"):
        load_cluster_checkpoint(ck, expect_workers=2)


# -------------------------------------------- multi-process (separate


def _mp_coord(cfg, seeds, *, workers=2, obs=None, **kw):
    kw.setdefault("round_size", 128)
    kw.setdefault("spread_per_tick", 64)
    return ClusterCoordinator(cfg, seeds, workers=workers,
                              backend="multiprocess", obs=obs, **kw)


@pytest.mark.slow
def test_contract_harness_across_processes():
    """The full random-interleaving contract with the coordinator here
    and the worker in a separate OS process."""
    cfg = _cfg(max_postings=128, nprobe=128)
    data = make_clustered(2600, seed=0)
    coord = _mp_coord(cfg, data[:300], workers=1, insert_retries=4)
    try:
        run_program("ubis-cluster", coord, data, 0, n_ops=10)
    finally:
        coord.close()


@pytest.mark.slow
def test_local_equals_multiprocess_on_seeded_stream():
    cfg = _cfg()
    data = make_clustered(1600, seed=21)
    kw = dict(round_size=128, seed=0, insert_retries=2)
    a = ClusterCoordinator(cfg, data[:200], workers=2, backend="local",
                           **kw)
    b = _mp_coord(cfg, data[:200], workers=2, **kw)
    try:
        tape_a = _interleaving(a, data[200:], 17)
        tape_b = _interleaving(b, data[200:], 17)
        _assert_tapes_equal(tape_a, tape_b)
        da = a.snapshot().digest
        db = b.snapshot().digest
        assert da == db
    finally:
        a.close()
        b.close()


@pytest.mark.slow
def test_multiprocess_worker_kill_midstream_preserves_multiset():
    cfg = _cfg()
    data = make_clustered(1400, seed=23)
    obs = Obs()
    coord = _mp_coord(cfg, data[:200], workers=2, obs=obs, seed=0)
    try:
        coord.insert(data[200:700], np.arange(500))
        coord.flush()
        before = coord.snapshot()
        coord.backend.kill_worker(0)          # SIGKILL mid-stream
        after = coord.snapshot()              # triggers recovery
        assert after.digest == before.digest
        assert obs.events("worker_lost")
        assert obs.events("worker_restarted")
        # the restarted worker still serves: recall vs exact merge
        q = data[300:320]
        found = coord.search(q, 8).ids
        true = coord.exact(q, 8).ids
        hits = sum(len(set(map(int, f)) & set(map(int, t)))
                   for f, t in zip(found, true))
        assert hits / true.size >= 0.9
    finally:
        coord.close()


@pytest.mark.slow
def test_two_workers_stay_occupancy_balanced_on_zipf_stream():
    """<=1.5 max/min live-vector ratio across 2 simulated hosts under a
    skewed (Zipfian-cluster) insert stream."""
    rng = np.random.default_rng(31)
    cfg = _cfg()
    # zipf-weighted cluster draw: most inserts land near few centroids
    cents = rng.normal(size=(20, 16)) * 5.0
    ranks = np.arange(1, 21, dtype=np.float64)
    pz = (1.0 / ranks ** 1.2)
    pz /= pz.sum()
    a = rng.choice(20, size=1200, p=pz)
    data = (cents[a] + rng.normal(size=(1200, 16))).astype(np.float32)
    coord = ClusterCoordinator(cfg, data[:200], workers=2,
                               backend="local", round_size=128,
                               spread_per_tick=64, seed=0)
    try:
        next_id = 0
        for _ in range(10):
            n = 100
            coord.insert(data[next_id:next_id + n],
                         np.arange(next_id, next_id + n))
            next_id += n
            coord.tick()
        live = coord.worker_live()
        assert live.min() > 0
        assert live.max() / live.min() <= 1.5, live
        assert coord.live_count() == next_id
    finally:
        coord.close()
