"""Property tests for the ``StreamingIndex`` engine contract.

One differential harness (``contract_harness.run_program``) drives a
seed-deterministic random interleaving of insert / delete / search /
tick / flush through EVERY ``make_index`` engine — the 1-shard-mesh
sharded driver included — asserting live-multiset equality against a
pure-Python oracle and a recall@k floor vs the engine's own ``exact()``
after every tick.  The quick suite runs one program per engine; with
``hypothesis`` installed a slow-marked fuzz layer draws more
(engine, seed) pairs from the same generator.

The multi-shard form of the same program (where the interleaving also
exercises the cross-shard migrate round) lives in ``test_rebalance.py``
— it needs a fake multi-device platform, hence a subprocess.
"""
import numpy as np
import pytest

from repro.api import ENGINES, engine_spec, list_engines, make_index
from repro.core import UBISConfig
from repro.serving import QueuedIndex

from contract_harness import make_clustered, run_program

DIM = 16
N_DATA = 2600


def _cfg(**kw):
    # nprobe = max_postings: searches probe everything, so the recall
    # floor measures the update plane's integrity, not probe luck
    base = dict(dim=DIM, max_postings=128, capacity=96, l_min=10,
                l_max=80, nprobe=128, max_ids=1 << 13,
                cache_capacity=2048, use_pallas="off")
    base.update(kw)
    return UBISConfig(**base)


# the cold-tier configuration the tiered interleavings run under: PQ on
# (spilled postings serve ADC-only) with a wide exact rerank, and a low
# device watermark so the planner spills aggressively mid-program
TIER_KW = dict(use_pq=True, pq_m=4, pq_ksub=16, rerank_k=256,
               use_tier=True, tier_hot_max=8)


def _build(engine, data, seed, cfg_kw=None):
    import jax
    n_seed = 300
    kw = dict(seed_ids=np.arange(n_seed), round_size=256,
              bg_ops_per_round=8, insert_retries=4, seed=seed,
              max_nodes=1 << 13, beam=24)
    if engine == "ubis-sharded":
        kw["mesh"] = jax.make_mesh((1, 1), ("data", "model"))
    idx = make_index(engine, _cfg(**(cfg_kw or {})), data[:n_seed], **kw)
    # build-once / graph engines ingest the seed corpus at construction
    # (the registry's audit tier encodes which semantics an engine has)
    seed_ids = (np.arange(n_seed)
                if engine_spec(engine).audit in ("static", "count")
                else None)
    return idx, seed_ids


def _run(engine, seed, cfg_kw=None, restore: bool = False,
         queued: bool = False):
    data = make_clustered(N_DATA, d=DIM, k=10, seed=100 + seed)
    idx, seed_ids = _build(engine, data, seed, cfg_kw)
    if queued:
        # every op rides the serving queue (submit -> drain -> resolve);
        # the oracle checks are unchanged, which is the proof the queue
        # adds scheduling, not semantics
        idx = QueuedIndex(idx)
    restore_fn = None
    if restore:
        def restore_fn(snap):
            idx2, _ = _build(engine, data, seed, cfg_kw)
            idx2 = idx2.load_snapshot(snap)
            return QueuedIndex(idx2) if queued else idx2
    oracle, stats = run_program(engine, idx, data, seed,
                                seed_ids=seed_ids, restore_fn=restore_fn)
    return stats


@pytest.mark.parametrize("engine", ENGINES)
def test_contract_random_interleaving(engine):
    stats = _run(engine, seed=0)
    assert stats["inserted"] > 0


# ---- cold-tier layer: the same program with tiering ON ----------------
# Every tier-capable engine (the UBISConfig-driven cluster engines —
# the build-once/graph baselines have no posting tiles to spill) runs
# the interleaving with forced spill/promote ops and the
# snapshot->restore equivalence check; the oracle checks are identical
# to the tiering-off runs above, which is the "indistinguishable from
# the all-float program" acceptance.  The tier-capable set comes from
# the registry's capability flags, not a hard-coded name tuple.
TIER_ENGINES = tuple(s.name for s in list_engines() if s.supports_tier)


@pytest.mark.parametrize("engine", TIER_ENGINES)
def test_contract_random_interleaving_tiered(engine):
    stats = _run(engine, seed=0, cfg_kw=TIER_KW, restore=True)
    assert stats["inserted"] > 0


@pytest.mark.slow
@pytest.mark.parametrize("engine", TIER_ENGINES)
@pytest.mark.parametrize("seed", [1, 2])
def test_contract_random_interleaving_tiered_more_seeds(engine, seed):
    _run(engine, seed, cfg_kw=TIER_KW, restore=True)


@pytest.mark.slow
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("seed", [1, 2])
def test_contract_random_interleaving_more_seeds(engine, seed):
    _run(engine, seed)


# ---- serving-queue layer: the same programs through the queue ---------
# ``QueuedIndex`` submits every op to a ServingEngine and drains, so the
# whole differential harness (oracle multiset, recall floors, tier
# transitions, snapshot->restore) runs with requests folded into padded
# batches by the fill-or-deadline scheduler.

@pytest.mark.parametrize("engine", ENGINES)
def test_contract_through_serving_queue(engine):
    stats = _run(engine, seed=0, queued=True)
    assert stats["inserted"] > 0


@pytest.mark.parametrize("engine", ("ubis", "ubis-sharded"))
def test_contract_through_serving_queue_tiered(engine):
    stats = _run(engine, seed=0, cfg_kw=TIER_KW, restore=True,
                 queued=True)
    assert stats["inserted"] > 0


# ---- hypothesis layer (skips gracefully when not installed) ----------
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @pytest.mark.slow
    @settings(max_examples=6, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(engine=st.sampled_from(ENGINES), seed=st.integers(3, 2 ** 12))
    def test_contract_random_interleaving_fuzz(engine, seed):
        _run(engine, seed)

    @pytest.mark.slow
    @settings(max_examples=4, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(engine=st.sampled_from(TIER_ENGINES),
           seed=st.integers(3, 2 ** 12))
    def test_contract_tiered_fuzz(engine, seed):
        _run(engine, seed, cfg_kw=TIER_KW, restore=True)
except ImportError:  # pragma: no cover - hypothesis is optional
    pass
