"""Data pipeline: determinism, cursor resume, host sharding, drift."""
import numpy as np

from repro.data import DriftingVectorStream, StaticVectorSet, TokenStream


def test_token_stream_deterministic_and_resumable():
    a = TokenStream(vocab=100, seq_len=16, batch_per_host=4, seed=1)
    b1 = [a.next_batch() for _ in range(3)]
    # resume from cursor 1
    b = TokenStream(vocab=100, seq_len=16, batch_per_host=4, seed=1)
    b.load_state_dict({"cursor": 1, "seed": 1, "host_index": 0})
    b2 = b.next_batch()
    np.testing.assert_array_equal(b1[1]["tokens"], b2["tokens"])


def test_token_stream_host_disjoint():
    a = TokenStream(vocab=100, seq_len=16, batch_per_host=4, seed=1,
                    host_index=0, num_hosts=2)
    b = TokenStream(vocab=100, seq_len=16, batch_per_host=4, seed=1,
                    host_index=1, num_hosts=2)
    assert not np.array_equal(a.next_batch()["tokens"],
                              b.next_batch()["tokens"])


def test_targets_are_next_tokens():
    s = TokenStream(vocab=50, seq_len=8, batch_per_host=2, seed=0)
    b = s.next_batch()
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_drifting_stream_drifts():
    s = DriftingVectorStream(dim=8, n_clusters=4, seed=0)
    first = s.next_batch(256)
    for _ in range(30):
        last = s.next_batch(256)
    # distribution shift: mean distance between batch centroids grows
    d = np.linalg.norm(first.mean(0) - last.mean(0))
    assert d > 0.5, d


def test_static_set_batches_cover_all():
    s = StaticVectorSet(n=1000, dim=8, seed=0)
    seen = np.concatenate([idx for idx, _ in s.batches(10)])
    assert len(np.unique(seen)) == 1000
