"""Multi-device tests: run in a subprocess with 8 fake CPU devices so
the main pytest process keeps its single-device platform."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(ROOT, "src"),
               TF_CPP_MIN_LOG_LEVEL="2")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=540)
    assert r.returncode == 0, f"STDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"
    return r.stdout


@pytest.mark.slow
def test_sharded_ubis_matches_single_device():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.core import UBISConfig, UBISDriver, brute_force, metrics
        from repro.core.sharded import (index_specs, make_sharded_insert,
                                        make_sharded_search)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = UBISConfig(dim=16, max_postings=256, capacity=96,
                         max_ids=1 << 14, use_pallas="off")
        r = np.random.default_rng(1)
        cents = r.normal(size=(12, 16)) * 5
        data = (cents[r.integers(0, 12, 3000)]
                + r.normal(size=(3000, 16))).astype(np.float32)
        drv = UBISDriver(cfg, data[:500], round_size=256,
                         bg_ops_per_round=8)
        drv.insert(data[:2000], np.arange(2000)); drv.flush()
        sh = jax.tree_util.tree_map(
            lambda sp: NamedSharding(mesh, sp), index_specs(cfg),
            is_leaf=lambda x: isinstance(x, PartitionSpec))
        st = jax.device_put(drv.state, sh)
        search = make_sharded_search(cfg, mesh, k=10)
        q = (cents[r.integers(0, 12, 64)]
             + r.normal(size=(64, 16))).astype(np.float32)
        found, _ = search(st, jnp.asarray(q))
        true, _ = brute_force(drv.state, cfg, jnp.asarray(q), 10)
        rec = metrics.recall_at_k(np.asarray(found), np.asarray(true))
        assert rec > 0.95, rec
        ins = make_sharded_insert(cfg, mesh)
        nv = (cents[r.integers(0, 12, 128)]
              + r.normal(size=(128, 16))).astype(np.float32)
        st2, accm, routed = ins(st, jnp.asarray(nv),
                                jnp.arange(2000, 2128, dtype=jnp.int32),
                                jnp.ones(128, bool))
        accm = np.asarray(accm)
        routed = np.asarray(routed)
        assert accm.shape == (128,)
        assert int(accm.sum()) > 64
        # routed pids are GLOBAL and in range wherever a job landed
        assert ((routed[accm] >= 0) & (routed[accm] < 256)).all()
        found2, _ = search(st2, jnp.asarray(nv[:32]))
        hits = sum(2000 + i in set(f.tolist())
                   for i, f in enumerate(np.asarray(found2)))
        assert hits >= 30, hits
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_search_pq_phase2():
    """With cfg.use_pq, the sharded search's phase 2 is served from the
    PQ codes (per-shard ADC scan + exact rerank); recall vs the float
    brute force stays high and the float sharded path agrees."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.core import UBISConfig, UBISDriver, brute_force, metrics
        from repro.core.sharded import index_specs, make_sharded_search
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = UBISConfig(dim=16, max_postings=256, capacity=96,
                         max_ids=1 << 14, use_pallas="off", use_pq=True,
                         pq_m=4, pq_ksub=32, rerank_k=128)
        r = np.random.default_rng(2)
        cents = r.normal(size=(10, 16)) * 6
        data = (cents[r.integers(0, 10, 2500)]
                + r.normal(size=(2500, 16))).astype(np.float32)
        drv = UBISDriver(cfg, data[:500], round_size=256,
                         bg_ops_per_round=8)
        drv.insert(data, np.arange(2500)); drv.flush()
        sh = jax.tree_util.tree_map(
            lambda sp: NamedSharding(mesh, sp), index_specs(cfg),
            is_leaf=lambda x: isinstance(x, PartitionSpec))
        st = jax.device_put(drv.state, sh)
        q = (cents[r.integers(0, 10, 64)]
             + r.normal(size=(64, 16))).astype(np.float32)
        found_pq, _ = make_sharded_search(cfg, mesh, k=10)(
            st, jnp.asarray(q))
        true, _ = brute_force(drv.state, cfg, jnp.asarray(q), 10)
        rec = metrics.recall_at_k(np.asarray(found_pq), np.asarray(true))
        # apples to apples: the sharded ADC path must not trail the
        # single-device ADC path (it reranks rerank_k PER SHARD, so it
        # usually leads slightly); coarse m=4 codes cap both ~0.88
        found_1 = drv.search(q, 10).ids
        rec_1 = metrics.recall_at_k(np.asarray(found_1),
                                    np.asarray(true))
        assert rec >= rec_1 - 0.02, (rec, rec_1)
        assert rec > 0.8, rec
        # the float sharded path on the same state stays exact-grade
        cfg_f = dataclasses.replace(cfg, use_pq=False)
        found_f, _ = make_sharded_search(cfg_f, mesh, k=10)(
            st, jnp.asarray(q))
        rec_f = metrics.recall_at_k(np.asarray(found_f),
                                    np.asarray(true))
        assert rec_f > 0.95, rec_f
        print("OK", rec, rec_1, rec_f)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_background_round_splits_and_stays_consistent():
    """The batched background round, shard-mapped: per-shard detect ->
    select -> execute in one collective-free device call; oversize
    postings come down, ids are never lost or duplicated, and the
    replicated id map stays in sync after the psum merge."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.core import UBISConfig, UBISDriver
        from repro.core import version_manager as vm
        from repro.core.sharded import index_specs, make_sharded_background
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = UBISConfig(dim=16, max_postings=256, capacity=96,
                         max_ids=1 << 14, use_pallas="off")
        r = np.random.default_rng(1)
        cents = r.normal(size=(12, 16)) * 5
        data = (cents[r.integers(0, 12, 3000)]
                + r.normal(size=(3000, 16))).astype(np.float32)
        drv = UBISDriver(cfg, data[:500], round_size=256,
                         bg_ops_per_round=8)
        # no ticks: leave oversize postings for the background plane
        drv.insert(data[:2500], np.arange(2500), tick_between=False)
        pre_over = int((np.asarray(drv.state.lengths) > cfg.l_max).sum())
        assert pre_over > 0, "schedule built no oversize postings"
        sh = jax.tree_util.tree_map(
            lambda sp: NamedSharding(mesh, sp), index_specs(cfg),
            is_leaf=lambda x: isinstance(x, PartitionSpec))
        st = jax.device_put(drv.state, sh)
        bg = make_sharded_background(cfg, mesh, bg_ops=8)
        total = 0
        for _ in range(12):
            st, ex, _gc, press = bg(st, jnp.uint32(0))
            total += int(ex)
            if int(ex) == 0:
                break
        assert total > 0
        # pressure rows: one per shard, live+free bounded by the pool
        press = np.asarray(press)
        assert press.shape == (4, 4)
        assert (press[:, 0] + press[:, 1] <= 64).all()
        assert press[:, 0].sum() > 0
        # a quiescent tick must round-trip rec_succ EXACTLY — the
        # entry-localize/exit-rebase may only rewrite words the round
        # touched (cross-shard successor pointers survive untouched)
        st2, ex2, _gc2, _p2 = bg(st, jnp.uint32(0))
        assert int(ex2) == 0
        assert (np.asarray(jax.device_get(st).rec_succ)
                == np.asarray(jax.device_get(st2).rec_succ)).all()
        st = st2
        full = jax.device_get(st)
        status = np.asarray(vm.unpack_status(full.rec_meta))
        vis = np.asarray(full.allocated) & (status != 3)
        lens = np.asarray(full.lengths)
        assert (lens[vis] <= cfg.l_max).all(), lens[vis].max()
        # audit: live ids (postings + cache) == id_loc, no duplicates
        ids = np.asarray(full.ids); sv = np.asarray(full.slot_valid)
        where = {}
        for p in np.flatnonzero(vis):
            for c in np.flatnonzero(sv[p]):
                i = int(ids[p, c])
                assert i not in where, f"dup id {i}"
                where[i] = p * cfg.capacity + c
        cv = np.asarray(full.cache_valid)
        ci = np.asarray(full.cache_ids)
        for s in np.flatnonzero(cv):
            where[int(ci[s])] = -2 - s
        il = np.asarray(full.id_loc)
        tracked = {int(i): int(il[i]) for i in np.flatnonzero(il != -1)}
        assert tracked == where, (len(tracked), len(where))
        # successor pointers must be GLOBAL pids after gather: every
        # retired posting's successors land on allocated postings
        s1, s2 = (np.asarray(x) for x in vm.succ_ids(full.rec_succ))
        alloc = np.asarray(full.allocated)
        retired = np.flatnonzero(alloc & (status == 3))
        assert len(retired), "no retirements despite executed ops"
        n_succ = 0
        for p in retired:
            for s in (int(s1[p]), int(s2[p])):
                if s >= 0:
                    n_succ += 1
                    assert alloc[s], f"successor {s} of {p} not allocated"
        assert n_succ > 0
        # exit free stack is fail-safe empty; rebuild restores the
        # canonical single-device invariant
        assert int(full.free_top) == 0
        from repro.core.update import rebuild_free_stack
        full = rebuild_free_stack(full)
        top = int(full.free_top)
        free = np.asarray(full.free_list)[:top]
        alloc = np.asarray(full.allocated)
        assert len(np.unique(free)) == top
        assert not alloc[free].any()
        assert top + alloc.sum() == cfg.max_postings
        print("OK", total, "ops")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_driver_end_to_end_multishard():
    """ShardedUBISDriver on a real 4-shard mesh: the full protocol
    surface (insert with retries, sharded deletes, search, ticks with
    in-round GC, flush, canonical snapshot) with an id->vector audit."""
    out = _run("""
        import numpy as np, jax
        from repro.api import ShardedUBISDriver
        from repro.core import UBISConfig
        from repro.core import version_manager as vm
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = UBISConfig(dim=16, max_postings=256, capacity=96,
                         max_ids=1 << 14, use_pallas="off")
        r = np.random.default_rng(3)
        cents = r.normal(size=(12, 16)) * 5
        data = (cents[r.integers(0, 12, 4000)]
                + r.normal(size=(4000, 16))).astype(np.float32)
        drv = ShardedUBISDriver(cfg, data[:500], mesh=mesh,
                                round_size=256, bg_ops_per_round=8,
                                gc_lag=4)
        res = drv.insert(data, np.arange(4000))
        assert res.accepted + res.cached == 4000, res
        drv.delete(np.arange(0, 600))
        drv.flush(max_ticks=40)
        # everything streamed minus deletes is live, exactly once
        st = drv.snapshot()       # asserts canonical free stack
        status = np.asarray(vm.unpack_status(st.rec_meta))
        vis = np.asarray(st.allocated) & (status != 3)
        ids = np.asarray(st.ids); sv = np.asarray(st.slot_valid)
        live = set()
        for p in np.flatnonzero(vis):
            for c in np.flatnonzero(sv[p]):
                i = int(ids[p, c])
                assert i not in live, f"dup id {i}"
                live.add(i)
        cv = np.asarray(st.cache_valid)
        live |= {int(i) for i in np.asarray(st.cache_ids)[cv]}
        assert live == set(range(600, 4000)), (
            len(live), min(live), max(live))
        # oversize postings all came down; GC reclaimed retirees
        lens = np.asarray(st.lengths)
        assert (lens[vis] <= cfg.l_max).all()
        assert drv.stats["bg_gc"] > 0, "in-round GC never reclaimed"
        # search quality vs exact truth over the live contents
        from repro.core import metrics
        q = (cents[r.integers(0, 12, 64)]
             + r.normal(size=(64, 16))).astype(np.float32)
        found = drv.search(q, 10).ids
        true = drv.exact(q, 10).ids
        rec = metrics.recall_at_k(np.asarray(found), np.asarray(true))
        assert rec > 0.95, rec
        print("OK", len(live), "live")
    """)
    assert "OK" in out


