"""Index-level workload on the PALLAS kernel path (interpret=True on
CPU) — not the jnp reference the index normally dispatches to off-TPU.

This is the ROADMAP "run the kernel path periodically" item: the weekly
``kernels-interpret`` CI job runs it (marked slow, so the per-PR quick
suite skips it).  The kernels are alignment-free, so BOTH an aligned
config (d=128, C=128, ksub=256) and a deliberately misaligned one
(d=100, odd C, non-power-of-two ksub) exercise the same fused Pallas
``centroid_topk``, ``posting_scan_topk``, ``pq_scan_topk`` and
``rerank_topk`` kernels (plus ``posting_scan``/``centroid_score`` via
the exact oracle) end to end through the driver — no fallback gates.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import UBISConfig, UBISDriver, brute_force, metrics
from conftest import make_clustered

pytestmark = pytest.mark.slow


@pytest.mark.parametrize("use_pq", [False, True])
@pytest.mark.parametrize("dim,capacity,pq_m,ksub", [(128, 128, 8, 256),
                                                    (100, 96, 10, 100)])
def test_driver_workload_on_pallas_interpret(use_pq, dim, capacity, pq_m,
                                             ksub):
    cfg = UBISConfig(dim=dim, max_postings=64, capacity=capacity, l_min=8,
                     l_max=int(capacity * 0.75), cache_capacity=256,
                     max_ids=1 << 12, nprobe=8, use_pallas="pallas",
                     use_pq=use_pq, pq_m=pq_m, pq_ksub=ksub, rerank_k=64)
    data = make_clustered(700, d=cfg.dim, k=5, seed=2)
    drv = UBISDriver(cfg, data[:200], round_size=128, bg_ops_per_round=4,
                     pq_retrain_every=3)
    drv.insert(data, np.arange(700))
    drv.delete(np.arange(0, 120))
    drv.flush(max_ticks=12)
    assert drv.stats["bg_ops"] > 0, "workload exercised no structural ops"
    q = make_clustered(8, d=cfg.dim, k=5, seed=7)
    found = drv.search(q, 10).ids
    true, _ = brute_force(drv.state, cfg, jnp.asarray(q), 10)
    rec = metrics.recall_at_k(found, np.asarray(true))
    floor = 0.8 if use_pq else 0.9
    assert rec > floor, rec
