"""Index-level workload on the PALLAS kernel path (interpret=True on
CPU) — not the jnp reference the index normally dispatches to off-TPU.

This is the ROADMAP "run the kernel path periodically" item: the weekly
``kernels-interpret`` CI job runs it (marked slow, so the per-PR quick
suite skips it).  Shapes satisfy every kernel-path alignment gate:
dim % 128 == 0, capacity % 128 == 0, pq_ksub % 128 == 0 — so search
exercises the fused Pallas ``centroid_topk``, ``posting_scan_topk``
and ``pq_scan_topk`` kernels (plus ``posting_scan``/``centroid_score``
via the exact oracle) end to end through the driver.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import UBISConfig, UBISDriver, brute_force, metrics
from conftest import make_clustered

pytestmark = pytest.mark.slow


@pytest.mark.parametrize("use_pq", [False, True])
def test_driver_workload_on_pallas_interpret(use_pq):
    cfg = UBISConfig(dim=128, max_postings=64, capacity=128, l_min=8,
                     l_max=96, cache_capacity=256, max_ids=1 << 12,
                     nprobe=8, use_pallas="pallas", use_pq=use_pq,
                     pq_m=8, pq_ksub=256, rerank_k=64)
    data = make_clustered(700, d=cfg.dim, k=5, seed=2)
    drv = UBISDriver(cfg, data[:200], round_size=128, bg_ops_per_round=4,
                     pq_retrain_every=3)
    drv.insert(data, np.arange(700))
    drv.delete(np.arange(0, 120))
    drv.flush(max_ticks=12)
    assert drv.stats["bg_ops"] > 0, "workload exercised no structural ops"
    q = make_clustered(8, d=cfg.dim, k=5, seed=7)
    found = drv.search(q, 10).ids
    true, _ = brute_force(drv.state, cfg, jnp.asarray(q), 10)
    rec = metrics.recall_at_k(found, np.asarray(true))
    floor = 0.8 if use_pq else 0.9
    assert rec > floor, rec
