"""Property-based system invariants (hypothesis): under ANY interleaving
of inserts / deletes / background ticks / searches, the index never
loses, duplicates, or fabricates a vector, and the structural counters
stay consistent.

These are the distributed-systems guarantees the paper's CAS +
version-manager design is supposed to provide; here they are checked
mechanically over randomized schedules for BOTH modes (ubis/spfresh).
"""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import UBISConfig, UBISDriver
from repro.core import version_manager as vm

settings.register_profile("sys", max_examples=8, deadline=None)
settings.load_profile("sys")

DIM = 8


def _mk_cfg(mode):
    return UBISConfig(dim=DIM, max_postings=256, capacity=64, l_min=4,
                      l_max=48, cache_capacity=512, max_ids=1 << 13,
                      use_pallas="off", mode=mode)


def audit(state, cfg):
    """Returns (locations dict id->where, duplicates count)."""
    status = np.asarray(vm.unpack_status(state.rec_meta))
    alloc = np.asarray(state.allocated)
    vis = alloc & (status != 3)
    ids = np.asarray(state.ids)
    sv = np.asarray(state.slot_valid)
    where, dup = {}, 0
    for p in np.flatnonzero(vis):
        for c in np.flatnonzero(sv[p]):
            i = int(ids[p, c])
            if i in where:
                dup += 1
            where[i] = ("post", p, c)
    cv = np.asarray(state.cache_valid)
    ci = np.asarray(state.cache_ids)
    for s in np.flatnonzero(cv):
        i = int(ci[s])
        if i in where:
            dup += 1
        where[i] = ("cache", s)
    return where, dup


def check_all(state, cfg, live_ids):
    where, dup = audit(state, cfg)
    assert dup == 0, "duplicated vector"
    # id_loc agreement: every id the map knows is where the map says
    il = np.asarray(state.id_loc)
    tracked = set(int(i) for i in np.flatnonzero(il != -1))
    assert tracked == set(where), (
        f"id_loc tracks {len(tracked)} ids but audit found {len(where)}")
    # no externally-live id may be missing unless it was rejected
    assert set(where) <= live_ids
    # counters: lengths == live slots per visible posting
    status = np.asarray(vm.unpack_status(state.rec_meta))
    alloc = np.asarray(state.allocated)
    sv = np.asarray(state.slot_valid)
    lengths = np.asarray(state.lengths)
    used = np.asarray(state.used)
    for p in np.flatnonzero(alloc & (status != 3)):
        assert lengths[p] == sv[p].sum(), f"length mismatch at {p}"
        assert used[p] >= lengths[p]
        assert used[p] <= cfg.capacity


@pytest.mark.parametrize("mode", ["ubis", "spfresh"])
@given(data=st.data())
def test_random_schedule_invariants(mode, data):
    cfg = _mk_cfg(mode)
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 16)))
    seed_vecs = rng.normal(size=(200, DIM)).astype(np.float32) * 4
    drv = UBISDriver(cfg, seed_vecs, round_size=64, bg_ops_per_round=4,
                     insert_retries=1)
    next_id = 0
    live = set()
    ops_seq = data.draw(st.lists(
        st.sampled_from(["insert", "delete", "tick", "search"]),
        min_size=4, max_size=12))
    for op in ops_seq:
        if op == "insert":
            n = int(rng.integers(1, 120))
            vecs = rng.normal(size=(n, DIM)).astype(np.float32) * 4
            ids = np.arange(next_id, next_id + n)
            next_id += n
            res = drv.insert(vecs, ids, tick_between=False)
            live |= set(int(i) for i in ids)
            # rejected ids are NOT live (caller owns retry)
            il = np.asarray(drv.state.id_loc)
            for i in ids:
                if il[i] == -1:
                    live.discard(int(i))
        elif op == "delete" and live:
            k = min(len(live), int(rng.integers(1, 40)))
            dels = rng.choice(sorted(live), size=k, replace=False)
            drv.delete(dels)
            # SPFresh's lock model BLOCKS deletes on non-NORMAL postings;
            # only ids the index actually dropped leave the live set
            il = np.asarray(drv.state.id_loc)
            live -= {int(x) for x in dels if il[int(x)] == -1}
        elif op == "tick":
            drv.tick()
        elif op == "search":
            q = rng.normal(size=(8, DIM)).astype(np.float32)
            found = drv.search(q, 5).ids
            # results only contain live ids
            for f in found.ravel():
                assert f == -1 or int(f) in live
        check_all(drv.state, cfg, live)
    drv.flush(max_ticks=50)
    check_all(drv.state, cfg, live)


def test_free_list_integrity():
    """Posting ids on the free list are unique and unallocated."""
    cfg = _mk_cfg("ubis")
    rng = np.random.default_rng(3)
    vecs = rng.normal(size=(3000, DIM)).astype(np.float32) * 4
    drv = UBISDriver(cfg, vecs[:500], round_size=128, bg_ops_per_round=8,
                     gc_lag=4)
    drv.insert(vecs, np.arange(3000))
    drv.flush(max_ticks=60)
    st_ = drv.state
    top = int(st_.free_top)
    free = np.asarray(st_.free_list)[:top]
    assert len(np.unique(free)) == top, "duplicate ids on free list"
    alloc = np.asarray(st_.allocated)
    assert not alloc[free].any(), "allocated posting on free list"
    # every posting is either allocated or on the free list
    assert top + alloc.sum() == cfg.max_postings
