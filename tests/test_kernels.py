"""Per-kernel correctness: Pallas (interpret=True on CPU) vs ref.py
oracles, swept over shapes and dtypes."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

SHAPES_QM = [(8, 16, 16), (17, 33, 40), (128, 512, 64), (130, 700, 96)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("Q,M,d", SHAPES_QM)
@pytest.mark.parametrize("dtype", DTYPES)
def test_centroid_score(Q, M, d, dtype, rng):
    q = jnp.asarray(rng.normal(size=(Q, d)), dtype)
    c = jnp.asarray(rng.normal(size=(M, d)), dtype)
    vis = jnp.asarray(rng.random(M) > 0.3)
    a = ops.centroid_score(q, c, vis, backend="ref")
    b = ops.centroid_score(q, c, vis, backend="pallas")
    np.testing.assert_allclose(a, b, **_tol(dtype))


@pytest.mark.parametrize("Q,G,C,d", [(5, 3, 24, 16), (64, 8, 96, 40),
                                     (16, 4, 128, 128)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_posting_scan(Q, G, C, d, dtype, rng):
    q = jnp.asarray(rng.normal(size=(Q, d)), dtype)
    tiles = jnp.asarray(rng.normal(size=(G, C, d)), dtype)
    valid = jnp.asarray(rng.random((G, C)) > 0.4)
    a = ops.posting_scan(q, tiles, valid, backend="ref")
    b = ops.posting_scan(q, tiles, valid, backend="pallas")
    np.testing.assert_allclose(a, b, **_tol(dtype))


@pytest.mark.parametrize("Q,M,C,P,d", [(6, 12, 128, 4, 128)])
def test_posting_scan_gather(Q, M, C, P, d, rng):
    q = jnp.asarray(rng.normal(size=(Q, d)).astype(np.float32))
    vectors = jnp.asarray(rng.normal(size=(M, C, d)).astype(np.float32))
    slot_valid = jnp.asarray(rng.random((M, C)) > 0.3)
    vis = jnp.asarray(rng.random(M) > 0.2)
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    a = ops.posting_scan_gather(q, vectors, slot_valid, vis, probe,
                                backend="ref")
    b = ops.posting_scan_gather(q, vectors, slot_valid, vis, probe,
                                backend="pallas")
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("Q,V,m,ksub,M,C,P", [(6, 2, 8, 128, 12, 128, 4),
                                              (3, 3, 4, 256, 9, 128, 5)])
def test_pq_scan_gather(Q, V, m, ksub, M, C, P, rng):
    from repro.kernels.pq_scan import pq_scan_gather as pallas_pq
    luts = jnp.asarray(rng.normal(size=(Q, V, m, ksub)).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, ksub, (M, m, C)).astype(np.uint8))
    slot = jnp.asarray(rng.integers(0, V, (M,)).astype(np.int32))
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    a = ref.pq_scan_gather(luts, codes, slot, probe)
    b = pallas_pq(luts, codes, slot, probe, interpret=True)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
    # dispatch wrapper applies the validity mask identically per backend
    slot_valid = jnp.asarray(rng.random((M, C)) > 0.3)
    vis = jnp.asarray(rng.random(M) > 0.2)
    w1 = ops.pq_scan_gather(luts, codes, slot, slot_valid, vis, probe,
                            backend="ref")
    w2 = ops.pq_scan_gather(luts, codes, slot, slot_valid, vis, probe,
                            backend="pallas")
    np.testing.assert_allclose(w1, w2, rtol=1e-5, atol=1e-5)


def test_pq_scan_matches_decoded_float_scan(rng):
    """ADC scores equal the float scan over the *decoded* vectors —
    the semantic contract between the quant plane and the float plane."""
    from repro.quant import pq
    Q, m, dsub, ksub, M, C, P = 4, 4, 3, 16, 8, 24, 3
    d = m * dsub
    cb = jnp.asarray(rng.normal(size=(1, m, ksub, dsub)).astype(np.float32))
    vecs = jnp.asarray(rng.normal(size=(M * C, d)).astype(np.float32))
    codes = pq.encode(cb[0], vecs)
    decoded = pq.decode(cb[0], codes)
    q = jnp.asarray(rng.normal(size=(Q, d)).astype(np.float32))
    luts = pq.lookup_tables(cb, q)
    codes_t = codes.reshape(M, C, m).transpose(0, 2, 1)
    slot = jnp.zeros((M,), jnp.int32)
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    adc = ref.pq_scan_gather(luts, codes_t, slot, probe)
    want = ref.posting_scan_gather(
        q, decoded.reshape(M, C, d), jnp.ones((M, C), bool),
        jnp.ones((M,), bool), probe)
    np.testing.assert_allclose(adc, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("N,K,d", [(10, 3, 8), (50, 7, 19), (256, 128, 64),
                                   (300, 130, 40)])
def test_kmeans_assign(N, K, d, rng):
    pts = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    cen = jnp.asarray(rng.normal(size=(K, d)).astype(np.float32))
    mask = jnp.asarray(rng.random(N) > 0.2)
    a1, b1 = ops.kmeans_assign(pts, cen, mask, backend="ref")
    a2, b2 = ops.kmeans_assign(pts, cen, mask, backend="pallas")
    # argmin ties can differ; compare scores, and assignments where the
    # best score is unique
    np.testing.assert_allclose(b1, b2, rtol=1e-4, atol=1e-3)
    same = np.asarray(a1) == np.asarray(a2)
    assert same.mean() > 0.99


@pytest.mark.parametrize("Lq,Lk,D,Hq,Hkv", [(37, 53, 16, 4, 2),
                                            (64, 64, 32, 2, 2),
                                            (16, 128, 64, 8, 1)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 9])
def test_flash_attention(Lq, Lk, D, Hq, Hkv, causal, window, rng):
    B = 2
    q = jnp.asarray(rng.normal(size=(B, Hq, Lq, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Hkv, Lk, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, Lk, D)).astype(np.float32))
    a = ops.flash_attention(q, k, v, causal=causal, window=window,
                            backend="ref")
    b = ops.flash_attention(q, k, v, causal=causal, window=window,
                            backend="pallas")
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Fused score+select kernels: the pallas path must be BIT-identical to
# the ref twin (scores and indices), including tie order — integer-
# valued float32 data makes every sum exact and ties frequent.
# ---------------------------------------------------------------------------


def _int_normal(rng, shape, lo=-3, hi=4):
    return jnp.asarray(rng.integers(lo, hi, shape).astype(np.float32))


@pytest.mark.parametrize("Q,M,d,k", [(1, 7, 5, 3), (9, 33, 24, 33),
                                     (128, 512, 64, 16), (5, 130, 16, 10)])
def test_centroid_topk_parity(Q, M, d, k, rng):
    q = _int_normal(rng, (Q, d))
    c = _int_normal(rng, (M, d))
    vis = jnp.asarray(rng.random(M) > 0.3)
    s1, i1 = ops.centroid_topk(q, c, vis, k=k, backend="ref")
    s2, i2 = ops.centroid_topk(q, c, vis, k=k, backend="pallas")
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_centroid_topk_all_masked(rng):
    """No visible centroid: every score is the BIG sentinel and both
    backends agree on the (degenerate) index order."""
    q = _int_normal(rng, (4, 8))
    c = _int_normal(rng, (12, 8))
    vis = jnp.zeros((12,), bool)
    s1, i1 = ops.centroid_topk(q, c, vis, k=5, backend="ref")
    s2, i2 = ops.centroid_topk(q, c, vis, k=5, backend="pallas")
    assert np.all(np.asarray(s1) >= ref.BIG / 2)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_centroid_topk_ties(rng):
    """Duplicate centroids: ties must break lowest-index-first on both
    backends (the lax.top_k discipline)."""
    q = _int_normal(rng, (6, 16))
    base = _int_normal(rng, (8, 16))
    c = jnp.concatenate([base, base, base], axis=0)  # every score x3
    vis = jnp.ones((24,), bool)
    s1, i1 = ops.centroid_topk(q, c, vis, k=24, backend="ref")
    s2, i2 = ops.centroid_topk(q, c, vis, k=24, backend="pallas")
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@pytest.mark.parametrize("Q,M,C,P,d,k", [(1, 12, 128, 1, 128, 5),
                                         (6, 12, 128, 4, 128, 17),
                                         (3, 9, 128, 5, 128, 128)])
def test_posting_scan_topk_parity(Q, M, C, P, d, k, rng):
    q = _int_normal(rng, (Q, d))
    vectors = _int_normal(rng, (M, C, d), lo=-2, hi=3)
    slot_valid = jnp.asarray(rng.random((M, C)) > 0.3)
    vis = jnp.asarray(rng.random(M) > 0.2)
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    s1, i1 = ops.posting_scan_topk(q, vectors, slot_valid, vis, probe,
                                   k=k, backend="ref")
    s2, i2 = ops.posting_scan_topk(q, vectors, slot_valid, vis, probe,
                                   k=k, backend="pallas")
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_posting_scan_topk_sparse_and_qp_ok(rng):
    """k beyond the live-candidate count: the tail is BIG on both
    backends; a per-(query, probe) ownership mask is honoured."""
    Q, M, C, P, d = 4, 6, 128, 3, 128
    q = _int_normal(rng, (Q, d))
    vectors = _int_normal(rng, (M, C, d), lo=-2, hi=3)
    slot_valid = jnp.asarray(rng.random((M, C)) > 0.95)  # ~6 live per tile
    vis = jnp.ones((M,), bool)
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    qp_ok = jnp.asarray(rng.integers(0, 2, (Q, P)).astype(np.int32))
    k = P * C  # every candidate requested
    s1, i1 = ops.posting_scan_topk(q, vectors, slot_valid, vis, probe,
                                   k=k, qp_ok=qp_ok, backend="ref")
    s2, i2 = ops.posting_scan_topk(q, vectors, slot_valid, vis, probe,
                                   k=k, qp_ok=qp_ok, backend="pallas")
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    assert np.any(np.asarray(s1) >= ref.BIG / 2)  # sparse -> BIG tail


@pytest.mark.parametrize("Q,V,m,ksub,M,C,P,k", [(1, 2, 8, 128, 12, 128, 1, 3),
                                                (6, 2, 8, 128, 12, 128, 4, 20),
                                                (3, 3, 4, 256, 9, 128, 5, 64)])
def test_pq_scan_topk_parity(Q, V, m, ksub, M, C, P, k, rng):
    luts = _int_normal(rng, (Q, V, m, ksub), lo=0, hi=8)
    codes = jnp.asarray(rng.integers(0, ksub, (M, m, C)).astype(np.uint8))
    slot = jnp.asarray(rng.integers(0, V, (M,)).astype(np.int32))
    slot_valid = jnp.asarray(rng.random((M, C)) > 0.3)
    vis = jnp.asarray(rng.random(M) > 0.2)
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    s1, i1 = ops.pq_scan_topk(luts, codes, slot, slot_valid, vis, probe,
                              k=k, backend="ref")
    s2, i2 = ops.pq_scan_topk(luts, codes, slot, slot_valid, vis, probe,
                              k=k, backend="pallas")
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_pq_scan_topk_all_invalid(rng):
    """Every probed posting invisible: scores are all BIG and the
    degenerate candidate order still matches the ref twin."""
    Q, V, m, ksub, M, C, P, k = 3, 2, 4, 128, 8, 128, 3, 7
    luts = _int_normal(rng, (Q, V, m, ksub), lo=0, hi=8)
    codes = jnp.asarray(rng.integers(0, ksub, (M, m, C)).astype(np.uint8))
    slot = jnp.zeros((M,), jnp.int32)
    slot_valid = jnp.ones((M, C), bool)
    vis = jnp.zeros((M,), bool)
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    s1, i1 = ops.pq_scan_topk(luts, codes, slot, slot_valid, vis, probe,
                              k=k, backend="ref")
    s2, i2 = ops.pq_scan_topk(luts, codes, slot, slot_valid, vis, probe,
                              k=k, backend="pallas")
    assert np.all(np.asarray(s1) >= ref.BIG / 2)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_kmeans_assign_large_nonmultiple_k(rng):
    """K > 128 and not a multiple of the 128-lane tile, mask=None: the
    sentinel-row padding must never win an assignment."""
    N, K, d = 64, 200, 24
    pts = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    cen = jnp.asarray(rng.normal(size=(K, d)).astype(np.float32))
    a1, b1 = ops.kmeans_assign(pts, cen, backend="ref")
    a2, b2 = ops.kmeans_assign(pts, cen, backend="pallas")
    np.testing.assert_allclose(b1, b2, rtol=1e-4, atol=1e-3)
    assert np.all(np.asarray(a2) < K)
    same = np.asarray(a1) == np.asarray(a2)
    assert same.mean() > 0.99


def test_kernel_fallback_observability(rng):
    """A pallas-backend request with misaligned storage shapes serves
    the ref path AND reports it: counter bump per dispatch, one trace
    event per (kernel, reason)."""
    from repro.obs import Obs
    obs = Obs()
    ops.observe_fallbacks(obs)
    Q, V, m, ksub, M, C, P = 2, 1, 2, 16, 4, 24, 2  # C, ksub misaligned
    luts = jnp.asarray(rng.normal(size=(Q, V, m, ksub)).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, ksub, (M, m, C)).astype(np.uint8))
    slot = jnp.zeros((M,), jnp.int32)
    slot_valid = jnp.ones((M, C), bool)
    vis = jnp.ones((M,), bool)
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    ops.pq_scan_gather(luts, codes, slot, slot_valid, vis, probe,
                       backend="pallas")
    assert obs.counter("kernel_fallback").value == 1.0
    evs = obs.events("kernel_fallback")
    assert len(evs) == 1 and evs[0]["kernel"] == "pq_scan_gather"
    # repeat dispatch: counter counts every fallback, the trace event
    # stays one-per-(kernel, reason)
    ops.pq_scan_gather(luts, codes, slot, slot_valid, vis, probe,
                       backend="pallas")
    assert obs.counter("kernel_fallback").value == 2.0
    assert len(obs.events("kernel_fallback")) == 1
    # a different kernel falling back emits its own event
    q = jnp.asarray(rng.normal(size=(Q, 24)).astype(np.float32))
    vecs = jnp.asarray(rng.normal(size=(M, C, 24)).astype(np.float32))
    ops.posting_scan_topk(q, vecs, slot_valid, vis, probe, k=3,
                          backend="pallas")
    assert obs.counter("kernel_fallback").value == 3.0
    assert len(obs.events("kernel_fallback")) == 2
    # aligned pallas dispatch does NOT report a fallback
    before = obs.counter("kernel_fallback").value
    qa = jnp.asarray(rng.normal(size=(2, 128)).astype(np.float32))
    va = jnp.asarray(rng.normal(size=(4, 128, 128)).astype(np.float32))
    ops.posting_scan_topk(qa, va, jnp.ones((4, 128), bool),
                          jnp.ones((4,), bool),
                          jnp.zeros((2, 2), jnp.int32), k=3,
                          backend="pallas")
    assert obs.counter("kernel_fallback").value == before


def test_flash_attention_matches_chunked(rng):
    """The pure-JAX chunked attention (model fast path) agrees with the
    kernel oracle."""
    from repro.models.attention import chunked_attention, local_attention
    B, Hq, Hkv, L, D = 2, 4, 2, 96, 16
    q = jnp.asarray(rng.normal(size=(B, Hq, L, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Hkv, L, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, L, D)).astype(np.float32))
    a = ref.flash_attention(q, k, v, causal=True)
    b = chunked_attention(q, k, v, causal=True, chunk_q=32, chunk_k=32,
                          backend="off")
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
    # windowed: blocked-local path vs masked reference
    w = 32
    a = ref.flash_attention(q, k, v, causal=True, window=w)
    b = local_attention(q, k, v, window=w, backend="off")
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
