"""Per-kernel correctness: Pallas (interpret=True on CPU) vs ref.py
oracles, swept over shapes and dtypes."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

SHAPES_QM = [(8, 16, 16), (17, 33, 40), (128, 512, 64), (130, 700, 96)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("Q,M,d", SHAPES_QM)
@pytest.mark.parametrize("dtype", DTYPES)
def test_centroid_score(Q, M, d, dtype, rng):
    q = jnp.asarray(rng.normal(size=(Q, d)), dtype)
    c = jnp.asarray(rng.normal(size=(M, d)), dtype)
    vis = jnp.asarray(rng.random(M) > 0.3)
    a = ops.centroid_score(q, c, vis, backend="ref")
    b = ops.centroid_score(q, c, vis, backend="pallas")
    np.testing.assert_allclose(a, b, **_tol(dtype))


@pytest.mark.parametrize("Q,G,C,d", [(5, 3, 24, 16), (64, 8, 96, 40),
                                     (16, 4, 128, 128)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_posting_scan(Q, G, C, d, dtype, rng):
    q = jnp.asarray(rng.normal(size=(Q, d)), dtype)
    tiles = jnp.asarray(rng.normal(size=(G, C, d)), dtype)
    valid = jnp.asarray(rng.random((G, C)) > 0.4)
    a = ops.posting_scan(q, tiles, valid, backend="ref")
    b = ops.posting_scan(q, tiles, valid, backend="pallas")
    np.testing.assert_allclose(a, b, **_tol(dtype))


@pytest.mark.parametrize("Q,M,C,P,d", [(6, 12, 128, 4, 128)])
def test_posting_scan_gather(Q, M, C, P, d, rng):
    q = jnp.asarray(rng.normal(size=(Q, d)).astype(np.float32))
    vectors = jnp.asarray(rng.normal(size=(M, C, d)).astype(np.float32))
    slot_valid = jnp.asarray(rng.random((M, C)) > 0.3)
    vis = jnp.asarray(rng.random(M) > 0.2)
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    a = ops.posting_scan_gather(q, vectors, slot_valid, vis, probe,
                                backend="ref")
    b = ops.posting_scan_gather(q, vectors, slot_valid, vis, probe,
                                backend="pallas")
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("Q,V,m,ksub,M,C,P", [(6, 2, 8, 128, 12, 128, 4),
                                              (3, 3, 4, 256, 9, 128, 5)])
def test_pq_scan_gather(Q, V, m, ksub, M, C, P, rng):
    from repro.kernels.pq_scan import pq_scan_gather as pallas_pq
    luts = jnp.asarray(rng.normal(size=(Q, V, m, ksub)).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, ksub, (M, m, C)).astype(np.uint8))
    slot = jnp.asarray(rng.integers(0, V, (M,)).astype(np.int32))
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    a = ref.pq_scan_gather(luts, codes, slot, probe)
    b = pallas_pq(luts, codes, slot, probe, interpret=True)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
    # dispatch wrapper applies the validity mask identically per backend
    slot_valid = jnp.asarray(rng.random((M, C)) > 0.3)
    vis = jnp.asarray(rng.random(M) > 0.2)
    w1 = ops.pq_scan_gather(luts, codes, slot, slot_valid, vis, probe,
                            backend="ref")
    w2 = ops.pq_scan_gather(luts, codes, slot, slot_valid, vis, probe,
                            backend="pallas")
    np.testing.assert_allclose(w1, w2, rtol=1e-5, atol=1e-5)


def test_pq_scan_matches_decoded_float_scan(rng):
    """ADC scores equal the float scan over the *decoded* vectors —
    the semantic contract between the quant plane and the float plane."""
    from repro.quant import pq
    Q, m, dsub, ksub, M, C, P = 4, 4, 3, 16, 8, 24, 3
    d = m * dsub
    cb = jnp.asarray(rng.normal(size=(1, m, ksub, dsub)).astype(np.float32))
    vecs = jnp.asarray(rng.normal(size=(M * C, d)).astype(np.float32))
    codes = pq.encode(cb[0], vecs)
    decoded = pq.decode(cb[0], codes)
    q = jnp.asarray(rng.normal(size=(Q, d)).astype(np.float32))
    luts = pq.lookup_tables(cb, q)
    codes_t = codes.reshape(M, C, m).transpose(0, 2, 1)
    slot = jnp.zeros((M,), jnp.int32)
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    adc = ref.pq_scan_gather(luts, codes_t, slot, probe)
    want = ref.posting_scan_gather(
        q, decoded.reshape(M, C, d), jnp.ones((M, C), bool),
        jnp.ones((M,), bool), probe)
    np.testing.assert_allclose(adc, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("N,K,d", [(10, 3, 8), (50, 7, 19), (256, 128, 64),
                                   (300, 130, 40)])
def test_kmeans_assign(N, K, d, rng):
    pts = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    cen = jnp.asarray(rng.normal(size=(K, d)).astype(np.float32))
    mask = jnp.asarray(rng.random(N) > 0.2)
    a1, b1 = ops.kmeans_assign(pts, cen, mask, backend="ref")
    a2, b2 = ops.kmeans_assign(pts, cen, mask, backend="pallas")
    # argmin ties can differ; compare scores, and assignments where the
    # best score is unique
    np.testing.assert_allclose(b1, b2, rtol=1e-4, atol=1e-3)
    same = np.asarray(a1) == np.asarray(a2)
    assert same.mean() > 0.99


@pytest.mark.parametrize("Lq,Lk,D,Hq,Hkv", [(37, 53, 16, 4, 2),
                                            (64, 64, 32, 2, 2),
                                            (16, 128, 64, 8, 1)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 9])
def test_flash_attention(Lq, Lk, D, Hq, Hkv, causal, window, rng):
    B = 2
    q = jnp.asarray(rng.normal(size=(B, Hq, Lq, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Hkv, Lk, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, Lk, D)).astype(np.float32))
    a = ops.flash_attention(q, k, v, causal=causal, window=window,
                            backend="ref")
    b = ops.flash_attention(q, k, v, causal=causal, window=window,
                            backend="pallas")
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_flash_attention_matches_chunked(rng):
    """The pure-JAX chunked attention (model fast path) agrees with the
    kernel oracle."""
    from repro.models.attention import chunked_attention, local_attention
    B, Hq, Hkv, L, D = 2, 4, 2, 96, 16
    q = jnp.asarray(rng.normal(size=(B, Hq, L, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Hkv, L, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, L, D)).astype(np.float32))
    a = ref.flash_attention(q, k, v, causal=True)
    b = chunked_attention(q, k, v, causal=True, chunk_q=32, chunk_k=32,
                          backend="off")
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
    # windowed: blocked-local path vs masked reference
    w = 32
    a = ref.flash_attention(q, k, v, causal=True, window=w)
    b = local_attention(q, k, v, window=w, backend="off")
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
