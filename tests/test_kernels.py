"""Per-kernel correctness: Pallas (interpret=True on CPU) vs ref.py
oracles, swept over shapes and dtypes."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

SHAPES_QM = [(8, 16, 16), (17, 33, 40), (128, 512, 64), (130, 700, 96)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("Q,M,d", SHAPES_QM)
@pytest.mark.parametrize("dtype", DTYPES)
def test_centroid_score(Q, M, d, dtype, rng):
    q = jnp.asarray(rng.normal(size=(Q, d)), dtype)
    c = jnp.asarray(rng.normal(size=(M, d)), dtype)
    vis = jnp.asarray(rng.random(M) > 0.3)
    a = ops.centroid_score(q, c, vis, backend="ref")
    b = ops.centroid_score(q, c, vis, backend="pallas")
    np.testing.assert_allclose(a, b, **_tol(dtype))


@pytest.mark.parametrize("Q,G,C,d", [(5, 3, 24, 16), (64, 8, 96, 40),
                                     (16, 4, 128, 128)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_posting_scan(Q, G, C, d, dtype, rng):
    q = jnp.asarray(rng.normal(size=(Q, d)), dtype)
    tiles = jnp.asarray(rng.normal(size=(G, C, d)), dtype)
    valid = jnp.asarray(rng.random((G, C)) > 0.4)
    a = ops.posting_scan(q, tiles, valid, backend="ref")
    b = ops.posting_scan(q, tiles, valid, backend="pallas")
    np.testing.assert_allclose(a, b, **_tol(dtype))


@pytest.mark.parametrize("Q,M,C,P,d", [(6, 12, 128, 4, 128)])
def test_posting_scan_gather(Q, M, C, P, d, rng):
    q = jnp.asarray(rng.normal(size=(Q, d)).astype(np.float32))
    vectors = jnp.asarray(rng.normal(size=(M, C, d)).astype(np.float32))
    slot_valid = jnp.asarray(rng.random((M, C)) > 0.3)
    vis = jnp.asarray(rng.random(M) > 0.2)
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    a = ops.posting_scan_gather(q, vectors, slot_valid, vis, probe,
                                backend="ref")
    b = ops.posting_scan_gather(q, vectors, slot_valid, vis, probe,
                                backend="pallas")
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("Q,V,m,ksub,M,C,P", [(6, 2, 8, 128, 12, 128, 4),
                                              (3, 3, 4, 256, 9, 128, 5)])
def test_pq_scan_gather(Q, V, m, ksub, M, C, P, rng):
    from repro.kernels.pq_scan import pq_scan_gather as pallas_pq
    luts = jnp.asarray(rng.normal(size=(Q, V, m, ksub)).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, ksub, (M, m, C)).astype(np.uint8))
    slot = jnp.asarray(rng.integers(0, V, (M,)).astype(np.int32))
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    a = ref.pq_scan_gather(luts, codes, slot, probe)
    b = pallas_pq(luts, codes, slot, probe, interpret=True)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
    # dispatch wrapper applies the validity mask identically per backend
    slot_valid = jnp.asarray(rng.random((M, C)) > 0.3)
    vis = jnp.asarray(rng.random(M) > 0.2)
    w1 = ops.pq_scan_gather(luts, codes, slot, slot_valid, vis, probe,
                            backend="ref")
    w2 = ops.pq_scan_gather(luts, codes, slot, slot_valid, vis, probe,
                            backend="pallas")
    np.testing.assert_allclose(w1, w2, rtol=1e-5, atol=1e-5)


def test_pq_scan_matches_decoded_float_scan(rng):
    """ADC scores equal the float scan over the *decoded* vectors —
    the semantic contract between the quant plane and the float plane."""
    from repro.quant import pq
    Q, m, dsub, ksub, M, C, P = 4, 4, 3, 16, 8, 24, 3
    d = m * dsub
    cb = jnp.asarray(rng.normal(size=(1, m, ksub, dsub)).astype(np.float32))
    vecs = jnp.asarray(rng.normal(size=(M * C, d)).astype(np.float32))
    codes = pq.encode(cb[0], vecs)
    decoded = pq.decode(cb[0], codes)
    q = jnp.asarray(rng.normal(size=(Q, d)).astype(np.float32))
    luts = pq.lookup_tables(cb, q)
    codes_t = codes.reshape(M, C, m).transpose(0, 2, 1)
    slot = jnp.zeros((M,), jnp.int32)
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    adc = ref.pq_scan_gather(luts, codes_t, slot, probe)
    want = ref.posting_scan_gather(
        q, decoded.reshape(M, C, d), jnp.ones((M, C), bool),
        jnp.ones((M,), bool), probe)
    np.testing.assert_allclose(adc, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("N,K,d", [(10, 3, 8), (50, 7, 19), (256, 128, 64),
                                   (300, 130, 40)])
def test_kmeans_assign(N, K, d, rng):
    pts = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    cen = jnp.asarray(rng.normal(size=(K, d)).astype(np.float32))
    mask = jnp.asarray(rng.random(N) > 0.2)
    a1, b1 = ops.kmeans_assign(pts, cen, mask, backend="ref")
    a2, b2 = ops.kmeans_assign(pts, cen, mask, backend="pallas")
    # argmin ties can differ; compare scores, and assignments where the
    # best score is unique
    np.testing.assert_allclose(b1, b2, rtol=1e-4, atol=1e-3)
    same = np.asarray(a1) == np.asarray(a2)
    assert same.mean() > 0.99


@pytest.mark.parametrize("Lq,Lk,D,Hq,Hkv", [(37, 53, 16, 4, 2),
                                            (64, 64, 32, 2, 2),
                                            (16, 128, 64, 8, 1)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 9])
def test_flash_attention(Lq, Lk, D, Hq, Hkv, causal, window, rng):
    B = 2
    q = jnp.asarray(rng.normal(size=(B, Hq, Lq, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Hkv, Lk, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, Lk, D)).astype(np.float32))
    a = ops.flash_attention(q, k, v, causal=causal, window=window,
                            backend="ref")
    b = ops.flash_attention(q, k, v, causal=causal, window=window,
                            backend="pallas")
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Fused score+select kernels: the pallas path must be BIT-identical to
# the ref twin (scores and indices), including tie order — integer-
# valued float32 data makes every sum exact and ties frequent.
# ---------------------------------------------------------------------------


def _int_normal(rng, shape, lo=-3, hi=4):
    return jnp.asarray(rng.integers(lo, hi, shape).astype(np.float32))


@pytest.mark.parametrize("Q,M,d,k", [(1, 7, 5, 3), (9, 33, 24, 33),
                                     (128, 512, 64, 16), (5, 130, 16, 10)])
def test_centroid_topk_parity(Q, M, d, k, rng):
    q = _int_normal(rng, (Q, d))
    c = _int_normal(rng, (M, d))
    vis = jnp.asarray(rng.random(M) > 0.3)
    s1, i1 = ops.centroid_topk(q, c, vis, k=k, backend="ref")
    s2, i2 = ops.centroid_topk(q, c, vis, k=k, backend="pallas")
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_centroid_topk_all_masked(rng):
    """No visible centroid: every score is the BIG sentinel and both
    backends agree on the (degenerate) index order."""
    q = _int_normal(rng, (4, 8))
    c = _int_normal(rng, (12, 8))
    vis = jnp.zeros((12,), bool)
    s1, i1 = ops.centroid_topk(q, c, vis, k=5, backend="ref")
    s2, i2 = ops.centroid_topk(q, c, vis, k=5, backend="pallas")
    assert np.all(np.asarray(s1) >= ref.BIG / 2)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_centroid_topk_ties(rng):
    """Duplicate centroids: ties must break lowest-index-first on both
    backends (the lax.top_k discipline)."""
    q = _int_normal(rng, (6, 16))
    base = _int_normal(rng, (8, 16))
    c = jnp.concatenate([base, base, base], axis=0)  # every score x3
    vis = jnp.ones((24,), bool)
    s1, i1 = ops.centroid_topk(q, c, vis, k=24, backend="ref")
    s2, i2 = ops.centroid_topk(q, c, vis, k=24, backend="pallas")
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@pytest.mark.parametrize("Q,M,C,P,d,k", [(1, 12, 128, 1, 128, 5),
                                         (6, 12, 128, 4, 128, 17),
                                         (3, 9, 128, 5, 128, 128)])
def test_posting_scan_topk_parity(Q, M, C, P, d, k, rng):
    q = _int_normal(rng, (Q, d))
    vectors = _int_normal(rng, (M, C, d), lo=-2, hi=3)
    slot_valid = jnp.asarray(rng.random((M, C)) > 0.3)
    vis = jnp.asarray(rng.random(M) > 0.2)
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    s1, i1 = ops.posting_scan_topk(q, vectors, slot_valid, vis, probe,
                                   k=k, backend="ref")
    s2, i2 = ops.posting_scan_topk(q, vectors, slot_valid, vis, probe,
                                   k=k, backend="pallas")
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_posting_scan_topk_sparse_and_qp_ok(rng):
    """k beyond the live-candidate count: the tail is BIG on both
    backends; a per-(query, probe) ownership mask is honoured."""
    Q, M, C, P, d = 4, 6, 128, 3, 128
    q = _int_normal(rng, (Q, d))
    vectors = _int_normal(rng, (M, C, d), lo=-2, hi=3)
    slot_valid = jnp.asarray(rng.random((M, C)) > 0.95)  # ~6 live per tile
    vis = jnp.ones((M,), bool)
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    qp_ok = jnp.asarray(rng.integers(0, 2, (Q, P)).astype(np.int32))
    k = P * C  # every candidate requested
    s1, i1 = ops.posting_scan_topk(q, vectors, slot_valid, vis, probe,
                                   k=k, qp_ok=qp_ok, backend="ref")
    s2, i2 = ops.posting_scan_topk(q, vectors, slot_valid, vis, probe,
                                   k=k, qp_ok=qp_ok, backend="pallas")
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    assert np.any(np.asarray(s1) >= ref.BIG / 2)  # sparse -> BIG tail


@pytest.mark.parametrize("Q,V,m,ksub,M,C,P,k", [(1, 2, 8, 128, 12, 128, 1, 3),
                                                (6, 2, 8, 128, 12, 128, 4, 20),
                                                (3, 3, 4, 256, 9, 128, 5, 64)])
def test_pq_scan_topk_parity(Q, V, m, ksub, M, C, P, k, rng):
    luts = _int_normal(rng, (Q, V, m, ksub), lo=0, hi=8)
    codes = jnp.asarray(rng.integers(0, ksub, (M, m, C)).astype(np.uint8))
    slot = jnp.asarray(rng.integers(0, V, (M,)).astype(np.int32))
    slot_valid = jnp.asarray(rng.random((M, C)) > 0.3)
    vis = jnp.asarray(rng.random(M) > 0.2)
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    s1, i1 = ops.pq_scan_topk(luts, codes, slot, slot_valid, vis, probe,
                              k=k, backend="ref")
    s2, i2 = ops.pq_scan_topk(luts, codes, slot, slot_valid, vis, probe,
                              k=k, backend="pallas")
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_pq_scan_topk_all_invalid(rng):
    """Every probed posting invisible: scores are all BIG and the
    degenerate candidate order still matches the ref twin."""
    Q, V, m, ksub, M, C, P, k = 3, 2, 4, 128, 8, 128, 3, 7
    luts = _int_normal(rng, (Q, V, m, ksub), lo=0, hi=8)
    codes = jnp.asarray(rng.integers(0, ksub, (M, m, C)).astype(np.uint8))
    slot = jnp.zeros((M,), jnp.int32)
    slot_valid = jnp.ones((M, C), bool)
    vis = jnp.zeros((M,), bool)
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    s1, i1 = ops.pq_scan_topk(luts, codes, slot, slot_valid, vis, probe,
                              k=k, backend="ref")
    s2, i2 = ops.pq_scan_topk(luts, codes, slot, slot_valid, vis, probe,
                              k=k, backend="pallas")
    assert np.all(np.asarray(s1) >= ref.BIG / 2)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


# -- alignment-free sweeps: real-world dims (96/100/300), odd posting
# capacities and non-power-of-two ksub must serve the SAME fused pallas
# path bit-identically — no silent slow-path, no fallback (PR 10).


@pytest.mark.parametrize("Q,M,C,P,d,k", [(1, 7, 33, 3, 96, 5),
                                         (4, 9, 100, 4, 100, 40),
                                         (3, 6, 133, 5, 300, 17)])
def test_posting_scan_topk_misaligned_parity(Q, M, C, P, d, k, rng):
    q = _int_normal(rng, (Q, d))
    vectors = _int_normal(rng, (M, C, d), lo=-2, hi=3)
    slot_valid = jnp.asarray(rng.random((M, C)) > 0.3)
    vis = jnp.asarray(rng.random(M) > 0.2)
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    s1, i1 = ops.posting_scan_topk(q, vectors, slot_valid, vis, probe,
                                   k=k, backend="ref")
    s2, i2 = ops.posting_scan_topk(q, vectors, slot_valid, vis, probe,
                                   k=k, backend="pallas")
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    # candidate encoding uses the LOGICAL capacity C, not the padded one
    assert np.all((np.asarray(i2) >= 0) & (np.asarray(i2) < M * C))


@pytest.mark.parametrize("Q,M,C,P,d", [(1, 5, 33, 2, 96),
                                       (6, 12, 100, 4, 100),
                                       (2, 8, 130, 3, 300)])
def test_posting_scan_gather_misaligned_parity(Q, M, C, P, d, rng):
    q = _int_normal(rng, (Q, d))
    vectors = _int_normal(rng, (M, C, d), lo=-2, hi=3)
    slot_valid = jnp.asarray(rng.random((M, C)) > 0.3)
    vis = jnp.asarray(rng.random(M) > 0.2)
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    a = ops.posting_scan_gather(q, vectors, slot_valid, vis, probe,
                                backend="ref")
    b = ops.posting_scan_gather(q, vectors, slot_valid, vis, probe,
                                backend="pallas")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("Q,V,m,ksub,M,C,P,k",
                         [(1, 2, 4, 16, 8, 33, 2, 3),
                          (5, 2, 4, 100, 10, 100, 4, 25),
                          (3, 3, 8, 200, 7, 133, 3, 64)])
def test_pq_scan_topk_misaligned_parity(Q, V, m, ksub, M, C, P, k, rng):
    luts = _int_normal(rng, (Q, V, m, ksub), lo=0, hi=8)
    codes = jnp.asarray(rng.integers(0, ksub, (M, m, C)).astype(np.uint8))
    slot = jnp.asarray(rng.integers(0, V, (M,)).astype(np.int32))
    slot_valid = jnp.asarray(rng.random((M, C)) > 0.3)
    vis = jnp.asarray(rng.random(M) > 0.2)
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    s1, i1 = ops.pq_scan_topk(luts, codes, slot, slot_valid, vis, probe,
                              k=k, backend="ref")
    s2, i2 = ops.pq_scan_topk(luts, codes, slot, slot_valid, vis, probe,
                              k=k, backend="pallas")
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    assert np.all((np.asarray(i2) >= 0) & (np.asarray(i2) < M * C))


@pytest.mark.parametrize("Q,V,m,ksub,M,C,P", [(1, 2, 4, 16, 6, 33, 2),
                                              (4, 2, 4, 100, 9, 100, 4),
                                              (2, 3, 8, 200, 7, 133, 3)])
def test_pq_scan_gather_misaligned_parity(Q, V, m, ksub, M, C, P, rng):
    luts = _int_normal(rng, (Q, V, m, ksub), lo=0, hi=8)
    codes = jnp.asarray(rng.integers(0, ksub, (M, m, C)).astype(np.uint8))
    slot = jnp.asarray(rng.integers(0, V, (M,)).astype(np.int32))
    slot_valid = jnp.asarray(rng.random((M, C)) > 0.3)
    vis = jnp.asarray(rng.random(M) > 0.2)
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    a = ops.pq_scan_gather(luts, codes, slot, slot_valid, vis, probe,
                           backend="ref")
    b = ops.pq_scan_gather(luts, codes, slot, slot_valid, vis, probe,
                           backend="pallas")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("Q,M,C,d,R,k", [(1, 5, 100, 96, 7, 3),
                                         (4, 8, 33, 100, 24, 10),
                                         (3, 6, 128, 128, 64, 64)])
def test_rerank_topk_parity(Q, M, C, d, R, k, rng):
    """Fused exact rerank: candidate gather + ||v||^2 - 2 q.v +
    tier-spill ADC passthrough + top-k, bit-identical to the ref twin —
    including BIG carry for dead ADC slots and spilled-tile rows."""
    q = _int_normal(rng, (Q, d))
    vectors = _int_normal(rng, (M, C, d), lo=-2, hi=3)
    tier_spilled = jnp.asarray(rng.random(M) > 0.7)
    cand = jnp.asarray(rng.integers(0, M * C, (Q, R)).astype(np.int32))
    adc = np.array(_int_normal(rng, (Q, R), lo=0, hi=9))
    adc[rng.random((Q, R)) > 0.8] = ref.BIG  # dead candidate slots
    adc = jnp.asarray(adc)
    s1, i1 = ops.rerank_topk(q, vectors, tier_spilled, cand, adc, k=k,
                             backend="ref")
    s2, i2 = ops.rerank_topk(q, vectors, tier_spilled, cand, adc, k=k,
                             backend="pallas")
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    # selected indices address the flattened (M*C) vector store
    assert np.all((np.asarray(i2) >= 0) & (np.asarray(i2) < M * C))


def test_rerank_topk_all_dead(rng):
    """Every ADC slot dead: the fused kernel carries BIG through and
    both backends agree on the degenerate order."""
    Q, M, C, d, R, k = 2, 4, 33, 100, 9, 5
    q = _int_normal(rng, (Q, d))
    vectors = _int_normal(rng, (M, C, d), lo=-2, hi=3)
    tier_spilled = jnp.zeros((M,), bool)
    cand = jnp.asarray(rng.integers(0, M * C, (Q, R)).astype(np.int32))
    adc = jnp.full((Q, R), ref.BIG, jnp.float32)
    s1, i1 = ops.rerank_topk(q, vectors, tier_spilled, cand, adc, k=k,
                             backend="ref")
    s2, i2 = ops.rerank_topk(q, vectors, tier_spilled, cand, adc, k=k,
                             backend="pallas")
    assert np.all(np.asarray(s1) >= ref.BIG / 2)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_kmeans_assign_large_nonmultiple_k(rng):
    """K > 128 and not a multiple of the 128-lane tile, mask=None: the
    sentinel-row padding must never win an assignment."""
    N, K, d = 64, 200, 24
    pts = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    cen = jnp.asarray(rng.normal(size=(K, d)).astype(np.float32))
    a1, b1 = ops.kmeans_assign(pts, cen, backend="ref")
    a2, b2 = ops.kmeans_assign(pts, cen, backend="pallas")
    np.testing.assert_allclose(b1, b2, rtol=1e-4, atol=1e-3)
    assert np.all(np.asarray(a2) < K)
    same = np.asarray(a1) == np.asarray(a2)
    assert same.mean() > 0.99


def test_no_fallback_on_misaligned_shapes(rng):
    """The kernels are alignment-free: a pallas-backend request with
    misaligned storage shapes serves the Pallas path and reports NO
    fallback (the PR-10 contract — this test pinned the opposite
    behaviour before the wrappers learned to pad)."""
    from repro.obs import Obs
    obs = Obs()
    ops.observe_fallbacks(obs)
    Q, V, m, ksub, M, C, P = 2, 1, 2, 16, 4, 24, 2  # C, ksub misaligned
    luts = jnp.asarray(rng.normal(size=(Q, V, m, ksub)).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, ksub, (M, m, C)).astype(np.uint8))
    slot = jnp.zeros((M,), jnp.int32)
    slot_valid = jnp.ones((M, C), bool)
    vis = jnp.ones((M,), bool)
    probe = jnp.asarray(rng.integers(0, M, (Q, P)).astype(np.int32))
    sig = ("test-misaligned",)
    with ops.count_fallback_dispatches(obs, sig):
        ops.pq_scan_gather(luts, codes, slot, slot_valid, vis, probe,
                           backend="pallas")
        q = jnp.asarray(rng.normal(size=(Q, 24)).astype(np.float32))
        vecs = jnp.asarray(rng.normal(size=(M, C, 24)).astype(np.float32))
        ops.posting_scan_topk(q, vecs, slot_valid, vis, probe, k=3,
                              backend="pallas")
    assert obs.counter("kernel_fallback").value == 0.0
    assert obs.counter("kernel_fallback_traces").value == 0.0
    assert obs.events("kernel_fallback") == []


def test_fallback_dispatch_counting():
    """The two-clock fallback plane: ``kernel_fallback_traces`` bumps at
    note (trace) time, ``kernel_fallback`` bumps per wrapped dispatch by
    the signature's memoized fallback count — including cache-warm
    dispatches where the note itself never re-runs."""
    from repro.obs import Obs
    obs = Obs()
    ops.observe_fallbacks(obs)
    sig = ("plane", "pallas", 100)
    # first dispatch of this signature: the program "traces" and notes
    with ops.count_fallback_dispatches(obs, sig):
        ops._note_fallback("some_kernel", "no pallas lowering")
        ops._note_fallback("some_kernel", "no pallas lowering")  # same key
        ops._note_fallback("other_kernel", "int8 unsupported")
    assert obs.counter("kernel_fallback_traces").value == 3.0
    assert obs.counter("kernel_fallback").value == 2.0  # distinct keys
    evs = obs.events("kernel_fallback")
    assert {e["kernel"] for e in evs} == {"some_kernel", "other_kernel"}
    # cache-warm dispatch: no notes run, the memo still counts 2
    with ops.count_fallback_dispatches(obs, sig):
        pass
    assert obs.counter("kernel_fallback").value == 4.0
    assert obs.counter("kernel_fallback_traces").value == 3.0
    assert len(obs.events("kernel_fallback")) == 2  # one-shot per key
    # a different signature captures independently
    with ops.count_fallback_dispatches(obs, ("plane", "pallas", 128)):
        pass
    assert obs.counter("kernel_fallback").value == 4.0
    # reset clears the memo, the sinks and the one-shot dedup
    ops.reset_fallback_state()
    obs2 = Obs()
    ops.observe_fallbacks(obs2)
    with ops.count_fallback_dispatches(obs2, sig):
        ops._note_fallback("some_kernel", "no pallas lowering")
    assert obs2.counter("kernel_fallback").value == 1.0
    assert len(obs2.events("kernel_fallback")) == 1


def test_driver_close_detaches_fallback_sink():
    """UBISDriver.close() unregisters its Obs bundle so later notes no
    longer reach it."""
    from repro.core import UBISConfig, UBISDriver
    cfg = UBISConfig(dim=16, max_postings=8, capacity=16, l_min=2,
                     l_max=12, cache_capacity=16, max_ids=1 << 8,
                     nprobe=2, use_pallas="ref")
    rng = np.random.default_rng(0)
    drv = UBISDriver(cfg, rng.normal(size=(20, 16)).astype(np.float32))
    drv.close()
    ops._note_fallback("k", "r")
    assert drv.obs.counter("kernel_fallback_traces").value == 0.0


@pytest.mark.slow
@pytest.mark.parametrize("use_pq", [False, True])
def test_e2e_d100_pallas_bit_identical_zero_fallback(use_pq):
    """End-to-end PR-10 acceptance: a pallas-backend index at d=100
    (odd capacity, non-power-of-two ksub) answers bit-identically to
    the ref backend through inserts/deletes/splits, and the fallback
    counters stay at ZERO — the alignment slow-path hole is closed."""
    from repro.core import UBISConfig, UBISDriver

    def build(backend):
        cfg = UBISConfig(dim=100, max_postings=24, capacity=33, l_min=4,
                         l_max=28, cache_capacity=64, max_ids=1 << 11,
                         nprobe=6, use_pallas=backend, use_pq=use_pq,
                         pq_m=4, pq_ksub=100, rerank_k=40)
        r = np.random.default_rng(7)
        seed = r.integers(-3, 4, (80, 100)).astype(np.float32)
        data = r.integers(-3, 4, (300, 100)).astype(np.float32)
        drv = UBISDriver(cfg, seed)
        drv.insert(data, np.arange(300))
        drv.delete(np.arange(0, 300, 7))
        drv.flush(max_ticks=6)
        q = r.integers(-3, 4, (5, 100)).astype(np.float32)
        return drv, drv.search(q, 10)

    drv_p, res_p = build("pallas")
    _, res_r = build("ref")
    np.testing.assert_array_equal(np.asarray(res_p.ids),
                                  np.asarray(res_r.ids))
    np.testing.assert_array_equal(np.asarray(res_p.scores),
                                  np.asarray(res_r.scores))
    assert drv_p.obs.counter("kernel_fallback").value == 0.0
    assert drv_p.obs.counter("kernel_fallback_traces").value == 0.0


def test_flash_attention_matches_chunked(rng):
    """The pure-JAX chunked attention (model fast path) agrees with the
    kernel oracle."""
    from repro.models.attention import chunked_attention, local_attention
    B, Hq, Hkv, L, D = 2, 4, 2, 96, 16
    q = jnp.asarray(rng.normal(size=(B, Hq, L, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Hkv, L, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, L, D)).astype(np.float32))
    a = ref.flash_attention(q, k, v, causal=True)
    b = chunked_attention(q, k, v, causal=True, chunk_q=32, chunk_k=32,
                          backend="off")
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
    # windowed: blocked-local path vs masked reference
    w = 32
    a = ref.flash_attention(q, k, v, causal=True, window=w)
    b = local_attention(q, k, v, window=w, backend="off")
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
