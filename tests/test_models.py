"""Per-arch smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + no NaNs; decode-vs-prefill consistency;
mixer-level equivalences (chunked vs stepwise recurrences)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models import ARCH_IDS, get_model
from repro.models.layers import values


def _batch_for(m, B, L):
    batch = {}
    L_tok = L
    if m.cfg.family == "vlm":
        P = m.cfg.prefix_len
        L_tok = L - P
        batch["prefix"] = jnp.zeros((B, P, m.cfg.d_model))
    if m.cfg.family == "encdec":
        batch["src"] = jnp.zeros((B, 16, m.cfg.d_model))
    batch["tokens"] = jnp.ones((B, L_tok), jnp.int32)
    batch["targets"] = jnp.ones((B, L_tok), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch):
    m = get_model(arch, reduced=True)
    pv = values(m.init(jax.random.key(0)))
    B, L = 2, 64
    batch = _batch_for(m, B, L)
    loss, metrics = jax.jit(m.train_loss)(pv, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    # one decode step
    cache = values(m.init_cache(B, 96))
    logits, cache2 = jax.jit(m.decode_step)(
        pv, cache, jnp.ones((B,), jnp.int32), jnp.asarray(3))
    assert logits.shape == (B, m.cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch} decode NaN"


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "rwkv6-3b",
                                  "gemma3-4b"])
def test_prefill_decode_consistency(arch):
    """Greedy continuation via (prefill -> decode) matches teacher-forced
    forward logits: the caches carry exactly the right state."""
    m = get_model(arch, reduced=True)
    pv = values(m.init(jax.random.key(1)))
    B, L = 2, 33
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, m.cfg.vocab, (B, L)), jnp.int32)
    # full forward logits at the last position via prefill on all L
    logits_full, _ = jax.jit(m.prefill)(pv, {"tokens": toks})
    # prefill on L-1 then decode token L-1
    logits_pre, caches = jax.jit(m.prefill)(pv, {"tokens": toks[:, :-1]})
    # rebuild a padded cache to decode into (prefill cache has len L-1)
    S = 64
    cache_full = values(m.init_cache(B, S))

    def _place(full, part):
        # pad the seq axis of attention caches up to S
        if full.ndim >= 4 and full.shape != part.shape:
            pad = [(0, 0)] * part.ndim
            pad[3] = (0, full.shape[3] - part.shape[3])
            return jnp.pad(part, pad)
        return part

    cache = jax.tree_util.tree_map(_place, cache_full, caches)
    logits_dec, _ = jax.jit(m.decode_step)(
        pv, cache, toks[:, -1], jnp.asarray(L - 1))
    np.testing.assert_allclose(np.asarray(logits_full),
                               np.asarray(logits_dec),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_chunked_equals_stepwise():
    from repro.models import rwkv6
    from repro.models.layers import values as vals
    key = jax.random.key(0)
    D, hd, B, L = 64, 16, 2, 37
    p = vals(rwkv6.init_time_mix(key, D, hd))
    x = jax.random.normal(jax.random.key(1), (B, L, D))
    out_chunk, (state, xl) = rwkv6.apply_time_mix(p, x, hd)
    # stepwise
    st = jnp.zeros((B, D // hd, hd, hd), jnp.float32)
    xlast = jnp.zeros((B, D))
    outs = []
    for t in range(L):
        o, (st, xlast) = rwkv6.decode_time_mix(p, x[:, t], st, xlast, hd)
        outs.append(o)
    out_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_chunk),
                               np.asarray(out_step), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(state), np.asarray(st),
                               rtol=2e-3, atol=2e-3)


def test_mamba_scan_equals_stepwise():
    from repro.models import mamba
    from repro.models.layers import values as vals
    key = jax.random.key(0)
    D, N, B, L = 32, 8, 2, 29
    p = vals(mamba.init_mamba(key, D, N, 2, 4))
    x = jax.random.normal(jax.random.key(1), (B, L, D))
    out_chunk, (conv, ssm) = mamba.apply_mamba(p, x, N)
    conv_s = jnp.zeros((B, 3, 2 * D))
    ssm_s = jnp.zeros((B, 2 * D, N), jnp.float32)
    outs = []
    for t in range(L):
        o, (conv_s, ssm_s) = mamba.decode_mamba(p, x[:, t], conv_s,
                                                ssm_s, N)
        outs.append(o)
    out_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_chunk),
                               np.asarray(out_step), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(ssm), np.asarray(ssm_s),
                               rtol=2e-3, atol=2e-3)


def test_moe_routes_and_balances():
    from repro.models import moe
    from repro.models.config import MoEConfig
    from repro.models.layers import values as vals
    mcfg = MoEConfig(num_experts=8, top_k=2, d_ff_expert=32)
    p = vals(moe.init_moe(jax.random.key(0), 16, mcfg))
    x = jax.random.normal(jax.random.key(1), (2, 24, 16))
    out, aux = moe.apply_moe(p, x, mcfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0.0
    # capacity drops: shrink capacity hard and confirm it still runs
    mcfg2 = MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                      capacity_factor=0.25)
    out2, _ = moe.apply_moe(p, x, mcfg2)
    assert bool(jnp.all(jnp.isfinite(out2)))


def test_train_step_decreases_loss():
    """A few optimizer steps on the synthetic stream reduce loss."""
    from repro.optim import AdamW, AdamWConfig
    from repro.data import TokenStream
    m = get_model("tinyllama-1.1b", reduced=True)
    pv = values(m.init(jax.random.key(0)))
    opt = AdamW(AdamWConfig(weight_decay=0.0), lr=5e-3)
    ostate = opt.init(pv)
    stream = TokenStream(vocab=m.cfg.vocab, seq_len=64, batch_per_host=8)

    @jax.jit
    def step(pv, ostate, batch):
        (loss, _), g = jax.value_and_grad(m.train_loss, has_aux=True)(
            pv, batch)
        pv, ostate, _ = opt.apply(pv, g, ostate)
        return pv, ostate, loss

    losses = []
    for _ in range(8):
        b = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        pv, ostate, loss = step(pv, ostate, b)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.1, losses
