"""Tests for the unified observability plane (``repro.obs``).

Covers the metrics registry (log-bucket histograms, exposition
round-trip, the shared driver-stat schema across every engine), the
structured tracer (reasons on every planner decision), the serving
request spans, and the sampled live-recall probe.
"""
from __future__ import annotations

import json

import numpy as np
import pytest

from repro.api.registry import list_engines
from repro.core.types import UBISConfig
from repro.obs import (DRIVER_STAT_SCHEMA, Histogram, Obs, StatsMap, Tracer,
                       parse_exposition, required_series)


def small_cfg(**kw):
    kw.setdefault("dim", 16)
    kw.setdefault("max_postings", 16)
    kw.setdefault("nprobe", 8)
    kw.setdefault("capacity", 96)
    kw.setdefault("max_ids", 1 << 12)
    kw.setdefault("use_pallas", "off")
    return UBISConfig(**kw)


def seeds(n=64, dim=16, seed=0):
    return np.random.default_rng(seed).normal(size=(n, dim)).astype(
        np.float32)


# ---------------------------------------------------------------- metrics


def test_histogram_summary_and_quantiles():
    h = Histogram("lat")
    vals = [0.001, 0.002, 0.004, 0.008, 0.1]
    for v in vals:
        h.record(v)
    s = h.summary()
    assert s["count"] == 5
    assert s["sum"] == pytest.approx(sum(vals))
    assert s["mean"] == pytest.approx(np.mean(vals))
    # log-bucket quantiles are bucket midpoints: exact to within one
    # bucket's growth factor (2**0.25), clamped to the observed range
    assert s["p50"] == pytest.approx(0.004, rel=2 ** 0.25 - 1)
    assert s["p99"] <= 0.1 + 1e-12
    assert h.quantile(0.0) >= min(vals)


def test_histogram_empty():
    s = Histogram("empty").summary()
    assert s == {"count": 0, "sum": 0.0, "mean": 0.0,
                 "p50": 0.0, "p95": 0.0, "p99": 0.0}


def test_registry_exposition_round_trip():
    obs = Obs()
    obs.counter("reqs").inc(3)
    obs.gauge("fill").set(0.75)
    h = obs.histogram("lat_seconds")
    h.record(0.01)
    h.record(0.02)
    series = parse_exposition(obs.to_prometheus())
    assert series["reqs"] == 3.0
    assert series["fill"] == 0.75
    assert series["lat_seconds_count"] == 2.0
    assert series["lat_seconds_sum"] == pytest.approx(0.03)
    assert not required_series(series, ("reqs", "fill", "lat_seconds_count"))
    assert required_series(series, ("reqs", "nope")) == ["nope"]


def test_parse_exposition_rejects_malformed():
    with pytest.raises(ValueError):
        parse_exposition("this is { not prometheus\n")


def test_stats_map_is_defaultdict_compatible():
    obs = Obs()
    s = obs.driver_stats()
    assert s["inserted"] == 0.0          # missing reads are 0.0
    s["inserted"] += 5
    s["bg_time"] += 0.25
    assert float(s["inserted"]) == 5.0
    assert set(dict(s)) == set(DRIVER_STAT_SCHEMA)
    # same prefix -> the SAME map (driver and tier manager share it)
    assert obs.driver_stats() is s
    snap = obs.snapshot()
    assert snap["index_inserted"] == 5.0
    assert isinstance(StatsMap.__slots__, tuple)


def test_snapshot_is_json_ready():
    obs = Obs()
    obs.driver_stats()["queries"] += 2
    obs.histogram("h").record(0.5)
    json.dumps(obs.snapshot(), allow_nan=False)


# ---------------------------------------------------------------- tracer


def test_tracer_ring_and_seq():
    tr = Tracer(capacity=4)
    for i in range(6):
        tr.emit("tick", i=i)
    evs = tr.events()
    assert len(evs) == 4                       # oldest dropped
    assert [e["i"] for e in evs] == [2, 3, 4, 5]
    assert [e["seq"] for e in evs] == [2, 3, 4, 5]
    assert tr.events("tick") == evs and tr.events("other") == []


def test_tracer_disabled_is_noop():
    tr = Tracer(enabled=False)
    tr.emit("tick", huge=list(range(1000)))
    assert len(tr) == 0


def test_tracer_jsonl_sink_and_numpy(tmp_path):
    p = tmp_path / "trace.jsonl"
    tr = Tracer(path=str(p))
    tr.emit("plan", pids=np.array([1, 2]), n=np.int64(2),
            frac=np.float32(0.5))
    tr.close()
    ev = json.loads(p.read_text().strip())
    assert ev["kind"] == "plan" and ev["pids"] == [1, 2]
    assert ev["n"] == 2 and isinstance(ev["frac"], float)


# ------------------------------------------------- shared driver schema


def test_every_engine_exposes_the_shared_stat_schema():
    """Satellite (a): the stats key drift across engines is gone — one
    schema, every ``make_index`` engine, keys identical and readable
    before any operation touched them."""
    cfg = small_cfg()
    sv = seeds()
    for spec in list_engines():
        idx = spec.make(cfg, sv, round_size=64)
        assert set(dict(idx.stats)) == set(DRIVER_STAT_SCHEMA), spec.name
        # snapshot exports the same keys under the index_ prefix
        snap = idx.obs.snapshot()
        missing = [k for k in DRIVER_STAT_SCHEMA
                   if f"index_{k}" not in snap]
        assert not missing, (spec.name, missing)


def test_driver_emits_reasoned_planner_events():
    from repro.core.driver import UBISDriver
    drv = UBISDriver(small_cfg(), seeds(), round_size=64,
                     bg_ops_per_round=4)
    rng = np.random.default_rng(1)
    drv.insert(rng.normal(size=(48, 16)).astype(np.float32),
               np.arange(48))
    drv.flush(max_ticks=8)
    drv.delete(np.arange(8))
    drv.flush(max_ticks=8)
    kinds = {e["kind"] for e in drv.obs.events()}
    assert {"insert", "delete", "tick"} <= kinds
    for e in drv.obs.events("bg_mark"):
        assert e["reason"], e                  # every decision says why
    for e in drv.obs.events("insert"):
        assert {"accepted", "cached", "rejected"} <= set(e)
    ins = sum(e["accepted"] + e["cached"]
              for e in drv.obs.events("insert"))
    dels = sum(e["deleted"] for e in drv.obs.events("delete"))
    assert ins - dels == drv.live_count()


def test_search_introspection_counters():
    from repro.core.driver import UBISDriver
    drv = UBISDriver(small_cfg(), seeds(), round_size=64)
    drv.insert(seeds(32, seed=2), np.arange(32))
    drv.flush(max_ticks=4)
    drv.search(seeds(8, seed=3), 4)
    s = drv.stats
    assert s["queries"] == 8
    assert s["search_probed"] > 0
    assert s["search_results"] > 0
    assert s["search_exact_batches"] == 1      # no PQ in this config
    assert s["search_adc_batches"] == 0


def test_rebalance_planner_records_move_triggers():
    from repro.api.rebalance import RebalancePlanner
    S, pool = 2, 8
    pl = RebalancePlanner(n_shards=S, pool_per_shard=pool,
                          watermark=0.85, min_gap=1, max_moves=4)
    lengths = np.zeros(S * pool, np.int32)
    lengths[:pool] = 40                        # shard 0 holds all mass
    movable = np.zeros(S * pool, bool)
    movable[:pool] = True
    # pressure rows: live, free, backlog, occ
    pressure = np.array([[8, 0, 0, 320.0], [1, 7, 0, 40.0]])
    src, dst = pl.plan(pressure, lengths, movable)
    assert len(src) == len(pl.last_moves) > 0
    for mv in pl.last_moves:
        assert mv["trigger"] in ("watermark", "spread")
        assert mv["donor"] == 0 and mv["dst"] == 1


# ---------------------------------------------------------- serving spans


def _drain(eng, tickets, n=50):
    for _ in range(n):
        eng.pump()
        if all(t.done() for t in tickets):
            return True
    return False


def test_serving_request_spans_and_probe():
    from repro.api.registry import make_index
    from repro.serving.engine import ServingConfig, ServingEngine
    idx = make_index("ubis", small_cfg(), seeds(), round_size=64)
    eng = ServingEngine(idx, ServingConfig(
        search_batch=4, search_deadline_s=0.0, recall_probe=1.0,
        recall_probe_rows=4))
    assert eng.obs is idx.obs                  # one plane, both layers
    qs = seeds(6, seed=5)
    tickets = [eng.submit_search(q[None], 4) for q in qs]
    assert _drain(eng, tickets)
    snap = eng.obs.snapshot()
    assert snap["serve_queue_wait_seconds"]["count"] == 6
    assert snap["serve_latency_seconds"]["count"] == 6
    assert snap["serve_service_seconds"]["count"] >= 1
    assert 0 < snap["serve_batch_fill"] <= 1.0
    assert snap["live_recall_probes"] >= 1
    assert 0.0 <= snap["live_recall"] <= 1.0
    assert eng.probe.rolling_recall == snap["live_recall"]


def test_serving_spans_disabled_with_plane_off():
    from repro.api.registry import make_index
    from repro.serving.engine import ServingConfig, ServingEngine
    obs = Obs(enabled=False)
    idx = make_index("ubis", small_cfg(), seeds(), round_size=64,
                     obs=obs)
    eng = ServingEngine(idx, ServingConfig(search_batch=4,
                                           search_deadline_s=0.0),
                        obs=obs)
    tickets = [eng.submit_search(seeds(1, seed=7), 4)]
    assert _drain(eng, tickets)
    snap = eng.obs.snapshot()
    assert snap["serve_latency_seconds"]["count"] == 0
    assert len(obs.tracer) == 0
    # the stats plane stays live even with tracing/spans off (the
    # driver counts padded batch rows, so >= the 1 real request)
    assert idx.stats["queries"] >= 1


def test_probe_sampling_is_seeded_and_bounded():
    from repro.obs import RecallProbe

    class FakeIndex:
        calls = 0

        def exact(self, q, k):
            FakeIndex.calls += 1
            ids = np.tile(np.arange(k), (len(q), 1))
            return type("R", (), {"ids": ids})()

    obs = Obs()
    pr = RecallProbe(FakeIndex(), obs.registry, fraction=0.5,
                     window=8, max_rows=2, seed=42)
    q = np.zeros((4, 8), np.float32)
    found = np.tile(np.arange(4), (4, 1))
    rs = [pr.maybe_probe(q, 4, found) for _ in range(40)]
    fired = [r for r in rs if r is not None]
    assert 0 < len(fired) < 40                 # sampled, not all/none
    assert FakeIndex.calls == len(fired)
    assert all(r == 1.0 for r in fired)
    assert pr.rolling_recall == 1.0
    # fraction=0 never probes and never builds device work
    pr0 = RecallProbe(FakeIndex(), Obs().registry, fraction=0.0)
    before = FakeIndex.calls
    assert pr0.maybe_probe(q, 4, found) is None
    assert FakeIndex.calls == before


def test_profile_hook_is_best_effort(tmp_path):
    obs = Obs()
    ran = []
    with obs.profile(None):
        ran.append(1)                          # no dir -> plain block
    with obs.profile(str(tmp_path / "prof")):
        ran.append(2)
    assert ran == [1, 2]
