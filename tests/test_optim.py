"""Optimizer unit tests: AdamW math, state dtypes, int8 quantisation,
schedules, clipping, EF-int8 gradient compression."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.optim import AdamW, AdamWConfig, cosine_warmup
from repro.optim.adamw import q8_decode, q8_encode

settings.register_profile("fast", max_examples=20, deadline=None)
settings.load_profile("fast")


def test_adamw_matches_reference():
    cfg = AdamWConfig(b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      clip_norm=None)
    opt = AdamW(cfg, lr=0.1)
    p = {"w": jnp.array([[1.0, -2.0], [0.5, 3.0]])}
    g = {"w": jnp.array([[0.1, 0.2], [-0.3, 0.4]])}
    s = opt.init(p)
    p1, s1, _ = opt.apply(p, g, s)
    # closed-form first step: m=0.1g/(1-b1)... update = g/ (|g| + eps)
    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.asarray(g["w"]) ** 2
    upd = (m / 0.1) / (np.sqrt(v / 0.01) + 1e-8)
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               np.asarray(p["w"]) - 0.1 * upd, rtol=1e-5)


def test_weight_decay_and_clip():
    cfg = AdamWConfig(weight_decay=0.1, clip_norm=1e-9)  # clip ~ zeroes g
    opt = AdamW(cfg, lr=0.1)
    p = {"w": jnp.ones((4, 4))}
    g = {"w": jnp.full((4, 4), 100.0)}
    s = opt.init(p)
    p1, _, om = opt.apply(p, g, s)
    # with gradient clipped to ~0, only decay moves params (downward)
    assert float(om["grad_norm"]) > 0
    assert np.all(np.asarray(p1["w"]) < 1.0)
    assert np.all(np.asarray(p1["w"]) > 0.98)


@given(st.integers(1, 6))
def test_state_dtypes_agree(seed):
    """bf16/int8 moment states track the f32 trajectory: the parameter
    *updates* stay directionally aligned (blockwise-linear int8 has
    coarse per-element error by construction, so elementwise closeness
    is the wrong assertion — trajectory agreement is the guarantee)."""
    rng = np.random.default_rng(seed)
    p0 = {"w": jnp.asarray(rng.normal(size=(16, 257)).astype(np.float32))}
    # gradients with a persistent mean component (like real training):
    # pure zero-mean noise is the adversarial case for signed linear
    # quantisation (moments hover where int8 resolution is coarsest)
    mu = rng.normal(size=(16, 257)).astype(np.float32)
    trajs = {}
    for sd in ("f32", "bf16", "int8"):
        opt = AdamW(AdamWConfig(state_dtype=sd, weight_decay=0.0,
                                clip_norm=None), lr=1e-2)
        p, s = p0, opt.init(p0)
        for i in range(12):
            rng = np.random.default_rng(seed * 100 + i)  # same grads
            g = {"w": jnp.asarray(
                mu + 0.5 * rng.normal(size=(16, 257)).astype(np.float32))}
            p, s, _ = opt.apply(p, g, s)
        trajs[sd] = np.asarray(p["w"]) - np.asarray(p0["w"])

    def cos(a, b):
        return float((a * b).sum()
                     / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-12))

    assert cos(trajs["bf16"], trajs["f32"]) > 0.995
    # linear blockwise int8 moments sit at ~0.92-0.97 cosine after only
    # five steps (production recipes warm the moments up before
    # quantising); directional tracking is the guarantee
    assert cos(trajs["int8"], trajs["f32"]) > 0.90
    rel = (np.linalg.norm(trajs["int8"] - trajs["f32"])
           / (np.linalg.norm(trajs["f32"]) + 1e-12))
    assert rel < 0.7, rel


@given(st.integers(0, 10))
def test_q8_roundtrip_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(7, 300)).astype(np.float32)) * 10
    q, s = q8_encode(x)
    y = q8_decode(q, s, x.shape)
    err = np.abs(np.asarray(y) - np.asarray(x))
    blockmax = np.abs(np.asarray(x)).max()
    assert err.max() <= blockmax / 127 + 1e-6


def test_cosine_warmup_shape():
    lr = cosine_warmup(1.0, 10, 100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(5)) == 0.5
    assert float(lr(100)) <= 0.11
    assert float(lr(55)) > float(lr(90))
