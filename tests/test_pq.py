"""Quant-plane properties: code/float sync through churn, versioned
codebook re-train safety, and use_pq=False float-path identity."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (UBISConfig, UBISDriver, brute_force, metrics,
                        version_manager as vm)
from repro.core.search import search as search_fn
from repro.kernels import ops
from repro.kernels.posting_scan import BIG
from repro.quant import pq
from conftest import make_clustered


def _mk_cfg(mode="ubis", **kw):
    base = dict(dim=16, max_postings=256, capacity=64, l_min=6, l_max=48,
                cache_capacity=512, max_ids=1 << 14, use_pallas="off",
                mode=mode, use_pq=True, pq_m=4, pq_ksub=32, rerank_k=48)
    base.update(kw)
    return UBISConfig(**base)


def assert_codes_in_sync(state, cfg):
    """The tentpole invariant: for every valid slot of every live
    posting, the stored code equals encode(codebooks[posting's slot],
    stored float vector) — the planes never diverge."""
    status = np.asarray(vm.unpack_status(state.rec_meta))
    alive = np.flatnonzero(np.asarray(state.allocated) & (status != 3))
    cbs = np.asarray(state.pq_codebooks)
    slot = np.asarray(state.pq_posting_slot)
    codes = np.asarray(state.codes)
    vecs = np.asarray(state.vectors)
    sv = np.asarray(state.slot_valid)
    checked = 0
    for p in alive:
        if not sv[p].any():
            continue
        want = np.asarray(pq.encode(jnp.asarray(cbs[slot[p]]),
                                    jnp.asarray(vecs[p])))
        got = codes[p].T                       # (C, m)
        rows = np.flatnonzero(sv[p])
        assert (want[rows] == got[rows]).all(), f"codes diverged at {p}"
        checked += len(rows)
    assert checked > 0, "audit found nothing to check"
    return checked


def _churn(cfg, seed=0, n=2500, retrain_every=3):
    data = make_clustered(n, d=cfg.dim, k=6, seed=seed)
    drv = UBISDriver(cfg, data[:300], round_size=128, bg_ops_per_round=8,
                     pq_retrain_every=retrain_every)
    rng = np.random.default_rng(seed)
    drv.insert(data[: n // 2], np.arange(n // 2))
    drv.delete(rng.choice(n // 2, size=n // 5, replace=False))
    drv.insert(data[n // 2:], np.arange(n // 2, n))
    drv.flush(max_ticks=40)
    return drv, data


@pytest.mark.parametrize("mode", ["ubis", "spfresh"])
def test_codes_track_floats_through_churn(mode):
    """Insert/delete/split/merge/compact/reassign + scheduled re-trains:
    the code plane never diverges from the float plane."""
    drv, _ = _churn(_mk_cfg(mode), seed=1)
    assert drv.stats["bg_ops"] > 0, "churn produced no structural ops"
    if mode == "ubis":
        assert drv.stats["pq_retrains"] > 0, "no re-train was scheduled"
    assert_codes_in_sync(drv.state, drv.cfg)


def test_decode_reencode_fixed_point():
    """Decode -> nearest-centroid re-encode is a fixed point (decoded
    vectors quantize back to their own code)."""
    drv, _ = _churn(_mk_cfg(), seed=2, n=1200)
    state = drv.state
    cbs = np.asarray(state.pq_codebooks)
    slot = np.asarray(state.pq_posting_slot)
    status = np.asarray(vm.unpack_status(state.rec_meta))
    alive = np.flatnonzero(np.asarray(state.allocated) & (status != 3))
    sv = np.asarray(state.slot_valid)
    codes = np.asarray(state.codes)
    hit = 0
    for p in alive[:16]:
        rows = np.flatnonzero(sv[p])
        if not len(rows):
            continue
        cb = jnp.asarray(cbs[slot[p]])
        got = jnp.asarray(codes[p].T[rows])        # (r, m)
        again = pq.encode(cb, pq.decode(cb, got))
        assert (np.asarray(again) == np.asarray(got)).all()
        hit += len(rows)
    assert hit > 0


def test_use_pq_false_is_bit_identical_to_float_path():
    """With use_pq=False the two-stage machinery must be fully inert:
    search equals the pre-quant float implementation bit for bit."""
    cfg = _mk_cfg(use_pq=False, pq_m=8)
    drv, data = _churn(cfg, seed=3, n=1500)
    state, k, nprobe = drv.state, 10, cfg.nprobe
    queries = jnp.asarray(make_clustered(32, d=cfg.dim, seed=7))
    found, scores, _ = search_fn(state, cfg, queries, k)

    # the seed float search, inlined verbatim as the identity oracle
    # (jitted like the production path so XLA fuses both identically)
    @jax.jit
    def oracle(state, queries):
        Q = queries.shape[0]
        q32 = queries.astype(jnp.float32)
        vis = vm.visible(state.rec_meta, state.allocated,
                         state.global_version)
        csc = ops.centroid_score(q32, state.centroids, vis, backend="off")
        _, probe = jax.lax.top_k(-csc, nprobe)
        pscores = ops.posting_scan_gather(
            q32, state.vectors, state.slot_valid, vis,
            probe.astype(jnp.int32), backend="off")
        pids = state.ids[probe]
        cscores = ops.centroid_score(q32, state.cache_vecs,
                                     state.cache_valid, backend="off")
        cids = jnp.broadcast_to(state.cache_ids[None, :],
                                (Q, cfg.cache_capacity))
        all_scores = jnp.concatenate([pscores.reshape(Q, -1), cscores], 1)
        all_ids = jnp.concatenate([pids.reshape(Q, -1), cids], 1)
        neg, idx = jax.lax.top_k(-all_scores, k)
        want = jnp.where(-neg < BIG / 2,
                         jnp.take_along_axis(all_ids, idx, axis=1), -1)
        return want, -neg

    want_found, want_scores = oracle(state, queries)
    np.testing.assert_array_equal(np.asarray(found),
                                  np.asarray(want_found))
    np.testing.assert_array_equal(np.asarray(scores),
                                  np.asarray(want_scores))


def test_pq_search_recall_close_to_float():
    """Two-stage ADC + rerank stays within 5 recall points of the float
    scan on the same state (the ISSUE acceptance bar, shrunk to CI size)."""
    cfg = _mk_cfg(pq_m=8, pq_ksub=64, rerank_k=96)
    drv, data = _churn(cfg, seed=4, n=3000)
    queries = make_clustered(64, d=cfg.dim, seed=11)
    found = drv.search(queries, 10).ids
    true, _ = brute_force(drv.state, drv.cfg, jnp.asarray(queries), 10)
    rec_pq = metrics.recall_at_k(found, np.asarray(true))
    # same state searched through the float phase-2 (use_pq off)
    fcfg = _mk_cfg(pq_m=8, pq_ksub=64, use_pq=False)
    found_f, _, _ = search_fn(drv.state, fcfg, jnp.asarray(queries),
                                      10)
    rec_f = metrics.recall_at_k(np.asarray(found_f), np.asarray(true))
    assert rec_pq >= rec_f - 0.05, (rec_pq, rec_f)


def test_retrain_rotates_versions_and_keeps_old_codes_decodable():
    """A re-train installs a new generation in the evicted slot, re-encodes
    only postings pinned to it, and leaves every other posting's codes
    byte-identical (decodable under their original generation)."""
    cfg = _mk_cfg()
    drv, _ = _churn(cfg, seed=5, n=1500, retrain_every=0)  # no auto retrain
    state = drv.state
    active0 = int(state.pq_active)
    slot0 = np.asarray(state.pq_posting_slot)
    codes0 = np.asarray(state.codes)
    alloc = np.asarray(state.allocated)

    state2 = pq.retrain_round(state, cfg, jax.random.key(0))
    evict = (active0 + 1) % cfg.pq_versions
    assert int(state2.pq_active) == evict
    assert int(state2.pq_slot_gen[evict]) == int(state.pq_slot_gen[active0]) + 1
    # postings NOT pinned to the evicted slot keep their bytes and slot
    untouched = alloc & (slot0 != evict)
    assert (np.asarray(state2.pq_posting_slot)[untouched]
            == slot0[untouched]).all()
    assert (np.asarray(state2.codes)[untouched]
            == codes0[untouched]).all()
    # and the whole state is still in sync (pinned ones re-encoded)
    assert_codes_in_sync(state2, cfg)
    # float plane untouched: same vectors, ids, visibility
    np.testing.assert_array_equal(np.asarray(state2.vectors),
                                  np.asarray(state.vectors))
    np.testing.assert_array_equal(np.asarray(state2.id_loc),
                                  np.asarray(state.id_loc))
    np.testing.assert_array_equal(np.asarray(state2.rec_meta),
                                  np.asarray(state.rec_meta))
