"""Cross-shard rebalance property tests (multi-device, subprocess).

Four layers over a real fake-CPU pod mesh:
  * migrate-round invariants — after ``make_sharded_migrate`` no id is
    lost or duplicated across shards, ``id_loc`` stays replica-identical
    on every device, PQ codes still satisfy
    ``codes == encode(codebooks[slot], vectors)`` on migrated postings,
    donors retire with NO successor pointers, and garbage jobs
    (out-of-range, dst==src, non-NORMAL donors) are exact no-ops;
  * saturated-donor convergence — a hot stream that saturates one
    shard's sub-pool drops below the planner watermark once rebalance
    ticks run, with the live multiset intact;
  * the acceptance criterion — a Zipfian-routed stream keeps max/min
    shard occupancy <= 1.5 and recall@10 within 2 points of the
    uniform-stream run;
  * the engine-contract differential program (contract_harness) on a
    real 4-shard mesh, where the interleaving exercises the migrate
    round alongside every other op.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(ROOT, "src"),
                    os.path.join(ROOT, "tests")]),
               TF_CPP_MIN_LOG_LEVEL="2")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=540)
    assert r.returncode == 0, f"STDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"
    return r.stdout


def test_planner_vector_mode_cannot_ping_pong():
    """Pure-numpy planner properties (no devices needed): a vector-mode
    move must fit HALF the gap to the shard actually receiving — not
    the global min, which may be ineligible — so a move can never push
    the receiver past the donor, and repeated planning over a simulated
    state always reaches an empty plan (convergence)."""
    import numpy as np
    from repro.api.rebalance import RebalancePlanner

    pool = 64
    pl = RebalancePlanner(3, pool, max_moves=8, min_gap=80)
    # shard 2 is lightest but has NO free slot; shard 1 receives.  The
    # 0->1 gap is 101, so only postings of length <= 50 may move — and
    # shard 0 only has length-77 postings: the plan must be EMPTY
    # (moving 77 would overshoot shard 1 past shard 0 and churn forever)
    press = np.array([[13, 51, 0, 1001], [12, 52, 0, 900], [1, 0, 0, 100]])
    lengths = np.zeros(3 * pool, np.int32)
    movable = np.zeros(3 * pool, bool)
    lengths[:13] = 77
    movable[:13] = True
    src, dst = pl.plan(press, lengths, movable)
    assert len(src) == 0
    # widen the 0->1 gap: now a 77 fits half of it (77 <= 82); ONE move
    # ships the longest fitting posting to shard 1, the shrunken gap
    # (964-77 vs 800+77: gap 10) admits nothing more
    lengths[12] = 40
    press[0, 3] = 12 * 77 + 40
    press[1, 3] = 800
    src, dst = pl.plan(press, lengths, movable)
    assert list(dst) == [1] and len(src) == 1
    assert lengths[src[0]] <= (964 - 800) / 2

    # parked-cache backlog counts toward saturation: a shard whose live
    # postings sit below the watermark but with a deep parked backlog
    # (pressure column 2) must still shed postings
    pl2 = RebalancePlanner(2, pool, watermark=0.85, min_gap=80,
                           max_moves=4)
    live0 = int(0.7 * pool)                 # below watermark on its own
    press2 = np.array([[live0, pool - live0, 40 * 80, live0 * 60],
                       [4, pool - 4, 0, 240]])
    assert pl2.needs(press2)
    lengths2 = np.zeros(2 * pool, np.int32)
    movable2 = np.zeros(2 * pool, bool)
    lengths2[:live0] = 60
    movable2[:live0] = True
    src2, dst2 = pl2.plan(press2, lengths2, movable2)
    assert len(src2) > 0 and set(dst2) == {1}
    # without the backlog the same rows are quiet (gap below ratio gate
    # is irrelevant here: saturation was the only trigger)
    press2[0, 2] = 0
    press2[1, 3] = press2[0, 3]             # no vector gap either
    assert not pl2.needs(press2)

    # convergence: repeatedly apply the plan to a simulated skewed pool;
    # the planner must go quiet, and within a bounded number of rounds
    rng = np.random.default_rng(0)
    S = 4
    pl = RebalancePlanner(S, pool, max_moves=8, min_gap=80)
    lengths = np.zeros(S * pool, np.int32)
    movable = np.zeros(S * pool, bool)
    lengths[:50] = rng.integers(10, 80, 50)     # all mass on shard 0
    movable[:50] = True
    for rounds in range(64):
        live = np.array([(movable[s * pool:(s + 1) * pool]).sum()
                         for s in range(S)])
        occ = np.array([lengths[s * pool:(s + 1) * pool][
            movable[s * pool:(s + 1) * pool]].sum() for s in range(S)])
        press = np.stack([live, pool - live, 0 * live, occ], axis=1)
        src, dst = pl.plan(press, lengths, movable)
        if len(src) == 0:
            break
        for p, r in zip(src, dst):
            free = r * pool + np.flatnonzero(
                ~movable[r * pool:(r + 1) * pool])[0]
            lengths[free], movable[free] = lengths[p], True
            lengths[p], movable[p] = 0, False
    else:
        pytest.fail("planner never converged")
    occ = np.array([lengths[s * pool:(s + 1) * pool].sum()
                    for s in range(S)])
    assert occ.max() - occ.min() <= 80 or (
        occ.max() <= max(occ.min(), 1) * 1.2), occ
    assert rounds < 32, rounds


@pytest.mark.slow
def test_migrate_round_invariants():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.core import UBISConfig, UBISDriver
        from repro.core import version_manager as vm
        from repro.core.sharded import index_specs, make_sharded_migrate
        from repro.quant import pq

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = UBISConfig(dim=16, max_postings=256, capacity=96,
                         max_ids=1 << 14, use_pallas="off", use_pq=True,
                         pq_m=4, pq_ksub=16, rerank_k=128)
        r = np.random.default_rng(7)
        cents = r.normal(size=(10, 16)) * 6
        data = (cents[r.integers(0, 10, 2500)]
                + r.normal(size=(2500, 16))).astype(np.float32)
        drv = UBISDriver(cfg, data[:500], round_size=256,
                         bg_ops_per_round=8)
        drv.insert(data, np.arange(2500)); drv.flush()

        def audit(full):
            status = np.asarray(vm.unpack_status(full.rec_meta))
            vis = np.asarray(full.allocated) & (status != 3)
            ids = np.asarray(full.ids); sv = np.asarray(full.slot_valid)
            where = {}
            for p in np.flatnonzero(vis):
                for c in np.flatnonzero(sv[p]):
                    i = int(ids[p, c])
                    assert i not in where, f"dup id {i}"
                    where[i] = p * cfg.capacity + c
            cv = np.asarray(full.cache_valid)
            ci = np.asarray(full.cache_ids)
            for s in np.flatnonzero(cv):
                where[int(ci[s])] = -2 - s
            il = np.asarray(full.id_loc)
            tracked = {int(i): int(il[i])
                       for i in np.flatnonzero(il != -1)}
            assert tracked == where, (len(tracked), len(where))
            return where

        sh = jax.tree_util.tree_map(
            lambda sp: NamedSharding(mesh, sp), index_specs(cfg),
            is_leaf=lambda x: isinstance(x, PartitionSpec))
        st = jax.device_put(drv.state, sh)
        before = audit(jax.device_get(st))

        # everything seeded on shard 0 (contiguous pids): migrate 4 live
        # postings to shards 1..3, plus garbage lanes that must no-op
        lens = np.asarray(drv.state.lengths)
        status = np.asarray(vm.unpack_status(drv.state.rec_meta))
        live = np.flatnonzero(np.asarray(drv.state.allocated)
                              & (status == 0) & (lens > 0))
        live = live[live < 64]            # shard-0 donors
        assert len(live) >= 7, len(live)
        B = 8
        src = np.full(B, -1, np.int32); dst = np.zeros(B, np.int32)
        valid = np.zeros(B, bool)
        src[:4] = live[:4]; dst[:4] = [1, 2, 3, 1]; valid[:4] = True
        src[4], dst[4], valid[4] = live[0], 2, True    # dup src: no-op
        src[5], dst[5], valid[5] = live[5], 0, True    # dst == src shard
        src[6], dst[6], valid[6] = 9999, 1, True       # out of range
        src[7], dst[7], valid[7] = live[6], 2, True    # valid extra move
        mig = make_sharded_migrate(cfg, mesh, jobs=B)
        st, moved, new_pids = mig(st, jnp.asarray(src), jnp.asarray(dst),
                                  jnp.asarray(valid))
        moved = np.asarray(moved)
        new_pids = np.asarray(new_pids)
        assert moved[:4].all() and moved[7], moved
        assert not moved[4] and not moved[5] and not moved[6], moved
        # landing pids are reported (and -1 for no-op lanes)
        assert (new_pids[moved] // 64 == dst[moved]).all(), new_pids
        assert (new_pids[~moved] == -1).all(), new_pids

        # a retired donor (now DELETED) must be an exact no-op
        il_before = np.asarray(jax.device_get(st.id_loc))
        st, again, _ = mig(st, jnp.asarray(src[:1].repeat(B)),
                           jnp.asarray(np.full(B, 3, np.int32)),
                           jnp.asarray(np.ones(B, bool)))
        assert not np.asarray(again).any()
        assert (np.asarray(jax.device_get(st.id_loc)) == il_before).all()

        # id_loc replica-identical on EVERY device
        ref = None
        for s in st.id_loc.addressable_shards:
            d = np.asarray(s.data)
            ref = d if ref is None else ref
            assert (d == ref).all(), "id_loc replicas diverged"

        full = jax.device_get(st)
        after = audit(full)
        assert set(after) == set(before), "ids lost or fabricated"
        # moved postings landed on their target shards, donors retired
        # with NO successors
        status = np.asarray(vm.unpack_status(full.rec_meta))
        s1, s2 = (np.asarray(x) for x in vm.succ_ids(full.rec_succ))
        for j in np.flatnonzero(moved):
            p = src[j]
            assert status[p] == 3, f"donor {p} not retired"
            assert s1[p] == -1 and s2[p] == -1, "migrate set successors"
        nbrs = np.asarray(full.nbrs)
        for j in np.flatnonzero(moved):
            tids = np.asarray(full.ids)[src[j]]
            tsv = np.asarray(full.slot_valid)[src[j]]
            for i in tids[tsv]:
                new_pid = after[int(i)] // cfg.capacity
                assert new_pid // 64 == dst[j], (j, int(i), new_pid)
                # landed postings start with an EMPTY neighbour row —
                # the donor's row held shard-local pids that would
                # alias unrelated postings in the receiver's pool
                assert (nbrs[new_pid] == -1).all(), nbrs[new_pid]
        # PQ invariant on every live posting (migrated included):
        # codes == encode(codebooks[pinned slot], stored vectors)
        vis = np.asarray(full.allocated) & (status != 3)
        for p in np.flatnonzero(vis):
            slot = int(np.asarray(full.pq_posting_slot)[p])
            want = np.asarray(pq.encode_tiles(
                jnp.asarray(full.pq_codebooks)[slot],
                jnp.asarray(full.vectors)[p][None].astype(jnp.float32)))[0]
            sv = np.asarray(full.slot_valid)[p]
            got = np.asarray(full.codes)[p]
            assert (got[:, sv] == want[:, sv]).all(), f"pq drift at {p}"
        print("OK", int(moved.sum()), "moved")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_saturated_donor_converges_below_watermark():
    out = _run("""
        import numpy as np, jax
        from repro.api import ShardedUBISDriver
        from repro.core import UBISConfig

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = UBISConfig(dim=16, max_postings=256, capacity=96,
                         max_ids=1 << 14, use_pallas="off")
        r = np.random.default_rng(11)
        # ONE tight cluster family: every insert routes to the seed
        # shard, the canonical saturated-donor stream
        cents = r.normal(size=(4, 16)) * 4
        data = (cents[r.integers(0, 4, 5000)]
                + r.normal(size=(5000, 16))).astype(np.float32)
        drv = ShardedUBISDriver(cfg, data[:400], mesh=mesh,
                                round_size=256, bg_ops_per_round=8,
                                gc_lag=4, rebalance_watermark=0.8)
        rej = 0
        for off in range(0, 5000, 1000):
            rej += drv.insert(data[off:off + 1000],
                              np.arange(off, off + 1000)).rejected
            drv.flush(max_ticks=20)
        assert rej == 0, rej
        drv.flush(max_ticks=60)
        press = drv.shard_pressure()
        frac = press[:, 0] / 64.0
        assert (frac <= 0.8 + 1e-9).all(), frac
        occ = drv.shard_occupancy()
        ratio = occ.max() / max(occ.min(), 1)
        assert ratio <= 1.5, (ratio, occ)
        assert drv.stats["migrated"] > 0
        assert drv.live_count() == 5000
        print("OK", occ.tolist(), drv.stats["migrated"])
    """)
    assert "OK" in out


@pytest.mark.slow
def test_zipf_stream_matches_uniform_acceptance():
    """Acceptance: Zipfian-routed inserts on a multi-shard mesh keep
    max/min occupancy <= 1.5 and recall@10 within 2 points of the
    uniform-stream run."""
    out = _run("""
        import numpy as np, jax
        from repro.api import ShardedUBISDriver
        from repro.core import UBISConfig, metrics

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = UBISConfig(dim=16, max_postings=256, capacity=96,
                         max_ids=1 << 14, use_pallas="off")
        r = np.random.default_rng(5)
        K = 12
        cents = r.normal(size=(K, 16)) * 5

        def stream(kind, n=4000):
            if kind == "uniform":
                a = r.integers(0, K, n)
            else:
                w = 1.0 / (np.arange(K) + 1) ** 1.5
                a = r.choice(K, size=n, p=w / w.sum())
            return (cents[a] + r.normal(size=(n, 16))).astype(np.float32)

        results = {}
        for kind in ("uniform", "zipf"):
            data = stream(kind)
            drv = ShardedUBISDriver(cfg, data[:400], mesh=mesh,
                                    round_size=256, bg_ops_per_round=8,
                                    gc_lag=4)
            for off in range(0, 4000, 1000):
                drv.insert(data[off:off + 1000],
                           np.arange(off, off + 1000))
                drv.flush(max_ticks=20)
            drv.flush(max_ticks=60)
            q = stream(kind, 64)
            found = drv.search(q, 10).ids
            true = drv.exact(q, 10).ids
            occ = drv.shard_occupancy()
            results[kind] = dict(
                recall=metrics.recall_at_k(np.asarray(found),
                                           np.asarray(true)),
                ratio=occ.max() / max(occ.min(), 1),
                occ=occ.tolist(),
                migrated=int(drv.stats["migrated"]))
        print(results)
        assert results["zipf"]["ratio"] <= 1.5, results
        assert results["zipf"]["migrated"] > 0
        assert (results["zipf"]["recall"]
                >= results["uniform"]["recall"] - 0.02), results
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_migrate_moves_spilled_postings_without_promoting():
    """Cold tier x rebalance: a saturated shard full of SPILLED postings
    still rebalances — the migrate round carries codes + heat +
    ``tier_spilled`` verbatim (no promotion), and the driver remaps the
    host-pool entries to the landing pids.  Residency, the live
    multiset, and the exact oracle all survive."""
    out = _run("""
        import numpy as np, jax
        from repro.api import ShardedUBISDriver
        from repro.core import UBISConfig, metrics
        from repro.core import version_manager as vm

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = UBISConfig(dim=16, max_postings=256, capacity=96,
                         max_ids=1 << 14, use_pallas="off", use_pq=True,
                         pq_m=4, pq_ksub=16, rerank_k=256,
                         use_tier=True, tier_hot_max=0)
        r = np.random.default_rng(21)
        cents = r.normal(size=(4, 16)) * 4
        data = (cents[r.integers(0, 4, 3000)]
                + r.normal(size=(3000, 16))).astype(np.float32)
        drv = ShardedUBISDriver(cfg, data[:400], mesh=mesh,
                                round_size=256, bg_ops_per_round=8,
                                gc_lag=4, rebalance_watermark=0.8)
        drv.insert(data[:1500], np.arange(1500))
        # freeze the background plane's view: spill EVERY cold posting
        n_sp = drv.force_spill(10 ** 6)
        assert n_sp > 0, n_sp
        pool_before = set(int(p) for p in drv.tier.pool.pids())
        # keep inserting: the hot shard saturates and must shed postings
        drv.insert(data[1500:], np.arange(1500, 3000))
        drv.flush(max_ticks=40)
        assert drv.stats['migrated'] > 0, drv.stats
        # every pool key matches a spilled, allocated posting
        sp = np.asarray(drv.state.tier_spilled)
        alloc = np.asarray(drv.state.allocated)
        status = np.asarray(vm.unpack_status(drv.state.rec_meta))
        pool_now = set(int(p) for p in drv.tier.pool.pids())
        assert pool_now == set(np.flatnonzero(sp & alloc
                                              & (status != 3))), \
            (len(pool_now), int(sp.sum()))
        # at least one pool entry was REMAPPED (migrated while spilled)
        assert pool_now != pool_before or not pool_now
        assert drv.live_count() == 3000
        q = data[:32]
        found = drv.search(q, 10).ids
        true = drv.exact(q, 10).ids
        rec = metrics.recall_at_k(np.asarray(found), np.asarray(true))
        assert rec >= 0.9, rec
        print("OK", len(pool_now), int(drv.stats['migrated']))
    """)
    assert "OK" in out


@pytest.mark.slow
def test_pressure_aware_routing_cuts_migration_volume():
    """The ROADMAP follow-up, landed: with ``route_alpha`` on, insert
    locate penalizes saturated shards, so a Zipf-skewed stream lands
    flatter and the rebalance stage has fewer postings to migrate —
    at the same live contents and recall."""
    out = _run("""
        import numpy as np, jax
        from repro.api import ShardedUBISDriver
        from repro.core import UBISConfig, metrics

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = UBISConfig(dim=16, max_postings=256, capacity=96,
                         max_ids=1 << 14, use_pallas="off")
        r = np.random.default_rng(9)
        K = 12
        cents = r.normal(size=(K, 16)) * 5
        # a light uniform warm-up spreads postings over the pod, then a
        # maximally skewed stream hammers ONE cluster: without routing
        # every hot insert lands on that cluster's shard and rebalance
        # must keep shipping postings back out; with routing the locate
        # step deflects to colder shards once the mass gap grows
        warm = (cents[r.integers(0, K, 600)]
                + r.normal(size=(600, 16))).astype(np.float32)
        hot = (cents[0] + r.normal(size=(3400, 16))).astype(np.float32)

        migrated, stats = {}, {}
        for alpha in (0.0, 16.0):
            drv = ShardedUBISDriver(cfg, warm[:400], mesh=mesh,
                                    round_size=256, bg_ops_per_round=8,
                                    gc_lag=4, route_alpha=alpha)
            drv.insert(warm, np.arange(600))
            drv.flush(max_ticks=20)
            m0 = int(drv.stats['migrated'])       # warm-up spread moves
            for off in range(0, 3400, 425):
                drv.insert(hot[off:off + 425],
                           np.arange(600 + off, 1025 + off))
                drv.flush(max_ticks=20)
            drv.flush(max_ticks=40)
            assert drv.live_count() == 4000
            q = np.concatenate([warm[:24], hot[:24]])
            found = drv.search(q, 10).ids
            true = drv.exact(q, 10).ids
            rec = metrics.recall_at_k(np.asarray(found),
                                      np.asarray(true))
            assert rec >= 0.95, (alpha, rec)
            occ = drv.shard_occupancy()
            migrated[alpha] = int(drv.stats['migrated']) - m0
            stats[alpha] = (rec, float(occ.max() / max(occ.min(), 1)))
        print(migrated, stats)
        # routing cuts skew-phase migration volume (measured ~2x here)
        # while the final balance stays within the acceptance ratio
        assert migrated[16.0] < migrated[0.0], migrated
        assert stats[16.0][1] <= 1.5, stats
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_contract_program_on_multishard_mesh():
    """The engine-contract differential program (contract_harness) on a
    real 4-shard mesh: the random interleaving runs over the sharded
    driver with rebalance enabled, so ticks exercise the migrate round
    alongside insert/delete/search/flush — and the live multiset must
    still match the pure-Python oracle exactly."""
    out = _run("""
        import numpy as np, jax
        from contract_harness import make_clustered, run_program
        from repro.api import make_index
        from repro.core import UBISConfig

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = UBISConfig(dim=16, max_postings=256, capacity=96,
                         l_min=10, l_max=80, nprobe=256, max_ids=1 << 13,
                         cache_capacity=2048, use_pallas="off")
        data = make_clustered(2600, d=16, k=10, seed=104)
        idx = make_index("ubis-sharded", cfg, data[:300], mesh=mesh,
                         round_size=256, bg_ops_per_round=8,
                         insert_retries=4, seed=4)
        oracle, stats = run_program("ubis-sharded", idx, data, seed=4)
        assert idx.stats["migrated"] > 0, "program never migrated"
        print("OK", stats, int(idx.stats["migrated"]))
    """)
    assert "OK" in out
